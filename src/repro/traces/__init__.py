from repro.traces.generator import (synth_azure_arrays,
                                    synth_azure_trace,
                                    synth_azure_windows,
                                    trace_from_lists)

__all__ = ["synth_azure_arrays", "synth_azure_trace",
           "synth_azure_windows", "trace_from_lists"]
