from repro.traces.generator import (synth_azure_arrays,
                                    synth_azure_trace, trace_from_lists)

__all__ = ["synth_azure_arrays", "synth_azure_trace",
           "trace_from_lists"]
