"""Synthetic serverless request traces.

The paper evaluates on the Azure Functions 2021 trace [Zhang et al.,
SOSP'21] (2.2e6 requests / two weeks; first 6e5 used). That trace is not
redistributable inside this offline container, so ``synth_azure_trace``
generates a stream with the same published coarse statistics:

* function popularity ~ Zipf (a few functions dominate invocations),
* execution times ~ heavy-tailed log-normal across functions (ms .. min),
  quantised to 1 ms with the paper's "0 ms -> 1 ms" floor,
* arrivals: per-function Poisson thinned by a diurnal profile plus
  random burst windows (edge workloads are bursty, §II),
* cold-start / eviction latencies ~ U[0.5, 1.5] s (paper §VI-A, from
  ServerlessBench characterisation).

Everything is seeded and parameterised; benchmarks state their exact
parameters so results are reproducible.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.request import FunctionProfile, Request, Trace


def trace_from_lists(fn_ids: Sequence[int], arrivals: Sequence[float],
                     exec_times: Sequence[float],
                     cold: Sequence[float], evict: Sequence[float],
                     names: Optional[Sequence[str]] = None) -> Trace:
    """Build a fully explicit trace (used by unit tests / paper figures)."""
    functions = [
        FunctionProfile(j, float(c), float(v),
                        name=(names[j] if names else ""))
        for j, (c, v) in enumerate(zip(cold, evict))
    ]
    reqs = [
        Request(i, int(f), float(a), float(e))
        for i, (f, a, e) in enumerate(zip(fn_ids, arrivals, exec_times))
    ]
    # record ground-truth means for oracle mode
    for f in functions:
        mine = [r.exec_time for r in reqs if r.fn_id == f.fn_id]
        f.true_mean_exec = float(np.mean(mine)) if mine else 0.0
    return Trace(functions, reqs)


def synth_azure_trace(
    n_functions: int = 200,
    n_requests: int = 60_000,
    *,
    utilization: float = 0.8,
    capacity_ref: int = 16,
    zipf_a: float = 1.3,
    exec_median: float = 0.15,
    exec_sigma: float = 1.4,
    jitter_sigma: float = 0.25,
    cold_range: tuple = (0.5, 1.5),
    burst_frac: float = 0.3,
    n_bursts_per_fn: int = 3,
    diurnal_amp: float = 0.6,
    seed: int = 0,
) -> Trace:
    """Generate an Azure-2021-like synthetic request trace.

    ``utilization`` sets mean offered load relative to a ``capacity_ref``-
    slot server: total execution time / (duration * capacity_ref).
    """
    rng = np.random.default_rng(seed)

    # --- function catalogue ------------------------------------------------
    pop = 1.0 / np.arange(1, n_functions + 1) ** zipf_a
    pop /= pop.sum()
    base_exec = np.exp(rng.normal(np.log(exec_median), exec_sigma,
                                  n_functions))
    base_exec = np.clip(base_exec, 1e-3, 120.0)
    cold = rng.uniform(*cold_range, n_functions)
    evict = rng.uniform(*cold_range, n_functions)

    counts = rng.multinomial(n_requests, pop)

    # --- duration from target utilisation ----------------------------------
    total_exec = float((counts * base_exec).sum())
    duration = total_exec / (utilization * capacity_ref)

    # Arrival model matching the Azure trace's granularity: per-minute
    # invocation counts per function. Minute rates follow a log-normal
    # multiplicative burst process on top of a diurnal profile — bursty
    # across minutes (the paper's §II "request bursts"), Poisson within.
    day = 86_400.0
    n_min = max(int(np.ceil(duration / 60.0)), 1)
    minute_t = (np.arange(n_min) + 0.5) * 60.0
    fn_col, arr_col, exe_col = [], [], []
    for j in range(n_functions):
        n_j = int(counts[j])
        if n_j == 0:
            continue
        phase = rng.uniform(0, 2 * np.pi)
        diurnal = 1 + diurnal_amp * np.sin(2 * np.pi * minute_t / day + phase)
        # burst multiplier: most minutes ~quiet, a few minutes hot.
        sigma_b = np.log(10.0) * burst_frac * 2  # burst_frac .3 -> x10 tail
        bursts = np.exp(rng.normal(0, sigma_b, n_min))
        weights = np.clip(diurnal, 0.05, None) * bursts
        weights /= weights.sum()
        per_min = rng.multinomial(n_j, weights)
        nz = np.nonzero(per_min)[0]
        t = np.concatenate([
            (m + rng.uniform(0, 1, per_min[m])) * 60.0 for m in nz
        ]) if len(nz) else np.empty(0)
        ex = base_exec[j] * np.exp(rng.normal(0, jitter_sigma, n_j))
        ex = np.maximum(np.round(ex, 3), 1e-3)   # 1 ms quantisation + floor
        fn_col.append(np.full(n_j, j, np.int32))
        arr_col.append(t)
        exe_col.append(ex)

    fn_ids = np.concatenate(fn_col)
    arrivals = np.concatenate(arr_col)
    execs = np.concatenate(exe_col)

    functions = [FunctionProfile(j, float(cold[j]), float(evict[j]),
                                 true_mean_exec=float(base_exec[j]))
                 for j in range(n_functions)]
    reqs = [Request(i, int(f), float(a), float(e))
            for i, (f, a, e) in enumerate(zip(fn_ids, arrivals, execs))]
    meta = dict(kind="synth_azure", n_functions=n_functions,
                n_requests=len(reqs), utilization=utilization,
                duration=duration, seed=seed)
    return Trace(functions, reqs, meta)
