"""Synthetic serverless request traces.

The paper evaluates on the Azure Functions 2021 trace [Zhang et al.,
SOSP'21] (2.2e6 requests / two weeks; first 6e5 used). That trace is not
redistributable inside this offline container, so ``synth_azure_trace``
generates a stream with the same published coarse statistics:

* function popularity ~ Zipf (a few functions dominate invocations),
* execution times ~ heavy-tailed log-normal across functions (ms .. min),
  quantised to 1 ms with the paper's "0 ms -> 1 ms" floor,
* arrivals: per-function Poisson thinned by a diurnal profile plus
  random burst windows (edge workloads are bursty, §II),
* cold-start / eviction latencies ~ U[0.5, 1.5] s (paper §VI-A, from
  ServerlessBench characterisation).

Everything is seeded and parameterised; benchmarks state their exact
parameters so results are reproducible. ``synth_azure_arrays`` is the
columnar fast path: the same sampler, but the result stays in (sorted)
numpy arrays — at 10^6 requests the ``Request``-object representation
costs hundreds of MB and seconds of pure-Python loops that the
vectorised engine never needs (benchmarks/engine_scale.py).
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.request import FunctionProfile, Request, Trace


def trace_from_lists(fn_ids: Sequence[int], arrivals: Sequence[float],
                     exec_times: Sequence[float],
                     cold: Sequence[float], evict: Sequence[float],
                     names: Optional[Sequence[str]] = None) -> Trace:
    """Build a fully explicit trace (used by unit tests / paper figures)."""
    functions = [
        FunctionProfile(j, float(c), float(v),
                        name=(names[j] if names else ""))
        for j, (c, v) in enumerate(zip(cold, evict))
    ]
    reqs = [
        Request(i, int(f), float(a), float(e))
        for i, (f, a, e) in enumerate(zip(fn_ids, arrivals, exec_times))
    ]
    # record ground-truth means for oracle mode
    for f in functions:
        mine = [r.exec_time for r in reqs if r.fn_id == f.fn_id]
        f.true_mean_exec = float(np.mean(mine)) if mine else 0.0
    return Trace(functions, reqs)


def _sample_azure(
    n_functions: int,
    n_requests: int,
    *,
    utilization: float,
    capacity_ref: int,
    zipf_a: float,
    exec_median: float,
    exec_sigma: float,
    jitter_sigma: float,
    cold_range: tuple,
    burst_frac: float,
    diurnal_amp: float,
    seed: int,
    n_bursts_per_fn: int = 3,   # legacy knob, accepted and unused
):
    """Shared sampler: unsorted request columns + function catalogue."""
    rng = np.random.default_rng(seed)

    # --- function catalogue ------------------------------------------------
    pop = 1.0 / np.arange(1, n_functions + 1) ** zipf_a
    pop /= pop.sum()
    base_exec = np.exp(rng.normal(np.log(exec_median), exec_sigma,
                                  n_functions))
    base_exec = np.clip(base_exec, 1e-3, 120.0)
    cold = rng.uniform(*cold_range, n_functions)
    evict = rng.uniform(*cold_range, n_functions)

    counts = rng.multinomial(n_requests, pop)

    # --- duration from target utilisation ----------------------------------
    total_exec = float((counts * base_exec).sum())
    duration = total_exec / (utilization * capacity_ref)

    # Arrival model matching the Azure trace's granularity: per-minute
    # invocation counts per function. Minute rates follow a log-normal
    # multiplicative burst process on top of a diurnal profile — bursty
    # across minutes (the paper's §II "request bursts"), Poisson within.
    day = 86_400.0
    n_min = max(int(np.ceil(duration / 60.0)), 1)
    minute_t = (np.arange(n_min) + 0.5) * 60.0
    fn_col, arr_col, exe_col = [], [], []
    for j in range(n_functions):
        n_j = int(counts[j])
        if n_j == 0:
            continue
        phase = rng.uniform(0, 2 * np.pi)
        diurnal = 1 + diurnal_amp * np.sin(2 * np.pi * minute_t / day + phase)
        # burst multiplier: most minutes ~quiet, a few minutes hot.
        sigma_b = np.log(10.0) * burst_frac * 2  # burst_frac .3 -> x10 tail
        bursts = np.exp(rng.normal(0, sigma_b, n_min))
        weights = np.clip(diurnal, 0.05, None) * bursts
        weights /= weights.sum()
        per_min = rng.multinomial(n_j, weights)
        nz = np.nonzero(per_min)[0]
        t = np.concatenate([
            (m + rng.uniform(0, 1, per_min[m])) * 60.0 for m in nz
        ]) if len(nz) else np.empty(0)
        ex = base_exec[j] * np.exp(rng.normal(0, jitter_sigma, n_j))
        ex = np.maximum(np.round(ex, 3), 1e-3)   # 1 ms quantisation + floor
        fn_col.append(np.full(n_j, j, np.int32))
        arr_col.append(t)
        exe_col.append(ex)

    fn_ids = np.concatenate(fn_col)
    arrivals = np.concatenate(arr_col)
    execs = np.concatenate(exe_col)
    return fn_ids, arrivals, execs, cold, evict, base_exec, duration


_AZURE_DEFAULTS = dict(
    utilization=0.8, capacity_ref=16, zipf_a=1.3, exec_median=0.15,
    exec_sigma=1.4, jitter_sigma=0.25, cold_range=(0.5, 1.5),
    burst_frac=0.3, diurnal_amp=0.6, seed=0,
)


def synth_azure_trace(n_functions: int = 200, n_requests: int = 60_000,
                      **kw) -> Trace:
    """Generate an Azure-2021-like synthetic request trace.

    ``utilization`` sets mean offered load relative to a
    ``capacity_ref``-slot server: total execution time /
    (duration * capacity_ref).
    """
    params = dict(_AZURE_DEFAULTS)
    params.update(kw)
    seed = params["seed"]
    utilization = params["utilization"]
    fn_ids, arrivals, execs, cold, evict, base_exec, duration = \
        _sample_azure(n_functions, n_requests, **params)

    functions = [FunctionProfile(j, float(cold[j]), float(evict[j]),
                                 true_mean_exec=float(base_exec[j]))
                 for j in range(n_functions)]
    reqs = [Request(i, int(f), float(a), float(e))
            for i, (f, a, e) in enumerate(zip(fn_ids, arrivals, execs))]
    meta = dict(kind="synth_azure", n_functions=n_functions,
                n_requests=len(reqs), utilization=utilization,
                duration=duration, seed=seed)
    return Trace(functions, reqs, meta)


def synth_azure_arrays(n_functions: int = 200,
                       n_requests: int = 60_000, **kw) -> dict:
    """Columnar ``synth_azure_trace``: the ``Trace.to_arrays()`` layout
    (arrival-sorted, ids by position) without materialising Request
    objects — identical arrays to
    ``synth_azure_trace(...).to_arrays()`` for the same parameters."""
    params = dict(_AZURE_DEFAULTS)
    params.update(kw)
    fn_ids, arrivals, execs, cold, evict, _, _ = \
        _sample_azure(n_functions, n_requests, **params)
    # Trace sorts by (arrival, req_id) with req_id assigned in
    # generation order — a stable arrival sort is the same permutation
    order = np.argsort(arrivals, kind="stable")
    return dict(fn_id=fn_ids[order].astype(np.int32),
                arrival=arrivals[order].astype(np.float64),
                exec_time=execs[order].astype(np.float64),
                cold_start=np.asarray(cold, np.float64),
                evict=np.asarray(evict, np.float64))


def synth_azure_windows(n_functions: int = 200,
                        n_requests: int = 60_000, *,
                        window: int = 65_536, **kw):
    """Windowed columnar emission: yield ``synth_azure_arrays`` output
    in time-ordered slabs of ``window`` requests.

    Each yielded dict carries the per-window request columns (views
    into the sorted arrays — ``fn_id`` / ``arrival`` / ``exec_time``),
    the shared function catalogue (``cold_start`` / ``evict``) and the
    window's request-id ``base``; concatenating the windows reproduces
    ``synth_azure_arrays`` exactly. This is the producer-side mirror of
    the engine's cache-window slabs (`repro.core.jax_engine`,
    perf-contract rule 6): consumers that stream a trace window by
    window — npz shard writers, slab prefetchers, out-of-core pipelines
    feeding traces bigger than memory — get the same time-ordered
    id-range partitioning the engine's event loop uses.
    """
    a = synth_azure_arrays(n_functions, n_requests, **kw)
    n = len(a["fn_id"])
    for base in range(0, n, int(window)):
        end = min(base + int(window), n)
        yield dict(base=base,
                   fn_id=a["fn_id"][base:end],
                   arrival=a["arrival"][base:end],
                   exec_time=a["exec_time"][base:end],
                   cold_start=a["cold_start"],
                   evict=a["evict"])
