"""Labeled experiment results: the `ResultSet`.

Every metric array carries the grid axes ``(policy, trace, capacity,
beta)`` in that order — plus a trailing ``cluster`` axis when the
producing `ExperimentSpec` declared one (`repro.cluster.ClusterSpec`
entries; its coords are the entries' router-first labels). Trailing
metric-specific dims — histogram bins, timeline bins, per-node counts,
per-request N — follow the grid axes, with the axis values in
``coords``. Selection (`sel` / `value`), tidy-row iteration (`rows`),
CSV emission (`to_csv`) and an npz round-trip (`save_npz`/`load_npz`)
replace the per-benchmark CSV/dict plumbing; `merge` reassembles
``host_shard`` partials computed on different machines. A ``computed``
mask tracks which grid cells this ResultSet actually holds (all of
them unless the producing run was host-sharded).
"""
from __future__ import annotations

import csv
import io
import json
import sys
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

DIMS = ("policy", "trace", "capacity", "beta")
CLUSTER_DIM = "cluster"     # optional trailing axis of cluster runs

# metrics that must be zero on every computed cell for a run to be
# valid (mirrors the overflow/stalled checks the figure scripts used
# to hand-roll)
HEALTH_METRICS = ("overflow", "stalled")


@dataclass
class ResultSet:
    """Metric arrays over the labeled experiment grid."""

    data: Dict[str, np.ndarray]
    coords: Dict[str, list]
    computed: Optional[np.ndarray] = None    # (P, T, K, B) bool
    meta: dict = field(default_factory=dict)
    # per-cell event streams of a trace_events=True run
    # (`repro.telemetry.TraceRun`); not part of the npz payload —
    # export separately with `trace.save_npz`
    trace: Optional[object] = None

    def __post_init__(self):
        shape = self.grid_shape
        nd = len(shape)
        if self.computed is None:
            self.computed = np.ones(shape, bool)
        for k, v in self.data.items():
            if tuple(v.shape[:nd]) != shape:
                raise ValueError(
                    f"ResultSet: metric {k!r} shape {v.shape} does not "
                    f"lead with the grid shape {shape}")

    # ----------------------------------------------------------- basics
    @property
    def dims(self):
        """Grid axis names: the four core dims, plus ``cluster`` when
        the producing spec declared a cluster axis."""
        return (DIMS + (CLUSTER_DIM,) if CLUSTER_DIM in self.coords
                else DIMS)

    @property
    def grid_shape(self):
        return tuple(len(self.coords[d]) for d in self.dims)

    @property
    def metrics(self) -> List[str]:
        return sorted(self.data)

    def __getitem__(self, metric: str) -> np.ndarray:
        try:
            return self.data[metric]
        except KeyError:
            raise KeyError(f"ResultSet: no metric {metric!r}; have "
                           f"{self.metrics}") from None

    def __contains__(self, metric: str) -> bool:
        return metric in self.data

    # -------------------------------------------------------- selection
    def _axis_indices(self, dim: str, want) -> List[int]:
        values = self.coords[dim]
        singular = not isinstance(want, (list, tuple, np.ndarray))
        wants = [want] if singular else list(want)
        idx = []
        for w in wants:
            matches = [i for i, v in enumerate(values)
                       if v == w or (isinstance(v, float)
                                     and isinstance(w, (int, float))
                                     and float(v) == float(w))]
            if not matches:
                raise KeyError(
                    f"ResultSet.sel: {dim}={w!r} not on the {dim} axis "
                    f"{values}")
            if singular and len(matches) > 1:
                raise KeyError(
                    f"ResultSet.sel: {dim}={w!r} is ambiguous "
                    f"({len(matches)} axis entries match) — pass a "
                    f"list to select all of them")
            idx.extend(matches)
        return idx

    def sel(self, **which) -> "ResultSet":
        """Subset by coordinate *value* (scalar or list per dim), e.g.
        ``rs.sel(policy="esff", capacity=[8, 16])``. Axes are retained
        (scalar selections become size-1) so any selection round-trips
        through ``save_npz``/``merge``; use `value` for one cell."""
        dims = self.dims
        unknown = set(which) - set(dims)
        if unknown:
            raise KeyError(f"ResultSet.sel: unknown dim(s) "
                           f"{sorted(unknown)}; dims are {dims}")
        index = [slice(None)] * len(dims)
        coords = dict(self.coords)
        for d, want in which.items():
            ax = dims.index(d)
            ids = self._axis_indices(d, want)
            index[ax] = ids
            coords[d] = [self.coords[d][i] for i in ids]
        data = {}
        for k, v in self.data.items():
            out = v
            for ax, ids in enumerate(index):
                if not isinstance(ids, slice):
                    out = np.take(out, ids, axis=ax)
            data[k] = out
        comp = self.computed
        for ax, ids in enumerate(index):
            if not isinstance(ids, slice):
                comp = np.take(comp, ids, axis=ax)
        return ResultSet(data=data, coords=coords, computed=comp,
                         meta=dict(self.meta))

    def value(self, metric: str, **which):
        """The one cell of ``metric`` selected by ``which`` (every grid
        axis must resolve to a single entry). Returns a python scalar
        for scalar metrics, an ndarray for metrics with trailing dims
        (``resp_hist``, ``tl_*``, ``response``)."""
        sub = self.sel(**which) if which else self
        nd = len(sub.dims)
        if sub.grid_shape != (1,) * nd:
            raise KeyError(
                f"ResultSet.value({metric!r}): selection leaves grid "
                f"{dict(zip(sub.dims, sub.grid_shape))}, need exactly "
                "one cell — add coords")
        if not sub.computed.reshape(-1)[0]:
            raise ValueError(
                f"ResultSet.value({metric!r}): cell not computed (this "
                "is a host shard — merge() the other shards first)")
        cell = sub[metric][(0,) * nd]
        return cell.item() if np.ndim(cell) == 0 else np.asarray(cell)

    # -------------------------------------------------------- telemetry
    def timeline(self, bucket: float = 60.0, *, deadlines=None,
                 **sel) -> Dict[str, np.ndarray]:
        """Streaming per-bin time series of one traced grid cell.

        Requires a run with ``trace_events=True`` (the attached
        `repro.telemetry.TraceRun`). ``sel`` selects one cell exactly
        like `value` (axes of length one resolve implicitly); returns
        the `repro.telemetry.metrics.timeline` dict — per-node queue
        depth, warm occupancy, utilization, throughput, goodput and
        SLO attainment per ``bucket``-second bin. ``deadlines``
        defaults to the producing spec's (from ``meta``)."""
        if self.trace is None:
            raise ValueError(
                "ResultSet.timeline: no event streams attached — run "
                "with ExperimentSpec(trace_events=True)")
        from repro.telemetry import metrics as _tmet
        ev = self.trace.events(**sel)
        key = self.trace._cell_key(**sel)
        tr_coords = self.trace.coords
        cap = None
        if "capacity" in tr_coords:
            c = tr_coords["capacity"][
                key[list(tr_coords).index("capacity")]]
            if isinstance(c, (int, np.integer)):
                cap = int(c)
        if deadlines is None:
            deadlines = self.meta.get("deadlines")
        return _tmet.timeline(ev, bucket=bucket, capacity=cap,
                              deadlines=deadlines)

    # ------------------------------------------------------- tidy rows
    def rows(self, metrics: Optional[Sequence[str]] = None
             ) -> Iterator[dict]:
        """Tidy iteration: one dict per computed grid cell carrying
        every grid coordinate (the four core dims, plus ``cluster``
        when the producing spec declared one) and every scalar metric
        (vector metrics are skipped unless named explicitly in
        ``metrics``)."""
        dims = self.dims
        names = list(metrics) if metrics is not None else [
            m for m in self.metrics if self.data[m].ndim == len(dims)]
        for cell_ix in np.ndindex(*self.grid_shape):
            if not self.computed[cell_ix]:
                continue
            row = {d: self.coords[d][i]
                   for d, i in zip(dims, cell_ix)}
            for m in names:
                cell = self.data[m][cell_ix]
                row[m] = (cell.item() if np.ndim(cell) == 0
                          else np.asarray(cell))
            yield row

    def to_csv(self, out=None,
               metrics: Optional[Sequence[str]] = None) -> str:
        """Write the tidy rows as CSV to ``out`` (path, file object, or
        None for stdout); returns the header line for convenience."""
        rows = list(self.rows(metrics))
        if not rows:
            raise ValueError("ResultSet.to_csv: no computed cells")
        header = list(rows[0].keys())

        def _write(fh):
            w = csv.DictWriter(fh, fieldnames=header)
            w.writeheader()
            for r in rows:
                w.writerow({k: (f"{v:.6g}" if isinstance(v, float)
                                else v) for k, v in r.items()})
        if out is None:
            _write(sys.stdout)
        elif isinstance(out, (str, bytes)) or hasattr(out, "__fspath__"):
            with open(out, "w", newline="") as fh:
                _write(fh)
        else:
            _write(out)
        return ",".join(header)

    # ----------------------------------------------------------- health
    def _cell_label(self, cell_ix) -> str:
        """One grid cell's full spec coordinate, e.g.
        ``policy='esff', trace='zipf[n8000]', capacity=16,
        beta='default'`` (plus ``cluster=...`` on cluster grids)."""
        return ", ".join(f"{d}={self.coords[d][i]!r}"
                         for d, i in zip(self.dims, cell_ix))

    def _bad_cells(self, bad: np.ndarray, limit: int = 8) -> str:
        cells = np.argwhere(bad)[:limit]
        named = "; ".join(self._cell_label(tuple(c)) for c in cells)
        more = int(bad.sum()) - len(cells)
        return named + (f"; ... {more} more" if more > 0 else "")

    def check(self) -> "ResultSet":
        """Raise if any computed cell is invalid; returns self for
        chaining.

        Invalid means: nonzero ``overflow`` (a queue overran with
        shedding *disabled* — requests silently dropped; deliberate
        drops under ``on_overflow="shed"``/``"shed_oldest"`` land in
        the ``shed`` counter instead and are by design, never an
        error), nonzero ``stalled`` (the event loop hit its iteration
        cap — an engine invariant violation), or — on fault-injected
        runs — a broken conservation identity
        ``done + shed + failed_exhausted != n_requests``. Every error
        names the offending cells by their full spec coordinate."""
        resil = self.meta.get("resilience") or None
        for m in HEALTH_METRICS:
            if m not in self.data:
                continue
            bad = (self.data[m] != 0) & self.computed
            if not bad.any():
                continue
            if m == "overflow":
                hint = ("queue overran with shedding disabled — "
                        "requests were dropped. Raise queue_cap, or "
                        "opt into load shedding with "
                        'ExperimentSpec(on_overflow="shed" / '
                        '"shed_oldest") to count drops as `shed` '
                        "by design")
            else:
                hint = ("event loop hit its iteration cap before "
                        "draining — engine invariant violation")
            raise RuntimeError(
                f"ResultSet.check: {int(bad.sum())} cell(s) with "
                f"nonzero {m!r} ({hint}): {self._bad_cells(bad)}")
        if resil is not None and "n_requests" in self.meta:
            need = ("done", "shed", "failed_exhausted")
            if all(k in self.data for k in need):
                n = int(self.meta["n_requests"])
                tot = sum(self.data[k].astype(np.int64) for k in need)
                bad = (tot != n) & self.computed
                if bad.any():
                    raise RuntimeError(
                        f"ResultSet.check: {int(bad.sum())} cell(s) "
                        f"break conservation (done + shed + "
                        f"failed_exhausted != n_requests={n}): "
                        f"{self._bad_cells(bad)}")
        return self

    # -------------------------------------------------------- npz io
    def save_npz(self, path) -> None:
        payload = {f"m_{k}": v for k, v in self.data.items()}
        payload["computed"] = self.computed
        payload["coords_json"] = np.frombuffer(
            json.dumps(self.coords).encode(), np.uint8)
        payload["meta_json"] = np.frombuffer(
            json.dumps(self.meta, default=str).encode(), np.uint8)
        np.savez_compressed(path, **payload)

    @staticmethod
    def load_npz(path) -> "ResultSet":
        with np.load(path) as z:
            data = {k[2:]: z[k] for k in z.files if k.startswith("m_")}
            coords = json.loads(bytes(z["coords_json"]).decode())
            meta = json.loads(bytes(z["meta_json"]).decode())
            computed = np.asarray(z["computed"], bool)
        return ResultSet(data=data, coords=coords, computed=computed,
                         meta=meta)

    # ----------------------------------------------------------- merge
    def merge(self, *others: "ResultSet") -> "ResultSet":
        """Combine host-sharded partial ResultSets over the same grid.

        Shards must share coords and metric sets; each grid cell must
        be computed by at most one shard (the runner's ``host_shard``
        partitioning guarantees it). Returns a new ResultSet whose
        computed mask is the union."""
        merged = ResultSet(
            data={k: v.copy() for k, v in self.data.items()},
            coords={k: list(v) for k, v in self.coords.items()},
            computed=self.computed.copy(), meta=dict(self.meta))
        for o in others:
            if o.coords != merged.coords:
                raise ValueError("ResultSet.merge: coords differ — "
                                 "shards must come from the same spec")
            if set(o.data) != set(merged.data):
                raise ValueError(
                    f"ResultSet.merge: metric sets differ "
                    f"({sorted(set(o.data) ^ set(merged.data))})")
            overlap = merged.computed & o.computed
            if overlap.any():
                raise ValueError(
                    f"ResultSet.merge: {int(overlap.sum())} cell(s) "
                    "computed by more than one shard")
            take = o.computed
            for k in merged.data:
                merged.data[k][take] = o.data[k][take]
            merged.computed |= take
        return merged

    # ------------------------------------------------------------ repr
    def __repr__(self):
        shape = self.grid_shape
        done = int(self.computed.sum())
        axes = ", ".join(f"{d}={n}"
                         for d, n in zip(self.dims, shape))
        return (f"ResultSet({axes}; {done}/{int(np.prod(shape))} "
                f"cells, metrics={self.metrics})")

    def summary(self) -> str:
        """Small human-readable table of mean_response per cell."""
        buf = io.StringIO()
        self.to_csv(buf, metrics=["mean_response"])
        return buf.getvalue()
