"""Declarative experiment API for the scheduling engine.

The spec -> run -> results triple (plus the policy registry) is the
supported way to drive the vectorised engine::

    from repro.api import ExperimentSpec, SyntheticTrace, run

    spec = ExperimentSpec(
        traces=[SyntheticTrace.make(n_functions=60, n_requests=8_000,
                                    seed=4, utilization=0.3)],
        policies=("esff", "sff"), capacities=(8, 16, 32))
    rs = run(spec).check()
    print(rs.value("mean_response", policy="esff", capacity=16))
    rs.to_csv("grid.csv"); rs.save_npz("grid.npz")

See docs/api.md for the full tour (trace sources, device/host
sharding, custom-policy registration).
"""
from repro.api.registry import (available_policies, get_kernel,
                                register_policy, unregister_policy)
from repro.api.results import ResultSet
from repro.api.runner import run, run_experiment
from repro.api.spec import (ArrayTrace, ExperimentSpec, NpzTrace,
                            SyntheticTrace, TraceSource,
                            as_trace_source)
from repro.cluster import (ClusterSpec, DelaySchedule, PeriodicChurn,
                           available_routers, get_router,
                           register_router, unregister_router)
from repro.core.resilience import RetryPolicy

__all__ = [
    "ExperimentSpec", "TraceSource", "SyntheticTrace", "NpzTrace",
    "ArrayTrace", "as_trace_source", "ResultSet", "run",
    "run_experiment", "register_policy", "unregister_policy",
    "get_kernel", "available_policies", "ClusterSpec",
    "PeriodicChurn", "DelaySchedule", "RetryPolicy",
    "register_router", "unregister_router", "get_router",
    "available_routers",
]
