"""Lower an `ExperimentSpec` onto the vectorised engine, sharded.

The grid is flattened exactly the way the legacy ``sweep`` flattened
it — per policy, lanes ordered trace-major, then capacity, then beta,
split into `resolve_lane_chunk`-sized chunks — so the deprecation shim
is bitwise-identical by construction and the jit cache stays warm
across both surfaces. On top of that lowering this runner adds the
scale-out halves the ROADMAP called for:

* **device sharding** — lane chunks round-robin over
  ``jax.local_devices()`` (capped by ``spec.devices``); each device
  gets its own copy of the shared trace operands once, and chunk
  inputs are committed to their device so XLA runs the per-device
  calls concurrently. Lanes are embarrassingly parallel and the engine
  is deterministic per lane, so a multi-device run is bitwise
  identical to the single-device run — gated by the 2-device CPU
  parity checks in ``benchmarks/run.py --smoke`` and
  ``tests/test_api.py``.
* **host sharding** — ``spec.host_shard=(i, n)`` keeps only chunks
  ``i, i+n, i+2n, ...`` of the global chunk list; the resulting
  partial `ResultSet` marks the rest uncomputed and
  `ResultSet.merge` reassembles the full grid from all hosts' shards.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Dict

import numpy as np

from repro.api.registry import get_kernel
from repro.api.results import ResultSet
from repro.api.spec import ExperimentSpec

_BETA_DEFAULT = "default"

# Multi-trace grids whose stacked (T, N) trace operands exceed this
# many elements run one trace row per engine call instead: inside the
# lanes' vmap a (T, N) operand is a *batched* gather operand, and
# XLA:CPU drops batched multi-element gathers onto its generic
# (~25x slower) path once the operand outgrows cache scale. Per-row
# calls keep every shared operand (1, N) — the fast path — and
# per-lane outputs depend only on the lane's own trace row, so the
# grouped grid is bitwise the stacked one (gated in tests/test_api.py).
ROW_SPLIT_ELEMS = 1 << 16


def _unique_labels(labels):
    """Disambiguate repeated source labels positionally (``#k`` suffix)
    so ResultSet coordinate selection stays unambiguous — e.g. four
    same-shape inline traces all labeled ``trace[n5000]`` become
    ``trace[n5000]``, ``trace[n5000]#1``, ..."""
    seen: Dict[str, int] = {}
    out = []
    for lab in labels:
        k = seen.get(lab, 0)
        seen[lab] = k + 1
        out.append(lab if k == 0 else f"{lab}#{k}")
    return out


def _lower_grid(spec: ExperimentSpec):
    """Materialise sources and build the per-policy lane layout."""
    sources = spec.expanded_traces()
    arrs = [src.arrays() for src in sources]
    F = len(arrs[0]["cold_start"])
    N = len(arrs[0]["fn_id"])
    for src, a in zip(sources, arrs):
        if len(a["cold_start"]) != F or len(a["fn_id"]) != N:
            raise ValueError(
                f"ExperimentSpec traces must share shape "
                f"(n_functions, n_requests): {src.label} has "
                f"({len(a['cold_start'])}, {len(a['fn_id'])}), "
                f"{sources[0].label} has ({F}, {N})")
    stacked = {k: np.stack([np.asarray(a[k]) for a in arrs])
               for k in ("fn_id", "arrival", "exec_time", "cold_start",
                         "evict")}
    return sources, stacked, F, N


def _chunk_plan(spec: ExperimentSpec, T: int, chunk: int,
                row_split: bool = False):
    """The global chunk list [(policy_index, lane_lo, lane_hi)] in the
    legacy sweep order (policy-major; lanes trace-major, then capacity,
    then beta). Under ``row_split`` chunks additionally never cross a
    trace boundary, so each engine call sees lanes of a single trace
    row."""
    K = len(spec.capacities)
    B = 1 if spec.betas is None else len(spec.betas)
    bounds = ([(t * K * B, (t + 1) * K * B) for t in range(T)]
              if row_split else [(0, T * K * B)])
    plan = []
    for pi in range(len(spec.policies)):
        for blo, bhi in bounds:
            for lo in range(blo, bhi, chunk):
                plan.append((pi, lo, min(lo + chunk, bhi)))
    return plan, K, B


def run_experiment(spec: ExperimentSpec) -> ResultSet:
    """Execute ``spec`` and return its labeled `ResultSet`.

    A spec with a ``cluster`` axis is delegated to
    `repro.cluster.runner.run_cluster_experiment`, which stacks one
    (policy, trace, capacity, beta) grid per cluster topology into the
    ResultSet's trailing ``cluster`` dim."""
    import jax
    import jax.numpy as jnp

    from repro.core.jax_engine import _sweep_metrics, resolve_lane_chunk

    if spec.cluster is not None:
        from repro.cluster.runner import run_cluster_experiment
        return run_cluster_experiment(spec)

    spec.validate()
    sources, stacked, F, N = _lower_grid(spec)
    rs = spec.resilience_ops(stacked, F)
    resil = None
    if rs is not None:
        # faults on: the effective (timeout-clipped) exec times replace
        # the exec operand; the pre-planned outcome operands ride the
        # same per-device / per-row slicing as the trace operands
        eff, rs_nfail, rs_tmo, rs_key, resil = rs
        stacked = dict(stacked, exec_time=eff)
    T = len(sources)
    C = max(spec.capacities)
    masks = np.stack([np.arange(C) < c for c in spec.capacities])
    chunk = resolve_lane_chunk(spec.lane_chunk)
    row_split = T > 1 and T * N > ROW_SPLIT_ELEMS
    plan, K, B = _chunk_plan(spec, T, chunk, row_split)

    host_i, host_n = spec.host_shard
    mine = [ci for ci in range(len(plan)) if ci % host_n == host_i]
    if not mine:
        raise ValueError(
            f"ExperimentSpec: host_shard={spec.host_shard} gets no "
            f"chunks (the grid lowers to {len(plan)} chunk(s) of "
            f"{chunk} lanes — lower host count or lane_chunk)")

    devs = jax.local_devices()
    if spec.devices is not None:
        if spec.devices > len(devs):
            raise ValueError(
                f"ExperimentSpec: devices={spec.devices} but only "
                f"{len(devs)} local device(s) present")
        devs = devs[: spec.devices]
    if spec.trace_events:
        # traced chunks run serially on the default device so the
        # ordered-callback flushes of different chunks cannot
        # interleave in one collect scope
        devs = devs[:1]
    multi_dev = len(devs) > 1

    # shared (T, ...) trace operands — one committed copy per device
    # (a single uncommitted copy when not sharding, matching the legacy
    # single-device path exactly)
    shared0 = {k: jnp.asarray(v) for k, v in stacked.items()}
    if rs is not None:
        shared0["rs_nfail"] = jnp.asarray(rs_nfail, jnp.int32)
        shared0["rs_tmo"] = jnp.asarray(rs_tmo)
        shared0["rs_key"] = jnp.asarray(rs_key, jnp.int32)
    if multi_dev:
        shared_per_dev = [
            {k: jax.device_put(v, d) for k, v in shared0.items()}
            for d in devs]
    else:
        shared_per_dev = [shared0]

    kernels = {p: get_kernel(p) for p in spec.policies}
    dl = spec.deadline_ops(F)
    dl_op = None if dl is None else jnp.asarray(dl)

    # per-policy lane coordinate columns (identical for every policy:
    # betas=None resolves per kernel at chunk build time)
    tix_col = np.repeat(np.arange(T, dtype=np.int32), K * B)
    mask_col = np.tile(np.repeat(masks, B, axis=0), (T, 1))

    def beta_col(policy: str) -> np.ndarray:
        bs = np.asarray(
            [kernels[policy].default_beta] if spec.betas is None
            else list(spec.betas), np.float64)
        return np.tile(bs, T * K)

    beta_cols = {p: beta_col(p) for p in spec.policies}

    def run_chunk(ci: int):
        pi, lo, hi = plan[ci]
        policy = spec.policies[pi]
        di = mine.index(ci) % len(devs)
        sh = shared_per_dev[di]
        tix_l = jnp.asarray(tix_col[lo:hi])
        if row_split:
            # single-trace chunk: slice the shared operands to this
            # chunk's trace row and renumber the lanes' trace index
            t0 = int(tix_col[lo])
            sh = {k: v[t0:t0 + 1] for k, v in sh.items()}
            tix_l = jnp.zeros((hi - lo,), jnp.int32)
        mask_l = jnp.asarray(mask_col[lo:hi])
        beta_l = jnp.asarray(beta_cols[policy][lo:hi])
        if multi_dev:
            dev = devs[di]
            tix_l = jax.device_put(tix_l, dev)
            mask_l = jax.device_put(mask_l, dev)
            beta_l = jax.device_put(beta_l, dev)
        out = _sweep_metrics(
            sh["fn_id"], sh["arrival"], sh["exec_time"],
            sh["cold_start"], sh["evict"], tix_l, mask_l, beta_l,
            jnp.float64(spec.prior), jnp.float64(spec.threshold),
            deadlines=dl_op,
            rs_nfail=sh.get("rs_nfail"), rs_tmo=sh.get("rs_tmo"),
            rs_key=sh.get("rs_key"), resil=resil,
            kernel=kernels[policy], n_fns=F, capacity=C,
            queue_cap=spec.queue_cap, stream=spec.stream,
            window=spec.window, tl_bins=spec.tl_bins,
            tl_bucket=spec.tl_bucket,
            keep_responses=spec.keep_per_request,
            trace=spec.trace_events)
        return ci, jax.device_get(out)

    if spec.trace_events:
        # one collect scope per chunk: device_get inside run_chunk
        # blocks, so every ordered flush lands before the scope closes
        from repro.telemetry import rail
        outs = {}
        lane_events: Dict[tuple, dict] = {}
        for ci in mine:
            with rail.collect() as sink:
                _, out = run_chunk(ci)
            outs[ci] = out
            pi, lo, hi = plan[ci]
            for j in range(hi - lo):
                lane_events[(pi, lo + j)] = sink.lane_events(j)
    else:
        # device calls overlap on the host thread pool (XLA releases
        # the GIL while a computation runs); at least 2 workers even on
        # one device so transfer/compile of chunk k+1 hides behind
        # chunk k
        workers = max(2, len(devs))
        with ThreadPoolExecutor(max_workers=workers) as tp:
            outs = dict(tp.map(run_chunk, mine))

    # ------------------------------------------------------- assembly
    P = len(spec.policies)
    lanes_per_policy = T * K * B
    flat: Dict[str, np.ndarray] = {}
    computed = np.zeros((P, lanes_per_policy), bool)
    for ci in mine:
        pi, lo, hi = plan[ci]
        out = outs[ci]
        for k, v in out.items():
            v = np.asarray(v)
            if k not in flat:
                flat[k] = np.zeros((P, lanes_per_policy) + v.shape[1:],
                                   v.dtype)
            flat[k][pi, lo:hi] = v
        computed[pi, lo:hi] = True

    grid = lambda a: a.reshape((P, T, K, B) + a.shape[2:])  # noqa: E731
    data = {k: grid(v) for k, v in flat.items()}
    if dl is not None:
        from repro.core.jax_engine import slo_attainment
        data["slo_attainment"] = slo_attainment(
            data["deadline_miss"], data["done"])
    if resil is not None:
        from repro.core.jax_engine import goodput
        data["goodput"] = goodput(data["done"], N)
    beta_coord = (list(spec.betas) if spec.betas is not None
                  else [_BETA_DEFAULT])
    coords = dict(policy=list(spec.policies),
                  trace=_unique_labels([s.label for s in sources]),
                  capacity=list(spec.capacities),
                  beta=beta_coord)
    meta = dict(spec.meta,
                n_requests=N, n_functions=F, queue_cap=spec.queue_cap,
                stream=spec.stream, window=spec.window,
                tl_bins=spec.tl_bins, tl_bucket=spec.tl_bucket,
                prior=spec.prior, threshold=spec.threshold,
                lane_chunk=chunk, host_shard=list(spec.host_shard),
                row_split=row_split,
                deadlines=(None if dl is None else
                           (spec.deadlines
                            if isinstance(spec.deadlines, float)
                            else list(spec.deadlines))),
                n_devices=len(devs), backend=jax.default_backend(),
                resilience=spec.resilience_meta(),
                seeds=(list(spec.seeds) if spec.seeds is not None
                       else None),
                trace_events=spec.trace_events,
                default_betas={p: kernels[p].default_beta
                               for p in spec.policies})
    trace_run = None
    if spec.trace_events:
        from repro.telemetry.spans import TraceRun
        trace_run = TraceRun(coords)
        for (pi, lane), ev in lane_events.items():
            t_i, rest = divmod(lane, K * B)
            kc, b = divmod(rest, B)
            trace_run.add_cell((pi, t_i, kc, b), ev)
    return ResultSet(data=data, coords=coords,
                     computed=grid(computed), meta=meta,
                     trace=trace_run)


# short alias — `from repro.api import run`
run = run_experiment


def legacy_sweep_dict(rs: ResultSet, n_traces: int) -> dict:
    """Convert a ResultSet into the legacy ``sweep()`` return layout
    (metric arrays keyed by name + the ad-hoc ``"axes"`` dict) for the
    deprecation shim."""
    out = {k: v for k, v in rs.data.items() if k != "response"}
    betas = rs.coords["beta"]
    out["axes"] = dict(policy=list(rs.coords["policy"]),
                       trace=n_traces,
                       capacity=list(rs.coords["capacity"]),
                       beta=(None if betas == [_BETA_DEFAULT]
                             else list(betas)))
    return out


# ---------------------------------------------------------- audit hooks
def jit_cache_sizes() -> Dict[str, int]:
    """Jit cache sizes of every engine entry point (single-node +
    cluster tiers), for `repro.analysis`'s recompilation auditor: run
    a grid, then compare these counts against the padding-sharing
    design's expected specialisation count."""
    from repro.cluster.runner import jit_cache_sizes as _cluster_sizes
    from repro.core.jax_engine import audit_jits
    sizes = {name: fn._cache_size()
             for name, fn in audit_jits().items()}
    sizes.update(_cluster_sizes())
    return sizes


def clear_jit_caches() -> None:
    """Reset every engine entry point's jit cache (single-node +
    cluster tiers) so `jit_cache_sizes` counts only the grid under
    audit."""
    from repro.cluster import engine as _cengine
    from repro.cluster import static as _cstatic
    from repro.core.jax_engine import audit_jits
    for fn in {**audit_jits(), **_cengine.audit_jits(),
               **_cstatic.audit_jits()}.values():
        fn.clear_cache()
