"""Declarative experiment surface: `TraceSource` + `ExperimentSpec`.

A *trace source* declares where a request stream comes from — a seeded
synthetic generator, a preprocessed npz slice of the real Azure-2021
trace, or inline columnar arrays — instead of threading env vars and
raw dicts through every benchmark. Sources compose declaratively:
``src.head(20_000)`` and ``src.scaled(1.2)`` wrap a source the way the
paper's figures slice and re-intensify the shared evaluation trace,
and every source materialises to the engine's columnar layout
(``arrays()``) exactly once (cached), however many figures share it.

An `ExperimentSpec` declares a whole study — sources x policies x
capacities x betas plus the engine knobs — as one validated value.
`repro.api.run` lowers it onto the vectorised engine's lanes
(`repro.core.jax_engine._sweep_metrics`), shards the lane chunks over
local devices and hosts, and returns a labeled `repro.api.ResultSet`.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple, Union

import numpy as np

TRACE_COLUMNS = ("fn_id", "arrival", "exec_time", "cold_start", "evict")

# engine defaults mirrored here so a spec is self-describing
DEFAULT_POLICIES = ("esff", "esff_h", "sff", "openwhisk", "faascache",
                    "openwhisk_v2")


class TraceSource:
    """Declarative origin of one request stream.

    Subclasses implement ``_materialise() -> dict`` returning the
    engine's columnar layout (`TRACE_COLUMNS`: arrival-sorted request
    columns + the per-function catalogue) and a ``label``. ``arrays()``
    caches the materialised columns for the source's lifetime — figure
    scripts share one source across sweeps, and reloading/regenerating
    a 6e5-request trace per figure costs seconds each time (this
    replaces the old ``_NPZ_TRACE_CACHE`` in ``benchmarks.common``).
    """

    label: str = "trace"

    def _materialise(self) -> Dict[str, np.ndarray]:
        raise NotImplementedError

    def arrays(self) -> Dict[str, np.ndarray]:
        """Columnar view (cached; arrays are marked read-only)."""
        cached = getattr(self, "_cache", None)
        if cached is None:
            cached = validate_trace_arrays(self._materialise(),
                                           where=self.label)
            for v in cached.values():
                v.setflags(write=False)
            object.__setattr__(self, "_cache", cached)
        return dict(cached)

    # ------------------------------------------------------ conveniences
    @property
    def n_requests(self) -> int:
        return len(self.arrays()["fn_id"])

    @property
    def n_functions(self) -> int:
        return len(self.arrays()["cold_start"])

    def to_trace(self):
        """Materialise `repro.core.request.Trace` objects (the Python
        event engine's representation; avoid for large N)."""
        from repro.core.request import Trace
        return Trace.from_arrays(self.arrays(),
                                 {"source": self.label})

    def head(self, n: int) -> "TraceSource":
        """First ``n`` requests (arrival order), same catalogue."""
        return HeadTrace(base=self, n=int(n))

    def scaled(self, ratio: float) -> "TraceSource":
        """Inter-arrival intensity scaling (paper Fig. 6): arrivals are
        multiplied by ``ratio`` (> 1 = lighter load), execution times
        untouched."""
        return ScaledTrace(base=self, ratio=float(ratio))

    def with_seed(self, seed: int) -> "TraceSource":
        """Re-seeded copy (only generator-backed sources support it —
        the hook `ExperimentSpec.seeds` expansion uses)."""
        raise TypeError(
            f"trace source {self.label!r} ({type(self).__name__}) is "
            "not reseedable; ExperimentSpec(seeds=...) needs "
            "generator-backed sources (SyntheticTrace)")


def validate_trace_arrays(a: dict, where: str = "trace"
                          ) -> Dict[str, np.ndarray]:
    """Check/normalise a columnar trace dict (`TRACE_COLUMNS` layout)."""
    missing = [k for k in TRACE_COLUMNS if k not in a]
    if missing:
        raise ValueError(f"{where}: missing trace column(s) {missing}; "
                         f"need {list(TRACE_COLUMNS)}")
    out = dict(
        fn_id=np.ascontiguousarray(a["fn_id"], np.int32),
        arrival=np.ascontiguousarray(a["arrival"], np.float64),
        exec_time=np.ascontiguousarray(a["exec_time"], np.float64),
        cold_start=np.ascontiguousarray(a["cold_start"], np.float64),
        evict=np.ascontiguousarray(a["evict"], np.float64),
    )
    n = len(out["fn_id"])
    if not (len(out["arrival"]) == len(out["exec_time"]) == n):
        raise ValueError(f"{where}: request columns disagree on length")
    if len(out["cold_start"]) != len(out["evict"]):
        raise ValueError(f"{where}: function columns disagree on length")
    if n and out["fn_id"].max(initial=0) >= len(out["cold_start"]):
        raise ValueError(f"{where}: fn_id exceeds catalogue size "
                         f"{len(out['cold_start'])}")
    return out


@dataclass(frozen=True)
class SyntheticTrace(TraceSource):
    """Seeded Azure-like generator spec
    (`repro.traces.synth_azure_arrays`)."""

    n_functions: int = 200
    n_requests: int = 30_000
    seed: int = 0
    params: Tuple[Tuple[str, float], ...] = ()

    @staticmethod
    def make(n_functions: int = 200, n_requests: int = 30_000,
             seed: int = 0, **params) -> "SyntheticTrace":
        """Keyword-friendly constructor (generator knobs as kwargs)."""
        return SyntheticTrace(n_functions=n_functions,
                              n_requests=n_requests, seed=seed,
                              params=tuple(sorted(params.items())))

    @property
    def label(self) -> str:
        return (f"synth[f{self.n_functions},n{self.n_requests},"
                f"seed{self.seed}]")

    def _materialise(self):
        from repro.traces import synth_azure_arrays
        return synth_azure_arrays(n_functions=self.n_functions,
                                  n_requests=self.n_requests,
                                  seed=self.seed, **dict(self.params))

    def with_seed(self, seed: int) -> "SyntheticTrace":
        return replace(self, seed=int(seed))


@dataclass(frozen=True)
class NpzTrace(TraceSource):
    """A ``Trace.save_npz``-format file, e.g. the real Azure-2021 slice
    produced by ``scripts/prepare_azure_trace.py``."""

    path: str = ""

    @property
    def label(self) -> str:
        return f"npz[{os.path.basename(self.path) or self.path}]"

    def _materialise(self):
        if not self.path or not os.path.exists(self.path):
            raise FileNotFoundError(
                f"NpzTrace: no npz at {self.path!r} (see "
                "docs/azure_trace.md for producing one)")
        with np.load(self.path) as z:
            return {k: z[k] for k in TRACE_COLUMNS}


@dataclass(frozen=True)
class ArrayTrace(TraceSource):
    """Inline columnar arrays (already in the engine layout)."""

    arrays_in: Tuple[Tuple[str, np.ndarray], ...] = ()
    name: str = "arrays"

    @staticmethod
    def make(arrays: dict, name: str = "arrays") -> "ArrayTrace":
        return ArrayTrace(arrays_in=tuple(sorted(arrays.items())),
                          name=name)

    @staticmethod
    def from_trace(trace, name: str = "") -> "ArrayTrace":
        """Wrap a `repro.core.request.Trace` object."""
        return ArrayTrace.make(trace.to_arrays(),
                               name or f"trace[n{len(trace)}]")

    @property
    def label(self) -> str:
        return self.name

    def _materialise(self):
        return dict(self.arrays_in)

    # inline arrays are identity-hashed via the tuple of (key, array)
    # pairs; ndarray is unhashable, so hash/eq fall back to object id
    def __hash__(self):
        return id(self)

    def __eq__(self, other):
        return self is other


@dataclass(frozen=True)
class HeadTrace(TraceSource):
    """First-``n``-requests view of another source."""

    base: TraceSource = None
    n: int = 0

    @property
    def label(self) -> str:
        return f"head{self.n}({self.base.label})"

    def _materialise(self):
        a = self.base.arrays()
        out = {k: a[k][: self.n] for k in ("fn_id", "arrival",
                                           "exec_time")}
        out["cold_start"] = a["cold_start"]
        out["evict"] = a["evict"]
        return out

    def with_seed(self, seed: int) -> "HeadTrace":
        return replace(self, base=self.base.with_seed(seed))


@dataclass(frozen=True)
class ScaledTrace(TraceSource):
    """Intensity-scaled view (arrivals x ``ratio``) of another source."""

    base: TraceSource = None
    ratio: float = 1.0

    @property
    def label(self) -> str:
        return f"scale{self.ratio:g}({self.base.label})"

    def _materialise(self):
        a = self.base.arrays()
        out = dict(a)
        out["arrival"] = a["arrival"] * self.ratio
        return out

    def with_seed(self, seed: int) -> "ScaledTrace":
        return replace(self, base=self.base.with_seed(seed))


def as_trace_source(obj, name: str = "") -> TraceSource:
    """Coerce ``obj`` into a `TraceSource`.

    Accepts a source (returned as-is), a `repro.core.request.Trace`,
    a columnar array dict (``to_arrays()`` layout), or an npz path
    string.
    """
    from repro.core.request import Trace
    if isinstance(obj, TraceSource):
        return obj
    if isinstance(obj, Trace):
        return ArrayTrace.from_trace(obj, name)
    if isinstance(obj, dict):
        return ArrayTrace.make(obj, name or "arrays")
    if isinstance(obj, (str, os.PathLike)):
        return NpzTrace(path=os.fspath(obj))
    raise TypeError(
        f"cannot interpret {type(obj).__name__!r} as a trace source; "
        "pass a TraceSource, Trace, columnar array dict, or npz path")


@dataclass
class ExperimentSpec:
    """One declared experiment: the full grid plus engine options.

    The grid is ``traces x policies x capacities x betas`` (exactly the
    engine's lane axes); ``seeds`` optionally expands each reseedable
    source into one trace per seed, widening the trace axis. Metric
    semantics and defaults mirror the engine (`jax_engine._simulate`):
    streaming mode keeps carried state independent of trace length,
    ``tl_bins > 0`` adds the minute-binned Fig.-8 timeline,
    ``keep_per_request=True`` (requires ``stream=False``) additionally
    returns the (N,)-per-lane response vector for CDF/percentile
    studies. ``deadlines`` (one scalar, or one value per function)
    switches on SLO accounting: every tier folds a per-function
    ``deadline_miss`` counter (``response > deadline`` at completion
    time) and the ResultSet gains the derived ``slo_attainment``
    metric (`repro.core.jax_engine.slo_attainment`).

    Scale-out: ``devices`` caps how many local JAX devices the runner
    shards lane chunks over (None = all of ``jax.local_devices()``);
    ``host_shard=(i, n)`` keeps only every n-th chunk (offset i) for
    multi-host slicing — each host computes a disjoint chunk subset and
    the shards reassemble with `ResultSet.merge`.

    Multi-node: ``cluster`` declares a fifth grid axis of
    `repro.cluster.ClusterSpec` topologies (``None`` entries are the
    plain single-node engine) — each cell simulates K edge nodes
    behind the entry's router, via the static sub-stream fast path or
    the dynamic in-loop router (docs/cluster.md). A single ClusterSpec
    is promoted to a one-entry axis. When any entry fixes
    ``node_capacity``, the capacity axis must have exactly one entry
    (it labels the aggregate). Cluster runs execute on the default
    device (``host_shard`` must stay (0, 1)).

    Resilience (docs/cluster.md): ``fail_prob`` (scalar or one value
    per function) injects deterministic counter-hash request failures,
    ``timeouts`` (scalar / per function, seconds) kills attempts whose
    execution exceeds the budget, ``retry`` (a
    `repro.core.resilience.RetryPolicy`, default ``RetryPolicy()``
    when faults are on) re-enters failed attempts after capped
    exponential backoff, and ``on_overflow`` picks the admission-
    control mode for full queues: ``"error"`` (drop + count
    ``overflow``, `ResultSet.check` fails — the legacy behaviour),
    ``"shed"`` (drop the arriving request, counted ``shed``) or
    ``"shed_oldest"`` (evict the queue head). With every knob at its
    default the resilience layer is off and every run lowers onto the
    unchanged engine loop bitwise.

    Observability (docs/observability.md): ``trace_events=True``
    records one fixed-width record per processed event inside every
    jitted loop and attaches a `repro.telemetry.TraceRun` to the
    result (``ResultSet.trace``) — per-request spans, Perfetto
    export, and `ResultSet.timeline` time series all hang off it.
    Traced runs execute lane chunks serially on the default device
    (``devices`` must be None or 1, ``host_shard`` (0, 1));
    ``trace_events=False`` lowers onto the unchanged loops bitwise.
    """

    traces: Sequence = ()
    policies: Sequence[str] = DEFAULT_POLICIES
    capacities: Sequence[int] = (8, 16, 32)
    betas: Optional[Sequence[float]] = None
    seeds: Optional[Sequence[int]] = None
    queue_cap: int = 2048
    prior: float = 0.1
    threshold: float = 0.1
    stream: bool = True
    window: int = 0
    tl_bins: int = 0
    tl_bucket: float = 60.0
    keep_per_request: bool = False
    deadlines: Union[float, Sequence[float], None] = None
    fail_prob: Union[float, Sequence[float]] = 0.0
    timeouts: Union[float, Sequence[float], None] = None
    retry: Optional[object] = None
    on_overflow: str = "error"
    fail_seed: int = 0
    lane_chunk: Union[int, str, None] = None
    devices: Optional[int] = None
    host_shard: Tuple[int, int] = (0, 1)
    cluster: Optional[Sequence] = None
    trace_events: bool = False
    meta: dict = field(default_factory=dict)

    def __post_init__(self):
        if isinstance(self.traces, (TraceSource, dict, str)) or (
                type(self.traces).__name__ == "Trace"):
            self.traces = [self.traces]
        self.traces = tuple(as_trace_source(t) for t in self.traces)
        self.policies = tuple(self.policies)
        self.capacities = tuple(int(c) for c in self.capacities)
        if self.betas is not None:
            self.betas = tuple(float(b) for b in self.betas)
        if self.seeds is not None:
            self.seeds = tuple(int(s) for s in self.seeds)
        self.host_shard = tuple(int(x) for x in self.host_shard)
        if self.deadlines is not None:
            if np.isscalar(self.deadlines):
                self.deadlines = float(self.deadlines)
            else:
                self.deadlines = tuple(float(d)
                                       for d in self.deadlines)
        if self.cluster is not None:
            from repro.cluster.spec import ClusterSpec
            if isinstance(self.cluster, ClusterSpec):
                self.cluster = (self.cluster,)
            self.cluster = tuple(self.cluster)
        if not np.isscalar(self.fail_prob):
            self.fail_prob = tuple(float(p) for p in self.fail_prob)
        if self.timeouts is not None and not np.isscalar(self.timeouts):
            self.timeouts = tuple(float(b) for b in self.timeouts)
        self.fail_seed = int(self.fail_seed)

    # ------------------------------------------------------- validation
    def validate(self) -> "ExperimentSpec":
        """Raise ``ValueError``/``TypeError``/``KeyError`` with a
        precise message on the first invalid field; returns self so
        callers can chain."""
        from repro.api.registry import get_kernel
        if not self.traces:
            raise ValueError("ExperimentSpec: no trace sources")
        if not self.policies:
            raise ValueError("ExperimentSpec: no policies")
        for p in self.policies:
            get_kernel(p)     # KeyError lists registered policies
        if len(set(self.policies)) != len(self.policies):
            raise ValueError(
                f"ExperimentSpec: duplicate policies {self.policies}")
        if not self.capacities:
            raise ValueError("ExperimentSpec: no capacities")
        if any(c <= 0 for c in self.capacities):
            raise ValueError(
                f"ExperimentSpec: capacities must be positive, got "
                f"{self.capacities}")
        if self.betas is not None and not self.betas:
            raise ValueError("ExperimentSpec: betas=() — use None for "
                             "per-policy defaults")
        if self.seeds is not None:
            if not self.seeds:
                raise ValueError("ExperimentSpec: seeds=() — use None "
                                 "to keep sources as declared")
            for t in self.traces:
                t.with_seed(self.seeds[0])   # raises on non-reseedable
        if self.queue_cap <= 0:
            raise ValueError("ExperimentSpec: queue_cap must be > 0")
        if self.window < 0 or self.tl_bins < 0:
            raise ValueError("ExperimentSpec: window/tl_bins must be "
                             ">= 0")
        if self.keep_per_request and self.stream:
            raise ValueError(
                "ExperimentSpec: keep_per_request needs stream=False "
                "(streaming folds per-request records away)")
        if self.deadlines is not None:
            vals = ([self.deadlines]
                    if isinstance(self.deadlines, float)
                    else list(self.deadlines))
            if not vals:
                raise ValueError(
                    "ExperimentSpec: deadlines=() — use None to "
                    "disable SLO accounting")
            for d in vals:
                if not np.isfinite(d) or d <= 0:
                    raise ValueError(
                        f"ExperimentSpec: deadlines must be finite "
                        f"and > 0, got {d}")
        from repro.core.resilience import SHED_MODES, RetryPolicy
        if self.on_overflow not in SHED_MODES:
            raise ValueError(
                f"ExperimentSpec: on_overflow must be one of "
                f"{sorted(SHED_MODES)}, got {self.on_overflow!r}")
        fp = np.atleast_1d(np.asarray(self.fail_prob, np.float64))
        if np.any((fp < 0) | (fp > 1)) or not np.all(np.isfinite(fp)):
            raise ValueError(
                f"ExperimentSpec: fail_prob must be in [0, 1], got "
                f"{self.fail_prob}")
        if self.timeouts is not None:
            to = np.atleast_1d(np.asarray(self.timeouts, np.float64))
            if np.any(to <= 0) or not np.all(np.isfinite(to)):
                raise ValueError(
                    "ExperimentSpec: timeouts must be finite and > 0, "
                    f"got {self.timeouts}")
        if self.retry is not None and not isinstance(self.retry,
                                                     RetryPolicy):
            raise TypeError(
                "ExperimentSpec: retry must be a RetryPolicy or None, "
                f"got {type(self.retry).__name__}")
        if self.resilience_active():
            timered = [p for p in self.policies
                       if get_kernel(p).has_timers]
            if timered:
                raise ValueError(
                    f"ExperimentSpec: policies {timered} arm "
                    "per-request timers, which the resilience layer "
                    "does not support (a killed or retried request "
                    "would leave a timer aimed at a stale attempt); "
                    "drop the policy or the fail_prob/timeouts/"
                    "on_overflow settings")
        elif self.retry is not None:
            raise ValueError(
                "ExperimentSpec: retry= without fail_prob/timeouts/"
                "on_overflow does nothing — remove it or switch a "
                "fault knob on")
        if self.trace_events:
            if self.host_shard != (0, 1):
                raise ValueError(
                    "ExperimentSpec: trace_events needs every lane "
                    "on this host; host_shard must stay (0, 1)")
            if self.devices not in (None, 1):
                raise ValueError(
                    "ExperimentSpec: traced runs execute serially on "
                    "the default device; devices must be None or 1, "
                    f"got {self.devices}")
        i, n = self.host_shard
        if n < 1 or not (0 <= i < n):
            raise ValueError(
                f"ExperimentSpec: host_shard must be (i, n) with "
                f"0 <= i < n, got {self.host_shard}")
        if self.devices is not None and self.devices < 1:
            raise ValueError("ExperimentSpec: devices must be >= 1 "
                             "(None = all local devices)")
        if self.cluster is not None:
            from repro.cluster.spec import ClusterSpec
            if not self.cluster:
                raise ValueError(
                    "ExperimentSpec: cluster=() — use None for plain "
                    "single-node runs")
            for entry in self.cluster:
                if entry is None:
                    continue
                if not isinstance(entry, ClusterSpec):
                    raise TypeError(
                        f"ExperimentSpec: cluster entries must be "
                        f"ClusterSpec or None, got "
                        f"{type(entry).__name__}")
                entry.validate()
                if (entry.node_capacity is not None
                        and len(self.capacities) != 1):
                    raise ValueError(
                        "ExperimentSpec: a ClusterSpec with "
                        "node_capacity fixes per-node slots, so the "
                        "capacity axis must have exactly one entry "
                        f"(the aggregate label); got "
                        f"{self.capacities}")
            if self.host_shard != (0, 1):
                raise ValueError(
                    "ExperimentSpec: cluster runs do not support "
                    "host_shard yet")
            if self.devices not in (None, 1):
                raise ValueError(
                    "ExperimentSpec: cluster runs execute on the "
                    "default device; devices must be None or 1, got "
                    f"{self.devices}")
        return self

    def deadline_ops(self, n_fns: int) -> Optional[np.ndarray]:
        """Lower ``deadlines`` to the engine's (F,) float64 operand
        (a scalar broadcasts to every function), or ``None`` when SLO
        accounting is off. Raises if a per-function sequence does not
        match the catalogue size."""
        if self.deadlines is None:
            return None
        if isinstance(self.deadlines, float):
            return np.full((n_fns,), self.deadlines, np.float64)
        if len(self.deadlines) != n_fns:
            raise ValueError(
                f"ExperimentSpec: deadlines has {len(self.deadlines)} "
                f"entries but the trace catalogue declares {n_fns} "
                "functions (pass one scalar or one deadline per "
                "function)")
        return np.asarray(self.deadlines, np.float64)

    # ------------------------------------------------------- resilience
    def resilience_active(self) -> bool:
        """True when any fault knob leaves its trivial default — the
        engines then run their resilience rails; otherwise every run
        lowers onto the unchanged loop bitwise."""
        fp = np.atleast_1d(np.asarray(self.fail_prob, np.float64))
        return (bool(np.any(fp > 0)) or self.timeouts is not None
                or self.on_overflow != "error")

    def retry_policy(self):
        """The effective `RetryPolicy` (defaults apply when faults are
        on), or ``None`` when the resilience layer is off."""
        from repro.core.resilience import RetryPolicy
        if not self.resilience_active():
            return None
        return self.retry if self.retry is not None else RetryPolicy()

    def resilience_ops(self, stacked: Dict[str, np.ndarray],
                       n_fns: int):
        """Lower the fault knobs to the engines' operands, or ``None``
        when the layer is off.

        Returns ``(eff_exec, n_fail, is_tmo, rid_key, resil)``: the
        (T, N) effective execution times (``min(exec, timeout)`` —
        substituted for the exec operand), pre-planned leading-failure
        counts, timeout flags and original-rid jitter keys (see
        `repro.core.resilience.plan_outcomes`), plus the static
        ``resil`` tuple ``(max_attempts, shed_mode, base, cap, jitter,
        fail_seed)`` the jitted loops specialise on."""
        from repro.core.resilience import SHED_MODES, plan_outcomes
        rp = self.retry_policy()
        if rp is None:
            return None
        fn_id = np.asarray(stacked["fn_id"])
        ex = np.asarray(stacked["exec_time"])
        T, N = fn_id.shape
        eff = np.empty((T, N), np.float64)
        nfail = np.empty((T, N), np.int32)
        tmo = np.empty((T, N), bool)
        for t in range(T):
            eff[t], nfail[t], tmo[t] = plan_outcomes(
                fn_id[t], ex[t], fail_prob=self.fail_prob,
                timeouts=self.timeouts,
                max_attempts=rp.max_attempts, n_fns=n_fns,
                seed=self.fail_seed)
        key = np.broadcast_to(np.arange(N, dtype=np.int32), (T, N))
        resil = (int(rp.max_attempts), SHED_MODES[self.on_overflow],
                 float(rp.base), float(rp.cap), float(rp.jitter),
                 self.fail_seed)
        return eff, nfail, tmo, np.ascontiguousarray(key), resil

    def resilience_meta(self):
        """JSON-friendly record of the fault knobs for `ResultSet.meta`
        (``None`` when the resilience layer is off)."""
        rp = self.retry_policy()
        if rp is None:
            return None
        tolist = lambda v: (list(v) if isinstance(v, tuple)  # noqa: E731
                            else v)
        return dict(fail_prob=tolist(self.fail_prob),
                    timeouts=tolist(self.timeouts),
                    on_overflow=self.on_overflow,
                    retry=list(rp.as_tuple()),
                    fail_seed=self.fail_seed)

    # -------------------------------------------------------- expansion
    def expanded_traces(self) -> Tuple[TraceSource, ...]:
        """The trace axis after seed expansion (seed-major per source:
        ``[src.with_seed(s) for src in traces for s in seeds]``)."""
        if self.seeds is None:
            return self.traces
        return tuple(src.with_seed(s)
                     for src in self.traces for s in self.seeds)

    def grid_size(self) -> int:
        b = 1 if self.betas is None else len(self.betas)
        u = 1 if self.cluster is None else len(self.cluster)
        return (len(self.policies) * len(self.expanded_traces())
                * len(self.capacities) * b * u)
