"""Pluggable policy registry over the engine's kernel table.

The engine selects its jit-specialised loop body by kernel identity
(`repro.core.jax_policies.KERNELS`). `register_policy` lets external
`PolicyKernel` subclasses — a LaSS-style latency-target variant, a
different keep-alive heuristic — join that table under a name and then
participate in `ExperimentSpec.policies` (and every benchmark CLI)
exactly like the built-ins. The registry wraps the *same* dict the
engine reads, so registration is visible everywhere at once.
"""
from __future__ import annotations

from typing import List


def _kernels() -> dict:
    from repro.core.jax_policies import KERNELS
    return KERNELS


def available_policies() -> List[str]:
    """Registered policy names (built-ins + `register_policy` adds)."""
    return sorted(_kernels())


def get_kernel(name: str):
    """Kernel registered under ``name`` (KeyError lists what exists)."""
    kernels = _kernels()
    try:
        return kernels[name]
    except KeyError:
        raise KeyError(
            f"unknown policy {name!r}; registered policies: "
            f"{sorted(kernels)} (add your own with "
            "repro.api.register_policy)") from None


def register_policy(name: str, kernel, *, replace: bool = False):
    """Register a `repro.core.jax_engine.PolicyKernel` instance under
    ``name``.

    The instance must be a singleton the caller keeps stable: the
    engine jit-caches per kernel *identity*, so re-creating instances
    per call would retrace. ``replace=True`` allows overwriting an
    existing name (kept off by default so two plug-ins cannot silently
    shadow each other or a built-in). Returns ``kernel`` so it can be
    used as a decorator-style one-liner.
    """
    from repro.core.jax_engine import PolicyKernel
    if not isinstance(kernel, PolicyKernel):
        raise TypeError(
            f"register_policy({name!r}): expected a PolicyKernel "
            f"*instance* (got {type(kernel).__name__}); subclass "
            "repro.core.jax_engine.PolicyKernel and pass an instance")
    if not name or not isinstance(name, str):
        raise ValueError("register_policy: name must be a non-empty "
                         "string")
    kernels = _kernels()
    if name in kernels and not replace:
        raise ValueError(
            f"register_policy: policy {name!r} is already registered "
            f"(to {type(kernels[name]).__name__}); pass replace=True "
            "to overwrite deliberately")
    kernels[name] = kernel
    return kernel


def unregister_policy(name: str) -> None:
    """Remove a registered policy (built-ins included — callers own the
    consequences; primarily for test cleanup)."""
    kernels = _kernels()
    if name not in kernels:
        raise KeyError(f"unregister_policy: {name!r} is not registered")
    del kernels[name]
