"""Qwen3-4B [hf:Qwen/Qwen3-4B family]: 36L, d=2560, 32H (GQA kv=8,
head_dim=128 > d_model/H as in Qwen3), d_ff=9728, vocab=151936, qk-norm."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("qwen3-4b")
def qwen3_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=9728,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
    )
