"""InternVL2-76B [arXiv:2404.16821]: InternViT frontend STUB + Llama3-70B
class backbone: 80L, d=8192, 64H (GQA kv=8), d_ff=28672, vocab=128256.
input_specs provides precomputed ViT patch embeddings (256 prefix
positions)."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("internvl2-76b")
def internvl2_76b() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128256,
        n_patches=256,
        rope_theta=5e5,
    )
