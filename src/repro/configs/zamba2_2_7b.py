"""Zamba2-2.7B [arXiv:2411.15242; hf]: 54 Mamba2 layers, d=2560
(ssm_state=64), plus a SHARED attention block (32H, d_ff=10240) applied
every 6 layers on concat(hidden, embeddings); vocab=32000. Sliding-window
(long_context_window) attention for the long_500k cell."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("zamba2-2.7b")
def zamba2_2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_conv=4,
        ssm_chunk=256,
        ssm_ngroups=1,
        attn_every=6,
        long_context_window=4096,
    )
