"""Qwen3-14B [hf:Qwen/Qwen3-14B family]: 40L, d=5120, 40H (GQA kv=8),
d_ff=17408, vocab=151936, qk-norm (per-head RMSNorm on q,k)."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("qwen3-14b")
def qwen3_14b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b",
        family="dense",
        n_layers=40,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=17408,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
    )
