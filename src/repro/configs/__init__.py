"""Assigned architecture configs (``--arch <id>``) + the paper's own
edge-serving scenario config. Each ``<id>.py`` holds the exact published
configuration; ``ARCHS[name]()`` returns its :class:`ModelConfig`.
"""
from repro.configs.registry import ARCHS, SHAPES, get_arch, shape_cells

__all__ = ["ARCHS", "SHAPES", "get_arch", "shape_cells"]
