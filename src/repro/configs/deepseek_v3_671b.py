"""DeepSeek-V3-671B [arXiv:2412.19437; hf]: 61L, d=7168, 128H MLA,
MoE 256 routed (top-8) + 1 shared expert (d_ff 2048 each), first 3 layers
dense (d_ff 18432), vocab=129280, multi-token prediction (depth 1)."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("deepseek-v3-671b")
def deepseek_v3_671b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=128,
        n_kv_heads=128,          # MLA: all heads share the latent cache
        d_ff=18432,              # dense layers / shared-expert unit is moe_d_ff
        vocab_size=129280,
        n_experts=256,
        n_shared_experts=1,
        topk=8,
        moe_d_ff=2048,
        first_dense_layers=3,
        mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        head_dim=192,            # qk_nope + qk_rope
        mtp=True,
        rope_theta=1e4,
    )
