"""InternLM2-20B [arXiv:2403.17297; hf]: 48L, d=6144, 48 heads (GQA kv=8),
d_ff=16384, vocab=92544, SwiGLU + RMSNorm + RoPE."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("internlm2-20b")
def internlm2_20b() -> ModelConfig:
    return ModelConfig(
        name="internlm2-20b",
        family="dense",
        n_layers=48,
        d_model=6144,
        n_heads=48,
        n_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92544,
        rope_theta=1e6,
    )
