"""Whisper-tiny [arXiv:2212.04356]: 4 encoder + 4 decoder layers, d=384,
6H, d_ff=1536, vocab=51865, enc-dec with conv frontend STUB (input_specs
provides precomputed log-mel frame embeddings, 1500 positions)."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("whisper-tiny")
def whisper_tiny() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="encdec",
        n_layers=8,              # 4 enc + 4 dec
        enc_layers=4,
        dec_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        n_enc_positions=1500,
        norm_eps=1e-5,
    )
