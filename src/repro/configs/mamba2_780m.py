"""Mamba2-780M [arXiv:2405.21060]: 48L, d=1536 (attention-free SSD),
ssm_state=128, expand=2 (d_inner=3072, 48 heads x headdim 64),
vocab=50280."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("mamba2-780m")
def mamba2_780m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        n_layers=48,
        d_model=1536,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_headdim=64,
        ssm_conv=4,
        ssm_chunk=256,
        ssm_ngroups=1,
    )
