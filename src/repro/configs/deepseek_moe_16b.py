"""DeepSeekMoE-16B [arXiv:2401.06066; hf]: 28L, d=2048, 16H (MHA kv=16),
fine-grained MoE: 64 routed experts (top-6) + 2 shared, expert d_ff=1408,
first layer dense (d_ff 10944), vocab=102400."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("deepseek-moe-16b")
def deepseek_moe_16b() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=10944,
        vocab_size=102400,
        n_experts=64,
        n_shared_experts=2,
        topk=6,
        moe_d_ff=1408,
        first_dense_layers=1,
        rope_theta=1e4,
    )
