"""Architecture & input-shape registry.

Shapes (assignment):
  train_4k     seq_len=4096   global_batch=256   -> train_step
  prefill_32k  seq_len=32768  global_batch=32    -> prefill_step
  decode_32k   seq_len=32768  global_batch=128   -> decode_step (1 token)
  long_500k    seq_len=524288 global_batch=1     -> decode_step (1 token)

``long_500k`` runs only for sub-quadratic archs (ssm / hybrid); quadratic
full-attention archs skip it (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import List, Tuple

from repro.models.config import ModelConfig
from repro.utils.registry import Registry

ARCHS = Registry("architectures")

_ARCH_MODULES = [
    "internlm2_20b", "qwen3_14b", "qwen1_5_4b", "qwen3_4b", "mamba2_780m",
    "deepseek_v3_671b", "deepseek_moe_16b", "whisper_tiny", "zamba2_2_7b",
    "internvl2_76b", "paper_edge",
]


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}


def _load_all() -> None:
    for m in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def get_arch(name: str) -> ModelConfig:
    _load_all()
    return ARCHS[name]()


def shape_cells(arch: str = None) -> List[Tuple[str, str]]:
    """All runnable (arch, shape) cells per the assignment rules."""
    _load_all()
    names = [a for a in ARCHS.keys() if a != "paper_edge"] \
        if arch is None else [arch]
    cells = []
    for a in names:
        cfg = ARCHS[a]()
        for s, sc in SHAPES.items():
            if s == "long_500k" and not cfg.sub_quadratic:
                continue   # quadratic attention: skipped per assignment
            cells.append((a, s))
    return cells
