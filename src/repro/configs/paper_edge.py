"""The paper's own scenario config: the edge serverless platform.

Not an LM architecture — this configures the ESFF serving stack
(capacity, trace parameters, scheduler) used by examples/serve_edge.py
and the paper-figure benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.configs.registry import ARCHS


@dataclass(frozen=True)
class EdgeServingConfig:
    name: str = "paper-edge"
    capacity: int = 16                  # paper default C
    policy: str = "esff"
    cold_range: tuple = (0.5, 1.5)      # seconds (paper §VI-A)
    n_functions: int = 200
    n_requests: int = 60_000
    utilization: float = 0.2
    exec_median: float = 0.1
    exec_sigma: float = 1.4
    burst_frac: float = 0.3
    seed: int = 0
    intensity_ratios: tuple = (0.6, 0.8, 1.0, 1.2, 1.4)   # Fig. 6
    capacities: tuple = (8, 12, 16, 20, 24, 28, 32)        # Fig. 5


@ARCHS.register("paper_edge")
def paper_edge() -> EdgeServingConfig:
    return EdgeServingConfig()
