"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B family]: 40L, d=2560, 20H (kv=20, MHA),
d_ff=6912, vocab=151936, bias on QKV projections."""
from repro.configs.registry import ARCHS
from repro.models.config import ModelConfig


@ARCHS.register("qwen1.5-4b")
def qwen1_5_4b() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        n_layers=40,
        d_model=2560,
        n_heads=20,
        n_kv_heads=20,
        head_dim=128,
        d_ff=6912,
        vocab_size=151936,
        qkv_bias=True,
        rope_theta=1e6,
    )
