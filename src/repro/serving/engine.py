"""Edge serving engine: the paper's scheduler driving real JAX models.

The engine reuses the event-driven core (``repro.core``) unchanged —
policies, server slots, metrics — but the *times are measured, not
simulated*: a cold start really builds/compiles the model
(ModelInstance.cold_start) and an execution really runs
prefill+decode (ModelInstance.execute). Measured durations feed back
into the discrete-event clock, so a trace's worth of requests is
evaluated in one pass without wall-clock idling, while every service
time is a genuine accelerator measurement.

Straggler mitigation: an execution exceeding ``straggler_factor`` x the
function's running-mean is recorded and (optionally) re-dispatched to a
second instance — the duplicate's completion wins (speculative
execution; see tests/test_serving.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.events import EventKind, EventQueue
from repro.core.metrics import SimResult, collect
from repro.core.policy import POLICIES, Policy
from repro.core.request import FunctionProfile, Request, Trace
from repro.core.server import EdgeServer, ExecTimeEstimator
from repro.serving.instance import ModelInstance, ServedFunction
from repro.utils import get_logger

log = get_logger("serving")


class EdgeServingEngine:
    """C-slot edge server serving real models under a core policy."""

    def __init__(self, functions: Sequence[ServedFunction], capacity: int,
                 policy: str = "esff", straggler_factor: float = 0.0,
                 seed: int = 0):
        self.served = list(functions)
        self.capacity = capacity
        self.policy_name = policy
        self.straggler_factor = straggler_factor
        self.seed = seed
        # measured platform profile (filled by warm_profile)
        self.profiles: Dict[int, FunctionProfile] = {}
        self._instances: Dict[int, ModelInstance] = {}
        self.stragglers: List[dict] = []

    # ------------------------------------------------------------ setup
    def _measure_function(self, fn: ServedFunction) -> FunctionProfile:
        """One throwaway instance measures t_l (cold) and seeds t_e."""
        inst = ModelInstance(fn)
        cold = inst.cold_start()
        exec_s = inst.execute(seed=0)
        evict = inst.evict() + 1e-4
        return FunctionProfile(fn.fn_id, cold_start=cold, evict=evict,
                               true_mean_exec=exec_s, name=fn.name)

    def warm_profile(self) -> Dict[int, FunctionProfile]:
        for fn in self.served:
            p = self._measure_function(fn)
            self.profiles[fn.fn_id] = p
            log.info("profiled %s: cold %.3fs exec %.4fs", fn.name,
                     p.cold_start, p.true_mean_exec)
        return self.profiles

    # ------------------------------------------------------------- run
    def run(self, requests: Sequence[Request]) -> SimResult:
        """Serve ``requests`` (arrival times define the event clock;
        exec/cold times are measured live)."""
        if not self.profiles:
            self.warm_profile()
        functions = [self.profiles[f.fn_id] for f in self.served]
        events = EventQueue()
        server = EdgeServer(functions, self.capacity, events)
        est = ExecTimeEstimator(len(functions))
        policy: Policy = POLICIES[self.policy_name]()
        policy.bind(server, est)

        by_id = {f.fn_id: f for f in self.served}
        live: Dict[int, ModelInstance] = {}   # inst_id -> replica

        # live execution: measured service time replaces trace exec_time
        orig_dispatch = server.dispatch

        def live_dispatch(inst, req, t):
            replica = live.get(inst.inst_id)
            if replica is None or replica.params is None:
                replica = ModelInstance(by_id[inst.fn_id])
                replica.cold_start()   # should be rare: warm pool miss
                live[inst.inst_id] = replica
            measured = replica.execute(seed=req.req_id)
            mean = est.mean(req.fn_id)
            if (self.straggler_factor and est.n[req.fn_id] > 3
                    and measured > self.straggler_factor * mean):
                # speculative re-execution: duplicate wins
                dup = replica.execute(seed=req.req_id)
                self.stragglers.append(dict(
                    req=req.req_id, fn=req.fn_id, measured=measured,
                    mean=mean, dup=dup))
                measured = min(measured, dup)
            req.exec_time = measured
            orig_dispatch(inst, req, t)

        orig_cold = server.start_cold

        def live_cold(fn_id, t, evict=None):
            if evict is not None:
                rep = live.pop(evict.inst_id, None)
                if rep is not None:
                    functions[evict.fn_id].evict = max(rep.evict(), 1e-4)
            replica = ModelInstance(by_id[fn_id])
            measured = replica.cold_start()
            functions[fn_id].cold_start = measured   # event clock uses
            inst = orig_cold(fn_id, t, evict=evict)  # the measured value
            live[inst.inst_id] = replica
            return inst

        server.dispatch = live_dispatch
        server.start_cold = live_cold

        for r in requests:
            r.start = -1.0
            r.completion = -1.0
            events.push(r.arrival, EventKind.ARRIVAL, r)

        t0 = time.perf_counter()
        while True:
            ev = events.pop()
            if ev is None:
                break
            if ev.kind == EventKind.ARRIVAL:
                policy.on_arrival(ev.payload, ev.time)
            elif ev.kind == EventKind.EXEC_DONE:
                inst = ev.payload
                req = inst.current
                est.observe(req.fn_id, req.exec_time)
                policy.on_exec_done(inst, req, ev.time)
            elif ev.kind == EventKind.COLD_DONE:
                policy.on_cold_done(ev.payload, ev.time)
            elif ev.kind == EventKind.TIMER:
                policy.on_timer(ev.payload, ev.time)
        wall = time.perf_counter() - t0
        return collect(self.policy_name, self.capacity, list(requests),
                       server.stats, wall,
                       {"engine": "live", "stragglers":
                        len(self.stragglers)})

    # --------------------------------------------------------- helpers
    def make_requests(self, n: int, duration: float,
                      popularity: Optional[Sequence[float]] = None,
                      seed: int = 0) -> List[Request]:
        rng = np.random.default_rng(seed)
        F = len(self.served)
        p = np.asarray(popularity if popularity is not None
                       else 1.0 / np.arange(1, F + 1))
        p = p / p.sum()
        fns = rng.choice(F, size=n, p=p)
        arr = np.sort(rng.uniform(0, duration, n))
        return [Request(i, int(self.served[f].fn_id), float(t), 0.0)
                for i, (f, t) in enumerate(zip(fns, arr))]
