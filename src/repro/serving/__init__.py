from repro.serving.engine import EdgeServingEngine, ServedFunction

__all__ = ["EdgeServingEngine", "ServedFunction"]
