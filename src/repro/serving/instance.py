"""A resident model replica — the serving-side realisation of the paper's
"function instance".

Cold start is REAL here: building the model, initialising parameters and
jit-compiling the serve step. ``ModelInstance.cold_start()`` measures it;
the scheduler sees the measured latency, exactly as the paper's t_j^l.
Eviction frees the params (device memory) and is timed as t_j^v.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model
from repro.models.config import ModelConfig


@dataclass
class ServedFunction:
    """A deployable serverless function = model config + request shape."""

    fn_id: int
    cfg: ModelConfig
    prompt_len: int = 32
    gen_tokens: int = 8
    batch: int = 1
    max_len: int = 64
    name: str = ""

    def __post_init__(self):
        if not self.name:
            self.name = self.cfg.name


class ModelInstance:
    """One resident replica of a ServedFunction."""

    def __init__(self, fn: ServedFunction):
        self.fn = fn
        self.model = build_model(fn.cfg)
        self.params = None
        self._prefill = None
        self._decode = None
        self.cold_time: Optional[float] = None

    # ------------------------------------------------------- lifecycle
    def cold_start(self) -> float:
        """Init + compile + warmup; returns measured seconds (t_j^l)."""
        t0 = time.perf_counter()
        self.params = jax.jit(
            lambda k: self.model.init(k)[0])(jax.random.key(self.fn.fn_id))
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)
        # compile both paths with representative shapes
        batch = self._dummy_batch()
        cache = self.model.cache_spec(self.fn.batch, self.fn.max_len).zeros()
        logits, cache = self._prefill(self.params, batch, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        logits, cache = self._decode(self.params, tok, cache)
        jax.block_until_ready(logits)
        self.cold_time = time.perf_counter() - t0
        return self.cold_time

    def evict(self) -> float:
        t0 = time.perf_counter()
        self.params = None
        self._prefill = None
        self._decode = None
        # drop donated buffers eagerly
        jax.clear_caches() if False else None
        return time.perf_counter() - t0

    # ------------------------------------------------------- execution
    def _dummy_batch(self, seed: int = 0) -> Dict[str, Any]:
        fn = self.fn
        rng = np.random.default_rng(seed)
        batch = {"tokens": jnp.asarray(rng.integers(
            0, fn.cfg.vocab_size, (fn.batch, fn.prompt_len)), jnp.int32)}
        if fn.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.asarray(rng.normal(
                size=(fn.batch, fn.cfg.n_patches, fn.cfg.d_model)),
                jnp.float32)
        if fn.cfg.family == "encdec":
            batch["frames"] = jnp.asarray(rng.normal(
                size=(fn.batch, fn.cfg.n_enc_positions, fn.cfg.d_model)),
                jnp.float32)
        return batch

    def execute(self, seed: int = 0) -> float:
        """Serve one request (prefill + gen_tokens decode steps);
        returns measured seconds (the request's t_i^e)."""
        assert self.params is not None, "instance not warm"
        t0 = time.perf_counter()
        batch = self._dummy_batch(seed)
        cache = self.model.cache_spec(self.fn.batch,
                                      self.fn.max_len).zeros()
        logits, cache = self._prefill(self.params, batch, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None]
        for _ in range(self.fn.gen_tokens):
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.argmax(logits[:, -1], -1)[:, None]
        jax.block_until_ready(tok)
        return time.perf_counter() - t0
