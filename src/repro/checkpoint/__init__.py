from repro.checkpoint.checkpointer import (Checkpointer, latest_step,
                                           restore, save)

__all__ = ["Checkpointer", "save", "restore", "latest_step"]
