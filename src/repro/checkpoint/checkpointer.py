"""Fault-tolerant checkpointing.

* **Atomic**: writes land in ``<dir>/tmp.step_N`` and are renamed to
  ``<dir>/step_N`` only after the manifest (tree structure + per-file
  crc32) is fsynced — a crash mid-write can never produce a readable but
  corrupt checkpoint.
* **Async**: ``save(..., blocking=False)`` snapshots to host memory
  (device_get) synchronously — the step loop proceeds — and writes on a
  background thread; ``wait()`` joins before the next save or exit.
* **Keep-N GC**: older steps are deleted after a successful save.
* **Elastic restore**: ``restore(..., mesh=..., shardings=...)`` places
  the loaded arrays under *any* target sharding — restoring a 512-chip
  run onto a 256-chip mesh (or CPU) is the same call; resharding happens
  in ``jax.device_put``. Per-process sharded IO would slot in at
  ``_write_leaf`` (each process writing its addressable shards); in this
  single-process container every leaf is written whole.
* **Integrity**: crc32 per leaf file, verified on restore (corrupt or
  truncated checkpoints raise, and ``restore(strict=False)`` falls back
  to the previous step).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.utils import get_logger

log = get_logger("checkpoint")

_SEP = "::"
_NUMPY_NATIVE = {"bool", "int8", "uint8", "int16", "uint16", "int32",
                 "uint32", "int64", "uint64", "float16", "float32",
                 "float64", "complex64", "complex128"}


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"#{p.idx}"
    return str(p)


def _unflatten_into(treedef_tree, flat: Dict[str, np.ndarray]):
    """Rebuild arrays into the structure of ``treedef_tree`` (a matching
    tree of anything, e.g. ShapeDtypeStructs)."""
    paths = jax.tree_util.tree_flatten_with_path(treedef_tree)
    leaves = []
    for path, ref in paths[0]:
        key = _SEP.join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if hasattr(ref, "shape") and tuple(ref.shape) != arr.shape:
            raise ValueError(
                f"leaf {key!r}: checkpoint shape {arr.shape} != "
                f"expected {tuple(ref.shape)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths[1], leaves)


def latest_step(directory) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = [int(m.group(1)) for p in d.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def save(directory, step: int, tree, keep: int = 3) -> None:
    Checkpointer(directory, keep=keep).save(step, tree, blocking=True)


def restore(directory, target, step: Optional[int] = None,
            mesh=None, shardings=None, strict: bool = True):
    return Checkpointer(directory).restore(target, step=step, mesh=mesh,
                                           shardings=shardings,
                                           strict=strict)


class Checkpointer:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree, blocking: bool = True) -> None:
        self.wait()
        host = _flatten(jax.device_get(tree))   # snapshot before async
        if blocking:
            self._write(step, host)
        else:
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host), daemon=True)
            self._thread.start()

    def _write_guarded(self, step, host):
        try:
            self._write(step, host)
        except BaseException as e:              # noqa: BLE001
            self._error = e

    def _write(self, step: int, host: Dict[str, np.ndarray]) -> None:
        self.dir.mkdir(parents=True, exist_ok=True)
        tmp = self.dir / f"tmp.step_{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "leaves": {}}
        for i, (key, arr) in enumerate(sorted(host.items())):
            fname = f"leaf_{i:05d}.npy"
            dtype = str(arr.dtype)
            store = arr
            if dtype not in _NUMPY_NATIVE:
                # bfloat16/fp8 (ml_dtypes) don't survive np.save; store
                # the raw bits and record the logical dtype.
                store = arr.view(np.uint8).reshape(
                    arr.shape + (arr.dtype.itemsize,))
            np.save(tmp / fname, store)
            crc = zlib.crc32((tmp / fname).read_bytes())
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": dtype, "crc32": crc,
            }
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        log.info("saved checkpoint step %d (%d leaves)", step,
                 len(host))
        self._gc()

    def _gc(self) -> None:
        steps = sorted(
            int(m.group(1)) for p in self.dir.iterdir()
            if (m := re.fullmatch(r"step_(\d+)", p.name)))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---------------------------------------------------------- restore
    def restore(self, target, step: Optional[int] = None, mesh=None,
                shardings=None, strict: bool = True):
        """Load into the structure of ``target`` (tree of arrays or
        ShapeDtypeStructs). Optional ``shardings`` (tree of NamedSharding
        matching target) performs elastic resharding at load."""
        self.wait()
        candidates = ([step] if step is not None else
                      sorted((int(m.group(1)) for p in self.dir.iterdir()
                              if (m := re.fullmatch(r"step_(\d+)",
                                                    p.name))),
                             reverse=True))
        last_err: Optional[Exception] = None
        for s in candidates:
            try:
                flat = self._read(s)
                tree = _unflatten_into(target, flat)
                if shardings is not None:
                    tree = jax.tree.map(
                        lambda a, sh: jax.device_put(a, sh), tree,
                        shardings)
                return tree, s
            except Exception as e:              # noqa: BLE001
                last_err = e
                log.warning("checkpoint step %s unusable: %s", s, e)
                if strict:
                    raise
        raise FileNotFoundError(
            f"no usable checkpoint in {self.dir}: {last_err}")

    def _read(self, step: int) -> Dict[str, np.ndarray]:
        d = self.dir / f"step_{step}"
        with open(d / "manifest.json") as f:
            manifest = json.load(f)
        out = {}
        for key, meta in manifest["leaves"].items():
            raw = (d / meta["file"]).read_bytes()
            if zlib.crc32(raw) != meta["crc32"]:
                raise IOError(f"crc mismatch for {key} in step {step}")
            arr = np.load(d / meta["file"], allow_pickle=False)
            if meta["dtype"] not in _NUMPY_NATIVE:
                import ml_dtypes
                dt = np.dtype(getattr(ml_dtypes, meta["dtype"]))
                arr = arr.reshape(-1).view(dt).reshape(meta["shape"])
            out[key] = arr
        return out
