"""AST-level deprecation lint (absorbs benchmarks/run.py's regex scan).

The retired driving surface must not creep back in:

* importing ``sweep`` from ``repro.core.jax_engine`` (or calling
  ``jax_engine.sweep(...)``) — the shim exists for tests only; code
  goes through `repro.api.ExperimentSpec`;
* the ``REPRO_AZURE_NPZ`` env var — superseded by `NpzTrace`;
* benchmarks driving the Python event engine (``repro.core.simulate``
  / ``repro.core.simulator``) — every figure/ablation runs through
  the API since PR 4/5; only the head-to-head parity benches may.

The old regex scan matched raw text, so prose in a docstring could
trip it and a parenthesised import could dodge it. This pass parses
each file and inspects actual ``import`` statements, attribute calls
and string constants — comments and docs are structurally exempt
(string *constants* still count: an env-var read is a string
constant). `scan` keeps the regex scan's exact failure surface: one
``DEPRECATED ENTRY POINT: <path> <reason>`` line per hit on stderr,
return value = hit count, so `benchmarks/run.py --smoke` and CI are
unchanged consumers.
"""
from __future__ import annotations

import ast
import os
import sys
from typing import Iterator, List, Tuple

# Files allowed to reference the deprecated entry points: the shim
# itself, the smoke gate, the env-var fallback that now wraps
# NpzTrace, and this linter (it names what it bans).
DEPRECATION_ALLOW = {
    os.path.join("src", "repro", "core", "jax_engine.py"),
    os.path.join("src", "repro", "analysis", "lint.py"),
    os.path.join("benchmarks", "run.py"),
    os.path.join("benchmarks", "common.py"),
}

# Benchmarks allowed to *deliberately* drive the Python event engine:
# the engines-head-to-head microbenches (their whole point is the
# comparison) — everything else must go through repro.api.
PY_ENGINE_ALLOW = {
    os.path.join("benchmarks", "run.py"),
    os.path.join("benchmarks", "sim_throughput.py"),
}

SCAN_DIRS = ("src", "benchmarks", "examples", "scripts")

_ENGINE_MOD = "repro.core.jax_engine"
_PY_ENGINE_MODS = ("repro.core.simulator",)


def _dotted(node: ast.AST) -> str:
    """'a.b.c' for an Attribute/Name chain, '' otherwise."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def lint_source(text: str, *, is_benchmark: bool,
                py_engine_exempt: bool = False
                ) -> List[Tuple[int, str]]:
    """(lineno, reason) findings for one file's source text."""
    tree = ast.parse(text)
    out: List[Tuple[int, str]] = []
    # docstrings/prose are bare-expression string statements — exempt
    # (an env-var *read* passes the name as an argument, never as a
    # free-standing expression statement)
    doc_ids = {id(node.value) for node in ast.walk(tree)
               if isinstance(node, ast.Expr)
               and isinstance(node.value, ast.Constant)
               and isinstance(node.value.value, str)}
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            names = {a.name for a in node.names}
            if mod == _ENGINE_MOD and "sweep" in names:
                out.append((node.lineno,
                            "imports sweep from jax_engine"))
            if is_benchmark and not py_engine_exempt:
                if mod == "repro.core" and "simulate" in names:
                    out.append((node.lineno,
                                "drives the Python event engine"
                                " (use repro.api)"))
                if mod in _PY_ENGINE_MODS or mod.startswith(
                        _PY_ENGINE_MODS[0] + "."):
                    out.append((node.lineno,
                                "drives the Python event engine"
                                " (use repro.api)"))
        elif isinstance(node, ast.Import):
            if is_benchmark and not py_engine_exempt and any(
                    a.name in _PY_ENGINE_MODS or
                    a.name.startswith(_PY_ENGINE_MODS[0] + ".")
                    for a in node.names):
                out.append((node.lineno,
                            "drives the Python event engine"
                            " (use repro.api)"))
        elif isinstance(node, ast.Call):
            chain = _dotted(node.func)
            if chain.endswith("jax_engine.sweep"):
                out.append((node.lineno, "calls jax_engine.sweep()"))
        elif isinstance(node, ast.Constant):
            if (isinstance(node.value, str)
                    and "REPRO_AZURE_NPZ" in node.value
                    and id(node) not in doc_ids):
                out.append((node.lineno,
                            "reads the REPRO_AZURE_NPZ env var "
                            "(use NpzTrace)"))
    return sorted(set(out))


def repo_root() -> str:
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def iter_findings(root: str) -> Iterator[Tuple[str, int, str]]:
    for sub in SCAN_DIRS:
        for dirpath, _, files in os.walk(os.path.join(root, sub)):
            for f in sorted(files):
                if not f.endswith(".py"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, f), root)
                if rel in DEPRECATION_ALLOW:
                    continue
                with open(os.path.join(dirpath, f)) as fh:
                    text = fh.read()
                try:
                    findings = lint_source(
                        text, is_benchmark=(sub == "benchmarks"),
                        py_engine_exempt=(rel in PY_ENGINE_ALLOW))
                except SyntaxError as e:
                    findings = [(e.lineno or 0,
                                 f"does not parse: {e.msg}")]
                for lineno, reason in findings:
                    yield rel, lineno, reason


def scan(root: str = None, out=None) -> int:
    """Drop-in replacement for the old regex `deprecation_scan`:
    prints one line per hit, returns the hit count."""
    root = root or repo_root()
    out = out or sys.stderr
    bad = 0
    for rel, lineno, reason in iter_findings(root):
        bad += 1
        print(f"DEPRECATED ENTRY POINT: {rel}:{lineno} {reason}",
              file=out)
    return bad


def audit_lint(root: str = None) -> dict:
    """Gate wrapper for the JSON report."""
    root = root or repo_root()
    findings = [f"{rel}:{lineno} {reason}"
                for rel, lineno, reason in iter_findings(root)]
    return dict(entry="repo_tree", passed=not findings,
                findings=len(findings), problems=findings)
