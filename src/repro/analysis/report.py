"""Gate orchestration and the JSON report for ``python -m
repro.analysis``.

One pass traces every audit entry (cheap), runs the jaxpr-level gates
on each trace, compiles optimized HLO for the ``compile_hlo`` entries
(the expensive step, shared by the copy and f32 gates), then the
boundary/backoff dtype checks, the recompilation audit (the only gate
that executes the engines) and the AST lint. The report is
self-describing: per-gate ``passed`` + measured values + actionable
``problems`` strings; CI uploads it next to BENCH_smoke.json.
"""
from __future__ import annotations

import time
from dataclasses import asdict
from typing import Dict, List, Optional

from repro.analysis.markers import MARKERS

GATES = ("carry_budget", "copy_insertion", "gather_cliff",
         "dtype_policy", "recompilation", "deprecation_lint",
         "telemetry_lowering")


def _merge(entries: List[Dict]) -> Dict:
    return dict(passed=all(e["passed"] for e in entries),
                entries=entries,
                problems=[p for e in entries
                          for p in e.get("problems", ())])


def run_gates(gates: Optional[List[str]] = None,
              copy_budget: int = 2, log=None) -> Dict:
    gates = list(gates) if gates is not None else list(GATES)
    unknown = set(gates) - set(GATES)
    if unknown:
        raise SystemExit(f"unknown gate(s) {sorted(unknown)}; "
                         f"available: {list(GATES)}")
    say = log or (lambda *_: None)
    t0 = time.perf_counter()
    report: Dict = dict(schema=1, markers=asdict(MARKERS),
                        copy_budget=copy_budget, gates={})

    need_traces = {"carry_budget", "gather_cliff",
                   "dtype_policy", "telemetry_lowering"} & set(gates)
    need_hlo = {"copy_insertion", "dtype_policy",
                "telemetry_lowering"} & set(gates)

    traced = {}
    entries = ()
    if need_traces or need_hlo:
        import jax

        from repro.analysis.entrypoints import build_entries
        report["jax_version"] = jax.__version__
        entries = build_entries()
        for e in entries:
            say(f"tracing {e.name}")
            traced[e.name] = e.trace()

    if "carry_budget" in gates:
        from repro.analysis.carries import audit_carries
        say("carry budget")
        report["gates"]["carry_budget"] = _merge(
            [audit_carries(e, traced[e.name]) for e in entries])

    if "gather_cliff" in gates:
        from repro.analysis.gathers import audit_gathers
        say("gather cliff")
        report["gates"]["gather_cliff"] = _merge(
            [audit_gathers(e, traced[e.name]) for e in entries])

    hlo_texts = {}
    if need_hlo:
        for e in entries:
            if e.compile_hlo:
                say(f"compiling {e.name} (optimized HLO)")
                hlo_texts[e.name] = (
                    traced[e.name].lower().compile().as_text())

    if "copy_insertion" in gates:
        from repro.analysis.hlo import audit_copies
        say("copy insertion")
        budgets = {e.name: e.copy_budget for e in entries}
        report["gates"]["copy_insertion"] = _merge(
            [audit_copies(name, text, MARKERS,
                          budget=(copy_budget
                                  if budgets.get(name) is not None
                                  else None))
             for name, text in hlo_texts.items()])

    if "dtype_policy" in gates:
        from repro.analysis.dtypes import (audit_backoff_jaxpr,
                                           audit_boundary_dtypes,
                                           audit_entry_dtypes)
        from repro.analysis.hlo import audit_f32
        say("dtype policy")
        checks = [audit_entry_dtypes(e, traced[e.name])
                  for e in entries]
        checks += [audit_f32(f"{name}:hlo", text)
                   for name, text in hlo_texts.items()]
        checks.append(audit_backoff_jaxpr())
        checks.append(audit_boundary_dtypes())
        report["gates"]["dtype_policy"] = _merge(checks)

    if "telemetry_lowering" in gates:
        from repro.analysis.telemetry_gate import audit_telemetry
        say("telemetry lowering (untraced HLO callback-free)")
        report["gates"]["telemetry_lowering"] = _merge(
            audit_telemetry(hlo_texts))

    if "recompilation" in gates:
        from repro.analysis.recompile import audit_recompilation
        say("recompilation audit (runs a tiny grid)")
        report["gates"]["recompilation"] = _merge(
            [audit_recompilation()])

    if "deprecation_lint" in gates:
        from repro.analysis.lint import audit_lint
        say("deprecation lint")
        report["gates"]["deprecation_lint"] = _merge([audit_lint()])

    report["wall_s"] = round(time.perf_counter() - t0, 2)
    report["passed"] = all(g["passed"]
                           for g in report["gates"].values())
    return report
