"""Gather-cliff detector: no per-event reads of multi-row shared
operands.

The cliff (found in PR 5, re-found in PR 6, both at ~25x): inside a
jitted loop, a gather whose operand is a *multi-row* array — leading
dimension T > 1 — with more than ``ROW_SPLIT_ELEMS`` total elements
drops XLA:CPU onto a generic gather path. The engines avoid the shape
entirely: every per-event trace read goes through flattened ``(T*N,)``
views with per-lane base offsets (rank-1 gathers are immune), and
`repro.api.runner` row-splits any grid whose stacked operands would
exceed the threshold.

This analyzer re-checks the first half on every traced entry: walk the
jaxpr for ``gather``/``dynamic_slice`` equations inside loop bodies
and flag any whose operand is rank >= 2 with a T-sized leading
dimension and an N-scaling dimension (symbolically above the
threshold at production sizes — the markers keep T and N
unambiguous). Windowed trace-slab refreshes are the one sanctioned
dynamic-slice of that shape: a per-*window* contiguous copy of W
columns, not a per-event random gather, recognised by its static
``slice_sizes`` ending in W.
"""
from __future__ import annotations

from typing import Dict

from repro.analysis.entrypoints import AuditEntry
from repro.analysis.jaxprs import in_loop, walk_eqns

# Mirrors repro.api.runner.ROW_SPLIT_ELEMS (imported lazily in
# audit_gathers to keep this module import-light for the linter).
_PRIMS = ("gather", "dynamic_slice")


def _cliff_shaped(shape, m) -> bool:
    return (len(shape) >= 2 and shape[0] == m.T
            and any(m.scales_with_n(d) for d in shape[1:]))


def audit_gathers(entry: AuditEntry, traced) -> Dict:
    from repro.api.runner import ROW_SPLIT_ELEMS
    m = entry.markers
    checked = 0
    hits = []
    slab_refreshes = 0
    for path, eqn in walk_eqns(traced.jaxpr.jaxpr):
        if eqn.primitive.name not in _PRIMS or not in_loop(path):
            continue
        operand = eqn.invars[0].aval
        shape = tuple(getattr(operand, "shape", ()))
        checked += 1
        if not _cliff_shaped(shape, m):
            continue
        if eqn.primitive.name == "dynamic_slice":
            sizes = tuple(eqn.params.get("slice_sizes", ()))
            if sizes and sizes[-1] == m.W:
                slab_refreshes += 1   # windowed trace-slab copy
                continue
        hits.append(
            f"{entry.name} [{'/'.join(path)}]: {eqn.primitive.name} "
            f"over a {'x'.join(m.shape_class(shape))} operand "
            f"(leading dim T={m.T} > 1, trace-scaling row) inside a "
            f"loop body — above ROW_SPLIT_ELEMS={ROW_SPLIT_ELEMS} "
            f"this is the ~25x XLA:CPU generic-gather cliff (PR 5/6)."
            f" Read through a flattened (T*N,) view with per-lane "
            f"base offsets instead (see EngineCtx).")
    return dict(entry=entry.name, passed=not hits,
                loop_gathers_checked=checked,
                sanctioned_slab_refreshes=slab_refreshes,
                problems=hits)
