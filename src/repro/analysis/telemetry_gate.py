"""Telemetry-lowering gate: tracing must be structurally free when off.

``trace_events=False`` is a *static* jit flag, so the disabled path
must lower onto the unchanged event loops — machine-checked here at
the compiled-HLO level: the untraced engines' optimized HLO must
contain **zero** callback custom calls (the trace rail's only escape
to the host is `jax.experimental.io_callback`). The complementary
positive check traces the ``trace=True`` variants and asserts the
ordered callback IS present — so the gate cannot rot into vacuously
passing if the rail's flush mechanism is renamed.
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis.markers import MARKERS, Markers

_NEEDLE = "callback"


def audit_telemetry(hlo_texts: Dict[str, str],
                    m: Markers = MARKERS) -> List[dict]:
    """One check per untraced compiled entry (zero callback custom
    calls) plus one positive traced-jaxpr check per tier."""
    checks: List[dict] = []
    for name, text in hlo_texts.items():
        hits = text.lower().count(_NEEDLE)
        checks.append(dict(
            name=f"{name}:untraced_hlo", passed=hits == 0,
            callback_hits=hits,
            problems=([] if hits == 0 else
                      [f"{name}: untraced compiled HLO contains "
                       f"{hits} callback reference(s) — the disabled "
                       "trace rail must lower onto the unchanged "
                       "loop"])))

    from repro.analysis.entrypoints import _cluster_args, _single_args
    from repro.cluster.engine import _cluster_metrics
    from repro.cluster.routers import get_router
    from repro.core.jax_engine import _sweep_metrics
    from repro.core.jax_policies import KERNELS

    jx_single = str(_sweep_metrics.trace(
        *_single_args(m), kernel=KERNELS["esff"], n_fns=m.F,
        capacity=m.C, queue_cap=m.Q, stream=True, trace=True).jaxpr)
    jx_cluster = str(_cluster_metrics.trace(
        *_cluster_args(m), kernel=KERNELS["esff"],
        router=get_router("jsq2"), n_nodes=m.K, n_fns=m.F,
        capacity=m.C, queue_cap=m.Q, stream=True, trace=True).jaxpr)
    for tier, jx in (("single_stream", jx_single),
                     ("cluster_stream", jx_cluster)):
        ok = _NEEDLE in jx.lower()
        checks.append(dict(
            name=f"{tier}:traced_jaxpr", passed=ok,
            problems=([] if ok else
                      [f"{tier}: trace=True jaxpr has no callback — "
                       "the flush mechanism changed; update the "
                       "telemetry gate needle"])))
    return checks
