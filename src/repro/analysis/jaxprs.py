"""Recursive jaxpr traversal shared by the jaxpr-level analyzers.

`walk_eqns` yields every equation in a closed jaxpr and all its
sub-jaxprs (pjit bodies, while cond/body, scan bodies, cond branches,
custom_* rules) with a structural path, so analyzers can tell whether
an op sits inside a loop body. `loops` yields each `while`/`scan`
equation together with its carried output avals — for `while` the body
jaxpr's outputs *are* the carry; for `scan` the first ``num_carry``
outputs are.
"""
from __future__ import annotations

from typing import Any, Iterator, List, Tuple


def _sub_jaxprs(params: dict) -> Iterator[Any]:
    from jax.core import ClosedJaxpr, Jaxpr
    for v in params.values():
        vs = v if isinstance(v, (list, tuple)) else (v,)
        for vv in vs:
            if isinstance(vv, ClosedJaxpr):
                yield vv.jaxpr
            elif isinstance(vv, Jaxpr):
                yield vv


def walk_eqns(jaxpr, path: Tuple[str, ...] = ()) -> Iterator[
        Tuple[Tuple[str, ...], Any]]:
    """Yield ``(path, eqn)`` for every equation, depth-first. ``path``
    is the chain of enclosing primitive names (e.g. ``("pjit",
    "while", "scan")``)."""
    for eqn in jaxpr.eqns:
        yield path, eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from walk_eqns(sub, path + (eqn.primitive.name,))


def in_loop(path: Tuple[str, ...]) -> bool:
    return "while" in path or "scan" in path


def loops(jaxpr) -> Iterator[Tuple[Tuple[str, ...], Any, List[Any]]]:
    """Yield ``(path, eqn, carry_avals)`` for every while/scan."""
    for path, eqn in walk_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            yield path, eqn, [v.aval for v in body.outvars]
        elif name == "scan":
            body = eqn.params["jaxpr"].jaxpr
            nc = eqn.params["num_carry"]
            yield path, eqn, [v.aval for v in body.outvars[:nc]]
