"""Traceable engine entry points for the invariant auditor.

Each `AuditEntry` names one jitted event loop variant and builds the
abstract (`jax.ShapeDtypeStruct`) operands to trace it at the marker
shapes — `jax.jit`'s AOT stages then give the jaxpr (``.trace``) and
optimized HLO (``.lower().compile().as_text()``) without executing a
single event. The variant list covers every static-flag combination
that changes the traced program: streaming/exact, timer rails,
windowed slabs, the resilience rail, and the dynamic cluster tier with
net-delay, churn and resilience.

``allow`` names the rails (keys of the owning engine module's
`CARRY_RAILS`) whose N-scaling carries are accepted — the carry gate
fails on any deviation from that multiset, in either direction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Tuple

from repro.analysis.markers import MARKERS, Markers

# (shape-class, dtype) signature of every allowed rail, by tier. The
# signatures are what the carry gate matches: rail *names* exist only
# in the report (jaxpr carries are anonymous).
RAIL_SIGS = {
    "single": {
        "start": (("L", "N"), "float64"),
        "completion": (("L", "N"), "float64"),
        "nxt": (("L", "N"), "int32"),
        "att": (("L", "N"), "int32"),
        "rt_t": (("L", "N"), "float64"),
    },
    "cluster": {
        "nxt": (("L", "N"), "int32"),
        "tnx": (("L", "N"), "int32"),
        "dnx": (("L", "N"), "int32"),
        "node_of": (("L", "N"), "int32"),
        "att": (("L", "N"), "int32"),
        "land_t": (("L", "N"), "float64"),
        "rt_t": (("L", "N"), "float64"),
        "start": (("L", "N"), "float64"),
        "completion": (("L", "N"), "float64"),
    },
}

# Static resil tuple (max_attempts, shed_mode, base, cap, jitter,
# seed) — values are irrelevant to the traced structure.
_RESIL = (3, 0, 0.5, 8.0, 0.25, 42)


@dataclass(frozen=True)
class AuditEntry:
    name: str
    tier: str                      # "single" | "cluster"
    build: Callable[[], object]    # -> jax.stages.Traced
    allow: Tuple[str, ...]         # rail names from CARRY_RAILS
    compile_hlo: bool = False      # optimized-HLO gates run on these
    # max table-scale copies per while body; None = report-only. Only
    # the dynamic loop carries the PR-6-verified <= 2 bound — the
    # single-node loop predates the write-first register spelling and
    # is throughput-gated by BENCH instead.
    copy_budget: int = None
    markers: Markers = field(default=MARKERS)

    def trace(self):
        return self.build()

    def rail_rationales(self) -> Dict[str, str]:
        if self.tier == "single":
            from repro.core.jax_engine import CARRY_RAILS
        else:
            from repro.cluster.engine import CARRY_RAILS
        return {r: CARRY_RAILS[r] for r in self.allow}


def _single_args(m: Markers):
    import jax
    import jax.numpy as jnp
    S = jax.ShapeDtypeStruct
    return (S((m.T, m.N), jnp.int32),      # fn_id
            S((m.T, m.N), jnp.float64),    # arrival
            S((m.T, m.N), jnp.float64),    # exec_time
            S((m.T, m.F), jnp.float64),    # t_cold
            S((m.T, m.F), jnp.float64),    # t_evict
            S((m.L,), jnp.int32),          # trace_ix
            S((m.L, m.C), jnp.bool_),      # cap_mask
            S((m.L,), jnp.float64),        # beta
            S((), jnp.float64),            # prior
            S((), jnp.float64))            # threshold


def _cluster_args(m: Markers):
    import jax
    import jax.numpy as jnp
    S = jax.ShapeDtypeStruct
    return (S((m.T, m.N), jnp.int32),
            S((m.T, m.N), jnp.float64),
            S((m.T, m.N), jnp.float64),
            S((m.T, m.F), jnp.float64),
            S((m.T, m.F), jnp.float64),
            S((m.L,), jnp.int32),
            S((m.L, m.K, m.C), jnp.bool_),  # per-node slot masks
            S((m.L,), jnp.float64),
            S((), jnp.float64),
            S((), jnp.float64),
            S((m.K,), jnp.float64))         # delays
    # churn/delay-schedule/resilience operands are appended per entry


def _resil_args(m: Markers):
    import jax
    import jax.numpy as jnp
    S = jax.ShapeDtypeStruct
    return dict(rs_nfail=S((m.T, m.N), jnp.int32),
                rs_tmo=S((m.T, m.N), jnp.bool_),
                rs_key=S((m.T, m.N), jnp.int32))


def build_entries(m: Markers = MARKERS) -> Tuple[AuditEntry, ...]:
    """The audited variant list. Tracing is cheap (~100 ms/entry);
    only ``compile_hlo`` entries pay XLA compilation."""
    import jax

    from repro.cluster.engine import _cluster_metrics
    from repro.cluster.routers import get_router
    from repro.core.jax_engine import _sweep_metrics
    from repro.core.jax_policies import KERNELS

    def single(kernel="esff", extra=None, **kw):
        def build():
            args = _single_args(m)
            kwargs = dict(kernel=KERNELS[kernel], n_fns=m.F,
                          capacity=m.C, queue_cap=m.Q, stream=True)
            kwargs.update(extra() if extra else {})
            kwargs.update(kw)
            return _sweep_metrics.trace(*args, **kwargs)
        return build

    def cluster(kernel="esff", router="jsq2", extra=None, **kw):
        def build():
            args = _cluster_args(m)
            kwargs = dict(kernel=KERNELS[kernel],
                          router=get_router(router), n_nodes=m.K,
                          n_fns=m.F, capacity=m.C, queue_cap=m.Q,
                          stream=True)
            kwargs.update(extra() if extra else {})
            kwargs.update(kw)
            return _cluster_metrics.trace(*args, **kwargs)
        return build

    def nlive():
        import jax.numpy as jnp
        return dict(n_live=jax.ShapeDtypeStruct((m.L,), jnp.int32))

    def churn_op():
        import jax.numpy as jnp
        return dict(churn_t=jax.ShapeDtypeStruct((m.K, m.E),
                                                 jnp.float64))

    return (
        AuditEntry("single_stream", "single", single(),
                   allow=(), compile_hlo=True, markers=m),
        AuditEntry("single_stream_padded", "single",
                   single(extra=nlive), allow=(), markers=m),
        AuditEntry("single_exact", "single", single(stream=False),
                   allow=("start", "completion"), markers=m),
        AuditEntry("single_timers", "single",
                   single(kernel="openwhisk_v2"), allow=(), markers=m),
        AuditEntry("single_windowed", "single", single(window=m.W),
                   allow=(), markers=m),
        AuditEntry("single_resil", "single",
                   single(resil=_RESIL, extra=_resil_args_thunk(m)),
                   allow=("nxt", "att", "rt_t"), markers=m),
        AuditEntry("cluster_stream", "cluster", cluster(),
                   allow=("nxt",), compile_hlo=True, copy_budget=2,
                   markers=m),
        AuditEntry("cluster_timers", "cluster",
                   cluster(kernel="openwhisk_v2"),
                   allow=("nxt", "tnx"), markers=m),
        AuditEntry("cluster_delay", "cluster",
                   cluster(has_delay=True),
                   allow=("nxt", "dnx"), markers=m),
        AuditEntry("cluster_churn", "cluster",
                   cluster(has_delay=True, has_churn=True,
                           extra=churn_op),
                   allow=("nxt", "dnx", "land_t"), markers=m),
        AuditEntry("cluster_resil", "cluster",
                   cluster(resil=_RESIL, extra=_resil_args_thunk(m)),
                   allow=("nxt", "att", "rt_t"), markers=m),
        AuditEntry("cluster_exact_delay", "cluster",
                   cluster(stream=False, has_delay=True),
                   allow=("nxt", "dnx", "node_of", "start",
                          "completion"), markers=m),
    )


def _resil_args_thunk(m: Markers):
    return lambda: _resil_args(m)
