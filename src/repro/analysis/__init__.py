"""Static analysis of the compiled engines: jaxpr/HLO invariant gates.

The scheduling engines' throughput rests on a handful of hand-earned
XLA:CPU invariants (ROADMAP PRs 3/5/6) that nothing used to check:

* **carry budget** — streaming loop state is O(F + C + SEG +
  HIST_BINS) per lane; any carried array that scales with the trace
  length N must be a documented rid-chain rail (`carries`).
* **copy insertion** — the dynamic loop's write-first cursor-register
  spelling keeps XLA's read-then-write liveness copies to <= 2 large
  copies per event step (`hlo`).
* **gather cliff** — per-event gathers must never read a multi-row
  shared operand above ``ROW_SPLIT_ELEMS`` elements; all trace reads
  go through flattened (T*N,) views (`gathers`).
* **recompilation** — a (router, K, heterogeneity) grid on the static
  tier collapses onto one padded (1, N) specialisation per policy
  (`recompile`).
* **dtype policy** — engine programs are f64-only past the x64 import
  guard; no f32 may appear in any traced value (`dtypes`).
* **deprecation lint** — AST-level scan for the retired driving
  surface (`lint`).

Everything except the recompilation auditor works from `jax.jit`'s
AOT stages (``trace`` -> ``lower`` -> ``compile``) without executing a
single event loop. ``python -m repro.analysis`` runs the gates and
emits a JSON report; see docs/analysis.md.
"""
from repro.analysis.report import GATES, run_gates

__all__ = ["GATES", "run_gates"]
