"""Optimized-HLO analyzers: copy-insertion gate and f32 leak scan.

XLA's copy-insertion pass materialises a copy for every buffer that is
read after being (aliased-)written inside a loop body — the
read-before-write spelling costs 2 copies per event per state table,
which PR 6 eliminated for the dynamic loop with write-first cursor
registers (HLO-verified 8 -> 2 large copies). This gate re-verifies
that bound mechanically on every run: parse the compiled module text,
find each while-loop body computation, and count copies of
*table-scale* arrays (an F-divisible or N-scaling dimension; see
`markers.Markers.is_table_scale`). Scalar shuffles and constant-size
counter copies are free by comparison and not counted.

The f32 scan is the compiled-side half of the dtype gate: no ``f32``
tensor may appear anywhere in an engine module's optimized HLO.
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

from repro.analysis.markers import Markers

# `%name = f64[3,11]{1,0} copy(%operand)` — shape first, layout
# annotation optional.
# parameter lists and result types contain nested parens, so the
# middle of the header is matched greedily up to the opening brace
_COMP_HEAD = re.compile(
    r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(.*\))?\s*(?:->[^{]*)?\{")
_COPY = re.compile(r"^\s*%?[\w.\-]+\s*=\s*(\w+)\[([\d,]*)\]"
                   r"(?:\{[^}]*\})?\s*copy\(")
_WHILE_BODY = re.compile(r"\bwhile\([^)]*\).*?body=%?([\w.\-]+)")
_F32 = re.compile(r"\bf32\[")


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Computation name -> its instruction lines."""
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_HEAD.match(line)
        if m:
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def while_bodies(comps: Dict[str, List[str]]) -> List[str]:
    bodies = []
    for lines in comps.values():
        for ln in lines:
            m = _WHILE_BODY.search(ln)
            if m and m.group(1) in comps:
                bodies.append(m.group(1))
    return sorted(set(bodies))


def _parse_shape(dims: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in dims.split(",") if d)


def count_large_copies(hlo_text: str, m: Markers) -> Dict:
    """Per-while-body counts of table-scale copies, plus the max over
    bodies (the per-event-step figure PR 6 bounded at 2)."""
    comps = split_computations(hlo_text)
    bodies = while_bodies(comps)
    per_body = {}
    for b in bodies:
        large = []
        for ln in comps[b]:
            cm = _COPY.match(ln)
            if not cm:
                continue
            shape = _parse_shape(cm.group(2))
            if m.is_table_scale(shape):
                large.append(f"{cm.group(1)}[{cm.group(2)}]")
        if large:
            per_body[b] = large
    max_large = max((len(v) for v in per_body.values()), default=0)
    return dict(while_bodies=len(bodies),
                large_copies_per_body={b: v
                                       for b, v in per_body.items()},
                max_large_copies_per_body=max_large)


def audit_copies(entry_name: str, hlo_text: str, m: Markers,
                 budget=2) -> Dict:
    """Copy-insertion gate: table-scale copies per while body <=
    ``budget`` (the PR-6-verified bound for the dynamic loop).
    ``budget=None`` measures and reports without gating — used for
    the single-node loop, whose pre-PR-6 spelling is throughput-gated
    by BENCH rather than by copy count."""
    counts = count_large_copies(hlo_text, m)
    n = counts["max_large_copies_per_body"]
    problems = []
    if counts["while_bodies"] == 0:
        problems.append(
            f"{entry_name}: no while-loop body found in the "
            f"optimized HLO — the event loop is gone or the module "
            f"parser regressed; either way the copy gate cannot "
            f"measure and must not pass silently.")
    if budget is not None and n > budget:
        worst = max(counts["large_copies_per_body"].items(),
                    key=lambda kv: len(kv[1]))
        problems.append(
            f"{entry_name}: {n} table-scale copies per iteration of "
            f"while body '{worst[0]}' (budget {budget}): "
            f"{worst[1]}. XLA copy-insertion charges 2 copies per "
            f"event per state table that is read before it is "
            f"written — keep the write-first cursor-register "
            f"spelling (PR 6): stage per-event writes in scalar "
            f"registers and commit them once, after the last read.")
    return dict(entry=entry_name, passed=not problems,
                measured=counts, budget=budget, problems=problems)


def audit_f32(entry_name: str, hlo_text: str) -> Dict:
    """Compiled-side dtype gate: zero f32 tensors in the module."""
    hits = len(_F32.findall(hlo_text))
    problems = []
    if hits:
        lines = [ln.strip()[:120] for ln in hlo_text.splitlines()
                 if _F32.search(ln)][:5]
        problems.append(
            f"{entry_name}: {hits} f32 tensor(s) in optimized HLO — "
            f"the engine dtype policy is f64-only past the x64 "
            f"import guard (`ensure_x64`). First sites: {lines}")
    return dict(entry=entry_name, passed=not problems,
                f32_tensors=hits, problems=problems)
