"""CLI: ``python -m repro.analysis [--out report.json] [--gates ...]``.

Exit status 0 iff every gate passed. ``--gates`` takes a
comma-separated subset (e.g. ``--gates deprecation_lint`` for the
fast lint-only run); ``--quick`` skips the two expensive stages
(XLA compilation and the recompilation grid)."""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis.report import GATES, run_gates


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxpr/HLO invariant gates for the scheduling "
                    "engines (see docs/analysis.md)")
    ap.add_argument("--out", metavar="PATH",
                    help="write the JSON report here (default: "
                         "stdout only prints the summary)")
    ap.add_argument("--gates", metavar="G1,G2",
                    help=f"subset of {','.join(GATES)}")
    ap.add_argument("--quick", action="store_true",
                    help="jaxpr + lint gates only (no XLA compile, "
                         "no grid run)")
    ap.add_argument("--copy-budget", type=int, default=2,
                    help="max table-scale copies per while body "
                         "(default: the PR-6-verified 2)")
    ap.add_argument("--json", action="store_true",
                    help="print the full report to stdout")
    args = ap.parse_args(argv)

    gates = None
    if args.gates:
        gates = [g.strip() for g in args.gates.split(",") if g.strip()]
    if args.quick:
        gates = [g for g in (gates or list(GATES))
                 if g not in ("copy_insertion", "recompilation")]

    report = run_gates(gates=gates, copy_budget=args.copy_budget,
                       log=lambda msg: print(f"[analysis] {msg}",
                                             file=sys.stderr))

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
    if args.json:
        json.dump(report, sys.stdout, indent=2, sort_keys=True)
        print()

    for name, gate in report["gates"].items():
        status = "OK" if gate["passed"] else "FAIL"
        print(f"{name:18s} {status}")
        for p in gate["problems"]:
            print(f"  - {p}", file=sys.stderr)
    print(f"analysis: {'OK' if report['passed'] else 'FAIL'} "
          f"({report['wall_s']}s)")
    return 0 if report["passed"] else 1


if __name__ == "__main__":
    sys.exit(main())
