"""Dtype-policy gate: the engines are f64-only past the x64 guard.

Every simulated timestamp is an absolute f64 second; a single f32
intermediate would silently halve the mantissa and break the repo's
bitwise Python/JAX parity gates. The engines guarantee this with the
`ensure_x64` import guard, but a weakly-typed Python constant or an
explicit narrow cast could still drag a traced value to f32. Three
checks:

* jaxpr scan — no equation in any audited entry produces a float32
  (or float16/bfloat16) value;
* boundary scan — the numpy operands the spec layer lowers for the
  jitted loops (`ClusterSpec.delay_ops`, `ClusterSpec.churn_operand`,
  `ExperimentSpec.resilience_ops`) are exactly float64/int32/bool;
* `backoff_jax` — the one helper traced *inside* the loops from
  Python-float statics (the resil tuple) keeps an all-f64 jaxpr.

The compiled-side twin (zero ``f32[`` in optimized HLO) lives in
`repro.analysis.hlo.audit_f32`.
"""
from __future__ import annotations

from typing import Dict, List

from repro.analysis.entrypoints import AuditEntry
from repro.analysis.jaxprs import walk_eqns

_NARROW = ("float32", "float16", "bfloat16")


def _narrow_outputs(jaxpr) -> List[str]:
    hits = []
    for path, eqn in walk_eqns(jaxpr):
        for v in eqn.outvars:
            dt = str(getattr(v.aval, "dtype", ""))
            if dt in _NARROW:
                hits.append(f"{'/'.join(path) or '.'}: "
                            f"{eqn.primitive.name} -> {dt}"
                            f"{tuple(v.aval.shape)}")
    return hits


def audit_entry_dtypes(entry: AuditEntry, traced) -> Dict:
    hits = _narrow_outputs(traced.jaxpr.jaxpr)
    problems = [
        f"{entry.name}: narrow float produced by {h} — engine "
        f"programs are f64-only (ensure_x64); pin the constant or "
        f"operand to jnp.float64." for h in hits[:8]]
    return dict(entry=entry.name, passed=not hits,
                narrow_values=len(hits), problems=problems)


def audit_boundary_dtypes() -> Dict:
    """Check the spec layer's lowered numpy operands at a
    representative configuration of every schedule/fault knob."""
    import numpy as np

    from repro.api.spec import ExperimentSpec, SyntheticTrace
    from repro.cluster.spec import (ClusterSpec, DelaySchedule,
                                    PeriodicChurn)

    problems = []
    checked = {}

    def expect(name, arr, want):
        got = str(np.asarray(arr).dtype)
        checked[name] = got
        if got != want:
            problems.append(
                f"spec lowering '{name}' produced {got}, engine "
                f"boundary requires {want} — pin the array dtype at "
                f"the lowering site.")

    cs = ClusterSpec(
        n_nodes=3, router="jsq2", net_delay=(0.0, 0.01, 0.02),
        delay_schedule=(None,
                        DelaySchedule(times=(0.0, 5.0),
                                      values=(0.01, 0.05)),
                        DelaySchedule(times=(0.0, 2.0, 4.0),
                                      values=(0.0, 0.1, 0.02),
                                      period=8.0)),
        churn=PeriodicChurn(period=10.0, duty=0.8))
    dops = cs.delay_ops()
    expect("delay_ops.dtimes", dops[0], "float64")
    expect("delay_ops.dvals", dops[1], "float64")
    expect("delay_ops.dper", dops[2], "float64")
    expect("delays", np.asarray(cs.delays(), np.float64), "float64")
    churn_t = cs.churn_operand(horizon=30.0)
    expect("churn_operand", churn_t, "float64")

    spec = ExperimentSpec(
        traces=[SyntheticTrace.make(n_functions=4, n_requests=64,
                                    seed=1)],
        policies=("esff",), capacities=(4,), fail_prob=0.1,
        timeouts=5.0)
    arrays = spec.expanded_traces()[0].arrays()
    stacked = {k: np.asarray(arrays[k])[None]
               for k in ("fn_id", "arrival", "exec_time")}
    rs = spec.resilience_ops(stacked, 4)
    eff, nfail, tmo, key, resil = rs
    expect("resilience_ops.eff_exec", eff, "float64")
    expect("resilience_ops.n_fail", nfail, "int32")
    expect("resilience_ops.is_tmo", tmo, "bool")
    expect("resilience_ops.rid_key", key, "int32")
    for i, v in enumerate(resil[2:5]):
        if type(v) is not float:
            problems.append(
                f"resil tuple slot {i + 2} is {type(v).__name__}, "
                f"expected a Python float (it becomes a traced "
                f"constant inside backoff_jax).")

    return dict(entry="spec_boundaries", passed=not problems,
                checked=checked, problems=problems)


def audit_backoff_jaxpr() -> Dict:
    """Trace `backoff_jax` exactly as the engines call it (i32 arrays,
    Python-float statics) and hold its jaxpr to the f64-only policy —
    the pin for the PR-9 weak-constant audit of core/resilience.py."""
    import jax
    import jax.numpy as jnp

    from repro.core.resilience import backoff_jax

    jaxpr = jax.make_jaxpr(
        lambda a, k: backoff_jax(a, k, 0.5, 8.0, 0.25, 42))(
            jax.ShapeDtypeStruct((7,), jnp.int32),
            jax.ShapeDtypeStruct((7,), jnp.int32))
    hits = _narrow_outputs(jaxpr.jaxpr)
    out_dt = str(jaxpr.out_avals[0].dtype)
    problems = [f"backoff_jax: narrow float at {h}" for h in hits]
    if out_dt != "float64":
        problems.append(f"backoff_jax returns {out_dt}, expected "
                        f"float64")
    return dict(entry="backoff_jax", passed=not problems,
                out_dtype=out_dt, problems=problems)
