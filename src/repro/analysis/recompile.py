"""Recompilation auditor: specialisation count == padding-sharing
design.

The static cluster tier's whole performance story (PR 5) is that it
*never* re-specialises the engine: node sub-streams are PAD-padded
back to the full (1, N) row shape and masked with ``n_live``, so
every (router, K, heterogeneous-capacity) topology reuses ONE
`_sweep_metrics` cache entry per policy. The dynamic tier, by
contrast, legitimately specialises per (router, K) cell — ``router``
and ``n_nodes`` are static arguments of a different program.

This is the one analyzer that executes the engines (a few hundred
synthetic requests — the point is the *cache count*, not the result):
it clears every engine jit cache, runs a representative
`ExperimentSpec` grid that crosses static routers, node counts and a
heterogeneous topology with dynamic cells, and asserts the measured
cache sizes against the design formula:

* ``sweep_metrics`` == P_policies * (1 static-tier shape class
  + 1 if the grid has plain single-node rows)
* ``cluster_metrics`` == P_policies * n_dynamic_cells
"""
from __future__ import annotations

from typing import Dict


def audit_recompilation() -> Dict:
    from repro.api import ClusterSpec, ExperimentSpec, SyntheticTrace
    from repro.api.runner import (clear_jit_caches, jit_cache_sizes,
                                  run_experiment)

    policies = ("esff", "sff")
    static_cells = (
        ClusterSpec(n_nodes=2, router="hash"),
        ClusterSpec(n_nodes=2, router="round_robin"),
        ClusterSpec(n_nodes=4, router="hash"),
        # heterogeneous caps ride the slot *mask*, not the shape: max
        # node capacity matches the capacity axis so the cell shares
        # the same C and the same specialisation
        ClusterSpec(n_nodes=2, router="weighted_random",
                    node_capacity=(4, 2)),
    )
    dynamic_cells = (
        ClusterSpec(n_nodes=2, router="jsq2"),
        ClusterSpec(n_nodes=4, router="jsq2"),
        ClusterSpec(n_nodes=2, router="cold_aware"),
    )
    spec = ExperimentSpec(
        traces=[SyntheticTrace.make(n_functions=6, n_requests=400,
                                    seed=3)],
        policies=policies, capacities=(4,),
        cluster=(None,) + static_cells + dynamic_cells)

    clear_jit_caches()
    run_experiment(spec)
    sizes = jit_cache_sizes()

    # one shape class for all padded static cells + one for the plain
    # single-node row (n_live=None traces a different program)
    expect = {
        "sweep_metrics": len(policies) * 2,
        "cluster_metrics": len(policies) * len(dynamic_cells),
        "simulate": 0,
        "simulate_cluster": 0,
    }
    problems = []
    for name, want in expect.items():
        got = sizes.get(name)
        if got != want:
            grid = (f"{len(static_cells)} static cells x "
                    f"{len(policies)} policies")
            problems.append(
                f"jit cache '{name}': {got} specialisations, design "
                f"says {want} (grid: {grid} + 1 plain row + "
                f"{len(dynamic_cells)} dynamic cells). A higher "
                f"count means a previously shared shape class split "
                f"— check that static-tier node streams are still "
                f"padded to the full (1, N) row (static.py) and that "
                f"operands keep stable shapes/dtypes across cells; a "
                f"lower count means the grid no longer exercises the "
                f"design and this audit must be updated.")
    return dict(entry="experiment_grid", passed=not problems,
                cache_sizes=sizes, expected=expect,
                grid=dict(policies=list(policies),
                          static_cells=[c.label for c in static_cells],
                          dynamic_cells=[c.label
                                         for c in dynamic_cells],
                          plain_rows=1),
                problems=problems)
