"""Carry-size budget gate: no undocumented trace-length loop state.

Walks every `while_loop`/`scan` in an entry's jaxpr and classifies
each carried array by symbolic shape provenance (markers). Carries
whose every dimension is budget-class (L, F, C, K, Q, SEG, NCI, NCF,
HIST_BINS) are the streaming design's O(F + C + SEG + HIST_BINS)
state. Any carry with an N-scaling dimension must match — as an exact
multiset of (shape-class, dtype) signatures — the entry's allowlisted
rails, each of which carries a rationale from the owning engine
module's ``CARRY_RAILS``. Loop-invariant operands (the (T, N) trace
itself) are jaxpr constants, not carries, so they never trip the gate.
"""
from __future__ import annotations

from collections import Counter
from typing import Dict

from repro.analysis.entrypoints import RAIL_SIGS, AuditEntry
from repro.analysis.jaxprs import loops


def audit_carries(entry: AuditEntry, traced) -> Dict:
    """Gate result dict for one traced entry (see report.py for the
    shape). Fails when any loop's N-scaling carry multiset differs
    from the allowlist."""
    m = entry.markers
    sigs = RAIL_SIGS[entry.tier]
    allowed = Counter(sigs[r] for r in entry.allow)
    rails_by_sig: Dict = {}
    for r in entry.allow:
        rails_by_sig.setdefault(sigs[r], []).append(r)

    loops_out = []
    problems = []
    for path, eqn, carry_avals in loops(traced.jaxpr.jaxpr):
        scaling = Counter()
        carry_bytes = 0
        n_carries = 0
        for aval in carry_avals:
            shape = tuple(getattr(aval, "shape", ()))
            dtype = str(getattr(aval, "dtype", "?"))
            n_carries += 1
            size = 1
            for d in shape:
                size *= d
            if hasattr(aval, "dtype"):
                carry_bytes += size * aval.dtype.itemsize
            if any(m.scales_with_n(d) for d in shape):
                scaling[(m.shape_class(shape), dtype)] += 1
        loop_id = "/".join(path + (eqn.primitive.name,))
        extra = scaling - allowed
        missing = allowed - scaling
        loops_out.append(dict(
            loop=loop_id, carries=n_carries, carry_bytes=carry_bytes,
            n_scaling={f"{'x'.join(c[0])}:{c[1]}": n
                       for c, n in sorted(scaling.items())}))
        for sig, count in extra.items():
            problems.append(
                f"{entry.name} [{loop_id}]: {count} carried "
                f"{'x'.join(sig[0])} {sig[1]} array(s) scale with the "
                f"trace length N and match no allowlisted rail. "
                f"Streaming loop state must be O(F+C+SEG+HIST_BINS) "
                f"per lane (PR 2/5/6); move per-request state to a "
                f"loop-invariant operand, a positional cursor, or — "
                f"if a linked rail is genuinely required — add it to "
                f"CARRY_RAILS with a rationale and to this entry's "
                f"allowlist.")
        for sig, count in missing.items():
            names = ", ".join(rails_by_sig.get(sig, ["?"]))
            problems.append(
                f"{entry.name} [{loop_id}]: expected {count} "
                f"{'x'.join(sig[0])} {sig[1]} rail carry(s) "
                f"({names}) but found none — the documented rail "
                f"layout changed; update the allowlist and "
                f"CARRY_RAILS together.")

    return dict(entry=entry.name, passed=not problems,
                loops=loops_out, problems=problems,
                allowed_rails={r: entry.rail_rationales()[r]
                               for r in entry.allow})
