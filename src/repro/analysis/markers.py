"""Marker dimensions: symbolic shape provenance from concrete shapes.

The auditor traces the engines at small, pairwise-distinct dimension
sizes so that every dimension of every traced array reveals which
logical axis it came from — ``769`` can only be the trace length N,
``11`` only the function count F. Classification then happens on the
*labels*, which is what makes the gates symbolic: "no carried array
may have an N-labeled dimension" holds at any production size, because
the jaxpr is shape-polymorphic in nothing — the same program text is
retraced per shape, and the small-shape trace is structurally
identical to the production one.

N is prime and strictly larger than every other marker, so an
N-divisible (or >= N, for padded-to-window sizes) dimension cannot be
a product of the small markers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

# Engine-owned constant dimensions (repro.core.jax_engine): these may
# appear in carried shapes and are budget-class by construction.
ENGINE_DIMS = {16: "NCI", 6: "NCF", 32: "SEG", 64: "HIST_BINS"}


@dataclass(frozen=True)
class Markers:
    """Audit dimension sizes. All pairwise distinct; N prime > max of
    the rest; F prime (used by the copy gate's table-scale rule)."""

    T: int = 2     # trace rows (the multi-row shared-operand shape)
    L: int = 3     # lanes
    K: int = 4     # cluster nodes
    C: int = 5     # per-node slots (capacity)
    F: int = 11    # functions
    Q: int = 97    # queue cap
    W: int = 256   # window override for the multi-window entry
    N: int = 769   # requests per trace row
    E: int = 6     # churn toggle columns (operand-only, never carried)
    D: int = 8     # delay-schedule steps (operand-only)

    def label(self, dim: int) -> str:
        """Axis label for a concrete dimension size (engine constants
        win over coincidental marker collisions; unknown sizes keep
        their number so report readers see the raw shape)."""
        if self.scales_with_n(dim):
            return "N" if dim == self.N else f"~N({dim})"
        if dim in ENGINE_DIMS:
            return ENGINE_DIMS[dim]
        for name in ("T", "L", "K", "C", "F", "Q", "W"):
            if dim == getattr(self, name):
                return name
        return str(dim)

    def shape_class(self, shape: Tuple[int, ...]) -> Tuple[str, ...]:
        return tuple(self.label(d) for d in shape)

    def scales_with_n(self, dim: int) -> bool:
        """True when a dimension can only come from the trace-length
        axis: a multiple of N, or >= N (windowed paddings NP =
        ceil(N/W)*W land here)."""
        return dim >= self.N or (dim > 0 and dim % self.N == 0)

    def is_table_scale(self, shape: Tuple[int, ...]) -> bool:
        """True when the shape holds per-function or per-request state
        (an F-divisible or N-scaling dimension) — the arrays whose
        per-event liveness copies PR 6 drove to <= 2. Constant-size
        state (slots C, counters NCI/NCF, HIST_BINS, SEG overlays)
        never qualifies."""
        return any(self.scales_with_n(d)
                   or (d >= self.F and d % self.F == 0)
                   for d in shape)


MARKERS = Markers()
