"""Model assembly: builds init / loss / prefill / decode for every family.

``build_model(cfg)`` returns a :class:`Model` whose methods are pure
functions of ``(params, batch)`` — ready for ``jax.jit`` with shardings
from ``distributed/sharding.py``. Layers of one kind are stacked and
``lax.scan``-ned (fast compile, layer-boundary remat); heterogeneous
stacks (MoE first-dense, hybrid shared-attention) become scan *groups*.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import mamba as M
from repro.models.cache import CacheSpec, cache_spec
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def _identity_sharder(x, axes):
    return x


# --------------------------------------------------------------- blocks
def init_dense_block(ps: L.ParamSet, cfg, d_ff: Optional[int] = None,
                     gelu: bool = False, cross: bool = False) -> None:
    d = cfg.d_model
    ln = ("ones",)
    if gelu:   # whisper-style LayerNorm blocks
        ps.param("ln1_s", (d,), ("embed",), init="ones")
        ps.param("ln1_b", (d,), ("embed",), init="zeros")
        ps.param("ln2_s", (d,), ("embed",), init="ones")
        ps.param("ln2_b", (d,), ("embed",), init="zeros")
        if cross:
            ps.param("lnx_s", (d,), ("embed",), init="ones")
            ps.param("lnx_b", (d,), ("embed",), init="zeros")
    else:
        ps.param("norm1", (d,), ("embed",), init="ones")
        ps.param("norm2", (d,), ("embed",), init="ones")
    attn = ps.child()
    if cfg.mla:
        L.init_mla(attn, cfg)
    else:
        L.init_attention(attn, cfg)
    ps.sub("attn", attn)
    if cross:
        xa = ps.child()
        L.init_attention(xa, cfg)
        ps.sub("cross_attn", xa)
    mlp = ps.child()
    L.init_mlp(mlp, cfg, d_ff=d_ff, gelu=gelu)
    ps.sub("mlp", mlp)


def init_moe_block(ps: L.ParamSet, cfg) -> None:
    d = cfg.d_model
    ps.param("norm1", (d,), ("embed",), init="ones")
    ps.param("norm2", (d,), ("embed",), init="ones")
    attn = ps.child()
    if cfg.mla:
        L.init_mla(attn, cfg)
    else:
        L.init_attention(attn, cfg)
    ps.sub("attn", attn)
    moe = ps.child()
    L.init_moe(moe, cfg)
    ps.sub("moe", moe)


def init_mamba_block(ps: L.ParamSet, cfg) -> None:
    ps.param("norm1", (cfg.d_model,), ("embed",), init="ones")
    blk = ps.child()
    M.init_mamba(blk, cfg)
    ps.sub("mamba", blk)


def _stack_init(n: int, key, init_fn, dtype) -> Tuple[Params, Any]:
    """Initialise n identical layers and stack leaves on a leading axis."""
    keys = jax.random.split(key, n)

    def one(k):
        ps = L.ParamSet(k, dtype)
        init_fn(ps)
        return ps.params

    params = jax.vmap(one)(keys)
    ps = L.ParamSet(key, dtype)
    init_fn(ps)
    specs = jax.tree.map(
        lambda ax: ("layers",) + ax, ps.specs,
        is_leaf=lambda x: isinstance(x, tuple) and (
            not x or not isinstance(x[0], dict)))
    return params, specs


def _moe_capacity(cfg, tokens_per_row: int) -> int:
    """Expert capacity per (batch row, expert): dispatch slots are a
    per-row cumsum, so capacity scales with the row's tokens, not the
    global batch."""
    cap = int(cfg.capacity_factor * tokens_per_row * cfg.topk
              / max(cfg.n_experts, 1))
    return max(cap, 1)


def _moe_impl(cfg, sharder=None) -> str:
    if cfg.moe_impl != "auto":
        return cfg.moe_impl
    if cfg.n_experts <= 8:
        return "dense"
    # shard_map EP needs a mesh (and experts divisible by it)
    mesh = getattr(sharder, "mesh", None)
    if mesh is not None and "model" in mesh.axis_names \
            and cfg.n_experts % mesh.shape["model"] == 0:
        return "ep_shardmap"
    return "ep"


def _moe_call(impl, params, cfg, x, sharder, capacity):
    if impl == "dense":
        return L.moe_apply_dense(params, cfg, x, sharder)
    if impl == "ep_shardmap":
        return L.moe_apply_ep_shardmap(params, cfg, x, sharder, capacity)
    return L.moe_apply_capacity(params, cfg, x, sharder, capacity)


# ------------------------------------------------------------ assembly
@dataclass
class Model:
    cfg: ModelConfig

    # ------------------------------------------------------------- init
    def init(self, key) -> Tuple[Params, Any]:
        cfg = self.cfg
        ps = L.ParamSet(key, cfg.pdtype)
        L.init_embeddings(ps, cfg)
        params, specs = ps.done()
        key_l = jax.random.fold_in(key, 1)

        if cfg.family in ("dense", "vlm"):
            p, s = _stack_init(cfg.n_layers, key_l,
                               lambda q: init_dense_block(q, cfg),
                               cfg.pdtype)
            params["blocks"], specs["blocks"] = p, s
        elif cfg.family == "moe":
            nd = cfg.first_dense_layers
            if nd:
                dcfg = cfg.replace(n_experts=0)
                p, s = _stack_init(
                    nd, key_l,
                    lambda q: init_dense_block(q, dcfg, d_ff=cfg.d_ff),
                    cfg.pdtype)
                params["dense_blocks"], specs["dense_blocks"] = p, s
            p, s = _stack_init(cfg.n_layers - nd,
                               jax.random.fold_in(key_l, 2),
                               lambda q: init_moe_block(q, cfg),
                               cfg.pdtype)
            params["moe_blocks"], specs["moe_blocks"] = p, s
            if cfg.mtp:
                ps2 = L.ParamSet(jax.random.fold_in(key_l, 3), cfg.pdtype)
                ps2.param("mtp_proj", (2 * cfg.d_model, cfg.d_model),
                          ("embed", "embed"))
                blk = ps2.child()
                init_dense_block(blk, cfg.replace(n_experts=0),
                                 d_ff=cfg.d_ff)
                ps2.sub("mtp_block", blk)
                mp, msp = ps2.done()
                params["mtp"], specs["mtp"] = mp, msp
        elif cfg.family == "ssm":
            p, s = _stack_init(cfg.n_layers, key_l,
                               lambda q: init_mamba_block(q, cfg),
                               cfg.pdtype)
            params["blocks"], specs["blocks"] = p, s
        elif cfg.family == "hybrid":
            p, s = _stack_init(cfg.n_layers, key_l,
                               lambda q: init_mamba_block(q, cfg),
                               cfg.pdtype)
            params["blocks"], specs["blocks"] = p, s
            ps2 = L.ParamSet(jax.random.fold_in(key_l, 4), cfg.pdtype)
            ps2.param("shared_in", (2 * cfg.d_model, cfg.d_model),
                      ("embed", "embed"))
            init_dense_block(ps2, cfg)
            sp, ss = ps2.done()
            params["shared_attn"], specs["shared_attn"] = sp, ss
        elif cfg.family == "encdec":
            ps2 = L.ParamSet(jax.random.fold_in(key_l, 5), cfg.pdtype)
            ps2.param("enc_pos", (cfg.n_enc_positions, cfg.d_model),
                      (None, "embed"), scale=0.02)
            ep, es = ps2.done()
            params.update(ep)
            specs.update(es)
            p, s = _stack_init(
                cfg.enc_layers, key_l,
                lambda q: init_dense_block(q, cfg, gelu=True), cfg.pdtype)
            params["enc_blocks"], specs["enc_blocks"] = p, s
            p, s = _stack_init(
                cfg.dec_layers, jax.random.fold_in(key_l, 6),
                lambda q: init_dense_block(q, cfg, gelu=True, cross=True),
                cfg.pdtype)
            params["dec_blocks"], specs["dec_blocks"] = p, s
        else:
            raise ValueError(cfg.family)
        return params, specs

    def param_specs(self):
        return jax.eval_shape(self.init, jax.random.key(0))[1] if False \
            else self.init_abstract()[1]

    def init_abstract(self):
        """Shape-only init (no allocation) — used by the dry-run."""
        out = jax.eval_shape(lambda k: self.init(k)[0], jax.random.key(0))
        # specs must be computed eagerly (they are python data, not arrays)
        _, specs = _specs_only(self)
        return out, specs

    # --------------------------------------------------------- forward
    def _rope(self, positions):
        cfg = self.cfg
        if cfg.family in ("encdec", "ssm"):
            return None, None
        dim = cfg.qk_rope_dim if cfg.mla else cfg.head_dim_
        return L.rope_angles(positions, dim, cfg.rope_theta)

    def _trunk(self, params, h, cos, sin, sharder, window=None):
        """Full-sequence trunk over all layers. Returns (h, aux_loss)."""
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)

        if cfg.family in ("dense", "vlm"):
            def body(carry, p):
                h, aux = carry
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                y, _ = L.attention_apply(p["attn"], cfg, x, cos, sin,
                                         sharder, window=window)
                h = h + y
                x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
                h = h + L.mlp_apply(p["mlp"], x, sharder)
                h = sharder(h, ("batch", "seq_q", "embed"))
                return (h, aux), None
            (h, aux), _ = lax.scan(jax.checkpoint(body), (h, aux),
                                   params["blocks"])

        elif cfg.family == "moe":
            capacity = _moe_capacity(cfg, h.shape[1])
            impl = _moe_impl(cfg, sharder)

            def dense_body(carry, p):
                h, aux = carry
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                if cfg.mla:
                    y, _ = L.mla_apply(p["attn"], cfg, x, cos, sin, sharder)
                else:
                    y, _ = L.attention_apply(p["attn"], cfg, x, cos, sin,
                                             sharder)
                h = h + y
                x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
                h = h + L.mlp_apply(p["mlp"], x, sharder)
                return (h, aux), None

            def moe_body(carry, p):
                h, aux = carry
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                if cfg.mla:
                    y, _ = L.mla_apply(p["attn"], cfg, x, cos, sin, sharder)
                else:
                    y, _ = L.attention_apply(p["attn"], cfg, x, cos, sin,
                                             sharder)
                h = h + y
                x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
                y, a = _moe_call(impl, p["moe"], cfg, x, sharder,
                                 capacity)
                h = h + y
                h = sharder(h, ("batch", "seq_q", "embed"))
                return (h, aux + a), None

            if cfg.first_dense_layers:
                (h, aux), _ = lax.scan(jax.checkpoint(dense_body), (h, aux),
                                       params["dense_blocks"])
            (h, aux), _ = lax.scan(jax.checkpoint(moe_body), (h, aux),
                                   params["moe_blocks"])

        elif cfg.family == "ssm":
            def body(carry, p):
                h, aux = carry
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                h = h + M.mamba_apply(p["mamba"], cfg, x, sharder)
                h = sharder(h, ("batch", "seq_q", "embed"))
                return (h, aux), None
            (h, aux), _ = lax.scan(jax.checkpoint(body), (h, aux),
                                   params["blocks"])

        elif cfg.family == "hybrid":
            h0 = h   # original embeddings feed every shared block
            k = cfg.attn_every
            n_groups = cfg.n_layers // k
            rest = cfg.n_layers - n_groups * k
            blocks = params["blocks"]
            grouped = jax.tree.map(
                lambda x: x[:n_groups * k].reshape(
                    (n_groups, k) + x.shape[1:]), blocks)
            shared = params["shared_attn"]

            def mamba_body(carry, p):
                h, aux = carry
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                h = h + M.mamba_apply(p["mamba"], cfg, x, sharder)
                return (h, aux), None

            def group_body(carry, pg):
                (h, aux), _ = lax.scan(jax.checkpoint(mamba_body), carry, pg)
                # shared attention block on concat(h, embeddings)
                z = jnp.concatenate([h, h0], axis=-1)
                z = jnp.einsum("bse,ed->bsd", z, shared["shared_in"])
                x = L.rms_norm(z, shared["norm1"], cfg.norm_eps)
                y, _ = L.attention_apply(shared["attn"], cfg, x, cos, sin,
                                         sharder, window=window)
                z = z + y
                x = L.rms_norm(z, shared["norm2"], cfg.norm_eps)
                z = z + L.mlp_apply(shared["mlp"], x, sharder)
                h = h + z
                h = sharder(h, ("batch", "seq_q", "embed"))
                return (h, aux), None

            (h, aux), _ = lax.scan(group_body, (h, aux), grouped)
            if rest:
                tail = jax.tree.map(lambda x: x[n_groups * k:], blocks)
                (h, aux), _ = lax.scan(jax.checkpoint(mamba_body), (h, aux),
                                       tail)
        else:
            raise ValueError(cfg.family)
        return h, aux

    # ------------------------------------------------------------- loss
    def loss(self, params, batch, sharder=_identity_sharder):
        cfg = self.cfg
        if cfg.family == "encdec":
            return self._loss_encdec(params, batch, sharder)
        tokens, labels = batch["tokens"], batch["labels"]
        h = L.embed_tokens(params, cfg, tokens)
        if cfg.family == "vlm":
            patches = batch["patch_embeds"].astype(cfg.cdtype)
            h = jnp.concatenate([patches, h], axis=1)
        h = sharder(h, ("batch", "seq_q", "embed"))
        S = h.shape[1]
        positions = jnp.arange(S)
        cos, sin = self._rope(positions)
        h, aux = self._trunk(params, h, cos, sin, sharder)
        logits = L.logits_from_hidden(params, cfg, h, sharder)
        ce = L.cross_entropy(logits, labels, cfg.vocab_size)
        loss = ce + cfg.router_aux_coef * aux
        if cfg.mtp and "mtp" in params:
            loss = loss + cfg.mtp_coef * self._mtp_loss(
                params, h, tokens, labels, cos, sin, sharder)
        return loss, {"ce": ce, "aux": aux}

    def _mtp_loss(self, params, h, tokens, labels, cos, sin, sharder):
        """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
        trunk hidden at t combined with the embedding of token t+1."""
        cfg = self.cfg
        mp = params["mtp"]
        emb_next = L.embed_tokens(params, cfg, tokens)[:, 1:]
        z = jnp.concatenate([h[:, :-1], emb_next], axis=-1)
        z = jnp.einsum("bse,ed->bsd", z, mp["mtp_proj"])
        p = mp["mtp_block"]
        x = L.rms_norm(z, p["norm1"], cfg.norm_eps)
        if cfg.mla:
            y, _ = L.mla_apply(p["attn"], cfg, x, cos[:-1], sin[:-1],
                               sharder)
        else:
            y, _ = L.attention_apply(p["attn"], cfg, x, cos[:-1], sin[:-1],
                                     sharder)
        z = z + y
        x = L.rms_norm(z, p["norm2"], cfg.norm_eps)
        z = z + L.mlp_apply(p["mlp"], x, sharder)
        logits = L.logits_from_hidden(params, cfg, z, sharder)
        labels2 = jnp.pad(labels[:, 2:], ((0, 0), (0, 1)),
                          constant_values=-1)[:, :logits.shape[1]]
        return L.cross_entropy(logits, labels2, cfg.vocab_size)

    def _loss_encdec(self, params, batch, sharder):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], sharder)
        tokens, labels = batch["tokens"], batch["labels"]
        h = L.embed_tokens(params, cfg, tokens)
        S = h.shape[1]
        pos = _sinusoidal(S, cfg.d_model).astype(h.dtype)
        h = h + pos
        h = sharder(h, ("batch", "seq_q", "embed"))

        def body(carry, p):
            h, _ = carry
            x = L.layer_norm(h, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
            y, _ = L.attention_apply(p["attn"], cfg, x, None, None, sharder)
            h = h + y
            x = L.layer_norm(h, p["lnx_s"], p["lnx_b"], cfg.norm_eps)
            kx = jnp.einsum("bsd,dhk->bshk", enc, p["cross_attn"]["wk"])
            vx = jnp.einsum("bsd,dhk->bshk", enc, p["cross_attn"]["wv"])
            y, _ = L.attention_apply(p["cross_attn"], cfg, x, None, None,
                                     sharder, causal=False,
                                     kv_override=(kx, vx))
            h = h + y
            x = L.layer_norm(h, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
            h = h + L.mlp_apply(p["mlp"], x, sharder, gelu=True)
            return (h, 0.0), None

        (h, _), _ = lax.scan(jax.checkpoint(body), (h, 0.0),
                             params["dec_blocks"])
        logits = L.logits_from_hidden(params, cfg, h, sharder)
        ce = L.cross_entropy(logits, labels, cfg.vocab_size)
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def encode(self, params, frames, sharder=_identity_sharder):
        """Whisper encoder over precomputed (stub) frame embeddings."""
        cfg = self.cfg
        h = frames.astype(cfg.cdtype) + params["enc_pos"].astype(cfg.cdtype)
        h = sharder(h, ("batch", None, "embed"))

        def body(carry, p):
            h = carry
            x = L.layer_norm(h, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
            y, _ = L.attention_apply(p["attn"], cfg, x, None, None, sharder,
                                     causal=False)
            h = h + y
            x = L.layer_norm(h, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
            h = h + L.mlp_apply(p["mlp"], x, sharder, gelu=True)
            return h, None

        h, _ = lax.scan(jax.checkpoint(body), h, params["enc_blocks"])
        return h

    # ---------------------------------------------------------- serving
    def prefill(self, params, batch, cache, sharder=_identity_sharder):
        """Full-sequence forward that also fills the decode cache.
        Returns (last-position logits, cache)."""
        cfg = self.cfg
        if cfg.family == "encdec":
            return self._prefill_encdec(params, batch, cache, sharder)
        tokens = batch["tokens"]
        h = L.embed_tokens(params, cfg, tokens)
        if cfg.family == "vlm":
            h = jnp.concatenate(
                [batch["patch_embeds"].astype(cfg.cdtype), h], axis=1)
        h = sharder(h, ("batch", "seq_q", "embed"))
        S = h.shape[1]
        cos, sin = self._rope(jnp.arange(S))
        h, cache = self._trunk_cached_prefill(params, h, cos, sin, cache,
                                              sharder)
        logits = L.logits_from_hidden(params, cfg, h[:, -1:], sharder)
        cache["length"] = jnp.asarray(S, jnp.int32)
        return logits, cache

    def _trunk_cached_prefill(self, params, h, cos, sin, cache, sharder):
        cfg = self.cfg
        window = cache["k"].shape[2] if "k" in cache else None
        if cfg.family in ("dense", "vlm"):
            def body(carry, p):
                h = carry
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                y, (k, v) = L.attention_apply(p["attn"], cfg, x, cos, sin,
                                              sharder)
                h = h + y
                x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
                h = h + L.mlp_apply(p["mlp"], x, sharder)
                return h, (k, v)
            h, (ks, vs) = lax.scan(jax.checkpoint(body), h,
                                   params["blocks"])
            cache["k"] = _write_prefix(cache["k"], ks)
            cache["v"] = _write_prefix(cache["v"], vs)
        elif cfg.family == "moe":
            capacity = _moe_capacity(cfg, h.shape[1])
            impl = _moe_impl(cfg, sharder)

            def attn(p, x):
                if cfg.mla:
                    y, kv = L.mla_apply(p["attn"], cfg, x, cos, sin, sharder)
                else:
                    y, kv = L.attention_apply(p["attn"], cfg, x, cos, sin,
                                              sharder)
                return y, kv

            caches_d = None
            if cfg.first_dense_layers:
                def dbody(carry, p):
                    h = carry
                    x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                    y, kv = attn(p, x)
                    h = h + y
                    x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
                    h = h + L.mlp_apply(p["mlp"], x, sharder)
                    return h, kv
                h, caches_d = lax.scan(jax.checkpoint(dbody), h,
                                       params["dense_blocks"])

            def mbody(carry, p):
                h = carry
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                y, kv = attn(p, x)
                h = h + y
                x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
                y, _ = _moe_call(impl, p["moe"], cfg, x, sharder,
                                 capacity)
                h = h + y
                return h, kv
            h, caches_m = lax.scan(jax.checkpoint(mbody), h,
                                   params["moe_blocks"])
            caches = (jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0),
                caches_d, caches_m) if caches_d is not None else caches_m)
            if cfg.mla:
                cache["c_kv"] = _write_prefix(cache["c_kv"], caches[0])
                cache["k_rope"] = _write_prefix(cache["k_rope"], caches[1])
            else:
                cache["k"] = _write_prefix(cache["k"], caches[0])
                cache["v"] = _write_prefix(cache["v"], caches[1])
        elif cfg.family == "ssm":
            def body(carry, p):
                h = carry
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                y, st = M.mamba_apply(p["mamba"], cfg, x, sharder,
                                      return_state=True)
                return h + y, st
            h, (convs, ssms) = lax.scan(jax.checkpoint(body), h,
                                        params["blocks"])
            cache["conv"], cache["ssm"] = convs, ssms
        elif cfg.family == "hybrid":
            h, cache = self._hybrid_prefill(params, h, cos, sin, cache,
                                            sharder, window)
        else:
            raise ValueError(cfg.family)
        return h, cache

    def _hybrid_prefill(self, params, h, cos, sin, cache, sharder, window):
        cfg = self.cfg
        assert cfg.n_layers % cfg.attn_every == 0, \
            "hybrid serving requires n_layers % attn_every == 0"
        h0 = h
        k_every = cfg.attn_every
        n_groups = cfg.n_layers // k_every
        blocks = params["blocks"]
        grouped = jax.tree.map(
            lambda x: x[:n_groups * k_every].reshape(
                (n_groups, k_every) + x.shape[1:]), blocks)
        shared = params["shared_attn"]
        S = h.shape[1]
        W = cache["k"].shape[2]

        def mamba_body(carry, p):
            h = carry
            x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
            y, st = M.mamba_apply(p["mamba"], cfg, x, sharder,
                                  return_state=True)
            return h + y, st

        def group_body(carry, pg):
            h = carry
            h, st = lax.scan(jax.checkpoint(mamba_body), h, pg)
            z = jnp.concatenate([h, h0], axis=-1)
            z = jnp.einsum("bse,ed->bsd", z, shared["shared_in"])
            x = L.rms_norm(z, shared["norm1"], cfg.norm_eps)
            y, (k, v) = L.attention_apply(shared["attn"], cfg, x, cos, sin,
                                          sharder, window=window)
            z = z + y
            x = L.rms_norm(z, shared["norm2"], cfg.norm_eps)
            z = z + L.mlp_apply(shared["mlp"], x, sharder)
            h = h + z
            # keep only the last W positions for the sliding-window cache
            return h, (st, k[:, -W:] if S >= W else k, v[:, -W:] if S >= W
                       else v)

        h, (states, ks, vs) = lax.scan(group_body, h, grouped)
        convs, ssms = states
        # (G, k_every, B, ...) -> (L, B, ...)
        cache["conv"] = convs.reshape((-1,) + convs.shape[2:])
        cache["ssm"] = ssms.reshape((-1,) + ssms.shape[2:])
        cache["k"] = _write_prefix(cache["k"], ks)
        cache["v"] = _write_prefix(cache["v"], vs)
        return h, cache

    def _prefill_encdec(self, params, batch, cache, sharder):
        cfg = self.cfg
        enc = self.encode(params, batch["frames"], sharder)
        # precompute cross k/v per decoder layer
        def cross(p):
            k = jnp.einsum("bsd,dhk->bshk", enc, p["cross_attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", enc, p["cross_attn"]["wv"])
            return k, v
        ks, vs = jax.vmap(
            cross, in_axes=(0,))(params["dec_blocks"]) \
            if False else _map_layers(cross, params["dec_blocks"])
        cache["cross_k"], cache["cross_v"] = ks, vs

        tokens = batch["tokens"]
        h = L.embed_tokens(params, cfg, tokens)
        S = h.shape[1]
        h = h + _sinusoidal(S, cfg.d_model).astype(h.dtype)
        h = sharder(h, ("batch", "seq_q", "embed"))

        def body(carry, inp):
            h = carry
            p, kx, vx = inp
            x = L.layer_norm(h, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
            y, (k, v) = L.attention_apply(p["attn"], cfg, x, None, None,
                                          sharder)
            h = h + y
            x = L.layer_norm(h, p["lnx_s"], p["lnx_b"], cfg.norm_eps)
            y, _ = L.attention_apply(p["cross_attn"], cfg, x, None, None,
                                     sharder, causal=False,
                                     kv_override=(kx, vx))
            h = h + y
            x = L.layer_norm(h, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
            h = h + L.mlp_apply(p["mlp"], x, sharder, gelu=True)
            return h, (k, v)

        h, (ks2, vs2) = lax.scan(jax.checkpoint(body), h,
                                 (params["dec_blocks"], ks, vs))
        cache["k"] = _write_prefix(cache["k"], ks2)
        cache["v"] = _write_prefix(cache["v"], vs2)
        cache["length"] = jnp.asarray(S, jnp.int32)
        logits = L.logits_from_hidden(params, cfg, h[:, -1:], sharder)
        return logits, cache

    # -------------------------------------------------------------- decode
    def decode_step(self, params, tokens, cache,
                    sharder=_identity_sharder):
        """One-token decode against the cache. tokens (B, 1)."""
        cfg = self.cfg
        length = cache["length"]
        h = L.embed_tokens(params, cfg, tokens)
        h = sharder(h, ("batch", None, "embed"))
        if cfg.family == "encdec":
            h = h + _sinusoidal_at(length, cfg.d_model).astype(h.dtype)
            cos = sin = None
        elif cfg.family == "ssm":
            cos = sin = None
        else:
            dim = cfg.qk_rope_dim if cfg.mla else cfg.head_dim_
            cos, sin = L.rope_angles(length[None], dim, cfg.rope_theta)

        if cfg.family in ("dense", "vlm"):
            # NOTE (§Perf decode iteration 2, REFUTED): threading the
            # stacked cache through the scan carry with slot-only DUS
            # writes was tried to avoid the 2x67 MB/layer ys re-stacking;
            # SPMD rematerialises the sharded cache on every traced-index
            # update (measured 27x WORSE memory term). The ys path keeps
            # the per-layer slice update local to its shards.
            def body(h, pc):
                p, (k, v) = pc
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                y, (k, v) = _decode_attention(p["attn"], cfg, x, cos, sin,
                                              k, v, length, sharder)
                h = h + y
                x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
                h = h + L.mlp_apply(p["mlp"], x, sharder)
                return h, (k, v)
            h, (ks, vs) = _scan_layers(body, h,
                                       (params["blocks"],
                                        (cache["k"], cache["v"])))
            cache["k"], cache["v"] = ks, vs
        elif cfg.family == "moe":
            h, cache = self._decode_moe(params, h, cos, sin, cache, sharder)
        elif cfg.family == "ssm":
            def body(h, pc):
                p, (cs, ss) = pc
                x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
                y, (cs, ss) = M.mamba_decode_step(p["mamba"], cfg, x, cs, ss)
                return h + y, (cs, ss)
            h, (convs, ssms) = _scan_layers(
                body, h, (params["blocks"], (cache["conv"], cache["ssm"])))
            cache["conv"], cache["ssm"] = convs, ssms
        elif cfg.family == "hybrid":
            h, cache = self._decode_hybrid(params, h, cos, sin, cache,
                                           sharder)
        elif cfg.family == "encdec":
            def body(h, pc):
                p, (k, v, kx, vx) = pc
                x = L.layer_norm(h, p["ln1_s"], p["ln1_b"], cfg.norm_eps)
                y, (k, v) = _decode_attention(p["attn"], cfg, x, None, None,
                                              k, v, length, sharder)
                h = h + y
                x = L.layer_norm(h, p["lnx_s"], p["lnx_b"], cfg.norm_eps)
                y = _cross_attention_step(p["cross_attn"], cfg, x, kx, vx,
                                          sharder)
                h = h + y
                x = L.layer_norm(h, p["ln2_s"], p["ln2_b"], cfg.norm_eps)
                h = h + L.mlp_apply(p["mlp"], x, sharder, gelu=True)
                return h, (k, v)
            h, (ks, vs) = _scan_layers(
                body, h, (params["dec_blocks"],
                          (cache["k"], cache["v"],
                           cache["cross_k"], cache["cross_v"])))
            cache["k"], cache["v"] = ks, vs
        logits = L.logits_from_hidden(params, cfg, h, sharder)
        cache["length"] = length + 1
        return logits, cache

    def _decode_moe(self, params, h, cos, sin, cache, sharder):
        cfg = self.cfg
        length = cache["length"]
        capacity = _moe_capacity(cfg, 1)   # decode: one token per row
        impl = _moe_impl(cfg, sharder)
        if impl == "ep_shardmap":
            # decode moves ~1 token/row: the shard_map boundary re-gathers
            # FSDP expert weights every step (+44 % collective measured on
            # deepseek-v3 decode_32k); pjit's gather placement wins here.
            impl = "ep"
        nd = cfg.first_dense_layers

        if cfg.mla:
            def attn_step(p, x, kv):
                return _decode_mla(p["attn"], cfg, x, cos, sin, kv[0],
                                   kv[1], length, sharder)
            kv_names = ("c_kv", "k_rope")
        else:
            def attn_step(p, x, kv):
                return _decode_attention(p["attn"], cfg, x, cos, sin,
                                         kv[0], kv[1], length, sharder)
            kv_names = ("k", "v")
        kv_all = (cache[kv_names[0]], cache[kv_names[1]])

        def dbody(h, pc):
            p, kv = pc
            x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
            y, kv = attn_step(p, x, kv)
            h = h + y
            x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
            h = h + L.mlp_apply(p["mlp"], x, sharder)
            return h, kv

        def mbody(h, pc):
            p, kv = pc
            x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
            y, kv = attn_step(p, x, kv)
            h = h + y
            x = L.rms_norm(h, p["norm2"], cfg.norm_eps)
            y, _ = _moe_call(impl, p["moe"], cfg, x, sharder,
                             capacity)
            h = h + y
            return h, kv

        if nd:
            h, kv_d = _scan_layers(
                dbody, h, (params["dense_blocks"],
                           jax.tree.map(lambda x: x[:nd], kv_all)))
        h, kv_m = _scan_layers(
            mbody, h, (params["moe_blocks"],
                       jax.tree.map(lambda x: x[nd:], kv_all)))
        if nd:
            kv_new = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), kv_d, kv_m)
        else:
            kv_new = kv_m
        cache[kv_names[0]], cache[kv_names[1]] = kv_new
        return h, cache

    def _decode_hybrid(self, params, h, cos, sin, cache, sharder):
        cfg = self.cfg
        length = cache["length"]
        k_every = cfg.attn_every
        n_groups = cfg.n_layers // k_every
        blocks = params["blocks"]
        grouped = jax.tree.map(
            lambda x: x[:n_groups * k_every].reshape(
                (n_groups, k_every) + x.shape[1:]), blocks)
        shared = params["shared_attn"]
        h0 = h
        W = cache["k"].shape[2]

        def mamba_body(h, pc):
            p, (cs, ss) = pc
            x = L.rms_norm(h, p["norm1"], cfg.norm_eps)
            y, (cs, ss) = M.mamba_decode_step(p["mamba"], cfg, x, cs, ss)
            return h + y, (cs, ss)

        def group_body(h, pc):
            pg, (cs, ss, k, v) = pc
            h, (cs, ss) = _scan_layers(mamba_body, h, (pg, (cs, ss)))
            z = jnp.concatenate([h, h0], axis=-1)
            z = jnp.einsum("bse,ed->bsd", z, shared["shared_in"])
            x = L.rms_norm(z, shared["norm1"], cfg.norm_eps)
            y, (k, v) = _decode_attention(shared["attn"], cfg, x, cos, sin,
                                          k, v, length, sharder,
                                          ring=True)
            z = z + y
            x = L.rms_norm(z, shared["norm2"], cfg.norm_eps)
            z = z + L.mlp_apply(shared["mlp"], x, sharder)
            return h + z, (cs, ss, k, v)

        gconv = jax.tree.map(
            lambda x: x.reshape((n_groups, k_every) + x.shape[1:]),
            cache["conv"])
        gssm = jax.tree.map(
            lambda x: x.reshape((n_groups, k_every) + x.shape[1:]),
            cache["ssm"])
        h, (convs, ssms, ks, vs) = _scan_layers(
            group_body, h, (grouped, (gconv, gssm, cache["k"], cache["v"])))
        cache["conv"] = convs.reshape(cache["conv"].shape)
        cache["ssm"] = ssms.reshape(cache["ssm"].shape)
        cache["k"], cache["v"] = ks, vs
        return h, cache

    # ----------------------------------------------------------- caches
    def cache_spec(self, batch: int, max_len: int,
                   window: Optional[int] = None) -> CacheSpec:
        return cache_spec(self.cfg, batch, max_len, window)


# --------------------------------------------------------------- helpers
def _specs_only(model: Model):
    """Spec tree without touching device memory: init under eval_shape
    only returns shapes, so run the spec-collection side eagerly via a
    ParamSet with a dummy key. Specs are plain python, so this is cheap."""
    import numpy as np

    class _Dummy:
        pass

    # Re-run init in eval_shape to collect specs: ParamSet.param stores
    # specs as a side effect during tracing, which eval_shape executes.
    specs_box = {}

    def run(k):
        params, specs = model.init(k)
        specs_box["specs"] = specs
        return params

    jax.eval_shape(run, jax.random.key(0))
    return None, specs_box["specs"]


def _write_prefix(cache_buf, stacked):
    """Write (L, B, S, ...) prefill tensors into (L, B, S_max, ...) cache."""
    S = min(stacked.shape[2], cache_buf.shape[2])
    return lax.dynamic_update_slice(
        cache_buf, stacked[:, :, -S:].astype(cache_buf.dtype),
        (0,) * cache_buf.ndim)


def _scan_layers(body, h, xs):
    """scan over the layer axis with (params, cache) as scanned xs/ys."""
    def wrapped(carry, x):
        h, aux = carry, None
        h, ys = body(h, x)
        return h, ys
    h, ys = lax.scan(wrapped, h, xs)
    return h, ys


def _map_layers(fn, stacked_params):
    """vmap a function over the stacked layer axis of a param subtree."""
    return jax.vmap(fn)(stacked_params)


def _sinusoidal(S: int, d: int):
    pos = jnp.arange(S)[:, None].astype(jnp.float32)
    i = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _sinusoidal_at(pos, d: int):
    i = jnp.arange(d // 2).astype(jnp.float32)
    ang = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]


def _decode_attention_stacked(params, cfg, x, cos, sin, kc, vc, li,
                              length, sharder):
    """Decode attention writing the new token directly into the STACKED
    (L, B, T, KVH, hd) carry — the write touches one token slot, not the
    layer's whole cache (§Perf decode iteration 2)."""
    import math as _m
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    B, T = kc.shape[1], kc.shape[2]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k_new = k_new + params["bk"]
        v_new = v_new + params["bv"]
    if cfg.qk_norm:
        q = L.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k_new = L.rms_norm(k_new, params["k_norm"], cfg.norm_eps)
    if cos is not None:
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
    slot = jnp.minimum(length, T - 1)
    z = jnp.zeros((), slot.dtype)
    li = li.astype(slot.dtype)
    kc = lax.dynamic_update_slice(
        kc, k_new[None].astype(kc.dtype), (li, z, slot, z, z))
    vc = lax.dynamic_update_slice(
        vc, v_new[None].astype(vc.dtype), (li, z, slot, z, z))
    k_l = lax.dynamic_index_in_dim(kc, li, 0, keepdims=False)
    v_l = lax.dynamic_index_in_dim(vc, li, 0, keepdims=False)
    g = h // kvh
    qg = q.reshape(B, kvh, g, hd)
    scores = jnp.einsum("bhgd,bthd->bhgt", qg, k_l,
                        preferred_element_type=jnp.float32)
    scores = scores / _m.sqrt(hd)
    valid = jnp.arange(T) <= length
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p.astype(x.dtype), v_l,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(B, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, kc, vc


def _decode_attention(params, cfg, x, cos, sin, k_cache, v_cache, length,
                      sharder, ring: bool = False):
    """Single-token attention against a (B, T, KVH, hd) cache.

    The cache sequence axis may be sharded ('model'); softmax and the
    value contraction reduce over it, which SPMD lowers to the split-KV
    partial-softmax + combine pattern (tiny (B,H) collectives).
    ``ring=True`` treats the cache as a ring buffer of its own length
    (sliding-window serving).
    """
    import math as _m
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    B, T = k_cache.shape[0], k_cache.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k_new = k_new + params["bk"]
        v_new = v_new + params["bv"]
    if cfg.qk_norm:
        q = rms = L.rms_norm(q, params["q_norm"], cfg.norm_eps)
        k_new = L.rms_norm(k_new, params["k_norm"], cfg.norm_eps)
    if cos is not None:
        q = L.apply_rope(q, cos, sin)
        k_new = L.apply_rope(k_new, cos, sin)
    slot = (length % T) if ring else jnp.minimum(length, T - 1)
    z = jnp.zeros((), slot.dtype)   # match index dtypes (x64-safe)
    k_cache = lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (z, slot, z, z))
    v_cache = lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (z, slot, z, z))
    g = h // kvh
    qg = q.reshape(B, kvh, g, hd)
    # keep cache reads in their storage dtype (memory-bound step: the f32
    # upcast doubled HBM bytes — §Perf internlm2/decode_32k iteration);
    # the dot still accumulates in fp32.
    scores = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / _m.sqrt(hd)
    pos = jnp.arange(T)
    valid = pos <= (length % T) if ring else pos <= length
    if ring:
        valid = valid | (length >= T)
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p.astype(x.dtype), v_cache,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    out = out.reshape(B, 1, h, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, (k_cache, v_cache)


def _decode_mla(params, cfg, x, cos, sin, ckv_cache, krope_cache, length,
                sharder):
    """MLA decode with weight absorption: attends in the compressed
    (kv_lora + rope) space; cache per token is kv_lora_rank+qk_rope_dim."""
    import math as _m
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    B, T = ckv_cache.shape[0], ckv_cache.shape[1]
    q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q = L.rms_norm(q, params["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q, params["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = L.apply_rope(q_rope, cos, sin)
    # absorb W_UK: q_nope (B,1,H,dn) @ wk_b (kvr,H,dn) -> (B,1,H,kvr)
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, params["wk_b"])

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_new = L.rms_norm(kv[..., :cfg.kv_lora_rank], params["kv_a_norm"],
                       cfg.norm_eps)
    kr_new = L.apply_rope(kv[..., None, cfg.kv_lora_rank:], cos, sin)[:, :, 0]
    slot = jnp.minimum(length, T - 1)
    z = jnp.zeros((), slot.dtype)
    ckv_cache = lax.dynamic_update_slice(
        ckv_cache, c_new[:, 0:1].astype(ckv_cache.dtype), (z, slot, z))
    krope_cache = lax.dynamic_update_slice(
        krope_cache, kr_new[:, 0:1].astype(krope_cache.dtype),
        (z, slot, z))

    s_nope = jnp.einsum("bshr,btr->bhst", q_abs, ckv_cache,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bshk,btk->bhst", q_rope, krope_cache,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) / _m.sqrt(dn + dr)
    valid = jnp.arange(T) <= length
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    # attend in latent space then decompress through wv_b (absorbed into o)
    lat = jnp.einsum("bhst,btr->bshr", p.astype(x.dtype), ckv_cache,
                     preferred_element_type=jnp.float32)
    out = jnp.einsum("bshr,rhk->bshk", lat.astype(x.dtype), params["wv_b"])
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, (ckv_cache, krope_cache)


def _cross_attention_step(params, cfg, x, kx, vx, sharder):
    import math as _m
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    B = x.shape[0]
    g = h // kvh
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    qg = q.reshape(B, 1, kvh, g, hd)
    scores = jnp.einsum("bshgk,bthk->bhgst", qg.astype(jnp.float32),
                        kx.astype(jnp.float32)) / _m.sqrt(hd)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgst,bthk->bshgk", p, vx.astype(jnp.float32))
    out = out.reshape(B, 1, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), params["wo"])


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
