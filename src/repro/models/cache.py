"""Decode-time cache pytrees.

Layout notes: per-layer tensors are stacked on a leading ``layers`` axis so
the decode step can ``lax.scan`` over layers with the cache as scanned
input/output. KV caches keep keys *already rotary-encoded* (rope applied at
write time), the standard serving layout.

Sharding: the cache sequence axis carries the logical axis ``"cache_seq"``
which the production rules map to the ``model`` mesh axis — split-KV
(context-parallel) decoding. The batch axis maps to data axes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclass
class CacheSpec:
    """Shapes + logical axes for every cache leaf of a config."""

    shapes: Dict[str, Tuple[int, ...]]
    dtypes: Dict[str, Any]
    axes: Dict[str, Tuple]

    def zeros(self):
        out = {k: jnp.zeros(s, self.dtypes[k])
               for k, s in self.shapes.items()}
        out["length"] = jnp.zeros((), jnp.int32)
        return out

    def shape_dtype_structs(self):
        import jax
        out = {k: jax.ShapeDtypeStruct(s, self.dtypes[k])
               for k, s in self.shapes.items()}
        out["length"] = jax.ShapeDtypeStruct((), jnp.int32)
        return out


def cache_spec(cfg, batch: int, max_len: int,
               window: Optional[int] = None) -> CacheSpec:
    """Build the cache spec for a config. ``window`` bounds the attention
    cache length (sliding-window serving for hybrid long-context)."""
    shapes, dtypes, axes = {}, {}, {}
    dt = cfg.cdtype
    attn_len = min(max_len, window) if window else max_len

    def add(name, shape, ax, dtype=dt):
        shapes[name] = shape
        dtypes[name] = dtype
        axes[name] = ax

    L = cfg.n_layers
    if cfg.family in ("dense", "vlm"):
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        add("k", (L, batch, attn_len, kv, hd),
            ("layers", "batch", "cache_seq", "kv_heads", None))
        add("v", (L, batch, attn_len, kv, hd),
            ("layers", "batch", "cache_seq", "kv_heads", None))
    elif cfg.family == "moe":
        if cfg.mla:
            add("c_kv", (L, batch, attn_len, cfg.kv_lora_rank),
                ("layers", "batch", "cache_seq", None))
            add("k_rope", (L, batch, attn_len, cfg.qk_rope_dim),
                ("layers", "batch", "cache_seq", None))
        else:
            kv, hd = cfg.n_kv_heads, cfg.head_dim_
            add("k", (L, batch, attn_len, kv, hd),
                ("layers", "batch", "cache_seq", "kv_heads", None))
            add("v", (L, batch, attn_len, kv, hd),
                ("layers", "batch", "cache_seq", "kv_heads", None))
    elif cfg.family == "ssm":
        _add_ssm(add, cfg, L, batch)
    elif cfg.family == "hybrid":
        _add_ssm(add, cfg, L, batch)
        n_shared = cfg.n_layers // cfg.attn_every
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        add("k", (n_shared, batch, attn_len, kv, hd),
            ("layers", "batch", "cache_seq", "kv_heads", None))
        add("v", (n_shared, batch, attn_len, kv, hd),
            ("layers", "batch", "cache_seq", "kv_heads", None))
    elif cfg.family == "encdec":
        kv, hd = cfg.n_kv_heads, cfg.head_dim_
        Ld = cfg.dec_layers
        add("k", (Ld, batch, attn_len, kv, hd),
            ("layers", "batch", "cache_seq", "kv_heads", None))
        add("v", (Ld, batch, attn_len, kv, hd),
            ("layers", "batch", "cache_seq", "kv_heads", None))
        add("cross_k", (Ld, batch, cfg.n_enc_positions, kv, hd),
            ("layers", "batch", None, "kv_heads", None))
        add("cross_v", (Ld, batch, cfg.n_enc_positions, kv, hd),
            ("layers", "batch", None, "kv_heads", None))
    else:
        raise ValueError(cfg.family)
    return CacheSpec(shapes, dtypes, axes)


def _add_ssm(add, cfg, L, batch):
    conv_c = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    add("conv", (L, batch, cfg.ssm_conv - 1, conv_c),
        ("layers", "batch", None, "ssm_inner"))
    add("ssm", (L, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
        ("layers", "batch", "ssm_heads", None, None), dtype=jnp.float32)
