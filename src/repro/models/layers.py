"""Transformer layer library (pure functional JAX).

Every ``init_*`` returns ``(params, specs)`` built through :class:`ParamSet`
so the parameter tree and its logical-axis sharding tree can never drift.
Logical axes are resolved to mesh axes by ``distributed/sharding.py``.

The attention implementation is *chunk-pair* online-softmax causal
attention: a ``lax.scan`` over the statically enumerated causal (q-chunk,
kv-chunk) pairs. It has exact causal FLOPs (no masked-block waste), O(S)
live memory, is reverse-differentiable (the pair body is checkpointed),
honours sliding windows by static pair pruning, and doubles as the
reference the Pallas flash kernel is tested against.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.utils.compat import shard_map

Params = Dict[str, Any]
Specs = Dict[str, Any]


class ParamSet:
    """Collects parameters and their logical-axis specs in lock-step."""

    def __init__(self, key: jax.Array, dtype):
        self._key = key
        self.dtype = dtype
        self.params: Params = {}
        self.specs: Specs = {}

    def _next(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple,
              init: str = "normal", scale: Optional[float] = None):
        assert len(shape) == len(axes), (name, shape, axes)
        if init == "normal":
            if scale is None:
                scale = 1.0 / math.sqrt(shape[0])
            arr = jax.random.normal(self._next(), shape, self.dtype) * scale
        elif init == "zeros":
            arr = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            arr = jnp.ones(shape, self.dtype)
        else:
            raise ValueError(init)
        self.params[name] = arr
        self.specs[name] = axes

    def sub(self, name: str, ps: "ParamSet"):
        self.params[name] = ps.params
        self.specs[name] = ps.specs

    def child(self) -> "ParamSet":
        return ParamSet(self._next(), self.dtype)

    def done(self) -> Tuple[Params, Specs]:
        return self.params, self.specs


# ---------------------------------------------------------------- norms
def rms_norm(x, scale, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * lax.rsqrt(var + eps)).astype(dt) * scale


def layer_norm(x, scale, bias, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return y.astype(dt) * scale + bias


# ---------------------------------------------------------------- rotary
def rope_angles(positions, dim: int, theta: float):
    """positions (...,) -> cos/sin (..., dim/2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :].astype(x1.dtype)
    s = sin[..., None, :].astype(x1.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------- TP matmul helpers
def tp_einsum(eq: str, x, w, sharder, *, w_model_dim=None,
              x_model_dim=None, out_model_dim=None, psum: bool = False):
    """Tensor-parallel einsum via shard_map (§Perf optimisation).

    Under pjit, row-parallel matmuls all-reduce the dot's fp32
    accumulator (measured: 2x the necessary bytes on every TP boundary),
    and column-parallel backward passes do the same for dx. Expressing
    the matmul per-shard makes the psum operate on the bf16 activation
    (forward) / cotangent (backward). Falls back to a plain einsum when
    no mesh is active or the weight isn't model-sharded.
    """
    mesh = getattr(sharder, "mesh", None)
    if mesh is None or "model" not in mesh.axis_names \
            or w_model_dim is None:
        return jnp.einsum(eq, x, w)
    from jax.sharding import PartitionSpec as P
    tp = "model"
    dp = sharder.rules.rules.get("batch")
    out_ndim = len(eq.split("->")[1])

    def spec(ndim, model_dim, batched=False):
        ax = [None] * ndim
        if model_dim is not None:
            ax[model_dim] = tp
        if batched:
            ax[0] = dp
        return P(*ax)

    def f(xl, wl):
        y = jnp.einsum(eq, xl, wl)
        if psum:
            # reduce on the activation dtype, not the accumulator's
            y = lax.psum(y.astype(xl.dtype), tp)
        return y

    return shard_map(
        f, mesh=mesh,
        in_specs=(spec(x.ndim, x_model_dim, batched=True),
                  spec(w.ndim, w_model_dim)),
        out_specs=spec(out_ndim, out_model_dim, batched=True),
        check_vma=False,
    )(x, w)


def _heads_sharded(sharder) -> bool:
    """True when attention heads are model-sharded AND the explicit
    shard_map TP path is enabled (rules flag "_tp_shardmap").

    §Perf iteration A3: routing TP matmuls through shard_map was meant to
    force bf16 psums; XLA:CPU re-promotes them to f32, and the explicit
    boundaries add FSDP re-gather collectives — measured regressions of
    +20-45 % on internlm2/internvl2/deepseek-v3 cells. Default OFF; the
    code stays for TPU-target experiments (flip the rules flag).
    """
    rules = getattr(sharder, "rules", None)
    return (rules is not None
            and bool(rules.rules.get("_tp_shardmap"))
            and rules.rules.get("heads") == "model")


def _seq_attn(sharder) -> bool:
    rules = getattr(sharder, "rules", None)
    return rules is not None and bool(rules.rules.get("_seq_attn"))


def seq_parallel_attention(q, k, v, sharder, *, chunk: int,
                           window=None, softmax_scale=None):
    """Sequence-parallel attention for head counts that do not divide the
    model axis (§Perf qwen3-14b/prefill_32k iteration).

    Baseline replicated attention does the full S x S wedge on every
    model rank (16x redundant compute and tile traffic — the dominant
    roofline term for these archs). Here every rank takes its S/TP query
    slice against the full locally-computed K/V: forward needs ZERO
    collectives (k, v are already replicated over 'model'); backward
    psums dk/dv once. Causal masking uses the rank's dynamic offset, so
    per-rank compute is S^2/TP masked pairs (2x the exact wedge, 8x
    better than replication at TP=16).
    """
    mesh = sharder.mesh
    from jax.sharding import PartitionSpec as P
    tp = "model"
    dp = sharder.rules.rules.get("batch")
    S = q.shape[1]
    tp_size = mesh.shape[tp]
    S_local = S // tp_size

    def f(ql, kl, vl):
        rank = lax.axis_index(tp)
        off = rank * S_local
        q_slice = lax.dynamic_slice_in_dim(ql, off, S_local, axis=1)
        y = chunked_attention(q_slice, kl, vl, chunk=chunk, causal=True,
                              window=window, softmax_scale=softmax_scale,
                              q_offset_dyn=off)
        return y

    return shard_map(
        f, mesh=mesh,
        in_specs=(P(dp, None, None, None),) * 3,
        out_specs=P(dp, tp, None, None),
        check_vma=False,
    )(q, k, v)


def _ff_sharded(sharder) -> bool:
    rules = getattr(sharder, "rules", None)
    return (rules is not None
            and bool(rules.rules.get("_tp_shardmap"))
            and rules.rules.get("ff") == "model")


# ------------------------------------------------------------- attention
def init_attention(ps: ParamSet, cfg) -> None:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ps.param("wq", (d, h, hd), ("embed", "heads", "head_dim"))
    ps.param("wk", (d, kv, hd), ("embed", "kv_heads", "head_dim"))
    ps.param("wv", (d, kv, hd), ("embed", "kv_heads", "head_dim"))
    ps.param("wo", (h, hd, d), ("heads", "head_dim", "embed"),
             scale=1.0 / math.sqrt(h * hd))
    if cfg.qkv_bias:
        ps.param("bq", (h, hd), ("heads", "head_dim"), init="zeros")
        ps.param("bk", (kv, hd), ("kv_heads", "head_dim"), init="zeros")
        ps.param("bv", (kv, hd), ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        ps.param("q_norm", (hd,), ("head_dim",), init="ones")
        ps.param("k_norm", (hd,), ("head_dim",), init="ones")


def _causal_pairs(n_q: int, n_kv: int, q_offset_chunks: int,
                  window_chunks: Optional[int]):
    """Static (i, j) chunk-pair list for causal (+windowed) attention.

    q chunk i covers absolute chunk index i + q_offset_chunks; kv chunk j
    is attended iff j <= i + q_offset_chunks and (no window or
    i + q_offset_chunks - j < window_chunks + 1).
    """
    pairs = []
    for i in range(n_q):
        ai = i + q_offset_chunks
        for j in range(n_kv):
            if j > ai:
                continue
            if window_chunks is not None and ai - j > window_chunks:
                continue
            pairs.append((i, j))
    return pairs


def chunked_attention(q, k, v, *, chunk: int, causal: bool = True,
                      q_offset: int = 0, window: Optional[int] = None,
                      softmax_scale: Optional[float] = None,
                      q_offset_dyn=None):
    """Online-softmax attention over statically enumerated chunk pairs.

    q: (B, Sq, H, D); k, v: (B, Skv, KVH, D) with H % KVH == 0 (grouped
    query attention — kv heads are never materialised H-wide).
    ``q_offset``: absolute position of q[0] (prefill continuation).
    Exact causal FLOPs; reverse-differentiable (checkpointed body).
    """
    B, Sq, H, D = q.shape
    _, Skv, KVH, Dv = v.shape       # v may have its own head dim (MLA)
    G = H // KVH
    scale = softmax_scale or (1.0 / math.sqrt(D))

    c = min(chunk, Sq, Skv)
    # pad seqs to chunk multiples (static)
    pq = (-Sq) % c
    pk = (-Skv) % c
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    n_q, n_kv = (Sq + pq) // c, (Skv + pk) // c

    if causal and q_offset_dyn is None:
        assert q_offset % c == 0, "q_offset must be chunk-aligned"
        # pair (i, j) can contain a visible element iff
        # c*(i-j) - (c-1) <= window  <=>  i-j <= (window + c - 1) // c
        wc = None if window is None else (window + c - 1) // c
        pairs = _causal_pairs(n_q, n_kv, q_offset // c, wc)
    else:
        # dynamic offset (sequence-parallel shards): masking is runtime,
        # so the pair list cannot be pruned statically
        pairs = [(i, j) for i in range(n_q) for j in range(n_kv)]
    pi = jnp.array([p[0] for p in pairs], jnp.int32)
    pj = jnp.array([p[1] for p in pairs], jnp.int32)

    qc = q.reshape(B, n_q, c, KVH, G, D)
    kc = k.reshape(B, n_kv, c, KVH, D)
    vc = v.reshape(B, n_kv, c, KVH, Dv)

    acc = jnp.zeros((B, n_q, c, KVH, G, Dv), jnp.float32)
    m = jnp.full((B, n_q, c, KVH, G), -jnp.inf, jnp.float32)
    l = jnp.zeros((B, n_q, c, KVH, G), jnp.float32)

    kv_pos = jnp.arange(c)
    q_pos = jnp.arange(c)

    def body(carry, ij):
        acc, m, l = carry
        i, j = ij
        qi = qc[:, i]                      # (B, c, KVH, G, D)
        kj = kc[:, j]                      # (B, c, KVH, D)
        vj = vc[:, j]
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        if causal:
            off = q_offset if q_offset_dyn is None else q_offset_dyn
            aq = i * c + q_pos + off
            ak = j * c + kv_pos
            mask = aq[:, None] >= ak[None, :]
            if window is not None:
                mask &= (aq[:, None] - ak[None, :]) <= window
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        # mask padded kv positions
        if pk:
            valid = (j * c + kv_pos) < Skv
            s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
        # clamp: a fully-masked tile (window pruning) must not produce
        # -inf - -inf = nan
        m_new = jnp.maximum(jnp.maximum(m[:, i], s.max(axis=-1)), -1e30)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m[:, i] - m_new)
        l_new = l[:, i] * corr + p.sum(axis=-1)
        acc_new = (acc[:, i] * corr[..., None]
                   + jnp.einsum("bqhgk,bkhd->bqhgd", p,
                                vj.astype(jnp.float32)))
        return (acc.at[:, i].set(acc_new), m.at[:, i].set(m_new),
                l.at[:, i].set(l_new)), None

    (acc, m, l), _ = lax.scan(jax.checkpoint(body), (acc, m, l), (pi, pj))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(B, n_q * c, H, Dv)[:, :Sq]
    return out.astype(q.dtype)


def attention_apply(params: Params, cfg, x, cos, sin, sharder,
                    *, q_offset: int = 0, window: Optional[int] = None,
                    causal: bool = True, kv_override=None):
    """Full-sequence attention (train / prefill). Returns (y, (k, v))."""
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    hm = 1 if _heads_sharded(sharder) else None
    q = tp_einsum("bsd,dhk->bshk", x, params["wq"], sharder,
                  w_model_dim=hm, out_model_dim=2 if hm else None)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    else:  # cross attention: precomputed encoder k, v
        k, v = kv_override
    if cfg.qkv_bias:
        q = q + params["bq"]
        if kv_override is None:
            k = k + params["bk"]
            v = v + params["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        if kv_override is None:
            k = apply_rope(k, cos, sin)
    q = sharder(q, ("batch", "seq_q", "heads", None))
    k = sharder(k, ("batch", "seq_kv", "kv_heads", None))
    v = sharder(v, ("batch", "seq_kv", "kv_heads", None))
    if causal and q_offset == 0 and _seq_attn(sharder) \
            and q.shape[1] % sharder.mesh.shape["model"] == 0:
        y = seq_parallel_attention(q, k, v, sharder,
                                   chunk=cfg.attn_chunk, window=window)
        y = sharder(y, ("batch", "seq_q", "heads", None))
    else:
        y = chunked_attention(q, k, v, chunk=cfg.attn_chunk,
                              causal=causal, q_offset=q_offset,
                              window=window)
    hm = 0 if _heads_sharded(sharder) else None
    y = tp_einsum("bshk,hkd->bsd", y, params["wo"], sharder,
                  w_model_dim=hm, x_model_dim=2 if hm == 0 else None,
                  psum=hm == 0)
    return y, (k, v)


# ------------------------------------------------------------------ MLP
def init_mlp(ps: ParamSet, cfg, d_ff: Optional[int] = None,
             gelu: bool = False) -> None:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ps.param("w_gate", (d, f), ("embed", "ff"))
    if not gelu:
        ps.param("w_up", (d, f), ("embed", "ff"))
    ps.param("w_down", (f, d), ("ff", "embed"))
    if gelu:
        ps.param("b_gate", (f,), ("ff",), init="zeros")
        ps.param("b_down", (d,), ("embed",), init="zeros")


def mlp_apply(params: Params, x, sharder, gelu: bool = False):
    if gelu:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["w_gate"])
                        + params["b_gate"])
        h = sharder(h, ("batch", "seq_q", "ff"))
        return jnp.einsum("bsf,fd->bsd", h, params["w_down"]) \
            + params["b_down"]
    fm = 1 if _ff_sharded(sharder) else None
    g = tp_einsum("bsd,df->bsf", x, params["w_gate"], sharder,
                  w_model_dim=fm, out_model_dim=2 if fm else None)
    u = tp_einsum("bsd,df->bsf", x, params["w_up"], sharder,
                  w_model_dim=fm, out_model_dim=2 if fm else None)
    h = jax.nn.silu(g) * u
    h = sharder(h, ("batch", "seq_q", "ff"))
    return tp_einsum("bsf,fd->bsd", h, params["w_down"], sharder,
                     w_model_dim=0 if fm else None,
                     x_model_dim=2 if fm else None, psum=fm is not None)


# ------------------------------------------------------------------ MoE
def init_moe(ps: ParamSet, cfg) -> None:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    ps.param("router", (d, e), ("embed", None), scale=0.02)
    ps.param("we_gate", (e, d, f), ("experts", "embed", "moe_ff"))
    ps.param("we_up", (e, d, f), ("experts", "embed", "moe_ff"))
    ps.param("we_down", (e, f, d), ("experts", "moe_ff", "embed"))
    if cfg.n_shared_experts:
        fs = f * cfg.n_shared_experts
        ps.param("ws_gate", (d, fs), ("embed", "ff"))
        ps.param("ws_up", (d, fs), ("embed", "ff"))
        ps.param("ws_down", (fs, d), ("ff", "embed"))


def router_probs(params, cfg, x):
    """Softmax router over experts (fp32), top-k selection."""
    logits = jnp.einsum("btd,de->bte", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, cfg.topk)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return probs, top_p, top_e


def moe_aux_loss(probs, top_e, n_experts: int):
    """Switch-style load-balancing loss."""
    density = jnp.mean(
        jax.nn.one_hot(top_e, n_experts, dtype=jnp.float32), axis=(0, 1, 2))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    return n_experts * jnp.sum(density * mean_prob)


def moe_apply_dense(params: Params, cfg, x, sharder):
    """Oracle MoE: every expert on every token, masked combine. Exact but
    O(E) FLOPs — smoke tests and kernel references only."""
    probs, top_p, top_e = router_probs(params, cfg, x)
    gate = jnp.einsum("btd,edf->betf", x, params["we_gate"])
    up = jnp.einsum("btd,edf->betf", x, params["we_up"])
    h = jax.nn.silu(gate) * up
    y_e = jnp.einsum("betf,efd->betd", h, params["we_down"])
    combine = jnp.sum(
        jax.nn.one_hot(top_e, cfg.n_experts, dtype=x.dtype)
        * top_p.astype(x.dtype)[..., None], axis=2)           # (B,T,E)
    y = jnp.einsum("betd,bte->btd", y_e, combine)
    aux = moe_aux_loss(probs, top_e, cfg.n_experts)
    return y + _shared_expert(params, cfg, x, sharder), aux


def _shared_expert(params, cfg, x, sharder):
    if not cfg.n_shared_experts:
        return 0.0
    fm = 1 if _ff_sharded(sharder) else None
    g = tp_einsum("bsd,df->bsf", x, params["ws_gate"], sharder,
                  w_model_dim=fm, out_model_dim=2 if fm else None)
    u = tp_einsum("bsd,df->bsf", x, params["ws_up"], sharder,
                  w_model_dim=fm, out_model_dim=2 if fm else None)
    h = jax.nn.silu(g) * u
    h = sharder(h, ("batch", "seq_q", "ff"))
    return tp_einsum("bsf,fd->bsd", h, params["ws_down"], sharder,
                     w_model_dim=0 if fm else None,
                     x_model_dim=2 if fm else None, psum=fm is not None)


def moe_dispatch_indices(top_e, top_p, n_experts: int, capacity: int):
    """Capacity-based dispatch: returns (dest, weight) where
    dest (B, T, K) in [0, capacity) or capacity (dropped)."""
    B, T, K = top_e.shape
    flat_e = top_e.reshape(B, T * K)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=1) - 1            # position within expert
    slot = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]
    slot = slot.reshape(B, T, K)
    keep = slot < capacity
    return jnp.where(keep, slot, capacity), jnp.where(keep, top_p, 0.0)


def moe_apply_capacity(params: Params, cfg, x, sharder, capacity: int):
    """Capacity-dropping MoE with expert-sharded buffers.

    Tokens are scattered into (E, capacity) buffers, each expert runs a
    dense FFN over its buffer, results are gathered back with combine
    weights. Under the production mesh the expert axis is sharded
    ('model'); dispatch/combine lower to collectives chosen by SPMD.
    """
    B, T, _ = x.shape
    E = cfg.n_experts
    probs, top_p, top_e = router_probs(params, cfg, x)
    slot, w = moe_dispatch_indices(top_e, top_p, E, capacity)

    # scatter tokens into expert buffers: (B, E, capacity, d)
    buf = jnp.zeros((B, E, capacity + 1, x.shape[-1]), x.dtype)
    bidx = jnp.arange(B)[:, None, None]
    buf = buf.at[bidx, top_e, slot].add(
        x[:, :, None, :] * (w[..., None] > 0).astype(x.dtype))
    buf = buf[:, :, :capacity]
    buf = sharder(buf, ("batch", "experts", None, None))

    g = jnp.einsum("becd,edf->becf", buf, params["we_gate"])
    u = jnp.einsum("becd,edf->becf", buf, params["we_up"])
    h = jax.nn.silu(g) * u
    h = sharder(h, ("batch", "experts", None, "moe_ff"))
    y_buf = jnp.einsum("becf,efd->becd", h, params["we_down"])
    y_buf = jnp.pad(y_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))  # drop slot

    # gather back: token (b,t) takes y_buf[b, top_e[k], slot[k]] * w[k]
    y = jnp.einsum(
        "btkd,btk->btd",
        y_buf[bidx, top_e, slot],
        w.astype(x.dtype))
    aux = moe_aux_loss(probs, top_e, E)
    return y + _shared_expert(params, cfg, x, sharder), aux


def moe_apply_ep_shardmap(params: Params, cfg, x, sharder, capacity: int):
    """Expert-parallel MoE under ``shard_map`` (§Perf optimisation).

    The pjit/GSPMD lowering of ``moe_apply_capacity`` materialises the
    per-token expert outputs as a REPLICATED (B, T, K, d) fp32 tensor and
    all-reduces it across the whole mesh per layer (measured: 77 GB/dev
    per layer on deepseek-moe-16b — EXPERIMENTS.md §Perf). Here the
    dispatch/combine runs per shard: each model rank owns E/TP experts,
    scatters only its own tokens, and the single collective is a
    bf16 psum of the (B_local, T, d) partial outputs.

    Requires a mesh-carrying sharder; the router runs redundantly on
    every model rank (identical results — cheap) so no token shuffling
    collective is needed at all ("replicated-dispatch EP").
    """
    from jax.sharding import PartitionSpec as P

    mesh = sharder.mesh
    dp = sharder.rules.rules.get("batch")
    tp = "model"
    E = cfg.n_experts
    # NOTE (§Perf A4, not implemented): 2D expert parallelism (experts
    # over data x model) would eliminate the FSDP per-layer weight
    # gathers that cap deepseek-v3's multi-pod scaling at 1.14x — but it
    # requires an all-to-all token exchange (tokens are data-sharded and
    # a replicated-dispatch variant would have to gather the full global
    # batch per device: 15 GB/layer for dsv3 train). Recorded as the
    # 1000+-node direction in EXPERIMENTS.md.
    tp_size = mesh.shape[tp]
    E_local = E // tp_size

    def block(x_l, router, we_gate, we_up, we_down):
        # x_l: (B_local, T, d); we_*: (E_local, d, f)
        logits = jnp.einsum("btd,de->bte", x_l.astype(jnp.float32),
                            router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = lax.top_k(probs, cfg.topk)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        slot, w = moe_dispatch_indices(top_e, top_p, E, capacity)

        rank = lax.axis_index(tp)
        e0 = rank * E_local
        local = (top_e >= e0) & (top_e < e0 + E_local) & (w > 0)
        le = jnp.clip(top_e - e0, 0, E_local - 1)
        lslot = jnp.where(local, slot, capacity)

        B = x_l.shape[0]
        buf = jnp.zeros((B, E_local, capacity + 1, x_l.shape[-1]),
                        x_l.dtype)
        bidx = jnp.arange(B)[:, None, None]
        buf = buf.at[bidx, le, lslot].add(
            x_l[:, :, None, :] * local[..., None].astype(x_l.dtype))
        buf = buf[:, :, :capacity]

        g = jnp.einsum("becd,edf->becf", buf, we_gate)
        u = jnp.einsum("becd,edf->becf", buf, we_up)
        h = jax.nn.silu(g) * u
        y_buf = jnp.einsum("becf,efd->becd", h, we_down)
        y_buf = jnp.pad(y_buf, ((0, 0), (0, 0), (0, 1), (0, 0)))

        y = jnp.einsum(
            "btkd,btk->btd", y_buf[bidx, le, lslot],
            (w * local).astype(x_l.dtype))
        y = lax.psum(y.astype(cfg.cdtype), tp)
        aux = moe_aux_loss(probs, top_e, E)   # identical on all tp ranks
        if dp:
            aux = lax.pmean(aux, dp)          # P() out_spec needs global
        return y, aux

    y, aux = shard_map(
        block, mesh=mesh,
        in_specs=(P(dp, None, None), P(), P(tp, None, None),
                  P(tp, None, None), P(tp, None, None)),
        out_specs=(P(dp, None, None), P()),
        check_vma=False,
    )(x, params["router"], params["we_gate"], params["we_up"],
      params["we_down"])
    return y + _shared_expert(params, cfg, x, sharder), aux


# ------------------------------------------------------------------ MLA
def init_mla(ps: ParamSet, cfg) -> None:
    """DeepSeek multi-head latent attention."""
    d, h = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ps.param("wq_a", (d, qr), ("embed", "lora"))
    ps.param("q_a_norm", (qr,), (None,), init="ones")
    ps.param("wq_b", (qr, h, dn + dr), ("lora", "heads", "head_dim"))
    ps.param("wkv_a", (d, kvr + dr), ("embed", None))
    ps.param("kv_a_norm", (kvr,), (None,), init="ones")
    ps.param("wk_b", (kvr, h, dn), ("lora", "heads", "head_dim"))
    ps.param("wv_b", (kvr, h, dv), ("lora", "heads", "head_dim"))
    ps.param("wo", (h, dv, d), ("heads", "head_dim", "embed"),
             scale=1.0 / math.sqrt(h * dv))


def mla_apply(params: Params, cfg, x, cos, sin, sharder):
    """MLA for train/prefill (decompressed path). Returns (y, latent_cache)
    where latent_cache = (c_kv, k_rope) is what decode keeps per token."""
    dn, dr = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dr->bsr", x, params["wq_a"])
    q = rms_norm(q, params["q_a_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q, params["wq_b"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, cos, sin)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"])
    c_kv, k_rope = kv[..., :cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, params["kv_a_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # 1 shared head

    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["wv_b"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(
            k_rope, (*k_nope.shape[:3], dr))], axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    qf = sharder(qf, ("batch", "seq_q", "heads", None))
    k = sharder(k, ("batch", "seq_kv", "heads", None))
    v = sharder(v, ("batch", "seq_kv", "heads", None))
    y = chunked_attention(qf, k, v, chunk=cfg.attn_chunk,
                          softmax_scale=1.0 / math.sqrt(dn + dr))
    y = jnp.einsum("bshk,hkd->bsd", y, params["wo"])
    return y, (c_kv, k_rope[:, :, 0, :])


# ----------------------------------------------------------- embeddings
def init_embeddings(ps: ParamSet, cfg) -> None:
    # The token-id gather resists FSDP resharding (SPMD full-remat), and
    # vocab sharding already divides the table 16-way — so the d_model dim
    # stays unsharded ("embed_t" is never FSDP-mapped).
    v, d = cfg.padded_vocab, cfg.d_model
    ps.param("embed", (v, d), ("vocab", "embed_t"), scale=0.02)
    if not cfg.tie_embeddings:
        ps.param("unembed", (d, v), ("embed_t", "vocab"))
    ps.param("final_norm", (d,), ("embed_t",), init="ones")


def embed_tokens(params, cfg, tokens):
    return params["embed"].astype(cfg.cdtype)[tokens]


def logits_from_hidden(params, cfg, h, sharder):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", h, w.astype(cfg.cdtype))
    return sharder(logits, ("batch", "seq_q", "vocab"))


def cross_entropy(logits, labels, vocab_size: int):
    """Mean CE over positions with label >= 0 (padded vocab tail masked)."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32),
        jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    mask = (labels >= 0) & (labels < vocab_size)
    loss = jnp.where(mask, lse - gold, 0.0)
    return loss.sum() / jnp.maximum(mask.sum(), 1)
