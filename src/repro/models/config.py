"""Unified model configuration covering all assigned architecture families.

One frozen dataclass describes dense / MoE / MLA / SSM / hybrid / enc-dec /
VLM variants; ``family`` selects the assembly in ``models/model.py`` and
unused fields stay at their defaults. Architecture instances live in
``repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"       # dense | moe | ssm | hybrid | encdec | vlm

    # ---- transformer trunk ----
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0           # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    qk_norm: bool = False       # qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False      # qwen1.5-style bias on qkv projections
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    window: Optional[int] = None          # sliding-window attention size
    long_context_window: int = 4096       # window used for long_* shapes

    # ---- MoE ----
    n_experts: int = 0
    n_shared_experts: int = 0
    topk: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    moe_impl: str = "auto"      # auto | dense | ep  (dense = tiny oracle)

    # ---- MLA (deepseek) ----
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # ---- MTP (deepseek-v3 multi-token prediction) ----
    mtp: bool = False
    mtp_coef: float = 0.3

    # ---- SSM / Mamba2 ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # ---- hybrid (zamba2) ----
    attn_every: int = 0         # shared attention block every k ssm layers

    # ---- encoder-decoder (whisper) ----
    enc_layers: int = 0
    dec_layers: int = 0
    n_enc_positions: int = 1500
    enc_d_model: int = 0        # 0 -> d_model

    # ---- VLM (internvl) ----
    n_patches: int = 0          # prefix patch embeddings (frontend stub)

    # ---- numerics / layout ----
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    vocab_pad_multiple: int = 16
    attn_chunk: int = 1024      # kv-chunk for online-softmax attention
    use_pallas: bool = False    # kernels opt-in (dry-run uses pure XLA)

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid-with-window)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------- reduced smoke config
    def smoke(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            param_dtype="float32",
            compute_dtype="float32",
            attn_chunk=64,
        )
        if self.n_experts:
            kw.update(n_experts=8,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      topk=min(self.topk, 2), moe_d_ff=64,
                      first_dense_layers=min(self.first_dense_layers, 1))
        if self.mla:
            kw.update(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=16,
                      qk_rope_dim=16, v_head_dim=32)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32,
                      d_model=128)
        if self.attn_every:
            kw.update(attn_every=2, n_layers=4)
        if self.is_encdec:
            kw.update(enc_layers=2, dec_layers=2, n_enc_positions=64)
        if self.n_patches:
            kw.update(n_patches=8)
        return self.replace(**kw)
