"""Mamba2 (state-space duality) block — chunked SSD scan in pure JAX.

Train/prefill uses the chunked block decomposition of Dao & Gu 2024
(arXiv:2405.21060): intra-chunk quadratic attention-like term plus an
inter-chunk state recurrence (``lax.scan`` over chunks). Decode is the
O(1) per-token recurrence on the (heads, headdim, state) SSM state.

``ssd_reference`` (token-by-token recurrence) is the oracle used by the
unit tests and by the Pallas kernel tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import ParamSet, rms_norm


def init_mamba(ps: ParamSet, cfg) -> None:
    d = cfg.d_model
    di = cfg.d_inner
    g, n, h = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    ps.param("w_xz", (d, 2 * di), ("embed", "ssm_inner"))
    ps.param("w_bc", (d, 2 * g * n), ("embed", None))
    ps.param("w_dt", (d, h), ("embed", "ssm_heads"))
    ps.param("dt_bias", (h,), ("ssm_heads",), init="zeros")
    ps.param("A_log", (h,), ("ssm_heads",), init="ones")
    ps.param("D", (h,), ("ssm_heads",), init="ones")
    ps.param("conv_w", (cfg.ssm_conv, di + 2 * g * n), (None, "ssm_inner"))
    ps.param("conv_b", (di + 2 * g * n,), ("ssm_inner",), init="zeros")
    ps.param("gate_norm", (di,), ("ssm_inner",), init="ones")
    ps.param("w_out", (di, d), ("ssm_inner", "embed"))


def _depthwise_causal_conv(x, w, b, state=None):
    """x (B, L, C), w (K, C) depthwise causal; optional carry-in state
    (B, K-1, C). Returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(y + b), new_state


def ssd_chunked(x, dt, A, B, C, *, chunk: int,
                init_state=None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (b, l, h, p)    values
    dt: (b, l, h)       softplus-activated step sizes (>0)
    A:  (h,)            negative decay rates
    B, C: (b, l, g, n)  input/output projections (g groups)
    init_state: (b, h, p, n) or None.
    Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert h % g == 0
    pad = (-l) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (l + pad) // chunk
    hg = h // g

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)

    dA = dtc * A.astype(jnp.float32)                 # (b, nc, c, h) <= 0
    cum = jnp.cumsum(dA, axis=2)                     # within-chunk cumsum
    total = cum[:, :, -1]                            # (b, nc, h)

    # intra-chunk ("diagonal block"): attention-like with decay kernel
    # L[s, t] = exp(cum[s] - cum[t]) for s >= t. Mask BEFORE exp: the
    # masked diffs are positive (overflow + NaN grads through where).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,s,t,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    diff = jnp.where(causal[None, None, :, :, None], diff, -jnp.inf)
    Ldec = jnp.exp(diff)
    scores = jnp.einsum("bcsgn,bctgn->bcstg", Cc, Bc)       # (b,nc,s,t,g)
    scores = jnp.repeat(scores, hg, axis=-1) * Ldec         # (b,nc,s,t,h)
    y_diag = jnp.einsum("bcsth,bcth,bcthp->bcshp", scores, dtc,
                        xc.astype(jnp.float32))

    # chunk states: S_c = sum_t exp(total - cum[t]) * dt[t] * B[t] x[t]^T
    decay_in = jnp.exp(total[:, :, None, :] - cum)          # (b,nc,c,h)
    Bh = jnp.repeat(Bc, hg, axis=3)                         # (b,nc,c,h,n)
    dBx = jnp.einsum("bcthn,bcth,bcthp->bchpn",
                     Bh, decay_in * dtc, xc.astype(jnp.float32))

    # inter-chunk recurrence over nc
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        init_state = init_state.astype(jnp.float32)

    def step(S, inp):
        dBx_c, tot_c = inp                                  # (b,h,p,n),(b,h)
        S_out = S                                           # state BEFORE
        S = S * jnp.exp(tot_c)[..., None, None] + dBx_c
        return S, S_out

    dBx_t = jnp.moveaxis(dBx, 1, 0)                         # (nc,b,h,p,n)
    tot_t = jnp.moveaxis(total, 1, 0)                       # (nc,b,h)
    final, S_prev = lax.scan(step, init_state, (dBx_t, tot_t))
    S_prev = jnp.moveaxis(S_prev, 0, 1)                     # (b,nc,h,p,n)

    # inter-chunk contribution: y[s] += exp(cum[s]) * C[s] . S_prev
    Cg = jnp.repeat(Cc, hg, axis=3)                         # (b,nc,c,h,n)
    y_off = jnp.einsum("bcshn,bchpn->bcshp", Cg * jnp.exp(cum)[..., None],
                       S_prev)

    y = (y_diag + y_off).reshape(b, nc * chunk, h, p)[:, :l]
    return y.astype(x.dtype), final


def ssd_reference(x, dt, A, B, C, init_state=None):
    """Token-by-token recurrence oracle (slow, exact)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def step(S, inp):
        x_t, dt_t, B_t, C_t = inp   # (b,h,p),(b,h),(b,g,n),(b,g,n)
        dA = jnp.exp(dt_t * A)                               # (b,h)
        Bh = jnp.repeat(B_t, hg, axis=1)                     # (b,h,n)
        Ch = jnp.repeat(C_t, hg, axis=1)
        S = S * dA[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt_t, x_t.astype(jnp.float32), Bh)
        y = jnp.einsum("bhpn,bhn->bhp", S, Ch)
        return S, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    S, ys = lax.scan(step, init_state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), S


def mamba_apply(params, cfg, x, sharder, *, conv_state=None,
                ssm_state=None, return_state: bool = False):
    """Full-sequence Mamba2 block. x (B, L, d_model)."""
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    xz = jnp.einsum("bld,de->ble", x, params["w_xz"])
    xin, z = jnp.split(xz, 2, axis=-1)
    bc = jnp.einsum("bld,de->ble", x, params["w_bc"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out, new_conv = _depthwise_causal_conv(
        conv_in, params["conv_w"], params["conv_b"], conv_state)
    xin = conv_out[..., :di]
    B = conv_out[..., di:di + g * n].reshape(*x.shape[:2], g, n)
    C = conv_out[..., di + g * n:].reshape(*x.shape[:2], g, n)
    xh = xin.reshape(*x.shape[:2], h, cfg.ssm_headdim)
    xh = sharder(xh, ("batch", "seq_q", "ssm_heads", None))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, final = ssd_chunked(xh, dt, A, B, C, chunk=cfg.ssm_chunk,
                           init_state=ssm_state)
    y = y + xh * params["D"].astype(y.dtype)[:, None]
    y = y.reshape(*x.shape[:2], di)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["w_out"])
    if return_state:
        return out, (new_conv, final)
    return out


def mamba_decode_step(params, cfg, x, conv_state, ssm_state):
    """Single-token decode. x (B, 1, d). Returns (y, (conv, ssm))."""
    di, g, n, h = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_heads
    xz = jnp.einsum("bld,de->ble", x, params["w_xz"])
    xin, z = jnp.split(xz, 2, axis=-1)
    bc = jnp.einsum("bld,de->ble", x, params["w_bc"])
    dt = jax.nn.softplus(
        jnp.einsum("bld,dh->blh", x, params["w_dt"]).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))[:, 0]       # (B,h)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out, new_conv = _depthwise_causal_conv(
        conv_in, params["conv_w"], params["conv_b"], conv_state)
    xin = conv_out[..., :di]
    B = conv_out[:, 0, di:di + g * n].reshape(-1, g, n)
    C = conv_out[:, 0, di + g * n:].reshape(-1, g, n)
    xh = xin[:, 0].reshape(-1, h, cfg.ssm_headdim)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                     # (B,h)
    hg = h // g
    Bh = jnp.repeat(B, hg, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C, hg, axis=1).astype(jnp.float32)
    S = (ssm_state * dA[..., None, None]
         + jnp.einsum("bh,bhp,bhn->bhpn", dt,
                      xh.astype(jnp.float32), Bh))
    y = jnp.einsum("bhpn,bhn->bhp", S, Ch)
    y = y + xh.astype(jnp.float32) * params["D"].astype(jnp.float32)[:, None]
    y = y.reshape(-1, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], cfg.norm_eps)
    return jnp.einsum("ble,ed->bld", y, params["w_out"]), (new_conv, S)
