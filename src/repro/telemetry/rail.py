"""The in-loop event-trace rail: record layout, host sink, flush.

The engines stage one fixed-width record per *processed* event into a
(L, SEG, ·) segment overlay — the same shape class as exact mode's
``d_*`` dispatch overlay, so the carried state stays O(SEG) per lane
regardless of trace length — and flush the overlay to the host once
per segment through an **ordered** `jax.experimental.io_callback`.
Ordered callbacks serialise with the surrounding computation, so the
host receives segment blocks in simulation order and per-lane record
order is simply flush order x row order.

``trace`` is a *static* jit argument on every engine entry point: with
``trace=False`` (the default) none of this module's code is traced and
the loops lower bitwise onto the unchanged program — the analysis
gate (`repro.analysis.telemetry_gate`) asserts zero callback custom
calls appear in the compiled HLO of the untraced engines.

Record layout (int32 x TR_RI + float64 x TR_RF):

===========  ===========================================================
field        meaning
===========  ===========================================================
TR_KIND      `TraceKind` code; -1 rows are unused overlay slots
TR_RID       request id (-1 for rid-less events: cold-done, churn)
TR_FN        function id (-1 when not applicable)
TR_NODE      node id (-1 on the single-node tier; the static cluster
             tier patches the node in host-side)
TR_AUX       kind-dependent detail. EXEC: 0 ok / 1 fail-retry /
             2 fail-exhausted, +4 timeout. CHURN: 1 node came up /
             0 went down. Arrival-class events: bitfield — 1 cold
             start begun, 2 queued, 4 shed, 8 overflow-dropped.
TR_QLEN      queued requests after the event (event node's total)
TR_BUSY      busy slots after the event (event node)
TR_WARM      warm idle containers after the event (event node)
TR_SEQ       per-lane processed-event sequence number (1-based)
TF_T         simulation time of the event
TF_DT        execution time (EXEC events; 0 otherwise)
===========  ===========================================================
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import List, Optional, Tuple

import numpy as np


class TraceKind:
    """Event-kind codes shared by the jitted rails, the Python
    reference cluster's event log and the span reassembler."""
    ARRIVAL = 0        # fresh arrival consumed (routed/admitted/parked)
    EXEC = 1           # an execution finished (any outcome; see AUX)
    COLD = 2           # a cold container finished warming
    TIMER = 3          # a keep-alive / re-arm timer fired
    RETRY = 4          # a retry-rail head fired (re-entry)
    NODE_ARRIVAL = 5   # a delayed send landed on its node
    REROUTE = 6        # a churn-drained request re-entered routing
    CHURN = 7          # a node toggled up/down

    NAMES = ("ARRIVAL", "EXEC", "COLD", "TIMER", "RETRY",
             "NODE_ARRIVAL", "REROUTE", "CHURN")


# int32 record fields
(TR_KIND, TR_RID, TR_FN, TR_NODE, TR_AUX, TR_QLEN, TR_BUSY, TR_WARM,
 TR_SEQ) = range(9)
TR_RI = 9
# float64 record fields
TF_T, TF_DT = range(2)
TR_RF = 2

# TR_AUX bits on arrival-class events (ARRIVAL / RETRY / NODE_ARRIVAL
# / REROUTE / TIMER)
AUX_COLD = 1       # the event started a cold container
AUX_QUEUED = 2     # a request was pushed onto a queue
AUX_SHED = 4       # a request was shed (terminal)
AUX_OVERFLOW = 8   # a request was dropped on a full queue (error mode)
# TR_AUX on EXEC events
AUX_FAIL_RETRY = 1
AUX_FAIL_EXHAUSTED = 2
AUX_TIMEOUT = 4

_FIELDS_I = ("kind", "rid", "fn", "node", "aux", "qlen", "busy",
             "warm", "seq")
_FIELDS_F = ("t", "dt")


class TraceSink:
    """Per-collection-scope accumulator of flushed overlay blocks.

    ``blocks`` holds (tr_i, tr_f) pairs of (L, SEG, TR_RI) int32 /
    (L, SEG, TR_RF) float64 host copies in flush order."""

    def __init__(self):
        self.blocks: List[Tuple[np.ndarray, np.ndarray]] = []

    def append(self, tr_i: np.ndarray, tr_f: np.ndarray) -> None:
        self.blocks.append((np.array(tr_i, np.int32),
                            np.array(tr_f, np.float64)))

    @property
    def n_lanes(self) -> int:
        return self.blocks[0][0].shape[0] if self.blocks else 0

    def lane_events(self, lane: int) -> dict:
        """Per-lane columnar event arrays (unused overlay rows — kind
        -1 — filtered), in processed-event order."""
        ii = [bi[lane] for bi, _ in self.blocks]
        ff = [bf[lane] for _, bf in self.blocks]
        if not ii:
            i = np.zeros((0, TR_RI), np.int32)
            f = np.zeros((0, TR_RF), np.float64)
        else:
            i = np.concatenate(ii)
            f = np.concatenate(ff)
        keep = i[:, TR_KIND] >= 0
        i, f = i[keep], f[keep]
        out = {name: i[:, col].copy()
               for col, name in enumerate(_FIELDS_I)}
        out.update({name: f[:, col].copy()
                    for col, name in enumerate(_FIELDS_F)})
        return out


# active sink — a module global, NOT thread-local: ordered
# io_callbacks run on JAX-internal runtime threads, so the callback
# cannot see a sink pinned to the caller's thread. The lock keeps
# nested/concurrent collect() scopes honest (the runners serialise
# traced engine calls, so one scope is active at a time).
_SINK: Optional[TraceSink] = None
_SCOPE_LOCK = threading.Lock()


def _active_sink() -> Optional[TraceSink]:
    return _SINK


@contextmanager
def collect():
    """Scope that captures every trace-rail flush issued by engine
    calls made (and completed — callers must block on the device
    result inside the scope) within it. Scopes are exclusive: traced
    engine calls must not run concurrently."""
    global _SINK
    sink = TraceSink()
    with _SCOPE_LOCK:
        prev, _SINK = _SINK, sink
        try:
            yield sink
        finally:
            _SINK = prev


def _flush_cb(tr_i, tr_f) -> None:
    sink = _active_sink()
    if sink is not None:
        sink.append(np.asarray(tr_i), np.asarray(tr_f))


def emit_flush(tr_i, tr_f) -> None:
    """Flush one segment overlay to the active host sink, *in order*
    with the surrounding computation. Called from inside the jitted
    event loops; only traced (``trace=True``) programs contain it."""
    from jax.experimental import io_callback
    io_callback(_flush_cb, None, tr_i, tr_f, ordered=True)


def merge_events(events: List[dict]) -> dict:
    """Merge several per-lane event streams into one, stably sorted by
    (time, sequence) — used by the static cluster tier, where one
    logical cell is K independent single-node streams."""
    if not events:
        return {name: np.zeros((0,),
                               np.int32 if name in _FIELDS_I
                               else np.float64)
                for name in _FIELDS_I + _FIELDS_F}
    cat = {k: np.concatenate([e[k] for e in events])
           for k in events[0]}
    order = np.lexsort((cat["seq"], cat["t"]))
    return {k: v[order] for k, v in cat.items()}
