"""Chrome/Perfetto ``trace_event`` JSON export.

Maps one cell's event stream onto the Trace Event Format accepted by
Perfetto (ui.perfetto.dev) and chrome://tracing: each cluster node is
a *process*, each warm slot's function a *thread*, executions are
complete slices (``ph="X"``) and routing events are instants
(``ph="i"``). Sim-time seconds become microsecond timestamps.

Dependency-free: stdlib ``json`` only.
"""
from __future__ import annotations

import json
from typing import Dict, Optional

import numpy as np

from repro.telemetry.rail import (AUX_COLD, AUX_FAIL_EXHAUSTED,
                                  AUX_FAIL_RETRY, AUX_OVERFLOW,
                                  AUX_QUEUED, AUX_SHED, AUX_TIMEOUT,
                                  TraceKind)

_US = 1e6  # sim seconds -> trace microseconds

_ARR_BITS = ((AUX_COLD, "cold"), (AUX_QUEUED, "queued"),
             (AUX_SHED, "shed"), (AUX_OVERFLOW, "overflow"))
_EXEC_BITS = ((AUX_FAIL_RETRY, "fail_retry"),
              (AUX_FAIL_EXHAUSTED, "fail_exhausted"),
              (AUX_TIMEOUT, "timeout"))


def _aux_args(kind: int, aux: int) -> Dict[str, bool]:
    bits = _EXEC_BITS if kind == TraceKind.EXEC else _ARR_BITS
    return {name: True for bit, name in bits if aux & bit}


def events_to_trace(events: Dict[str, np.ndarray], *,
                    label: str = "repro") -> dict:
    """Build a Trace Event Format dict from one columnar stream."""
    out = []
    nodes = sorted(int(n) for n in np.unique(events["node"])
                   if n >= 0)
    for k in nodes:
        out.append(dict(ph="M", name="process_name", pid=k, tid=0,
                        args={"name": f"node {k}"}))
    kind, rid = events["kind"], events["rid"]
    fn, node = events["fn"], events["node"]
    aux, t, dt = events["aux"], events["t"], events["dt"]
    for i in range(len(kind)):
        k = int(kind[i])
        pid = max(int(node[i]), 0)
        tid = max(int(fn[i]), 0)
        args = dict(rid=int(rid[i]), fn=int(fn[i]),
                    qlen=int(events["qlen"][i]),
                    warm=int(events["warm"][i]),
                    **_aux_args(k, int(aux[i])))
        name = TraceKind.NAMES[k]
        if k == TraceKind.EXEC:
            ts = (t[i] - dt[i]) * _US
            out.append(dict(ph="X", name=f"exec fn{int(fn[i])}",
                            cat=name, ts=float(ts),
                            dur=float(dt[i] * _US), pid=pid, tid=tid,
                            args=args))
        elif k == TraceKind.CHURN:
            state = "up" if int(aux[i]) else "down"
            out.append(dict(ph="i", name=f"node {state}", cat=name,
                            ts=float(t[i] * _US), pid=pid, tid=0,
                            s="p", args={}))
        else:
            out.append(dict(ph="i", name=f"{name} rid{int(rid[i])}",
                            cat=name, ts=float(t[i] * _US), pid=pid,
                            tid=tid, s="t", args=args))
    return dict(traceEvents=out, displayTimeUnit="ms",
                otherData={"source": label})


def validate_trace(trace: dict) -> int:
    """Check Trace Event Format invariants; return the event count.

    Raises ``ValueError`` on the first violation — used by the test
    suite and the ``--smoke`` gate as a schema round-trip check."""
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        raise ValueError("trace: missing top-level 'traceEvents'")
    evs = trace["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("trace: 'traceEvents' is not a list")
    for i, e in enumerate(evs):
        for f in ("ph", "name", "pid", "tid"):
            if f not in e:
                raise ValueError(f"trace event {i}: missing {f!r}")
        ph = e["ph"]
        if ph not in ("X", "i", "M", "B", "E", "C"):
            raise ValueError(f"trace event {i}: bad ph {ph!r}")
        if ph != "M":
            if not isinstance(e.get("ts"), (int, float)):
                raise ValueError(f"trace event {i}: bad ts")
        if ph == "X":
            if not (isinstance(e.get("dur"), (int, float))
                    and e["dur"] >= 0):
                raise ValueError(f"trace event {i}: bad dur")
        if ph == "i" and e.get("s") not in ("t", "p", "g"):
            raise ValueError(f"trace event {i}: bad instant scope")
    return len(evs)


def save_trace(events: Dict[str, np.ndarray], path, *,
               label: str = "repro",
               validate: bool = True) -> Optional[dict]:
    """Export one event stream as Perfetto-loadable JSON."""
    trace = events_to_trace(events, label=label)
    if validate:
        validate_trace(trace)
    with open(path, "w") as fh:
        json.dump(trace, fh)
    return trace


def load_trace(path) -> dict:
    with open(path) as fh:
        return json.load(fh)
