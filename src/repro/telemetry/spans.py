"""Per-request span reassembly and the `TraceRun` container.

A *span* is one request's lifecycle reassembled from the flat
per-event records of the trace rail (`repro.telemetry.rail`):
arrival → (queued) → (cold start) → execution attempts → completion,
with retries, reroutes and deferred node arrivals as child instants.
`TraceRun` holds one event stream per computed grid cell, addressed by
the same labeled coordinates as the owning `ResultSet`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.telemetry.rail import (AUX_COLD, AUX_FAIL_EXHAUSTED,
                                  AUX_FAIL_RETRY, AUX_QUEUED,
                                  AUX_SHED, TraceKind, _FIELDS_F,
                                  _FIELDS_I)

_FIELDS = _FIELDS_I + _FIELDS_F


@dataclass
class Span:
    """One request's reassembled lifecycle."""

    rid: int
    fn: int
    arrival: float                  # raw-arrival instant (ARRIVAL)
    node: int = -1                  # node of the final execution
    start: float = -1.0             # dispatch of the final execution
    completion: float = -1.0        # -1: shed / exhausted / unfinished
    queued: bool = False            # ever pushed onto a queue
    cold: bool = False              # dispatch began a cold start
    shed: bool = False              # terminally load-shed
    # every execution attempt: (t_start, t_end, node, aux)
    attempts: List[Tuple[float, float, int, int]] = field(
        default_factory=list)
    # routing child instants: (kind name, t, node)
    children: List[Tuple[str, float, int]] = field(
        default_factory=list)

    @property
    def response(self) -> float:
        return (self.completion - self.arrival
                if self.completion >= 0 else float("nan"))

    @property
    def n_attempts(self) -> int:
        return len(self.attempts)


def assemble_spans(events: Dict[str, np.ndarray]) -> Dict[int, Span]:
    """Reassemble one cell's columnar event stream into per-rid spans.

    The stream must be in event order (the rail's flush order). The
    returned dict is keyed by request id; requests that never complete
    (shed, retry-exhausted) keep ``completion == -1``."""
    spans: Dict[int, Span] = {}
    kind = events["kind"]
    rid = events["rid"]
    fn = events["fn"]
    node = events["node"]
    aux = events["aux"]
    t = events["t"]
    dt = events["dt"]
    for i in range(len(kind)):
        k, r = int(kind[i]), int(rid[i])
        if r < 0:
            continue
        if k == TraceKind.ARRIVAL:
            sp = spans.get(r)
            if sp is None:
                spans[r] = sp = Span(rid=r, fn=int(fn[i]),
                                     arrival=float(t[i]))
            if aux[i] & AUX_QUEUED:
                sp.queued = True
            if aux[i] & AUX_COLD:
                sp.cold = True
            if aux[i] & AUX_SHED:
                sp.shed = True
        elif k == TraceKind.EXEC:
            sp = spans.get(r)
            if sp is None:
                # stream window cut the arrival off: synthesise
                spans[r] = sp = Span(rid=r, fn=int(fn[i]),
                                     arrival=float(t[i] - dt[i]))
            a = int(aux[i])
            sp.attempts.append((float(t[i] - dt[i]), float(t[i]),
                                int(node[i]), a))
            if not a & (AUX_FAIL_RETRY | AUX_FAIL_EXHAUSTED):
                sp.completion = float(t[i])
                sp.start = float(t[i] - dt[i])
                sp.node = int(node[i])
        elif k in (TraceKind.RETRY, TraceKind.NODE_ARRIVAL,
                   TraceKind.REROUTE, TraceKind.TIMER):
            sp = spans.get(r)
            if sp is not None:
                sp.children.append((TraceKind.NAMES[k], float(t[i]),
                                    int(node[i])))
                if aux[i] & AUX_QUEUED:
                    sp.queued = True
                if aux[i] & AUX_COLD:
                    sp.cold = True
                if aux[i] & AUX_SHED:
                    sp.shed = True
    return spans


class TraceRun:
    """Per-grid-cell event streams of one traced experiment run.

    ``coords`` are the owning `ResultSet`'s labeled axes; ``cells``
    maps coordinate-index tuples (same axis order) to columnar event
    dicts. Selection mirrors `ResultSet.value`: every axis must
    resolve to exactly one entry (axes of length one resolve
    implicitly)."""

    def __init__(self, coords: Dict[str, list],
                 cells: Optional[Dict[tuple, dict]] = None):
        self.coords = {k: list(v) for k, v in coords.items()}
        self.cells: Dict[tuple, dict] = dict(cells or {})

    @property
    def dims(self) -> Tuple[str, ...]:
        return tuple(self.coords)

    def add_cell(self, key: tuple, events: dict) -> None:
        self.cells[tuple(key)] = events

    def _cell_key(self, **sel) -> tuple:
        unknown = set(sel) - set(self.coords)
        if unknown:
            raise KeyError(f"TraceRun: unknown dim(s) "
                           f"{sorted(unknown)}; dims are {self.dims}")
        key = []
        for d, values in self.coords.items():
            if d in sel:
                want = sel[d]
                matches = [i for i, v in enumerate(values)
                           if v == want or (
                               isinstance(v, float)
                               and isinstance(want, (int, float))
                               and float(v) == float(want))]
                if len(matches) != 1:
                    raise KeyError(
                        f"TraceRun: {d}={want!r} matches "
                        f"{len(matches)} of {values}")
                key.append(matches[0])
            elif len(values) == 1:
                key.append(0)
            else:
                raise KeyError(
                    f"TraceRun: dim {d!r} has {len(values)} entries "
                    f"{values} — select one")
        return tuple(key)

    def events(self, **sel) -> Dict[str, np.ndarray]:
        """The selected cell's columnar event arrays."""
        key = self._cell_key(**sel)
        try:
            return self.cells[key]
        except KeyError:
            raise KeyError(
                f"TraceRun: cell {key} was not computed "
                f"({len(self.cells)} cells held)") from None

    def spans(self, **sel) -> Dict[int, Span]:
        return assemble_spans(self.events(**sel))

    @property
    def n_events(self) -> int:
        return sum(len(ev["kind"]) for ev in self.cells.values())

    # -------------------------------------------------------- npz io
    def save_npz(self, path) -> None:
        """Columnar npz export: one array per (cell, field), plus a
        json index of coords and cell keys."""
        import json
        payload = {}
        keys = sorted(self.cells)
        for ci, key in enumerate(keys):
            for f in _FIELDS:
                payload[f"c{ci}_{f}"] = self.cells[key][f]
        header = dict(coords=self.coords,
                      keys=[list(k) for k in keys])
        payload["index_json"] = np.frombuffer(
            json.dumps(header).encode(), np.uint8)
        np.savez_compressed(path, **payload)

    @staticmethod
    def load_npz(path) -> "TraceRun":
        import json
        with np.load(path) as z:
            header = json.loads(bytes(z["index_json"]).decode())
            cells = {}
            for ci, key in enumerate(header["keys"]):
                cells[tuple(key)] = {f: z[f"c{ci}_{f}"]
                                     for f in _FIELDS}
        return TraceRun(header["coords"], cells)

    def __repr__(self):
        axes = ", ".join(f"{d}={len(v)}"
                         for d, v in self.coords.items())
        return (f"TraceRun({axes}; {len(self.cells)} cells, "
                f"{self.n_events} events)")
