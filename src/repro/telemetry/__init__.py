"""repro.telemetry — in-loop event tracing, streaming metrics and
profiling hooks.

Layers (all opt-in; disabled tracing lowers onto the unchanged event
loops bitwise — see docs/observability.md):

- :mod:`repro.telemetry.rail` — the in-loop trace rail: record
  layout, host sink, ``collect()`` scope, ordered-callback flush.
- :mod:`repro.telemetry.spans` — per-request span reassembly and the
  per-cell :class:`TraceRun` container attached to ``ResultSet``.
- :mod:`repro.telemetry.perfetto` — Chrome/Perfetto ``trace_event``
  JSON export and schema validation.
- :mod:`repro.telemetry.metrics` — per-bin per-node time series
  (queue depth, warm occupancy, utilization, SLO attainment,
  goodput) with CSV and Prometheus exporters.
- :mod:`repro.telemetry.profiling` — compile/run split, AOT phase
  breakdown, run-provenance metadata.
"""
from repro.telemetry.rail import (TraceKind, TraceSink, collect,
                                  merge_events)
from repro.telemetry.spans import Span, TraceRun, assemble_spans
from repro.telemetry.perfetto import (events_to_trace, save_trace,
                                      validate_trace)
from repro.telemetry.metrics import (events_summary, timeline,
                                     timeline_to_csv, to_prometheus)
from repro.telemetry.profiling import (PhaseTimer, compile_run_split,
                                       jit_phase_breakdown,
                                       provenance, spec_hash)

__all__ = [
    "TraceKind", "TraceSink", "collect", "merge_events",
    "Span", "TraceRun", "assemble_spans",
    "events_to_trace", "save_trace", "validate_trace",
    "events_summary", "timeline", "timeline_to_csv", "to_prometheus",
    "PhaseTimer", "compile_run_split", "jit_phase_breakdown",
    "provenance", "spec_hash",
]
