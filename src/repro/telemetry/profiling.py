"""Profiling hooks: compile/run split, jit phase breakdown, and
run-provenance metadata.

These ride the registries that already exist — ``audit_jits()`` on
the engines for the jit inventory, ``jit_cache_sizes()`` on the
runners for cache state — and add wall-clock attribution so every
benchmark row records *where* time went (trace / lower / compile /
device execution) and *what* produced it (spec hash, backend,
chunking)."""
from __future__ import annotations

import hashlib
import json
import time
from contextlib import contextmanager
from typing import Callable, Dict, Optional

import jax


def spec_hash(spec) -> str:
    """Stable short hash of an ExperimentSpec's semantic content."""
    try:
        payload = spec.meta
    except Exception:
        payload = {k: v for k, v in vars(spec).items()
                   if not k.startswith("_")}
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def provenance(spec=None, **extra) -> Dict[str, object]:
    """Run-provenance dict folded into BENCH rows and result meta."""
    from repro.api.runner import jit_cache_sizes
    dev = jax.devices()[0]
    out: Dict[str, object] = dict(
        backend=dev.platform,
        device=getattr(dev, "device_kind", str(dev)),
        n_devices=jax.device_count(),
        jax_version=jax.__version__,
        x64=bool(jax.config.jax_enable_x64),
        jit_cache_sizes=jit_cache_sizes(),
    )
    if spec is not None:
        out["spec_hash"] = spec_hash(spec)
        out["lane_chunk"] = getattr(spec, "lane_chunk", None)
        out["trace_events"] = bool(getattr(spec, "trace_events",
                                           False))
    out.update(extra)
    return out


def compile_run_split(fn: Callable, *args, repeats: int = 3,
                      **kwargs):
    """Wall-clock compile vs steady-state split of a jitted call.

    First call = compile + one run; best of ``repeats`` warm calls =
    run. Returns ``(compile_s, run_s, result)`` where ``compile_s``
    is the first-call wall time minus the warm time (floored at 0)."""
    t0 = time.perf_counter()
    res = jax.block_until_ready(fn(*args, **kwargs))
    cold = time.perf_counter() - t0
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        best = min(best, time.perf_counter() - t0)
    return max(cold - best, 0.0), best, res


def jit_phase_breakdown(jitted, *args, **kwargs) -> Dict[str, float]:
    """Per-phase wall clock of one jitted function via AOT stages:
    abstract tracing, StableHLO lowering, backend compile, and one
    device execution. Keys: ``trace_s, lower_s, compile_s, run_s``."""
    t0 = time.perf_counter()
    traced = jitted.trace(*args, **kwargs)
    t1 = time.perf_counter()
    lowered = traced.lower()
    t2 = time.perf_counter()
    compiled = lowered.compile()
    t3 = time.perf_counter()
    jax.block_until_ready(compiled(*args, **kwargs))
    t4 = time.perf_counter()
    return dict(trace_s=t1 - t0, lower_s=t2 - t1, compile_s=t3 - t2,
                run_s=t4 - t3)


class PhaseTimer:
    """Named wall-clock phase accumulator.

    >>> pt = PhaseTimer()
    >>> with pt.phase("lower"):
    ...     do_work()
    >>> pt.report()  # {'lower': 0.12}
    """

    def __init__(self):
        self.acc: Dict[str, float] = {}

    @contextmanager
    def phase(self, name: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.acc[name] = (self.acc.get(name, 0.0)
                              + time.perf_counter() - t0)

    def report(self, ndigits: Optional[int] = 6) -> Dict[str, float]:
        if ndigits is None:
            return dict(self.acc)
        return {k: round(v, ndigits) for k, v in self.acc.items()}
