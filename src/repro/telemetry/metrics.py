"""Streaming time-series metrics derived from trace-event streams.

Generalises the engines' coarse ``tl_bins`` occupancy counters: from
one cell's event stream, :func:`timeline` computes per-bin per-node
queue depth, warm-instance occupancy, utilization, throughput,
goodput and rolling SLO attainment — all host-side, after the jitted
run, so the event loops stay untouched. Exporters cover CSV and the
Prometheus text exposition format (both dependency-free).

The rail's ``qlen`` / ``warm`` / ``busy`` snapshots are the *event
node's own* post-event counters (the single-node tier is the K=1
special case), so per-node series are exact forward-fills of each
node's last observation and the global series are their sums.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.telemetry.rail import (AUX_FAIL_EXHAUSTED, AUX_FAIL_RETRY,
                                  AUX_SHED, TraceKind)


def _last_per_bin(bix: np.ndarray, val: np.ndarray,
                  nbins: int) -> np.ndarray:
    """Last observed ``val`` per bin, forward-filled across empty
    bins (NaN before the first observation)."""
    out = np.full(nbins, np.nan)
    if len(bix):
        out[bix] = val  # later events overwrite: last wins
    for i in range(1, nbins):
        if np.isnan(out[i]):
            out[i] = out[i - 1]
    return out


def timeline(events: Dict[str, np.ndarray], *, bucket: float = 1.0,
             n_nodes: Optional[int] = None,
             capacity: Optional[int] = None,
             deadlines=None,
             t_end: Optional[float] = None) -> Dict[str, np.ndarray]:
    """Per-bin time series from one columnar event stream.

    Returns a dict of arrays — ``t`` (left bin edges, shape (B,)),
    global ``queue_total`` / ``warm`` / ``busy`` (B,), per-node
    ``queue_depth`` / ``arrivals`` / ``busy_time`` /
    ``utilization`` (B, K), plus ``throughput`` / ``goodput`` (req/s,
    (B,)) and ``slo_attainment`` / ``slo_rolling`` ((B,), NaN where
    no completions; requires ``deadlines`` per function id)."""
    kind = np.asarray(events["kind"])
    # the single-node tier records node -1: everything is node 0
    node = np.maximum(np.asarray(events["node"]), 0)
    t = np.asarray(events["t"], np.float64)
    dt = np.asarray(events["dt"], np.float64)
    aux = np.asarray(events["aux"])
    K = int(n_nodes if n_nodes is not None
            else (node.max() + 1 if len(node) else 1))
    hi = float(t_end if t_end is not None
               else (t.max() if len(t) else bucket))
    B = max(1, int(np.ceil(hi / bucket + 1e-9)))
    edges = np.arange(B) * bucket
    bix = np.minimum((t / bucket).astype(np.int64), B - 1)

    out: Dict[str, np.ndarray] = {"t": edges}

    # qlen/warm/busy snapshots are per-node: forward-fill each node's
    # own observations (0 before its first event), sum for the global
    def per_node(field):
        col = np.zeros((B, K))
        for k in range(K):
            m = node == k
            col[:, k] = np.nan_to_num(
                _last_per_bin(bix[m], np.asarray(events[field])[m], B))
        return col

    depth = per_node("qlen")
    out["queue_depth"] = depth
    out["queue_total"] = depth.sum(axis=1)
    out["warm"] = per_node("warm").sum(axis=1)
    out["busy"] = per_node("busy").sum(axis=1)

    arr = np.zeros((B, K))
    m = (kind == TraceKind.ARRIVAL) & (node >= 0) & (node < K)
    np.add.at(arr, (bix[m], node[m]), 1.0)
    out["arrivals"] = arr

    # utilization: EXEC slices clipped onto bins, per node
    busy_time = np.zeros((B, K))
    ex = np.flatnonzero(kind == TraceKind.EXEC)
    for i in ex:
        k = int(node[i])
        if not 0 <= k < K:
            continue
        lo, hicl = float(t[i] - dt[i]), float(t[i])
        b0 = min(max(int(lo / bucket), 0), B - 1)
        b1 = min(max(int(hicl / bucket - 1e-12), 0), B - 1)
        for b in range(b0, b1 + 1):
            busy_time[b, k] += (min(hicl, (b + 1) * bucket)
                                - max(lo, b * bucket))
    out["busy_time"] = busy_time
    cap = float(capacity) if capacity else 1.0
    out["utilization"] = busy_time / (bucket * cap)

    ok = (kind == TraceKind.EXEC) & (
        (aux & (AUX_FAIL_RETRY | AUX_FAIL_EXHAUSTED)) == 0)
    thr = np.zeros(B)
    np.add.at(thr, bix[ok], 1.0)
    out["throughput"] = thr / bucket

    # SLO attainment / goodput need per-rid arrival times
    rid = np.asarray(events["rid"])
    fn = np.asarray(events["fn"])
    arr_t: Dict[int, float] = {}
    am = kind == TraceKind.ARRIVAL
    for i in np.flatnonzero(am):
        arr_t.setdefault(int(rid[i]), float(t[i]))
    met = np.zeros(B)
    tot = np.zeros(B)
    good = np.zeros(B)
    if deadlines is not None:
        dl = np.asarray(deadlines, np.float64)
        for i in np.flatnonzero(ok):
            a = arr_t.get(int(rid[i]))
            if a is None:
                continue
            f = int(fn[i])
            d = float(dl[f]) if dl.ndim else float(dl)
            b = bix[i]
            tot[b] += 1
            if t[i] - a <= d:
                met[b] += 1
                good[b] += 1
    out["goodput"] = good / bucket
    with np.errstate(invalid="ignore", divide="ignore"):
        out["slo_attainment"] = np.where(tot > 0, met / tot, np.nan)
        ctot, cmet = np.cumsum(tot), np.cumsum(met)
        out["slo_rolling"] = np.where(ctot > 0, cmet / ctot, np.nan)
    return out


def timeline_to_csv(tl: Dict[str, np.ndarray], path) -> None:
    """Wide CSV: one row per bin; per-node columns suffixed ``_k<i>``."""
    cols, names = [], []
    for name, a in tl.items():
        a = np.asarray(a)
        if a.ndim == 1:
            names.append(name)
            cols.append(a)
        else:
            for k in range(a.shape[1]):
                names.append(f"{name}_k{k}")
                cols.append(a[:, k])
    with open(path, "w") as fh:
        fh.write(",".join(names) + "\n")
        for row in zip(*cols):
            fh.write(",".join(f"{v:.9g}" for v in row) + "\n")


def events_summary(events: Dict[str, np.ndarray]) -> Dict[str, int]:
    """Terminal counters of one event stream (Prometheus source)."""
    kind = np.asarray(events["kind"])
    aux = np.asarray(events["aux"])
    ok = (kind == TraceKind.EXEC) & (
        (aux & (AUX_FAIL_RETRY | AUX_FAIL_EXHAUSTED)) == 0)
    return dict(
        arrivals=int((kind == TraceKind.ARRIVAL).sum()),
        completions=int(ok.sum()),
        executions=int((kind == TraceKind.EXEC).sum()),
        cold_starts=int((kind == TraceKind.COLD).sum()),
        retries=int((kind == TraceKind.RETRY).sum()),
        reroutes=int((kind == TraceKind.REROUTE).sum()),
        shed=int(((kind == TraceKind.ARRIVAL)
                  & ((aux & AUX_SHED) != 0)).sum()),
    )


def to_prometheus(events: Dict[str, np.ndarray], *,
                  tl: Optional[Dict[str, np.ndarray]] = None,
                  prefix: str = "repro",
                  labels: Optional[Dict[str, str]] = None) -> str:
    """Prometheus text exposition (version 0.0.4) of one stream.

    Event totals become counters; when a :func:`timeline` dict is
    given, its final-bin values become per-node gauges."""
    lab = "".join(f'{k}="{v}",' for k, v in (labels or {}).items())
    base = f"{{{lab[:-1]}}}" if lab else ""
    lines = []

    def counter(name, val, extra=""):
        full = f"{prefix}_{name}"
        lines.append(f"# TYPE {full} counter")
        tag = (f"{{{lab}{extra}}}" if extra
               else base) if (lab or extra) else ""
        lines.append(f"{full}{tag} {val}")

    def gauge(name, val, extra=""):
        full = f"{prefix}_{name}"
        lines.append(f"# TYPE {full} gauge")
        if lab or extra:
            tag = f"{{{lab}{extra}}}".replace(",}", "}")
        else:
            tag = ""
        lines.append(f"{full}{tag} {val:.9g}")

    for name, val in events_summary(events).items():
        counter(f"{name}_total", val)
    if tl is not None:
        depth = np.asarray(tl["queue_depth"])
        for k in range(depth.shape[1]):
            gauge("queue_depth", float(depth[-1, k]),
                  extra=f'node="{k}"')
        for g in ("warm", "busy"):
            v = float(np.asarray(tl[g])[-1])
            if not np.isnan(v):
                gauge(f"{g}_instances", v)
        sr = np.asarray(tl["slo_rolling"])
        if len(sr) and not np.isnan(sr[-1]):
            gauge("slo_attainment", float(sr[-1]))
    return "\n".join(lines) + "\n"
