"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The single-pod production mesh is a 16x16
(256-chip, one v5e pod) (data, model) grid; the multi-pod mesh adds an
outer "pod" axis (2 pods = 512 chips) used as an extra data-parallel
dimension whose gradient all-reduce crosses the inter-pod DCI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model = max(1, min(model, n))
    return jax.make_mesh((n // model, model), ("data", "model"))
