"""Training driver: checkpoint/restart fault tolerance, host-mesh or
production-mesh execution, synthetic data pipeline.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --smoke \
        --steps 100 --ckpt-every 20 --out runs/demo

Fault tolerance: resumes from the latest valid checkpoint (crash-consistent
atomic saves, crc-verified); ``--fail-at N`` injects a crash at step N to
exercise the path (the integration test restarts and checks loss
continuity). Elastic: restore onto a different mesh with
``--model-parallel`` changed — shardings are recomputed and the
checkpoint is resharded at load.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_arch
from repro.distributed.sharding import ShardingRules, Sharder, \
    logical_to_pspec
from repro.launch.mesh import make_host_mesh
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.train import TrainConfig, make_train_step, synthetic_lm_batches
from repro.train.train_step import init_optimizer
from repro.utils import get_logger

log = get_logger("train")


def train(arch: str, *, smoke: bool = True, steps: int = 100,
          global_batch: int = 8, seq_len: int = 128, lr: float = 3e-4,
          microbatches: int = 1, ckpt_every: int = 0, out: str = "",
          model_parallel: int = 1, fail_at: int = -1, seed: int = 0,
          log_every: int = 10):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build_model(cfg)

    mesh = make_host_mesh(model=model_parallel) \
        if len(jax.devices()) > 1 else None
    if mesh is not None:
        rules = ShardingRules.for_config(cfg, mesh, "train")
        sharder = Sharder(mesh, rules)
    else:
        sharder = None

    tcfg = TrainConfig(microbatches=microbatches,
                       optimizer=AdamWConfig(lr=lr))
    step_fn = jax.jit(make_train_step(model, tcfg,
                                      sharder or (lambda x, a: x)),
                      donate_argnums=(0, 1))

    params, _ = model.init(jax.random.key(seed))
    opt_state = init_optimizer(tcfg, params)
    start = 0

    ckpt = Checkpointer(out) if out else None
    if ckpt and latest_step(out) is not None:
        target = {"params": params, "opt": opt_state}
        restored, s = ckpt.restore(target)
        params, opt_state = restored["params"], restored["opt"]
        start = s + 1
        log.info("resumed from step %d", s)

    losses = []
    t0 = time.perf_counter()
    data = synthetic_lm_batches(cfg, global_batch, seq_len,
                                steps, seed=seed)
    for step, batch in enumerate(data):
        if step < start:
            continue
        if step == fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % log_every == 0:
            log.info("step %4d loss %.4f gnorm %.3f (%.2f s/step)",
                     step, loss, float(metrics["grad_norm"]),
                     (time.perf_counter() - t0) / max(len(losses), 1))
        if ckpt and ckpt_every and step and step % ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state},
                      blocking=False)
    if ckpt:
        ckpt.save(steps - 1, {"params": params, "opt": opt_state},
                  blocking=True)
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--fail-at", type=int, default=-1)
    args = ap.parse_args()
    _, losses = train(args.arch, smoke=args.smoke, steps=args.steps,
                      global_batch=args.global_batch,
                      seq_len=args.seq_len, lr=args.lr,
                      microbatches=args.microbatches,
                      ckpt_every=args.ckpt_every, out=args.out,
                      model_parallel=args.model_parallel,
                      fail_at=args.fail_at)
    log.info("final loss %.4f (first %.4f)", losses[-1], losses[0])


if __name__ == "__main__":
    main()
