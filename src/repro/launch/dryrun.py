"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, lowers the right step
function with production shardings from ShapeDtypeStructs (no
allocation), compiles it, and records memory_analysis / cost_analysis /
collective bytes for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
    python -m repro.launch.dryrun --arch internlm2-20b --shape train_4k
    python -m repro.launch.dryrun --all --multi-pod both --out results/
"""
# The placeholder-device flag MUST precede any jax import (device count
# locks on first backend init). Do not move; do not set globally.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_arch, shape_cells       # noqa: E402
from repro.distributed.sharding import (ShardingRules, Sharder,  # noqa: E402
                                        logical_to_pspec)
from repro.launch.mesh import make_production_mesh             # noqa: E402
from repro.models import build_model                           # noqa: E402
from repro.optim import AdamWConfig, adamw_init, opt_state_axes  # noqa: E402
from repro.roofline import analyze                             # noqa: E402
from repro.train import TrainConfig, make_train_step           # noqa: E402
from repro.utils import get_logger                             # noqa: E402

log = get_logger("dryrun")

# Per-arch training knobs (microbatch count chosen so the per-device
# microbatch is >=1 on both meshes; optimizer memory options so the big
# configs fit 16 GB/chip — see EXPERIMENTS.md §Dry-run).
TRAIN_KNOBS = {
    "deepseek-v3-671b": dict(microbatches=8, moment_dtype="bfloat16",
                             quantize_nu=True, fsdp=True,
                             accum_dtype="bfloat16"),
    "internvl2-76b": dict(microbatches=8, moment_dtype="bfloat16",
                          quantize_nu=True, fsdp=True,
                          accum_dtype="bfloat16"),
    "internlm2-20b": dict(microbatches=4, fsdp=True),
    "qwen3-14b": dict(microbatches=4, fsdp=True),
    "deepseek-moe-16b": dict(microbatches=2, fsdp=True),
    "zamba2-2.7b": dict(microbatches=2),
    "qwen1.5-4b": dict(microbatches=2, fsdp=True),
    "qwen3-4b": dict(microbatches=2),
    "mamba2-780m": dict(microbatches=8),
    "whisper-tiny": dict(microbatches=1),
}

# Serving-side knobs: the two biggest archs need params 2D-sharded even
# for inference (params/16 > HBM); everything else keeps pure TP.
SERVE_KNOBS = {
    "deepseek-v3-671b": dict(fsdp=True),
    "internvl2-76b": dict(fsdp=True),
}


def input_specs(cfg, shape_cfg):
    """ShapeDtypeStruct stand-ins for every model input of a cell."""
    B, S = shape_cfg.global_batch, shape_cfg.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if shape_cfg.kind in ("train",):
        toks = S
        out = {"tokens": jax.ShapeDtypeStruct((B, toks), i32),
               "labels": jax.ShapeDtypeStruct((B, toks), i32)}
        if cfg.family == "vlm":
            out["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.n_patches),
                                                 i32)
            out["labels"] = jax.ShapeDtypeStruct((B, S), i32)
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), f32)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_enc_positions, cfg.d_model), f32)
        return out
    if shape_cfg.kind == "prefill":
        out = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.family == "vlm":
            out["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.n_patches),
                                                 i32)
            out["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), f32)
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (B, cfg.n_enc_positions, cfg.d_model), f32)
        return out
    # decode: one new token against a seq_len cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def _mesh_and_rules(cfg, shape_cfg, multi_pod: bool, fsdp: bool = False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = ShardingRules.for_config(cfg, mesh, shape_cfg.kind, fsdp=fsdp)
    dp = rules.rules.get("batch")
    dp_size = 1
    if dp:
        for a in (dp if isinstance(dp, tuple) else (dp,)):
            dp_size *= mesh.shape[a]
    if shape_cfg.global_batch % max(dp_size, 1) != 0:
        # long_500k (batch 1): replicate batch over the data axes
        rules = ShardingRules(dict(rules.rules, batch=None),
                              name=rules.name + "/batch-replicated")
    return mesh, rules


def _shardings(mesh, rules, axes_tree):
    specs = logical_to_pspec(axes_tree, rules)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape: str, multi_pod: bool,
               compile_: bool = True, extra_knobs=None):
    cfg = get_arch(arch)
    shape_cfg = SHAPES[shape]
    if shape == "long_500k":
        # sub-quadratic archs only; hybrid uses its sliding window
        window = cfg.long_context_window if cfg.family == "hybrid" else None
    else:
        window = None
    knobs0 = dict(TRAIN_KNOBS.get(arch, {})) if shape_cfg.kind == "train" \
        else dict(SERVE_KNOBS.get(arch, {}))
    knobs0.update(extra_knobs or {})
    fsdp = knobs0.pop("fsdp", False)
    mesh, rules = _mesh_and_rules(cfg, shape_cfg, multi_pod, fsdp=fsdp)
    sharder = Sharder(mesh, rules)
    model = build_model(cfg)

    abstract_params = jax.eval_shape(lambda k: model.init(k)[0],
                                     jax.random.key(0))
    # spec tree (eager side-channel of init)
    _, param_axes = model.init_abstract()
    param_sh = _shardings(mesh, rules, param_axes)
    batch_specs = input_specs(cfg, shape_cfg)
    batch_sh = {k: NamedSharding(mesh, rules.resolve(
        ("batch",) + (None,) * (v.ndim - 1)))
        for k, v in batch_specs.items()}

    t0 = time.perf_counter()
    if shape_cfg.kind == "train":
        knobs = knobs0
        mb = knobs.pop("microbatches", 1)
        adt = knobs.pop("accum_dtype", "float32")
        opt = AdamWConfig(**{k: v for k, v in knobs.items()
                             if k in AdamWConfig.__dataclass_fields__})
        tcfg = TrainConfig(microbatches=mb, optimizer=opt,
                           accum_dtype=adt)
        step = make_train_step(model, tcfg, sharder)
        abstract_opt = jax.eval_shape(
            lambda p: adamw_init(opt, p), abstract_params)
        opt_axes = opt_state_axes(opt, param_axes)
        opt_sh = _shardings(mesh, rules, opt_axes)
        fn = jax.jit(step,
                     in_shardings=(param_sh, opt_sh, batch_sh),
                     donate_argnums=(0, 1))
        lowered = fn.lower(abstract_params, abstract_opt, batch_specs)
    elif shape_cfg.kind == "prefill":
        cspec = model.cache_spec(shape_cfg.global_batch, shape_cfg.seq_len,
                                 window)
        cache_sds = cspec.shape_dtype_structs()
        cache_sh = {k: NamedSharding(mesh, rules.resolve(cspec.axes[k]))
                    for k in cspec.shapes}
        cache_sh["length"] = NamedSharding(mesh, P())

        def prefill(params, batch, cache):
            return model.prefill(params, batch, cache, sharder)

        fn = jax.jit(prefill,
                     in_shardings=(param_sh, batch_sh, cache_sh),
                     donate_argnums=(2,))
        lowered = fn.lower(abstract_params, batch_specs, cache_sds)
    else:  # decode
        cspec = model.cache_spec(shape_cfg.global_batch, shape_cfg.seq_len,
                                 window)
        cache_sds = cspec.shape_dtype_structs()
        cache_sh = {k: NamedSharding(mesh, rules.resolve(cspec.axes[k]))
                    for k in cspec.shapes}
        cache_sh["length"] = NamedSharding(mesh, P())

        def decode(params, tokens, cache):
            return model.decode_step(params, tokens, cache, sharder)

        fn = jax.jit(decode,
                     in_shardings=(param_sh, batch_sh["tokens"], cache_sh),
                     donate_argnums=(2,))
        lowered = fn.lower(abstract_params, batch_specs["tokens"],
                           cache_sds)
    t_lower = time.perf_counter() - t0

    result = dict(arch=arch, shape=shape,
                  mesh="pod2x16x16" if multi_pod else "pod16x16",
                  chips=512 if multi_pod else 256,
                  rules=rules.name, lower_s=round(t_lower, 1))
    if not compile_:
        return result, lowered, None

    t0 = time.perf_counter()
    compiled = lowered.compile()
    result["compile_s"] = round(time.perf_counter() - t0, 1)

    mem = compiled.memory_analysis()
    mem_stats = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            mem_stats[attr] = int(v)
    result["memory"] = mem_stats
    cost = compiled.cost_analysis()
    result["cost"] = {k: float(v) for k, v in cost.items()
                      if isinstance(v, (int, float))
                      and k in ("flops", "bytes accessed")}
    hlo = compiled.as_text()
    rep = analyze(arch, shape, result["mesh"], result["chips"],
                  cost, hlo, cfg, shape_cfg, mem_stats)
    result["roofline"] = rep.to_json()
    return result, lowered, compiled


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--out", default="results")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    results_file = outdir / "dryrun.jsonl"
    done = set()
    if args.skip_existing and results_file.exists():
        for line in results_file.read_text().splitlines():
            try:
                r = json.loads(line)
                if "error" not in r:
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    cells = shape_cells(args.arch) if (args.all or not args.shape) \
        else [(args.arch, args.shape)]
    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.multi_pod]

    n_fail = 0
    with results_file.open("a") as f:
        for arch, shape in cells:
            for mp in pods:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                if (arch, shape, mesh_name) in done:
                    log.info("skip %s %s %s (done)", arch, shape, mesh_name)
                    continue
                log.info("=== %s x %s on %s", arch, shape, mesh_name)
                try:
                    res, _, compiled = lower_cell(arch, shape, mp)
                    log.info("  ok: lower %.1fs compile %.1fs "
                             "temp/dev %.2f GB args/dev %.2f GB",
                             res["lower_s"], res["compile_s"],
                             res["memory"].get("temp_size_in_bytes", 0)
                             / 2**30,
                             res["memory"].get("argument_size_in_bytes", 0)
                             / 2**30)
                    del compiled
                except Exception as e:           # noqa: BLE001
                    n_fail += 1
                    res = dict(arch=arch, shape=shape, mesh=mesh_name,
                               error=f"{type(e).__name__}: {e}",
                               tb=traceback.format_exc()[-2000:])
                    log.error("  FAIL %s", res["error"])
                f.write(json.dumps(res) + "\n")
                f.flush()
    log.info("done, %d failures", n_fail)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
