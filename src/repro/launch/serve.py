"""Serving driver: ESFF-scheduled multi-model edge serving.

    PYTHONPATH=src python -m repro.launch.serve --policy esff \
        --capacity 2 --requests 50

Deploys a catalogue of small models as serverless functions and serves a
request stream with the selected scheduling policy; cold starts and
execution times are real JAX measurements (see serving/engine.py).
"""
from __future__ import annotations

import argparse
import json

from repro.models.config import ModelConfig
from repro.serving import EdgeServingEngine, ServedFunction
from repro.utils import get_logger

log = get_logger("serve")


def default_catalogue():
    def tiny(name, layers, d, ff_mult=2, family="dense", **kw):
        base = dict(name=name, family=family, n_layers=layers, d_model=d,
                    n_heads=4, n_kv_heads=2, head_dim=max(d // 4, 16),
                    d_ff=d * ff_mult, vocab_size=512,
                    param_dtype="float32", compute_dtype="float32",
                    attn_chunk=32)
        base.update(kw)
        return ModelConfig(**base)

    return [
        ServedFunction(0, tiny("edge-chat-s", 2, 64), prompt_len=16,
                       gen_tokens=4),
        ServedFunction(1, tiny("edge-chat-m", 4, 128), prompt_len=16,
                       gen_tokens=8),
        ServedFunction(2, tiny("edge-summarize", 2, 128), prompt_len=32,
                       gen_tokens=2),
        ServedFunction(3, tiny("edge-classify", 2, 64), prompt_len=16,
                       gen_tokens=1),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="esff")
    ap.add_argument("--capacity", type=int, default=2)
    ap.add_argument("--requests", type=int, default=50)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--straggler-factor", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    eng = EdgeServingEngine(default_catalogue(), capacity=args.capacity,
                            policy=args.policy,
                            straggler_factor=args.straggler_factor,
                            seed=args.seed)
    reqs = eng.make_requests(args.requests, args.duration, seed=args.seed)
    res = eng.run(reqs)
    print(json.dumps(res.summary(), indent=2))


if __name__ == "__main__":
    main()
