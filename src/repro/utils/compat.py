"""Version-drift shims for the pinned JAX build.

This container pins jax 0.4.37, which sits on the wrong side of two
API moves the model/distributed subsystems were written against:

* ``jax.shard_map`` — promoted to the top-level namespace (with the
  ``check_rep`` kwarg renamed ``check_vma``) only in later releases;
  0.4.37 still exposes it as ``jax.experimental.shard_map.shard_map``.
* ``Compiled.cost_analysis()`` — returns a single properties dict in
  later releases; 0.4.x returns a one-element list of dicts.

Import the shims from here instead of sprinkling try/except at call
sites; each forwards to the native API when it exists so nothing
changes on newer JAX.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the 0.4.x experimental fallback.

    ``check_vma`` maps onto the old spelling ``check_rep`` when the
    fallback is taken (same semantics: disable the replication/varying
    -axes output check).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)


def compiled_cost_analysis(compiled) -> dict:
    """Properties dict of ``jax.stages.Compiled.cost_analysis()`` on
    both sides of the list-of-dicts -> dict return-type change."""
    props = compiled.cost_analysis()
    if isinstance(props, (list, tuple)):
        props = props[0] if props else {}
    return props
