from repro.utils.registry import Registry
from repro.utils.logging_ import get_logger

__all__ = ["Registry", "get_logger"]
