"""Tiny string -> factory registry used for policies, archs and kernels."""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator


class Registry:
    """A named mapping from string keys to factories.

    Used for scheduler policies (``POLICIES``), architecture configs
    (``ARCHS``) and benchmark tables so CLIs can select them by name.
    """

    def __init__(self, name: str):
        self.name = name
        self._items: Dict[str, Any] = {}

    def register(self, key: str, obj: Any = None) -> Callable[[Any], Any]:
        if obj is not None:
            self._register(key, obj)
            return obj

        def deco(fn: Any) -> Any:
            self._register(key, fn)
            return fn

        return deco

    def _register(self, key: str, obj: Any) -> None:
        if key in self._items:
            raise KeyError(f"{self.name}: duplicate key {key!r}")
        self._items[key] = obj

    def __getitem__(self, key: str) -> Any:
        try:
            return self._items[key]
        except KeyError:
            raise KeyError(
                f"{self.name}: unknown key {key!r}. "
                f"Available: {sorted(self._items)}"
            ) from None

    def __contains__(self, key: str) -> bool:
        return key in self._items

    def __iter__(self) -> Iterator[str]:
        return iter(sorted(self._items))

    def keys(self):
        return sorted(self._items)

    def items(self):
        return sorted(self._items.items())
