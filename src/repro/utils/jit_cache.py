"""JAX persistent compilation cache switch.

Lives here — not in `repro.core.jax_engine`, whose import flips the
global x64 flag — so f32 callers (kernel microbenches, model tests) can
enable caching without inheriting the engine's dtype world.

Scope it deliberately: this JAX build miscompiles *deserialized*
executables for donated-buffer training steps (resuming training from
a cache hit yields garbage parameters — see tests/conftest.py), so
only enable it for workloads whose executables are known to round-trip
(the scheduling engine's are re-verified against the Python engine by
``benchmarks/run.py --smoke`` on every cached run).
"""
from __future__ import annotations

import os
from typing import Optional

import jax


def enable_compilation_cache(path: Optional[str] = None) -> None:
    """Turn on JAX's persistent compilation cache.

    The scheduling engine jit-specialises per (kernel, capacity,
    queue_cap, ...) tuple and each specialisation costs seconds of XLA
    compile time; tests and benchmarks re-pay it every process start.
    Caching compiled executables on disk makes repeat runs start hot.
    Safe to call more than once; a no-op if this JAX build lacks the
    knobs.
    """
    if path is None:
        path = os.environ.get(
            "JAX_COMPILATION_CACHE_DIR",
            os.path.join(os.path.expanduser("~"), ".cache",
                         "repro_jax_cache"))
    try:
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs",
                          0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes",
                          -1)
    except Exception:   # pragma: no cover - older JAX without the knobs
        pass


def disable_compilation_cache() -> None:
    """Turn the persistent cache back off (see module docstring).

    Clearing the config alone is not enough once the cache object has
    been lazily initialized — later compiles keep hitting it — so the
    initialized cache is reset too."""
    try:
        jax.config.update("jax_compilation_cache_dir", None)
        from jax.experimental.compilation_cache import (
            compilation_cache as cc)
        cc.reset_cache()
    except Exception:   # pragma: no cover
        pass
