"""Synthetic LM data pipeline.

Deterministic, seekable (step -> batch), host-parallel friendly: every
process materialises only its addressable shard. Used by the training
examples and the end-to-end driver; real-data loaders would slot in
behind the same iterator protocol.
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np


def synthetic_lm_batch(cfg, global_batch: int, seq_len: int, step: int,
                       seed: int = 0) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic token stream (not uniform noise: has learnable
    bigram structure so training loss meaningfully decreases)."""
    rng = np.random.default_rng(seed + step * 9973)
    V = cfg.vocab_size
    # latent bigram table (fixed by seed, not step)
    trng = np.random.default_rng(seed)
    hot = trng.integers(0, V, size=256)
    toks = np.empty((global_batch, seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, V, global_batch)
    noise = rng.random((global_batch, seq_len))
    rnd = rng.integers(0, V, (global_batch, seq_len))
    for t in range(seq_len):
        follow = hot[toks[:, t] % 256]
        toks[:, t + 1] = np.where(noise[:, t] < 0.7, follow, rnd[:, t])
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}
    if cfg.family == "vlm":
        n = cfg.n_patches
        batch["tokens"] = batch["tokens"][:, :seq_len - n]
        batch["patch_embeds"] = rng.standard_normal(
            (global_batch, n, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        batch["frames"] = rng.standard_normal(
            (global_batch, cfg.n_enc_positions, cfg.d_model)
        ).astype(np.float32)
    return batch


def synthetic_lm_batches(cfg, global_batch: int, seq_len: int,
                         steps: int, seed: int = 0
                         ) -> Iterator[Dict[str, np.ndarray]]:
    for s in range(steps):
        yield synthetic_lm_batch(cfg, global_batch, seq_len, s, seed)
