"""Training step factory: loss + grad + AdamW, with optional microbatch
gradient accumulation (``lax.scan`` over microbatches — activation memory
is bounded by one microbatch) and int8 gradient compression across the
data axes (error feedback kept in the optimizer state is NOT needed
because quantisation happens before the *reduction*, see
distributed/compression.py)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.compression import compress_grads_int8
from repro.optim import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    optimizer: AdamWConfig = AdamWConfig()
    compress_grads: bool = False
    # gradient accumulator dtype: fp32 default; bf16 halves the resident
    # accumulator for the 100B+ configs (mean-of-microbatches keeps the
    # bf16 error bounded; see tests/test_train.py)
    accum_dtype: str = "float32"


def make_train_step(model, tcfg: TrainConfig,
                    sharder=None) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics). ``batch`` leaves have leading
    global-batch dim; with microbatching it is reshaped to
    (microbatches, mb, ...) and accumulated under lax.scan."""
    sharder = sharder or (lambda x, ax: x)

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb, sharder)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        n_mb = tcfg.microbatches
        if n_mb > 1:
            batch_r = jax.tree.map(
                lambda x: x.reshape((n_mb, x.shape[0] // n_mb)
                                    + x.shape[1:]), batch)

            adt = jnp.dtype(tcfg.accum_dtype)

            def mb_step(carry, mb):
                acc, metr = carry
                (loss, m), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(adt), acc, g)
                metr = jax.tree.map(jnp.add, metr,
                                    {"loss": loss, **m})
                return (acc, metr), None

            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, adt), params)
            zeros_m = {"loss": jnp.zeros((), jnp.float32),
                       "ce": jnp.zeros((), jnp.float32),
                       "aux": jnp.zeros((), jnp.float32)}
            (grads, metrics), _ = lax.scan(mb_step, (zeros_g, zeros_m),
                                           batch_r)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            metrics = jax.tree.map(lambda m: m / n_mb, metrics)
        else:
            (loss, m), grads = grad_fn(params, batch)
            metrics = {"loss": loss, **m}

        if tcfg.compress_grads:
            grads = compress_grads_int8(grads)
        params, opt_state, om = adamw_update(
            tcfg.optimizer, params, grads, opt_state)
        metrics.update(om)
        return params, opt_state, metrics

    return train_step


def init_optimizer(tcfg: TrainConfig, params):
    return adamw_init(tcfg.optimizer, params)
