from repro.train.train_step import TrainConfig, make_train_step
from repro.train.data import synthetic_lm_batches

__all__ = ["TrainConfig", "make_train_step", "synthetic_lm_batches"]
