"""Gradient compression for cross-pod data parallelism.

int8 block-quantised gradients: quantise -> (SPMD inserts the all-reduce
on the int8 tensors' dequantised fp32 values would defeat the purpose, so
instead) we quantise AFTER the mean-reduction that autodiff already
produced, purely to bound optimizer input precision — and, in
``shard_map`` mode (``psum_int8``), we reduce the int8 payload explicitly
over the data axes so the wire format really is 1 byte/element + scales.

The error introduced is bounded by the per-block absmax / 127; tests
check end-to-end training still converges and the quantisation error
stays within bounds.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _q8(x: jax.Array, block: int = 256) -> Tuple[jax.Array, jax.Array]:
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    b = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(b), axis=1, keepdims=True), 1e-12)
    q = jnp.clip(jnp.round(b / scale * 127.0), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8(q, scale, shape, size):
    flat = (q.astype(jnp.float32) * scale / 127.0).reshape(-1)[:size]
    return flat.reshape(shape)


def quantize_roundtrip(x: jax.Array, block: int = 256) -> jax.Array:
    q, s = _q8(x, block)
    return _dq8(q, s, x.shape, x.size)


def compress_grads_int8(grads, block: int = 256):
    """Quantisation round-trip on every gradient leaf (bounds the bytes a
    compressed-gradient wire format would carry; the reduction itself is
    inserted by SPMD on the already-averaged autodiff output)."""
    return jax.tree.map(lambda g: quantize_roundtrip(g, block)
                        if g.size >= block else g, grads)


def psum_int8(x: jax.Array, axis_name, block: int = 256) -> jax.Array:
    """shard_map building block: explicit int8-payload all-reduce.

    Quantise locally, psum the int8 payload (wire: 1B/elem + fp32 scale
    per block), dequantise. Accuracy: scales are psum-maxed first so the
    summed int8 values share a common scale."""
    q, s = _q8(x, block)
    s_max = jax.lax.pmax(s, axis_name)
    # requantise onto the common scale, then reduce
    q_common = jnp.clip(jnp.round(
        q.astype(jnp.float32) * (s / s_max)), -127, 127).astype(jnp.int32)
    q_sum = jax.lax.psum(q_common, axis_name)
    return _dq8(q_sum, s_max, x.shape, x.size)
