"""Logical-axis -> mesh-axis sharding rules (DP / TP / EP / SP / pod).

The model layer annotates parameters and activations with *logical* axes
("embed", "heads", "ff", "vocab", "experts", "cache_seq", ...). This
module resolves them against the active mesh:

* ``data`` batch parallelism uses ``("pod", "data")`` so the pod axis is
  an outer data-parallel dimension (gradient all-reduce crosses pods once
  per step — the slow DCI link carries only gradient traffic).
* ``model`` carries TP (heads / ff / vocab), EP (experts) and the
  split-KV ``cache_seq`` axis for decoding.
* per-arch *attention mode*: head-sharded TP when head counts divide the
  model axis, sequence-parallel attention otherwise (qwen3-14b 40H,
  qwen1.5-4b 20H, whisper 6H are indivisible by 16).

``ShardingRules.for_config`` computes the right rule set per architecture
and shape kind; divisibility is checked explicitly so a bad mesh fails
fast with a readable error instead of an XLA partitioner crash.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]


@dataclass(frozen=True)
class ShardingRules:
    """Mapping from logical axis names to mesh axis names."""

    rules: Dict[str, Axis] = field(default_factory=dict)
    name: str = "default"

    def resolve(self, axes: Tuple) -> P:
        out = []
        for ax in axes:
            if ax is None:
                out.append(None)
            else:
                out.append(self.rules.get(ax, None))
        # PartitionSpec forbids repeated mesh axes; keep first occurrence.
        seen = set()
        clean = []
        for m in out:
            ms = m if isinstance(m, tuple) else (m,) if m else ()
            if any(x in seen for x in ms):
                clean.append(None)
            else:
                seen.update(ms)
                clean.append(m)
        return P(*clean)

    @staticmethod
    def for_config(cfg, mesh: Mesh, kind: str = "train",
                   fsdp: bool = False) -> "ShardingRules":
        """Build rules for an architecture on a mesh.

        kind: train | prefill | decode — decode adds the split-KV
        ``cache_seq`` -> model mapping and drops sequence sharding.

        fsdp=True additionally shards the parameters' ``embed``/``lora``
        dimensions over the data axes (ZeRO-3: SPMD all-gathers each
        layer's weights at use and reduce-scatters its gradients).
        Required to fit deepseek-v3-671b / internvl2-76b: 671B bf16
        params alone are 1.34 TB — 16-way TP leaves 84 GB/chip vs the
        v5e's 16 GB. Activations are unaffected (the batch dim claims the
        data axes first; the resolver drops duplicate mesh axes).
        """
        axes = mesh.axis_names
        dp: Axis = tuple(a for a in ("pod", "data") if a in axes) or None
        tp = "model" if "model" in axes else None
        tp_size = mesh.shape["model"] if tp else 1
        dp_size = 1
        if dp:
            for a in (dp if isinstance(dp, tuple) else (dp,)):
                dp_size *= mesh.shape[a]

        def divisible(n: int) -> bool:
            return tp_size > 1 and n % tp_size == 0

        heads_ok = cfg.n_heads > 0 and divisible(cfg.n_heads)
        kv_ok = cfg.n_kv_heads > 0 and divisible(cfg.n_kv_heads)
        fsdp_ok = fsdp and dp and cfg.d_model % dp_size == 0

        rules: Dict[str, Axis] = {
            "batch": dp,
            "embed": dp if fsdp_ok else None,
            # MLA latent dims (q_lora/kv_lora): FSDP-sharded so wq_b/wk_b
            # get (lora->data, heads->model) = full 2D sharding
            "lora": dp if fsdp_ok else None,
            "layers": None,
            "vocab": tp if divisible(cfg.padded_vocab) else None,
            "ff": tp if (cfg.d_ff and divisible(cfg.d_ff)) else None,
            "experts": tp if (cfg.n_experts and divisible(cfg.n_experts))
            else None,
            "moe_ff": None,
            "ssm_inner": tp if (cfg.ssm_state and divisible(cfg.d_inner))
            else None,
            "ssm_heads": tp if (cfg.ssm_state and divisible(cfg.ssm_heads))
            else None,
            "heads": tp if heads_ok else None,
            "kv_heads": tp if kv_ok else None,
            "head_dim": None,
        }

        if kind == "decode":
            # split-KV (context-parallel) decoding: shard the cache
            # sequence axis; scores/values reduce over cache_seq, which
            # SPMD lowers to the partial-softmax combine psum. q/k/v
            # projections keep head sharding only if divisible.
            rules["cache_seq"] = tp
            rules["seq_q"] = None
            rules["seq_kv"] = None
            # long_500k: global_batch may be smaller than the dp axes;
            # handled by caller overriding "batch".
        else:
            # Archs whose head count does not divide the model axis
            # (qwen3-14b 40H, qwen1.5-4b 20H, whisper 6H): attention
            # cannot be head-sharded. The §Perf-optimised path shards the
            # QUERY SEQUENCE instead (seq_parallel_attention in
            # models/layers.py) whenever seq % TP == 0; whisper's 1500
            # encoder positions fall back to replicated compute.
            rules["seq_q"] = None
            rules["seq_kv"] = None
            rules["cache_seq"] = tp
            if not heads_ok and cfg.n_heads > 0:
                rules["_seq_attn"] = True
        mode = "heads" if rules.get("heads") else "replicated-attn"
        return ShardingRules(rules, name=f"{cfg.name}/{kind}/{mode}")


class Sharder:
    """Callable threaded through the model: applies
    ``with_sharding_constraint`` when a mesh is active, else identity."""

    def __init__(self, mesh: Optional[Mesh], rules: ShardingRules):
        self.mesh = mesh
        self.rules = rules

    def __call__(self, x, axes: Tuple):
        if self.mesh is None:
            return x
        spec = self.rules.resolve(axes)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def spec(self, axes: Tuple) -> P:
        return self.rules.resolve(axes)

    def named(self, axes: Tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self.rules.resolve(axes))


def make_sharder(mesh: Optional[Mesh], cfg, kind: str) -> Sharder:
    if mesh is None:
        return Sharder(None, ShardingRules({}))
    return Sharder(mesh, ShardingRules.for_config(cfg, mesh, kind))


def logical_to_pspec(tree_axes, rules: ShardingRules):
    """Map a tree of logical-axis tuples to PartitionSpecs."""
    def is_axes(x):
        return isinstance(x, tuple) and all(
            isinstance(a, (str, tuple, type(None))) for a in x)
    return jax.tree.map(rules.resolve, tree_axes, is_leaf=is_axes)


def param_shardings(mesh: Mesh, tree_axes, rules: ShardingRules):
    specs = logical_to_pspec(tree_axes, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
