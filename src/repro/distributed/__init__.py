from repro.distributed.sharding import (ShardingRules, Sharder,
                                        logical_to_pspec, make_sharder,
                                        param_shardings)

__all__ = ["ShardingRules", "Sharder", "logical_to_pspec", "make_sharder",
           "param_shardings"]
