"""Event loop driving a scheduling policy over a request trace.

``simulate(trace, policy, capacity)`` is the single entry point used by
tests, benchmarks and the serving engine's shadow mode.
"""
from __future__ import annotations

import time as _time
from typing import Optional, Union

from repro.core.events import EventKind, EventQueue
from repro.core.metrics import SimResult, collect
from repro.core.policy import POLICIES, Policy
from repro.core.request import Trace
from repro.core.server import EdgeServer, ExecTimeEstimator


def simulate(trace: Trace, policy: Union[str, Policy], capacity: int,
             *, oracle_exec: bool = False, exec_prior: float = 0.1,
             max_events: Optional[int] = None) -> SimResult:
    """Run ``policy`` over ``trace`` on a C-slot edge server.

    oracle_exec=True gives the scheduler the true per-function mean
    execution times (used for validation); the default estimates them
    online from completions, as the paper's ESFF does.
    """
    if isinstance(policy, str):
        policy = POLICIES[policy]()
    events = EventQueue()
    server = EdgeServer(trace.functions, capacity, events)
    oracle = ([f.true_mean_exec for f in trace.functions]
              if oracle_exec else None)
    est = ExecTimeEstimator(trace.n_functions, prior=exec_prior,
                            oracle=oracle)
    policy.bind(server, est)

    for r in trace.requests:
        r.start = -1.0
        r.completion = -1.0
        events.push(r.arrival, EventKind.ARRIVAL, r)

    t0 = _time.perf_counter()
    n_events = 0
    while True:
        ev = events.pop()
        if ev is None:
            break
        n_events += 1
        if max_events is not None and n_events > max_events:
            raise RuntimeError(f"event budget exceeded ({max_events})")
        if ev.kind == EventKind.ARRIVAL:
            policy.on_arrival(ev.payload, ev.time)
        elif ev.kind == EventKind.EXEC_DONE:
            inst = ev.payload
            req = inst.current
            est.observe(req.fn_id, req.exec_time)   # history update first
            policy.on_exec_done(inst, req, ev.time)
        elif ev.kind == EventKind.COLD_DONE:
            policy.on_cold_done(ev.payload, ev.time)
        elif ev.kind == EventKind.TIMER:
            policy.on_timer(ev.payload, ev.time)
    wall = _time.perf_counter() - t0

    return collect(policy.name, capacity, trace.requests, server.stats,
                   wall, dict(trace.meta, n_events=n_events))
