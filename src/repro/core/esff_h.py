"""ESFF-H — beyond-paper scheduler (EXPERIMENTS.md §Perf, scheduling side).

Three measured pathologies of literal ESFF are fixed (each validated in
EXPERIMENTS.md §Repro; β=1 + the flags off recover exact ESFF):

1. **Lateral ping-pong** (dense-queue regimes): FRP converts slots
   between two hot functions whose queues coexist; each round trip costs
   t_v + t_l' + t_v' + t_l (~4-5 s) while serving milliseconds of work.
   Fix: a *hysteresis factor* ``beta`` > 1 on the conversion setup cost
   in the candidate weight, so a steal must beat the incumbent by the
   amortised round-trip cost, not half of it.

2. **Double provisioning**: Eq. (6)/(7) ignore instances already warming
   up (state COLD) — for long functions the drain term ``window*K/t_e``
   is ~0, so a second instance starts although one is seconds from
   ready. Fix: each in-flight instance claims one waiting request in the
   drain estimate (``n_e -= K_cold``).

3. **Warm-pool blindness** (abundant-capacity regimes): FCP's victim
   rule (Eq. 8, argmax t̄_e) repeatedly evicts the hottest long
   functions' idle instances; at capacity 32 the LRU-keep-alive
   baselines beat literal ESFF by 1.6x on warm hits alone. Fix: among
   Eq. 8's eligible candidates, evict the LEAST-RECENTLY-USED instead
   (``lru_victim``). With it, ESFF-H beats every baseline at every
   capacity 8-32 (benchmarks/fig5).

Everything else — weights, FCP/FRP structure, per-function queues — is
inherited from the faithful ESFF implementation.
"""
from __future__ import annotations

from repro.core.esff import ESFF
from repro.core.policy import POLICIES
from repro.core.server import InstanceState


@POLICIES.register("esff_h")
class ESFFH(ESFF):
    name = "esff_h"
    beta = 2.0          # hysteresis on conversion setup cost
    lru_victim = True   # Eq. 8 victim: LRU among eligible (vs argmax t_e)

    def _cold_count(self, fn_id: int) -> int:
        srv = self.server
        return sum(1 for i in srv.by_fn[fn_id]
                   if srv.instances[i].state == InstanceState.COLD)

    def _drain_estimate(self, fn_id: int, window: float) -> float:
        base = super()._drain_estimate(fn_id, window)
        return base - self._cold_count(fn_id)

    def _weight_candidate(self, fn_id: int, n_e: float) -> float:
        f = self.functions[fn_id]
        k = self.server.k_count(fn_id)
        return (self.est.mean(fn_id)
                + self.beta * (f.cold_start + f.evict) * (k + 1) / n_e)

    def on_arrival(self, req, t):
        if not self.lru_victim:
            return super().on_arrival(req, t)
        fn = req.fn_id
        srv = self.server
        idle = srv.idle_of(fn)
        if not self.queues[fn] and idle is not None:
            srv.dispatch(idle, req, t)
            return
        if srv.has_free_slot():
            n_e = self._drain_estimate(fn, self.functions[fn].cold_start)
            if n_e > 0:
                srv.start_cold(fn, t)
        else:
            best, best_lru = None, None
            for inst in srv.idle_instances():
                if inst.fn_id == fn:
                    continue
                window = (self.functions[fn].cold_start
                          + self.functions[inst.fn_id].evict)
                if self._drain_estimate(fn, window) > 0:
                    if best is None or inst.last_used < best_lru:
                        best, best_lru = inst, inst.last_used
            if best is not None:
                srv.start_cold(fn, t, evict=best)
        self.queues[fn].append(req)
