"""Baseline schedulers of paper §VI-A.

* **OpenWhisk** — central FIFO queue in arrival order; scales up an
  instance when an arriving request finds no idle instance (evicting the
  least-recently-used idle instance when at capacity).
* **SFF** — identical to OpenWhisk except the central queue is ordered by
  the function's *running-mean execution time* (shortest function first).
* **FaasCache** [Fuerst & Sharma, ASPLOS'21] — OpenWhisk-style scheduling
  with GREEDY-DUAL keep-alive: eviction victim = idle instance with the
  lowest priority ``clock + freq * cold_start``; the global clock is bumped
  to the evicted priority.
* **OpenWhisk V2** — per-function queues; a new instance is initialised
  only after the queue-head request has waited longer than a fixed
  threshold (100 ms).

All four reuse the slot primitives of :class:`~repro.core.server.EdgeServer`
so their cold-start / eviction accounting is identical to ESFF's.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, Optional, Tuple

from repro.core.events import EventKind
from repro.core.policy import POLICIES, Policy
from repro.core.request import Request
from repro.core.server import Instance, InstanceState


class CentralQueuePolicy(Policy):
    """Shared machinery for OpenWhisk / SFF / FaasCache.

    The central queue is stored as one FIFO deque per function plus a
    global count; "head of queue" scans the per-function heads with the
    policy's ordering key (O(|F|), exact even when SFF's running means
    drift over time).
    """

    def bind(self, server, estimator) -> None:
        super().bind(server, estimator)
        self.fifo: Dict[int, Deque[Request]] = {
            f.fn_id: deque() for f in self.functions
        }
        self.waiting = 0

    # -- ordering ---------------------------------------------------------
    def _key(self, req: Request) -> Tuple:
        return (req.arrival, req.req_id)

    def _head(self) -> Optional[Request]:
        best, best_key = None, None
        for q in self.fifo.values():
            if q:
                k = self._key(q[0])
                if best_key is None or k < best_key:
                    best, best_key = q[0], k
        return best

    def _pop(self, req: Request) -> None:
        q = self.fifo[req.fn_id]
        assert q and q[0] is req
        q.popleft()
        self.waiting -= 1

    def _push(self, req: Request) -> None:
        self.fifo[req.fn_id].append(req)
        self.waiting += 1

    # -- eviction choice (overridden by FaasCache) -------------------------
    def _victim(self) -> Optional[Instance]:
        idle = self.server.idle_instances()
        if not idle:
            return None
        return min(idle, key=lambda i: (i.last_used, i.inst_id))  # LRU

    def _note_evict(self, inst: Instance) -> None:
        pass

    def _note_use(self, inst: Instance) -> None:
        pass

    def _evict_and_start(self, fn_id: int, t: float) -> bool:
        victim = self._victim()
        if victim is None:
            return False
        self._note_evict(victim)
        self.server.start_cold(fn_id, t, evict=victim)
        return True

    # -- hooks --------------------------------------------------------------
    def on_arrival(self, req: Request, t: float) -> None:
        srv = self.server
        idle = srv.idle_of(req.fn_id)
        if idle is not None:
            self._note_use(idle)
            srv.dispatch(idle, req, t)
            return
        self._push(req)
        # Scale up: no idle instance for this request.
        if srv.has_free_slot():
            srv.start_cold(req.fn_id, t)
        else:
            self._evict_and_start(req.fn_id, t)

    def on_cold_done(self, inst: Instance, t: float) -> None:
        # The instance was provisioned *for* its function's waiting
        # requests; serve the earliest of them before falling back to the
        # central-queue discipline.
        self.server.make_idle(inst)
        q = self.fifo[inst.fn_id]
        if q:
            req = q[0]
            self._pop(req)
            self._note_use(inst)
            self.server.dispatch(inst, req, t)
            return
        self._serve_or_replace(inst, t)

    def on_exec_done(self, inst: Instance, req: Request, t: float) -> None:
        self.server.make_idle(inst)
        self._serve_or_replace(inst, t)

    # Central-queue discipline: a warm instance first drains its own
    # function's earliest waiting request (container reuse — real
    # OpenWhisk behaviour, and exactly Fig. 1(a)/(b)'s schedule); only an
    # instance with no matching work retargets to the queue-head function
    # (evict + cold start), at most one warming replica at a time.
    # ``strict=True`` (the *_hol ablation policies) removes warm reuse:
    # the slot serves the global head or retargets — full head-of-line
    # blocking, which collapses under bursts (EXPERIMENTS.md §Repro).
    strict = False

    def _serve_or_replace(self, inst: Instance, t: float) -> None:
        srv = self.server
        head = self._head()
        if head is None:
            return
        if not self.strict and self.fifo[inst.fn_id]:
            head = self.fifo[inst.fn_id][0]     # first matching request
        if head.fn_id == inst.fn_id:
            self._pop(head)
            self._note_use(inst)
            srv.dispatch(inst, head, t)
            return
        # Retarget this idle slot to the head's function, capped at the
        # smaller of (one warming replica, its waiting count).
        warming = sum(
            1 for i in srv.by_fn[head.fn_id]
            if srv.instances[i].state == InstanceState.COLD
        )
        cap = len(self.fifo[head.fn_id]) if self.strict else 1
        if warming < cap:
            self._note_evict(inst)
            srv.start_cold(head.fn_id, t, evict=inst)


@POLICIES.register("openwhisk")
class OpenWhisk(CentralQueuePolicy):
    name = "openwhisk"


@POLICIES.register("sff")
class SFF(CentralQueuePolicy):
    """Shortest Function First: arrival order -> mean-execution-time order."""

    name = "sff"

    def _key(self, req: Request):
        return (self.est.mean(req.fn_id), req.arrival, req.req_id)


@POLICIES.register("faascache")
class FaasCache(CentralQueuePolicy):
    """GREEDY-DUAL keep-alive eviction (size=1, cost=cold start)."""

    name = "faascache"

    def bind(self, server, estimator) -> None:
        super().bind(server, estimator)
        self.clock = 0.0

    def _note_use(self, inst: Instance) -> None:
        inst.priority = (
            self.clock
            + (inst.freq + 1) * self.functions[inst.fn_id].cold_start
        )

    def _note_evict(self, inst: Instance) -> None:
        self.clock = max(self.clock, inst.priority)

    def _victim(self) -> Optional[Instance]:
        idle = self.server.idle_instances()
        if not idle:
            return None
        return min(idle, key=lambda i: (i.priority, i.inst_id))


@POLICIES.register("openwhisk_hol")
class OpenWhiskHOL(OpenWhisk):
    """Ablation: fully head-of-line-blocking OpenWhisk (no warm reuse of
    non-head requests) — the literal reading of 'processes requests in
    ascending arrival order'. Collapses under bursts; kept to quantify
    how much of ESFF's win is blocking-removal vs cold-start awareness."""

    name = "openwhisk_hol"
    strict = True


@POLICIES.register("faascache_hol")
class FaasCacheHOL(FaasCache):
    """Ablation: head-of-line FaasCache (see openwhisk_hol)."""

    name = "faascache_hol"
    strict = True


@POLICIES.register("openwhisk_v2")
class OpenWhiskV2(Policy):
    """Per-function queues + 100 ms head-wait threshold before scale-up."""

    name = "openwhisk_v2"
    threshold = 0.1  # seconds (paper: 100 ms)

    def bind(self, server, estimator) -> None:
        super().bind(server, estimator)
        self._init_fn_queues()

    def _arm(self, req: Request, t: float) -> None:
        self.server.events.push(t + self.threshold, EventKind.TIMER, req)

    def on_arrival(self, req: Request, t: float) -> None:
        srv = self.server
        idle = srv.idle_of(req.fn_id)
        if not self.queues[req.fn_id] and idle is not None:
            srv.dispatch(idle, req, t)
            return
        self.queues[req.fn_id].append(req)
        self._arm(req, t)

    def on_timer(self, req: Request, t: float) -> None:
        if req.start >= 0:   # already running / done
            return
        q = self.queues[req.fn_id]
        if not q or q[0] is not req:
            return           # no longer the head; its own timer will fire
        srv = self.server
        warming = any(
            srv.instances[i].state == InstanceState.COLD
            for i in srv.by_fn[req.fn_id]
        )
        if not warming:
            if srv.has_free_slot():
                srv.start_cold(req.fn_id, t)
            else:
                idle = srv.idle_instances()
                if idle:
                    victim = min(idle, key=lambda i: (i.last_used, i.inst_id))
                    srv.start_cold(req.fn_id, t, evict=victim)
                else:
                    self._arm(req, t)   # still blocked; retry
                    return
        else:
            self._arm(req, t)

    def on_cold_done(self, inst: Instance, t: float) -> None:
        self.server.make_idle(inst)
        q = self.queues[inst.fn_id]
        if q:
            self.server.dispatch(inst, q.popleft(), t)

    def on_exec_done(self, inst: Instance, req: Request, t: float) -> None:
        # V2 keeps draining its own queue (the behaviour Fig. 1(b) criticises).
        self.server.make_idle(inst)
        q = self.queues[inst.fn_id]
        if q:
            self.server.dispatch(inst, q.popleft(), t)
