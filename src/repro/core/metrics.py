"""Evaluation metrics (paper §VI-A): mean response time, mean slowdown,
cold-start accounting, CDFs/percentiles and per-minute timelines (Fig. 8).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

import numpy as np

from repro.core.request import Request
from repro.core.server import ServerStats


@dataclass
class SimResult:
    policy: str
    capacity: int
    responses: np.ndarray          # t^c - t^a per request
    slowdowns: np.ndarray          # response / exec
    exec_times: np.ndarray
    arrivals: np.ndarray
    server: ServerStats
    wall_seconds: float = 0.0
    meta: dict = field(default_factory=dict)

    # ------------------------------------------------------------ scalars
    @property
    def mean_response(self) -> float:
        return float(self.responses.mean())

    @property
    def mean_slowdown(self) -> float:
        return float(self.slowdowns.mean())

    @property
    def cold_starts(self) -> int:
        return self.server.cold_starts

    @property
    def cold_time_per_request(self) -> float:
        return self.server.cold_time / max(len(self.responses), 1)

    def percentile(self, q: float, what: str = "responses") -> float:
        return float(np.percentile(getattr(self, what), q))

    # ---------------------------------------------------------------- cdf
    def cdf(self, what: str = "responses", points: int = 200):
        x = np.sort(getattr(self, what))
        idx = np.linspace(0, len(x) - 1, points).astype(int)
        return x[idx], (idx + 1) / len(x)

    def timeline(self, bucket: float = 60.0) -> Dict[str, np.ndarray]:
        """Per-minute aggregates over arrival time (Fig. 8)."""
        b = (self.arrivals // bucket).astype(int)
        n = b.max() + 1 if len(b) else 0
        counts = np.bincount(b, minlength=n)
        resp = np.bincount(b, weights=self.responses, minlength=n)
        ex = np.bincount(b, weights=self.exec_times, minlength=n)
        safe = np.maximum(counts, 1)
        return dict(minute=np.arange(n), n_requests=counts,
                    mean_response=resp / safe, mean_exec=ex / safe)

    def summary(self) -> dict:
        return dict(
            policy=self.policy,
            capacity=self.capacity,
            n_requests=len(self.responses),
            mean_response=self.mean_response,
            mean_slowdown=self.mean_slowdown,
            p95_response=self.percentile(95),
            p99_response=self.percentile(99),
            cold_starts=self.server.cold_starts,
            cold_time=self.server.cold_time,
            evictions=self.server.evictions,
            cold_time_per_request=self.cold_time_per_request,
            wall_seconds=self.wall_seconds,
        )


def collect(policy: str, capacity: int, requests: List[Request],
            stats: ServerStats, wall: float, meta: dict) -> SimResult:
    done = [r for r in requests if r.done]
    if len(done) != len(requests):
        raise RuntimeError(
            f"{policy}: {len(requests) - len(done)} requests never completed"
        )
    return SimResult(
        policy=policy,
        capacity=capacity,
        responses=np.array([r.response for r in done]),
        slowdowns=np.array([r.slowdown for r in done]),
        exec_times=np.array([r.exec_time for r in done]),
        arrivals=np.array([r.arrival for r in done]),
        server=stats,
        wall_seconds=wall,
        meta=meta,
    )
