"""Request / function / trace data model (paper §III-A).

All times are float seconds. A :class:`Request` ``r_i`` carries its arrival
time ``t_i^a`` and (ground-truth) execution time ``t_i^e``; the scheduler
never reads ``exec_time`` directly — it sees it only once the request
completes (the simulator feeds completions back into the per-function
running-mean estimators, §V).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class FunctionProfile:
    """Static, platform-known properties of a serverless function f_j.

    ``cold_start`` is t_j^l and ``evict`` is t_j^v — both are platform
    properties (image pull + runtime init / teardown) and are known to the
    scheduler, matching the paper's setup where they are sampled once per
    function from U[0.5, 1.5] s.
    """

    fn_id: int
    cold_start: float
    evict: float
    # Ground-truth mean execution time; used only by trace generators and
    # by the oracle estimator mode, never by the online scheduler.
    true_mean_exec: float = 0.0
    name: str = ""

    def __post_init__(self) -> None:
        if not self.name:
            self.name = f"f{self.fn_id}"


@dataclass
class Request:
    """A single invocation r_i of function ``fn_id`` (= l_i)."""

    req_id: int
    fn_id: int
    arrival: float          # t_i^a
    exec_time: float        # t_i^e  (ground truth; hidden from scheduler)
    # Filled in by the simulator:
    start: float = -1.0     # t_i^s
    completion: float = -1.0  # t_i^c

    @property
    def response(self) -> float:
        """t_i^r = t_i^c - t_i^a (execution + waiting [+ cold start])."""
        return self.completion - self.arrival

    @property
    def slowdown(self) -> float:
        return self.response / max(self.exec_time, 1e-9)

    @property
    def done(self) -> bool:
        return self.completion >= 0.0


@dataclass
class Trace:
    """An ordered request stream plus the function catalogue."""

    functions: List[FunctionProfile]
    requests: List[Request]
    meta: dict = field(default_factory=dict)
    # memoized to_arrays() view (not part of the value: excluded from
    # comparison/repr)
    _arrays: Optional[dict] = field(default=None, repr=False,
                                    compare=False)

    def __post_init__(self) -> None:
        self.requests.sort(key=lambda r: (r.arrival, r.req_id))

    @property
    def n_functions(self) -> int:
        return len(self.functions)

    def __len__(self) -> int:
        return len(self.requests)

    def scaled(self, intensity_ratio: float) -> "Trace":
        """Scale inter-arrival intervals by ``intensity_ratio`` (paper Fig. 6).

        Ratio > 1 stretches intervals (lighter load); < 1 compresses them.
        Execution times are untouched.
        """
        reqs = [
            Request(r.req_id, r.fn_id, r.arrival * intensity_ratio, r.exec_time)
            for r in self.requests
        ]
        meta = dict(self.meta, intensity_ratio=intensity_ratio)
        return Trace(self.functions, reqs, meta)

    def head(self, n: int) -> "Trace":
        reqs = [Request(r.req_id, r.fn_id, r.arrival, r.exec_time)
                for r in self.requests[:n]]
        return Trace(self.functions, reqs, dict(self.meta, head=n))

    # ------------------------------------------------------------------ io
    def to_arrays(self):
        """Columnar view (used by the vectorized JAX simulator and npz io).

        Memoized: the exported columns (ids, arrivals, exec/cold/evict
        times) are immutable for a Trace's lifetime — the simulator
        only ever mutates per-request ``start``/``completion``, which
        are not part of the view — and re-walking 10^4+ Request objects
        per ``sweep`` call is pure-Python overhead the vectorised
        engine would otherwise pay on every repeat sweep."""
        if self._arrays is None:
            n = len(self.requests)
            fn = np.empty(n, np.int32)
            arr = np.empty(n, np.float64)
            ex = np.empty(n, np.float64)
            for i, r in enumerate(self.requests):
                fn[i], arr[i], ex[i] = r.fn_id, r.arrival, r.exec_time
            cold = np.array([f.cold_start for f in self.functions],
                            np.float64)
            evict = np.array([f.evict for f in self.functions],
                             np.float64)
            self._arrays = dict(fn_id=fn, arrival=arr, exec_time=ex,
                                cold_start=cold, evict=evict)
            for v in self._arrays.values():
                v.setflags(write=False)   # shared across calls
        return dict(self._arrays)

    @staticmethod
    def from_arrays(a: dict, meta: Optional[dict] = None) -> "Trace":
        funcs = [
            FunctionProfile(j, float(c), float(v))
            for j, (c, v) in enumerate(zip(a["cold_start"], a["evict"]))
        ]
        reqs = [
            Request(i, int(f), float(t), float(e))
            for i, (f, t, e) in enumerate(
                zip(a["fn_id"], a["arrival"], a["exec_time"]))
        ]
        return Trace(funcs, reqs, meta or {})

    def save_npz(self, path: str) -> None:
        np.savez_compressed(path, **self.to_arrays())

    @staticmethod
    def load_npz(path: str) -> "Trace":
        with np.load(path) as z:
            return Trace.from_arrays({k: z[k] for k in z.files},
                                     {"source": path})
