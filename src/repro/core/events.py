"""Discrete-event engine.

A single binary heap of ``(time, priority, seq)`` keys. Priorities order
simultaneous events so that capacity freed at time t is visible to an
arrival at the same t:

    EXEC_DONE < COLD_DONE < TIMER < NODE_ARRIVAL < REROUTE < CHURN
              < RETRY < ARRIVAL

``NODE_ARRIVAL`` is the deferred-delivery leg of a routed request
(dynamic cluster routing under per-node network delay: the router
decides at the raw ARRIVAL, the node sees the request ``delay`` later);
it sorts before raw ARRIVALs so an in-flight request reaches its node
before the router decides the next one at the same instant.
``REROUTE`` carries a request orphaned by a node failure back through
the router, and ``CHURN`` is a node availability toggle (NODE_DOWN /
NODE_UP, see docs/cluster.md); orphans re-route before any same-time
churn toggle or fresh arrival, and churn resolves before the router
sees a same-time arrival. ``RETRY`` re-injects a failed/timed-out
request after its backoff delay (see `repro.core.resilience`); it
resolves after churn (a same-time toggle settles availability first)
but before fresh arrivals (the retried request is older). ``seq``
breaks remaining ties FIFO, keeping runs fully deterministic.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Any, Optional


class EventKind(IntEnum):
    EXEC_DONE = 0     # an instance finished a request     -> FRP hook
    COLD_DONE = 1     # a (re)initialisation finished      -> instance ready
    TIMER = 2         # policy-armed timer (OpenWhisk V2 threshold)
    NODE_ARRIVAL = 3  # a routed request reaches its node  -> FCP hook
    REROUTE = 4       # an orphaned request re-enters the router
    CHURN = 5         # a node goes down / comes back up
    RETRY = 6         # a failed request re-enters after backoff
    ARRIVAL = 7       # a request arrives (router decides) -> FCP hook


@dataclass(order=True)
class Event:
    time: float
    kind: int
    seq: int
    payload: Any = field(compare=False, default=None)
    cancelled: bool = field(compare=False, default=False)


class EventQueue:
    def __init__(self) -> None:
        self._heap: list[Event] = []
        self._seq = itertools.count()

    def push(self, time: float, kind: EventKind, payload: Any = None) -> Event:
        ev = Event(time, int(kind), next(self._seq), payload)
        heapq.heappush(self._heap, ev)
        return ev

    def pop(self) -> Optional[Event]:
        while self._heap:
            ev = heapq.heappop(self._heap)
            if not ev.cancelled:
                return ev
        return None

    def __len__(self) -> int:
        return sum(1 for e in self._heap if not e.cancelled)

    def __bool__(self) -> bool:
        return any(not e.cancelled for e in self._heap)
