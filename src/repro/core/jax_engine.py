"""Policy-agnostic fixed-shape event core for vectorised scheduling.

The Python event engine (`repro.core.simulator`) replays ~10^4 req/s;
policy x capacity x trace sweeps need orders of magnitude more. This
module owns everything that is *policy independent* about simulating a
C-slot edge server in JAX — the state layout, the queue ops, the slot
primitives (`dispatch` / `start_cold`), the running-mean estimator and
the ``lax.while_loop`` event loop — while the *decisions* live in
pure-function policy kernels (`repro.core.jax_policies`). A kernel is
selected by a static argument, so ``jax.jit`` specialises the loop body
per policy, and the engine carries a leading *lane* dimension so a whole
policy x capacity x beta x trace grid runs as one device call (`sweep`).

State layout (static F functions, C slots, N requests, L lanes; all
arrays carry the leading L):

  slots:  slot_fn    (C,) i32  function resident in the slot (-1 empty)
          slot_state (C,) i32  {0 COLD (warming), 1 IDLE, 2 BUSY}
          slot_ready (C,) f64  next slot event time (cold-done for COLD,
                               exec-done for BUSY; BIG when IDLE/empty)
          slot_req   (C,) i32  request id being executed (BUSY only)
          slot_used  (C,) f64  last dispatch time (LRU bookkeeping;
                               0.0 for a never-used instance)
          slot_seq   (C,) i32  creation sequence number of the resident
                               instance — mirrors the Python engine's
                               monotonically increasing ``inst_id`` so
                               iteration-order tie-breaks (LRU, victim
                               scans) reproduce exactly
  queues: per-function FIFOs as *position cursors* into the trace's
          per-function arrival order. The requests of f_j, sorted by
          id, are a loop-invariant shared operand (``pos_rids`` +
          ``pos_off`` built from a stable argsort of fn_id), and
          because every arrival of f_j consumes exactly one position —
          q_push for a queued arrival, q_consume_direct for a directly
          dispatched one — and pops are FIFO, the queue of f_j is
          always the contiguous position range
          [q_head_pos, q_head_pos + q_len). Head/successor lookups are
          gathers into the shared operand; the carried queue state is
          just q_head_pos/q_len (F,) i32 plus a q_head_rid (F,) i32
          cache (refreshed with the successor at pop time, so head
          reads — including the central-queue head scan — touch no
          large operand) — O(F) no matter how long a backlog gets (SFF
          starvation can hold a request queued for the whole trace).
          ``queue_cap`` bounds the per-function
          backlog: a push onto a function with queue_cap waiting
          requests is dropped and counted in ``overflow`` (must stay 0
          for a valid run; a dropped request breaks the position
          invariant, which is fine — the run is already invalid).
  est:    est_sum (F,) f64 / est_n (F,) i32 — running means of observed
          execution times with global-mean, then `prior`, fallback (the
          global accumulators live in the packed counters)
  timers: original timers fire at arrival + threshold in arrival
          order, so the rail rides the same per-function positions:
          tmr_pos (F,) i32 is the next position whose timer fires,
          arr_cnt (F,) i32 counts arrived positions, tmr_next (F,) f64
          is the head fire time. Every arrival arms its position;
          arrivals that dispatch directly while the rail is idle are
          consumed silently, and one that slips into a busy rail fires
          later as a no-op (the is-head gate drops it, exactly like the
          Python policy drops timers of already-served requests).
          Re-arms (only ever the current queue head) keep the one-slot
          cache rearm_t (F,) f64 / rearm_rid (F,) i32. Allocated only
          when the kernel sets ``has_timers``.
  ctrs:   ci (NCI,) i32 / cf (NCF,) f64 — every per-lane scalar counter
          (arrival cursor, done/event counts, stall flag, instance
          sequence, estimator globals, cold/eviction/overflow tallies
          and the streaming response accumulators) packed into two
          arrays so the while_loop carries 2 small buffers instead of
          a dozen scalars.
  out:    always: streaming metric accumulators — response sum,
          slowdown sum, response max (in cf) and ``hist`` (HIST_BINS,)
          i32, a fixed log-spaced response-time histogram (8 bins per
          decade over 1e-4..1e4 s) that serves p99 and CDFs to within
          one bin width; optionally (``tl_bins > 0``) a minute-binned
          timeline (request count / response sum / exec sum per
          arrival-time bucket, the Fig. 8 fold). In *exact* mode
          (``stream=False``) additionally start/completion (N,) f64
          per-request records.

Event arbitration mirrors `repro.core.events`: at equal times
EXEC_DONE < COLD_DONE < TIMER < ARRIVAL, so capacity freed at time t is
visible to an arrival at the same t. ``cap_mask`` masks slots so
capacity is sweepable across lanes without retracing; ``stalled`` flags
lanes that ran out of events or iteration budget before every request
completed (overflowed requests can never finish).

Engine internals — window/slab layout
-------------------------------------

The event loop runs over the trace in *time-ordered windows* of ``W =
window`` requests (``DEFAULT_WINDOW`` unless overridden; traces are
arrival-sorted, so a contiguous id range *is* a time window). The loop
nest is::

    fori_loop over windows            # shared slab refresh per window
      while_loop over segments        # until every lane leaves the window
        fori_loop over SEG events     # lane-stacked pick + vmapped body
          segment flush               # exact mode: overlay scatter

Per window, the four gather-heavy shared operands — ``arrival`` /
``exec_time`` / ``fn_id`` (rid-indexed) and the positional queue layout
(position-indexed) — are ``dynamic_slice``'d into (T, W) *slabs* sized
to stay L2-resident (24 bytes/request: f64 times + two i32 ids), so
the random gathers of the inner loop stop thrashing the cache once N
outgrows it. Slabs are f64/i32 *copies*, so results are bitwise
independent of the window size; every read goes through a dual-source
bounds check (`EngineCtx._dual`): in-window indices hit the slab,
out-of-window indices (a queue entry or running request whose links
span a window boundary — the positional-cursor design makes this a
bounds check, not a re-link) fall back to the full operand, and the
disabled side of each pair reads a fixed cached location.

Windows are *global*: all lanes share one slab set (a per-lane window
would batch the slab operand and knock every gather off vmap's
unbatched-operand fast path). A lane whose next event is an arrival
beyond the current window **parks** — its arrival candidate keeps its
exact time (read from the full operand at the boundary element) so the
packed argmin still resolves event order exactly, but the consume is
gated off and the lane no-ops until the slowest lane finishes the
window. Parking preserves each lane's event order exactly: a lane only
parks when its true earliest pending event is the out-of-window
arrival. The per-lane window cursor is implicit in the arrival cursor
(``ci[CI_NEXT] // W``); ``n_events`` counts *processed events*, so it
is window-size invariant. The queue-successor gathers use a second,
window-major positional layout (stable argsort of (rid // W, fn)) with
per-window per-function offsets (``off_w`` / ``cum_cnt``) so in-window
position reads are slab-local.

f32 slab copies for the time reads were evaluated and rejected for the
default path: every consumer feeds either the event-time arbitration
or the f64 metric accumulators, and a float32 round (~1e-7 relative)
breaks the engine's bitwise gates (stream-vs-exact equality and
request-for-request parity with the Python engine). The indices
(``fn_id`` + positional layout, half the slab bytes) are i32 already.

Performance shape — the six rules the layout follows, measured on the
XLA CPU backend:

1. *No control flow inside the body.* Every handler runs every
   iteration gated by an ``on`` predicate, and all writes are guarded
   scatters — ``mode="drop"`` with an out-of-bounds sentinel index when
   disabled (`_gidx`). A ``lax.cond`` under vmap lowers to a `select`
   over every carried array, i.e. a dense copy of the whole state per
   event.
2. *Lanes live inside the loop.* One ``while_loop`` carries (L, ...)
   state and the branchless body is vmapped per lane; finished lanes
   no-op through their guards. Vmapping the ``while_loop`` itself would
   mask finished lanes with per-event dense selects over all state.
3. *No large carried array is both gathered and scattered in one loop
   body.* XLA's copy-insertion materialises a full copy of such a
   buffer every iteration — the dominant cost of a naive spelling.
   Queues therefore never carry their contents at all: successor
   lookups are gathers into loop-invariant shared operands (which XLA
   neither copies nor scatters), and the only per-event writes touch
   O(F)/O(C) cursor arrays. Exact-mode per-request records go through
   the small per-segment overlay (d_rid/d_start/d_comp),
   batch-scattered into the (L, N) arrays once per SEG-event segment.
4. *Carried state is independent of trace length, and metrics fold per
   event.* Each dispatch leaves its (rid, completion, exec) triple in
   three per-event registers (``ev_*`` — plain selects, no scatters)
   and `_fold_event` folds them into the O(1) streaming accumulators
   (sums, max, histogram, optional timeline bins) at the end of every
   event — in event order, which is what makes the streamed sums
   bitwise *window-size invariant* (any deferred batch fold regroups
   its reduction tree wherever a window boundary cuts a segment; PR 2's
   per-segment flush fold was also, measurably, the large-N
   bottleneck: its (L, SEG) gathers/scatters scaled with N and cost
   ~3x at N = 3e5). The (L, N) per-request records exist only in exact
   mode (``stream=False``). A streaming lane carries
   O(F + C + HIST_BINS) state no matter how long the trace, which is
   what lets one machine sweep 10^6-request traces at a flat
   ~190k req/s per lane (benchmarks/engine_scale.py). Both modes run
   the identical fold, so streamed means are bit-identical to
   exact-mode means.
5. *One packed reduction picks the next event.* The candidate times of
   every event source — BUSY slots, COLD slots, original timers,
   re-arms, the arrival cursor — are concatenated in priority order
   into one lane-stacked (L, 2C+2F+1) matrix and a single segmented
   first-index ``argmin`` over the candidate axis resolves, for every
   lane at once, both the time and the tie-break (position encodes
   EXEC < COLD < TIMER < ARRIVAL and the within-class index order).
   The pick lives *outside* the per-lane vmap so wide lane batches on
   GPU/TPU lower to one reduction kernel instead of L small ones;
   small scalar counters ride the two packed ci/cf arrays so XLA:CPU
   dispatches fewer ops per event.
6. *The hot loop reads cache-sized slabs.* Shared trace operands are
   re-sliced per window (see above) so gather working sets stay
   L2-resident at any N; lane batching is backend-adaptive
   (`LANE_CHUNKS` / ``REPRO_LANE_CHUNK`` / `resolve_lane_chunk`)
   because the XLA:CPU sweet spot (~16 lanes) underfills an
   accelerator by orders of magnitude.
"""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, Optional, Sequence, Union

# The engine's event loop is hundreds of tiny fused ops per simulated
# event; XLA:CPU's thunk runtime pays a dispatch overhead per op that
# slows the loop ~10x vs the legacy single-LLVM-function emitter. Ask
# for the legacy runtime before JAX initialises its CPU client (no-op
# for other backends, and respected only if the backend isn't live yet;
# callers can override by setting the flag themselves).
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_cpu_use_thunk_runtime" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_cpu_use_thunk_runtime=false").strip()

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax import lax             # noqa: E402

from repro.core.request import Trace  # noqa: E402
from repro.core.resilience import backoff_jax  # noqa: E402

BIG = 1e30
COLD, IDLE, BUSY = 0, 1, 2
I32_MAX = np.iinfo(np.int32).max
SEG = 32          # events per segment (deferred result-write window)

# Requests per trace window: the four slabs cost 24 bytes/request
# (2 x f64 + 2 x i32), so 524288 bounds the gather working set to
# ~12 MB — last-level-cache scale — however long the trace grows,
# while traces at or below it run the single-window fast path (no
# dual-source reads at all). ``window=`` overrides per call; results
# are bitwise identical at every setting, only locality changes.
DEFAULT_WINDOW = 524288

# Lanes per device call, by backend. XLA:CPU's per-lane efficiency is
# flat over ~8-48 lanes since the lane-stacked event pick landed, so
# the CPU entry is sized for *scheduling*: smaller chunks pack evenly
# onto the sweep's overlapping host threads (a 48-lane grid in chunks
# of 16 leaves one thread a straggler chunk; chunks of 8 balance).
# Accelerators amortise kernel launches over wide batches and the
# O(F+C) streaming carry fits thousands of lanes in HBM — table
# entries there are educated defaults pending real-hardware runs
# (ROADMAP). ``REPRO_LANE_CHUNK`` overrides with an integer or
# ``auto`` (two-point probe, see `resolve_lane_chunk`).
LANE_CHUNKS = {"cpu": 8, "gpu": 256, "tpu": 512}
_AUTO_CHUNK: Dict[str, int] = {}

# Packed per-lane counters: ci (NCI,) i32 and cf (NCF,) f64.
# CI_TERM..CI_TRIPS are the resilience tallies (requests terminal for
# any reason, injected failures, timeouts, retries, sheds, retry-budget
# exhaustions, circuit-breaker trips) — appended so the pre-resilience
# indices, and therefore every existing jaxpr, are unchanged; they stay
# zero unless the run declares a failure source.
(CI_NEXT, CI_DONE, CI_ITERS, CI_STALL, CI_SEQ, CI_GN, CI_COLD,
 CI_EVICT, CI_OVF, CI_TERM, CI_FAILED, CI_TMO, CI_RETRY, CI_SHED,
 CI_EXH, CI_TRIPS) = range(16)
NCI = 16
CF_GSUM, CF_COLDT, CF_EVICTT, CF_RSUM, CF_SSUM, CF_RMAX = range(6)
NCF = 6

# Streaming response histogram: log-spaced, 8 bins/decade over
# [1e-4, 1e4) seconds. Quantile reads are exact to one bin width
# (a factor of 10^(1/8) ~ 1.33x).
HIST_BINS = 64
HIST_LO = -4.0
HIST_PER_DECADE = 8


def ensure_x64() -> None:
    """Enable f64 before anything is traced.

    Event times need f64 for exact agreement with the Python engine over
    multi-hour traces. Flipping the flag mid-run (the old
    ``simulate_jax_from_trace`` behaviour) invalidates already-traced
    f32 jits elsewhere; importing this module instead performs the
    switch once, at import time, before the engine traces anything.
    """
    if not jax.config.jax_enable_x64:
        jax.config.update("jax_enable_x64", True)


ensure_x64()


# ---------------------------------------------------------- lane batching
def default_lane_chunk(backend: Optional[str] = None) -> int:
    """Table entry for the active (or given) JAX backend."""
    return LANE_CHUNKS.get(backend or jax.default_backend(),
                           LANE_CHUNKS["cpu"])


def resolve_lane_chunk(setting: Union[int, str, None] = None) -> int:
    """Resolve the lanes-per-device-call batch size.

    ``setting`` (or the ``REPRO_LANE_CHUNK`` environment variable when
    ``setting`` is None) may be an integer, ``"table"``/empty (use the
    per-backend `LANE_CHUNKS` entry) or ``"auto"`` — time a two-point
    probe (the table entry vs 4x it) on a small synthetic workload at
    the first sweep and keep whichever sustains more req/s. The probe
    result is cached per backend for the process lifetime.
    """
    if setting is None:
        setting = os.environ.get("REPRO_LANE_CHUNK", "")
    if isinstance(setting, str):
        setting = setting.strip().lower()
    if setting in ("", "table", None):
        return default_lane_chunk()
    if setting == "auto":
        return _probe_lane_chunk()
    return max(1, int(setting))


def _probe_lane_chunk(n_requests: int = 2048, n_functions: int = 24,
                      capacity: int = 8) -> int:
    """Two-point lane-batch probe: per-backend table entry vs 4x it.

    Runs the streaming engine (``sff`` — the cheapest kernel) over a
    small synthetic trace once per candidate (after a warm-up call per
    jit specialisation) and returns the candidate with the higher
    aggregate req/s. Cached per backend in ``_AUTO_CHUNK``.
    """
    backend = jax.default_backend()
    if backend in _AUTO_CHUNK:
        return _AUTO_CHUNK[backend]
    from repro.core.jax_policies import KERNELS
    from repro.traces.generator import synth_azure_arrays
    base = default_lane_chunk(backend)
    cands = (base, max(1, base * 4))
    a = synth_azure_arrays(n_functions=n_functions,
                           n_requests=n_requests, seed=0,
                           utilization=0.3)
    shared = tuple(jnp.asarray(a[k])[None]
                   for k in ("fn_id", "arrival", "exec_time",
                             "cold_start", "evict"))
    best, best_rate = base, -1.0
    for c in cands:
        args = shared + (jnp.zeros((c,), jnp.int32),
                         jnp.ones((c, capacity), bool),
                         jnp.ones((c,), jnp.float64),
                         jnp.float64(0.1), jnp.float64(0.1))
        kw = dict(kernel=KERNELS["sff"], n_fns=n_functions,
                  capacity=capacity, queue_cap=n_requests, stream=True)
        jax.block_until_ready(_sweep_metrics(*args, **kw))
        t0 = time.perf_counter()
        jax.block_until_ready(_sweep_metrics(*args, **kw))
        rate = c * n_requests / (time.perf_counter() - t0)
        if rate > best_rate:
            best, best_rate = c, rate
    _AUTO_CHUNK[backend] = best
    return best


class EngineCtx:
    """Per-lane view of the run handed to policy kernels.

    Bundles the (traced) trace arrays and window slabs, the (static)
    shape constants, the scalar knobs and the current segment step
    ``k``. Built inside the jitted entry point — it never crosses a jit
    boundary itself.

    Trace arrays are *shared* (T, ...) operands indexed by the lane's
    ``tix``: under vmap a gather whose operand is unbatched lowers to a
    single efficient gather, whereas a batched operand takes a generic
    path that is orders of magnitude slower on the CPU backend. The
    row-indexed (T, X) operands are additionally read through
    *flattened* views with a precomputed per-lane base offset
    (``tix * X + i``): a two-index-dim gather only hits XLA:CPU's fast
    path when the leading dim is size 1 (the simplifier drops the
    always-clamped index) — at T > 1 (multi-trace grids, the cluster
    static path's (T·K) sub-stream rows) it falls to the generic
    gather, measured ~25x slower per event. The per-request reads
    (`fn_at` / `arrival_at` / `exec_at`, and the positional queue
    reads `rid_at_pos`) are *dual-source*: indices inside the current
    window read the (T, W) L2-resident slab, the rest (queue links
    spanning a window boundary, long-running requests) fall back to
    the full operand — a bounds check plus two guarded gathers whose
    disabled side reads a fixed cached location, never a branch. Slabs
    hold exact f64/i32 copies, so which source serves a read can never
    change a result bit.
    """

    def __init__(self, *, fn_id2, arrival2, exec2, cold2, evict2,
                 pos_rids2, pos_off2, slabs, win_base, win_w, tix,
                 cap_mask, beta, prior, threshold, k, n, f, c, q,
                 stream=False, tl_bins=0, tl_bucket=60.0,
                 deadlines=None):
        flat = lambda a: (None if a is None          # noqa: E731
                          else a.reshape(-1))
        self._fn = flat(fn_id2)     # (T*N,) shared, flattened view
        self._arr = flat(arrival2)
        self._ex = flat(exec2)
        self._pos = flat(pos_rids2)  # rids by (fn, id)
        self._off = flat(pos_off2)   # per-fn offsets ((T*(F+1),))
        # current-window slabs: rid-indexed (T, W) copies + the
        # window-major positional slab and its per-fn (T, F) rows —
        # all flattened the same way
        (fn_s, arr_s, ex_s, pos_s, offw, cc_lo, cc_hi) = slabs
        self._fn_s, self._arr_s, self._ex_s = \
            flat(fn_s), flat(arr_s), flat(ex_s)
        self._pos_s = flat(pos_s)
        self._offw, self._cc_lo, self._cc_hi = \
            flat(offw), flat(cc_lo), flat(cc_hi)
        self.win_base = win_base   # first request id of the window
        self.W = win_w             # static window length
        self.single_win = win_w >= n   # static: slab == whole trace
        self.tix = tix             # this lane's trace index
        # per-lane flat base offsets into each operand family
        self._b_n = tix * n            # (T, N) rows
        self._b_w = tix * win_w        # (T, W) slabs
        self._b_f = tix * f            # (T, F) rows
        self._b_f1 = tix * (f + 1)     # (T, F+1) offsets
        self.t_cold = cold2        # (F,) — this lane's row, pre-gathered
        self.t_evict = evict2      # once outside the loops
        self.cap_mask = cap_mask
        self.beta = beta
        self.prior = prior
        self.threshold = threshold
        self.k = k                  # segment step (overlay slot)
        self.seg_n = SEG            # overlay length (drop sentinel)
        self.N, self.F, self.C, self.Q = n, f, c, q
        self.stream = stream        # static: drop per-request records
        self.tl_bins = tl_bins      # static: timeline fold bins (0=off)
        self.tl_bucket = tl_bucket
        self.deadlines = deadlines  # (F,) per-fn SLO deadlines or None
        # fold-site gates: the cluster's churn loop folds metrics at
        # EXEC_DONE (a drained request may be re-dispatched, so the
        # dispatch-time record would double-count) and writes exact-
        # mode per-request records directly per event (the d_* overlay
        # assumes one record per rid per segment)
        self.fold_at_dispatch = True
        self.direct_records = False
        # resilience gates: attempt counting at dispatch and deferring
        # the exact-mode completion record to the (successful)
        # EXEC_DONE — an exhausted request must keep completion == -1,
        # not its last attempt's dispatch-time completion
        self.has_resil = False
        self.defer_completion = False

    def _dual(self, full, slab, rid):
        """Windowed read of ``full[tix, rid]``: slab when ``rid`` is in
        the current window, full-operand fallback otherwise. The
        disabled source reads a fixed, hot location (slab 0 / the
        window base) so it costs no extra cache traffic. Single-window
        runs (W >= N — every trace at or under `DEFAULT_WINDOW`) skip
        the bounds check statically: the one window covers every id."""
        r = jnp.clip(jnp.asarray(rid, jnp.int32), 0, self.N - 1)
        if self.single_win:
            return full[self._b_n + r]
        off = r - self.win_base
        inw = (off >= 0) & (off < self.W)
        sv = slab[self._b_w + jnp.where(inw, off, 0)]
        fv = full[self._b_n + jnp.where(inw, self.win_base, r)]
        return jnp.where(inw, sv, fv)

    def fn_at(self, rid):
        return self._dual(self._fn, self._fn_s, rid)

    def arrival_at(self, rid):
        return self._dual(self._arr, self._arr_s, rid)

    def exec_at(self, rid):
        return self._dual(self._ex, self._ex_s, rid)

    def rid_at_pos(self, fn, pos):
        """Request id at arrival position ``pos`` of function ``fn``
        (garbage on out-of-range positions — callers gate).

        Positions are absolute (per-function arrival order over the
        whole trace); the bounds check against the window's per-fn
        position range [cc_lo, cc_hi) routes in-window positions to
        the window-major slab and cross-window links to the full
        (fn, id)-sorted layout. Single-window runs read the full
        layout directly (it is the slab)."""
        fc = jnp.clip(fn, 0, self.F - 1)
        if self.single_win:
            gi = self._off[self._b_f1 + fc] + pos
            return self._pos[self._b_n + jnp.clip(gi, 0, self.N - 1)]
        lo = self._cc_lo[self._b_f + fc]
        inw = (pos >= lo) & (pos < self._cc_hi[self._b_f + fc])
        si = self._offw[self._b_f + fc] + (pos - lo)
        sv = self._pos_s[self._b_w
                         + jnp.where(inw, jnp.clip(si, 0, self.W - 1),
                                     0)]
        gi = self._off[self._b_f1 + fc] + pos
        fv = self._pos[self._b_n
                       + jnp.where(inw, 0, jnp.clip(gi, 0, self.N - 1))]
        return jnp.where(inw, sv, fv)

    # ------------------------------------------------- overridable ops
    # The queue discipline and the estimator's fallback chain are ctx
    # *methods* so an alternative engine (the multi-node cluster loop,
    # `repro.cluster.engine`) can substitute its own carried layout —
    # linked-list per-(node, function) queues, per-node estimator
    # globals — while policy kernels keep calling the same module-level
    # helpers (`q_push`/`q_pop`/`q_head`/`q_consume_direct`/
    # `est_means`), which delegate here.

    def est_means(self, s):
        """Per-function running means with global-mean / prior
        fallback."""
        counts = s["est_n"].astype(jnp.float64)
        g_n = s["ci"][CI_GN]
        gcount = g_n.astype(jnp.float64)
        g = jnp.where(g_n > 0,
                      s["cf"][CF_GSUM] / jnp.maximum(gcount, 1),
                      self.prior)
        return jnp.where(s["est_n"] > 0,
                         s["est_sum"] / jnp.maximum(counts, 1), g)

    def q_head(self, s, fn):
        """Request id at the head of ``fn``'s queue (garbage when
        empty — callers gate on ``q_len``). Served from the carried
        q_head_rid cache so head reads — including the central-queue
        (F,) head scan — cost no gathers into the big positional
        operand."""
        return s["q_head_rid"][jnp.clip(fn, 0, self.F - 1)]

    def q_push(self, s, fn, rid, on):
        """Append ``rid``; returns (state, pushed). The pushed request
        is by construction the next arrival position of ``fn``, so only
        the length moves (plus the head cache when the queue was
        empty). A push onto a full backlog (q_len == queue_cap) is
        dropped and counted in overflow."""
        fc = jnp.clip(fn, 0, self.F - 1)
        was_empty = s["q_len"][fc] == 0
        full = s["q_len"][fc] >= self.Q
        do = on & ~full
        s = dict(s)
        s["q_head_rid"] = s["q_head_rid"].at[
            _gidx(do & was_empty, fn, self.F)].set(
            jnp.asarray(rid, jnp.int32), mode="drop")
        s["q_len"] = s["q_len"].at[_gidx(do, fn, self.F)].add(
            1, mode="drop")
        s["ci"] = s["ci"].at[CI_OVF].add((on & full).astype(jnp.int32))
        return s, do

    def q_consume_direct(self, s, fn, on):
        """Account a directly dispatched arrival: its (empty-queue)
        head position is consumed without ever being enqueued. The head
        cache stays stale-but-gated (q_len == 0) until the next push
        rewrites it."""
        s = dict(s)
        s["q_head_pos"] = s["q_head_pos"].at[
            _gidx(on, fn, self.F)].add(1, mode="drop")
        return s

    def q_pop(self, s, fn, on):
        """Consume the head of ``fn``'s queue; returns (state, rid).
        The one positional gather refreshes the head cache with the
        successor (garbage when the queue empties — reads gate on
        q_len)."""
        fc = jnp.clip(fn, 0, self.F - 1)
        rid = s["q_head_rid"][fc]
        succ = self.rid_at_pos(fc, s["q_head_pos"][fc] + 1)
        fi = _gidx(on, fn, self.F)
        s = dict(s)
        s["q_head_rid"] = s["q_head_rid"].at[fi].set(succ, mode="drop")
        s["q_head_pos"] = s["q_head_pos"].at[fi].add(1, mode="drop")
        s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
        return s, rid

    def arm_timer(self, s, fn, rid, t, pushed, on):
        """Account the original timer of an arrival (position
        ``arr_cnt - 1`` of the positional timer rail; ``rid`` is
        redundant here — the position identifies the request — but the
        cluster's rid-chain rail needs it). See the module-level
        `arm_timer` for the semantics."""
        fc = jnp.clip(fn, 0, self.F - 1)
        rail_head = s["tmr_pos"][fc] == s["arr_cnt"][fc] - 1
        s = dict(s)
        s["tmr_next"] = s["tmr_next"].at[
            _gidx(on & rail_head & pushed, fn, self.F)].set(
            t + self.threshold, mode="drop")
        s["tmr_pos"] = s["tmr_pos"].at[
            _gidx(on & rail_head & ~pushed, fn, self.F)].add(
            1, mode="drop")
        return s


class ResilCtx(EngineCtx):
    """Engine ctx under the resilience layer (fail_prob / timeouts /
    retries / shedding).

    Retries re-enqueue an old rid, which breaks the positional-cursor
    queue invariant (each arrival consumes exactly one position, once),
    so the per-function queues switch to the direct rid-link layout the
    cluster's churn loop uses: a shared ``nxt`` (N,) successor array
    (a rid is queued XOR running XOR awaiting retry XOR terminal, so
    one link array serves both the function queues and the retry rail)
    plus carried ``q_tail_rid``. Resilience runs are forced
    single-window for the same reason (a retried rid can be arbitrarily
    far behind the arrival cursor), so the dual-source reads are the
    flat fast path anyway.

    The pre-planned outcome operands (`repro.core.resilience
    .plan_outcomes`) ride three (T, N) rows: ``nfail_at`` (leading
    failed attempts), ``tmo_at`` (the failure is a timeout) and
    ``key_at`` (the request's *original* trace id — the jitter hash
    key, so sliced/renumbered sub-streams draw identically)."""

    def __init__(self, *, nfail2, tmo2, key2, resil, **kw):
        super().__init__(**kw)
        self._nf = nfail2.reshape(-1)
        self._tm = tmo2.reshape(-1)
        self._ky = key2.reshape(-1)
        self.resil = resil  # (max_attempts, shed_mode, base, cap,
        self.has_resil = True            # jitter, fail_seed) — static
        self.fold_at_dispatch = False    # fold successes at EXEC_DONE
        self.direct_records = True       # re-dispatches break the d_*
        self.defer_completion = True     # overlay; completion on success

    def nfail_at(self, rid):
        return self._nf[self._b_n + jnp.clip(rid, 0, self.N - 1)]

    def tmo_at(self, rid):
        return self._tm[self._b_n + jnp.clip(rid, 0, self.N - 1)]

    def key_at(self, rid):
        return self._ky[self._b_n + jnp.clip(rid, 0, self.N - 1)]

    def q_push(self, s, fn, rid, on):
        """Direct-link append with the admission-control modes: a push
        onto a full backlog drops-and-counts (``error``, the legacy
        invalid-run behaviour), sheds the arriving request
        (``shed`` — it becomes terminal, never admitted) or evicts the
        queue head to admit the newcomer (``shed_oldest``)."""
        fc = jnp.clip(fn, 0, self.F - 1)
        rid32 = jnp.asarray(rid, jnp.int32)
        len0 = s["q_len"][fc]
        full = len0 >= self.Q
        mode = self.resil[1]
        s = dict(s)
        if mode == 2:  # shed_oldest: head out (terminal), newcomer in
            evict = on & full
            h = s["q_head_rid"][fc]
            hsucc = s["nxt"][jnp.clip(h, 0, self.N - 1)]
            fi = _gidx(evict, fn, self.F)
            s["q_head_rid"] = s["q_head_rid"].at[fi].set(hsucc,
                                                         mode="drop")
            s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
            ev_i = evict.astype(jnp.int32)
            s["ci"] = s["ci"].at[jnp.array([CI_SHED, CI_TERM])].add(
                jnp.stack([ev_i, ev_i]))
            do = on
            was_empty = (len0 - ev_i) == 0
        else:
            do = on & ~full
            was_empty = len0 == 0
            if mode == 1:  # shed the arriving request
                sh_i = (on & full).astype(jnp.int32)
                s["ci"] = s["ci"].at[jnp.array([CI_SHED, CI_TERM])].add(
                    jnp.stack([sh_i, sh_i]))
            else:
                s["ci"] = s["ci"].at[CI_OVF].add(
                    (on & full).astype(jnp.int32))
        tail = s["q_tail_rid"][fc]
        s["q_head_rid"] = s["q_head_rid"].at[
            _gidx(do & was_empty, fn, self.F)].set(rid32, mode="drop")
        s["nxt"] = s["nxt"].at[
            _gidx(do & ~was_empty, tail, self.N)].set(rid32,
                                                      mode="drop")
        s["q_tail_rid"] = s["q_tail_rid"].at[
            _gidx(do, fn, self.F)].set(rid32, mode="drop")
        s["q_len"] = s["q_len"].at[_gidx(do, fn, self.F)].add(
            1, mode="drop")
        return s, do

    def q_consume_direct(self, s, fn, on):
        """Direct links carry no positional cursor — nothing to
        account for a straight-to-slot arrival."""
        return s

    def q_pop(self, s, fn, on):
        fc = jnp.clip(fn, 0, self.F - 1)
        rid = s["q_head_rid"][fc]
        succ = s["nxt"][jnp.clip(rid, 0, self.N - 1)]
        fi = _gidx(on, fn, self.F)
        s = dict(s)
        s["q_head_rid"] = s["q_head_rid"].at[fi].set(succ, mode="drop")
        s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
        return s, rid


class PolicyKernel:
    """Interface a vectorised policy implements over the engine state.

    Each hook is a pure function ``state -> state`` gated by an ``on``
    predicate (guarded-write style — hooks run every iteration, their
    writes are masked); the engine has already done the
    policy-independent bookkeeping — cursor advance for arrivals,
    estimator update + slot release for exec-done, slot release for
    cold-done, timer consumption for timers — exactly mirroring
    `repro.core.simulator.simulate`.

    Queue contract: every enabled ``on_arrival`` must consume exactly
    one queue position of the request's function — `q_push` when it
    queues, `q_consume_direct` when it dispatches the arrival straight
    to a slot — so the positional queues stay contiguous.
    """

    name = "base"
    has_timers = False
    default_beta = 1.0

    def extra_state(self, L, C, F) -> Dict[str, jnp.ndarray]:
        """Kernel-private carried arrays (leading L), e.g. FaasCache's
        per-slot GREEDY-DUAL bookkeeping. Keys must not collide with
        the engine's."""
        return {}

    def on_arrival(self, ctx, s, rid, t, on):
        raise NotImplementedError

    def on_cold_done(self, ctx, s, slot, t, on):
        raise NotImplementedError

    def on_exec_done(self, ctx, s, slot, rid, t, on):
        raise NotImplementedError

    def on_timer(self, ctx, s, rid, t, on):  # pragma: no cover
        return s


# --------------------------------------------------------------- helpers
def _gidx(on, idx, size):
    """Guarded scatter index: ``idx`` when enabled and valid, else an
    out-of-bounds sentinel that ``mode="drop"`` discards."""
    return jnp.where(on & (idx >= 0), idx, size)


def lex_argmin(primary, secondary, valid):
    """First index minimising ``(primary, secondary)`` among ``valid``.

    Reproduces the Python engine's deterministic scans: iterate in
    ``secondary`` (creation / fn-id) order, keep on strict improvement.
    """
    p = jnp.where(valid, primary, BIG)
    tie = valid & (p <= jnp.min(p))
    return jnp.argmin(jnp.where(tie, secondary, I32_MAX))


def argmin_i32(vals, valid):
    """First valid index minimising an i32 key (sentinel-masked)."""
    return jnp.argmin(jnp.where(valid, vals, I32_MAX))


def est_means(ctx, s):
    """Per-function running means with global-mean / prior fallback
    (delegates to the ctx so cluster node views can rebind the
    globals)."""
    return ctx.est_means(s)


def k_counts(ctx, s):
    """|K^j| — slots assigned to each function, any state."""
    return jnp.zeros((ctx.F,), jnp.int32).at[
        jnp.where(s["slot_fn"] >= 0, s["slot_fn"], jnp.int32(ctx.F))
    ].add(jnp.int32(1), mode="drop")


def cold_counts(ctx, s):
    """Slots currently warming up (state COLD) per function."""
    warming = s["slot_state"] == COLD
    return jnp.zeros((ctx.F,), jnp.int32).at[
        jnp.where((s["slot_fn"] >= 0) & warming, s["slot_fn"],
                  jnp.int32(ctx.F))
    ].add(jnp.int32(1), mode="drop")


def idle_own(ctx, s, fn):
    """Mask of usable idle slots already resident with ``fn``."""
    return ((s["slot_fn"] == fn) & (s["slot_state"] == IDLE)
            & ctx.cap_mask)


def pick_idle_own(ctx, s, fn):
    """(mask.any(), earliest-created idle own slot) — Python's
    ``idle_of`` picks the lowest ``inst_id``."""
    mask = idle_own(ctx, s, fn)
    return mask.any(), argmin_i32(s["slot_seq"], mask)


def q_head(ctx, s, fn):
    """Head request id of ``fn``'s queue (ctx-dispatched)."""
    return ctx.q_head(s, fn)


def q_push(ctx, s, fn, rid, on):
    """Append ``rid``; returns (state, pushed) (ctx-dispatched)."""
    return ctx.q_push(s, fn, rid, on)


def q_consume_direct(ctx, s, fn, on):
    """Account a directly dispatched arrival (ctx-dispatched)."""
    return ctx.q_consume_direct(s, fn, on)


def q_pop(ctx, s, fn, on):
    """Consume the head of ``fn``'s queue; returns (state, rid)
    (ctx-dispatched)."""
    return ctx.q_pop(s, fn, on)


def arm_timer(ctx, s, fn, rid, t, pushed, on):
    """Account the original timer of the arrival ``rid`` (the newest
    entry of ``fn``'s timer rail; ctx-dispatched).

    The rail covers every arrival in order. If the rail is idle (this
    arrival is its head) a *pushed* arrival arms the head fire time,
    while a directly dispatched one is consumed silently; a direct
    dispatch behind a busy rail stays armed and later fires as a no-op
    (its is-head gate fails), mirroring how the Python policy drops
    timers of already-served requests."""
    return ctx.arm_timer(s, fn, rid, t, pushed, on)


def rearm_timer(ctx, s, fn, rid, t_fire, on):
    """Re-arm the (unique) blocked queue head of ``fn`` at ``t_fire``."""
    fi = _gidx(on, fn, ctx.F)
    s = dict(s)
    s["rearm_t"] = s["rearm_t"].at[fi].set(t_fire, mode="drop")
    s["rearm_rid"] = s["rearm_rid"].at[fi].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    return s


def dispatch(ctx, s, slot, rid, t, on):
    """Run ``rid`` on an idle ``slot`` of its function.

    The streaming metrics (response/slowdown sums, max, histogram and
    the optional timeline bins) are folded *per event*: each dispatch
    site only records the (rid, completion, exec) triple in the
    per-event ``ev_*`` registers — three cheap selects, no scatters —
    and the engine applies the fold once at the end of the event
    (`_fold_event`). The accumulation order is then exactly the event
    order, which makes the streamed sums bitwise invariant to the
    window size (a deferred batch fold would regroup the reduction
    tree wherever a window boundary cuts a segment), and both modes
    share the fold so streamed means stay bit-identical to exact-mode
    means. At most one dispatch happens per event (call sites are
    mutually exclusive), so the registers cannot clobber a live
    record.

    In exact mode the per-request start/completion record additionally
    goes into the segment overlay (d_*), batch-scattered into the
    (L, N) result arrays once per SEG-event segment; the overlay slot
    is indexed by the segment step and disabled sites drop instead of
    clobbering it."""
    s = dict(s)
    e = ctx.exec_at(rid)
    comp = t + e
    si = _gidx(on, slot, ctx.C)
    s["slot_state"] = s["slot_state"].at[si].set(BUSY, mode="drop")
    s["slot_ready"] = s["slot_ready"].at[si].set(comp, mode="drop")
    s["slot_req"] = s["slot_req"].at[si].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["slot_used"] = s["slot_used"].at[si].set(t, mode="drop")
    if ctx.has_resil:
        # attempt counter: incremented when the request starts running,
        # read back at its EXEC_DONE to classify the outcome
        s["att"] = s["att"].at[_gidx(on, rid, ctx.N)].add(1,
                                                          mode="drop")
    if ctx.fold_at_dispatch:
        s["ev_rid"] = jnp.where(on, jnp.asarray(rid, jnp.int32),
                                s["ev_rid"])
        s["ev_comp"] = jnp.where(on, comp, s["ev_comp"])
        s["ev_exec"] = jnp.where(on, e, s["ev_exec"])
    if not ctx.stream:
        if ctx.direct_records:
            # churn can re-dispatch a drained rid within one segment;
            # the overlay's one-slot-per-rid assumption breaks, so pay
            # a per-event scatter (last write wins, matching the
            # reference's completion rewrite)
            ri = _gidx(on, rid, ctx.N)
            s["start"] = s["start"].at[ri].set(t, mode="drop")
            if not ctx.defer_completion:
                s["completion"] = s["completion"].at[ri].set(
                    comp, mode="drop")
        else:
            ki = jnp.where(on, ctx.k, ctx.seg_n)
            s["d_rid"] = s["d_rid"].at[ki].set(
                jnp.asarray(rid, jnp.int32), mode="drop")
            s["d_start"] = s["d_start"].at[ki].set(t, mode="drop")
            s["d_comp"] = s["d_comp"].at[ki].set(comp, mode="drop")
    return s


def _fold_event(ctx, s):
    """End-of-event metric fold of the ``ev_*`` dispatch registers
    (see `dispatch`): one arrival gather + one histogram bin per
    event, applied in event order so the streamed accumulators are
    bitwise window-size invariant. Consumes (pops) the registers."""
    s = dict(s)
    rid = s.pop("ev_rid")
    comp = s.pop("ev_comp")
    e = s.pop("ev_exec")
    on = rid >= 0
    arr = ctx.arrival_at(rid)
    resp = comp - arr
    slow = resp / jnp.maximum(e, 1e-9)
    cf = s["cf"]
    cf = cf.at[jnp.array([CF_RSUM, CF_SSUM])].add(
        jnp.stack([jnp.where(on, resp, 0.0),
                   jnp.where(on, slow, 0.0)]))
    cf = cf.at[CF_RMAX].max(jnp.where(on, resp, 0.0))
    s["cf"] = cf
    s["hist"] = s["hist"].at[
        jnp.where(on, hist_bin(resp), jnp.int32(HIST_BINS))
    ].add(1, mode="drop")
    if ctx.deadlines is not None:
        fnr = ctx.fn_at(rid)
        dl = ctx.deadlines[jnp.clip(fnr, 0, ctx.F - 1)]
        s["dl_miss"] = s["dl_miss"].at[
            _gidx(on & (resp > dl), fnr, ctx.F)].add(1, mode="drop")
    if ctx.tl_bins:
        tb = jnp.clip((arr / ctx.tl_bucket).astype(jnp.int32),
                      0, ctx.tl_bins - 1)
        ti = jnp.where(on, tb, jnp.int32(ctx.tl_bins))
        s["tl_cnt"] = s["tl_cnt"].at[ti].add(1, mode="drop")
        s["tl_resp"] = s["tl_resp"].at[ti].add(resp, mode="drop")
        s["tl_exec"] = s["tl_exec"].at[ti].add(e, mode="drop")
    return s


def start_cold(ctx, s, slot, fn, t, evict_fn, on):
    """Claim/convert ``slot`` for ``fn`` (``evict_fn`` = -1 -> empty slot,
    otherwise the resident function paying its eviction cost first)."""
    s = dict(s)
    fn = jnp.asarray(fn, jnp.int32)  # argmin/argmax indices are i64
    evict_fn = jnp.asarray(evict_fn, jnp.int32)
    fc = jnp.clip(fn, 0, ctx.F - 1)
    evicting = on & (evict_fn >= 0)
    ev_cost = jnp.where(evicting,
                        ctx.t_evict[jnp.clip(evict_fn, 0, ctx.F - 1)],
                        0.0)
    si = _gidx(on, slot, ctx.C)
    s["slot_fn"] = s["slot_fn"].at[si].set(fn, mode="drop")
    s["slot_state"] = s["slot_state"].at[si].set(COLD, mode="drop")
    s["slot_ready"] = s["slot_ready"].at[si].set(
        t + ctx.t_cold[fc] + ev_cost, mode="drop")
    s["slot_req"] = s["slot_req"].at[si].set(-1, mode="drop")
    s["slot_used"] = s["slot_used"].at[si].set(0.0, mode="drop")
    s["slot_seq"] = s["slot_seq"].at[si].set(s["ci"][CI_SEQ],
                                             mode="drop")
    on_i = on.astype(jnp.int32)
    s["ci"] = s["ci"].at[jnp.array([CI_SEQ, CI_COLD, CI_EVICT])].add(
        jnp.stack([on_i, on_i, evicting.astype(jnp.int32)]))
    s["cf"] = s["cf"].at[jnp.array([CF_COLDT, CF_EVICTT])].add(
        jnp.stack([jnp.where(on, ctx.t_cold[fc], 0.0), ev_cost]))
    return s


# ----------------------------------------------------- streaming metrics
def hist_edges() -> np.ndarray:
    """Bin edges (HIST_BINS + 1,) of the streaming response histogram."""
    return 10.0 ** (HIST_LO
                    + np.arange(HIST_BINS + 1) / HIST_PER_DECADE)


def hist_bin(resp):
    """Log-spaced bin index of a (batch of) response time(s)."""
    b = jnp.floor((jnp.log10(jnp.maximum(resp, 1e-30)) - HIST_LO)
                  * HIST_PER_DECADE)
    return jnp.clip(b, 0, HIST_BINS - 1).astype(jnp.int32)


def hist_quantile(hist, q, n, resp_max=None):
    """Upper edge of the bin containing the q-quantile of ``n`` folded
    responses — exact to one bin width (~1.33x).

    The edge bins also hold everything clipped past the histogram
    range, so their edges would silently misstate out-of-range tails;
    with ``resp_max`` (the exact carried maximum) the result is never
    range-capped: a quantile in the top bin reports the maximum itself,
    and any bin's edge is clamped to it (which makes all-fast traces —
    every response under the 1e-4 s floor — report the true tail
    instead of the floor edge). The reported value always upper-bounds
    the true quantile; only a distribution almost entirely below the
    floor with large outliers can push it past one bin of the truth."""
    cum = jnp.cumsum(hist, axis=-1)
    need = jnp.ceil(q * n).astype(cum.dtype)
    b = jnp.argmax(cum >= need, axis=-1)
    edge = jnp.asarray(hist_edges())[b + 1]
    if resp_max is None:
        return edge
    return jnp.where(b >= HIST_BINS - 1, resp_max,
                     jnp.minimum(edge, resp_max))


def hist_cdf(hist):
    """(edges, cdf) arrays for plotting a CDF from the streamed
    histogram (exact to one bin width)."""
    h = np.asarray(hist, np.float64)
    cum = h.cumsum(axis=-1)
    total = np.maximum(cum[..., -1:], 1.0)
    return hist_edges()[1:], cum / total


# ------------------------------------------------------------ event loop
@functools.partial(jax.jit,
                   static_argnames=("kernel", "n_fns", "capacity",
                                    "queue_cap", "stream", "window",
                                    "tl_bins", "resil", "trace"))
def _simulate(fn_id, arrival, exec_time, t_cold, t_evict, trace_ix,
              cap_mask, beta, prior, threshold, n_live=None,
              deadlines=None, rs_nfail=None, rs_tmo=None, rs_key=None,
              *, kernel, n_fns, capacity, queue_cap,
              stream=False, window=0, tl_bins=0, tl_bucket=60.0,
              resil=None, trace=False):
    """Lane-batched engine. Trace arrays are shared (T, ...) operands;
    ``trace_ix``, ``cap_mask`` and ``beta`` carry the leading lane
    dimension L (one lane per sweep point). The loop nest is windows ->
    segments -> events (see the module docstring): per window the
    shared operands are re-sliced into L2-resident slabs, and within a
    window one ``while_loop`` runs all lanes in segments of SEG events
    with the branchless per-event body vmapped per lane (finished and
    parked lanes no-op via their guards).

    ``stream=True`` drops the (L, N) per-request result arrays: each
    event folds its dispatch record into the per-lane metric
    accumulators (`_fold_event`), so carried state is independent of N.
    ``window`` (static; 0 -> `DEFAULT_WINDOW`) sets the slab size and
    never changes results, only locality. ``tl_bins > 0`` adds the
    minute-binned timeline fold (bucket width ``tl_bucket`` seconds).

    ``n_live`` ((L,) i32, optional) caps how many leading requests of
    each lane's trace row are real: a lane completes once its first
    ``n_live`` requests have finished and never consumes the padding
    tail. This is what lets ragged request streams — the per-node
    sub-streams of `repro.cluster`'s static routing path — share one
    padded (T, N) operand without recompilation per length. ``None``
    (every existing caller) means all N requests are live.

    ``resil`` (static: ``(max_attempts, shed_mode, base, cap, jitter,
    fail_seed)``, or None) enables the request-resilience layer; the
    pre-planned outcome operands ``rs_nfail`` / ``rs_tmo`` / ``rs_key``
    ((T, N), see `repro.core.resilience.plan_outcomes` and `ResilCtx`)
    then ride along. With ``resil=None`` — every no-fault spec — none
    of the resilience code is traced and the loop lowers bitwise
    unchanged. A lane is finished when every live request is
    *terminal* (done, shed, or retry-exhausted), counted in CI_TERM.

    ``trace`` (static) enables the telemetry event-trace rail
    (`repro.telemetry.rail`): every processed event stages a
    fixed-width record into an (L, SEG, ·) overlay, flushed to the
    host sink once per segment through an ordered ``io_callback``.
    ``trace=False`` traces none of it — the loop lowers bitwise onto
    the unchanged program, exactly like the other optional rails.
    """
    L = trace_ix.shape[0]
    T_ = fn_id.shape[0]
    N = fn_id.shape[1]
    F, C, Q = n_fns, capacity, queue_cap
    nl = (jnp.full((L,), N, jnp.int32) if n_live is None
          else jnp.asarray(n_live, jnp.int32))

    has_resil = resil is not None
    if has_resil:
        if kernel.has_timers:
            raise NotImplementedError(
                "resilience (fail_prob/timeouts/retries) does not "
                "support timer-rail kernels (openwhisk_v2) — the "
                "positional timer rail assumes each arrival position "
                "is consumed exactly once, which retries break")
        max_att, shed_mode, rt_base, rt_cap, rt_jit, rt_seed = resil
        rs_nfail = rs_nfail.astype(jnp.int32)
        rs_tmo = rs_tmo.astype(bool)
        rs_key = rs_key.astype(jnp.int32)

    W = int(window) if window else DEFAULT_WINDOW
    W = max(1, min(W, N))
    if has_resil:
        # a retried rid can trail the arrival cursor by any distance,
        # so the 2-source window-slab invariant doesn't hold; run the
        # whole trace as one window (results are window-invariant)
        W = N
    n_win = -(-N // W)
    NP = n_win * W

    fn_id = fn_id.astype(jnp.int32)
    arrival = arrival.astype(jnp.float64)
    exec_time = exec_time.astype(jnp.float64)
    t_cold = t_cold.astype(jnp.float64)
    t_evict = t_evict.astype(jnp.float64)
    trace_ix = trace_ix.astype(jnp.int32)
    prior = jnp.float64(prior)
    threshold = jnp.float64(threshold)
    tl_bucket = jnp.float64(tl_bucket)

    # positional queue layout (loop-invariant): request ids sorted by
    # (fn, id) + per-function offsets — fn j's k-th arrival is
    # pos_rids[pos_off[j] + k]
    pos_rids = jnp.argsort(fn_id, axis=1, stable=True).astype(jnp.int32)
    counts = jax.vmap(
        lambda row: jnp.zeros((F,), jnp.int32).at[
            jnp.clip(row, 0, F - 1)].add(1))(fn_id)
    pos_off = jnp.concatenate(
        [jnp.zeros((counts.shape[0], 1), jnp.int32),
         jnp.cumsum(counts, axis=1)], axis=1)

    # window-major operands: the trace padded to n_win * W (so slab
    # slices never clamp) plus a second positional layout sorted by
    # (window, fn, id) — window w's block is rows [w*W, (w+1)*W), with
    # per-window per-fn offsets off_w and exclusive prefix counts
    # cum_cnt (fn j's positions in window w are [cum_cnt[w], cum_cnt[w+1])).
    # Single-window runs (W >= N) skip all of it statically — the full
    # operands are the slab and every windowed read takes its fast path.
    single_win = n_win == 1
    if not single_win:
        pad = NP - N
        fn_pad = jnp.pad(fn_id, ((0, 0), (0, pad)))
        arr_pad = jnp.pad(arrival, ((0, 0), (0, pad)),
                          constant_values=BIG)
        ex_pad = jnp.pad(exec_time, ((0, 0), (0, pad)))
        win_key = ((jnp.arange(N, dtype=jnp.int32) // W)[None] * F
                   + fn_id)
        pos_w = jnp.pad(
            jnp.argsort(win_key, axis=1, stable=True).astype(jnp.int32),
            ((0, 0), (0, pad)))
        wcnt = jax.vmap(
            lambda kr: jnp.zeros((n_win * F,), jnp.int32).at[kr].add(1)
        )(win_key).reshape(T_, n_win, F)
        off_w = jnp.concatenate(
            [jnp.zeros((T_, n_win, 1), jnp.int32),
             jnp.cumsum(wcnt, axis=2)[:, :, :-1]], axis=2)
        cum_cnt = jnp.concatenate(
            [jnp.zeros((T_, 1, F), jnp.int32),
             jnp.cumsum(wcnt, axis=1)], axis=1)

    s = dict(
        slot_fn=jnp.full((L, C), -1, jnp.int32),
        slot_state=jnp.full((L, C), IDLE, jnp.int32),
        slot_ready=jnp.full((L, C), BIG, jnp.float64),
        slot_req=jnp.full((L, C), -1, jnp.int32),
        slot_used=jnp.zeros((L, C), jnp.float64),
        slot_seq=jnp.full((L, C), I32_MAX, jnp.int32),
        q_head_pos=jnp.zeros((L, F), jnp.int32),
        q_head_rid=jnp.full((L, F), -1, jnp.int32),
        q_len=jnp.zeros((L, F), jnp.int32),
        est_sum=jnp.zeros((L, F), jnp.float64),
        est_n=jnp.zeros((L, F), jnp.int32),
        ci=jnp.zeros((L, NCI), jnp.int32),
        cf=jnp.zeros((L, NCF), jnp.float64),
        hist=jnp.zeros((L, HIST_BINS), jnp.int32),
    )
    if not stream:
        s["d_rid"] = jnp.full((L, SEG), N, jnp.int32)
        s["d_start"] = jnp.zeros((L, SEG), jnp.float64)
        s["d_comp"] = jnp.zeros((L, SEG), jnp.float64)
        s["start"] = jnp.full((L, N), -1.0, jnp.float64)
        s["completion"] = jnp.full((L, N), -1.0, jnp.float64)
    if deadlines is not None:
        deadlines = jnp.asarray(deadlines, jnp.float64)
        s["dl_miss"] = jnp.zeros((L, F), jnp.int32)
    if tl_bins:
        s["tl_cnt"] = jnp.zeros((L, tl_bins), jnp.int32)
        s["tl_resp"] = jnp.zeros((L, tl_bins), jnp.float64)
        s["tl_exec"] = jnp.zeros((L, tl_bins), jnp.float64)
    if kernel.has_timers:
        s["arr_cnt"] = jnp.zeros((L, F), jnp.int32)
        s["tmr_pos"] = jnp.zeros((L, F), jnp.int32)
        s["tmr_next"] = jnp.full((L, F), BIG, jnp.float64)
        s["rearm_t"] = jnp.full((L, F), BIG, jnp.float64)
        s["rearm_rid"] = jnp.full((L, F), -1, jnp.int32)
    if has_resil:
        # direct-link queues (ResilCtx) + the retry FIFO rail: one
        # shared successor array serves both chains (a rid is in at
        # most one), the rail carries head/tail/len and the head fire
        # time (BIG when empty). rt_t holds each waiter's eligible
        # time; a head promoted behind a later-firing predecessor is
        # clamped to the pop time (no overtaking within the rail).
        s["q_tail_rid"] = jnp.full((L, F), -1, jnp.int32)
        s["nxt"] = jnp.full((L, N), -1, jnp.int32)
        s["att"] = jnp.zeros((L, N), jnp.int32)
        s["rt_t"] = jnp.zeros((L, N), jnp.float64)
        s["r_head"] = jnp.full((L,), -1, jnp.int32)
        s["r_tail"] = jnp.full((L,), -1, jnp.int32)
        s["r_len"] = jnp.zeros((L,), jnp.int32)
        s["r_fire"] = jnp.full((L,), BIG, jnp.float64)
    if trace:
        from repro.telemetry.rail import TR_RF, TR_RI
        s["tr_i"] = jnp.full((L, SEG, TR_RI), -1, jnp.int32)
        s["tr_f"] = jnp.zeros((L, SEG, TR_RF), jnp.float64)
    s.update(kernel.extra_state(L, C, F))

    max_iters = (256 * N + 4096) * (max_att if has_resil else 1)
    n_slot = 2 * C   # candidate positions: busy slots then cold slots
    # candidate order: busy | cold | (timers) | retry | arrival
    n_cand = (n_slot + (2 * F if kernel.has_timers else 0)
              + (1 if has_resil else 0) + 1)
    lanes = jnp.arange(L, dtype=jnp.int32)
    lane_iota = lanes[:, None]
    # per-lane (F,) cold/evict rows, gathered once (the (T, F) row
    # gather would otherwise sit inside the per-event body)
    t_cold_l = t_cold[trace_ix]
    t_evict_l = t_evict[trace_ix]
    # lane-stacked arrival reads go through the flattened operand with
    # a per-lane base — a (T, N) two-dim gather only hits the fast
    # XLA:CPU path at T == 1 (see EngineCtx)
    arr_flat = arrival.reshape(-1)
    base_n = trace_ix * N

    def window_body(w, s):
        base = w * W
        if single_win:
            slabs = (None,) * 7
            win_end = N
            is_last = True
        else:
            # shared (T, W) slabs for this window — contiguous copies,
            # so the inner loop's gathers stay inside ~24*W bytes per
            # trace
            fn_s = lax.dynamic_slice_in_dim(fn_pad, base, W, 1)
            arr_s = lax.dynamic_slice_in_dim(arr_pad, base, W, 1)
            ex_s = lax.dynamic_slice_in_dim(ex_pad, base, W, 1)
            pos_s = lax.dynamic_slice_in_dim(pos_w, base, W, 1)
            offw = lax.dynamic_slice_in_dim(off_w, w, 1, 1)[:, 0]
            cc_lo = lax.dynamic_slice_in_dim(cum_cnt, w, 1, 1)[:, 0]
            cc_hi = lax.dynamic_slice_in_dim(cum_cnt, w + 1, 1, 1)[:, 0]
            slabs = (fn_s, arr_s, ex_s, pos_s, offw, cc_lo, cc_hi)
            win_end = jnp.minimum(base + W, N)
            is_last = w >= n_win - 1

        def pick_events(s):
            """Lane-stacked next-event pick: one segmented first-index
            argmin over the (L, 2C[+2F]+1) candidate matrix resolves
            time and tie-break for every lane at once — position
            encodes the same-time class order EXEC < COLD <
            TIMER(orig < rearm) < ARRIVAL and the within-class index
            tie-break (Python engine heap order)."""
            na = s["ci"][:, CI_NEXT]
            r = jnp.minimum(na, N - 1)
            if single_win:
                t_arr = jnp.where(na < nl, arr_flat[base_n + r], BIG)
            else:
                off = r - base
                inw = (off >= 0) & (off < W)
                sv = arr_s.reshape(-1)[trace_ix * W
                                       + jnp.where(inw, off, 0)]
                fv = arr_flat[base_n + jnp.where(inw, base, r)]
                t_arr = jnp.where(na < nl, jnp.where(inw, sv, fv), BIG)
            ready = jnp.where(cap_mask, s["slot_ready"], BIG)
            st = s["slot_state"]
            blocks = [jnp.where(st == BUSY, ready, BIG),
                      jnp.where(st == COLD, ready, BIG)]
            if kernel.has_timers:
                blocks += [s["tmr_next"], s["rearm_t"]]
            if has_resil:
                blocks.append(s["r_fire"][:, None])
            blocks.append(t_arr[:, None])
            cand = jnp.concatenate(blocks, axis=1)
            ei = jnp.argmin(cand, axis=1).astype(jnp.int32)
            t_ev = jnp.take_along_axis(cand, ei[:, None], axis=1)[:, 0]
            return ei, t_ev, t_arr

        def lane_step(k, s, tix, cold_l, evict_l, cap_mask, beta,
                      nl_l, ei, t_ev, t_arr):
            kw = dict(fn_id2=fn_id, arrival2=arrival,
                      exec2=exec_time, cold2=cold_l,
                      evict2=evict_l, pos_rids2=pos_rids,
                      pos_off2=pos_off, slabs=slabs,
                      win_base=base, win_w=W, tix=tix,
                      cap_mask=cap_mask, beta=beta, prior=prior,
                      threshold=threshold, k=k, n=N, f=F, c=C,
                      q=Q, stream=stream, tl_bins=tl_bins,
                      tl_bucket=tl_bucket, deadlines=deadlines)
            ctx = (ResilCtx(nfail2=rs_nfail, tmo2=rs_tmo, key2=rs_key,
                            resil=resil, **kw)
                   if has_resil else EngineCtx(**kw))
            ci = s["ci"]
            done_ci = CI_TERM if has_resil else CI_DONE
            active = (ci[done_ci] < nl_l) & (ci[CI_STALL] == 0)
            if trace:
                tr_q0 = s["q_len"].sum()
            na = ci[CI_NEXT]
            live = active & (t_ev < BIG)
            # per-event dispatch registers (consumed by _fold_event)
            s = dict(s)
            s["ev_rid"] = jnp.int32(-1)
            s["ev_comp"] = jnp.float64(0.0)
            s["ev_exec"] = jnp.float64(0.0)
            ev_slot = live & (ei < n_slot)
            is_cold = ei >= C
            slot = jnp.clip(jnp.where(is_cold, ei - C, ei), 0, C - 1)
            # an arrival beyond the current window parks the lane (its
            # time still won the pick, so every earlier event has been
            # processed); the consume waits for the next window
            ev_arr = live & (ei == n_cand - 1) & (na < win_end)

            # ------------------------------------------------- slot event
            cold_on = ev_slot & is_cold
            exec_on = ev_slot & ~is_cold
            rid_done = s["slot_req"][slot]
            j_done = s["slot_fn"][slot]
            e_done = ctx.exec_at(rid_done)
            si = _gidx(ev_slot, slot, C)
            ji = _gidx(exec_on, j_done, F)
            exec_i = exec_on.astype(jnp.int32)
            s = dict(s)
            s["slot_state"] = s["slot_state"].at[si].set(IDLE,
                                                         mode="drop")
            s["slot_ready"] = s["slot_ready"].at[si].set(BIG,
                                                         mode="drop")
            s["slot_req"] = s["slot_req"].at[si].set(-1, mode="drop")
            # estimator sees the completion before the policy reacts
            s["est_sum"] = s["est_sum"].at[ji].add(e_done, mode="drop")
            s["est_n"] = s["est_n"].at[ji].add(1, mode="drop")
            s["cf"] = s["cf"].at[CF_GSUM].add(
                jnp.where(exec_on, e_done, 0.0))
            if not has_resil:
                s["ci"] = s["ci"].at[jnp.array([CI_GN, CI_DONE])].add(
                    jnp.stack([exec_i, exec_i]))
            else:
                # outcome of this attempt: the estimator observed the
                # attempt above (every attempt burns real slot time);
                # success/failure is the pre-planned attempt test
                att_d = s["att"][jnp.clip(rid_done, 0, N - 1)]
                nf_d = ctx.nfail_at(rid_done)
                ok_d = exec_on & (att_d > nf_d)
                fail_d = exec_on & ~ok_d
                exh_d = fail_d & (att_d >= max_att)
                retry_d = fail_d & ~exh_d
                tmo_d = ctx.tmo_at(rid_done)
                ok_i = ok_d.astype(jnp.int32)
                s["ci"] = s["ci"].at[jnp.array(
                    [CI_GN, CI_DONE, CI_TERM, CI_FAILED, CI_TMO,
                     CI_RETRY, CI_EXH])].add(jnp.stack(
                    [exec_i, ok_i, ok_i + exh_d.astype(jnp.int32),
                     (fail_d & ~tmo_d).astype(jnp.int32),
                     (fail_d & tmo_d).astype(jnp.int32),
                     retry_d.astype(jnp.int32),
                     exh_d.astype(jnp.int32)]))
                # fold (and exact-record) successful completions only
                rd32 = jnp.asarray(rid_done, jnp.int32)
                s["ev_rid"] = jnp.where(ok_d, rd32, s["ev_rid"])
                s["ev_comp"] = jnp.where(ok_d, t_ev, s["ev_comp"])
                s["ev_exec"] = jnp.where(ok_d, e_done, s["ev_exec"])
                if not stream:
                    s["completion"] = s["completion"].at[
                        _gidx(ok_d, rid_done, N)].set(t_ev,
                                                      mode="drop")
                # a retrying rid re-enters after its backoff; the rail
                # is FIFO so only an empty rail arms the fire time here
                key_d = ctx.key_at(rid_done)
                elig = t_ev + backoff_jax(att_d, key_d, rt_base,
                                          rt_cap, rt_jit, rt_seed)
                s["rt_t"] = s["rt_t"].at[
                    _gidx(retry_d, rid_done, N)].set(elig, mode="drop")
                r_empty = s["r_len"] == 0
                s["nxt"] = s["nxt"].at[
                    _gidx(retry_d & ~r_empty, s["r_tail"], N)].set(
                    rd32, mode="drop")
                s["r_head"] = jnp.where(retry_d & r_empty, rd32,
                                        s["r_head"])
                s["r_tail"] = jnp.where(retry_d, rd32, s["r_tail"])
                s["r_fire"] = jnp.where(retry_d & r_empty, elig,
                                        s["r_fire"])
                s["r_len"] = s["r_len"] + retry_d.astype(jnp.int32)
            s = kernel.on_cold_done(ctx, s, slot, t_ev, cold_on)
            s = kernel.on_exec_done(ctx, s, slot, rid_done, t_ev,
                                    exec_on)

            # ------------------------------------------------ timer event
            ev_timer = jnp.bool_(False)
            if kernel.has_timers:
                # originals (arrival + threshold, arrival order) vs the
                # unique re-armed head; originals win exact ties (FIFO
                # seq)
                fire_orig = live & (ei >= n_slot) & (ei < n_slot + F)
                fire_re = (live & (ei >= n_slot + F)
                           & (ei < n_slot + 2 * F))
                ev_timer = fire_orig | fire_re
                f_o = jnp.clip(ei - n_slot, 0, F - 1)
                f_r = jnp.clip(ei - n_slot - F, 0, F - 1)
                p_o = s["tmr_pos"][f_o]
                rid_o = ctx.rid_at_pos(f_o, p_o)
                succ = ctx.rid_at_pos(f_o, p_o + 1)
                more = p_o + 1 < s["arr_cnt"][f_o]
                oi = _gidx(fire_orig, f_o, F)
                rid_r = s["rearm_rid"][f_r]
                s = dict(s)
                s["tmr_pos"] = s["tmr_pos"].at[oi].add(1, mode="drop")
                s["tmr_next"] = s["tmr_next"].at[oi].set(
                    jnp.where(more, ctx.arrival_at(succ) + threshold,
                              BIG),
                    mode="drop")
                s["rearm_t"] = s["rearm_t"].at[
                    _gidx(fire_re, f_r, F)].set(BIG, mode="drop")
                rid_t = jnp.where(fire_orig, rid_o, rid_r)
                s = kernel.on_timer(ctx, s, rid_t, t_ev, ev_timer)

            # ------------------------------------------------ retry event
            ev_rtry = jnp.bool_(False)
            rid_a = jnp.minimum(na, N - 1)
            rid_na, t_na = rid_a, t_arr
            if has_resil:
                ev_rtry = live & (ei == n_slot)
                rlen0 = s["r_len"]
                rid_r = s["r_head"]
                succ_r = s["nxt"][jnp.clip(rid_r, 0, N - 1)]
                s = dict(s)
                s["r_head"] = jnp.where(ev_rtry, succ_r, s["r_head"])
                s["r_tail"] = jnp.where(ev_rtry & (rlen0 <= 1),
                                        jnp.int32(-1), s["r_tail"])
                s["r_len"] = rlen0 - ev_rtry.astype(jnp.int32)
                # promote the successor; it may not fire before this
                # pop (FIFO, no overtaking within the rail)
                nfire = jnp.maximum(
                    s["rt_t"][jnp.clip(succ_r, 0, N - 1)], t_ev)
                s["r_fire"] = jnp.where(
                    ev_rtry, jnp.where(rlen0 > 1, nfire, BIG),
                    s["r_fire"])
                # a retry re-enters through the same arrival hook, at
                # its fire time
                rid_na = jnp.where(ev_rtry, rid_r, rid_a)
                t_na = jnp.where(ev_rtry, t_ev, t_arr)

            # ---------------------------------------------------- arrival
            s = dict(s)
            if kernel.has_timers:
                s["arr_cnt"] = s["arr_cnt"].at[
                    _gidx(ev_arr, ctx.fn_at(rid_a), F)].add(
                    1, mode="drop")
            # n_events counts processed events (parked no-op spins are
            # excluded, so the count is window-size invariant)
            progress = ev_slot | ev_timer | ev_arr | ev_rtry
            s["ci"] = s["ci"].at[jnp.array([CI_NEXT, CI_ITERS])].add(
                jnp.stack([ev_arr.astype(jnp.int32),
                           progress.astype(jnp.int32)]))
            s = kernel.on_arrival(ctx, s, rid_na, t_na,
                                  ev_arr | ev_rtry)

            s = _fold_event(ctx, s)
            s = dict(s)
            if trace:
                # telemetry record: one fixed-width row per processed
                # event, staged at the segment-step slot (parked spins
                # drop). Outcome detail comes from the counter deltas
                # of this event, so every rail reports through one
                # code path.
                from repro.telemetry.rail import (
                    AUX_COLD, AUX_FAIL_EXHAUSTED, AUX_FAIL_RETRY,
                    AUX_OVERFLOW, AUX_QUEUED, AUX_SHED, AUX_TIMEOUT,
                    TraceKind)
                ci1 = s["ci"]
                dlt = ci1 - ci
                kind = jnp.where(exec_on, TraceKind.EXEC, jnp.where(
                    cold_on, TraceKind.COLD, jnp.where(
                        ev_timer, TraceKind.TIMER, jnp.where(
                            ev_rtry, TraceKind.RETRY, jnp.where(
                                ev_arr, TraceKind.ARRIVAL, -1)))))
                rid_tr = jnp.where(
                    ev_slot, rid_done,
                    jnp.where(ev_arr | ev_rtry, rid_na, -1))
                if kernel.has_timers:
                    rid_tr = jnp.where(ev_timer, rid_t, rid_tr)
                fn_tr = jnp.where(ev_slot, j_done, jnp.where(
                    rid_tr >= 0, ctx.fn_at(rid_tr), -1))
                fail_i = dlt[CI_FAILED] + dlt[CI_TMO]
                aux_ex = (jnp.where(
                    dlt[CI_EXH] > 0, AUX_FAIL_EXHAUSTED,
                    jnp.where(fail_i > 0, AUX_FAIL_RETRY, 0))
                    + jnp.where(dlt[CI_TMO] > 0, AUX_TIMEOUT, 0))
                aux_arr = (
                    jnp.where(dlt[CI_COLD] > 0, AUX_COLD, 0)
                    + jnp.where(s["q_len"].sum() > tr_q0,
                                AUX_QUEUED, 0)
                    + jnp.where(dlt[CI_SHED] > 0, AUX_SHED, 0)
                    + jnp.where(dlt[CI_OVF] > 0, AUX_OVERFLOW, 0))
                busy = ((s["slot_state"] == BUSY)
                        & cap_mask).sum()
                warm = ((s["slot_state"] == IDLE) & (s["slot_fn"] >= 0)
                        & cap_mask).sum()
                rec_i = jnp.stack([
                    kind, rid_tr, fn_tr, jnp.int32(-1),
                    jnp.where(exec_on, aux_ex, aux_arr),
                    s["q_len"].sum(), busy, warm,
                    ci1[CI_ITERS]]).astype(jnp.int32)
                rec_f = jnp.stack([
                    t_ev, jnp.where(exec_on, e_done, 0.0)])
                ki = jnp.where(progress, k, SEG)
                s["tr_i"] = s["tr_i"].at[ki].set(rec_i, mode="drop")
                s["tr_f"] = s["tr_f"].at[ki].set(rec_f, mode="drop")
            stall = jnp.where(
                active & ~live, 1,
                jnp.where(active & (s["ci"][CI_ITERS] >= max_iters), 2,
                          s["ci"][CI_STALL]))
            s["ci"] = s["ci"].at[CI_STALL].set(stall)
            return s

        step_lanes = jax.vmap(
            lane_step, in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))

        def cond(s):
            ci = s["ci"]
            done_col = CI_TERM if has_resil else CI_DONE
            act = (ci[:, done_col] < nl) & (ci[:, CI_STALL] == 0)
            return jnp.any(act & (is_last | (ci[:, CI_NEXT] < win_end)))

        def segment(s):
            # streaming metrics fold per event (`dispatch` registers +
            # `_fold_event`) — a segment is pure event-stepping
            # plus, in exact mode, the batched overlay scatter into the
            # (L, N) per-request arrays (the only large-array write,
            # paid once per SEG events, not per event)
            if not stream:
                s = dict(s)
                s["d_rid"] = jnp.full((L, SEG), N, jnp.int32)
            if trace:
                from repro.telemetry.rail import TR_RF, TR_RI
                s = dict(s)
                s["tr_i"] = jnp.full((L, SEG, TR_RI), -1, jnp.int32)
                s["tr_f"] = jnp.zeros((L, SEG, TR_RF), jnp.float64)

            def step(k, s):
                ei, t_ev, t_arr = pick_events(s)
                return step_lanes(k, s, trace_ix, t_cold_l, t_evict_l,
                                  cap_mask, beta, nl, ei, t_ev, t_arr)

            s = lax.fori_loop(0, SEG, step, s)
            if not stream:
                s = dict(s)
                s["start"] = s["start"].at[lane_iota, s["d_rid"]].set(
                    s["d_start"], mode="drop")
                s["completion"] = s["completion"].at[
                    lane_iota, s["d_rid"]].set(s["d_comp"], mode="drop")
            if trace:
                from repro.telemetry.rail import emit_flush
                emit_flush(s["tr_i"], s["tr_f"])
            return s

        return lax.while_loop(cond, segment, s)

    final = (window_body(0, s) if single_win
             else lax.fori_loop(0, n_win, window_body, s))
    ci, cf = final["ci"], final["cf"]
    out = dict(cold_starts=ci[:, CI_COLD], cold_time=cf[:, CF_COLDT],
               evictions=ci[:, CI_EVICT], evict_time=cf[:, CF_EVICTT],
               overflow=ci[:, CI_OVF],
               stalled=ci[:, CI_STALL], n_events=ci[:, CI_ITERS],
               done=ci[:, CI_DONE],
               resp_sum=cf[:, CF_RSUM], slow_sum=cf[:, CF_SSUM],
               max_response=cf[:, CF_RMAX], resp_hist=final["hist"])
    if tl_bins:
        out["tl_count"] = final["tl_cnt"]
        out["tl_resp_sum"] = final["tl_resp"]
        out["tl_exec_sum"] = final["tl_exec"]
    if deadlines is not None:
        out["deadline_miss"] = final["dl_miss"]
    if has_resil:
        out["failed"] = ci[:, CI_FAILED]
        out["timed_out"] = ci[:, CI_TMO]
        out["retried"] = ci[:, CI_RETRY]
        out["shed"] = ci[:, CI_SHED]
        out["failed_exhausted"] = ci[:, CI_EXH]
    if not stream:
        out["start"] = final["start"]
        out["completion"] = final["completion"]
    return out


# ------------------------------------------------------------ public API
def simulate_policy_jax(fn_id, arrival, exec_time, t_cold, t_evict, *,
                        policy: str = "esff", n_fns: int, capacity: int,
                        queue_cap: int = 512, beta=None,
                        prior: float = 0.1, threshold: float = 0.1,
                        cap_mask=None, stream: bool = False,
                        window: int = 0, tl_bins: int = 0,
                        tl_bucket: float = 60.0
                        ) -> Dict[str, jnp.ndarray]:
    """Run ``policy`` over a (sorted-by-arrival) request stream.

    ``policy`` selects a kernel from `repro.core.jax_policies.KERNELS`
    statically, so each policy gets its own jit specialisation. ``beta``
    defaults to the kernel's own default (2.0 for ESFF-H, else 1.0).
    ``window`` sets the cache-window slab size (0 -> `DEFAULT_WINDOW`;
    results are bitwise independent of it). ``tl_bins > 0`` adds the
    minute-binned timeline accumulators (``tl_count`` / ``tl_resp_sum``
    / ``tl_exec_sum``). Returns the counter block (cold starts,
    evictions, overflow, stalled) plus the streaming metric
    accumulators (resp_sum / slow_sum / max_response / resp_hist);
    with the default ``stream=False`` also per-request
    start/completion.
    """
    from repro.core.jax_policies import KERNELS  # deferred: cycle-free
    kernel = KERNELS[policy]
    if beta is None:
        beta = kernel.default_beta
    if cap_mask is None:
        cap_mask = jnp.ones((capacity,), bool)
    share = lambda x: jnp.expand_dims(jnp.asarray(x), 0)  # noqa: E731
    out = _simulate(share(fn_id), share(arrival), share(exec_time),
                    share(t_cold), share(t_evict),
                    jnp.zeros((1,), jnp.int32),
                    jnp.expand_dims(jnp.asarray(cap_mask), 0),
                    jnp.asarray(beta, jnp.float64).reshape((1,)),
                    jnp.float64(prior), jnp.float64(threshold),
                    kernel=kernel, n_fns=n_fns, capacity=capacity,
                    queue_cap=queue_cap, stream=stream, window=window,
                    tl_bins=tl_bins, tl_bucket=tl_bucket)
    return {k: jnp.squeeze(v, axis=0) for k, v in out.items()}


def simulate_policy_from_trace(trace: Trace, policy: str, capacity: int,
                               *, beta=None, queue_cap: int = 1024,
                               prior: float = 0.1,
                               threshold: float = 0.1,
                               window: int = 0
                               ) -> Dict[str, np.ndarray]:
    """Trace-object convenience wrapper mirroring ``simulate()``
    (exact per-request mode)."""
    a = trace.to_arrays()
    out = simulate_policy_jax(
        jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
        jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
        jnp.asarray(a["evict"]), policy=policy,
        n_fns=trace.n_functions, capacity=capacity, queue_cap=queue_cap,
        beta=beta, prior=prior, threshold=threshold, window=window)
    out = {k: np.asarray(v) for k, v in out.items()}
    out["response"] = out["completion"] - a["arrival"]
    out["mean_response"] = float(out["response"].mean())
    return out


@functools.partial(jax.jit,
                   static_argnames=("kernel", "n_fns", "capacity",
                                    "queue_cap", "stream", "window",
                                    "tl_bins", "keep_responses",
                                    "resil", "trace"))
def _sweep_metrics(fn, arr, ex, cold, ev, tix, masks, betas, prior,
                   threshold, n_live=None, deadlines=None,
                   rs_nfail=None, rs_tmo=None, rs_key=None, *, kernel,
                   n_fns, capacity, queue_cap, stream=True, window=0,
                   tl_bins=0, tl_bucket=60.0, keep_responses=False,
                   resil=None, trace=False):
    """Lane-batched run + on-device metric reduction. Means and
    slowdowns come from the streaming accumulators in *both* modes (so
    streamed and exact sweeps agree bitwise); p99 is exact in exact
    mode and one-bin-accurate from the histogram in streaming mode.
    ``keep_responses`` (exact mode only) additionally returns the
    (L, N) per-request response vector — the CDF/percentile surface
    `repro.api.ExperimentSpec(keep_per_request=True)` exposes.
    ``n_live`` ((L,) i32) marks lanes as ragged prefixes of their
    padded trace rows (see `_simulate`); means/quantiles then reduce
    over each lane's live prefix only."""
    if keep_responses and stream:
        raise ValueError("keep_responses requires stream=False")
    out = _simulate(fn, arr, ex, cold, ev, tix, masks, betas, prior,
                    threshold, n_live, deadlines, rs_nfail, rs_tmo,
                    rs_key, kernel=kernel,
                    n_fns=n_fns, capacity=capacity, queue_cap=queue_cap,
                    stream=stream, window=window, tl_bins=tl_bins,
                    tl_bucket=tl_bucket, resil=resil, trace=trace)
    N = fn.shape[1]
    if resil is not None:
        # under faults only successes fold into the response sums and
        # per-request records; means/quantiles reduce over those
        denom = jnp.maximum(out["done"], 1).astype(jnp.float64)
    elif n_live is None:
        denom = N
    else:
        n_live = jnp.asarray(n_live, jnp.int32)
        denom = jnp.maximum(n_live, 1).astype(jnp.float64)
    if stream:
        if resil is not None:
            nq = out["done"][:, None]
        else:
            nq = N if n_live is None else n_live[:, None]
        p99 = hist_quantile(out["resp_hist"], 0.99, nq,
                            out["max_response"])
    else:
        resp = out["completion"] - arr[tix]
        if resil is not None:
            # shed / retry-exhausted rids keep completion == -1
            resp = jnp.where(out["completion"] >= 0, resp, jnp.nan)
            p99 = jnp.nanpercentile(resp, 99.0, axis=1)
        elif n_live is None:
            p99 = jnp.percentile(resp, 99.0, axis=1)
        else:
            live = jnp.arange(N) < n_live[:, None]
            p99 = jnp.nanpercentile(
                jnp.where(live, resp, jnp.nan), 99.0, axis=1)
    res = dict(mean_response=out["resp_sum"] / denom,
               mean_slowdown=out["slow_sum"] / denom,
               resp_sum=out["resp_sum"],
               slow_sum=out["slow_sum"],
               done=out["done"],
               p99_response=p99,
               max_response=out["max_response"],
               resp_hist=out["resp_hist"],
               cold_starts=out["cold_starts"],
               cold_time=out["cold_time"],
               evictions=out["evictions"],
               overflow=out["overflow"],
               stalled=out["stalled"])
    if tl_bins:
        res["tl_count"] = out["tl_count"]
        res["tl_resp_sum"] = out["tl_resp_sum"]
        res["tl_exec_sum"] = out["tl_exec_sum"]
    if deadlines is not None:
        res["deadline_miss"] = out["deadline_miss"]
    if resil is not None:
        for key in ("failed", "timed_out", "retried", "shed",
                    "failed_exhausted"):
            res[key] = out[key]
    if keep_responses:
        res["response"] = resp
    return res


def goodput(done, n):
    """Fraction of offered requests that eventually completed
    successfully: ``done / n``. Computed in numpy *outside* jit and
    shared by every tier (like `slo_attainment`) so the derived metric
    is bitwise identical no matter which tier produced the counters."""
    return (np.asarray(done, np.float64)
            / np.maximum(np.asarray(n, np.float64), 1.0))


def slo_attainment(deadline_miss, done):
    """Fraction of completed requests that met their per-fn deadline:
    ``1 - deadline_miss.sum(-1) / done``. Computed in numpy *outside*
    jit and shared by every tier (single-node runner, dynamic cluster,
    static merge) so the derived metric is bitwise identical no matter
    which tier produced the counters."""
    miss = np.asarray(deadline_miss)
    d = np.maximum(np.asarray(done, dtype=np.float64), 1.0)
    return 1.0 - miss.sum(axis=-1) / d


def sweep(traces: Union[Trace, Sequence[Trace], dict, Sequence[dict]],
          policies: Sequence[str] = ("esff", "esff_h", "sff",
                                     "openwhisk", "faascache",
                                     "openwhisk_v2"),
          capacities: Sequence[int] = (8, 16, 32),
          betas=None, *, queue_cap: int = 2048, prior: float = 0.1,
          threshold: float = 0.1, stream: bool = True,
          window: int = 0, tl_bins: int = 0, tl_bucket: float = 60.0,
          lane_chunk: Union[int, str, None] = None
          ) -> Dict[str, np.ndarray]:
    """Deprecated batched-sweep entry point (use `repro.api`).

    This is now a thin shim over the declarative experiment API: the
    arguments are packed into a `repro.api.ExperimentSpec`, executed by
    `repro.api.run_experiment` (the same `_sweep_metrics` lanes, same
    chunk order, so outputs are bitwise identical — gated by
    ``benchmarks/run.py --smoke`` and ``tests/test_api.py``), and the
    `ResultSet` is flattened back into the legacy dict of
    (P, T, K, B)-shaped metric arrays plus the ``"axes"`` dict.

    Prefer::

        from repro.api import ExperimentSpec, run
        rs = run(ExperimentSpec(traces=[...], policies=...,
                                capacities=...))

    which adds labeled selection, CSV/npz round-trips, multi-device
    and multi-host sharding, and registry-backed custom policies.
    """
    import warnings
    warnings.warn(
        "repro.core.jax_engine.sweep() is deprecated; build a "
        "repro.api.ExperimentSpec and call repro.api.run() instead "
        "(see docs/api.md)", DeprecationWarning, stacklevel=2)
    from repro.api import ExperimentSpec
    from repro.api.runner import legacy_sweep_dict, run_experiment
    if isinstance(traces, (Trace, dict)):
        traces = [traces]
    traces = list(traces)
    spec = ExperimentSpec(
        traces=traces, policies=policies, capacities=capacities,
        betas=betas, queue_cap=queue_cap, prior=prior,
        threshold=threshold, stream=stream, window=window,
        tl_bins=tl_bins, tl_bucket=tl_bucket, lane_chunk=lane_chunk,
        devices=1)
    return legacy_sweep_dict(run_experiment(spec), len(traces))


# ---------------------------------------------------------- audit hooks
# Pure metadata for `repro.analysis` (the jaxpr/HLO invariant auditor):
# nothing in the hot loops reads any of this. Every carried array that
# is *allowed* to scale with the trace length N carries a rationale
# here; the carry-budget analyzer fails on any N-scaling carry whose
# (shape-class, dtype) signature is not claimed by one of these rails.
CARRY_RAILS = {
    "start": "exact mode records every request's dispatch time -- the "
             "(L, N) record *is* the requested output, not loop "
             "bookkeeping (streaming mode folds it away).",
    "completion": "exact mode's per-request completion-time record; "
                  "same contract as `start`.",
    "nxt": "resilience rid-chain: per-function FIFO successor links, "
           "one i32 per request. Retries re-enqueue old rids, which "
           "breaks the positional-cursor invariant, so the linked "
           "spelling is the documented O(N) cost of the layer.",
    "att": "resilience attempt counter per original rid; i32, "
           "written once per retry.",
    "rt_t": "resilience retry-eligibility time per rid (backoff "
            "target); f64, written once per retry.",
    "tr_i": "telemetry trace rail (trace=True only): (L, SEG, TR_RI) "
            "i32 record overlay, reset per segment and flushed to "
            "the host through an ordered io_callback -- O(SEG) "
            "carried state, never N-scaling.",
    "tr_f": "telemetry trace rail float half ((L, SEG, TR_RF) f64); "
            "same contract as `tr_i`.",
}


def audit_jits():
    """Jitted engine entry points by name, for `repro.analysis` and
    the recompilation auditor (cache introspection via
    ``_cache_size``/``clear_cache``)."""
    return {"simulate": _simulate, "sweep_metrics": _sweep_metrics}
