"""Policy-agnostic fixed-shape event core for vectorised scheduling.

The Python event engine (`repro.core.simulator`) replays ~10^4 req/s;
policy x capacity x trace sweeps need orders of magnitude more. This
module owns everything that is *policy independent* about simulating a
C-slot edge server in JAX — the state layout, the queue ops, the slot
primitives (`dispatch` / `start_cold`), the running-mean estimator and
the ``lax.while_loop`` event loop — while the *decisions* live in
pure-function policy kernels (`repro.core.jax_policies`). A kernel is
selected by a static argument, so ``jax.jit`` specialises the loop body
per policy, and the engine carries a leading *lane* dimension so a whole
policy x capacity x beta x trace grid runs as one device call (`sweep`).

State layout (static F functions, C slots, N requests, L lanes; all
arrays carry the leading L):

  slots:  slot_fn    (C,) i32  function resident in the slot (-1 empty)
          slot_state (C,) i32  {0 COLD (warming), 1 IDLE, 2 BUSY}
          slot_ready (C,) f64  next slot event time (cold-done for COLD,
                               exec-done for BUSY; BIG when IDLE/empty)
          slot_req   (C,) i32  request id being executed (BUSY only)
          slot_used  (C,) f64  last dispatch time (LRU bookkeeping;
                               0.0 for a never-used instance)
          slot_seq   (C,) i32  creation sequence number of the resident
                               instance — mirrors the Python engine's
                               monotonically increasing ``inst_id`` so
                               iteration-order tie-breaks (LRU, victim
                               scans) reproduce exactly
  queues: per-function FIFOs as a successor linked list over requests —
          q_next (N,) i32 (next queued request of the same function),
          q_head_rid/q_tail_rid (F,) i32, q_len (F,) i32. A request is
          queued at most once, so each link is written at most once.
          ``queue_cap`` bounds the backlog: a push onto a function with
          queue_cap waiting requests is dropped and counted in
          ``overflow`` (must stay 0 for a valid run).
  est:    est_sum/est_n (F,) + g_sum/g_n () — running means of observed
          execution times with global-mean, then `prior`, fallback
  timers: original timers ride the queue push order (they are armed
          exactly at q_push, at the request's arrival time, so the fire
          time is arrival + threshold and the successor is q_next) —
          tmr_head_rid/tmr_len (F,) i32 + tmr_next (F,) f64 head fire
          time; re-arms (only ever the current queue head) get a
          one-slot cache rearm_t (F,) f64 / rearm_rid (F,) i32.
          Allocated only when the kernel sets ``has_timers``.
  out:    start/completion (N,) f64, cold_starts/evictions/overflow i32,
          cold_time/evict_time f64, stalled i32

Event arbitration mirrors `repro.core.events`: at equal times
EXEC_DONE < COLD_DONE < TIMER < ARRIVAL, so capacity freed at time t is
visible to an arrival at the same t. ``cap_mask`` masks slots so
capacity is sweepable across lanes without retracing; ``stalled`` flags
lanes that ran out of events or iteration budget before every request
completed (overflowed requests can never finish).

Performance shape — the three rules the layout follows, measured on the
XLA CPU backend:

1. *No control flow inside the body.* Every handler runs every
   iteration gated by an ``on`` predicate, and all writes are guarded
   scatters — ``mode="drop"`` with an out-of-bounds sentinel index when
   disabled (`_gidx`). A ``lax.cond`` under vmap lowers to a `select`
   over every carried array, i.e. a dense copy of the whole state per
   event.
2. *Lanes live inside the loop.* One ``while_loop`` carries (L, ...)
   state and the branchless body is vmapped per lane; finished lanes
   no-op through their guards. Vmapping the ``while_loop`` itself would
   mask finished lanes with per-event dense selects over all state.
3. *No large carried array is both gathered and scattered in one loop
   body.* XLA's copy-insertion materialises a full copy of such a
   buffer every iteration (~200 KB per event for a ring layout — the
   dominant cost of a naive spelling). Hence the linked-list queue: the
   only per-event read of a large carried array is the successor lookup
   at pop time, and those reads go through a small per-segment overlay
   (w_idx/w_val) while the writes are batch-applied to ``q_next`` once
   per SEG-event segment, amortising the one unavoidable copy.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Sequence, Union

# The engine's event loop is hundreds of tiny fused ops per simulated
# event; XLA:CPU's thunk runtime pays a dispatch overhead per op that
# slows the loop ~10x vs the legacy single-LLVM-function emitter. Ask
# for the legacy runtime before JAX initialises its CPU client (no-op
# for other backends, and respected only if the backend isn't live yet;
# callers can override by setting the flag themselves).
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_cpu_use_thunk_runtime" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_cpu_use_thunk_runtime=false").strip()

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax import lax             # noqa: E402

from repro.core.request import Trace  # noqa: E402

BIG = 1e30
COLD, IDLE, BUSY = 0, 1, 2
I32_MAX = np.iinfo(np.int32).max
SEG = 32          # events per segment (deferred q_next write window)
LANE_CHUNK = 16   # lanes per device call (XLA:CPU regresses beyond)


def ensure_x64() -> None:
    """Enable f64 before anything is traced.

    Event times need f64 for exact agreement with the Python engine over
    multi-hour traces. Flipping the flag mid-run (the old
    ``simulate_jax_from_trace`` behaviour) invalidates already-traced
    f32 jits elsewhere; importing this module instead performs the
    switch once, at import time, before the engine traces anything.
    """
    if not jax.config.jax_enable_x64:
        jax.config.update("jax_enable_x64", True)


ensure_x64()


class EngineCtx:
    """Per-lane view of the run handed to policy kernels.

    Bundles the (traced) trace arrays, the (static) shape constants, the
    scalar knobs and the current segment step ``k``. Built inside the
    jitted entry point — it never crosses a jit boundary itself.

    Trace arrays are *shared* (T, ...) operands indexed by the lane's
    ``tix``: under vmap a gather whose operand is unbatched lowers to a
    single efficient gather, whereas a batched operand takes a generic
    path that is orders of magnitude slower on the CPU backend. The
    per-request reads (`fn_at` / `arrival_at` / `exec_at`, and `next_of`
    over the lane-flattened ``q_next``) all go through that fast path.
    """

    def __init__(self, *, fn_id2, arrival2, exec2, cold2, evict2, tix,
                 lane, q_next_flat, cap_mask, beta, prior, threshold,
                 k, n, f, c, q):
        self._fn = fn_id2          # (T, N) shared
        self._arr = arrival2       # (T, N) shared
        self._ex = exec2           # (T, N) shared
        self.tix = tix             # this lane's trace index
        self.t_cold = cold2[tix]   # (F,) row of the shared (T, F)
        self.t_evict = evict2[tix]
        self._q_next = q_next_flat  # (L*N,) shared view of the links
        self._off = lane * n
        self.cap_mask = cap_mask
        self.beta = beta
        self.prior = prior
        self.threshold = threshold
        self.k = k                  # segment step (overlay slot)
        self.N, self.F, self.C, self.Q = n, f, c, q

    def fn_at(self, rid):
        return self._fn[self.tix, jnp.clip(rid, 0, self.N - 1)]

    def arrival_at(self, rid):
        return self._arr[self.tix, jnp.clip(rid, 0, self.N - 1)]

    def exec_at(self, rid):
        return self._ex[self.tix, jnp.clip(rid, 0, self.N - 1)]

    def next_of(self, rid):
        return self._q_next[self._off + jnp.clip(rid, 0, self.N - 1)]


class PolicyKernel:
    """Interface a vectorised policy implements over the engine state.

    Each hook is a pure function ``state -> state`` gated by an ``on``
    predicate (guarded-write style — hooks run every iteration, their
    writes are masked); the engine has already done the
    policy-independent bookkeeping — cursor advance for arrivals,
    estimator update + slot release for exec-done, slot release for
    cold-done, timer consumption for timers — exactly mirroring
    `repro.core.simulator.simulate`.
    """

    name = "base"
    has_timers = False
    default_beta = 1.0

    def on_arrival(self, ctx, s, rid, t, on):
        raise NotImplementedError

    def on_cold_done(self, ctx, s, slot, t, on):
        raise NotImplementedError

    def on_exec_done(self, ctx, s, slot, rid, t, on):
        raise NotImplementedError

    def on_timer(self, ctx, s, rid, t, on):  # pragma: no cover
        return s


# --------------------------------------------------------------- helpers
def _gidx(on, idx, size):
    """Guarded scatter index: ``idx`` when enabled and valid, else an
    out-of-bounds sentinel that ``mode="drop"`` discards."""
    return jnp.where(on & (idx >= 0), idx, size)


def lex_argmin(primary, secondary, valid):
    """First index minimising ``(primary, secondary)`` among ``valid``.

    Reproduces the Python engine's deterministic scans: iterate in
    ``secondary`` (creation / fn-id) order, keep on strict improvement.
    """
    p = jnp.where(valid, primary, BIG)
    tie = valid & (p <= jnp.min(p))
    return jnp.argmin(jnp.where(tie, secondary, I32_MAX))


def argmin_i32(vals, valid):
    """First valid index minimising an i32 key (sentinel-masked)."""
    return jnp.argmin(jnp.where(valid, vals, I32_MAX))


def est_means(ctx, s):
    """Per-function running means with global-mean / prior fallback."""
    counts = s["est_n"].astype(jnp.float64)
    gcount = s["g_n"].astype(jnp.float64)
    g = jnp.where(s["g_n"] > 0, s["g_sum"] / jnp.maximum(gcount, 1),
                  ctx.prior)
    return jnp.where(s["est_n"] > 0,
                     s["est_sum"] / jnp.maximum(counts, 1), g)


def k_counts(ctx, s):
    """|K^j| — slots assigned to each function, any state."""
    return jnp.zeros((ctx.F,), jnp.int32).at[
        jnp.where(s["slot_fn"] >= 0, s["slot_fn"], jnp.int32(ctx.F))
    ].add(jnp.int32(1), mode="drop")


def cold_counts(ctx, s):
    """Slots currently warming up (state COLD) per function."""
    warming = s["slot_state"] == COLD
    return jnp.zeros((ctx.F,), jnp.int32).at[
        jnp.where((s["slot_fn"] >= 0) & warming, s["slot_fn"],
                  jnp.int32(ctx.F))
    ].add(jnp.int32(1), mode="drop")


def idle_own(ctx, s, fn):
    """Mask of usable idle slots already resident with ``fn``."""
    return ((s["slot_fn"] == fn) & (s["slot_state"] == IDLE)
            & ctx.cap_mask)


def pick_idle_own(ctx, s, fn):
    """(mask.any(), earliest-created idle own slot) — Python's
    ``idle_of`` picks the lowest ``inst_id``."""
    mask = idle_own(ctx, s, fn)
    return mask.any(), argmin_i32(s["slot_seq"], mask)


def q_read_next(ctx, s, rid):
    """Successor of ``rid`` in its function's queue: the per-segment
    overlay first (links written since the last q_next flush), else the
    q_next snapshot. Each link is written at most once, so at most one
    overlay slot can match."""
    snap = ctx.next_of(rid)
    hit = s["w_idx"] == rid
    return jnp.where(hit.any(), s["w_val"][jnp.argmax(hit)], snap)


def q_head(ctx, s, fn):
    """Request id at the head of ``fn``'s queue (garbage when empty —
    callers gate on ``q_len``)."""
    return s["q_head_rid"][jnp.clip(fn, 0, ctx.F - 1)]


def q_push(ctx, s, fn, rid, on):
    """Append ``rid``; returns (state, pushed). A push onto a full
    backlog (q_len == queue_cap) is dropped and counted in overflow."""
    fc = jnp.clip(fn, 0, ctx.F - 1)
    was_empty = s["q_len"][fc] == 0
    full = s["q_len"][fc] >= ctx.Q
    do = on & ~full
    fi = _gidx(do, fn, ctx.F)
    link = do & ~was_empty
    s = dict(s)
    # successor link from the old tail — deferred to the segment flush
    s["w_idx"] = s["w_idx"].at[ctx.k].set(
        jnp.where(link, s["q_tail_rid"][fc], jnp.int32(ctx.N)))
    s["w_val"] = s["w_val"].at[ctx.k].set(jnp.asarray(rid, jnp.int32))
    s["q_head_rid"] = s["q_head_rid"].at[
        _gidx(do & was_empty, fn, ctx.F)].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["q_tail_rid"] = s["q_tail_rid"].at[fi].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["q_len"] = s["q_len"].at[fi].add(1, mode="drop")
    s["overflow"] = s["overflow"] + (on & full).astype(jnp.int32)
    return s, do


def q_pop(ctx, s, fn, on):
    """Consume the head of ``fn``'s queue; returns (state, rid)."""
    rid = q_head(ctx, s, fn)
    succ = q_read_next(ctx, s, rid)
    fi = _gidx(on, fn, ctx.F)
    s = dict(s)
    # when the queue empties the head is garbage until the next push
    # (which sees q_len == 0 and rewrites it) — reads gate on q_len
    s["q_head_rid"] = s["q_head_rid"].at[fi].set(succ, mode="drop")
    s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
    return s, rid


def arm_timer(ctx, s, fn, rid, on):
    """Register the original timer of a just-pushed request.

    Original timers fire at arrival + threshold in push order, so they
    share the queue's successor links; only the head bookkeeping is
    materialised."""
    fc = jnp.clip(fn, 0, ctx.F - 1)
    was_empty = s["tmr_len"][fc] == 0
    hi = _gidx(on & was_empty, fn, ctx.F)
    s = dict(s)
    s["tmr_head_rid"] = s["tmr_head_rid"].at[hi].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["tmr_next"] = s["tmr_next"].at[hi].set(
        ctx.arrival_at(rid) + ctx.threshold, mode="drop")
    s["tmr_len"] = s["tmr_len"].at[_gidx(on, fn, ctx.F)].add(
        1, mode="drop")
    return s


def rearm_timer(ctx, s, fn, rid, t_fire, on):
    """Re-arm the (unique) blocked queue head of ``fn`` at ``t_fire``."""
    fi = _gidx(on, fn, ctx.F)
    s = dict(s)
    s["rearm_t"] = s["rearm_t"].at[fi].set(t_fire, mode="drop")
    s["rearm_rid"] = s["rearm_rid"].at[fi].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    return s


def dispatch(ctx, s, slot, rid, t, on):
    """Run ``rid`` on an idle ``slot`` of its function.

    The per-request start/completion record goes into the segment
    overlay (d_*), not the (N,) result arrays — those are flushed once
    per segment so no large carried array is touched per event. At most
    one dispatch happens per event (call sites are mutually exclusive),
    so the overlay slot is indexed by the segment step and disabled
    sites drop instead of clobbering it."""
    s = dict(s)
    comp = t + ctx.exec_at(rid)
    si = _gidx(on, slot, ctx.C)
    ki = jnp.where(on, ctx.k, SEG)
    s["slot_state"] = s["slot_state"].at[si].set(BUSY, mode="drop")
    s["slot_ready"] = s["slot_ready"].at[si].set(comp, mode="drop")
    s["slot_req"] = s["slot_req"].at[si].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["slot_used"] = s["slot_used"].at[si].set(t, mode="drop")
    s["d_rid"] = s["d_rid"].at[ki].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["d_start"] = s["d_start"].at[ki].set(t, mode="drop")
    s["d_comp"] = s["d_comp"].at[ki].set(comp, mode="drop")
    return s


def start_cold(ctx, s, slot, fn, t, evict_fn, on):
    """Claim/convert ``slot`` for ``fn`` (``evict_fn`` = -1 -> empty slot,
    otherwise the resident function paying its eviction cost first)."""
    s = dict(s)
    fn = jnp.asarray(fn, jnp.int32)  # argmin/argmax indices are i64
    evict_fn = jnp.asarray(evict_fn, jnp.int32)
    fc = jnp.clip(fn, 0, ctx.F - 1)
    evicting = on & (evict_fn >= 0)
    ev_cost = jnp.where(evicting,
                        ctx.t_evict[jnp.clip(evict_fn, 0, ctx.F - 1)],
                        0.0)
    si = _gidx(on, slot, ctx.C)
    s["slot_fn"] = s["slot_fn"].at[si].set(fn, mode="drop")
    s["slot_state"] = s["slot_state"].at[si].set(COLD, mode="drop")
    s["slot_ready"] = s["slot_ready"].at[si].set(
        t + ctx.t_cold[fc] + ev_cost, mode="drop")
    s["slot_req"] = s["slot_req"].at[si].set(-1, mode="drop")
    s["slot_used"] = s["slot_used"].at[si].set(0.0, mode="drop")
    s["slot_seq"] = s["slot_seq"].at[si].set(s["seq_ctr"], mode="drop")
    on_i = on.astype(jnp.int32)
    s["seq_ctr"] = s["seq_ctr"] + on_i
    s["cold_starts"] = s["cold_starts"] + on_i
    s["cold_time"] = s["cold_time"] + jnp.where(on, ctx.t_cold[fc], 0.0)
    s["evictions"] = s["evictions"] + evicting.astype(jnp.int32)
    s["evict_time"] = s["evict_time"] + ev_cost
    return s


# ------------------------------------------------------------ event loop
@functools.partial(jax.jit,
                   static_argnames=("kernel", "n_fns", "capacity",
                                    "queue_cap"))
def _simulate(fn_id, arrival, exec_time, t_cold, t_evict, trace_ix,
              cap_mask, beta, prior, threshold, *, kernel, n_fns,
              capacity, queue_cap):
    """Lane-batched engine. Trace arrays are shared (T, ...) operands;
    ``trace_ix``, ``cap_mask`` and ``beta`` carry the leading lane
    dimension L (one lane per sweep point). One ``while_loop`` runs all
    lanes in segments of SEG events; the branchless per-event body is
    vmapped per lane and finished lanes no-op via their guards."""
    L = trace_ix.shape[0]
    N = fn_id.shape[1]
    F, C, Q = n_fns, capacity, queue_cap

    fn_id = fn_id.astype(jnp.int32)
    arrival = arrival.astype(jnp.float64)
    exec_time = exec_time.astype(jnp.float64)
    t_cold = t_cold.astype(jnp.float64)
    t_evict = t_evict.astype(jnp.float64)
    trace_ix = trace_ix.astype(jnp.int32)
    prior = jnp.float64(prior)
    threshold = jnp.float64(threshold)

    s = dict(
        slot_fn=jnp.full((L, C), -1, jnp.int32),
        slot_state=jnp.full((L, C), IDLE, jnp.int32),
        slot_ready=jnp.full((L, C), BIG, jnp.float64),
        slot_req=jnp.full((L, C), -1, jnp.int32),
        slot_used=jnp.zeros((L, C), jnp.float64),
        slot_seq=jnp.full((L, C), I32_MAX, jnp.int32),
        q_next=jnp.full((L * N,), -1, jnp.int32),
        q_head_rid=jnp.full((L, F), -1, jnp.int32),
        q_tail_rid=jnp.full((L, F), -1, jnp.int32),
        q_len=jnp.zeros((L, F), jnp.int32),
        w_idx=jnp.full((L, SEG), N, jnp.int32),
        w_val=jnp.full((L, SEG), -1, jnp.int32),
        d_rid=jnp.full((L, SEG), N, jnp.int32),
        d_start=jnp.zeros((L, SEG), jnp.float64),
        d_comp=jnp.zeros((L, SEG), jnp.float64),
        est_sum=jnp.zeros((L, F), jnp.float64),
        est_n=jnp.zeros((L, F), jnp.int32),
        g_sum=jnp.zeros((L,), jnp.float64),
        g_n=jnp.zeros((L,), jnp.int32),
        seq_ctr=jnp.zeros((L,), jnp.int32),
        start=jnp.full((L, N), -1.0, jnp.float64),
        completion=jnp.full((L, N), -1.0, jnp.float64),
        next_arrival=jnp.zeros((L,), jnp.int32),
        done=jnp.zeros((L,), jnp.int32),
        iters=jnp.zeros((L,), jnp.int32),
        stalled=jnp.zeros((L,), jnp.int32),
        cold_starts=jnp.zeros((L,), jnp.int32),
        cold_time=jnp.zeros((L,), jnp.float64),
        evictions=jnp.zeros((L,), jnp.int32),
        evict_time=jnp.zeros((L,), jnp.float64),
        overflow=jnp.zeros((L,), jnp.int32),
    )
    if kernel.has_timers:
        s["tmr_head_rid"] = jnp.full((L, F), -1, jnp.int32)
        s["tmr_len"] = jnp.zeros((L, F), jnp.int32)
        s["tmr_next"] = jnp.full((L, F), BIG, jnp.float64)
        s["rearm_t"] = jnp.full((L, F), BIG, jnp.float64)
        s["rearm_rid"] = jnp.full((L, F), -1, jnp.int32)

    max_iters = 256 * N + 4096

    def lane_step(k, q_next_flat, s, lane, tix, cap_mask, beta):
        ctx = EngineCtx(fn_id2=fn_id, arrival2=arrival, exec2=exec_time,
                        cold2=t_cold, evict2=t_evict, tix=tix,
                        lane=lane, q_next_flat=q_next_flat,
                        cap_mask=cap_mask, beta=beta, prior=prior,
                        threshold=threshold, k=k, n=N, f=F, c=C, q=Q)
        active = (s["done"] < N) & (s["stalled"] == 0)
        na = s["next_arrival"]
        t_arr = jnp.where(na < N, ctx.arrival_at(na), BIG)
        ready = jnp.where(cap_mask, s["slot_ready"], BIG)
        t_slot = jnp.min(ready)
        if kernel.has_timers:
            t_orig = jnp.min(s["tmr_next"])
            t_re = jnp.min(s["rearm_t"])
            t_timer = jnp.minimum(t_orig, t_re)
        else:
            t_timer = jnp.float64(BIG)
        t_next = jnp.minimum(jnp.minimum(t_slot, t_timer), t_arr)
        live = active & (t_next < BIG)
        # same-time priority: EXEC/COLD (slot) < TIMER < ARRIVAL
        ev_slot = live & (t_slot <= jnp.minimum(t_timer, t_arr))
        ev_timer = live & ~ev_slot & (t_timer <= t_arr)
        ev_arr = live & ~ev_slot & ~ev_timer

        # ------------------------------------------------- slot event
        # EXEC_DONE outranks COLD_DONE at equal times (events.py order)
        slot = lex_argmin(
            jnp.where(s["slot_state"] == BUSY, 0.0, 1.0),
            jnp.arange(C, dtype=jnp.int32), ready <= t_slot)
        t_s = s["slot_ready"][slot]
        is_cold = s["slot_state"][slot] == COLD
        cold_on = ev_slot & is_cold
        exec_on = ev_slot & ~is_cold
        rid_done = s["slot_req"][slot]
        j_done = s["slot_fn"][slot]
        e_done = ctx.exec_at(rid_done)
        si = _gidx(ev_slot, slot, C)
        ji = _gidx(exec_on, j_done, F)
        s = dict(s)
        s["slot_state"] = s["slot_state"].at[si].set(IDLE, mode="drop")
        s["slot_ready"] = s["slot_ready"].at[si].set(BIG, mode="drop")
        s["slot_req"] = s["slot_req"].at[si].set(-1, mode="drop")
        # estimator sees the completion before the policy reacts
        s["est_sum"] = s["est_sum"].at[ji].add(e_done, mode="drop")
        s["est_n"] = s["est_n"].at[ji].add(1, mode="drop")
        s["g_sum"] = s["g_sum"] + jnp.where(exec_on, e_done, 0.0)
        s["g_n"] = s["g_n"] + exec_on.astype(jnp.int32)
        s["done"] = s["done"] + exec_on.astype(jnp.int32)
        s = kernel.on_cold_done(ctx, s, slot, t_s, cold_on)
        s = kernel.on_exec_done(ctx, s, slot, rid_done, t_s, exec_on)

        # ------------------------------------------------ timer event
        if kernel.has_timers:
            # originals (arrival + threshold, queue push order) vs the
            # unique re-armed head; originals win exact ties (FIFO seq)
            fire_orig = ev_timer & (t_orig <= t_re)
            fire_re = ev_timer & ~fire_orig
            f_o = jnp.argmin(s["tmr_next"])
            rid_o = s["tmr_head_rid"][f_o]
            succ = q_read_next(ctx, s, rid_o)
            more = s["tmr_len"][f_o] > 1
            oi = _gidx(fire_orig, f_o, F)
            f_r = jnp.argmin(s["rearm_t"])
            rid_r = s["rearm_rid"][f_r]
            s = dict(s)
            s["tmr_head_rid"] = s["tmr_head_rid"].at[oi].set(
                succ, mode="drop")
            s["tmr_next"] = s["tmr_next"].at[oi].set(
                jnp.where(more, ctx.arrival_at(succ) + threshold, BIG),
                mode="drop")
            s["tmr_len"] = s["tmr_len"].at[oi].add(-1, mode="drop")
            s["rearm_t"] = s["rearm_t"].at[
                _gidx(fire_re, f_r, F)].set(BIG, mode="drop")
            rid_t = jnp.where(fire_orig, rid_o, rid_r)
            s = kernel.on_timer(ctx, s, rid_t, t_timer, ev_timer)

        # ---------------------------------------------------- arrival
        rid_a = jnp.minimum(na, N - 1)
        s = dict(s)
        s["next_arrival"] = na + ev_arr.astype(jnp.int32)
        s = kernel.on_arrival(ctx, s, rid_a, t_arr, ev_arr)

        s = dict(s)
        s["iters"] = s["iters"] + active.astype(jnp.int32)
        s["stalled"] = jnp.where(
            active & ~live, 1,
            jnp.where(active & (s["iters"] >= max_iters), 2,
                      s["stalled"]))
        return s

    step_lanes = jax.vmap(lane_step, in_axes=(None, None, 0, 0, 0, 0,
                                              0))
    lanes = jnp.arange(L, dtype=jnp.int32)
    lane_iota = lanes[:, None]

    def cond(s):
        return jnp.any((s["done"] < N) & (s["stalled"] == 0))

    def segment(s):
        s = dict(s)
        s["w_idx"] = jnp.full((L, SEG), N, jnp.int32)
        s["w_val"] = jnp.full((L, SEG), -1, jnp.int32)
        s["d_rid"] = jnp.full((L, SEG), N, jnp.int32)

        def step(k, s):
            q_next_flat = s["q_next"]   # read-only within the segment
            rest = {k2: v for k2, v in s.items() if k2 != "q_next"}
            rest = step_lanes(k, q_next_flat, rest, lanes, trace_ix,
                              cap_mask, beta)
            rest["q_next"] = q_next_flat
            return rest

        s = lax.fori_loop(0, SEG, step, s)
        # flush the segment's successor links and dispatch records in
        # one batched scatter each — the only writes to the large (N,)
        # carried arrays, so their per-iteration copies are paid once
        # per SEG events, not per event
        s = dict(s)
        flat_w = jnp.where(s["w_idx"] < N,
                           lane_iota * N + s["w_idx"],
                           jnp.int32(L * N))
        s["q_next"] = s["q_next"].at[flat_w].set(s["w_val"],
                                                 mode="drop")
        s["start"] = s["start"].at[lane_iota, s["d_rid"]].set(
            s["d_start"], mode="drop")
        s["completion"] = s["completion"].at[lane_iota, s["d_rid"]].set(
            s["d_comp"], mode="drop")
        return s

    final = lax.while_loop(cond, segment, s)
    return dict(start=final["start"], completion=final["completion"],
                cold_starts=final["cold_starts"],
                cold_time=final["cold_time"],
                evictions=final["evictions"],
                evict_time=final["evict_time"],
                overflow=final["overflow"], stalled=final["stalled"],
                n_events=final["iters"])


# ------------------------------------------------------------ public API
def simulate_policy_jax(fn_id, arrival, exec_time, t_cold, t_evict, *,
                        policy: str = "esff", n_fns: int, capacity: int,
                        queue_cap: int = 512, beta=None,
                        prior: float = 0.1, threshold: float = 0.1,
                        cap_mask=None) -> Dict[str, jnp.ndarray]:
    """Run ``policy`` over a (sorted-by-arrival) request stream.

    ``policy`` selects a kernel from `repro.core.jax_policies.KERNELS`
    statically, so each policy gets its own jit specialisation. ``beta``
    defaults to the kernel's own default (2.0 for ESFF-H, else 1.0).
    Returns per-request start/completion plus the counter block (cold
    starts, evictions, overflow, stalled).
    """
    from repro.core.jax_policies import KERNELS  # deferred: cycle-free
    kernel = KERNELS[policy]
    if beta is None:
        beta = kernel.default_beta
    if cap_mask is None:
        cap_mask = jnp.ones((capacity,), bool)
    share = lambda x: jnp.expand_dims(jnp.asarray(x), 0)  # noqa: E731
    out = _simulate(share(fn_id), share(arrival), share(exec_time),
                    share(t_cold), share(t_evict),
                    jnp.zeros((1,), jnp.int32),
                    jnp.expand_dims(jnp.asarray(cap_mask), 0),
                    jnp.asarray(beta, jnp.float64).reshape((1,)),
                    jnp.float64(prior), jnp.float64(threshold),
                    kernel=kernel, n_fns=n_fns, capacity=capacity,
                    queue_cap=queue_cap)
    return {k: jnp.squeeze(v, axis=0) for k, v in out.items()}


def simulate_policy_from_trace(trace: Trace, policy: str, capacity: int,
                               *, beta=None, queue_cap: int = 1024,
                               prior: float = 0.1,
                               threshold: float = 0.1
                               ) -> Dict[str, np.ndarray]:
    """Trace-object convenience wrapper mirroring ``simulate()``."""
    a = trace.to_arrays()
    out = simulate_policy_jax(
        jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
        jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
        jnp.asarray(a["evict"]), policy=policy,
        n_fns=trace.n_functions, capacity=capacity, queue_cap=queue_cap,
        beta=beta, prior=prior, threshold=threshold)
    out = {k: np.asarray(v) for k, v in out.items()}
    out["response"] = out["completion"] - a["arrival"]
    out["mean_response"] = float(out["response"].mean())
    return out


@functools.partial(jax.jit,
                   static_argnames=("kernel", "n_fns", "capacity",
                                    "queue_cap"))
def _sweep_metrics(fn, arr, ex, cold, ev, tix, masks, betas, prior,
                   threshold, *, kernel, n_fns, capacity, queue_cap):
    """Lane-batched run + on-device metric reduction (per-request
    arrays stay on device; only (L,) metric vectors come back)."""
    out = _simulate(fn, arr, ex, cold, ev, tix, masks, betas, prior,
                    threshold, kernel=kernel, n_fns=n_fns,
                    capacity=capacity, queue_cap=queue_cap)
    resp = out["completion"] - arr[tix]
    slow = resp / jnp.maximum(ex[tix], 1e-9)
    return dict(mean_response=resp.mean(axis=1),
                mean_slowdown=slow.mean(axis=1),
                p99_response=jnp.percentile(resp, 99.0, axis=1),
                cold_starts=out["cold_starts"],
                cold_time=out["cold_time"],
                evictions=out["evictions"],
                overflow=out["overflow"], stalled=out["stalled"])


def sweep(traces: Union[Trace, Sequence[Trace]],
          policies: Sequence[str] = ("esff", "esff_h", "sff",
                                     "openwhisk", "openwhisk_v2"),
          capacities: Sequence[int] = (8, 16, 32),
          betas=None, *, queue_cap: int = 2048, prior: float = 0.1,
          threshold: float = 0.1) -> Dict[str, np.ndarray]:
    """Batched policy x trace x capacity x beta sweep in one device call
    per policy.

    The grid is flattened to engine lanes: every (trace, capacity, beta)
    combination becomes one lane of a single lane-batched ``while_loop``
    (capacities as slot masks over a static ``capacity=max(capacities)``,
    so one jit specialisation per policy covers the whole grid).

    ``betas=None`` uses each kernel's default (so ESFF-H keeps its
    hysteresis). Returns metric arrays of shape (P, T, K, B) keyed by
    metric name, plus the axis values under ``"axes"``.
    """
    from repro.core.jax_policies import KERNELS
    if isinstance(traces, Trace):
        traces = [traces]
    traces = list(traces)
    F = traces[0].n_functions
    N = len(traces[0])
    for tr in traces:
        if tr.n_functions != F or len(tr) != N:
            raise ValueError("sweep traces must share shape "
                             "(n_functions, n_requests)")
    arrs = [tr.to_arrays() for tr in traces]
    stacked = {k: np.stack([np.asarray(a[k]) for a in arrs])
               for k in ("fn_id", "arrival", "exec_time", "cold_start",
                         "evict")}
    T, K = len(traces), len(capacities)
    C = max(capacities)
    masks = np.stack([np.arange(C) < c for c in capacities])

    shared = {k: jnp.asarray(v) for k, v in stacked.items()}

    def run_chunk(p, tix_l, mask_l, beta_l):
        out = _sweep_metrics(
            shared["fn_id"], shared["arrival"], shared["exec_time"],
            shared["cold_start"], shared["evict"], jnp.asarray(tix_l),
            jnp.asarray(mask_l), jnp.asarray(beta_l),
            jnp.float64(prior), jnp.float64(threshold),
            kernel=KERNELS[p], n_fns=F, capacity=C,
            queue_cap=queue_cap)
        return jax.device_get(out)

    chunks = []
    for p in policies:
        bs = np.asarray([KERNELS[p].default_beta] if betas is None
                        else list(betas), np.float64)
        B = len(bs)
        # lane order: trace-major, then capacity, then beta
        tix_l = np.repeat(np.arange(T, dtype=np.int32), K * B)
        mask_l = np.tile(np.repeat(masks, B, axis=0), (T, 1))
        beta_l = np.tile(bs, T * K)
        for lo in range(0, T * K * B, LANE_CHUNK):
            hi = lo + LANE_CHUNK
            chunks.append((p, tix_l[lo:hi], mask_l[lo:hi],
                           beta_l[lo:hi]))

    # device calls overlap on the host thread pool (XLA releases the
    # GIL while a computation runs); lanes are chunked to LANE_CHUNK
    # per call to stay in XLA:CPU's fast regime
    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(max_workers=2) as tp:
        outs = list(tp.map(lambda c: run_chunk(*c), chunks))

    per_policy = []
    for pi, p in enumerate(policies):
        B = 1 if betas is None else len(betas)
        mine = [o for c, o in zip(chunks, outs) if c[0] == p]
        cat = {k: np.concatenate([np.asarray(o[k]) for o in mine])
               for k in mine[0]}
        per_policy.append({k: v.reshape((T, K, B))
                           for k, v in cat.items()})

    out = {k: np.stack([r[k] for r in per_policy])
           for k in per_policy[0]}
    out["axes"] = dict(policy=list(policies), trace=len(traces),
                       capacity=list(capacities),
                       beta=(None if betas is None else list(betas)))
    return out
