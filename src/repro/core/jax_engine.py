"""Policy-agnostic fixed-shape event core for vectorised scheduling.

The Python event engine (`repro.core.simulator`) replays ~10^4 req/s;
policy x capacity x trace sweeps need orders of magnitude more. This
module owns everything that is *policy independent* about simulating a
C-slot edge server in JAX — the state layout, the queue ops, the slot
primitives (`dispatch` / `start_cold`), the running-mean estimator and
the ``lax.while_loop`` event loop — while the *decisions* live in
pure-function policy kernels (`repro.core.jax_policies`). A kernel is
selected by a static argument, so ``jax.jit`` specialises the loop body
per policy, and the engine carries a leading *lane* dimension so a whole
policy x capacity x beta x trace grid runs as one device call (`sweep`).

State layout (static F functions, C slots, N requests, L lanes; all
arrays carry the leading L):

  slots:  slot_fn    (C,) i32  function resident in the slot (-1 empty)
          slot_state (C,) i32  {0 COLD (warming), 1 IDLE, 2 BUSY}
          slot_ready (C,) f64  next slot event time (cold-done for COLD,
                               exec-done for BUSY; BIG when IDLE/empty)
          slot_req   (C,) i32  request id being executed (BUSY only)
          slot_used  (C,) f64  last dispatch time (LRU bookkeeping;
                               0.0 for a never-used instance)
          slot_seq   (C,) i32  creation sequence number of the resident
                               instance — mirrors the Python engine's
                               monotonically increasing ``inst_id`` so
                               iteration-order tie-breaks (LRU, victim
                               scans) reproduce exactly
  queues: per-function FIFOs as *position cursors* into the trace's
          per-function arrival order. The requests of f_j, sorted by
          id, are a loop-invariant shared operand (``pos_rids`` +
          ``pos_off`` built from a stable argsort of fn_id), and
          because every arrival of f_j consumes exactly one position —
          q_push for a queued arrival, q_consume_direct for a directly
          dispatched one — and pops are FIFO, the queue of f_j is
          always the contiguous position range
          [q_head_pos, q_head_pos + q_len). Head/successor lookups are
          gathers into the shared operand; the carried queue state is
          just q_head_pos/q_len (F,) i32 plus a q_head_rid (F,) i32
          cache (refreshed with the successor at pop time, so head
          reads — including the central-queue head scan — touch no
          large operand) — O(F) no matter how long a backlog gets (SFF
          starvation can hold a request queued for the whole trace).
          ``queue_cap`` bounds the per-function
          backlog: a push onto a function with queue_cap waiting
          requests is dropped and counted in ``overflow`` (must stay 0
          for a valid run; a dropped request breaks the position
          invariant, which is fine — the run is already invalid).
  est:    est_sum (F,) f64 / est_n (F,) i32 — running means of observed
          execution times with global-mean, then `prior`, fallback (the
          global accumulators live in the packed counters)
  timers: original timers fire at arrival + threshold in arrival
          order, so the rail rides the same per-function positions:
          tmr_pos (F,) i32 is the next position whose timer fires,
          arr_cnt (F,) i32 counts arrived positions, tmr_next (F,) f64
          is the head fire time. Every arrival arms its position;
          arrivals that dispatch directly while the rail is idle are
          consumed silently, and one that slips into a busy rail fires
          later as a no-op (the is-head gate drops it, exactly like the
          Python policy drops timers of already-served requests).
          Re-arms (only ever the current queue head) keep the one-slot
          cache rearm_t (F,) f64 / rearm_rid (F,) i32. Allocated only
          when the kernel sets ``has_timers``.
  ctrs:   ci (NCI,) i32 / cf (NCF,) f64 — every per-lane scalar counter
          (arrival cursor, done/iteration counts, stall flag, instance
          sequence, estimator globals, cold/eviction/overflow tallies
          and the streaming response accumulators) packed into two
          arrays so the while_loop carries 2 small buffers instead of
          a dozen scalars.
  out:    always: streaming metric accumulators — response sum,
          slowdown sum, response max (in cf) and ``hist`` (HIST_BINS,)
          i32, a fixed log-spaced response-time histogram (8 bins per
          decade over 1e-4..1e4 s) that serves p99 and CDFs to within
          one bin width. In *exact* mode (``stream=False``) additionally
          start/completion (N,) f64 per-request records.

Event arbitration mirrors `repro.core.events`: at equal times
EXEC_DONE < COLD_DONE < TIMER < ARRIVAL, so capacity freed at time t is
visible to an arrival at the same t. ``cap_mask`` masks slots so
capacity is sweepable across lanes without retracing; ``stalled`` flags
lanes that ran out of events or iteration budget before every request
completed (overflowed requests can never finish).

Performance shape — the five rules the layout follows, measured on the
XLA CPU backend:

1. *No control flow inside the body.* Every handler runs every
   iteration gated by an ``on`` predicate, and all writes are guarded
   scatters — ``mode="drop"`` with an out-of-bounds sentinel index when
   disabled (`_gidx`). A ``lax.cond`` under vmap lowers to a `select`
   over every carried array, i.e. a dense copy of the whole state per
   event.
2. *Lanes live inside the loop.* One ``while_loop`` carries (L, ...)
   state and the branchless body is vmapped per lane; finished lanes
   no-op through their guards. Vmapping the ``while_loop`` itself would
   mask finished lanes with per-event dense selects over all state.
3. *No large carried array is both gathered and scattered in one loop
   body.* XLA's copy-insertion materialises a full copy of such a
   buffer every iteration — the dominant cost of a naive spelling.
   Queues therefore never carry their contents at all: successor
   lookups are gathers into loop-invariant shared operands (which XLA
   neither copies nor scatters), and the only per-event writes touch
   O(F)/O(C) cursor arrays. Result records go through the small
   per-segment overlay (d_rid/d_start/d_comp), batch-applied once per
   SEG-event segment.
4. *Carried state is independent of trace length.* The dispatch
   overlay is *folded* at flush time into O(1) streaming accumulators
   (sums, max, histogram) instead of scattered into (L, N) arrays; the
   (L, N) per-request records exist only in exact mode
   (``stream=False``). A streaming lane carries
   O(F + C + SEG + HIST_BINS) state no matter how long the trace,
   which is what lets one machine sweep 10^6-request traces
   (benchmarks/engine_scale.py). Both modes run the identical fold, so
   streamed means are bit-identical to exact-mode means.
5. *One packed reduction picks the next event.* The candidate times of
   every event source — BUSY slots, COLD slots, original timers,
   re-arms, the arrival cursor — are concatenated in priority order and
   a single first-index ``argmin`` resolves both the time and the
   tie-break (position encodes EXEC < COLD < TIMER < ARRIVAL and the
   within-class index order), replacing three separate min-reductions
   plus lexicographic scans; small scalar counters ride the two packed
   ci/cf arrays so XLA:CPU dispatches fewer ops per event.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Sequence, Union

# The engine's event loop is hundreds of tiny fused ops per simulated
# event; XLA:CPU's thunk runtime pays a dispatch overhead per op that
# slows the loop ~10x vs the legacy single-LLVM-function emitter. Ask
# for the legacy runtime before JAX initialises its CPU client (no-op
# for other backends, and respected only if the backend isn't live yet;
# callers can override by setting the flag themselves).
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_cpu_use_thunk_runtime" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_cpu_use_thunk_runtime=false").strip()

import jax                      # noqa: E402
import jax.numpy as jnp         # noqa: E402
import numpy as np              # noqa: E402
from jax import lax             # noqa: E402

from repro.core.request import Trace  # noqa: E402

BIG = 1e30
COLD, IDLE, BUSY = 0, 1, 2
I32_MAX = np.iinfo(np.int32).max
SEG = 32          # events per segment (deferred result-write window)
LANE_CHUNK = 16   # lanes per device call (XLA:CPU regresses beyond)

# Packed per-lane counters: ci (NCI,) i32 and cf (NCF,) f64.
(CI_NEXT, CI_DONE, CI_ITERS, CI_STALL, CI_SEQ, CI_GN, CI_COLD,
 CI_EVICT, CI_OVF) = range(9)
NCI = 9
CF_GSUM, CF_COLDT, CF_EVICTT, CF_RSUM, CF_SSUM, CF_RMAX = range(6)
NCF = 6

# Streaming response histogram: log-spaced, 8 bins/decade over
# [1e-4, 1e4) seconds. Quantile reads are exact to one bin width
# (a factor of 10^(1/8) ~ 1.33x).
HIST_BINS = 64
HIST_LO = -4.0
HIST_PER_DECADE = 8


def ensure_x64() -> None:
    """Enable f64 before anything is traced.

    Event times need f64 for exact agreement with the Python engine over
    multi-hour traces. Flipping the flag mid-run (the old
    ``simulate_jax_from_trace`` behaviour) invalidates already-traced
    f32 jits elsewhere; importing this module instead performs the
    switch once, at import time, before the engine traces anything.
    """
    if not jax.config.jax_enable_x64:
        jax.config.update("jax_enable_x64", True)


ensure_x64()


class EngineCtx:
    """Per-lane view of the run handed to policy kernels.

    Bundles the (traced) trace arrays, the (static) shape constants, the
    scalar knobs and the current segment step ``k``. Built inside the
    jitted entry point — it never crosses a jit boundary itself.

    Trace arrays are *shared* (T, ...) operands indexed by the lane's
    ``tix``: under vmap a gather whose operand is unbatched lowers to a
    single efficient gather, whereas a batched operand takes a generic
    path that is orders of magnitude slower on the CPU backend. The
    per-request reads (`fn_at` / `arrival_at` / `exec_at`, and the
    positional queue reads `rid_at_pos` / `heads`) all go through that
    fast path.
    """

    def __init__(self, *, fn_id2, arrival2, exec2, cold2, evict2,
                 pos_rids2, pos_off2, tix, cap_mask, beta, prior,
                 threshold, k, n, f, c, q):
        self._fn = fn_id2          # (T, N) shared
        self._arr = arrival2       # (T, N) shared
        self._ex = exec2           # (T, N) shared
        self._pos = pos_rids2      # (T, N) shared: rids by (fn, id)
        self._off = pos_off2       # (T, F+1) shared: per-fn offsets
        self.tix = tix             # this lane's trace index
        self.t_cold = cold2[tix]   # (F,) row of the shared (T, F)
        self.t_evict = evict2[tix]
        self.cap_mask = cap_mask
        self.beta = beta
        self.prior = prior
        self.threshold = threshold
        self.k = k                  # segment step (overlay slot)
        self.N, self.F, self.C, self.Q = n, f, c, q

    def fn_at(self, rid):
        return self._fn[self.tix, jnp.clip(rid, 0, self.N - 1)]

    def arrival_at(self, rid):
        return self._arr[self.tix, jnp.clip(rid, 0, self.N - 1)]

    def exec_at(self, rid):
        return self._ex[self.tix, jnp.clip(rid, 0, self.N - 1)]

    def rid_at_pos(self, fn, pos):
        """Request id at arrival position ``pos`` of function ``fn``
        (garbage on out-of-range positions — callers gate)."""
        base = self._off[self.tix, jnp.clip(fn, 0, self.F - 1)]
        return self._pos[self.tix,
                         jnp.clip(base + pos, 0, self.N - 1)]


class PolicyKernel:
    """Interface a vectorised policy implements over the engine state.

    Each hook is a pure function ``state -> state`` gated by an ``on``
    predicate (guarded-write style — hooks run every iteration, their
    writes are masked); the engine has already done the
    policy-independent bookkeeping — cursor advance for arrivals,
    estimator update + slot release for exec-done, slot release for
    cold-done, timer consumption for timers — exactly mirroring
    `repro.core.simulator.simulate`.

    Queue contract: every enabled ``on_arrival`` must consume exactly
    one queue position of the request's function — `q_push` when it
    queues, `q_consume_direct` when it dispatches the arrival straight
    to a slot — so the positional queues stay contiguous.
    """

    name = "base"
    has_timers = False
    default_beta = 1.0

    def extra_state(self, L, C, F) -> Dict[str, jnp.ndarray]:
        """Kernel-private carried arrays (leading L), e.g. FaasCache's
        per-slot GREEDY-DUAL bookkeeping. Keys must not collide with
        the engine's."""
        return {}

    def on_arrival(self, ctx, s, rid, t, on):
        raise NotImplementedError

    def on_cold_done(self, ctx, s, slot, t, on):
        raise NotImplementedError

    def on_exec_done(self, ctx, s, slot, rid, t, on):
        raise NotImplementedError

    def on_timer(self, ctx, s, rid, t, on):  # pragma: no cover
        return s


# --------------------------------------------------------------- helpers
def _gidx(on, idx, size):
    """Guarded scatter index: ``idx`` when enabled and valid, else an
    out-of-bounds sentinel that ``mode="drop"`` discards."""
    return jnp.where(on & (idx >= 0), idx, size)


def lex_argmin(primary, secondary, valid):
    """First index minimising ``(primary, secondary)`` among ``valid``.

    Reproduces the Python engine's deterministic scans: iterate in
    ``secondary`` (creation / fn-id) order, keep on strict improvement.
    """
    p = jnp.where(valid, primary, BIG)
    tie = valid & (p <= jnp.min(p))
    return jnp.argmin(jnp.where(tie, secondary, I32_MAX))


def argmin_i32(vals, valid):
    """First valid index minimising an i32 key (sentinel-masked)."""
    return jnp.argmin(jnp.where(valid, vals, I32_MAX))


def est_means(ctx, s):
    """Per-function running means with global-mean / prior fallback."""
    counts = s["est_n"].astype(jnp.float64)
    g_n = s["ci"][CI_GN]
    gcount = g_n.astype(jnp.float64)
    g = jnp.where(g_n > 0, s["cf"][CF_GSUM] / jnp.maximum(gcount, 1),
                  ctx.prior)
    return jnp.where(s["est_n"] > 0,
                     s["est_sum"] / jnp.maximum(counts, 1), g)


def k_counts(ctx, s):
    """|K^j| — slots assigned to each function, any state."""
    return jnp.zeros((ctx.F,), jnp.int32).at[
        jnp.where(s["slot_fn"] >= 0, s["slot_fn"], jnp.int32(ctx.F))
    ].add(jnp.int32(1), mode="drop")


def cold_counts(ctx, s):
    """Slots currently warming up (state COLD) per function."""
    warming = s["slot_state"] == COLD
    return jnp.zeros((ctx.F,), jnp.int32).at[
        jnp.where((s["slot_fn"] >= 0) & warming, s["slot_fn"],
                  jnp.int32(ctx.F))
    ].add(jnp.int32(1), mode="drop")


def idle_own(ctx, s, fn):
    """Mask of usable idle slots already resident with ``fn``."""
    return ((s["slot_fn"] == fn) & (s["slot_state"] == IDLE)
            & ctx.cap_mask)


def pick_idle_own(ctx, s, fn):
    """(mask.any(), earliest-created idle own slot) — Python's
    ``idle_of`` picks the lowest ``inst_id``."""
    mask = idle_own(ctx, s, fn)
    return mask.any(), argmin_i32(s["slot_seq"], mask)


def q_head(ctx, s, fn):
    """Request id at the head of ``fn``'s queue (garbage when empty —
    callers gate on ``q_len``). Served from the carried q_head_rid
    cache so head reads — including the central-queue (F,) head scan —
    cost no gathers into the big positional operand."""
    return s["q_head_rid"][jnp.clip(fn, 0, ctx.F - 1)]


def q_push(ctx, s, fn, rid, on):
    """Append ``rid``; returns (state, pushed). The pushed request is
    by construction the next arrival position of ``fn``, so only the
    length moves (plus the head cache when the queue was empty). A push
    onto a full backlog (q_len == queue_cap) is dropped and counted in
    overflow."""
    fc = jnp.clip(fn, 0, ctx.F - 1)
    was_empty = s["q_len"][fc] == 0
    full = s["q_len"][fc] >= ctx.Q
    do = on & ~full
    s = dict(s)
    s["q_head_rid"] = s["q_head_rid"].at[
        _gidx(do & was_empty, fn, ctx.F)].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["q_len"] = s["q_len"].at[_gidx(do, fn, ctx.F)].add(
        1, mode="drop")
    s["ci"] = s["ci"].at[CI_OVF].add((on & full).astype(jnp.int32))
    return s, do


def q_consume_direct(ctx, s, fn, on):
    """Account a directly dispatched arrival: its (empty-queue) head
    position is consumed without ever being enqueued. The head cache
    stays stale-but-gated (q_len == 0) until the next push rewrites
    it."""
    s = dict(s)
    s["q_head_pos"] = s["q_head_pos"].at[_gidx(on, fn, ctx.F)].add(
        1, mode="drop")
    return s


def q_pop(ctx, s, fn, on):
    """Consume the head of ``fn``'s queue; returns (state, rid). The
    one positional gather refreshes the head cache with the successor
    (garbage when the queue empties — reads gate on q_len)."""
    fc = jnp.clip(fn, 0, ctx.F - 1)
    rid = s["q_head_rid"][fc]
    succ = ctx.rid_at_pos(fc, s["q_head_pos"][fc] + 1)
    fi = _gidx(on, fn, ctx.F)
    s = dict(s)
    s["q_head_rid"] = s["q_head_rid"].at[fi].set(succ, mode="drop")
    s["q_head_pos"] = s["q_head_pos"].at[fi].add(1, mode="drop")
    s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
    return s, rid


def arm_timer(ctx, s, fn, t, pushed, on):
    """Account the original timer of an arrival (position cnt-1).

    The rail covers every arrival position in order. If the rail is
    idle (this arrival is its head) a *pushed* arrival arms the head
    fire time, while a directly dispatched one is consumed silently;
    a direct dispatch behind a busy rail stays armed and later fires
    as a no-op (its is-head gate fails), mirroring how the Python
    policy drops timers of already-served requests."""
    fc = jnp.clip(fn, 0, ctx.F - 1)
    rail_head = s["tmr_pos"][fc] == s["arr_cnt"][fc] - 1
    s = dict(s)
    s["tmr_next"] = s["tmr_next"].at[
        _gidx(on & rail_head & pushed, fn, ctx.F)].set(
        t + ctx.threshold, mode="drop")
    s["tmr_pos"] = s["tmr_pos"].at[
        _gidx(on & rail_head & ~pushed, fn, ctx.F)].add(
        1, mode="drop")
    return s


def rearm_timer(ctx, s, fn, rid, t_fire, on):
    """Re-arm the (unique) blocked queue head of ``fn`` at ``t_fire``."""
    fi = _gidx(on, fn, ctx.F)
    s = dict(s)
    s["rearm_t"] = s["rearm_t"].at[fi].set(t_fire, mode="drop")
    s["rearm_rid"] = s["rearm_rid"].at[fi].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    return s


def dispatch(ctx, s, slot, rid, t, on):
    """Run ``rid`` on an idle ``slot`` of its function.

    The per-request start/completion record goes into the segment
    overlay (d_*), not large result arrays — the overlay is folded (and
    in exact mode also scattered) once per segment so no large carried
    array is touched per event. At most one dispatch happens per event
    (call sites are mutually exclusive), so the overlay slot is indexed
    by the segment step and disabled sites drop instead of clobbering
    it."""
    s = dict(s)
    comp = t + ctx.exec_at(rid)
    si = _gidx(on, slot, ctx.C)
    ki = jnp.where(on, ctx.k, SEG)
    s["slot_state"] = s["slot_state"].at[si].set(BUSY, mode="drop")
    s["slot_ready"] = s["slot_ready"].at[si].set(comp, mode="drop")
    s["slot_req"] = s["slot_req"].at[si].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["slot_used"] = s["slot_used"].at[si].set(t, mode="drop")
    s["d_rid"] = s["d_rid"].at[ki].set(
        jnp.asarray(rid, jnp.int32), mode="drop")
    s["d_start"] = s["d_start"].at[ki].set(t, mode="drop")
    s["d_comp"] = s["d_comp"].at[ki].set(comp, mode="drop")
    return s


def start_cold(ctx, s, slot, fn, t, evict_fn, on):
    """Claim/convert ``slot`` for ``fn`` (``evict_fn`` = -1 -> empty slot,
    otherwise the resident function paying its eviction cost first)."""
    s = dict(s)
    fn = jnp.asarray(fn, jnp.int32)  # argmin/argmax indices are i64
    evict_fn = jnp.asarray(evict_fn, jnp.int32)
    fc = jnp.clip(fn, 0, ctx.F - 1)
    evicting = on & (evict_fn >= 0)
    ev_cost = jnp.where(evicting,
                        ctx.t_evict[jnp.clip(evict_fn, 0, ctx.F - 1)],
                        0.0)
    si = _gidx(on, slot, ctx.C)
    s["slot_fn"] = s["slot_fn"].at[si].set(fn, mode="drop")
    s["slot_state"] = s["slot_state"].at[si].set(COLD, mode="drop")
    s["slot_ready"] = s["slot_ready"].at[si].set(
        t + ctx.t_cold[fc] + ev_cost, mode="drop")
    s["slot_req"] = s["slot_req"].at[si].set(-1, mode="drop")
    s["slot_used"] = s["slot_used"].at[si].set(0.0, mode="drop")
    s["slot_seq"] = s["slot_seq"].at[si].set(s["ci"][CI_SEQ],
                                             mode="drop")
    on_i = on.astype(jnp.int32)
    s["ci"] = s["ci"].at[jnp.array([CI_SEQ, CI_COLD, CI_EVICT])].add(
        jnp.stack([on_i, on_i, evicting.astype(jnp.int32)]))
    s["cf"] = s["cf"].at[jnp.array([CF_COLDT, CF_EVICTT])].add(
        jnp.stack([jnp.where(on, ctx.t_cold[fc], 0.0), ev_cost]))
    return s


# ----------------------------------------------------- streaming metrics
def hist_edges() -> np.ndarray:
    """Bin edges (HIST_BINS + 1,) of the streaming response histogram."""
    return 10.0 ** (HIST_LO
                    + np.arange(HIST_BINS + 1) / HIST_PER_DECADE)


def hist_bin(resp):
    """Log-spaced bin index of a (batch of) response time(s)."""
    b = jnp.floor((jnp.log10(jnp.maximum(resp, 1e-30)) - HIST_LO)
                  * HIST_PER_DECADE)
    return jnp.clip(b, 0, HIST_BINS - 1).astype(jnp.int32)


def hist_quantile(hist, q, n, resp_max=None):
    """Upper edge of the bin containing the q-quantile of ``n`` folded
    responses — exact to one bin width (~1.33x).

    The edge bins also hold everything clipped past the histogram
    range, so their edges would silently misstate out-of-range tails;
    with ``resp_max`` (the exact carried maximum) the result is never
    range-capped: a quantile in the top bin reports the maximum itself,
    and any bin's edge is clamped to it (which makes all-fast traces —
    every response under the 1e-4 s floor — report the true tail
    instead of the floor edge). The reported value always upper-bounds
    the true quantile; only a distribution almost entirely below the
    floor with large outliers can push it past one bin of the truth."""
    cum = jnp.cumsum(hist, axis=-1)
    need = jnp.ceil(q * n).astype(cum.dtype)
    b = jnp.argmax(cum >= need, axis=-1)
    edge = jnp.asarray(hist_edges())[b + 1]
    if resp_max is None:
        return edge
    return jnp.where(b >= HIST_BINS - 1, resp_max,
                     jnp.minimum(edge, resp_max))


def hist_cdf(hist):
    """(edges, cdf) arrays for plotting a CDF from the streamed
    histogram (exact to one bin width)."""
    h = np.asarray(hist, np.float64)
    cum = h.cumsum(axis=-1)
    total = np.maximum(cum[..., -1:], 1.0)
    return hist_edges()[1:], cum / total


# ------------------------------------------------------------ event loop
@functools.partial(jax.jit,
                   static_argnames=("kernel", "n_fns", "capacity",
                                    "queue_cap", "stream"))
def _simulate(fn_id, arrival, exec_time, t_cold, t_evict, trace_ix,
              cap_mask, beta, prior, threshold, *, kernel, n_fns,
              capacity, queue_cap, stream=False):
    """Lane-batched engine. Trace arrays are shared (T, ...) operands;
    ``trace_ix``, ``cap_mask`` and ``beta`` carry the leading lane
    dimension L (one lane per sweep point). One ``while_loop`` runs all
    lanes in segments of SEG events; the branchless per-event body is
    vmapped per lane and finished lanes no-op via their guards.

    ``stream=True`` drops the (L, N) per-request result arrays: the
    dispatch overlay is folded into per-lane metric accumulators at
    each segment flush, so carried state is independent of N."""
    L = trace_ix.shape[0]
    N = fn_id.shape[1]
    F, C, Q = n_fns, capacity, queue_cap

    fn_id = fn_id.astype(jnp.int32)
    arrival = arrival.astype(jnp.float64)
    exec_time = exec_time.astype(jnp.float64)
    t_cold = t_cold.astype(jnp.float64)
    t_evict = t_evict.astype(jnp.float64)
    trace_ix = trace_ix.astype(jnp.int32)
    prior = jnp.float64(prior)
    threshold = jnp.float64(threshold)

    # positional queue layout (loop-invariant): request ids sorted by
    # (fn, id) + per-function offsets — fn j's k-th arrival is
    # pos_rids[pos_off[j] + k]
    pos_rids = jnp.argsort(fn_id, axis=1, stable=True).astype(jnp.int32)
    counts = jax.vmap(
        lambda row: jnp.zeros((F,), jnp.int32).at[
            jnp.clip(row, 0, F - 1)].add(1))(fn_id)
    pos_off = jnp.concatenate(
        [jnp.zeros((counts.shape[0], 1), jnp.int32),
         jnp.cumsum(counts, axis=1)], axis=1)

    s = dict(
        slot_fn=jnp.full((L, C), -1, jnp.int32),
        slot_state=jnp.full((L, C), IDLE, jnp.int32),
        slot_ready=jnp.full((L, C), BIG, jnp.float64),
        slot_req=jnp.full((L, C), -1, jnp.int32),
        slot_used=jnp.zeros((L, C), jnp.float64),
        slot_seq=jnp.full((L, C), I32_MAX, jnp.int32),
        q_head_pos=jnp.zeros((L, F), jnp.int32),
        q_head_rid=jnp.full((L, F), -1, jnp.int32),
        q_len=jnp.zeros((L, F), jnp.int32),
        d_rid=jnp.full((L, SEG), N, jnp.int32),
        d_start=jnp.zeros((L, SEG), jnp.float64),
        d_comp=jnp.zeros((L, SEG), jnp.float64),
        est_sum=jnp.zeros((L, F), jnp.float64),
        est_n=jnp.zeros((L, F), jnp.int32),
        ci=jnp.zeros((L, NCI), jnp.int32),
        cf=jnp.zeros((L, NCF), jnp.float64),
        hist=jnp.zeros((L, HIST_BINS), jnp.int32),
    )
    if not stream:
        s["start"] = jnp.full((L, N), -1.0, jnp.float64)
        s["completion"] = jnp.full((L, N), -1.0, jnp.float64)
    if kernel.has_timers:
        s["arr_cnt"] = jnp.zeros((L, F), jnp.int32)
        s["tmr_pos"] = jnp.zeros((L, F), jnp.int32)
        s["tmr_next"] = jnp.full((L, F), BIG, jnp.float64)
        s["rearm_t"] = jnp.full((L, F), BIG, jnp.float64)
        s["rearm_rid"] = jnp.full((L, F), -1, jnp.int32)
    s.update(kernel.extra_state(L, C, F))

    max_iters = 256 * N + 4096
    n_slot = 2 * C   # candidate positions: busy slots then cold slots

    def lane_step(k, s, tix, cap_mask, beta):
        ctx = EngineCtx(fn_id2=fn_id, arrival2=arrival, exec2=exec_time,
                        cold2=t_cold, evict2=t_evict,
                        pos_rids2=pos_rids, pos_off2=pos_off, tix=tix,
                        cap_mask=cap_mask, beta=beta, prior=prior,
                        threshold=threshold, k=k, n=N, f=F, c=C, q=Q)
        ci = s["ci"]
        active = (ci[CI_DONE] < N) & (ci[CI_STALL] == 0)
        na = ci[CI_NEXT]
        t_arr = jnp.where(na < N, ctx.arrival_at(na), BIG)
        # fused next-event pick: one first-index argmin over candidate
        # times laid out in priority order — position encodes both the
        # same-time class order EXEC < COLD < TIMER(orig < rearm) <
        # ARRIVAL and the within-class index tie-break (Python engine
        # heap order)
        ready = jnp.where(cap_mask, s["slot_ready"], BIG)
        busy_key = jnp.where(s["slot_state"] == BUSY, ready, BIG)
        cold_key = jnp.where(s["slot_state"] == COLD, ready, BIG)
        if kernel.has_timers:
            cand = jnp.concatenate([busy_key, cold_key, s["tmr_next"],
                                    s["rearm_t"], t_arr[None]])
        else:
            cand = jnp.concatenate([busy_key, cold_key, t_arr[None]])
        ei = jnp.argmin(cand)
        t_ev = cand[ei]
        live = active & (t_ev < BIG)
        ev_slot = live & (ei < n_slot)
        is_cold = ei >= C
        slot = jnp.clip(jnp.where(is_cold, ei - C, ei), 0, C - 1)
        ev_arr = live & (ei == cand.shape[0] - 1)

        # ------------------------------------------------- slot event
        cold_on = ev_slot & is_cold
        exec_on = ev_slot & ~is_cold
        rid_done = s["slot_req"][slot]
        j_done = s["slot_fn"][slot]
        e_done = ctx.exec_at(rid_done)
        si = _gidx(ev_slot, slot, C)
        ji = _gidx(exec_on, j_done, F)
        exec_i = exec_on.astype(jnp.int32)
        s = dict(s)
        s["slot_state"] = s["slot_state"].at[si].set(IDLE, mode="drop")
        s["slot_ready"] = s["slot_ready"].at[si].set(BIG, mode="drop")
        s["slot_req"] = s["slot_req"].at[si].set(-1, mode="drop")
        # estimator sees the completion before the policy reacts
        s["est_sum"] = s["est_sum"].at[ji].add(e_done, mode="drop")
        s["est_n"] = s["est_n"].at[ji].add(1, mode="drop")
        s["cf"] = s["cf"].at[CF_GSUM].add(
            jnp.where(exec_on, e_done, 0.0))
        s["ci"] = s["ci"].at[jnp.array([CI_GN, CI_DONE])].add(
            jnp.stack([exec_i, exec_i]))
        s = kernel.on_cold_done(ctx, s, slot, t_ev, cold_on)
        s = kernel.on_exec_done(ctx, s, slot, rid_done, t_ev, exec_on)

        # ------------------------------------------------ timer event
        if kernel.has_timers:
            # originals (arrival + threshold, arrival order) vs the
            # unique re-armed head; originals win exact ties (FIFO seq)
            fire_orig = live & (ei >= n_slot) & (ei < n_slot + F)
            fire_re = live & (ei >= n_slot + F) & (ei < n_slot + 2 * F)
            ev_timer = fire_orig | fire_re
            f_o = jnp.clip(ei - n_slot, 0, F - 1)
            f_r = jnp.clip(ei - n_slot - F, 0, F - 1)
            p_o = s["tmr_pos"][f_o]
            rid_o = ctx.rid_at_pos(f_o, p_o)
            succ = ctx.rid_at_pos(f_o, p_o + 1)
            more = p_o + 1 < s["arr_cnt"][f_o]
            oi = _gidx(fire_orig, f_o, F)
            rid_r = s["rearm_rid"][f_r]
            s = dict(s)
            s["tmr_pos"] = s["tmr_pos"].at[oi].add(1, mode="drop")
            s["tmr_next"] = s["tmr_next"].at[oi].set(
                jnp.where(more, ctx.arrival_at(succ) + threshold, BIG),
                mode="drop")
            s["rearm_t"] = s["rearm_t"].at[
                _gidx(fire_re, f_r, F)].set(BIG, mode="drop")
            rid_t = jnp.where(fire_orig, rid_o, rid_r)
            s = kernel.on_timer(ctx, s, rid_t, t_ev, ev_timer)

        # ---------------------------------------------------- arrival
        rid_a = jnp.minimum(na, N - 1)
        s = dict(s)
        if kernel.has_timers:
            s["arr_cnt"] = s["arr_cnt"].at[
                _gidx(ev_arr, ctx.fn_at(rid_a), F)].add(
                1, mode="drop")
        s["ci"] = s["ci"].at[jnp.array([CI_NEXT, CI_ITERS])].add(
            jnp.stack([ev_arr.astype(jnp.int32),
                       active.astype(jnp.int32)]))
        s = kernel.on_arrival(ctx, s, rid_a, t_arr, ev_arr)

        s = dict(s)
        stall = jnp.where(
            active & ~live, 1,
            jnp.where(active & (s["ci"][CI_ITERS] >= max_iters), 2,
                      s["ci"][CI_STALL]))
        s["ci"] = s["ci"].at[CI_STALL].set(stall)
        return s

    step_lanes = jax.vmap(lane_step, in_axes=(None, 0, 0, 0, 0))
    lanes = jnp.arange(L, dtype=jnp.int32)
    lane_iota = lanes[:, None]

    def cond(s):
        return jnp.any((s["ci"][:, CI_DONE] < N)
                       & (s["ci"][:, CI_STALL] == 0))

    def segment(s):
        s = dict(s)
        s["d_rid"] = jnp.full((L, SEG), N, jnp.int32)

        def step(k, s):
            return step_lanes(k, s, trace_ix, cap_mask, beta)

        s = lax.fori_loop(0, SEG, step, s)
        # flush the segment: *fold* the dispatch records into the
        # streaming accumulators (and, in exact mode, scatter them into
        # the per-request arrays) — the only writes to large carried
        # arrays, paid once per SEG events, not per event
        s = dict(s)
        valid = s["d_rid"] < N
        ridc = jnp.minimum(s["d_rid"], N - 1)
        t_ix = trace_ix[:, None]
        resp = jnp.where(valid, s["d_comp"] - arrival[t_ix, ridc], 0.0)
        slow = jnp.where(
            valid,
            resp / jnp.maximum(exec_time[t_ix, ridc], 1e-9), 0.0)
        cf = s["cf"]
        cf = cf.at[:, CF_RSUM].add(resp.sum(axis=1))
        cf = cf.at[:, CF_SSUM].add(slow.sum(axis=1))
        cf = cf.at[:, CF_RMAX].max(resp.max(axis=1))
        s["cf"] = cf
        s["hist"] = s["hist"].at[
            lane_iota, jnp.where(valid, hist_bin(resp),
                                 jnp.int32(HIST_BINS))
        ].add(1, mode="drop")
        if not stream:
            s["start"] = s["start"].at[lane_iota, s["d_rid"]].set(
                s["d_start"], mode="drop")
            s["completion"] = s["completion"].at[
                lane_iota, s["d_rid"]].set(s["d_comp"], mode="drop")
        return s

    final = lax.while_loop(cond, segment, s)
    ci, cf = final["ci"], final["cf"]
    out = dict(cold_starts=ci[:, CI_COLD], cold_time=cf[:, CF_COLDT],
               evictions=ci[:, CI_EVICT], evict_time=cf[:, CF_EVICTT],
               overflow=ci[:, CI_OVF],
               stalled=ci[:, CI_STALL], n_events=ci[:, CI_ITERS],
               done=ci[:, CI_DONE],
               resp_sum=cf[:, CF_RSUM], slow_sum=cf[:, CF_SSUM],
               max_response=cf[:, CF_RMAX], resp_hist=final["hist"])
    if not stream:
        out["start"] = final["start"]
        out["completion"] = final["completion"]
    return out


# ------------------------------------------------------------ public API
def simulate_policy_jax(fn_id, arrival, exec_time, t_cold, t_evict, *,
                        policy: str = "esff", n_fns: int, capacity: int,
                        queue_cap: int = 512, beta=None,
                        prior: float = 0.1, threshold: float = 0.1,
                        cap_mask=None, stream: bool = False
                        ) -> Dict[str, jnp.ndarray]:
    """Run ``policy`` over a (sorted-by-arrival) request stream.

    ``policy`` selects a kernel from `repro.core.jax_policies.KERNELS`
    statically, so each policy gets its own jit specialisation. ``beta``
    defaults to the kernel's own default (2.0 for ESFF-H, else 1.0).
    Returns the counter block (cold starts, evictions, overflow,
    stalled) plus the streaming metric accumulators (resp_sum /
    slow_sum / max_response / resp_hist); with the default
    ``stream=False`` also per-request start/completion.
    """
    from repro.core.jax_policies import KERNELS  # deferred: cycle-free
    kernel = KERNELS[policy]
    if beta is None:
        beta = kernel.default_beta
    if cap_mask is None:
        cap_mask = jnp.ones((capacity,), bool)
    share = lambda x: jnp.expand_dims(jnp.asarray(x), 0)  # noqa: E731
    out = _simulate(share(fn_id), share(arrival), share(exec_time),
                    share(t_cold), share(t_evict),
                    jnp.zeros((1,), jnp.int32),
                    jnp.expand_dims(jnp.asarray(cap_mask), 0),
                    jnp.asarray(beta, jnp.float64).reshape((1,)),
                    jnp.float64(prior), jnp.float64(threshold),
                    kernel=kernel, n_fns=n_fns, capacity=capacity,
                    queue_cap=queue_cap, stream=stream)
    return {k: jnp.squeeze(v, axis=0) for k, v in out.items()}


def simulate_policy_from_trace(trace: Trace, policy: str, capacity: int,
                               *, beta=None, queue_cap: int = 1024,
                               prior: float = 0.1,
                               threshold: float = 0.1
                               ) -> Dict[str, np.ndarray]:
    """Trace-object convenience wrapper mirroring ``simulate()``
    (exact per-request mode)."""
    a = trace.to_arrays()
    out = simulate_policy_jax(
        jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
        jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
        jnp.asarray(a["evict"]), policy=policy,
        n_fns=trace.n_functions, capacity=capacity, queue_cap=queue_cap,
        beta=beta, prior=prior, threshold=threshold)
    out = {k: np.asarray(v) for k, v in out.items()}
    out["response"] = out["completion"] - a["arrival"]
    out["mean_response"] = float(out["response"].mean())
    return out


@functools.partial(jax.jit,
                   static_argnames=("kernel", "n_fns", "capacity",
                                    "queue_cap", "stream"))
def _sweep_metrics(fn, arr, ex, cold, ev, tix, masks, betas, prior,
                   threshold, *, kernel, n_fns, capacity, queue_cap,
                   stream=True):
    """Lane-batched run + on-device metric reduction. Means and
    slowdowns come from the streaming accumulators in *both* modes (so
    streamed and exact sweeps agree bitwise); p99 is exact in exact
    mode and one-bin-accurate from the histogram in streaming mode."""
    out = _simulate(fn, arr, ex, cold, ev, tix, masks, betas, prior,
                    threshold, kernel=kernel, n_fns=n_fns,
                    capacity=capacity, queue_cap=queue_cap,
                    stream=stream)
    N = fn.shape[1]
    if stream:
        p99 = hist_quantile(out["resp_hist"], 0.99, N,
                            out["max_response"])
    else:
        resp = out["completion"] - arr[tix]
        p99 = jnp.percentile(resp, 99.0, axis=1)
    return dict(mean_response=out["resp_sum"] / N,
                mean_slowdown=out["slow_sum"] / N,
                p99_response=p99,
                max_response=out["max_response"],
                resp_hist=out["resp_hist"],
                cold_starts=out["cold_starts"],
                cold_time=out["cold_time"],
                evictions=out["evictions"],
                overflow=out["overflow"],
                stalled=out["stalled"])


def sweep(traces: Union[Trace, Sequence[Trace], dict, Sequence[dict]],
          policies: Sequence[str] = ("esff", "esff_h", "sff",
                                     "openwhisk", "faascache",
                                     "openwhisk_v2"),
          capacities: Sequence[int] = (8, 16, 32),
          betas=None, *, queue_cap: int = 2048, prior: float = 0.1,
          threshold: float = 0.1, stream: bool = True
          ) -> Dict[str, np.ndarray]:
    """Batched policy x trace x capacity x beta sweep in one device call
    per policy.

    The grid is flattened to engine lanes: every (trace, capacity, beta)
    combination becomes one lane of a single lane-batched ``while_loop``
    (capacities as slot masks over a static ``capacity=max(capacities)``,
    so one jit specialisation per policy covers the whole grid).

    Traces may be `Trace` objects or plain array dicts (the
    ``to_arrays()`` layout — the fast path for 10^6-request synthetic
    streams that never materialise Request objects). ``stream=True``
    (default) keeps carried state independent of trace length: means
    are exact, p99 is histogram-derived (one ~1.33x bin). ``betas=None``
    uses each kernel's default (so ESFF-H keeps its hysteresis).
    Returns metric arrays of shape (P, T, K, B) keyed by metric name
    ((P, T, K, B, HIST_BINS) for ``resp_hist``), plus the axis values
    under ``"axes"``.
    """
    from repro.core.jax_policies import KERNELS
    if isinstance(traces, (Trace, dict)):
        traces = [traces]
    traces = list(traces)
    arrs = [tr.to_arrays() if isinstance(tr, Trace) else tr
            for tr in traces]
    F = len(arrs[0]["cold_start"])
    N = len(arrs[0]["fn_id"])
    for a in arrs:
        if len(a["cold_start"]) != F or len(a["fn_id"]) != N:
            raise ValueError("sweep traces must share shape "
                             "(n_functions, n_requests)")
    stacked = {k: np.stack([np.asarray(a[k]) for a in arrs])
               for k in ("fn_id", "arrival", "exec_time", "cold_start",
                         "evict")}
    T, K = len(traces), len(capacities)
    C = max(capacities)
    masks = np.stack([np.arange(C) < c for c in capacities])

    shared = {k: jnp.asarray(v) for k, v in stacked.items()}

    def run_chunk(p, tix_l, mask_l, beta_l):
        out = _sweep_metrics(
            shared["fn_id"], shared["arrival"], shared["exec_time"],
            shared["cold_start"], shared["evict"], jnp.asarray(tix_l),
            jnp.asarray(mask_l), jnp.asarray(beta_l),
            jnp.float64(prior), jnp.float64(threshold),
            kernel=KERNELS[p], n_fns=F, capacity=C,
            queue_cap=queue_cap, stream=stream)
        return jax.device_get(out)

    chunks = []
    for p in policies:
        bs = np.asarray([KERNELS[p].default_beta] if betas is None
                        else list(betas), np.float64)
        B = len(bs)
        # lane order: trace-major, then capacity, then beta
        tix_l = np.repeat(np.arange(T, dtype=np.int32), K * B)
        mask_l = np.tile(np.repeat(masks, B, axis=0), (T, 1))
        beta_l = np.tile(bs, T * K)
        for lo in range(0, T * K * B, LANE_CHUNK):
            hi = lo + LANE_CHUNK
            chunks.append((p, tix_l[lo:hi], mask_l[lo:hi],
                           beta_l[lo:hi]))

    # device calls overlap on the host thread pool (XLA releases the
    # GIL while a computation runs); lanes are chunked to LANE_CHUNK
    # per call to stay in XLA:CPU's fast regime
    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(max_workers=2) as tp:
        outs = list(tp.map(lambda c: run_chunk(*c), chunks))

    per_policy = []
    for pi, p in enumerate(policies):
        B = 1 if betas is None else len(betas)
        mine = [o for c, o in zip(chunks, outs) if c[0] == p]
        cat = {k: np.concatenate([np.asarray(o[k]) for o in mine])
               for k in mine[0]}
        per_policy.append({k: v.reshape((T, K, B) + v.shape[1:])
                           for k, v in cat.items()})

    out = {k: np.stack([r[k] for r in per_policy])
           for k in per_policy[0]}
    out["axes"] = dict(policy=list(policies), trace=len(traces),
                       capacity=list(capacities),
                       beta=(None if betas is None else list(betas)))
    return out
