"""SSFS — the simplified offline problem and its optimal algorithm (§IV).

Setting (paper simplifications S1-S4): a unary edge server (one resident
instance), per-function deterministic execution time t_j, all requests
present at time 0, and full knowledge of (n_j, t_j, t_j^l, t_j^v).

Cost model: starting a batch of function f_j costs its own setup
``s_j = t_j^l + t_j^v`` (the paper's exchange arguments, Eqs. (2)-(5),
attribute each function's eviction to itself), after which its n_j
requests run back to back.

Theorem 2: processing functions contiguously in ascending order of

    w_j = t_j + (t_j^l + t_j^v) / n_j

minimises total (= average) response time. This is a weighted-SPT rule
over function batches: batch duration D_j = s_j + n_j t_j, and the
optimal order is ascending D_j / n_j = w_j.

``brute_force_best`` enumerates *all* request orderings (with setup paid
at every function switch) and is used by the property tests to certify
optimality on small instances.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import List, Sequence, Tuple


@dataclass(frozen=True)
class SSFSFunction:
    """One function family in the SSFS instance."""

    fn_id: int
    n: int            # n_j  — number of requests (all arrive at t=0)
    exec: float       # t_j  — per-request execution time
    cold: float       # t_j^l
    evict: float      # t_j^v

    @property
    def setup(self) -> float:
        return self.cold + self.evict

    @property
    def weight(self) -> float:
        """w_j = t_j + (t_j^l + t_j^v) / n_j."""
        return self.exec + self.setup / self.n


def ssfs_schedule(functions: Sequence[SSFSFunction]
                  ) -> Tuple[List[int], float]:
    """Optimal SSFS schedule: (function order by ascending weight,
    total response time)."""
    order = sorted(functions, key=lambda f: (f.weight, f.fn_id))
    total, clock = 0.0, 0.0
    for f in order:
        clock += f.setup
        for _ in range(f.n):
            clock += f.exec
            total += clock          # arrival is 0, so response = clock
    return [f.fn_id for f in order], total


def sequence_cost(functions: Sequence[SSFSFunction],
                  request_seq: Sequence[int]) -> float:
    """Total response time of an arbitrary request-level sequence.

    ``request_seq`` lists the function id of each processed request; setup
    s_j is paid whenever the function differs from the previous request's
    (and for the very first request).
    """
    by_id = {f.fn_id: f for f in functions}
    total, clock, prev = 0.0, 0.0, None
    for fid in request_seq:
        f = by_id[fid]
        if fid != prev:
            clock += f.setup
            prev = fid
        clock += f.exec
        total += clock
    return total


def brute_force_best(functions: Sequence[SSFSFunction]
                     ) -> Tuple[Tuple[int, ...], float]:
    """Exhaustive minimum over all distinct request orderings (small n!)."""
    pool: List[int] = []
    for f in functions:
        pool.extend([f.fn_id] * f.n)
    best_seq, best = None, float("inf")
    for perm in set(itertools.permutations(pool)):
        c = sequence_cost(functions, perm)
        if c < best:
            best_seq, best = perm, c
    return best_seq, best
