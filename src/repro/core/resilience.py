"""Request-level resilience: failure injection, timeouts, retry backoff.

The engine and the Python reference cluster must agree request-for-
request, so every stochastic choice is made *outside* the simulators,
from counter-hash draws keyed on the request id (its position in the
original trace) and the attempt number:

* ``plan_outcomes`` pre-computes, per request, the effective execution
  time (``min(exec, timeout)``), the number of leading failed attempts
  ``n_fail`` (attempt ``a`` fails iff ``a <= n_fail``), and whether a
  failure is a timeout. A timed-out request fails deterministically on
  *every* attempt (the budget does not change between attempts), so its
  ``n_fail`` is ``max_attempts``.
* ``backoff_py`` / ``backoff_jax`` compute the capped exponential
  backoff delay for a failed attempt, with deterministic jitter drawn
  from the same ``(rid, attempt)`` counter-hash stream. The two
  implementations are bitwise-equal for float64 inputs.

Both simulators then only need a deterministic rule at completion time:
``attempt > n_fail[rid]`` means success.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Union

import numpy as np

from repro.cluster.routers import mix32_np, mix32_py

# Salt xor-ed into the failure seed for the jitter stream so jitter
# draws never correlate with the fail/no-fail draws.
JITTER_SALT = 0x5BF03635

# Attempt counters are packed into the low 4 bits of the hash key.
MAX_ATTEMPTS = 16

SHED_MODES = {"error": 0, "shed": 1, "shed_oldest": 2}


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff: attempt ``a`` (1-based) that fails
    re-enters after ``min(base * 2**(a-1), cap)`` seconds, scaled by a
    deterministic jitter factor in ``[1 - jitter, 1 + jitter)``."""

    max_attempts: int = 3
    base: float = 1.0
    cap: float = 30.0
    jitter: float = 0.0

    def __post_init__(self) -> None:
        if not (1 <= int(self.max_attempts) <= MAX_ATTEMPTS):
            raise ValueError(
                f"RetryPolicy.max_attempts must be in [1, {MAX_ATTEMPTS}], "
                f"got {self.max_attempts}")
        if self.base < 0 or self.cap < 0:
            raise ValueError("RetryPolicy.base and cap must be >= 0")
        if not (0.0 <= float(self.jitter) < 1.0):
            raise ValueError("RetryPolicy.jitter must be in [0, 1)")

    def as_tuple(self) -> tuple:
        return (int(self.max_attempts), float(self.base), float(self.cap),
                float(self.jitter))


def per_fn(value, n_fns: int, name: str, dtype=np.float64) -> np.ndarray:
    """Broadcast a scalar or validate a per-function sequence."""
    if np.isscalar(value):
        return np.full(n_fns, value, dtype=dtype)
    arr = np.asarray(value, dtype=dtype)
    if arr.shape != (n_fns,):
        raise ValueError(
            f"{name} must be a scalar or a length-{n_fns} sequence, "
            f"got shape {arr.shape}")
    return arr


def plan_outcomes(
    fn_id: np.ndarray,
    exec_time: np.ndarray,
    *,
    fail_prob: Union[float, Sequence[float]],
    timeouts: Optional[Union[float, Sequence[float]]],
    max_attempts: int,
    n_fns: int,
    seed: int,
    rid: Optional[np.ndarray] = None,
):
    """Pre-compute per-request outcomes.

    Returns ``(eff_exec, n_fail, is_tmo)``:

    * ``eff_exec`` (float64): execution time actually spent per attempt
      — ``min(exec, timeout[fn])``. This is what the engine runs and
      what the estimators observe.
    * ``n_fail`` (int32): number of leading failed attempts; attempt
      ``a`` (1-based) succeeds iff ``a > n_fail``. ``n_fail ==
      max_attempts`` means the request exhausts its retry budget.
    * ``is_tmo`` (bool): the failures are timeouts (``exec`` exceeded
      the budget) rather than injected faults.

    ``rid`` defaults to ``arange(N)`` — pass the original trace indices
    when planning for a re-ordered or sliced view so that draws match
    the unsliced run.
    """
    fn_id = np.asarray(fn_id, dtype=np.int64)
    exec_time = np.asarray(exec_time, dtype=np.float64)
    n = fn_id.shape[0]
    if rid is None:
        rid = np.arange(n, dtype=np.int64)
    else:
        rid = np.asarray(rid, dtype=np.int64)
    a = int(max_attempts)
    if not (1 <= a <= MAX_ATTEMPTS):
        raise ValueError(f"max_attempts must be in [1, {MAX_ATTEMPTS}]")

    p = per_fn(fail_prob, n_fns, "fail_prob")
    if np.any((p < 0) | (p > 1)):
        raise ValueError("fail_prob must be in [0, 1]")
    thresh = p[fn_id] * 4294967296.0  # (N,)

    # u[i, j] ~ U32 for attempt j+1 of request rid[i].
    keys = (rid[:, None] << 4) | np.arange(a, dtype=np.int64)[None, :]
    u = mix32_np(keys, seed).astype(np.float64)
    fail_a = u < thresh[:, None]  # (N, A)
    # Leading run of failures: attempt j+1 contributes iff all attempts
    # <= j+1 failed.
    n_fail = np.cumprod(fail_a, axis=1).sum(axis=1).astype(np.int32)

    if timeouts is not None:
        budget = per_fn(timeouts, n_fns, "timeouts")
        if np.any(budget <= 0):
            raise ValueError("timeouts must be > 0")
        b = budget[fn_id]
        is_tmo = exec_time > b
        eff_exec = np.minimum(exec_time, b)
        # A timeout is deterministic: every attempt burns the full
        # budget and dies, so the retry ladder always exhausts.
        n_fail = np.where(is_tmo, np.int32(a), n_fail)
    else:
        is_tmo = np.zeros(n, dtype=bool)
        eff_exec = exec_time

    return eff_exec, n_fail.astype(np.int32), is_tmo


def backoff_py(attempt: int, key: int, base: float, cap: float,
               jitter: float, seed: int) -> float:
    """Backoff delay after failed attempt ``attempt`` (1-based) of the
    request with original id ``key``. Bitwise-equal to ``backoff_jax``."""
    d = min(base * 2.0 ** (attempt - 1), cap)
    u = mix32_py((int(key) << 4) | ((attempt - 1) & 15),
                 seed ^ JITTER_SALT) / 4294967296.0
    return d * (1.0 + jitter * (2.0 * u - 1.0))


def backoff_jax(attempt, key, base: float, cap: float, jitter: float,
                seed: int):
    """Vectorised twin of ``backoff_py`` (attempt/key are i32 arrays)."""
    import jax.numpy as jnp

    from repro.cluster.routers import mix32_jax
    from repro.core.jax_engine import ensure_x64
    ensure_x64()

    a1 = (attempt - 1).astype(jnp.int32)
    # base/cap/jitter arrive as Python floats from the static `resil`
    # tuple; pin them to strongly-typed f64 at the jit boundary so a
    # weakly-typed constant can never follow a narrower operand dtype
    # (the engine dtype policy is f64-only past the x64 guard, and
    # `repro.analysis`'s dtype gate traces this function to hold it).
    base = jnp.float64(base)
    cap = jnp.float64(cap)
    jitter = jnp.float64(jitter)
    # 2**(a-1) via an exact integer shift: XLA:CPU lowers exp2 to
    # exp(x*ln2), which is off by an ulp from exponent 3 upward and
    # would break bitwise parity with the Python reference
    pow2 = (jnp.int64(1) << a1.astype(jnp.int64)).astype(jnp.float64)
    d = jnp.minimum(base * pow2, cap)
    u = mix32_jax(((key.astype(jnp.uint32) << 4) | (a1.astype(jnp.uint32) & 15)),
                  seed ^ JITTER_SALT).astype(jnp.float64) / 4294967296.0
    return d * (1.0 + jitter * (2.0 * u - 1.0))
