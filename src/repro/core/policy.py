"""Scheduling-policy interface.

A policy owns its queue structures and reacts to four simulator hooks.
The :class:`~repro.core.server.EdgeServer` provides the slot primitives
(``dispatch`` / ``start_cold`` / ``make_idle``); the policy provides the
*decisions* (paper Algorithms 1-3 and the baselines of §VI-A).
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List

from repro.core.request import FunctionProfile, Request
from repro.core.server import EdgeServer, ExecTimeEstimator, Instance
from repro.utils.registry import Registry

POLICIES = Registry("scheduling policies")


class Policy:
    name = "base"

    def bind(self, server: EdgeServer, estimator: ExecTimeEstimator) -> None:
        self.server = server
        self.est = estimator
        self.functions: List[FunctionProfile] = server.functions

    # -- convenience shared by per-function-queue policies ---------------
    def _init_fn_queues(self) -> None:
        self.queues: Dict[int, Deque[Request]] = {
            f.fn_id: deque() for f in self.functions
        }

    # hooks ---------------------------------------------------------------
    def on_arrival(self, req: Request, t: float) -> None:
        raise NotImplementedError

    def on_cold_done(self, inst: Instance, t: float) -> None:
        raise NotImplementedError

    def on_exec_done(self, inst: Instance, req: Request, t: float) -> None:
        raise NotImplementedError

    def on_timer(self, payload, t: float) -> None:  # only OpenWhisk V2 uses it
        pass
