"""Core paper library: SFS problem model, ESFF scheduler, SSFS optimum,
baselines and the discrete-event simulator."""
from repro.core import baselines as _baselines  # noqa: F401 (registers)
from repro.core import esff as _esff            # noqa: F401 (registers)
from repro.core import esff_h as _esff_h        # noqa: F401 (registers)
from repro.core.metrics import SimResult
from repro.core.policy import POLICIES, Policy
from repro.core.request import FunctionProfile, Request, Trace
from repro.core.server import EdgeServer, ExecTimeEstimator, Instance
from repro.core.simulator import simulate
from repro.core.ssfs import (SSFSFunction, brute_force_best, sequence_cost,
                             ssfs_schedule)

__all__ = [
    "POLICIES", "Policy", "SimResult", "FunctionProfile", "Request",
    "Trace", "EdgeServer", "ExecTimeEstimator", "Instance", "simulate",
    "SSFSFunction", "brute_force_best", "sequence_cost", "ssfs_schedule",
]
