"""Edge server model (paper §III): at most C co-resident function instances.

An :class:`Instance` occupies one slot from the moment its (cold start or
eviction+cold-start) transition begins until it is evicted. Replacing an
idle instance of f_{j'} by f_j therefore keeps the slot count at C and
costs ``t_{j'}^v + t_j^l`` before the new instance becomes ready — exactly
the paper's cost model.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from enum import IntEnum
from typing import Dict, List, Optional, Set

from repro.core.events import EventKind, EventQueue
from repro.core.request import FunctionProfile, Request


class InstanceState(IntEnum):
    COLD = 0   # transitioning: eviction of predecessor + cold start
    IDLE = 1   # state(k) = 1 in the paper
    BUSY = 2   # state(k) = 0 in the paper


@dataclass
class Instance:
    inst_id: int
    fn_id: int
    state: InstanceState
    ready_at: float = 0.0
    current: Optional[Request] = None
    # bookkeeping for keep-alive style policies (FaasCache)
    freq: int = 0
    priority: float = 0.0
    last_used: float = 0.0


class ExecTimeEstimator:
    """Per-function running mean of *observed* execution times (§V).

    The scheduler can only learn execution times from completed requests.
    Before the first completion of f_j we fall back to the global running
    mean, and before any completion at all to ``prior`` seconds.
    """

    def __init__(self, n_functions: int, prior: float = 0.1,
                 oracle: Optional[List[float]] = None):
        self.n = [0] * n_functions
        self.sum = [0.0] * n_functions
        self.gn = 0
        self.gsum = 0.0
        self.prior = prior
        self.oracle = oracle

    def observe(self, fn_id: int, exec_time: float) -> None:
        self.n[fn_id] += 1
        self.sum[fn_id] += exec_time
        self.gn += 1
        self.gsum += exec_time

    def mean(self, fn_id: int) -> float:
        if self.oracle is not None:
            return max(self.oracle[fn_id], 1e-9)
        if self.n[fn_id] > 0:
            return max(self.sum[fn_id] / self.n[fn_id], 1e-9)
        if self.gn > 0:
            return max(self.gsum / self.gn, 1e-9)
        return self.prior


@dataclass
class ServerStats:
    cold_starts: int = 0
    cold_time: float = 0.0
    evictions: int = 0
    evict_time: float = 0.0
    busy_time: float = 0.0


class EdgeServer:
    """Slot/instance bookkeeping shared by every scheduling policy."""

    def __init__(self, functions: List[FunctionProfile], capacity: int,
                 events: EventQueue):
        self.functions = functions
        self.capacity = capacity
        self.events = events
        self.instances: Dict[int, Instance] = {}
        self.by_fn: Dict[int, Set[int]] = {f.fn_id: set() for f in functions}
        self.stats = ServerStats()
        self._ids = itertools.count()

    # ------------------------------------------------------------ queries
    def total_instances(self) -> int:
        return len(self.instances)

    def has_free_slot(self) -> bool:
        return len(self.instances) < self.capacity

    def k_count(self, fn_id: int) -> int:
        """|K^j| — instances currently assigned to f_j (any state)."""
        return len(self.by_fn[fn_id])

    def idle_of(self, fn_id: int) -> Optional[Instance]:
        # sorted => earliest-created first: deterministic across runs and
        # engines (set iteration order would leak hash-table layout)
        for iid in sorted(self.by_fn[fn_id]):
            inst = self.instances[iid]
            if inst.state == InstanceState.IDLE:
                return inst
        return None

    def idle_instances(self) -> List[Instance]:
        return [i for i in self.instances.values()
                if i.state == InstanceState.IDLE]

    def has_idle(self, fn_id: int) -> bool:
        return self.idle_of(fn_id) is not None

    # --------------------------------------------------------- primitives
    def dispatch(self, inst: Instance, req: Request, t: float) -> None:
        """Run ``req`` on an *idle* instance of its function."""
        assert inst.state == InstanceState.IDLE, inst
        assert inst.fn_id == req.fn_id
        inst.state = InstanceState.BUSY
        inst.current = req
        inst.freq += 1
        inst.last_used = t
        req.start = t
        req.completion = t + req.exec_time
        self.stats.busy_time += req.exec_time
        self.events.push(req.completion, EventKind.EXEC_DONE, inst)

    def start_cold(self, fn_id: int, t: float,
                   evict: Optional[Instance] = None) -> Instance:
        """Begin initialising a new instance of f_j, optionally by evicting
        an *idle* instance first (cost t_v of the evicted function)."""
        delay = self.functions[fn_id].cold_start
        if evict is not None:
            assert evict.state == InstanceState.IDLE, evict
            delay += self.functions[evict.fn_id].evict
            self.stats.evictions += 1
            self.stats.evict_time += self.functions[evict.fn_id].evict
            self._remove(evict)
        if len(self.instances) >= self.capacity:
            raise RuntimeError("start_cold would exceed capacity")
        inst = Instance(next(self._ids), fn_id, InstanceState.COLD,
                        ready_at=t + delay)
        self.instances[inst.inst_id] = inst
        self.by_fn[fn_id].add(inst.inst_id)
        self.stats.cold_starts += 1
        self.stats.cold_time += self.functions[fn_id].cold_start
        self.events.push(inst.ready_at, EventKind.COLD_DONE, inst)
        return inst

    def make_idle(self, inst: Instance) -> None:
        inst.state = InstanceState.IDLE
        inst.current = None

    def _remove(self, inst: Instance) -> None:
        del self.instances[inst.inst_id]
        self.by_fn[inst.fn_id].discard(inst.inst_id)
