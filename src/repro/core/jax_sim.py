"""Vectorised ESFF simulator — compatibility facade.

The monolithic ``lax.while_loop`` simulator that used to live here has
been split into a policy-agnostic event core (`repro.core.jax_engine`,
which owns the state layout and the loop) and per-policy kernels
(`repro.core.jax_policies`). ``simulate_esff_jax`` keeps its original
signature as a thin wrapper over the engine's ESFF kernel; ``beta`` is
still the ESFF-H hysteresis (1.0 = paper-faithful ESFF) and ``cap_mask``
still masks slots so capacity can be swept under vmap. Use
`repro.core.jax_engine.simulate_policy_jax` / ``sweep`` for the other
policies and for batched policy x capacity x trace grids.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.jax_engine import (simulate_policy_from_trace,
                                   simulate_policy_jax)
from repro.core.request import Trace


def simulate_esff_jax(fn_id, arrival, exec_time, t_cold, t_evict, *,
                      n_fns: int, capacity: int, queue_cap: int = 512,
                      beta: float = 1.0, prior: float = 0.1,
                      cap_mask=None):
    """Run ESFF over a (sorted-by-arrival) request stream.

    Returns dict with start/completion (N,), cold_starts, overflow count
    (requests that found a full per-function backlog — must be 0 for
    valid runs).
    """
    return simulate_policy_jax(
        fn_id, arrival, exec_time, t_cold, t_evict, policy="esff",
        n_fns=n_fns, capacity=capacity, queue_cap=queue_cap, beta=beta,
        prior=prior, cap_mask=cap_mask)


def simulate_jax_from_trace(trace: Trace, capacity: int, *,
                            beta: float = 1.0, queue_cap: int = 1024,
                            prior: float = 0.1) -> Dict[str, np.ndarray]:
    return simulate_policy_from_trace(
        trace, "esff", capacity, beta=beta, queue_cap=queue_cap,
        prior=prior)
