"""Vectorised ESFF simulator in JAX (``lax.while_loop``, fixed shapes).

The event-driven Python engine replays ~10^4 requests/s; sweeping
schedules (capacities x hysteresis x traces) for fleet-sizing needs
orders of magnitude more. This simulator keeps the FULL ESFF semantics —
FCP (Alg. 2), FRP (Alg. 3), running-mean estimation, slot lifecycle —
in fixed-shape arrays, so one ``jax.jit`` + ``vmap`` evaluates a policy
grid in parallel on device. Equivalence with the Python engine is tested
request-for-request (tests/test_jax_sim.py).

State layout (static F functions, C slots, Q queue depth, N requests):
  slots:   fn (C,) i32 [-1 empty] | state (C,) {0 cold,1 idle,2 busy}
           ready (C,) f64 (cold-done / exec-done time) | req (C,) i32
  queues:  ring (F, Q) i32 request ids | head/len (F,) i32
  est:     per-fn sum/count + global sum/count (running means)
  results: start/completion (N,)

Event loop: next event = min(arrival cursor, busy/cold slot readies);
slot events win ties (matching the Python engine's priority order).
``beta`` is the ESFF-H hysteresis (1.0 = paper-faithful ESFF) and
``cap_mask`` masks slots, so capacity can be swept under vmap.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.request import Trace

BIG = 1e30
COLD, IDLE, BUSY = 0, 1, 2


def _mean(sums, counts, gsum, gcount, prior):
    g = jnp.where(gcount > 0, gsum / jnp.maximum(gcount, 1), prior)
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1), g)


@functools.partial(jax.jit, static_argnames=("n_fns", "capacity",
                                             "queue_cap"))
def simulate_esff_jax(fn_id, arrival, exec_time, t_cold, t_evict, *,
                      n_fns: int, capacity: int, queue_cap: int = 512,
                      beta: float = 1.0, prior: float = 0.1,
                      cap_mask=None):
    """Run ESFF over a (sorted-by-arrival) request stream.

    Returns dict with start/completion (N,), cold_starts, overflow count
    (requests that found a full ring buffer — must be 0 for valid runs).
    """
    N = fn_id.shape[0]
    F, C, Q = n_fns, capacity, queue_cap
    if cap_mask is None:
        cap_mask = jnp.ones((C,), bool)

    state = dict(
        slot_fn=jnp.full((C,), -1, jnp.int32),
        slot_state=jnp.full((C,), IDLE, jnp.int32),
        slot_ready=jnp.full((C,), BIG, jnp.float64),
        slot_req=jnp.full((C,), -1, jnp.int32),
        q_ring=jnp.full((F, Q), -1, jnp.int32),
        q_head=jnp.zeros((F,), jnp.int32),
        q_len=jnp.zeros((F,), jnp.int32),
        est_sum=jnp.zeros((F,), jnp.float64),
        est_n=jnp.zeros((F,), jnp.int32),
        g_sum=jnp.zeros((), jnp.float64),
        g_n=jnp.zeros((), jnp.int32),
        start=jnp.full((N,), -1.0, jnp.float64),
        completion=jnp.full((N,), -1.0, jnp.float64),
        next_arrival=jnp.zeros((), jnp.int32),
        done=jnp.zeros((), jnp.int32),
        cold_starts=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), jnp.int32),
    )

    fn_id = fn_id.astype(jnp.int32)
    arrival = arrival.astype(jnp.float64)
    exec_time = exec_time.astype(jnp.float64)
    t_cold = t_cold.astype(jnp.float64)
    t_evict = t_evict.astype(jnp.float64)

    def k_counts(s):
        return jnp.zeros((F,), jnp.int32).at[
            jnp.where(s["slot_fn"] >= 0, s["slot_fn"],
                      jnp.int32(F))
        ].add(jnp.int32(1), mode="drop")

    def est_means(s):
        return _mean(s["est_sum"], s["est_n"].astype(jnp.float64),
                     s["g_sum"], s["g_n"].astype(jnp.float64), prior)

    def q_push(s, fn, rid):
        pos = (s["q_head"][fn] + s["q_len"][fn]) % Q
        full = s["q_len"][fn] >= Q
        s = dict(s)
        s["q_ring"] = s["q_ring"].at[fn, pos].set(
            jnp.where(full, s["q_ring"][fn, pos], rid))
        s["q_len"] = s["q_len"].at[fn].add(
            jnp.where(full, 0, 1))
        s["overflow"] = s["overflow"] + full.astype(jnp.int32)
        return s

    def q_pop(s, fn):
        rid = s["q_ring"][fn, s["q_head"][fn]]
        s = dict(s)
        s["q_head"] = s["q_head"].at[fn].set((s["q_head"][fn] + 1) % Q)
        s["q_len"] = s["q_len"].at[fn].add(-1)
        return s, rid

    def dispatch(s, slot, rid, t):
        """slot -> busy on request rid."""
        s = dict(s)
        comp = t + exec_time[rid]
        s["slot_state"] = s["slot_state"].at[slot].set(BUSY)
        s["slot_ready"] = s["slot_ready"].at[slot].set(comp)
        s["slot_req"] = s["slot_req"].at[slot].set(rid)
        s["start"] = s["start"].at[rid].set(t)
        s["completion"] = s["completion"].at[rid].set(comp)
        return s

    def start_cold(s, slot, fn, t, evict_fn):
        """claim/convert slot for fn (evict_fn = -1 -> empty slot)."""
        s = dict(s)
        delay = t_cold[fn] + jnp.where(evict_fn >= 0,
                                       t_evict[evict_fn], 0.0)
        s["slot_fn"] = s["slot_fn"].at[slot].set(fn)
        s["slot_state"] = s["slot_state"].at[slot].set(COLD)
        s["slot_ready"] = s["slot_ready"].at[slot].set(t + delay)
        s["cold_starts"] = s["cold_starts"] + 1
        return s

    # ------------------------------------------------------ FCP (Alg 2)
    def on_arrival(s):
        rid = s["next_arrival"]
        t = arrival[rid]
        j = fn_id[rid]
        s = dict(s)
        s["next_arrival"] = rid + 1
        means = est_means(s)
        K = k_counts(s)

        idle_own = (s["slot_fn"] == j) & (s["slot_state"] == IDLE) \
            & cap_mask
        has_idle_own = idle_own.any() & (s["q_len"][j] == 0)
        own_slot = jnp.argmax(idle_own)

        def direct(s):
            return dispatch(s, own_slot, rid, t)

        def queued(s):
            empty = (s["slot_fn"] < 0) & cap_mask
            has_empty = empty.any()
            n_e = (s["q_len"][j] + 1.0
                   - t_cold[j] * K[j] / means[j])

            def free_path(s):
                slot = jnp.argmax(empty)
                return lax.cond(n_e > 0,
                                lambda s: start_cold(s, slot, j, t, -1),
                                lambda s: s, s)

            def replace_path(s):
                idle = (s["slot_state"] == IDLE) & (s["slot_fn"] >= 0) \
                    & (s["slot_fn"] != j) & cap_mask
                sf = jnp.where(s["slot_fn"] >= 0, s["slot_fn"], 0)
                n_e2 = (s["q_len"][j] + 1.0
                        - (t_cold[j] + t_evict[sf]) * K[j] / means[j])
                elig = idle & (n_e2 > 0)
                score = jnp.where(elig, means[sf], -BIG)
                slot = jnp.argmax(score)
                return lax.cond(elig.any(),
                                lambda s: start_cold(
                                    s, slot, j, t, s["slot_fn"][slot]),
                                lambda s: s, s)

            s = lax.cond(has_empty, free_path, replace_path, s)
            return q_push(s, j, rid)

        return lax.cond(has_idle_own, direct, queued, s)

    # ------------------------------------------------- slot events
    def on_slot_event(s):
        slot = jnp.argmin(jnp.where(cap_mask, s["slot_ready"], BIG))
        t = s["slot_ready"][slot]
        j = s["slot_fn"][slot]
        is_cold = s["slot_state"][slot] == COLD

        def cold_done(s):
            s = dict(s)
            s["slot_state"] = s["slot_state"].at[slot].set(IDLE)
            s["slot_ready"] = s["slot_ready"].at[slot].set(BIG)

            def take(s):
                s, rid = q_pop(s, j)
                return dispatch(s, slot, rid, t)
            return lax.cond(s["q_len"][j] > 0, take, lambda s: s, s)

        def exec_done(s):
            rid = s["slot_req"][slot]
            s = dict(s)
            s["est_sum"] = s["est_sum"].at[j].add(exec_time[rid])
            s["est_n"] = s["est_n"].at[j].add(1)
            s["g_sum"] = s["g_sum"] + exec_time[rid]
            s["g_n"] = s["g_n"] + 1
            s["done"] = s["done"] + 1
            s["slot_state"] = s["slot_state"].at[slot].set(IDLE)
            s["slot_ready"] = s["slot_ready"].at[slot].set(BIG)
            s["slot_req"] = s["slot_req"].at[slot].set(-1)

            means = est_means(s)
            K = k_counts(s).astype(jnp.float64)
            nw = s["q_len"].astype(jnp.float64)
            # Eq. (9)
            w_own = jnp.where(
                nw[j] > 0,
                means[j] + t_evict[j] * K[j] / jnp.maximum(nw[j], 1),
                BIG)
            # Eq. (7) swapped + Eq. (10) with beta hysteresis
            n_e = nw + 1.0 - (t_cold + t_evict[j]) * K / means
            w = means + beta * (t_cold + t_evict) * (K + 1.0) \
                / jnp.maximum(n_e, 1e-30)
            idx = jnp.arange(F)
            valid = (nw > 0) & (n_e > 0) & (idx != j)
            w = jnp.where(valid, w, BIG)
            best = jnp.argmin(w)

            def replace(s):
                return start_cold(s, slot, best, t, j)

            def keep(s):
                def take(s):
                    s2, rid2 = q_pop(s, j)
                    return dispatch(s2, slot, rid2, t)
                return lax.cond(s["q_len"][j] > 0, take, lambda s: s, s)

            return lax.cond((w[best] < w_own) & valid.any(),
                            replace, keep, s)

        return lax.cond(is_cold, cold_done, exec_done, s)

    # --------------------------------------------------------- the loop
    def cond(s):
        return s["done"] < N

    def body(s):
        t_arr = jnp.where(s["next_arrival"] < N,
                          arrival[jnp.minimum(s["next_arrival"], N - 1)],
                          BIG)
        t_slot = jnp.min(jnp.where(cap_mask, s["slot_ready"], BIG))
        return lax.cond(t_slot <= t_arr, on_slot_event, on_arrival, s)

    final = lax.while_loop(cond, body, state)
    return dict(start=final["start"], completion=final["completion"],
                cold_starts=final["cold_starts"],
                overflow=final["overflow"])


def simulate_jax_from_trace(trace: Trace, capacity: int, *,
                            beta: float = 1.0, queue_cap: int = 1024,
                            prior: float = 0.1) -> Dict[str, np.ndarray]:
    # event times need f64 precision for exact agreement with the
    # Python engine over multi-hour traces
    jax.config.update("jax_enable_x64", True)
    a = trace.to_arrays()
    out = simulate_esff_jax(
        jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
        jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
        jnp.asarray(a["evict"]), n_fns=trace.n_functions,
        capacity=capacity, queue_cap=queue_cap, beta=beta, prior=prior)
    out = {k: np.asarray(v) for k, v in out.items()}
    out["response"] = out["completion"] - a["arrival"]
    out["mean_response"] = float(out["response"].mean())
    return out
