"""ESFF — Enhanced Shortest Function First (paper §V, Algorithms 1-3).

Two event-driven sub-policies:

* **FCP** (Function Creation Policy, Alg. 2) at request arrival: dispatch
  to an idle instance when the queue is empty, otherwise selectively cold
  start a new instance (Eq. 6) or replace another function's idle instance
  (Eqs. 7-8).
* **FRP** (Function Replacement Policy, Alg. 3) at request completion:
  replace the just-freed instance with the *most urgent* function — the
  smallest weight w_{j'} (Eq. 10) among functions with waiting requests —
  if w_{j'} <= w_j (Eq. 9).

Paper-typo resolutions are documented in DESIGN.md §1 and unit-tested
against the worked examples of Fig. 1 and Fig. 4.
"""
from __future__ import annotations

import math

from repro.core.policy import POLICIES, Policy
from repro.core.request import Request
from repro.core.server import Instance, InstanceState


@POLICIES.register("esff")
class ESFF(Policy):
    name = "esff"

    def bind(self, server, estimator) -> None:
        super().bind(server, estimator)
        self._init_fn_queues()

    # ------------------------------------------------------------ weights
    def _weight_current(self, fn_id: int) -> float:
        """Eq. (9): w_j = t̄_e^j + t̄_v^j |K^j| / n_j^w  (∞ when queue empty).

        t̄_l is dropped from the numerator because f_j is already resident.
        """
        n_w = len(self.queues[fn_id])
        if n_w == 0:
            return math.inf
        f = self.functions[fn_id]
        k = self.server.k_count(fn_id)
        return self.est.mean(fn_id) + f.evict * k / n_w

    def _drain_estimate(self, fn_id: int, window: float) -> float:
        """Eq. (6)/(7) core: n^e = n^w + 1 - window * |K^j| / t̄_e^j.

        ``window`` is the unavailability window (cold start, plus eviction
        when a replacement is involved); |K^j| existing instances keep
        draining the queue during it.
        """
        n_w = len(self.queues[fn_id])
        k = self.server.k_count(fn_id)
        return n_w + 1.0 - window * k / self.est.mean(fn_id)

    def _weight_candidate(self, fn_id: int, n_e: float) -> float:
        """Eq. (10): w_{j'} = t̄_e + (t̄_l + t̄_v)(|K^{j'}|+1) / n^e_{j',j}."""
        f = self.functions[fn_id]
        k = self.server.k_count(fn_id)
        return self.est.mean(fn_id) + (f.cold_start + f.evict) * (k + 1) / n_e

    # ------------------------------------------------- FCP (Algorithm 2)
    def on_arrival(self, req: Request, t: float) -> None:
        fn = req.fn_id
        srv = self.server
        idle = srv.idle_of(fn)
        if not self.queues[fn] and idle is not None:
            srv.dispatch(idle, req, t)                      # lines 1-2
            return
        if srv.has_free_slot():                             # lines 4-7
            n_e = self._drain_estimate(fn, self.functions[fn].cold_start)
            if n_e > 0:
                srv.start_cold(fn, t)
        else:                                               # lines 8-12
            best, best_exec = None, -1.0
            for inst in srv.idle_instances():
                if inst.fn_id == fn:
                    # An idle own instance with a non-empty queue cannot
                    # occur (invariant), but guard anyway: just dispatch.
                    continue
                window = (self.functions[fn].cold_start
                          + self.functions[inst.fn_id].evict)
                if self._drain_estimate(fn, window) > 0:    # Eqs. (7)-(8)
                    mean = self.est.mean(inst.fn_id)
                    if mean > best_exec:
                        best, best_exec = inst, mean
            if best is not None:                            # argmax t̄_e^{j'}
                srv.start_cold(fn, t, evict=best)
        self.queues[fn].append(req)                         # line 13

    # ---------------------------------------------------- instance ready
    def on_cold_done(self, inst: Instance, t: float) -> None:
        q = self.queues[inst.fn_id]
        if q:
            self.server.make_idle(inst)
            self.server.dispatch(inst, q.popleft(), t)
        else:
            self.server.make_idle(inst)

    # ------------------------------------------------- FRP (Algorithm 3)
    def on_exec_done(self, inst: Instance, req: Request, t: float) -> None:
        fn = inst.fn_id
        srv = self.server
        w_x = self._weight_current(fn)                      # line 1 (Eq. 9)
        f_x = fn
        for g in self.functions:                            # lines 2-9
            j2 = g.fn_id
            if j2 == fn or not self.queues[j2]:
                continue                                    # S = {n^w > 0}
            window = g.cold_start + self.functions[fn].evict
            n_e = self._drain_estimate(j2, window)          # Eq. (7) swapped
            if n_e <= 0:
                continue
            w = self._weight_candidate(j2, n_e)             # Eq. (10)
            if w < w_x:
                w_x, f_x = w, j2
        if f_x != fn:                                       # lines 10-11
            srv.make_idle(inst)
            srv.start_cold(f_x, t, evict=inst)
        elif self.queues[fn]:                               # lines 12-13
            srv.make_idle(inst)
            srv.dispatch(inst, self.queues[fn].popleft(), t)
        else:                                               # lines 14-15
            srv.make_idle(inst)
