"""Policy kernels for the vectorised event core (`repro.core.jax_engine`).

Each kernel re-expresses one Python scheduling policy as pure functions
over the engine's fixed-shape state, request-for-request equivalent to
its event-driven counterpart (tests/test_jax_engine.py):

* **esff** — FCP (Alg. 2) / FRP (Alg. 3) with running-mean estimation;
  ``beta`` = 1.0 recovers the paper-faithful scheduler and > 1 adds the
  ESFF-H hysteresis on the conversion setup cost.
* **esff_h** — ESFF plus the three ESFF-H fixes (`repro.core.esff_h`):
  beta hysteresis (default 2.0), cold-aware drain estimates (in-flight
  instances claim a waiting request) and LRU victim choice in Eq. 8.
* **sff / openwhisk** — the central-queue baselines: immediate scale-up
  on arrival (LRU eviction at capacity), warm reuse of a freed slot's
  own queue, otherwise retarget to the central-queue head (at most one
  warming replica). SFF orders the central queue by running-mean
  execution time, OpenWhisk by arrival.
* **faascache** — OpenWhisk scheduling with GREEDY-DUAL keep-alive
  [Fuerst & Sharma, ASPLOS'21]: per-slot ``slot_freq``/``slot_prio``
  state plus a global clock; eviction victim = lowest
  ``clock + freq * cold_start`` priority, clock bumped to the evicted
  priority.
* **openwhisk_v2** — per-function queues; a queue head must wait
  ``threshold`` (100 ms) before scale-up, enforced with engine timers.

Hooks follow the engine's guarded-write convention: they execute every
loop iteration, compute with possibly-garbage values when their ``on``
predicate is false, and fold the predicate into every state write (so
disabled paths cost dropped scatters instead of dense selects under
vmap). Tie-breaking faithfully mirrors the Python engine's iteration
order via the per-slot creation sequence numbers (``slot_seq``) the
engine maintains: victim scans break ties toward the earliest-created
instance, exactly like scanning ``instances`` in ``inst_id`` order with
strict inequalities.

Kernels address the trace exclusively through the `EngineCtx` read
API (``fn_at`` / ``arrival_at`` / ``exec_at`` / ``rid_at_pos``) with
*absolute* request ids and per-function positions; the engine's
cache-window machinery translates those to window-relative slab
indices underneath (and to full-operand fallbacks for ids whose queue
links span a window boundary), so a kernel is automatically correct —
and bitwise identical — at every window size. Dispatch accounting
likewise rides the engine's ``dispatch`` helper, whose per-event
metric registers keep the streamed accumulators window-invariant; a
kernel must never write result state directly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.jax_engine import (BIG, COLD, IDLE, EngineCtx,
                                   PolicyKernel, _gidx, arm_timer,
                                   cold_counts, dispatch, est_means,
                                   k_counts, lex_argmin, pick_idle_own,
                                   q_consume_direct, q_head, q_pop,
                                   q_push, rearm_timer, start_cold)


class ESFFKernel(PolicyKernel):
    """ESFF (Algorithms 1-3); flags select the ESFF-H variants."""

    def __init__(self, name: str, *, lru_victim: bool = False,
                 cold_aware: bool = False, default_beta: float = 1.0):
        self.name = name
        self.lru_victim = lru_victim
        self.cold_aware = cold_aware
        self.default_beta = default_beta

    def _drain_terms(self, ctx: EngineCtx, s):
        """means, |K|, and the cold-instance correction of Eq. 6/7."""
        means = est_means(ctx, s)
        K = k_counts(ctx, s)
        coldK = (cold_counts(ctx, s).astype(jnp.float64)
                 if self.cold_aware else None)
        return means, K, coldK

    # ------------------------------------------------- FCP (Algorithm 2)
    def on_arrival(self, ctx, s, rid, t, on):
        j = ctx.fn_at(rid)
        means, K, coldK = self._drain_terms(ctx, s)
        has_own, own_slot = pick_idle_own(ctx, s, j)
        direct = on & has_own & (s["q_len"][j] == 0)
        s = dispatch(ctx, s, own_slot, rid, t, direct)
        s = q_consume_direct(ctx, s, j, direct)
        queued = on & ~direct

        empty = (s["slot_fn"] < 0) & ctx.cap_mask
        n_e = s["q_len"][j] + 1.0 - ctx.t_cold[j] * K[j] / means[j]
        if self.cold_aware:
            n_e = n_e - coldK[j]
        s = start_cold(ctx, s, jnp.argmax(empty), j, t, -1,
                       queued & empty.any() & (n_e > 0))

        idle = ((s["slot_state"] == IDLE) & (s["slot_fn"] >= 0)
                & (s["slot_fn"] != j) & ctx.cap_mask)
        sf = jnp.where(s["slot_fn"] >= 0, s["slot_fn"], 0)
        n_e2 = (s["q_len"][j] + 1.0
                - (ctx.t_cold[j] + ctx.t_evict[sf]) * K[j] / means[j])
        if self.cold_aware:
            n_e2 = n_e2 - coldK[j]
        elig = idle & (n_e2 > 0)
        # Eq. 8 victim: argmax t̄_e (ESFF) or LRU (ESFF-H), ties toward
        # the earliest-created instance
        primary = s["slot_used"] if self.lru_victim else -means[sf]
        victim = lex_argmin(primary, s["slot_seq"], elig)
        s = start_cold(ctx, s, victim, j, t, s["slot_fn"][victim],
                       queued & ~empty.any() & elig.any())
        s, _ = q_push(ctx, s, j, rid, queued)
        return s

    # ----------------------------------------------------- instance ready
    def on_cold_done(self, ctx, s, slot, t, on):
        j = s["slot_fn"][slot]
        take = on & (s["q_len"][jnp.clip(j, 0, ctx.F - 1)] > 0)
        s, rid = q_pop(ctx, s, j, take)
        return dispatch(ctx, s, slot, rid, t, take)

    # ------------------------------------------------- FRP (Algorithm 3)
    def on_exec_done(self, ctx, s, slot, rid, t, on):
        j = s["slot_fn"][slot]
        jc = jnp.clip(j, 0, ctx.F - 1)
        means, K, coldK = self._drain_terms(ctx, s)
        K = K.astype(jnp.float64)
        nw = s["q_len"].astype(jnp.float64)
        # Eq. (9)
        w_own = jnp.where(
            nw[jc] > 0,
            means[jc] + ctx.t_evict[jc] * K[jc]
            / jnp.maximum(nw[jc], 1),
            BIG)
        # Eq. (7) swapped + Eq. (10) with beta hysteresis
        n_e = nw + 1.0 - (ctx.t_cold + ctx.t_evict[jc]) * K / means
        if self.cold_aware:
            n_e = n_e - coldK
        w = (means + ctx.beta * (ctx.t_cold + ctx.t_evict) * (K + 1.0)
             / jnp.maximum(n_e, 1e-30))
        idx = jnp.arange(ctx.F)
        valid = (nw > 0) & (n_e > 0) & (idx != jc)
        w = jnp.where(valid, w, BIG)
        best = jnp.argmin(w)

        replace = on & (w[best] < w_own) & valid.any()
        s = start_cold(ctx, s, slot, best, t, j, replace)
        take = on & ~replace & (s["q_len"][jc] > 0)
        s, rid2 = q_pop(ctx, s, j, take)
        return dispatch(ctx, s, slot, rid2, t, take)


class CentralQueueKernel(PolicyKernel):
    """OpenWhisk / SFF: central queue + immediate scale-up + LRU keep-
    alive, with warm reuse of a freed slot's own waiting requests.

    The eviction-victim key, the dispatch bookkeeping and the new-
    instance reset are overridable hooks so FaasCache can swap LRU for
    GREEDY-DUAL priorities without touching the queue discipline."""

    def __init__(self, name: str, *, order: str = "fifo"):
        assert order in ("fifo", "sff")
        self.name = name
        self.order = order

    # -- keep-alive hooks (FaasCache overrides) --------------------------
    def _dispatch(self, ctx, s, slot, rid, t, on):
        return dispatch(ctx, s, slot, rid, t, on)

    def _victim_key(self, ctx, s):
        """Primary eviction key among idle slots (ties: slot_seq)."""
        return s["slot_used"]    # LRU

    def _note_evict(self, ctx, s, victim, on):
        return s

    def _start_cold(self, ctx, s, slot, fn, t, evict_fn, on):
        return start_cold(ctx, s, slot, fn, t, evict_fn, on)

    def _head_fn(self, ctx, s):
        """Central-queue head: (exists, fn). Requests are globally
        FIFO-comparable by id (traces are arrival-sorted), so OpenWhisk
        minimises the head id and SFF (t̄_e, id) lexicographically."""
        heads = s["q_head_rid"]
        valid = s["q_len"] > 0
        if self.order == "sff":
            f = lex_argmin(est_means(ctx, s), heads, valid)
        else:
            f = lex_argmin(jnp.zeros((ctx.F,)), heads, valid)
        return valid.any(), f

    def _scale_up(self, ctx, s, j, t, on):
        """No idle instance for an arrival of ``j``: claim a free slot,
        else evict the keep-alive victim (LRU here; GREEDY-DUAL in
        FaasCache — ties: earliest-created)."""
        empty = (s["slot_fn"] < 0) & ctx.cap_mask
        s = self._start_cold(ctx, s, jnp.argmax(empty), j, t, -1,
                             on & empty.any())
        idle = (s["slot_state"] == IDLE) & (s["slot_fn"] >= 0) \
            & ctx.cap_mask
        victim = lex_argmin(self._victim_key(ctx, s), s["slot_seq"],
                            idle)
        evicting = on & ~empty.any() & idle.any()
        s = self._note_evict(ctx, s, victim, evicting)
        return self._start_cold(ctx, s, victim, j, t,
                                s["slot_fn"][victim], evicting)

    def on_arrival(self, ctx, s, rid, t, on):
        j = ctx.fn_at(rid)
        has_own, own_slot = pick_idle_own(ctx, s, j)
        # an idle own instance never coexists with a non-empty own
        # queue (every serve/replace path drains or converts first), so
        # the q_len gate is a no-op semantically — it guarantees the
        # positional-queue contract holds even for a buggy kernel state
        direct = on & has_own & (s["q_len"][jnp.clip(j, 0, ctx.F - 1)]
                                 == 0)
        s = self._dispatch(ctx, s, own_slot, rid, t, direct)
        s = q_consume_direct(ctx, s, j, direct)
        queued = on & ~direct
        s, _ = q_push(ctx, s, j, rid, queued)
        return self._scale_up(ctx, s, j, t, queued)

    def _serve_or_replace(self, ctx, s, slot, t, on):
        """Central-queue discipline for a freed idle slot: drain its own
        function's earliest request (warm reuse), else retarget to the
        queue-head function — at most one warming replica at a time."""
        j = s["slot_fn"][slot]
        own = on & (s["q_len"][jnp.clip(j, 0, ctx.F - 1)] > 0)
        s, rid = q_pop(ctx, s, j, own)
        s = self._dispatch(ctx, s, slot, rid, t, own)

        exists, f = self._head_fn(ctx, s)
        warming = ((s["slot_fn"] == f) & (s["slot_state"] == COLD)
                   & ctx.cap_mask).any()
        retarget = on & ~own & exists & ~warming
        s = self._note_evict(ctx, s, slot, retarget)
        return self._start_cold(ctx, s, slot, f, t, j, retarget)

    def on_cold_done(self, ctx, s, slot, t, on):
        return self._serve_or_replace(ctx, s, slot, t, on)

    def on_exec_done(self, ctx, s, slot, rid, t, on):
        return self._serve_or_replace(ctx, s, slot, t, on)


class FaasCacheKernel(CentralQueueKernel):
    """FaasCache [Fuerst & Sharma, ASPLOS'21]: OpenWhisk scheduling
    with GREEDY-DUAL keep-alive, request-for-request equivalent to
    `repro.core.baselines.FaasCache`.

    Per-slot state: ``slot_freq`` (use count of the resident instance)
    and ``slot_prio`` (= clock + freq * cold_start, recomputed at every
    dispatch with the pre-increment freq + 1, exactly the Python
    ``_note_use``/``dispatch`` order); ``gd_clock`` is the global clock,
    bumped to the victim's priority on every eviction. A fresh instance
    keeps priority 0.0 until its first dispatch (the Python
    ``Instance`` default), which is what ages never-used instances out
    first."""

    name = "faascache"

    def __init__(self):
        super().__init__("faascache", order="fifo")

    def extra_state(self, L, C, F):
        return dict(slot_freq=jnp.zeros((L, C), jnp.int32),
                    slot_prio=jnp.zeros((L, C), jnp.float64),
                    gd_clock=jnp.zeros((L,), jnp.float64))

    def _dispatch(self, ctx, s, slot, rid, t, on):
        sc = jnp.clip(slot, 0, ctx.C - 1)
        fn = jnp.clip(s["slot_fn"][sc], 0, ctx.F - 1)
        prio = (s["gd_clock"]
                + (s["slot_freq"][sc] + 1.0) * ctx.t_cold[fn])
        si = _gidx(on, slot, ctx.C)
        s = dict(s)
        s["slot_freq"] = s["slot_freq"].at[si].add(1, mode="drop")
        s["slot_prio"] = s["slot_prio"].at[si].set(prio, mode="drop")
        return dispatch(ctx, s, slot, rid, t, on)

    def _victim_key(self, ctx, s):
        return s["slot_prio"]    # GREEDY-DUAL

    def _note_evict(self, ctx, s, victim, on):
        prio = s["slot_prio"][jnp.clip(victim, 0, ctx.C - 1)]
        s = dict(s)
        s["gd_clock"] = jnp.maximum(
            s["gd_clock"], jnp.where(on, prio, -BIG))
        return s

    def _start_cold(self, ctx, s, slot, fn, t, evict_fn, on):
        s = start_cold(ctx, s, slot, fn, t, evict_fn, on)
        si = _gidx(on, slot, ctx.C)
        s["slot_freq"] = s["slot_freq"].at[si].set(0, mode="drop")
        s["slot_prio"] = s["slot_prio"].at[si].set(0.0, mode="drop")
        return s


class OpenWhiskV2Kernel(PolicyKernel):
    """Per-function queues + head-wait threshold before scale-up.

    Timers replicate the event engine exactly, including its quirks: a
    timer firing for a non-head request is dropped (the then-head's own
    timer is relied upon), so a request can lose its timer and then wait
    for a warm instance of its function — same as the Python policy.
    The Python policy's ``req.start >= 0`` guard is subsumed by the
    head check: a dispatched request was popped from its queue, so it
    can never still be the head.
    """

    name = "openwhisk_v2"
    has_timers = True

    def on_arrival(self, ctx, s, rid, t, on):
        j = ctx.fn_at(rid)
        has_own, own_slot = pick_idle_own(ctx, s, j)
        direct = on & has_own & (s["q_len"][j] == 0)
        s = dispatch(ctx, s, own_slot, rid, t, direct)
        s = q_consume_direct(ctx, s, j, direct)
        queued = on & ~direct
        s, pushed = q_push(ctx, s, j, rid, queued)
        return arm_timer(ctx, s, j, rid, t, pushed, on)

    def on_timer(self, ctx, s, rid, t, on):
        j = ctx.fn_at(rid)
        is_head = (s["q_len"][j] > 0) & (q_head(ctx, s, j) == rid)
        act = on & is_head
        warming = ((s["slot_fn"] == j) & (s["slot_state"] == COLD)
                   & ctx.cap_mask).any()

        empty = (s["slot_fn"] < 0) & ctx.cap_mask
        scale = act & ~warming
        s = start_cold(ctx, s, jnp.argmax(empty), j, t, -1,
                       scale & empty.any())
        idle = (s["slot_state"] == IDLE) & (s["slot_fn"] >= 0) \
            & ctx.cap_mask
        victim = lex_argmin(s["slot_used"], s["slot_seq"], idle)
        s = start_cold(ctx, s, victim, j, t, s["slot_fn"][victim],
                       scale & ~empty.any() & idle.any())
        # blocked (still warming, or nothing evictable): retry later
        rearm = (act & warming) | (scale & ~empty.any() & ~idle.any())
        return rearm_timer(ctx, s, j, rid, t + ctx.threshold, rearm)

    def _drain_own(self, ctx, s, slot, t, on):
        j = s["slot_fn"][slot]
        take = on & (s["q_len"][jnp.clip(j, 0, ctx.F - 1)] > 0)
        s, rid = q_pop(ctx, s, j, take)
        return dispatch(ctx, s, slot, rid, t, take)

    def on_cold_done(self, ctx, s, slot, t, on):
        return self._drain_own(ctx, s, slot, t, on)

    def on_exec_done(self, ctx, s, slot, rid, t, on):
        return self._drain_own(ctx, s, slot, t, on)


# Kernel singletons: stable identities keep the jit cache warm across
# calls (the kernel is a static argument of the engine).
KERNELS = {
    "esff": ESFFKernel("esff"),
    "esff_h": ESFFKernel("esff_h", lru_victim=True, cold_aware=True,
                         default_beta=2.0),
    "sff": CentralQueueKernel("sff", order="sff"),
    "openwhisk": CentralQueueKernel("openwhisk", order="fifo"),
    "faascache": FaasCacheKernel(),
    "openwhisk_v2": OpenWhiskV2Kernel(),
}
