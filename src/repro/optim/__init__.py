from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               opt_state_axes)
from repro.optim.schedules import cosine_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "opt_state_axes",
           "cosine_schedule"]
