"""AdamW with large-model memory options.

* ``moment_dtype``: fp32 (default) / bf16 first moment.
* ``quantize_nu``: int8 block-quantised second moment (per-block absmax,
  block 128 on the trailing axis) — 4x smaller nu. Required to fit
  deepseek-v3-671b training on 512 v5e chips (DESIGN.md §2).
* State sharding (ZeRO-1) is not done here — optimizer states simply
  inherit the parameter PartitionSpecs, and ``distributed/zero.py`` can
  further shard replicated-parameter states across the data axis.

All update math runs in fp32 regardless of storage dtypes.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"
    quantize_nu: bool = False
    nu_block: int = 128


# ------------------------------------------- int8 log-space block quant
# Adam's second moment spans many orders of magnitude within a block;
# LINEAR absmax int8 rounds small entries to zero and 1/sqrt(nu) explodes
# (measured: parameter error 38x the update after 2 steps). We therefore
# quantise nu on a per-block LOG scale: q in [0,127] maps to
# blockmax * RATIO^(q/127) with RATIO=1e-6, i.e. bounded ~5.6% relative
# error across six decades (the bitsandbytes dynamic-exponent idea,
# simplified). Values below blockmax*RATIO clamp to the floor, which only
# makes those coordinates' updates slightly conservative.
#
# Shape-preserving: q keeps the parameter's shape (int8); per-block max
# lives on the last axis / nu_block. Both inherit the parameter sharding.
_LOG_RATIO = 1e-6
import math as _math

_LOG_DENOM = _math.log(_LOG_RATIO)


def _nu_scale_shape(shape, block: int):
    last = shape[-1] if shape else 1
    return tuple(shape[:-1]) + (-(-last // block),)


def _q8_encode(x: jax.Array, block: int) -> Tuple[jax.Array, jax.Array]:
    """x >= 0 (second moments)."""
    last = x.shape[-1]
    pad = (-last) % block
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    b = xp.reshape(xp.shape[:-1] + (-1, block))
    bmax = jnp.max(b, axis=-1)                         # (..., nb)
    safe = jnp.maximum(bmax, 1e-30)
    ratio = jnp.clip(b / safe[..., None], _LOG_RATIO, 1.0)
    q = jnp.round(127.0 * jnp.log(ratio) / _LOG_DENOM)
    q = q.reshape(xp.shape)[..., :last].astype(jnp.int8)
    return q, bmax.astype(jnp.float32)


def _q8_decode(q: jax.Array, bmax: jax.Array, block: int) -> jax.Array:
    last = q.shape[-1]
    pad = (-last) % block
    qp = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    b = qp.reshape(qp.shape[:-1] + (-1, block)).astype(jnp.float32)
    x = bmax[..., None] * jnp.exp(b / 127.0 * _LOG_DENOM)
    x = jnp.where(bmax[..., None] <= 0, 0.0, x)
    return x.reshape(qp.shape)[..., :last]


def adamw_init(cfg: AdamWConfig, params) -> Dict[str, Any]:
    mdt = jnp.dtype(cfg.moment_dtype)

    def mu_like(p):
        return jnp.zeros(p.shape, mdt)

    def nu_like(p):
        if cfg.quantize_nu:
            return {"q": jnp.zeros(p.shape, jnp.int8),
                    "scale": jnp.zeros(_nu_scale_shape(p.shape,
                                                       cfg.nu_block),
                                       jnp.float32)}
        return jnp.zeros(p.shape, mdt)

    return {
        "mu": jax.tree.map(mu_like, params),
        "nu": jax.tree.map(nu_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(cfg: AdamWConfig, param_axes):
    """Logical-axis tree for the optimizer state mirroring param axes."""
    def is_axes(x):
        return isinstance(x, tuple) and (
            len(x) == 0 or not isinstance(x[0], dict))

    mu = jax.tree.map(lambda ax: ax, param_axes, is_leaf=is_axes)
    if cfg.quantize_nu:
        # scale blocks divide the last axis by nu_block; its count rarely
        # divides the mesh axis, so replicate the (tiny) last scale dim.
        nu = jax.tree.map(
            lambda ax: {"q": ax, "scale": tuple(ax[:-1]) + (None,)},
            param_axes, is_leaf=is_axes)
    else:
        nu = jax.tree.map(lambda ax: ax, param_axes, is_leaf=is_axes)
    return {"mu": mu, "nu": nu, "step": ()}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, state,
                 lr: Optional[jax.Array] = None):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip else 1.0
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_f = mu.astype(jnp.float32)
        mu_new = cfg.b1 * mu_f + (1 - cfg.b1) * g
        if cfg.quantize_nu:
            nu_f = _q8_decode(nu["q"], nu["scale"], cfg.nu_block)
        else:
            nu_f = nu.astype(jnp.float32)
        nu_new = cfg.b2 * nu_f + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu_new / b1c
        nu_hat = nu_new / b2c
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        mu_out = mu_new.astype(mu.dtype)
        if cfg.quantize_nu:
            q, s = _q8_encode(nu_new, cfg.nu_block)
            nu_out = {"q": q, "scale": s}
        else:
            nu_out = nu_new.astype(nu.dtype)
        return p_new, mu_out, nu_out

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    is_nu = (lambda x: isinstance(x, dict) and "q" in x) \
        if cfg.quantize_nu else None
    flat_nu = jax.tree.leaves(state["nu"], is_leaf=is_nu)
    out = [upd(p, g, m, n)
           for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"grad_norm": gnorm,
                              "lr": jnp.asarray(lr, jnp.float32)}
