"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = sum over collective ops of operand_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (whole-program
totals across all devices). Collective bytes are NOT in cost_analysis: we
parse the optimized HLO text and sum operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops (these
are per-shard shapes; bytes counted are what each device moves, summed
program-wide).
"""
from __future__ import annotations

import dataclasses
import json
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
HW_V5E = dict(
    peak_flops=197e12,     # bf16 FLOP/s
    hbm_bw=819e9,          # bytes/s
    ici_bw=50e9,           # bytes/s per link (~4 usable links/chip on the
                           # 2D torus; we charge the single-link figure as
                           # the conservative per-hop bandwidth)
)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """bytes of one HLO shape like 'bf16[8,4096,128]' or a tuple of them."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes of every collective op in optimized HLO.

    Uses the op's *result* shape (per-participant shard bytes). all-reduce
    moves ~2x its buffer in a ring; we report raw buffer bytes and apply
    algorithm factors in `analyze`.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # matches:  %name = bf16[...] all-reduce(...)
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],]+)\s+"
                     r"([a-z\-]+)", s)
        if not m:
            continue
        op = m.group(2)
        if op.rstrip("-start").rstrip("-done") in _COLLECTIVES:
            op = op.replace("-start", "").replace("-done", "")
        if op not in _COLLECTIVES:
            continue
        if "-done" in s.split("=")[1][:60]:
            continue
        out[op] += _shape_bytes(m.group(1))
        counts[op] += 1
    out["counts"] = counts
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float          # whole-program FLOPs / 1e9
    hlo_gbytes: float          # whole-program HBM traffic / 1e9
    collective_gbytes: float   # per-device collective bytes / 1e9
    collective_breakdown: Dict[str, float]
    compute_s: float
    memory_s: float
    collective_s: float
    model_gflops: float        # 6*N*D (or 6*N_active*D for MoE)
    bytes_per_device: Dict[str, float] = field(default_factory=dict)
    notes: str = ""

    @property
    def dominant(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        return (self.model_gflops / self.hlo_gflops
                if self.hlo_gflops else 0.0)

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline step time."""
        chips_flops = self.chips * HW_V5E["peak_flops"]
        t = self.step_time_s
        return (self.model_gflops * 1e9) / (chips_flops * t) if t else 0.0

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant, step_time_s=self.step_time_s,
                 useful_flop_ratio=self.useful_flop_ratio, mfu=self.mfu)
        return d


def model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int,
                n_params_active: Optional[float] = None) -> float:
    """6*N*D for train, 2*N*D for forward-only (prefill), 2*N*B for one
    decode token. N = active params (MoE: routed fraction only)."""
    n = n_params_active if n_params_active is not None else 0.0
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * global_batch


def active_params(cfg) -> float:
    """Parameter count with MoE counted at top-k routed + shared experts."""
    d, L_ = cfg.d_model, cfg.n_layers
    v = cfg.padded_vocab
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        di = cfg.d_inner
        per = d * 2 * di + d * 2 * cfg.ssm_ngroups * cfg.ssm_state \
            + d * cfg.ssm_heads + di * d
        return emb + L_ * per
    if cfg.family == "encdec":
        att = 4 * d * cfg.n_heads * cfg.head_dim_
        mlp = 2 * d * cfg.d_ff
        return emb + cfg.enc_layers * (att + mlp) \
            + cfg.dec_layers * (2 * att + mlp)
    if cfg.mla:
        att = (d * cfg.q_lora_rank
               + cfg.q_lora_rank * cfg.n_heads
               * (cfg.qk_nope_dim + cfg.qk_rope_dim)
               + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)
               + cfg.kv_lora_rank * cfg.n_heads
               * (cfg.qk_nope_dim + cfg.v_head_dim)
               + cfg.n_heads * cfg.v_head_dim * d)
    else:
        att = d * cfg.n_heads * cfg.head_dim_ * 2 \
            + d * cfg.n_kv_heads * cfg.head_dim_ * 2
    mlp_dense = 3 * d * cfg.d_ff
    if cfg.family == "moe":
        f = cfg.moe_d_ff
        act_experts = cfg.topk + cfg.n_shared_experts
        moe = 3 * d * f * act_experts + d * cfg.n_experts  # + router
        nd = cfg.first_dense_layers
        total = emb + nd * (att + mlp_dense) + (L_ - nd) * (att + moe)
        return total
    if cfg.family == "hybrid":
        di = cfg.d_inner
        per = d * 2 * di + d * 2 * cfg.ssm_ngroups * cfg.ssm_state \
            + d * cfg.ssm_heads + di * d
        shared = (2 * d) * d + att + mlp_dense   # one shared block
        n_shared_uses = L_ // cfg.attn_every
        return emb + L_ * per + shared * max(n_shared_uses, 1)
    total = emb + L_ * (att + mlp_dense)
    if cfg.family == "vlm":
        pass  # frontend stubbed; backbone only
    return total


def analyze(arch: str, shape: str, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, cfg, shape_cfg,
            memory_stats: Optional[dict] = None) -> RooflineReport:
    """Roofline terms from the compiled module.

    FLOPs/bytes/collectives come from our own HLO analyzer
    (roofline/hlo_cost.py) because ``compiled.cost_analysis()`` counts
    while-loop bodies ONCE — models lowered as scan-over-layers inside
    scan-over-microbatches would be underreported by the product of trip
    counts. ``cost`` (XLA's numbers) is kept in the record for
    comparison.
    """
    from repro.roofline.hlo_cost import analyze_hlo

    summary = analyze_hlo(hlo_text)
    flops = summary.flops
    bts = summary.bytes_accessed
    weighted = summary.weighted_collective_bytes
    n_active = active_params(cfg)
    mf = model_flops(cfg, shape_cfg.kind, shape_cfg.seq_len,
                     shape_cfg.global_batch, n_active)
    # HLO totals are per-program = per-device under SPMD
    compute_s = flops / HW_V5E["peak_flops"]
    memory_s = bts / HW_V5E["hbm_bw"]
    collective_s = weighted / HW_V5E["ici_bw"]
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_gflops=flops / 1e9, hlo_gbytes=bts / 1e9,
        collective_gbytes=weighted / 1e9,
        collective_breakdown={k: v / 1e9 for k, v
                              in summary.collective_bytes.items()},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_gflops=mf / 1e9 / chips,   # per-device share of model FLOPs
        bytes_per_device=memory_stats or {},
    )
