from repro.roofline.analysis import (HW_V5E, RooflineReport, analyze,
                                     collective_bytes_from_hlo)

__all__ = ["HW_V5E", "RooflineReport", "analyze",
           "collective_bytes_from_hlo"]
