"""HLO-text cost analyzer with while-loop trip-count multiplication.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — a model
lowered as scan-over-layers inside scan-over-microbatches underreports
FLOPs/bytes/collectives by the product of trip counts (measured: 10x for
a 10-step scan; see tests/test_hlo_cost.py). This module parses the
optimized HLO text, reconstructs the computation graph, infers each
loop's trip count from its condition computation, and accumulates

* ``flops``       — dot/convolution FLOPs x loop multipliers,
* ``bytes``       — per-op (operands + result) bytes x multipliers
                    (same convention as XLA's bytes-accessed),
* ``collectives`` — per-collective-op result bytes x multipliers.

Trip-count inference: lax.scan lowers to a while whose condition compares
an s32 induction variable against a constant; we take the largest integer
constant in the condition computation. Fusion computations are charged to
their caller; their inner dots are counted (XLA keeps big dots unfused or
in output fusions — either way the dot op text carries shapes).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f4e2m1fn": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\](?:\{[\d,*]*\})?")
# result shape is either a scalar/array shape or a (possibly long) tuple;
# tuples may contain /*index=N*/ comments, so match balanced non-parens.
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[a-z0-9]+\["
    r"[\d,]*\](?:\{[\d,]*\})?))\s+([\w\-]+)\((.*)$")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")


def _shape_list(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(s):
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((m.group(1), dims))
    return out


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _shape_list(s):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 0)
    return total


@dataclass
class Op:
    name: str
    opcode: str
    result_shape: str
    rest: str            # text after the opening paren (args + attrs)
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # op -> result


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        # computation header: non-indented, ends with '{', has no ' = '
        # before the brace (op lines always contain ' = ').
        if (not line.startswith(" ") and s.endswith("{")
                and " = " not in s.split("{")[0]):
            m = _COMP_START_RE.match(s)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                continue
        if s.startswith("}"):
            continue
        m = _DEF_RE.match(s)
        if m and cur is not None:
            name, shape, opcode, rest = m.groups()
            operands = re.findall(r"%([\w.\-]+)", rest.split(
                "),")[0] if opcode != "fusion" else rest)
            op = Op(name, opcode, shape, rest, operands)
            cur.ops.append(op)
            cur.shapes[name] = shape
    return comps


def _called_comps(op: Op) -> List[str]:
    names = []
    for key in ("body=", "condition=", "calls=", "to_apply=",
                "branch_computations="):
        for m in re.finditer(key + r"\{?%?([\w.\-]+)", op.rest):
            names.append(m.group(1))
        if key == "branch_computations=":
            m = re.search(r"branch_computations=\{([^}]*)\}", op.rest)
            if m:
                names.extend(re.findall(r"%?([\w.\-]+)", m.group(1)))
    return names


def _trip_count(cond: Computation) -> int:
    """Largest integer constant in the loop condition computation."""
    best = 1
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return max(best, 1)


def _dot_flops(op: Op, comp: Computation) -> float:
    """2 * prod(result dims) * contraction size (batch dims cancel)."""
    res = _shape_list(op.result_shape)
    if not res:
        return 0.0
    _, rdims = res[0]
    out_elems = 1
    for d in rdims:
        out_elems *= d
    # contraction size from lhs shape + lhs_contracting_dims
    lhs_name = op.operands[0] if op.operands else None
    lhs_shape = comp.shapes.get(lhs_name, "")
    lhs = _shape_list(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if lhs and m:
        dims = lhs[0][1]
        for i in (int(x) for x in m.group(1).split(",") if x):
            if i < len(dims):
                contract *= dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, comp: Computation) -> float:
    res = _shape_list(op.result_shape)
    rhs_name = op.operands[1] if len(op.operands) > 1 else None
    rhs = _shape_list(comp.shapes.get(rhs_name, ""))
    if not res or not rhs:
        return 0.0
    out = 1
    for d in res[0][1]:
        out *= d
    ker = 1
    for d in rhs[0][1]:
        ker *= d
    # per output element: kernel-volume MACs (feature dims folded into rhs)
    out_feat = res[0][1][-1] if res[0][1] else 1
    return 2.0 * out * (ker / max(out_feat, 1))


@dataclass
class CostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    flops_by_comp: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    bytes_by_comp: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))
    convert_bytes_excluded: float = 0.0   # CPU-only dtype/layout traffic
    comp_mult: Dict[str, float] = field(
        default_factory=lambda: defaultdict(float))

    @property
    def weighted_collective_bytes(self) -> float:
        """Ring-algorithm wire bytes: all-reduce moves ~2x its buffer."""
        t = 0.0
        for k, v in self.collective_bytes.items():
            t += 2.0 * v if k == "all-reduce" else v
        return t


def analyze_hlo(text: str, entry: Optional[str] = None) -> CostSummary:
    comps = parse_hlo(text)
    if not comps:
        return CostSummary()
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        entry = m.group(1) if m else next(iter(comps))

    # computation multipliers via DFS from entry. Computations reached
    # through fusion-like ops are flagged: their ops contribute FLOPs but
    # not HBM bytes (the fusion callsite accounts for the traffic).
    mult: Dict[str, float] = defaultdict(float)
    fusion_mult: Dict[str, float] = defaultdict(float)
    seen_stack = set()

    def visit(cname: str, m: float, in_fusion: bool):
        if cname not in comps or cname in seen_stack:
            return
        (fusion_mult if in_fusion else mult)[cname] += m
        seen_stack.add(cname)
        comp = comps[cname]
        for op in comp.ops:
            if op.opcode == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", op.rest)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                # Prefer XLA's own annotation when present.
                tm = re.search(r'known_trip_count..\{.n.:.(\d+)', op.rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    visit(body, m * trip, in_fusion)
                if cond:
                    visit(cond, m * (trip + 1), in_fusion)
            else:
                child_fusion = in_fusion or op.opcode in (
                    "fusion", "reduce", "reduce-window", "scatter", "sort",
                    "map", "custom-call", "all-reduce", "reduce-scatter",
                    "select-and-scatter")
                for sub in _called_comps(op):
                    if sub in comps:
                        visit(sub, m, child_fusion)
        seen_stack.discard(cname)

    visit(entry, 1.0, False)

    # ops whose true HBM traffic is the sliced region, not the operand
    _SLICING = ("dynamic-slice", "slice", "gather")

    out = CostSummary()
    for mm, is_fusion in ((mult, False), (fusion_mult, True)):
        for cname, cmult in mm.items():
            comp = comps[cname]
            for op in comp.ops:
                if op.opcode == "dot":
                    f = _dot_flops(op, comp) * cmult
                    out.flops += f
                    out.flops_by_comp[cname] += f
                elif op.opcode == "convolution":
                    f = _conv_flops(op, comp) * cmult
                    out.flops += f
                    out.flops_by_comp[cname] += f
                opc = op.opcode
                for coll in _COLLECTIVES:
                    if opc == coll or opc == coll + "-start":
                        b = _shape_bytes(op.result_shape)
                        # XLA:CPU promotes bf16 collectives to f32
                        # (convert-wrapped); the TPU target reduces bf16
                        # natively, so charge the pre-promotion bytes.
                        if _is_promoted_bf16(op, comp):
                            b //= 2
                        out.collective_bytes[coll] += b * cmult
                        out.collective_counts[coll] += cmult
                if is_fusion:
                    continue   # no direct HBM bytes inside fusions
                if opc in ("parameter", "constant", "tuple",
                           "get-tuple-element", "bitcast", "while",
                           "conditional", "call", "after-all"):
                    continue
                if opc in _SLICING:
                    b = 2 * _shape_bytes(op.result_shape)
                elif opc == "dynamic-update-slice":
                    upd = (comp.shapes.get(op.operands[1], "")
                           if len(op.operands) > 1 else "")
                    b = 2 * _shape_bytes(upd) + 8
                elif opc == "fusion":
                    kind = _fusion_kind(op, comps)
                    if kind == "dus":
                        # in-place cache update: true traffic is the
                        # updated slice (r+w), not the whole buffer
                        b = 2 * _dus_update_bytes(op, comps) + 8
                    elif kind == "convert":
                        # pure dtype/layout conversion: exists only
                        # because XLA:CPU lacks native bf16 matmul; on
                        # the TPU target the MXU consumes bf16 directly
                        b = _shape_bytes(op.result_shape)
                        out.convert_bytes_excluded += b * cmult
                        continue
                    else:
                        # operands sliced inside the fusion are only
                        # read at their slice size, not the full buffer
                        b = (_shape_bytes(op.result_shape)
                             + _fusion_operand_bytes(op, comp, comps))
                elif opc in ("copy", "transpose", "convert", "reshape"):
                    # layout/dtype churn: real on CPU, absorbed by
                    # layout assignment / native bf16 on TPU
                    out.convert_bytes_excluded += (
                        2 * _shape_bytes(op.result_shape) * cmult)
                    continue
                else:
                    b = _shape_bytes(op.result_shape)
                    for o in op.operands:
                        if o in comp.shapes:
                            b += _shape_bytes(comp.shapes[o])
                out.bytes_accessed += b * cmult
                out.bytes_by_comp[cname] += b * cmult
    for mm, _ in ((mult, False), (fusion_mult, True)):
        for cname, cmult in mm.items():
            out.comp_mult[cname] += cmult
    return out


def _fusion_kind(op: Op, comps: Dict[str, Computation]) -> str:
    """Classify a fusion op: 'dus' (root dynamic-update-slice), 'convert'
    (only dtype/layout ops inside), or 'compute'."""
    m = re.search(r"calls=%?([\w.\-]+)", op.rest)
    if not m or m.group(1) not in comps:
        return "compute"
    comp = comps[m.group(1)]
    opcodes = {o.opcode for o in comp.ops}
    if "dynamic-update-slice" in opcodes:
        return "dus"
    layout_ops = {"parameter", "constant", "convert", "bitcast", "copy",
                  "transpose", "reshape", "broadcast", "dynamic-slice",
                  "slice"}
    # scalar ops (s32[] index arithmetic for slicing) don't make a fusion
    # "compute": only non-scalar non-layout ops do.
    for o in comp.ops:
        if o.opcode in layout_ops:
            continue
        shapes = _shape_list(o.result_shape)
        if any(dims for _, dims in shapes):
            return "compute"
    return "convert"


def _dus_update_bytes(op: Op, comps: Dict[str, Computation]) -> int:
    m = re.search(r"calls=%?([\w.\-]+)", op.rest)
    if not m or m.group(1) not in comps:
        return _shape_bytes(op.result_shape)
    comp = comps[m.group(1)]
    for o in comp.ops:
        if o.opcode == "dynamic-update-slice" and len(o.operands) > 1:
            upd = comp.shapes.get(o.operands[1], "")
            return _shape_bytes(upd)
    return _shape_bytes(op.result_shape)


def _fusion_operand_bytes(op: Op, comp: Computation,
                          comps: Dict[str, Computation]) -> int:
    """Sum of operand bytes with slice-aware accounting: when the fusion
    body dynamic-slices one of its parameters, only the slice is read."""
    m = re.search(r"calls=%?([\w.\-]+)", op.rest)
    fcomp = comps.get(m.group(1)) if m else None
    sliced: Dict[int, int] = {}
    if fcomp is not None:
        # parameter order inside the fusion comp == operand order
        pnames = []
        for o in fcomp.ops:
            if o.opcode == "parameter":
                idx = re.search(r"parameter\((\d+)\)",
                                "parameter(" + o.rest)
                pnames.append((int(idx.group(1)) if idx else len(pnames),
                               o.name))
        pmap = {name: i for i, name in pnames}
        for o in fcomp.ops:
            if o.opcode in ("dynamic-slice", "slice", "gather") \
                    and o.operands:
                src_name = o.operands[0]
                if src_name in pmap:
                    i = pmap[src_name]
                    sliced[i] = sliced.get(i, 0) + _shape_bytes(
                        o.result_shape)
    total = 0
    for i, oname in enumerate(op.operands):
        if oname not in comp.shapes:
            continue
        full = _shape_bytes(comp.shapes[oname])
        total += min(sliced[i], full) if i in sliced else full
    return total


def _is_promoted_bf16(op: Op, comp: Computation) -> bool:
    """True when every operand of a collective is an f32 convert/copy of
    a bf16 value (XLA:CPU's bf16-collective promotion pattern)."""
    if "f32" not in op.result_shape:
        return False
    ok = False
    for o in op.operands:
        src_op = None
        for cand in comp.ops:
            if cand.name == o:
                src_op = cand
                break
        if src_op is None or src_op.opcode not in ("convert", "fusion",
                                                   "copy", "bitcast"):
            return False
        inner = None
        for oo in src_op.operands:
            if oo in comp.shapes:
                inner = comp.shapes[oo]
                break
        if inner is None or "bf16" not in inner:
            return False
        ok = True
    return ok
