"""Pure-jnp oracles for every Pallas kernel (the references the
per-kernel allclose tests sweep against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, scale=None):
    B, S, H, D = q.shape
    _, T, KVH, Dv = v.shape
    g = H // KVH
    scale = scale or 1.0 / math.sqrt(D)
    kf = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kf) * scale
    if causal:
        mask = jnp.arange(S)[:, None] >= jnp.arange(T)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, vf).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, length):
    B, _, H, D = q.shape
    _, T, KVH, Dv = v_cache.shape
    g = H // KVH
    kf = jnp.repeat(k_cache, g, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v_cache, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kf)
    s = s / math.sqrt(D)
    valid = jnp.arange(T) <= length
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p, vf).astype(q.dtype)


def rmsnorm_ref(x, weight, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * weight


def rmsnorm_residual_ref(x, residual, weight, *, eps: float = 1e-6):
    s = x.astype(jnp.float32) + residual.astype(jnp.float32)
    var = jnp.mean(jnp.square(s), axis=-1, keepdims=True)
    normed = (s * jax.lax.rsqrt(var + eps)) * weight.astype(jnp.float32)
    return normed.astype(x.dtype), s.astype(x.dtype)


def ssd_chunk_ref(x, dt, cum, B, C):
    """Intra-chunk SSD oracle (same shapes as kernels.ssd_chunk)."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    cumf = cum.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    c = x.shape[2]
    diff = cumf[:, :, :, None, :] - cumf[:, :, None, :, :]  # (b,nc,s,t,h)
    causal = jnp.tril(jnp.ones((c, c), bool))
    diff = jnp.where(causal[None, None, :, :, None], diff, -jnp.inf)
    scores = jnp.einsum("bcshn,bcthn->bcsth", Cf, Bf)
    y = jnp.einsum("bcsth,bcth,bcthp->bcshp",
                   scores * jnp.exp(diff), dtf, xf)
    total = cumf[:, :, -1]
    decay_in = jnp.exp(total[:, :, None, :] - cumf) * dtf
    S = jnp.einsum("bcthn,bcth,bcthp->bchpn", Bf, decay_in, xf)
    return y, S


def frp_select_ref(t_e, t_l, t_v, n_w, K, tv_j, self_idx):
    te = jnp.asarray(t_e, jnp.float32)
    tl = jnp.asarray(t_l, jnp.float32)
    tv = jnp.asarray(t_v, jnp.float32)
    nw = jnp.asarray(n_w, jnp.float32)
    k = jnp.asarray(K, jnp.float32)
    n_e = nw + 1.0 - (tl + tv_j) * k / jnp.maximum(te, 1e-9)
    w = te + (tl + tv) * (k + 1.0) / jnp.maximum(n_e, 1e-9)
    idx = jnp.arange(te.shape[0])
    valid = (nw > 0) & (n_e > 0) & (idx != self_idx)
    w = jnp.where(valid, w, 1e30)
    i = jnp.argmin(w)
    return w[i], jnp.where(w[i] >= 1e30, -1, i).astype(jnp.int32)
