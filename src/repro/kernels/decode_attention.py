"""Flash-decode — single-token GQA attention over a KV cache.

One new query position per sequence attends a (B, T, KVH, D) cache.
Grid: (batch, kv_heads, n_kv_blocks); the kv axis is the sequential
reduction carrying online-softmax state for the whole q-head *group*
(G = H/KVH rows) in VMEM scratch, so the q-head group shares one pass
over its kv head's cache — the roofline-optimal decode data movement
(cache read exactly once).

Length masking: positions >= length contribute NEG_INF; the kernel reads
``length`` from SMEM (scalar prefetch).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, block_k: int, group: int):
    kj = pl.program_id(2)
    n_k = pl.num_programs(2)
    length = len_ref[0]

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(kj * block_k <= length)   # skip blocks past the length
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (G, bk)
        pos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (group, block_k), 1)
        s = jnp.where(pos <= length, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, length, *, block_k: int = 512,
                     scale=None, interpret: bool = True):
    """q (B,1,H,D); caches (B,T,KVH,D); length scalar int32.
    Returns (B,1,H,Dv)."""
    B, _, H, D = q.shape
    _, T, KVH, Dv = v_cache.shape
    G = H // KVH
    scale = scale or 1.0 / math.sqrt(D)
    block_k = min(block_k, T)
    pk = (-T) % block_k
    kp = jnp.pad(k_cache, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk \
        else k_cache
    vp = jnp.pad(v_cache, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk \
        else v_cache
    n_k = (T + pk) // block_k
    # (B, KVH, G, D) query groups; caches (B, KVH, T, D)
    qg = q[:, 0].reshape(B, KVH, G, D)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)
    length = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(_decode_kernel, scale=scale,
                               block_k=block_k, group=G)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, j, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, j, *_: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv), lambda b, h, j, *_: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, j, *_: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, Dv), q.dtype),
        interpret=interpret,
    )(length, qg, kp, vp)
    return out.reshape(B, 1, H, Dv)
