"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True in this container (no TPU); on real
hardware set ``REPRO_PALLAS_INTERPRET=0`` (or pass interpret=False) to
run the compiled kernels.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.rmsnorm import rmsnorm_residual as _rmsnorm_res
from repro.kernels.sched_weights import frp_select as _frp
from repro.kernels.ssd_chunk import ssd_chunk_kernel as _ssd


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, block_q=block_q,
                  block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, length, *, block_k: int = 512,
                     interpret: bool = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _decode(q, k_cache, v_cache, length, block_k=block_k,
                   interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, weight, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _rmsnorm(x, weight, eps=eps, block_rows=block_rows,
                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_residual(x, residual, weight, *, eps: float = 1e-6,
                     block_rows: int = 256, interpret: bool = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _rmsnorm_res(x, residual, weight, eps=eps,
                        block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, cum, B, C, *, interpret: bool = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _ssd(x, dt, cum, B, C, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def frp_select(t_e, t_l, t_v, n_w, K, tv_j, self_idx, *,
               block: int = 1024, interpret: bool = None):
    interpret = _interpret_default() if interpret is None else interpret
    return _frp(t_e, t_l, t_v, n_w, K, tv_j, self_idx, block=block,
                interpret=interpret)
