"""Flash attention (prefill/train) — Pallas TPU kernel.

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks); the innermost kv axis is
the sequential reduction dim, carrying the online-softmax state (m, l,
acc) in VMEM scratch. Block shapes are MXU-aligned (q/kv blocks x
head_dim, head_dim padded to 128 multiples by the caller). GQA is
expressed in the k/v BlockSpec index maps (q head h reads kv head
h // group); no H-wide kv materialisation.

Causal blocks above the diagonal are masked via in-kernel predication
(pl.when skips the matmul entirely for fully-masked tiles, so the MXU
work matches exact causal FLOPs).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  seq_q: int, seq_k: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = k_pos < seq_k                           # kv padding
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked tiles: first q row of block qi is qi*bq;
        # last k col of block kj is kj*bk + bk - 1
        pl.when(kj * block_k <= qi * block_q + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(kj == n_k - 1)
    def _final():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 256,
                    block_k: int = 256, scale=None, interpret: bool = True):
    """q (B,S,H,D); k,v (B,T,KVH,D) -> (B,S,H,D). H % KVH == 0."""
    B, S, H, D = q.shape
    _, T, KVH, Dv = v.shape
    G = H // KVH
    scale = scale or 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    pq = (-S) % block_q
    pk = (-T) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))) if pk else v
    # layout: (B, H, S, D) blocks
    qp = qp.transpose(0, 2, 1, 3)
    kp = kp.transpose(0, 2, 1, 3)
    vp = vp.transpose(0, 2, 1, 3)
    n_q = (S + pq) // block_q
    n_k = (T + pk) // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_q=S, seq_k=T)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S + pq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.transpose(0, 2, 1, 3)[:, :S]
