"""Mamba2 SSD intra-chunk kernel — the quadratic block of the state-space
duality decomposition, fused in VMEM.

Per (batch, chunk, head) the kernel computes, without materialising the
(c x c) decay tensor in HBM (the XLA path's dominant memory cost — see
EXPERIMENTS.md §Roofline, mamba2 train cell):

    y_diag = ((C B^T) .* L .* dt) x      L[s,t] = exp(cum[s]-cum[t]), s>=t
    S_c    = (B .* exp(total-cum) .* dt)^T x         (chunk state update)

The inter-chunk recurrence (linear scan over chunk states) stays in JAX —
it is O(L/c) and latency-bound, not a kernel candidate.

Inputs per grid cell: x (c,p), dt/cum (c,1), B/C (c,n). All fp32 math.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, cum_ref, b_ref, c_ref, y_ref, s_ref):
    x = x_ref[0, 0].astype(jnp.float32)          # (c, p)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (c, 1)
    cum = cum_ref[0, 0].astype(jnp.float32)      # (c, 1)
    B = b_ref[0, 0].astype(jnp.float32)          # (c, n)
    C = c_ref[0, 0].astype(jnp.float32)          # (c, n)
    c = x.shape[0]

    scores = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)      # (c, c) = C B^T
    diff = cum - cum.reshape(1, c)               # cum[s] - cum[t]
    s_pos = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
    t_pos = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    diff = jnp.where(s_pos >= t_pos, diff, NEG_INF)
    kernel = scores * jnp.exp(diff) * dt.reshape(1, c)
    y_ref[0, 0] = jax.lax.dot_general(
        kernel, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    total = cum[c - 1]
    decay_in = jnp.exp(total - cum) * dt         # (c, 1)
    s_ref[0, 0] = jax.lax.dot_general(
        B * decay_in, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)  # (n, p)


def ssd_chunk_kernel(x, dt, cum, B, C, *, interpret: bool = True):
    """Intra-chunk SSD.

    x (b, nc, c, h, p); dt, cum (b, nc, c, h); B, C (b, nc, c, h, n)
    (already head-broadcast). Returns (y_diag (b,nc,c,h,p),
    states (b,nc,h,n,p))."""
    b, nc, c, h, p = x.shape
    n = B.shape[-1]
    # layout: grid cell = (b, nc, h)
    xt = x.transpose(0, 1, 3, 2, 4).reshape(b, nc * h, c, p)
    dtt = dt.transpose(0, 1, 3, 2).reshape(b, nc * h, c, 1)
    cumt = cum.transpose(0, 1, 3, 2).reshape(b, nc * h, c, 1)
    Bt = B.transpose(0, 1, 3, 2, 4).reshape(b, nc * h, c, n)
    Ct = C.transpose(0, 1, 3, 2, 4).reshape(b, nc * h, c, n)

    y, s = pl.pallas_call(
        _ssd_kernel,
        grid=(b, nc * h),
        in_specs=[
            pl.BlockSpec((1, 1, c, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, c, n), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, p), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc * h, c, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc * h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(xt, dtt, cumt, Bt, Ct)
    y = y.reshape(b, nc, h, c, p).transpose(0, 1, 3, 2, 4)
    s = s.reshape(b, nc, h, n, p).transpose(0, 1, 2, 4, 3)  # (b,nc,h,p,n)
    return y, s
