"""ESFF FRP candidate selection — the control-plane hot loop as a kernel.

At every request completion, FRP (paper Alg. 3) scans all functions with
waiting requests, computes the drain estimate n^e_{j',j} (Eq. 7) and the
candidate weight w_{j'} (Eq. 10), and takes the argmin. At Azure fleet
scale (~70k functions) and edge event rates this scan dominates the
scheduler's cycle budget; the kernel fuses the weight computation with a
blocked argmin reduction (running (min, argmin) carried in VMEM scratch
across function blocks).

Inputs (F-vectors): t_e (running-mean exec), t_l (cold), t_v (evict),
n_w (queue lengths), K (instance counts); scalars: t_v_j of the finishing
instance, current weight w_j. Output: (best weight, best index); callers
replace iff best weight < w_j (index -1 when none qualifies).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BIG = 1e30


def _weights_kernel(scalars_ref, te_ref, tl_ref, tv_ref, nw_ref, k_ref,
                    best_w_ref, best_i_ref, minw_ref, mini_ref, *,
                    block: int, n_fns: int):
    j = pl.program_id(0)
    n_b = pl.num_programs(0)
    tv_j = scalars_ref[0]      # eviction time of the finishing instance
    self_idx = scalars_ref[1].astype(jnp.int32)

    @pl.when(j == 0)
    def _init():
        minw_ref[...] = jnp.full_like(minw_ref, BIG)
        mini_ref[...] = jnp.full_like(mini_ref, -1)

    te = te_ref[...].astype(jnp.float32)
    tl = tl_ref[...].astype(jnp.float32)
    tv = tv_ref[...].astype(jnp.float32)
    nw = nw_ref[...].astype(jnp.float32)
    K = k_ref[...].astype(jnp.float32)

    # Eq. (7): n_e = n_w + 1 - (t_l_{j'} + t_v_j) * K_{j'} / t_e_{j'}
    n_e = nw + 1.0 - (tl + tv_j) * K / jnp.maximum(te, 1e-9)
    # Eq. (10): w = t_e + (t_l + t_v) * (K + 1) / n_e
    w = te + (tl + tv) * (K + 1.0) / jnp.maximum(n_e, 1e-9)
    idx = j * block + jax.lax.broadcasted_iota(jnp.int32, w.shape, 1)
    valid = (nw > 0) & (n_e > 0) & (idx < n_fns) & (idx != self_idx)
    w = jnp.where(valid, w, BIG)

    bw = w.min(-1, keepdims=True)
    bi = idx[0, jnp.argmin(w[0])].reshape(1, 1)

    better = bw < minw_ref[...]
    mini_ref[...] = jnp.where(better, bi, mini_ref[...])
    minw_ref[...] = jnp.where(better, bw, minw_ref[...])

    @pl.when(j == n_b - 1)
    def _final():
        best_w_ref[...] = minw_ref[...]
        best_i_ref[...] = mini_ref[...]


def frp_select(t_e, t_l, t_v, n_w, K, tv_j, self_idx, *,
               block: int = 1024, interpret: bool = True):
    """Blocked FRP candidate selection. All inputs (F,) vectors.
    Returns (best_weight (), best_index ()) — index -1 if none."""
    F = t_e.shape[0]
    block = min(block, max(F, 8))
    pad = (-F) % block
    pad_to = F + pad

    def prep(x, dtype=jnp.float32):
        x = jnp.asarray(x, dtype)
        return jnp.pad(x, (0, pad))[None, :]      # (1, F+pad)

    scalars = jnp.stack([jnp.asarray(tv_j, jnp.float32),
                         jnp.asarray(self_idx, jnp.float32)]).reshape(2)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(pad_to // block,),
        in_specs=[pl.BlockSpec((1, block), lambda j, *_: (0, j))] * 5,
        out_specs=[pl.BlockSpec((1, 1), lambda j, *_: (0, 0)),
                   pl.BlockSpec((1, 1), lambda j, *_: (0, 0))],
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32),
                        pltpu.VMEM((1, 1), jnp.int32)],
    )
    kernel = functools.partial(_weights_kernel, block=block, n_fns=F)
    bw, bi = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((1, 1), jnp.float32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)],
        interpret=interpret,
    )(scalars, prep(t_e), prep(t_l), prep(t_v), prep(n_w), prep(K))
    return bw[0, 0], bi[0, 0]
