"""Fused RMSNorm (+ optional residual add) — Pallas TPU kernel.

One pass: read x (and residual), accumulate sum-of-squares in fp32,
normalise, scale — vs the XLA path's separate square/mean/rsqrt/mul
buffers. Grid over row tiles; the feature dim stays whole in VMEM
(d_model <= 8192 -> <= 32 KB/row tile, well inside VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def _rmsnorm_residual_kernel(x_ref, r_ref, w_ref, o_ref, res_ref, *,
                             eps: float):
    s = (x_ref[...].astype(jnp.float32)
         + r_ref[...].astype(jnp.float32))
    res_ref[...] = s.astype(res_ref.dtype)
    var = jnp.mean(jnp.square(s), axis=-1, keepdims=True)
    y = s * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, weight, *, eps: float = 1e-6, block_rows: int = 256,
            interpret: bool = True):
    """x (..., D), weight (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pr = (-R) % block_rows
    if pr:
        xf = jnp.pad(xf, ((0, pr), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((R + pr) // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R + pr, D), x.dtype),
        interpret=interpret,
    )(xf, weight)
    return out[:R].reshape(orig_shape)


def rmsnorm_residual(x, residual, weight, *, eps: float = 1e-6,
                     block_rows: int = 256, interpret: bool = True):
    """Fused (x + residual) -> RMSNorm. Returns (normed, new_residual)."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)
    rf = residual.reshape(-1, D)
    R = xf.shape[0]
    block_rows = min(block_rows, R)
    pr = (-R) % block_rows
    if pr:
        xf = jnp.pad(xf, ((0, pr), (0, 0)))
        rf = jnp.pad(rf, ((0, pr), (0, 0)))
    normed, res = pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=((R + pr) // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R + pr, D), x.dtype),
            jax.ShapeDtypeStruct((R + pr, D), x.dtype),
        ],
        interpret=interpret,
    )(xf, rf, weight)
    return (normed[:R].reshape(orig_shape), res[:R].reshape(orig_shape))
