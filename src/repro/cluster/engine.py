"""Dynamic-routing cluster engine: K nodes, one vectorised event loop.

Static routers can pre-partition the arrival stream and reuse the
single-node engine per node (`repro.cluster.static`); a *dynamic*
router (JSQ(d), cold-aware) reads live cluster state at every arrival,
so the routing decision has to live inside the event loop. This module
generalises `repro.core.jax_engine._simulate` to K co-simulated nodes
per lane:

* **slots** become a (L, K, C) node-major rail — the packed next-event
  argmin runs over the flattened (L, 2·K·C + 1) candidate matrix, so
  the same-time class order (EXEC < COLD < ARRIVAL) and the
  within-class index tie-break extend the single-node engine's exactly
  (node-major slot order);
* **queues** become per-(node, function) FIFOs. The single-node
  engine's positional cursors assume a function's queue is a contiguous
  range of its precomputed arrival order — runtime routing breaks that
  invariant (which arrivals of f_j reach node k is state-dependent) —
  so the cluster carries an (L, N) linked-list rail ``nxt`` plus
  (L, K, F) head/tail/length cursors. ``nxt`` is both gathered and
  scattered per event, the pattern the single-node engine's rule 3
  avoids; the resulting per-event copy is O(N) and is the documented
  cost of the dynamic tier (fine at the 10^4–10^5-request traces
  cluster studies run; the static tier keeps the O(F+C) carry).
* **estimators** become node-local ((L, K, F) running sums plus
  (L, K) node-global fallbacks): each node's scheduler learns only
  from its own completions, exactly as K independent servers would.

Policy kernels run *unmodified*: per event the lane state is sliced
into a single-node **view** of the event's node (slot/queue/estimator
rows; lane-global ci/cf/metric keys pass through) and the kernel's
hooks operate on that view through a `ClusterNodeCtx`, which overrides
the ctx-dispatched queue ops (`EngineCtx.q_push`/`q_pop`/…) with the
linked-list discipline and `est_means` with the node-local fallback
chain. Timer-rail policies (``openwhisk_v2``) ride arrival-order
positions that routing also breaks — they are rejected here and
supported on the static path only.

With ``n_nodes=1`` the loop degenerates to the single-node engine —
same candidate order, same helper arithmetic, same fold — and is
bitwise identical to it (gated in ``benchmarks/run.py --smoke`` and
tests/test_cluster.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.jax_engine import (BIG, BUSY, CI_DONE, CI_ITERS,
                                   CI_NEXT, CI_OVF, CI_STALL, COLD,
                                   HIST_BINS, I32_MAX, IDLE, NCF, NCI,
                                   SEG, EngineCtx, _fold_event, _gidx,
                                   ensure_x64, hist_quantile)
from repro.cluster.routers import ClusterView

ensure_x64()

# state keys sliced to the event's node before kernel hooks run (the
# kernel's extra_state keys are appended per call)
_NODAL = ("slot_fn", "slot_state", "slot_ready", "slot_req",
          "slot_used", "slot_seq", "q_len", "q_head_rid", "q_tail_rid",
          "est_sum", "est_n", "node_gn", "node_gsum")


class ClusterNodeCtx(EngineCtx):
    """Single-node view ctx over one node of a cluster lane.

    Reads go straight to the full trace operands (the cluster loop is
    single-window); the ctx-dispatched queue ops are the linked-list
    discipline over the ``nxt`` rail, and the estimator fallback chain
    uses the node-local globals instead of the lane counters.
    """

    def __init__(self, *, fn_id2, arrival2, exec2, cold2, evict2, tix,
                 cap_mask, beta, prior, threshold, k, n, f, c, q,
                 stream, tl_bins, tl_bucket):
        super().__init__(
            fn_id2=fn_id2, arrival2=arrival2, exec2=exec2, cold2=cold2,
            evict2=evict2, pos_rids2=None, pos_off2=None,
            slabs=(None,) * 7, win_base=0, win_w=n, tix=tix,
            cap_mask=cap_mask, beta=beta, prior=prior,
            threshold=threshold, k=k, n=n, f=f, c=c, q=q, stream=stream,
            tl_bins=tl_bins, tl_bucket=tl_bucket)

    # ------------------------------------------------ estimator override
    def est_means(self, s):
        counts = s["est_n"].astype(jnp.float64)
        gn = s["node_gn"]
        g = jnp.where(gn > 0,
                      s["node_gsum"]
                      / jnp.maximum(gn.astype(jnp.float64), 1),
                      self.prior)
        return jnp.where(s["est_n"] > 0,
                         s["est_sum"] / jnp.maximum(counts, 1), g)

    # ------------------------------------------- linked-list queue ops
    # (q_head is inherited: the head cache works the same way)
    def q_push(self, s, fn, rid, on):
        fc = jnp.clip(fn, 0, self.F - 1)
        was_empty = s["q_len"][fc] == 0
        full = s["q_len"][fc] >= self.Q
        do = on & ~full
        rid32 = jnp.asarray(rid, jnp.int32)
        tail = s["q_tail_rid"][fc]
        s = dict(s)
        s["q_head_rid"] = s["q_head_rid"].at[
            _gidx(do & was_empty, fn, self.F)].set(rid32, mode="drop")
        s["nxt"] = s["nxt"].at[
            _gidx(do & ~was_empty, tail, self.N)].set(rid32,
                                                      mode="drop")
        s["q_tail_rid"] = s["q_tail_rid"].at[
            _gidx(do, fn, self.F)].set(rid32, mode="drop")
        s["q_len"] = s["q_len"].at[_gidx(do, fn, self.F)].add(
            1, mode="drop")
        s["ci"] = s["ci"].at[CI_OVF].add((on & full).astype(jnp.int32))
        return s, do

    def q_consume_direct(self, s, fn, on):
        # no positional cursor to advance: a directly dispatched
        # arrival simply never enters the linked list
        return s

    def q_pop(self, s, fn, on):
        fc = jnp.clip(fn, 0, self.F - 1)
        rid = s["q_head_rid"][fc]
        succ = s["nxt"][jnp.clip(rid, 0, self.N - 1)]
        fi = _gidx(on, fn, self.F)
        s = dict(s)
        s["q_head_rid"] = s["q_head_rid"].at[fi].set(succ, mode="drop")
        s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
        return s, rid


# ------------------------------------------------------------ event loop
@functools.partial(jax.jit,
                   static_argnames=("kernel", "router", "n_nodes",
                                    "n_fns", "capacity", "queue_cap",
                                    "seed", "stream", "tl_bins"))
def _simulate_cluster(fn_id, arrival, exec_time, t_cold, t_evict,
                      trace_ix, cap_mask, beta, prior, threshold, *,
                      kernel, router, n_nodes, n_fns, capacity,
                      queue_cap, seed=0, stream=False, tl_bins=0,
                      tl_bucket=60.0):
    """K-node lane-batched cluster loop (see the module docstring).

    ``cap_mask`` is (L, K, C) — heterogeneous node capacities are
    per-node slot masks over the common C = max slots. Returns the
    single-node engine's output dict plus ``node_done`` (L, K), the
    per-node completion counts (the router balance diagnostic, and the
    conservation check: rows sum to N).
    """
    if kernel.has_timers:
        raise ValueError(
            f"dynamic cluster routing does not support timer-rail "
            f"policies ({kernel.name!r}); use a static router for "
            "them (docs/cluster.md)")
    L = trace_ix.shape[0]
    N = fn_id.shape[1]
    F, C, K, Q = n_fns, capacity, n_nodes, queue_cap
    KC = K * C

    fn_id = fn_id.astype(jnp.int32)
    arrival = arrival.astype(jnp.float64)
    exec_time = exec_time.astype(jnp.float64)
    t_cold = t_cold.astype(jnp.float64)
    t_evict = t_evict.astype(jnp.float64)
    trace_ix = trace_ix.astype(jnp.int32)
    prior = jnp.float64(prior)
    threshold = jnp.float64(threshold)
    tl_bucket = jnp.float64(tl_bucket)

    s = dict(
        slot_fn=jnp.full((L, K, C), -1, jnp.int32),
        slot_state=jnp.full((L, K, C), IDLE, jnp.int32),
        slot_ready=jnp.full((L, K, C), BIG, jnp.float64),
        slot_req=jnp.full((L, K, C), -1, jnp.int32),
        slot_used=jnp.zeros((L, K, C), jnp.float64),
        slot_seq=jnp.full((L, K, C), I32_MAX, jnp.int32),
        q_len=jnp.zeros((L, K, F), jnp.int32),
        q_head_rid=jnp.full((L, K, F), -1, jnp.int32),
        q_tail_rid=jnp.full((L, K, F), -1, jnp.int32),
        nxt=jnp.full((L, N), -1, jnp.int32),
        est_sum=jnp.zeros((L, K, F), jnp.float64),
        est_n=jnp.zeros((L, K, F), jnp.int32),
        node_gn=jnp.zeros((L, K), jnp.int32),
        node_gsum=jnp.zeros((L, K), jnp.float64),
        node_done=jnp.zeros((L, K), jnp.int32),
        ci=jnp.zeros((L, NCI), jnp.int32),
        cf=jnp.zeros((L, NCF), jnp.float64),
        hist=jnp.zeros((L, HIST_BINS), jnp.int32),
    )
    if not stream:
        s["d_rid"] = jnp.full((L, SEG), N, jnp.int32)
        s["d_start"] = jnp.zeros((L, SEG), jnp.float64)
        s["d_comp"] = jnp.zeros((L, SEG), jnp.float64)
        s["start"] = jnp.full((L, N), -1.0, jnp.float64)
        s["completion"] = jnp.full((L, N), -1.0, jnp.float64)
    if tl_bins:
        s["tl_cnt"] = jnp.zeros((L, tl_bins), jnp.int32)
        s["tl_resp"] = jnp.zeros((L, tl_bins), jnp.float64)
        s["tl_exec"] = jnp.zeros((L, tl_bins), jnp.float64)
    extra = kernel.extra_state(L, C, F)
    nodal = _NODAL + tuple(extra)
    for kk, v in extra.items():
        # one copy of the kernel's per-server state per node
        s[kk] = jnp.repeat(v[:, None, ...], K, axis=1)

    max_iters = 256 * N + 4096
    n_cand = 2 * KC + 1
    lanes = jnp.arange(L, dtype=jnp.int32)
    lane_iota = lanes[:, None]
    t_cold_l = t_cold[trace_ix]
    t_evict_l = t_evict[trace_ix]
    # flattened-view reads with per-lane bases: (T, N) two-dim gathers
    # only hit the fast XLA:CPU path at T == 1 (see EngineCtx)
    arr_flat = arrival.reshape(-1)
    fn_flat = fn_id.reshape(-1)
    base_n = trace_ix * N

    def node_view(s, k):
        v = dict(s)
        for key in nodal:
            v[key] = lax.dynamic_index_in_dim(s[key], k, 0, False)
        return v

    def node_commit(s, v, k):
        out = dict(v)
        for key in nodal:
            out[key] = s[key].at[k].set(v[key])
        return out

    def make_ctx(tix, cold_l, evict_l, capm_node, beta, k_step):
        return ClusterNodeCtx(
            fn_id2=fn_id, arrival2=arrival, exec2=exec_time,
            cold2=cold_l, evict2=evict_l, tix=tix, cap_mask=capm_node,
            beta=beta, prior=prior, threshold=threshold, k=k_step,
            n=N, f=F, c=C, q=Q, stream=stream, tl_bins=tl_bins,
            tl_bucket=tl_bucket)

    def pick_events(s):
        na = s["ci"][:, CI_NEXT]
        r = jnp.minimum(na, N - 1)
        t_arr = jnp.where(na < N, arr_flat[base_n + r], BIG)
        ready = jnp.where(cap_mask, s["slot_ready"], BIG
                          ).reshape(L, KC)
        st = s["slot_state"].reshape(L, KC)
        cand = jnp.concatenate(
            [jnp.where(st == BUSY, ready, BIG),
             jnp.where(st == COLD, ready, BIG),
             t_arr[:, None]], axis=1)
        ei = jnp.argmin(cand, axis=1).astype(jnp.int32)
        t_ev = jnp.take_along_axis(cand, ei[:, None], axis=1)[:, 0]
        return ei, t_ev, t_arr

    def lane_step(k_step, s, tix, cold_l, evict_l, capm, beta, ei,
                  t_ev, t_arr):
        ci = s["ci"]
        active = (ci[CI_DONE] < N) & (ci[CI_STALL] == 0)
        na = ci[CI_NEXT]
        live = active & (t_ev < BIG)
        s = dict(s)
        s["ev_rid"] = jnp.int32(-1)
        s["ev_comp"] = jnp.float64(0.0)
        s["ev_exec"] = jnp.float64(0.0)
        ev_slot = live & (ei < 2 * KC)
        is_cold = ei >= KC
        sflat = jnp.clip(jnp.where(is_cold, ei - KC, ei), 0, KC - 1)
        node_s = sflat // C
        slot = sflat % C
        ev_arr = live & (ei == n_cand - 1)

        # ------------------------------------------------- slot event
        cold_on = ev_slot & is_cold
        exec_on = ev_slot & ~is_cold
        v = node_view(s, node_s)
        ctx_s = make_ctx(tix, cold_l, evict_l, capm[node_s], beta,
                         k_step)
        rid_done = v["slot_req"][slot]
        j_done = v["slot_fn"][slot]
        e_done = ctx_s.exec_at(rid_done)
        si = _gidx(ev_slot, slot, C)
        ji = _gidx(exec_on, j_done, F)
        exec_i = exec_on.astype(jnp.int32)
        v = dict(v)
        v["slot_state"] = v["slot_state"].at[si].set(IDLE, mode="drop")
        v["slot_ready"] = v["slot_ready"].at[si].set(BIG, mode="drop")
        v["slot_req"] = v["slot_req"].at[si].set(-1, mode="drop")
        # the node's estimator sees the completion before its policy
        # reacts, exactly like the single-node engine
        v["est_sum"] = v["est_sum"].at[ji].add(e_done, mode="drop")
        v["est_n"] = v["est_n"].at[ji].add(1, mode="drop")
        v["node_gsum"] = v["node_gsum"] + jnp.where(exec_on, e_done,
                                                    0.0)
        v["node_gn"] = v["node_gn"] + exec_i
        v["ci"] = v["ci"].at[CI_DONE].add(exec_i)
        v = kernel.on_cold_done(ctx_s, v, slot, t_ev, cold_on)
        v = kernel.on_exec_done(ctx_s, v, slot, rid_done, t_ev,
                                exec_on)
        s = node_commit(s, v, node_s)
        s["node_done"] = s["node_done"].at[
            _gidx(exec_on, node_s, K)].add(1, mode="drop")

        # ---------------------------------------------------- arrival
        rid_a = jnp.minimum(na, N - 1)
        j_a = fn_flat[tix * N + rid_a]
        g = ClusterView(q_len=s["q_len"], slot_fn=s["slot_fn"],
                        slot_state=s["slot_state"], cap_mask=capm,
                        est_sum=s["est_sum"], est_n=s["est_n"],
                        node_gn=s["node_gn"], node_gsum=s["node_gsum"],
                        t_cold=cold_l, prior=prior, n_nodes=K,
                        seed=seed)
        k_route = jnp.clip(router.pick(g, j_a, rid_a, t_arr), 0, K - 1)
        v = node_view(s, k_route)
        ctx_a = make_ctx(tix, cold_l, evict_l, capm[k_route], beta,
                         k_step)
        progress = ev_slot | ev_arr
        v = dict(v)
        v["ci"] = v["ci"].at[jnp.array([CI_NEXT, CI_ITERS])].add(
            jnp.stack([ev_arr.astype(jnp.int32),
                       progress.astype(jnp.int32)]))
        v = kernel.on_arrival(ctx_a, v, rid_a, t_arr, ev_arr)
        s = node_commit(s, v, k_route)

        s = _fold_event(ctx_a, s)
        s = dict(s)
        stall = jnp.where(
            active & ~live, 1,
            jnp.where(active & (s["ci"][CI_ITERS] >= max_iters), 2,
                      s["ci"][CI_STALL]))
        s["ci"] = s["ci"].at[CI_STALL].set(stall)
        return s

    step_lanes = jax.vmap(
        lane_step, in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0))

    def cond(s):
        ci = s["ci"]
        return jnp.any((ci[:, CI_DONE] < N) & (ci[:, CI_STALL] == 0))

    def segment(s):
        if not stream:
            s = dict(s)
            s["d_rid"] = jnp.full((L, SEG), N, jnp.int32)

        def step(k_step, s):
            ei, t_ev, t_arr = pick_events(s)
            return step_lanes(k_step, s, trace_ix, t_cold_l,
                              t_evict_l, cap_mask, beta, ei, t_ev,
                              t_arr)

        s = lax.fori_loop(0, SEG, step, s)
        if not stream:
            s = dict(s)
            s["start"] = s["start"].at[lane_iota, s["d_rid"]].set(
                s["d_start"], mode="drop")
            s["completion"] = s["completion"].at[
                lane_iota, s["d_rid"]].set(s["d_comp"], mode="drop")
        return s

    final = lax.while_loop(cond, segment, s)
    ci, cf = final["ci"], final["cf"]
    from repro.core.jax_engine import (CF_COLDT, CF_EVICTT, CF_RMAX,
                                       CF_RSUM, CF_SSUM, CI_COLD,
                                       CI_EVICT)
    out = dict(cold_starts=ci[:, CI_COLD], cold_time=cf[:, CF_COLDT],
               evictions=ci[:, CI_EVICT], evict_time=cf[:, CF_EVICTT],
               overflow=ci[:, CI_OVF],
               stalled=ci[:, CI_STALL], n_events=ci[:, CI_ITERS],
               done=ci[:, CI_DONE], node_done=final["node_done"],
               resp_sum=cf[:, CF_RSUM], slow_sum=cf[:, CF_SSUM],
               max_response=cf[:, CF_RMAX], resp_hist=final["hist"])
    if tl_bins:
        out["tl_count"] = final["tl_cnt"]
        out["tl_resp_sum"] = final["tl_resp"]
        out["tl_exec_sum"] = final["tl_exec"]
    if not stream:
        out["start"] = final["start"]
        out["completion"] = final["completion"]
    return out


@functools.partial(jax.jit,
                   static_argnames=("kernel", "router", "n_nodes",
                                    "n_fns", "capacity", "queue_cap",
                                    "seed", "stream", "tl_bins",
                                    "keep_responses"))
def _cluster_metrics(fn, arr, ex, cold, ev, tix, masks, betas, prior,
                     threshold, *, kernel, router, n_nodes, n_fns,
                     capacity, queue_cap, seed=0, stream=True,
                     tl_bins=0, tl_bucket=60.0, keep_responses=False):
    """Cluster counterpart of `jax_engine._sweep_metrics`: lane-batched
    dynamic-router run + on-device metric reduction (same metric
    names, plus ``node_done``)."""
    if keep_responses and stream:
        raise ValueError("keep_responses requires stream=False")
    out = _simulate_cluster(fn, arr, ex, cold, ev, tix, masks, betas,
                            prior, threshold, kernel=kernel,
                            router=router, n_nodes=n_nodes,
                            n_fns=n_fns, capacity=capacity,
                            queue_cap=queue_cap, seed=seed,
                            stream=stream, tl_bins=tl_bins,
                            tl_bucket=tl_bucket)
    N = fn.shape[1]
    if stream:
        p99 = hist_quantile(out["resp_hist"], 0.99, N,
                            out["max_response"])
    else:
        resp = out["completion"] - arr[tix]
        p99 = jnp.percentile(resp, 99.0, axis=1)
    res = dict(mean_response=out["resp_sum"] / N,
               mean_slowdown=out["slow_sum"] / N,
               resp_sum=out["resp_sum"],
               slow_sum=out["slow_sum"],
               done=out["done"],
               node_done=out["node_done"],
               p99_response=p99,
               max_response=out["max_response"],
               resp_hist=out["resp_hist"],
               cold_starts=out["cold_starts"],
               cold_time=out["cold_time"],
               evictions=out["evictions"],
               overflow=out["overflow"],
               stalled=out["stalled"])
    if tl_bins:
        res["tl_count"] = out["tl_count"]
        res["tl_resp_sum"] = out["tl_resp_sum"]
        res["tl_exec_sum"] = out["tl_exec_sum"]
    if keep_responses:
        res["response"] = resp
    return res
