"""Dynamic-routing cluster engine: K nodes, one vectorised event loop.

Static routers can pre-partition the arrival stream and reuse the
single-node engine per node (`repro.cluster.static`); a *dynamic*
router (JSQ(d), cold-aware) reads live cluster state at every arrival,
so the routing decision has to live inside the event loop. This module
generalises `repro.core.jax_engine._simulate` to K co-simulated nodes
per lane:

* **slots** become a (L, K, C) node-major rail — the packed next-event
  argmin runs over the flattened (L, 2·K·C + …) candidate matrix, so
  the same-time class order (EXEC < COLD < TIMER < NODE_ARRIVAL <
  ARRIVAL) and the within-class index tie-break extend the single-node
  engine's exactly (node-major order within each class);
* **queues** become per-(node, function) FIFOs carried as a
  *segment-overlay link rail*: runtime routing breaks the single-node
  engine's positional-cursor invariant (which arrivals of f_j reach
  node k is state-dependent), so successor links live in an (L, N) i32
  rail ``nxt`` — but per event only a per-lane (pos, val) register is
  written, staged into an (L, SEG) overlay slot, and the rail itself is
  batch-scattered **once per segment**. Link *reads* (queue pops) are
  lazy: the popped head's successor is chased in-body (overlay match
  first, single-element rail gather second — each link position is
  written at most once ever, so a stale overlay entry can only repeat
  the flushed rail value) and lands in the parked head register. All
  queue-cursor writes (``q_len``/``q_head_rid``/``q_tail_rid``) park
  in per-lane (pos, val/delta) registers and are applied as
  single-element scatters at the **top of the next step**, before
  anything reads those arrays — write-first carry, which keeps the
  (L, K, F) cursor buffers copy-free under XLA's in-place analysis
  (read-early/write-late keeps a buffer live across the body and
  costs two full copies per event per array). Carried-copy cost is
  one (L, N) scatter per SEG events — O(F + C + SEG)-amortised per
  event, the single-node streaming-carry regime, instead of the
  O(N)-per-event gather+scatter of the earlier linked-list spelling;
* **timer rails** (``openwhisk_v2``) ride a second link chain ``tnx``
  over *node arrivals*: per (node, fn) the engine carries the chain
  tail, an arrival counter and a consumed counter, so the rid-chain
  reproduces the single-node positional timer rail event-for-event
  (arm at the node-local arrival, fire in arrival order, silent
  consume on direct dispatch, no-op fires gated by the queue-head
  check) without any arrival-order precomputation;
* **per-node net_delay** becomes a third chain ``dnx``: the router
  decides at the raw ARRIVAL time, the request is appended to its
  node's in-flight FIFO and surfaces as a deferred NODE_ARRIVAL
  candidate ``delay_k`` later — the node's policy, timers and response
  accounting all run on the node-local clock (response is measured
  from the delayed arrival, matching the static tier's convention);
* **estimators** are node-local ((L, K, F) running sums plus (L, K)
  node-global fallbacks): each node's scheduler learns only from its
  own completions, exactly as K independent servers would;
* **churn** (PR 7) adds a NODE_DOWN/NODE_UP event class on a per-node
  toggle-time operand ``churn_t`` with a carried cursor ``ch_ix``
  (even parity = up). NODE_DOWN drains the dying node — busy-slot
  requests sorted by rid, then the per-fn queues fn-major — onto a
  per-lane *park FIFO* (an O(1) chain splice on the ``nxt`` rail);
  one REROUTE/orphan candidate re-injects the park head through the
  router per event. Routers never see a down node (`ClusterView.up`
  mask + a lowest-up-id correction); when every node is down the park
  queue simply holds (its candidate gates on ``any_up``) until the
  next NODE_UP re-arms it. Cold state dies with the node, requests
  never do — conservation is exact and parity-tested. Because a
  drained rid re-enters some queue later, the write-once link
  invariant behind the segment overlays no longer holds, so under the
  static ``has_churn`` flag the engine switches to direct per-event
  rail writes (and commits the queue-cursor rows like any other nodal
  array); the no-churn path compiles to the exact PR-6 program. The
  metric fold also moves from dispatch time to EXEC_DONE (a drained
  request's dispatch record must not count) and responses are
  measured from the *raw* arrival — the user-perceived, SLO-honest
  convention; no-churn paths keep their node-local convention
  bit-for-bit. Time-varying per-node delay (``var_delay`` +
  `DelaySchedule` operands) rides the same deferred-arrival rail with
  the landing time sampled at send time.

Policy kernels run *unmodified*: per event the lane state is sliced
into a single-node **view** of the event's node — one view/commit pair
per event, shared by the slot, timer and arrival phases (the phases
are mutually exclusive by construction, and the router runs first,
before any enabled write) — and the kernel's hooks operate on that
view through a `ClusterNodeCtx`, which overrides the ctx-dispatched
queue/timer ops with the overlay-rail discipline and `est_means` with
the node-local fallback chain. The view slice and the row commit are
*lane-stacked*, outside the vmapped event body: a vmapped
dynamic-index over (L, K, F) carries is a batched-operand gather —
the generic XLA:CPU path, O(K·F) per event — while one
`take_along_axis` / row scatter per nodal array stays on the fast
path, so per-event cost is O(F + C), independent of K.

With ``n_nodes=1`` and zero delay the loop degenerates to the
single-node engine — same candidate order, same helper arithmetic,
same fold — and is bitwise identical to it for every kernel,
timer-rail policies included (gated in ``benchmarks/run.py --smoke``
and tests/test_cluster.py). The delay and timer machinery is gated
*statically*, so the zero-delay/no-timer arithmetic contains no
spurious ``+0.0`` or extra candidates. The static ``seg`` knob shrinks
the segment length (default `SEG`) so tests can prove the overlay rail
is bitwise invariant to where segment boundaries fall.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.jax_engine import (BIG, BUSY, CI_DONE, CI_EXH,
                                   CI_FAILED, CI_ITERS, CI_NEXT,
                                   CI_OVF, CI_RETRY, CI_SHED, CI_STALL,
                                   CI_TERM, CI_TMO, CI_TRIPS, COLD,
                                   HIST_BINS, I32_MAX, IDLE, NCF, NCI,
                                   SEG, EngineCtx, _fold_event, _gidx,
                                   ensure_x64, hist_quantile)
from repro.core.resilience import backoff_jax
from repro.cluster.routers import BreakerRouter, ClusterView

ensure_x64()

# state keys sliced to the event's node before kernel hooks run (the
# timer-rail keys and the kernel's extra_state keys are appended per
# call)
_NODAL = ("slot_fn", "slot_state", "slot_ready", "slot_req",
          "slot_used", "slot_seq", "q_len", "q_head_rid", "q_tail_rid",
          "q_tot", "est_sum", "est_n", "node_gn", "node_gsum")
_NODAL_TMR = ("arr_cnt", "tmr_seq", "tmr_rid", "tmr_next", "rearm_t",
              "rearm_rid", "la_rid")
_NODAL_PEND = ("pend_head", "pend_tail", "pend_len")


def _sched_delay(t, dt, dv, dp):
    """Piecewise-constant `DelaySchedule` lookup, elementwise over
    ``t``: value of the last step at or before ``t`` (mod ``dp`` when
    periodic). ``dt``/``dv`` are the BIG-padded step times / values
    with shape ``t.shape + (D,)``; ``dp`` has ``t.shape`` (0 = not
    periodic). ``dt[..., 0] == 0`` (spec-validated), so the index is
    always in range. Every call site — candidate times, router
    ``delay_now``, landing times, the response convention — funnels
    through this one function, so the same (t, node) pair can never
    produce two different floats."""
    per = jnp.where(dp > 0, dp, 1.0)
    tt = jnp.where(dp > 0, jnp.mod(t, per), t)
    ix = jnp.clip(jnp.sum(tt[..., None] >= dt, axis=-1) - 1,
                  0, dt.shape[-1] - 1)
    return jnp.take_along_axis(dv, ix[..., None], axis=-1)[..., 0]


class ClusterNodeCtx(EngineCtx):
    """Single-node view ctx over one node of a cluster lane.

    Reads go straight to the full trace operands (the cluster loop is
    single-window); the ctx-dispatched queue/timer ops implement the
    segment-overlay link-rail discipline — writes park per-event
    registers (``lw_*`` link writes, ``qw_*`` queue-cursor writes,
    ``pp_*``/``tp_*`` deferred reads) that the engine stages into the
    overlay, resolves via the in-body chase pass, and applies
    write-first at the top of the next step — and the estimator
    fallback chain uses the
    node-local globals instead of the lane counters. ``delay`` (only
    under ``has_delay``) shifts `arrival_at` to the node-local clock so
    the response fold measures from the delayed arrival.
    """

    def __init__(self, *, fn_id2, arrival2, exec2, cold2, evict2, tix,
                 cap_mask, beta, prior, threshold, k, n, f, c, q,
                 stream, tl_bins, tl_bucket, node, delay=None,
                 delay_sched=None, deadlines=None, direct_links=False,
                 seg_n=SEG):
        super().__init__(
            fn_id2=fn_id2, arrival2=arrival2, exec2=exec2, cold2=cold2,
            evict2=evict2, pos_rids2=None, pos_off2=None,
            slabs=(None,) * 7, win_base=0, win_w=n, tix=tix,
            cap_mask=cap_mask, beta=beta, prior=prior,
            threshold=threshold, k=k, n=n, f=f, c=c, q=q, stream=stream,
            tl_bins=tl_bins, tl_bucket=tl_bucket, deadlines=deadlines)
        self._node = jnp.asarray(node, jnp.int32)
        self._delay = delay
        self._dsched = delay_sched  # (dt_row, dv_row, dp) of the node
        self._direct = direct_links  # churn: rail writes, no overlays
        self.seg_n = seg_n

    def arrival_at(self, rid):
        a = super().arrival_at(rid)
        if self._delay is not None:
            return a + self._delay
        if self._dsched is not None:
            dt, dv, dp = self._dsched
            return a + _sched_delay(a, dt, dv, dp)
        return a

    # ------------------------------------------------ estimator override
    def est_means(self, s):
        counts = s["est_n"].astype(jnp.float64)
        gn = s["node_gn"]
        g = jnp.where(gn > 0,
                      s["node_gsum"]
                      / jnp.maximum(gn.astype(jnp.float64), 1),
                      self.prior)
        return jnp.where(s["est_n"] > 0,
                         s["est_sum"] / jnp.maximum(counts, 1), g)

    # ------------------------------------------ overlay-rail queue ops
    # (q_head is inherited: the head cache works the same way)
    def q_push(self, s, fn, rid, on):
        if self._direct:
            return self._q_push_direct(s, fn, rid, on)
        fc = jnp.clip(fn, 0, self.F - 1)
        was_empty = s["q_len"][fc] == 0
        full = s["q_len"][fc] >= self.Q
        do = on & ~full
        rid32 = jnp.asarray(rid, jnp.int32)
        tail = s["q_tail_rid"][fc]
        link = do & ~was_empty
        kf = self._node * self.F + fc
        s = dict(s)
        # the view-row updates keep intra-event reads consistent; the
        # carried (L, K, F) queue arrays are updated via the qw_*
        # write registers instead (scalar scatters in step() — a row
        # commit of these arrays defeats XLA's in-place rewrite and
        # costs two full copies per event)
        s["q_head_rid"] = s["q_head_rid"].at[
            _gidx(do & was_empty, fn, self.F)].set(rid32, mode="drop")
        s["qw_head_pos"] = jnp.where(do & was_empty, kf,
                                     s["qw_head_pos"])
        s["qw_head_val"] = jnp.where(do & was_empty, rid32,
                                     s["qw_head_val"])
        # nxt[tail] = rid, staged via the per-event link register
        s["lw_q_pos"] = jnp.where(link, tail, s["lw_q_pos"])
        s["lw_q_val"] = jnp.where(link, rid32, s["lw_q_val"])
        s["q_tail_rid"] = s["q_tail_rid"].at[
            _gidx(do, fn, self.F)].set(rid32, mode="drop")
        s["qw_tail_pos"] = jnp.where(do, kf, s["qw_tail_pos"])
        s["qw_tail_val"] = jnp.where(do, rid32, s["qw_tail_val"])
        s["q_len"] = s["q_len"].at[_gidx(do, fn, self.F)].add(
            1, mode="drop")
        s["qw_len_pos"] = jnp.where(do, kf, s["qw_len_pos"])
        s["qw_len_delta"] = jnp.where(do, jnp.int32(1),
                                      s["qw_len_delta"])
        s["q_tot"] = s["q_tot"] + do.astype(jnp.int32)
        s["ci"] = s["ci"].at[CI_OVF].add((on & full).astype(jnp.int32))
        return s, do

    def _q_push_direct(self, s, fn, rid, on):
        # churn mode: a drained rid re-enters a queue, so links are no
        # longer write-once — write the nxt rail per event and let the
        # cursor trio ride the nodal row commit
        fc = jnp.clip(fn, 0, self.F - 1)
        was_empty = s["q_len"][fc] == 0
        full = s["q_len"][fc] >= self.Q
        do = on & ~full
        rid32 = jnp.asarray(rid, jnp.int32)
        tail = s["q_tail_rid"][fc]
        s = dict(s)
        s["q_head_rid"] = s["q_head_rid"].at[
            _gidx(do & was_empty, fn, self.F)].set(rid32, mode="drop")
        s["nxt"] = s["nxt"].at[
            _gidx(do & ~was_empty, tail, self.N)].set(rid32,
                                                      mode="drop")
        s["q_tail_rid"] = s["q_tail_rid"].at[
            _gidx(do, fn, self.F)].set(rid32, mode="drop")
        s["q_len"] = s["q_len"].at[_gidx(do, fn, self.F)].add(
            1, mode="drop")
        s["q_tot"] = s["q_tot"] + do.astype(jnp.int32)
        s["ci"] = s["ci"].at[CI_OVF].add((on & full).astype(jnp.int32))
        return s, do

    def q_consume_direct(self, s, fn, on):
        # no positional cursor to advance: a directly dispatched
        # arrival simply never enters the link chain
        return s

    def q_pop(self, s, fn, on):
        if self._direct:
            return self._q_pop_direct(s, fn, on)
        fc = jnp.clip(fn, 0, self.F - 1)
        rid = s["q_head_rid"][fc]
        defer = on & (s["q_len"][fc] > 1)
        fi = _gidx(on, fn, self.F)
        kf = self._node * self.F + fc
        s = dict(s)
        # the successor lookup is deferred: the chase pass rewrites
        # the parked head register from the overlay/rail before the
        # registers are applied to the carried queue arrays
        s["q_head_rid"] = s["q_head_rid"].at[fi].set(-1, mode="drop")
        s["qw_head_pos"] = jnp.where(on, kf, s["qw_head_pos"])
        s["qw_head_val"] = jnp.where(on, jnp.int32(-1),
                                     s["qw_head_val"])
        s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
        s["qw_len_pos"] = jnp.where(on, kf, s["qw_len_pos"])
        s["qw_len_delta"] = jnp.where(on, jnp.int32(-1),
                                      s["qw_len_delta"])
        s["q_tot"] = s["q_tot"] - on.astype(jnp.int32)
        s["pp_kf"] = jnp.where(defer, kf, s["pp_kf"])
        s["pp_rid"] = jnp.where(defer, rid, s["pp_rid"])
        return s, rid

    def _q_pop_direct(self, s, fn, on):
        # churn mode: the successor is read straight off the rail (it
        # was written directly at push time, so it is always current)
        fc = jnp.clip(fn, 0, self.F - 1)
        rid = s["q_head_rid"][fc]
        succ = jnp.where(s["q_len"][fc] > 1,
                         s["nxt"][jnp.clip(rid, 0, self.N - 1)],
                         jnp.int32(-1))
        fi = _gidx(on, fn, self.F)
        s = dict(s)
        s["q_head_rid"] = s["q_head_rid"].at[fi].set(succ, mode="drop")
        s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
        s["q_tot"] = s["q_tot"] - on.astype(jnp.int32)
        return s, rid

    # -------------------------------------------- rid-chain timer rail
    def arm_timer(self, s, fn, rid, t, pushed, on):
        fc = jnp.clip(fn, 0, self.F - 1)
        rail_head = s["tmr_seq"][fc] == s["arr_cnt"][fc] - 1
        rid32 = jnp.asarray(rid, jnp.int32)
        head_arm = on & rail_head & pushed
        hi = _gidx(head_arm, fn, self.F)
        s = dict(s)
        s["tmr_rid"] = s["tmr_rid"].at[hi].set(rid32, mode="drop")
        s["tmr_next"] = s["tmr_next"].at[hi].set(
            t + self.threshold, mode="drop")
        s["tmr_seq"] = s["tmr_seq"].at[
            _gidx(on & rail_head & ~pushed, fn, self.F)].add(
            1, mode="drop")
        return s


class ClusterResilCtx(ClusterNodeCtx):
    """Cluster node ctx under the resilience layer (fail_prob /
    timeouts / retries / shedding — see `repro.core.jax_engine
    .ResilCtx`, whose outcome-operand reads and shed-mode queue push
    this mirrors on the cluster's direct-link queue layout). Retries
    re-enqueue old rids, so the engine always runs in direct-link mode
    (``direct_links=True``) when resilience is on."""

    def __init__(self, *, nfail2, tmo2, key2, resil, **kw):
        super().__init__(**kw)
        self._nf = nfail2.reshape(-1)
        self._tm = tmo2.reshape(-1)
        self._ky = key2.reshape(-1)
        self.resil = resil  # (max_attempts, shed_mode, base, cap,
        self.has_resil = True            # jitter, fail_seed) — static
        self.defer_completion = True     # completion on success only

    def nfail_at(self, rid):
        return self._nf[self._b_n + jnp.clip(rid, 0, self.N - 1)]

    def tmo_at(self, rid):
        return self._tm[self._b_n + jnp.clip(rid, 0, self.N - 1)]

    def key_at(self, rid):
        return self._ky[self._b_n + jnp.clip(rid, 0, self.N - 1)]

    def _q_push_direct(self, s, fn, rid, on):
        # direct-link append with the admission-control modes: a push
        # onto a full backlog drops-and-counts (``error``, the legacy
        # invalid-run behaviour), sheds the arriving request (``shed``
        # — terminal, never admitted) or evicts the queue head to
        # admit the newcomer (``shed_oldest``)
        fc = jnp.clip(fn, 0, self.F - 1)
        rid32 = jnp.asarray(rid, jnp.int32)
        len0 = s["q_len"][fc]
        full = len0 >= self.Q
        mode = self.resil[1]
        s = dict(s)
        if mode == 2:  # shed_oldest: head out (terminal), newcomer in
            evict = on & full
            h = s["q_head_rid"][fc]
            hsucc = s["nxt"][jnp.clip(h, 0, self.N - 1)]
            fi = _gidx(evict, fn, self.F)
            s["q_head_rid"] = s["q_head_rid"].at[fi].set(hsucc,
                                                         mode="drop")
            s["q_len"] = s["q_len"].at[fi].add(-1, mode="drop")
            ev_i = evict.astype(jnp.int32)
            s["q_tot"] = s["q_tot"] - ev_i
            s["ci"] = s["ci"].at[jnp.array([CI_SHED, CI_TERM])].add(
                jnp.stack([ev_i, ev_i]))
            do = on
            was_empty = (len0 - ev_i) == 0
        else:
            do = on & ~full
            was_empty = len0 == 0
            if mode == 1:  # shed the arriving request
                sh_i = (on & full).astype(jnp.int32)
                s["ci"] = s["ci"].at[jnp.array([CI_SHED, CI_TERM])].add(
                    jnp.stack([sh_i, sh_i]))
            else:
                s["ci"] = s["ci"].at[CI_OVF].add(
                    (on & full).astype(jnp.int32))
        tail = s["q_tail_rid"][fc]
        s["q_head_rid"] = s["q_head_rid"].at[
            _gidx(do & was_empty, fn, self.F)].set(rid32, mode="drop")
        s["nxt"] = s["nxt"].at[
            _gidx(do & ~was_empty, tail, self.N)].set(rid32,
                                                      mode="drop")
        s["q_tail_rid"] = s["q_tail_rid"].at[
            _gidx(do, fn, self.F)].set(rid32, mode="drop")
        s["q_len"] = s["q_len"].at[_gidx(do, fn, self.F)].add(
            1, mode="drop")
        s["q_tot"] = s["q_tot"] + do.astype(jnp.int32)
        return s, do


# ------------------------------------------------------------ event loop
@functools.partial(jax.jit,
                   static_argnames=("kernel", "router", "n_nodes",
                                    "n_fns", "capacity", "queue_cap",
                                    "seed", "stream", "tl_bins",
                                    "has_delay", "has_churn",
                                    "var_delay", "seg", "resil",
                                    "trace"))
def _simulate_cluster(fn_id, arrival, exec_time, t_cold, t_evict,
                      trace_ix, cap_mask, beta, prior, threshold,
                      delays, churn_t=None, dtimes=None, dvals=None,
                      dper=None, deadlines=None, rs_nfail=None,
                      rs_tmo=None, rs_key=None, *, kernel, router,
                      n_nodes, n_fns, capacity, queue_cap, seed=0,
                      stream=False, tl_bins=0, tl_bucket=60.0,
                      has_delay=False, has_churn=False,
                      var_delay=False, seg=0, resil=None,
                      trace=False):
    """K-node lane-batched cluster loop (see the module docstring).

    ``cap_mask`` is (L, K, C) — heterogeneous node capacities are
    per-node slot masks over the common C = max slots. ``delays`` is
    the (K,) per-node network delay operand, only read when the static
    ``has_delay`` flag is set (so zero-delay runs stay bitwise the
    single-node arithmetic). ``seg`` (static; 0 -> `SEG`) sets the
    overlay segment length and never changes results.

    PR 7 operands, each gated by its own static flag so every disabled
    combination keeps its previous jaxpr: ``churn_t`` (K, E) f64
    toggle times under ``has_churn`` (even index = node goes down, odd
    = up; BIG-padded with at least one all-BIG trailing column so the
    cursor can rest past the last real toggle); ``dtimes``/``dvals``
    (K, D) + ``dper`` (K,) `DelaySchedule` steps under ``var_delay``
    (implies ``has_delay``); ``deadlines`` (F,) per-function SLO
    deadlines (or None), folded into a (L, F) ``deadline_miss``
    output.

    Returns the single-node engine's output dict plus ``node_done``
    (L, K) and, in exact mode under delay without churn, ``node_of``
    (L, N), the per-request dispatching node."""
    L = trace_ix.shape[0]
    N = fn_id.shape[1]
    F, C, K, Q = n_fns, capacity, n_nodes, queue_cap
    KC = K * C
    KF = K * F
    SG = int(seg) if seg else SEG
    timers = kernel.has_timers
    has_resil = resil is not None
    has_breaker = isinstance(router, BreakerRouter)
    # retries re-enqueue old rids, which breaks the write-once link
    # invariant behind the segment overlays exactly like churn does —
    # both run the direct-link spelling (per-event rail writes)
    direct = has_churn or has_resil
    done_col = CI_TERM if has_resil else CI_DONE
    if timers and has_churn:
        raise ValueError("timer-rail kernels are not supported under "
                         "churn (rejected at the runner)")
    if timers and has_resil:
        raise ValueError("timer-rail kernels are not supported under "
                         "the resilience layer (rejected at the "
                         "runner)")
    if var_delay and not has_delay:
        raise ValueError("var_delay requires has_delay")

    fn_id = fn_id.astype(jnp.int32)
    arrival = arrival.astype(jnp.float64)
    exec_time = exec_time.astype(jnp.float64)
    t_cold = t_cold.astype(jnp.float64)
    t_evict = t_evict.astype(jnp.float64)
    trace_ix = trace_ix.astype(jnp.int32)
    prior = jnp.float64(prior)
    threshold = jnp.float64(threshold)
    tl_bucket = jnp.float64(tl_bucket)
    delays = jnp.asarray(delays, jnp.float64)
    if has_churn:
        churn_t = jnp.asarray(churn_t, jnp.float64)
        E = churn_t.shape[1]
        churn_offs = jnp.arange(K, dtype=jnp.int32) * E
    if var_delay:
        dtimes = jnp.asarray(dtimes, jnp.float64)
        dvals = jnp.asarray(dvals, jnp.float64)
        dper = jnp.asarray(dper, jnp.float64)
        dt_b = jnp.broadcast_to(dtimes[None], (L,) + dtimes.shape)
        dv_b = jnp.broadcast_to(dvals[None], (L,) + dvals.shape)
        dp_b = jnp.broadcast_to(dper[None], (L, K))
    if deadlines is not None:
        deadlines = jnp.asarray(deadlines, jnp.float64)
    if has_resil:
        max_att, shed_mode, rt_base, rt_cap, rt_jit, rt_seed = resil
        rs_nfail = jnp.asarray(rs_nfail, jnp.int32)
        rs_tmo = jnp.asarray(rs_tmo, jnp.bool_)
        rs_key = jnp.asarray(rs_key, jnp.int32)

    s = dict(
        slot_fn=jnp.full((L, K, C), -1, jnp.int32),
        slot_state=jnp.full((L, K, C), IDLE, jnp.int32),
        slot_ready=jnp.full((L, K, C), BIG, jnp.float64),
        slot_req=jnp.full((L, K, C), -1, jnp.int32),
        slot_used=jnp.zeros((L, K, C), jnp.float64),
        slot_seq=jnp.full((L, K, C), I32_MAX, jnp.int32),
        q_len=jnp.zeros((L, K, F), jnp.int32),
        q_head_rid=jnp.full((L, K, F), -1, jnp.int32),
        q_tail_rid=jnp.full((L, K, F), -1, jnp.int32),
        q_tot=jnp.zeros((L, K), jnp.int32),
        nxt=jnp.full((L, N), -1, jnp.int32),
        est_sum=jnp.zeros((L, K, F), jnp.float64),
        est_n=jnp.zeros((L, K, F), jnp.int32),
        node_gn=jnp.zeros((L, K), jnp.int32),
        node_gsum=jnp.zeros((L, K), jnp.float64),
        node_done=jnp.zeros((L, K), jnp.int32),
        ci=jnp.zeros((L, NCI), jnp.int32),
        cf=jnp.zeros((L, NCF), jnp.float64),
        hist=jnp.zeros((L, HIST_BINS), jnp.int32),
    )
    if not direct:
        # queue write registers, carried across steps: the previous
        # event's parked queue writes are applied at the *top* of the
        # next step (see step()), so within one step the queue arrays'
        # only direct user is the opening in-place scatter. In
        # direct-link mode (churn / resilience) the trio rides the
        # nodal row commit instead and links are written directly, so
        # neither register family exists.
        s["qw_len_pos"] = jnp.full((L,), -1, jnp.int32)
        s["qw_len_delta"] = jnp.zeros((L,), jnp.int32)
        s["qw_head_pos"] = jnp.full((L,), -1, jnp.int32)
        s["qw_head_val"] = jnp.zeros((L,), jnp.int32)
        s["qw_tail_pos"] = jnp.full((L,), -1, jnp.int32)
        s["qw_tail_val"] = jnp.zeros((L,), jnp.int32)
        s["ov_q_pos"] = jnp.full((L, SG), N, jnp.int32)
        s["ov_q_val"] = jnp.zeros((L, SG), jnp.int32)
    if has_churn:
        # availability cursor (even parity = up) + the park FIFO of
        # requests orphaned by node failures / all-down arrivals; the
        # chain rides the nxt rail, park_t is the head's eligibility
        # time (the whole FIFO drains at one instant whenever a node
        # is up, so one scalar per lane suffices — see NODE_DOWN)
        s["ch_ix"] = jnp.zeros((L, K), jnp.int32)
        s["park_head"] = jnp.full((L,), -1, jnp.int32)
        s["park_tail"] = jnp.full((L,), -1, jnp.int32)
        s["park_len"] = jnp.zeros((L,), jnp.int32)
        s["park_t"] = jnp.full((L,), BIG, jnp.float64)
    if direct and has_delay:
        # landing time of each in-flight request, written at send
        # time (an orphan's or retry's re-send samples the delay
        # then, so the raw-arrival closed form no longer applies)
        s["land_t"] = jnp.zeros((L, N), jnp.float64)
    if has_resil:
        # retry rail: one cluster-global FIFO per lane over the shared
        # nxt links (a rid is queued XOR running XOR in flight XOR
        # parked XOR awaiting retry XOR terminal), eligibility times
        # rt_t and the armed head fire time r_fire; att counts started
        # attempts per rid
        s["att"] = jnp.zeros((L, N), jnp.int32)
        s["rt_t"] = jnp.zeros((L, N), jnp.float64)
        s["r_head"] = jnp.full((L,), -1, jnp.int32)
        s["r_tail"] = jnp.full((L,), -1, jnp.int32)
        s["r_len"] = jnp.zeros((L,), jnp.int32)
        s["r_fire"] = jnp.full((L,), BIG, jnp.float64)
    if has_breaker:
        # per-node circuit-breaker state: tumbling-window completion /
        # failure counts and the reopen time (0 = closed, > t = open,
        # (0, t] = half-open probe pending); read by the router, and
        # updated at EXEC_DONE by the event's node — so the trio is
        # nodal state
        s["cbr_n"] = jnp.zeros((L, K), jnp.int32)
        s["cbr_f"] = jnp.zeros((L, K), jnp.int32)
        s["cbr_until"] = jnp.zeros((L, K), jnp.float64)
    if deadlines is not None:
        s["dl_miss"] = jnp.zeros((L, F), jnp.int32)
    if timers:
        s["arr_cnt"] = jnp.zeros((L, K, F), jnp.int32)
        s["tmr_seq"] = jnp.zeros((L, K, F), jnp.int32)
        s["tmr_rid"] = jnp.full((L, K, F), -1, jnp.int32)
        s["tmr_next"] = jnp.full((L, K, F), BIG, jnp.float64)
        s["rearm_t"] = jnp.full((L, K, F), BIG, jnp.float64)
        s["rearm_rid"] = jnp.full((L, K, F), -1, jnp.int32)
        s["la_rid"] = jnp.full((L, K, F), -1, jnp.int32)
        s["tnx"] = jnp.full((L, N), -1, jnp.int32)
        s["ov_t_pos"] = jnp.full((L, SG), N, jnp.int32)
        s["ov_t_val"] = jnp.zeros((L, SG), jnp.int32)
    if has_delay:
        s["pend_head"] = jnp.full((L, K), -1, jnp.int32)
        s["pend_tail"] = jnp.full((L, K), -1, jnp.int32)
        s["pend_len"] = jnp.zeros((L, K), jnp.int32)
        s["dnx"] = jnp.full((L, N), -1, jnp.int32)
        if not direct:
            s["ov_d_pos"] = jnp.full((L, SG), N, jnp.int32)
            s["ov_d_val"] = jnp.zeros((L, SG), jnp.int32)
    if not stream:
        s["start"] = jnp.full((L, N), -1.0, jnp.float64)
        s["completion"] = jnp.full((L, N), -1.0, jnp.float64)
        if not direct:
            # direct-link mode writes the per-request records directly
            # per event (ctx.direct_records) — no d_* overlays to stage
            s["d_rid"] = jnp.full((L, SG), N, jnp.int32)
            s["d_start"] = jnp.zeros((L, SG), jnp.float64)
            s["d_comp"] = jnp.zeros((L, SG), jnp.float64)
            if has_delay:
                s["d_node"] = jnp.zeros((L, SG), jnp.int32)
                s["node_of"] = jnp.zeros((L, N), jnp.int32)
    if tl_bins:
        s["tl_cnt"] = jnp.zeros((L, tl_bins), jnp.int32)
        s["tl_resp"] = jnp.zeros((L, tl_bins), jnp.float64)
        s["tl_exec"] = jnp.zeros((L, tl_bins), jnp.float64)
    if trace:
        # event-trace segment overlay: one fixed-width record per
        # processed event, flushed to the host per segment — lane
        # global (rides gather/commit untouched), O(SG) carried state
        from repro.telemetry.rail import TR_RF, TR_RI
        s["tr_i"] = jnp.full((L, SG, TR_RI), -1, jnp.int32)
        s["tr_f"] = jnp.zeros((L, SG, TR_RF), jnp.float64)
    extra = kernel.extra_state(L, C, F)
    nodal = _NODAL + (_NODAL_TMR if timers else ()) \
        + (_NODAL_PEND if has_delay else ()) \
        + (("ch_ix",) if has_churn else ()) \
        + (("cbr_n", "cbr_f", "cbr_until") if has_breaker else ()) \
        + tuple(extra)
    for kk, v in extra.items():
        # one copy of the kernel's per-server state per node
        s[kk] = jnp.repeat(v[:, None, ...], K, axis=1)
    if has_churn:
        # pristine per-node kernel rows, for the NODE_DOWN reset
        extra0 = {kk: v[0]
                  for kk, v in kernel.extra_state(1, C, F).items()}

    max_iters = 256 * N + 4096
    if has_churn:
        # every toggle can orphan up to a nodeful of requests, each
        # re-routed and re-executed — a generous stall guard, not a
        # budget
        max_iters += (4 * N + 64) * K * E
    if has_resil:
        # each rid can run (and re-enter) up to max_attempts times
        max_iters *= max_att
    n_slot = 2 * KC
    tmr_base = n_slot
    pend_base = n_slot + (2 * KF if timers else 0)
    orph_base = pend_base + (K if has_delay else 0)
    churn_base = orph_base + (1 if has_churn else 0)
    rtry_base = churn_base + (K if has_churn else 0)
    n_cand = rtry_base + (1 if has_resil else 0) + 1
    lanes = jnp.arange(L, dtype=jnp.int32)
    lane_iota = lanes[:, None]
    t_cold_l = t_cold[trace_ix]
    t_evict_l = t_evict[trace_ix]
    # flattened-view reads with per-lane bases: (T, N) two-dim gathers
    # only hit the fast XLA:CPU path at T == 1 (see EngineCtx)
    arr_flat = arrival.reshape(-1)
    fn_flat = fn_id.reshape(-1)
    base_n = trace_ix * N

    # node view/commit live OUTSIDE the vmapped body: a vmapped
    # dynamic_index over the (L, K, F) nodal arrays is a
    # batched-operand gather — the generic XLA:CPU path, measured
    # O(K*F) per event — whereas one lane-stacked take_along_axis /
    # row scatter per array rides the fast gather/scatter path
    # the queue trio's carried writes are at most one scalar position
    # per array per event, so they skip the row commit — XLA's
    # copy-insertion cannot prove the fused row arithmetic of these
    # rows in-place and charges two full (L, K, F) copies per event —
    # and ride the qw_* write registers instead (scalar drop-scatters
    # in step(); the gathered view row stays for kernel full-row reads)
    _Q_TRIO = ("q_len", "q_head_rid", "q_tail_rid")
    # under churn / resilience the write registers don't exist
    # (direct-link mode), so the trio commits like every other nodal
    # array
    nodal_commit = (nodal if direct else
                    tuple(kk for kk in nodal if kk not in _Q_TRIO))

    def gather_nodal(s, k_ev):
        v = dict(s)
        for key in nodal:
            a = s[key]
            idx = k_ev.reshape((L,) + (1,) * (a.ndim - 1))
            v[key] = jnp.take_along_axis(a, idx, axis=1)[:, 0]
        return v

    def commit_nodal(s, v, k_ev):
        out = dict(v)
        for key in nodal_commit:
            out[key] = s[key].at[lanes, k_ev].set(v[key])
        for key in nodal:
            if key not in nodal_commit:
                out[key] = s[key]
        return out

    def make_ctx(tix, cold_l, evict_l, capm_node, beta, k_step, node):
        # response convention: under churn / resilience requests are
        # measured from the *raw* arrival (user-perceived — an
        # orphaned or retried request may traverse several nodes and
        # attempts); otherwise the node-local clock (+const delay, or
        # +schedule-at-raw-arrival) is preserved
        if direct:
            dly, dsc = None, None
        elif var_delay:
            kc = jnp.clip(node, 0, K - 1)
            dly, dsc = None, (dtimes[kc], dvals[kc], dper[kc])
        elif has_delay:
            dly, dsc = delays[node], None
        else:
            dly, dsc = None, None
        kw = dict(
            fn_id2=fn_id, arrival2=arrival, exec2=exec_time,
            cold2=cold_l, evict2=evict_l, tix=tix, cap_mask=capm_node,
            beta=beta, prior=prior, threshold=threshold, k=k_step,
            n=N, f=F, c=C, q=Q, stream=stream, tl_bins=tl_bins,
            tl_bucket=tl_bucket, node=node, delay=dly, delay_sched=dsc,
            deadlines=deadlines, direct_links=direct, seg_n=SG)
        ctx = (ClusterResilCtx(nfail2=rs_nfail, tmo2=rs_tmo,
                               key2=rs_key, resil=resil, **kw)
               if has_resil else ClusterNodeCtx(**kw))
        if direct:
            # fold at EXEC_DONE (a drained / failed attempt's dispatch
            # record must not count) and write exact-mode records per
            # event
            ctx.fold_at_dispatch = False
            ctx.direct_records = True
        return ctx

    def pick_events(s):
        na = s["ci"][:, CI_NEXT]
        r = jnp.minimum(na, N - 1)
        t_arr = jnp.where(na < N, arr_flat[base_n + r], BIG)
        ready = jnp.where(cap_mask, s["slot_ready"], BIG
                          ).reshape(L, KC)
        st = s["slot_state"].reshape(L, KC)
        blocks = [jnp.where(st == BUSY, ready, BIG),
                  jnp.where(st == COLD, ready, BIG)]
        if timers:
            blocks += [s["tmr_next"].reshape(L, KF),
                       s["rearm_t"].reshape(L, KF)]
        if has_delay:
            ph = jnp.clip(s["pend_head"], 0, N - 1)
            if direct:
                land = jnp.take_along_axis(s["land_t"], ph, axis=1)
            elif var_delay:
                arr_ph = arr_flat[base_n[:, None] + ph]
                land = arr_ph + _sched_delay(arr_ph, dt_b, dv_b, dp_b)
            else:
                land = arr_flat[base_n[:, None] + ph] + delays[None, :]
            blocks.append(jnp.where(s["pend_len"] > 0, land, BIG))
        if has_churn:
            # orphan (one column): the park head re-routes as soon as
            # any node is up; churn (K columns): each node's next
            # toggle time off the BIG-padded cursor
            up = (s["ch_ix"] & 1) == 0
            blocks.append(jnp.where((s["park_len"] > 0)
                                    & up.any(axis=1),
                                    s["park_t"], BIG)[:, None])
            cix = jnp.clip(s["ch_ix"], 0, E - 1)
            blocks.append(churn_t.reshape(-1)[churn_offs[None, :]
                                              + cix])
        if has_resil:
            # armed retry-rail head (BIG while the rail is empty)
            blocks.append(s["r_fire"][:, None])
        blocks.append(t_arr[:, None])
        cand = jnp.concatenate(blocks, axis=1)
        ei = jnp.argmin(cand, axis=1).astype(jnp.int32)
        t_ev = jnp.take_along_axis(cand, ei[:, None], axis=1)[:, 0]
        return ei, t_ev, t_arr

    def pick_one(q_len, q_tot, slot_fn, slot_state, capm, est_sum,
                 est_n, node_gn, node_gsum, cold_l, up, delay_now, brk,
                 j, rid, t):
        g = ClusterView(q_len=q_len, q_tot=q_tot, slot_fn=slot_fn,
                        slot_state=slot_state, cap_mask=capm,
                        est_sum=est_sum, est_n=est_n, node_gn=node_gn,
                        node_gsum=node_gsum, t_cold=cold_l,
                        prior=prior, n_nodes=K, seed=seed,
                        up=up, delay_now=delay_now, brk_until=brk)
        return router.pick(g, j, rid, t)

    # ``up``/``delay_now``/``brk_until`` stay python-None (an empty
    # pytree — any in_axes is legal) when their feature is off, so the
    # no-churn / const-delay / no-breaker jaxprs are unchanged; a
    # const (K,) delay_now is shared across lanes (in_axes None), a
    # scheduled one is (L, K)
    pick_lanes = jax.vmap(
        pick_one, in_axes=(0,) * 10 + (0 if has_churn else None,
                                       0 if var_delay else None,
                                       0 if has_breaker else None)
        + (0, 0, 0))

    def lane_step(k_step, s, tix, cold_l, evict_l, capm, beta, ei,
                  t_ev, t_arr, node):
        # ``s`` arrives with the nodal keys already sliced to
        # ``node``'s row (gather_nodal); ``capm`` is that node's (C,)
        # slot mask
        ci = s["ci"]
        if trace:
            tr_q0 = s["q_tot"]  # event node's queue total, pre-event
        active = (ci[done_col] < N) & (ci[CI_STALL] == 0)
        na = ci[CI_NEXT]
        live = active & (t_ev < BIG)
        # per-event registers: dispatch record (consumed by
        # _fold_event), link writes (staged into the overlays) and
        # deferred link reads (resolved by the chase pass) — in
        # direct-link mode the overlay/register families don't exist
        # (links are written directly)
        s = dict(s)
        if has_churn:
            anyup = s.pop("anyup")
        s["ev_rid"] = jnp.int32(-1)
        s["ev_comp"] = jnp.float64(0.0)
        s["ev_exec"] = jnp.float64(0.0)
        if has_resil:
            # per-lane success flag of this event (popped by step() to
            # gate the node_done tally to successful completions)
            s["rs_ok"] = jnp.bool_(False)
        if not direct:
            s["lw_q_pos"] = jnp.int32(-1)
            s["lw_q_val"] = jnp.int32(0)
            s["pp_kf"] = jnp.int32(-1)
            s["pp_rid"] = jnp.int32(-1)
            # queue write registers: each event performs at most one
            # push or one pop (the kernels' hooks are push-xor-pop and
            # the event classes are mutually exclusive), so one scalar
            # write per queue array covers every case
            s["qw_len_pos"] = jnp.int32(-1)
            s["qw_len_delta"] = jnp.int32(0)
            s["qw_head_pos"] = jnp.int32(-1)
            s["qw_head_val"] = jnp.int32(0)
            s["qw_tail_pos"] = jnp.int32(-1)
            s["qw_tail_val"] = jnp.int32(0)
        if timers:
            s["lw_t_pos"] = jnp.int32(-1)
            s["lw_t_val"] = jnp.int32(0)
            s["tp_kf"] = jnp.int32(-1)
            s["tp_rid"] = jnp.int32(-1)
        if has_delay and not direct:
            s["lw_d_pos"] = jnp.int32(-1)
            s["lw_d_val"] = jnp.int32(0)
            s["dp_k"] = jnp.int32(-1)
            s["dp_rid"] = jnp.int32(-1)

        # ------------------------------------------ event class decode
        ev_slot = live & (ei < n_slot)
        is_cold = ei >= KC
        sflat = jnp.clip(jnp.where(is_cold, ei - KC, ei), 0, KC - 1)
        slot = sflat % C
        ev_arr = live & (ei == n_cand - 1)
        if has_churn:
            ev_orph = live & (ei == orph_base)
            ev_churn = live & (ei >= churn_base) & (ei < churn_base + K)
        ev_timer = jnp.bool_(False)
        if timers:
            fire_orig = live & (ei >= tmr_base) & (ei < tmr_base + KF)
            fire_re = (live & (ei >= tmr_base + KF)
                       & (ei < tmr_base + 2 * KF))
            ev_timer = fire_orig | fire_re
            kf_t = jnp.clip(jnp.where(fire_orig, ei - tmr_base,
                                      ei - tmr_base - KF), 0, KF - 1)
            f_t = kf_t % F
        if has_delay:
            ev_pend = live & (ei >= pend_base) & (ei < pend_base + K)

        rid_a = jnp.minimum(na, N - 1)
        ctx = make_ctx(tix, cold_l, evict_l, capm, beta, k_step, node)
        v = s

        # ------------------------------------------------- slot event
        cold_on = ev_slot & is_cold
        exec_on = ev_slot & ~is_cold
        rid_done = v["slot_req"][slot]
        j_done = v["slot_fn"][slot]
        e_done = ctx.exec_at(rid_done)
        si = _gidx(ev_slot, slot, C)
        ji = _gidx(exec_on, j_done, F)
        exec_i = exec_on.astype(jnp.int32)
        v = dict(v)
        v["slot_state"] = v["slot_state"].at[si].set(IDLE, mode="drop")
        v["slot_ready"] = v["slot_ready"].at[si].set(BIG, mode="drop")
        v["slot_req"] = v["slot_req"].at[si].set(-1, mode="drop")
        # the node's estimator sees the completion before its policy
        # reacts, exactly like the single-node engine
        v["est_sum"] = v["est_sum"].at[ji].add(e_done, mode="drop")
        v["est_n"] = v["est_n"].at[ji].add(1, mode="drop")
        v["node_gsum"] = v["node_gsum"] + jnp.where(exec_on, e_done,
                                                    0.0)
        v["node_gn"] = v["node_gn"] + exec_i
        if not has_resil:
            v["ci"] = v["ci"].at[CI_DONE].add(exec_i)
            fold_on = exec_on
        else:
            # outcome of this attempt: the estimator observed it above
            # (every attempt burns real slot time); success/failure is
            # the pre-planned attempt test (see core/resilience.py)
            att_d = v["att"][jnp.clip(rid_done, 0, N - 1)]
            nf_d = ctx.nfail_at(rid_done)
            ok_d = exec_on & (att_d > nf_d)
            fail_d = exec_on & ~ok_d
            exh_d = fail_d & (att_d >= max_att)
            retry_d = fail_d & ~exh_d
            tmo_d = ctx.tmo_at(rid_done)
            ok_i = ok_d.astype(jnp.int32)
            v["ci"] = v["ci"].at[jnp.array(
                [CI_DONE, CI_TERM, CI_FAILED, CI_TMO, CI_RETRY,
                 CI_EXH])].add(jnp.stack(
                [ok_i, ok_i + exh_d.astype(jnp.int32),
                 (fail_d & ~tmo_d).astype(jnp.int32),
                 (fail_d & tmo_d).astype(jnp.int32),
                 retry_d.astype(jnp.int32),
                 exh_d.astype(jnp.int32)]))
            v["rs_ok"] = ok_d
            fold_on = ok_d
        if direct:
            # fold at EXEC_DONE: a drained / failed attempt never
            # folds, so exactly the surviving run of each request
            # counts (response = completion - raw arrival via the ctx)
            v["ev_rid"] = jnp.where(fold_on,
                                    jnp.asarray(rid_done, jnp.int32),
                                    v["ev_rid"])
            v["ev_comp"] = jnp.where(fold_on, t_ev, v["ev_comp"])
            v["ev_exec"] = jnp.where(fold_on, e_done, v["ev_exec"])
        if has_resil:
            if not stream:
                # deferred exact-mode record: an exhausted / shed rid
                # must keep completion == -1
                v["completion"] = v["completion"].at[
                    _gidx(ok_d, rid_done, N)].set(t_ev, mode="drop")
            # a retrying rid re-enters after its backoff; the rail is
            # FIFO so only an empty rail arms the fire time here
            key_d = ctx.key_at(rid_done)
            elig = t_ev + backoff_jax(att_d, key_d, rt_base, rt_cap,
                                      rt_jit, rt_seed)
            rd32 = jnp.asarray(rid_done, jnp.int32)
            v["rt_t"] = v["rt_t"].at[
                _gidx(retry_d, rid_done, N)].set(elig, mode="drop")
            r_empty = v["r_len"] == 0
            v["nxt"] = v["nxt"].at[
                _gidx(retry_d & ~r_empty, v["r_tail"], N)].set(
                rd32, mode="drop")
            v["r_head"] = jnp.where(retry_d & r_empty, rd32,
                                    v["r_head"])
            v["r_tail"] = jnp.where(retry_d, rd32, v["r_tail"])
            v["r_fire"] = jnp.where(retry_d & r_empty, elig,
                                    v["r_fire"])
            v["r_len"] = v["r_len"] + retry_d.astype(jnp.int32)
        if has_breaker:
            # circuit-breaker bookkeeping at the event's node: closed
            # (until == 0) counts the attempt into the tumbling window
            # and trips when a full window's failures reach trip_at;
            # half-open (0 < until <= t) lets the first completed
            # attempt decide — success closes, failure re-trips; open
            # (until > t) completions are pre-trip stragglers, ignored
            fail_ev = fail_d if has_resil else jnp.bool_(False)
            until0 = v["cbr_until"]
            half = exec_on & (until0 > 0.0) & (until0 <= t_ev)
            closed = exec_on & (until0 == 0.0)
            n1 = v["cbr_n"] + closed.astype(jnp.int32)
            f1 = v["cbr_f"] + (closed & fail_ev).astype(jnp.int32)
            boundary = closed & (n1 >= router.volume)
            trip = (boundary & (f1 >= router.trip_at)) | (half
                                                          & fail_ev)
            v["cbr_until"] = jnp.where(
                trip, t_ev + router.cooldown,
                jnp.where(half, 0.0, until0))
            reset = boundary | half
            v["cbr_n"] = jnp.where(reset, 0, n1)
            v["cbr_f"] = jnp.where(reset, 0, f1)
            v["ci"] = v["ci"].at[CI_TRIPS].add(trip.astype(jnp.int32))
        v = kernel.on_cold_done(ctx, v, slot, t_ev, cold_on)
        v = kernel.on_exec_done(ctx, v, slot, rid_done, t_ev,
                                exec_on)

        # ------------------------------------------------- timer event
        if timers:
            rid_o = v["tmr_rid"][f_t]
            seq_o = v["tmr_seq"][f_t]
            more = seq_o + 1 < v["arr_cnt"][f_t]
            oi = _gidx(fire_orig, f_t, F)
            rid_r = v["rearm_rid"][f_t]
            v = dict(v)
            v["tmr_seq"] = v["tmr_seq"].at[oi].add(1, mode="drop")
            # placeholder; the chase pass installs the chained
            # successor and its fire time before the next pick
            v["tmr_rid"] = v["tmr_rid"].at[oi].set(-1, mode="drop")
            v["tmr_next"] = v["tmr_next"].at[oi].set(BIG, mode="drop")
            v["rearm_t"] = v["rearm_t"].at[
                _gidx(fire_re, f_t, F)].set(BIG, mode="drop")
            chase = fire_orig & more
            v["tp_kf"] = jnp.where(chase, node * F + f_t, v["tp_kf"])
            v["tp_rid"] = jnp.where(chase, rid_o, v["tp_rid"])
            rid_t = jnp.where(fire_orig, rid_o, rid_r)
            v = kernel.on_timer(ctx, v, rid_t, t_ev, ev_timer)

        # ------------------------------------------ churn toggle event
        if has_churn:
            up0 = (v["ch_ix"] & 1) == 0  # pre-toggle parity
            ev_down = ev_churn & up0
            ev_up = ev_churn & ~up0
            v = dict(v)
            v["ch_ix"] = v["ch_ix"] + ev_churn.astype(jnp.int32)
            # ---- NODE_DOWN: drain the node onto the park FIFO.
            # Busy-slot requests first, ascending rid (an engine-
            # independent order the reference mirrors), then the
            # per-fn queues fn-major — all as O(C + F) chain splices
            # on the nxt rail. The park FIFO is provably empty here:
            # the toggling node was up, so any parked head (park_t <=
            # t_ev, orphan class < CHURN) already re-routed.
            busy_m = (v["slot_state"] == BUSY) & capm
            rids_b = jnp.sort(jnp.where(busy_m & ev_down,
                                        v["slot_req"], I32_MAX))
            valid_b = rids_b < I32_MAX
            n_busy = valid_b.sum().astype(jnp.int32)
            succ_b = jnp.concatenate(
                [rids_b[1:], jnp.array([I32_MAX], jnp.int32)])
            link_b = ev_down & valid_b & (succ_b < I32_MAX)
            v["nxt"] = v["nxt"].at[_gidx(link_b, rids_b, N)].set(
                succ_b, mode="drop")
            if has_resil:
                # a drained attempt never completes, so it must not
                # consume the rid's retry budget (the reference never
                # counts it: att increments at dispatch here but at
                # EXEC_DONE there, and a drained run reaches neither)
                v["att"] = v["att"].at[
                    _gidx(ev_down & valid_b, rids_b, N)].add(
                    -1, mode="drop")
            # queue chains: prev[f] = tail of the last nonempty fn
            # before f (exclusive cummax of nonempty fn ids), else the
            # last busy rid
            nonempty = v["q_len"] > 0
            idxf = jnp.arange(F, dtype=jnp.int32)
            cmax = lax.associative_scan(
                jnp.maximum, jnp.where(nonempty, idxf, -1))
            lnb = jnp.concatenate(
                [jnp.array([-1], jnp.int32), cmax[:-1]])
            busy_last = jnp.where(
                n_busy > 0, rids_b[jnp.clip(n_busy - 1, 0, C - 1)],
                jnp.int32(-1))
            prev = jnp.where(lnb >= 0,
                             v["q_tail_rid"][jnp.clip(lnb, 0, F - 1)],
                             busy_last)
            heads = v["q_head_rid"]
            v["nxt"] = v["nxt"].at[
                _gidx(ev_down & nonempty & (prev >= 0), prev, N)].set(
                heads, mode="drop")
            has_q = nonempty.any()
            first_ne = jnp.clip(jnp.argmax(nonempty), 0, F - 1)
            d_head = jnp.where(
                n_busy > 0, rids_b[0],
                jnp.where(has_q, heads[first_ne], jnp.int32(-1)))
            d_tail = jnp.where(
                has_q, v["q_tail_rid"][jnp.clip(cmax[-1], 0, F - 1)],
                busy_last)
            n_drain = n_busy + v["q_tot"]
            parked = ev_down & (n_drain > 0)
            v["park_head"] = jnp.where(parked, d_head, v["park_head"])
            v["park_tail"] = jnp.where(parked, d_tail, v["park_tail"])
            v["park_len"] = jnp.where(parked, n_drain, v["park_len"])
            v["park_t"] = jnp.where(parked, t_ev, v["park_t"])
            # reset the node: cold state dies with it, requests never
            # do; the estimator state deliberately survives (the node
            # remembers its execution history across an outage)
            v["slot_fn"] = jnp.where(ev_down, jnp.int32(-1),
                                     v["slot_fn"])
            v["slot_state"] = jnp.where(ev_down, jnp.int32(IDLE),
                                        v["slot_state"])
            v["slot_ready"] = jnp.where(ev_down, BIG, v["slot_ready"])
            v["slot_req"] = jnp.where(ev_down, jnp.int32(-1),
                                      v["slot_req"])
            v["slot_used"] = jnp.where(ev_down, 0.0, v["slot_used"])
            v["slot_seq"] = jnp.where(ev_down, jnp.int32(I32_MAX),
                                      v["slot_seq"])
            v["q_len"] = jnp.where(ev_down, jnp.int32(0), v["q_len"])
            v["q_head_rid"] = jnp.where(ev_down, jnp.int32(-1),
                                        v["q_head_rid"])
            v["q_tail_rid"] = jnp.where(ev_down, jnp.int32(-1),
                                        v["q_tail_rid"])
            v["q_tot"] = jnp.where(ev_down, jnp.int32(0), v["q_tot"])
            for kk in extra0:
                v[kk] = jnp.where(ev_down, extra0[kk], v[kk])
            # ---- NODE_UP: re-arm the park FIFO's eligibility clock
            # (requests stranded all-down become routable now)
            v["park_t"] = jnp.where(ev_up & (v["park_len"] > 0), t_ev,
                                    v["park_t"])

            # -------------------------------------- orphan re-route
            # (one park-head pop per event; ``node`` is the router's
            # pick for it, applied below exactly like an arrival)
            rid_o = v["park_head"]
            plen_pk = v["park_len"]
            succ_o = jnp.where(plen_pk > 1,
                               v["nxt"][jnp.clip(rid_o, 0, N - 1)],
                               jnp.int32(-1))
            v["park_head"] = jnp.where(ev_orph, succ_o, v["park_head"])
            v["park_tail"] = jnp.where(ev_orph & (plen_pk <= 1),
                                       jnp.int32(-1), v["park_tail"])
            v["park_len"] = v["park_len"] - ev_orph.astype(jnp.int32)
            node_up = (v["ch_ix"] & 1) == 0  # event node, post-toggle

        # ------------------------------------------------- retry event
        ev_rtry = jnp.bool_(False)
        if has_resil:
            # pop the retry-rail head; the successor is promoted but
            # may not fire before this pop (FIFO, no overtaking)
            ev_rtry = live & (ei == rtry_base)
            rlen0 = v["r_len"]
            rid_r = v["r_head"]
            succ_r = v["nxt"][jnp.clip(rid_r, 0, N - 1)]
            rid_r32 = jnp.asarray(rid_r, jnp.int32)
            v = dict(v)
            v["r_head"] = jnp.where(ev_rtry, succ_r, v["r_head"])
            v["r_tail"] = jnp.where(ev_rtry & (rlen0 <= 1),
                                    jnp.int32(-1), v["r_tail"])
            v["r_len"] = rlen0 - ev_rtry.astype(jnp.int32)
            nfire = jnp.maximum(
                v["rt_t"][jnp.clip(succ_r, 0, N - 1)], t_ev)
            v["r_fire"] = jnp.where(
                ev_rtry, jnp.where(rlen0 > 1, nfire, BIG),
                v["r_fire"])

        # ------------------------------------- node arrival / deferral
        if has_delay:
            # deferred-arrival pop: the event time is the node-local
            # (delayed) arrival; the FIFO successor resolves lazily
            # (overlay mode) or straight off the rail (direct mode).
            # A retry (like a raw arrival) only *sends* here — it
            # reaches its node via a later NODE_ARRIVAL pop
            plen0 = v["pend_len"]
            rid_p = v["pend_head"]
            v = dict(v)
            if direct:
                succ_p = jnp.where(plen0 > 1,
                                   v["dnx"][jnp.clip(rid_p, 0, N - 1)],
                                   jnp.int32(-1))
                v["pend_head"] = jnp.where(ev_pend, succ_p,
                                           v["pend_head"])
                v["pend_len"] = (v["pend_len"]
                                 - ev_pend.astype(jnp.int32))
                # a request landing on a node that died in flight
                # parks instead of arriving
                na_on = (ev_pend & node_up) if has_churn else ev_pend
            else:
                v["pend_head"] = jnp.where(ev_pend, jnp.int32(-1),
                                           v["pend_head"])
                v["pend_len"] = (v["pend_len"]
                                 - ev_pend.astype(jnp.int32))
                defer_p = ev_pend & (plen0 > 1)
                v["dp_k"] = jnp.where(defer_p, node, v["dp_k"])
                v["dp_rid"] = jnp.where(defer_p, rid_p, v["dp_rid"])
                na_on = ev_pend
            rid_na = jnp.where(ev_pend, rid_p, rid_a)
            t_na = t_ev
        else:
            rid_na = rid_a
            t_na = t_arr
            na_on = ev_arr
            if has_churn:
                # an orphan re-enters the node exactly like an
                # arrival, at the orphan event's time; all-down fresh
                # arrivals park instead
                rid_na = jnp.where(ev_orph, rid_o, rid_na)
                t_na = jnp.where(ev_orph, t_ev, t_na)
                na_on = (ev_arr & anyup) | ev_orph
            if has_resil:
                # a retry re-enters the router-picked node exactly
                # like an arrival, at its fire time (all-down retries
                # park instead, like fresh arrivals)
                rid_na = jnp.where(ev_rtry, rid_r, rid_na)
                t_na = jnp.where(ev_rtry, t_ev, t_na)
                na_on = na_on | ((ev_rtry & anyup) if has_churn
                                 else ev_rtry)
        rid_na32 = jnp.asarray(rid_na, jnp.int32)
        if timers:
            # chain every node arrival onto the (node, fn) timer rail
            j_na = ctx.fn_at(rid_na)
            prev_tail = v["la_rid"][jnp.clip(j_na, 0, F - 1)]
            chain = na_on & (prev_tail >= 0)
            v = dict(v)
            v["lw_t_pos"] = jnp.where(chain, prev_tail, v["lw_t_pos"])
            v["lw_t_val"] = jnp.where(chain, rid_na32, v["lw_t_val"])
            ni = _gidx(na_on, j_na, F)
            v["la_rid"] = v["la_rid"].at[ni].set(rid_na32, mode="drop")
            v["arr_cnt"] = v["arr_cnt"].at[ni].add(1, mode="drop")
        progress = ev_slot | ev_timer | ev_arr | ev_rtry
        if has_delay:
            progress = progress | ev_pend
        if has_churn:
            progress = progress | ev_orph | ev_churn
        v = dict(v)
        v["ci"] = v["ci"].at[jnp.array([CI_NEXT, CI_ITERS])].add(
            jnp.stack([ev_arr.astype(jnp.int32),
                       progress.astype(jnp.int32)]))
        v = kernel.on_arrival(ctx, v, rid_na, t_na, na_on)
        if has_delay:
            # raw arrival (and, under churn / resilience, orphan
            # re-route or retry): the routing decision is made
            # (``node`` is the pick) and the request goes in flight to
            # that node
            rid_a32 = jnp.asarray(rid_a, jnp.int32)
            if direct:
                if has_churn:
                    snd_on = (ev_arr & anyup) | ev_orph
                    rid_s = jnp.where(ev_orph, rid_o, rid_a32)
                else:
                    snd_on = ev_arr
                    rid_s = rid_a32
                if has_resil:
                    snd_on = snd_on | ((ev_rtry & anyup) if has_churn
                                       else ev_rtry)
                    rid_s = jnp.where(ev_rtry, rid_r32, rid_s)
                # landing time samples the delay at send time
                kc = jnp.clip(node, 0, K - 1)
                if var_delay:
                    d_snd = _sched_delay(t_ev, dtimes[kc], dvals[kc],
                                         dper[kc])
                else:
                    d_snd = delays[kc]
                ptail = v["pend_tail"]
                pempty = v["pend_len"] == 0
                v = dict(v)
                v["land_t"] = v["land_t"].at[
                    _gidx(snd_on, rid_s, N)].set(t_ev + d_snd,
                                                 mode="drop")
                v["dnx"] = v["dnx"].at[
                    _gidx(snd_on & ~pempty, ptail, N)].set(
                    rid_s, mode="drop")
                v["pend_head"] = jnp.where(snd_on & pempty, rid_s,
                                           v["pend_head"])
                v["pend_tail"] = jnp.where(snd_on, rid_s,
                                           v["pend_tail"])
                v["pend_len"] = (v["pend_len"]
                                 + snd_on.astype(jnp.int32))
            else:
                ptail = v["pend_tail"]
                pempty = v["pend_len"] == 0
                v = dict(v)
                v["pend_head"] = jnp.where(ev_arr & pempty, rid_a32,
                                           v["pend_head"])
                v["lw_d_pos"] = jnp.where(ev_arr & ~pempty, ptail,
                                          v["lw_d_pos"])
                v["lw_d_val"] = jnp.where(ev_arr & ~pempty, rid_a32,
                                          v["lw_d_val"])
                v["pend_tail"] = jnp.where(ev_arr, rid_a32,
                                           v["pend_tail"])
                v["pend_len"] = (v["pend_len"]
                                 + ev_arr.astype(jnp.int32))
        if has_churn:
            # park append — the one code path that grows the FIFO:
            # all-down fresh arrivals / retries, and (under delay)
            # requests landing on a node that died while in flight
            if has_delay:
                park_in = (ev_arr & ~anyup) | (ev_pend & ~node_up)
                rid_pk = jnp.where(ev_pend, rid_p,
                                   jnp.asarray(rid_a, jnp.int32))
            else:
                park_in = ev_arr & ~anyup
                rid_pk = jnp.asarray(rid_a, jnp.int32)
            if has_resil:
                park_in = park_in | (ev_rtry & ~anyup)
                rid_pk = jnp.where(ev_rtry, rid_r32, rid_pk)
            pk_empty = v["park_len"] == 0
            pk_tail = v["park_tail"]
            v = dict(v)
            v["nxt"] = v["nxt"].at[
                _gidx(park_in & ~pk_empty, pk_tail, N)].set(
                rid_pk, mode="drop")
            v["park_head"] = jnp.where(park_in & pk_empty, rid_pk,
                                       v["park_head"])
            v["park_tail"] = jnp.where(park_in, rid_pk,
                                       v["park_tail"])
            v["park_len"] = v["park_len"] + park_in.astype(jnp.int32)
            v["park_t"] = jnp.where(park_in & pk_empty, t_ev,
                                    v["park_t"])
        s = v
        if has_delay and not stream and not direct:
            ki = jnp.where(s["ev_rid"] >= 0, k_step, SG)
            s["d_node"] = s["d_node"].at[ki].set(
                jnp.asarray(node, jnp.int32), mode="drop")

        s = _fold_event(ctx, s)
        s = dict(s)
        if trace:
            # stage this event's trace record (shared by both link
            # modes); non-progress steps park on the SG guard row
            from repro.core.jax_engine import CI_COLD
            from repro.telemetry.rail import (AUX_COLD,
                AUX_FAIL_EXHAUSTED, AUX_FAIL_RETRY, AUX_OVERFLOW,
                AUX_QUEUED, AUX_SHED, AUX_TIMEOUT, TraceKind)
            ci1 = s["ci"]
            dlt = ci1 - ci
            kind = jnp.where(exec_on, TraceKind.EXEC, jnp.where(
                cold_on, TraceKind.COLD, jnp.int32(-1)))
            if timers:
                kind = jnp.where(ev_timer, TraceKind.TIMER, kind)
            if has_churn:
                kind = jnp.where(
                    ev_churn, TraceKind.CHURN,
                    jnp.where(ev_orph, TraceKind.REROUTE, kind))
            if has_resil:
                kind = jnp.where(ev_rtry, TraceKind.RETRY, kind)
            if has_delay:
                kind = jnp.where(ev_pend, TraceKind.NODE_ARRIVAL,
                                 kind)
            kind = jnp.where(ev_arr, TraceKind.ARRIVAL, kind)
            rid_tr = jnp.where(ev_slot,
                               jnp.asarray(rid_done, jnp.int32),
                               jnp.int32(-1))
            if timers:
                rid_tr = jnp.where(ev_timer, rid_t, rid_tr)
            if has_churn:
                rid_tr = jnp.where(
                    ev_orph, jnp.asarray(rid_o, jnp.int32), rid_tr)
            if has_resil:
                rid_tr = jnp.where(ev_rtry, rid_r32, rid_tr)
            if has_delay:
                rid_tr = jnp.where(
                    ev_pend, jnp.asarray(rid_p, jnp.int32), rid_tr)
            rid_tr = jnp.where(ev_arr, jnp.asarray(rid_a, jnp.int32),
                               rid_tr)
            fn_tr = jnp.where(
                ev_slot, j_done,
                jnp.where(rid_tr >= 0,
                          ctx.fn_at(jnp.clip(rid_tr, 0, N - 1)),
                          jnp.int32(-1)))
            fail_i = dlt[CI_FAILED] + dlt[CI_TMO]
            aux_ex = (jnp.where(dlt[CI_EXH] > 0, AUX_FAIL_EXHAUSTED,
                                jnp.where(fail_i > 0, AUX_FAIL_RETRY,
                                          0))
                      + jnp.where(dlt[CI_TMO] > 0, AUX_TIMEOUT, 0))
            aux = (jnp.where(dlt[CI_COLD] > 0, AUX_COLD, 0)
                   + jnp.where(s["q_tot"] > tr_q0, AUX_QUEUED, 0)
                   + jnp.where(dlt[CI_SHED] > 0, AUX_SHED, 0)
                   + jnp.where(dlt[CI_OVF] > 0, AUX_OVERFLOW, 0))
            aux = jnp.where(exec_on, aux_ex, aux)
            if has_churn:
                aux = jnp.where(ev_churn, node_up.astype(jnp.int32),
                                aux)
            busy = ((s["slot_state"] == BUSY) & capm).sum()
            warm = ((s["slot_state"] == IDLE) & (s["slot_fn"] >= 0)
                    & capm).sum()
            rec_i = jnp.stack(
                [kind, rid_tr, fn_tr, jnp.asarray(node, jnp.int32),
                 aux, s["q_tot"], busy, warm,
                 ci1[CI_ITERS]]).astype(jnp.int32)
            rec_f = jnp.stack([t_ev, jnp.where(exec_on, e_done, 0.0)])
            ki_tr = jnp.where(progress, k_step, SG)
            s["tr_i"] = s["tr_i"].at[ki_tr].set(rec_i, mode="drop")
            s["tr_f"] = s["tr_f"].at[ki_tr].set(rec_f, mode="drop")
        if direct:
            # direct-link mode: no overlays to stage, no reads to
            # chase — every link write already hit its rail
            stall = jnp.where(
                active & ~live, 1,
                jnp.where(active & (s["ci"][CI_ITERS] >= max_iters),
                          2, s["ci"][CI_STALL]))
            s["ci"] = s["ci"].at[CI_STALL].set(stall)
            return s
        # stage this event's link writes into the overlay slot (every
        # step overwrites its own slot, so no per-segment reset — a
        # stale entry can only repeat the already-flushed rail value)
        lwp, lwv = s.pop("lw_q_pos"), s.pop("lw_q_val")
        s["ov_q_pos"] = s["ov_q_pos"].at[k_step].set(
            jnp.where(lwp >= 0, lwp, jnp.int32(N)))
        s["ov_q_val"] = s["ov_q_val"].at[k_step].set(lwv)
        if timers:
            ltp, ltv = s.pop("lw_t_pos"), s.pop("lw_t_val")
            s["ov_t_pos"] = s["ov_t_pos"].at[k_step].set(
                jnp.where(ltp >= 0, ltp, jnp.int32(N)))
            s["ov_t_val"] = s["ov_t_val"].at[k_step].set(ltv)
        if has_delay:
            ldp, ldv = s.pop("lw_d_pos"), s.pop("lw_d_val")
            s["ov_d_pos"] = s["ov_d_pos"].at[k_step].set(
                jnp.where(ldp >= 0, ldp, jnp.int32(N)))
            s["ov_d_val"] = s["ov_d_val"].at[k_step].set(ldv)

        # deferred link reads: a push and a pop of the same chain
        # never share an event, so the parked successor lookups can
        # run here — each rail read is a *single-element* gather
        # (cheap even on the vmap batched-operand path; it's full-row
        # batched gathers the design keeps out of the body) and every
        # park register targets the event's own node, so the
        # successor lands in the node's view row and rides the one
        # row commit
        def chase(rail, ov_pos, ov_val, rid):
            m = ov_pos == rid
            ov = ov_val[jnp.argmax(m)]
            return jnp.where(m.any(), ov,
                             rail[jnp.clip(rid, 0, N - 1)])

        pp_kf, pp_rid = s.pop("pp_kf"), s.pop("pp_rid")
        succ = chase(s["nxt"], s["ov_q_pos"], s["ov_q_val"], pp_rid)
        # a deferred pop's successor overrides the parked head write
        # (the pop already set qw_head_pos to the same (node, fn) slot)
        s["qw_head_val"] = jnp.where(pp_kf >= 0, succ,
                                     s["qw_head_val"])
        if timers:
            tp_kf, tp_rid = s.pop("tp_kf"), s.pop("tp_rid")
            tsucc = chase(s["tnx"], s["ov_t_pos"], s["ov_t_val"],
                          tp_rid)
            # ctx.arrival_at is the node-local clock (+delay under
            # has_delay) — the same float association as arming at
            # the head of the rail
            t_fire = ctx.arrival_at(tsucc) + threshold
            ti = _gidx(tp_kf >= 0, tp_kf % F, F)
            s["tmr_rid"] = s["tmr_rid"].at[ti].set(tsucc, mode="drop")
            s["tmr_next"] = s["tmr_next"].at[ti].set(t_fire,
                                                     mode="drop")
        if has_delay:
            dp_k, dp_rid = s.pop("dp_k"), s.pop("dp_rid")
            dsucc = chase(s["dnx"], s["ov_d_pos"], s["ov_d_val"],
                          dp_rid)
            s["pend_head"] = jnp.where(dp_k >= 0, dsucc,
                                       s["pend_head"])
        stall = jnp.where(
            active & ~live, 1,
            jnp.where(active & (s["ci"][CI_ITERS] >= max_iters), 2,
                      s["ci"][CI_STALL]))
        s["ci"] = s["ci"].at[CI_STALL].set(stall)
        return s

    step_lanes = jax.vmap(
        lane_step, in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))

    def cond(s):
        ci = s["ci"]
        return jnp.any((ci[:, done_col] < N) & (ci[:, CI_STALL] == 0))

    def segment(s):
        if not stream and not direct:
            s = dict(s)
            s["d_rid"] = jnp.full((L, SG), N, jnp.int32)
        if trace:
            # clear the trace overlay: non-progress steps leave their
            # slot untouched, so stale rows must read as unused (-1)
            from repro.telemetry.rail import TR_RF, TR_RI
            s = dict(s)
            s["tr_i"] = jnp.full((L, SG, TR_RI), -1, jnp.int32)
            s["tr_f"] = jnp.zeros((L, SG, TR_RF), jnp.float64)

        def step(k_step, s):
            # apply the previous event's parked queue writes before
            # anything reads the queue arrays: with the in-place
            # scatter as each buffer's sole direct user and every
            # later read consuming its output, copy-insertion carries
            # the (L, K, F) queue arrays copy-free (writing them at
            # the end of the step instead costs two full copies per
            # event per array). The final event's registers are never
            # applied — nothing reads the queues after the loop.
            def qw_idx(pos):
                return (jnp.where(pos >= 0, pos // F, K),
                        jnp.where(pos >= 0, pos % F, F))

            s = dict(s)
            if not direct:
                kw, fw = qw_idx(s["qw_len_pos"])
                s["q_len"] = s["q_len"].at[lanes, kw, fw].add(
                    s["qw_len_delta"], mode="drop")
                kw, fw = qw_idx(s["qw_head_pos"])
                s["q_head_rid"] = s["q_head_rid"].at[
                    lanes, kw, fw].set(s["qw_head_val"], mode="drop")
                kw, fw = qw_idx(s["qw_tail_pos"])
                s["q_tail_rid"] = s["q_tail_rid"].at[
                    lanes, kw, fw].set(s["qw_tail_val"], mode="drop")
            ei, t_ev, t_arr = pick_events(s)
            ci = s["ci"]
            live = ((ci[:, done_col] < N) & (ci[:, CI_STALL] == 0)
                    & (t_ev < BIG))
            # the router runs first, read-only: in an arrival event no
            # enabled write precedes the arrival phase, so the state
            # it reads equals the post-slot-phase state of the old
            # two-view spelling bit-for-bit
            rid_a = jnp.minimum(ci[:, CI_NEXT], N - 1)
            if has_churn:
                up = (s["ch_ix"] & 1) == 0
                # the routed request may be the park head (orphan
                # re-route), decided at the orphan event's time
                ev_orph_g = live & (ei == orph_base)
                rid_rt = jnp.where(
                    ev_orph_g, jnp.clip(s["park_head"], 0, N - 1),
                    rid_a)
                t_rt = jnp.where(ev_orph_g, t_ev, t_arr)
            else:
                up = None
                rid_rt, t_rt = rid_a, t_arr
            if has_resil:
                # ... or the retry-rail head, decided at its fire time
                ev_rtry_g = live & (ei == rtry_base)
                rid_rt = jnp.where(
                    ev_rtry_g, jnp.clip(s["r_head"], 0, N - 1),
                    rid_rt)
                t_rt = jnp.where(ev_rtry_g, t_ev, t_rt)
            j_rt = fn_flat[base_n + rid_rt]
            if var_delay:
                delay_now = _sched_delay(
                    jnp.broadcast_to(t_rt[:, None], (L, K)),
                    dt_b, dv_b, dp_b)
            elif has_delay:
                delay_now = delays
            else:
                delay_now = None
            k_route = jnp.clip(
                pick_lanes(s["q_len"], s["q_tot"], s["slot_fn"],
                           s["slot_state"], cap_mask, s["est_sum"],
                           s["est_n"], s["node_gn"], s["node_gsum"],
                           t_cold_l, up, delay_now,
                           s["cbr_until"] if has_breaker else None,
                           j_rt, rid_rt, t_rt), 0, K - 1)
            if has_churn:
                # a router may still name a down node (e.g. every
                # sampled JSQ candidate is down); re-aim at the
                # lowest-id up node — mirrored in the reference
                k_route = jnp.where(
                    jnp.take_along_axis(up, k_route[:, None],
                                        axis=1)[:, 0],
                    k_route, jnp.argmax(up, axis=1).astype(jnp.int32))
            # the event's node: the phases are mutually exclusive, so
            # one view/commit pair serves slot, timer,
            # deferred-arrival and arrival events alike
            ev_slot = live & (ei < n_slot)
            node_s = jnp.clip(jnp.where(ei >= KC, ei - KC, ei),
                              0, KC - 1) // C
            k_ev = jnp.where(ev_slot, node_s, k_route)
            if timers:
                ev_timer = live & (ei >= tmr_base) & (ei < pend_base)
                kf_t = jnp.clip(jnp.where(ei < tmr_base + KF,
                                          ei - tmr_base,
                                          ei - tmr_base - KF),
                                0, KF - 1)
                k_ev = jnp.where(ev_timer, kf_t // F, k_ev)
            if has_delay:
                ev_pend = (live & (ei >= pend_base)
                           & (ei < pend_base + K))
                k_ev = jnp.where(
                    ev_pend, jnp.clip(ei - pend_base, 0, K - 1), k_ev)
            if has_churn:
                ev_churn_g = (live & (ei >= churn_base)
                              & (ei < churn_base + K))
                k_ev = jnp.where(
                    ev_churn_g, jnp.clip(ei - churn_base, 0, K - 1),
                    k_ev)
            v = gather_nodal(s, k_ev)
            if has_churn:
                v["anyup"] = up.any(axis=1)
            capm_node = jnp.take_along_axis(
                cap_mask, k_ev[:, None, None], axis=1)[:, 0]
            v = step_lanes(k_step, v, trace_ix, t_cold_l, t_evict_l,
                           capm_node, beta, ei, t_ev, t_arr, k_ev)
            s = commit_nodal(s, v, k_ev)
            exec_on = ev_slot & (ei < KC)
            if has_resil:
                # only successful completions count toward the
                # per-node tally (the lane body classified them)
                nd_on = s.pop("rs_ok")
            else:
                nd_on = exec_on
            s["node_done"] = s["node_done"].at[
                lanes, jnp.where(nd_on, k_ev, K)].add(
                1, mode="drop")
            return s

        s = lax.fori_loop(0, SG, step, s)
        if trace:
            from repro.telemetry.rail import emit_flush
            emit_flush(s["tr_i"], s["tr_f"])
        if direct:
            # direct-link mode writes every rail in-body; nothing to
            # flush
            return s
        # batch-flush the staged links — the only (L, N) rail writes,
        # paid once per SG events
        s = dict(s)
        s["nxt"] = s["nxt"].at[lane_iota, s["ov_q_pos"]].set(
            s["ov_q_val"], mode="drop")
        if timers:
            s["tnx"] = s["tnx"].at[lane_iota, s["ov_t_pos"]].set(
                s["ov_t_val"], mode="drop")
        if has_delay:
            s["dnx"] = s["dnx"].at[lane_iota, s["ov_d_pos"]].set(
                s["ov_d_val"], mode="drop")
        if not stream:
            s["start"] = s["start"].at[lane_iota, s["d_rid"]].set(
                s["d_start"], mode="drop")
            s["completion"] = s["completion"].at[
                lane_iota, s["d_rid"]].set(s["d_comp"], mode="drop")
            if has_delay:
                s["node_of"] = s["node_of"].at[
                    lane_iota, s["d_rid"]].set(s["d_node"],
                                               mode="drop")
        return s

    final = lax.while_loop(cond, segment, s)
    ci, cf = final["ci"], final["cf"]
    from repro.core.jax_engine import (CF_COLDT, CF_EVICTT, CF_RMAX,
                                       CF_RSUM, CF_SSUM, CI_COLD,
                                       CI_EVICT)
    out = dict(cold_starts=ci[:, CI_COLD], cold_time=cf[:, CF_COLDT],
               evictions=ci[:, CI_EVICT], evict_time=cf[:, CF_EVICTT],
               overflow=ci[:, CI_OVF],
               stalled=ci[:, CI_STALL], n_events=ci[:, CI_ITERS],
               done=ci[:, CI_DONE], node_done=final["node_done"],
               resp_sum=cf[:, CF_RSUM], slow_sum=cf[:, CF_SSUM],
               max_response=cf[:, CF_RMAX], resp_hist=final["hist"])
    if tl_bins:
        out["tl_count"] = final["tl_cnt"]
        out["tl_resp_sum"] = final["tl_resp"]
        out["tl_exec_sum"] = final["tl_exec"]
    if not stream:
        out["start"] = final["start"]
        out["completion"] = final["completion"]
        if has_delay and not direct:
            out["node_of"] = final["node_of"]
    if deadlines is not None:
        out["deadline_miss"] = final["dl_miss"]
    if has_resil:
        out["failed"] = ci[:, CI_FAILED]
        out["timed_out"] = ci[:, CI_TMO]
        out["retried"] = ci[:, CI_RETRY]
        out["shed"] = ci[:, CI_SHED]
        out["failed_exhausted"] = ci[:, CI_EXH]
    if has_breaker:
        out["breaker_trips"] = ci[:, CI_TRIPS]
    return out


@functools.partial(jax.jit,
                   static_argnames=("kernel", "router", "n_nodes",
                                    "n_fns", "capacity", "queue_cap",
                                    "seed", "stream", "tl_bins",
                                    "has_delay", "has_churn",
                                    "var_delay", "seg",
                                    "keep_responses", "resil",
                                    "trace"))
def _cluster_metrics(fn, arr, ex, cold, ev, tix, masks, betas, prior,
                     threshold, delays=None, churn_t=None, dtimes=None,
                     dvals=None, dper=None, deadlines=None,
                     rs_nfail=None, rs_tmo=None, rs_key=None, *,
                     kernel, router, n_nodes, n_fns, capacity,
                     queue_cap, seed=0, stream=True, tl_bins=0,
                     tl_bucket=60.0, has_delay=False, has_churn=False,
                     var_delay=False, seg=0, keep_responses=False,
                     resil=None, trace=False):
    """Cluster counterpart of `jax_engine._sweep_metrics`: lane-batched
    dynamic-router run + on-device metric reduction (same metric
    names, plus ``node_done``). ``delays``/``has_delay`` switch on the
    deferred-arrival rail; exact-mode responses are then measured from
    each request's node-local (delayed) arrival. ``churn_t`` +
    ``has_churn`` switch on the failure rail (responses then measure
    from the *raw* arrival — the user-perceived convention);
    ``dtimes``/``dvals``/``dper`` + ``var_delay`` make the per-node
    delay time-varying; ``deadlines`` (F,) adds the per-function
    ``deadline_miss`` fold (attainment is derived outside jit by
    `repro.core.jax_engine.slo_attainment`, shared by every tier).
    ``rs_nfail``/``rs_tmo``/``rs_key`` + the static ``resil`` tuple
    switch on the resilience layer (failure injection / timeouts /
    retries / shedding — means and quantiles then reduce over the
    successful completions, and responses use the raw-arrival
    convention like churn)."""
    if keep_responses and stream:
        raise ValueError("keep_responses requires stream=False")
    if delays is None:
        delays = jnp.zeros((n_nodes,), jnp.float64)
    out = _simulate_cluster(fn, arr, ex, cold, ev, tix, masks, betas,
                            prior, threshold, delays, churn_t, dtimes,
                            dvals, dper, deadlines, rs_nfail, rs_tmo,
                            rs_key, kernel=kernel,
                            router=router, n_nodes=n_nodes,
                            n_fns=n_fns, capacity=capacity,
                            queue_cap=queue_cap, seed=seed,
                            stream=stream, tl_bins=tl_bins,
                            tl_bucket=tl_bucket, has_delay=has_delay,
                            has_churn=has_churn, var_delay=var_delay,
                            seg=seg, resil=resil, trace=trace)
    N = fn.shape[1]
    if resil is not None:
        # under faults only successes fold into the response sums and
        # per-request records; means/quantiles reduce over those
        denom = jnp.maximum(out["done"], 1).astype(jnp.float64)
    else:
        denom = N
    if stream:
        nq = out["done"][:, None] if resil is not None else N
        p99 = hist_quantile(out["resp_hist"], 0.99, nq,
                            out["max_response"])
    else:
        arr_l = arr[tix]
        if has_churn or resil is not None:
            pass  # raw-arrival convention: completion - arrival
        elif var_delay:
            nof = out["node_of"]
            arr_l = arr_l + _sched_delay(arr_l, dtimes[nof],
                                         dvals[nof], dper[nof])
        elif has_delay:
            arr_l = arr_l + delays[out["node_of"]]
        resp = out["completion"] - arr_l
        if resil is not None:
            # shed / retry-exhausted rids keep completion == -1
            resp = jnp.where(out["completion"] >= 0, resp, jnp.nan)
            p99 = jnp.nanpercentile(resp, 99.0, axis=1)
        else:
            p99 = jnp.percentile(resp, 99.0, axis=1)
    res = dict(mean_response=out["resp_sum"] / denom,
               mean_slowdown=out["slow_sum"] / denom,
               resp_sum=out["resp_sum"],
               slow_sum=out["slow_sum"],
               done=out["done"],
               node_done=out["node_done"],
               p99_response=p99,
               max_response=out["max_response"],
               resp_hist=out["resp_hist"],
               cold_starts=out["cold_starts"],
               cold_time=out["cold_time"],
               evictions=out["evictions"],
               overflow=out["overflow"],
               stalled=out["stalled"])
    if tl_bins:
        res["tl_count"] = out["tl_count"]
        res["tl_resp_sum"] = out["tl_resp_sum"]
        res["tl_exec_sum"] = out["tl_exec_sum"]
    if deadlines is not None:
        res["deadline_miss"] = out["deadline_miss"]
    if resil is not None:
        for key in ("failed", "timed_out", "retried", "shed",
                    "failed_exhausted"):
            res[key] = out[key]
    if "breaker_trips" in out:
        res["breaker_trips"] = out["breaker_trips"]
    if keep_responses:
        res["response"] = resp
    return res


# ---------------------------------------------------------- audit hooks
# Pure metadata for `repro.analysis`; the loops never read it. Each
# entry names a carried array that legitimately scales with the trace
# length N and the reason the cost is accepted (PR 5 documented the
# rid-chain rails as the dynamic tier's one O(N) concession; PR 6 kept
# them while moving everything else onto the segment overlay).
CARRY_RAILS = {
    "nxt": "per-function FIFO successor rid -- runtime routing means "
           "queue membership is only known at dispatch time, so the "
           "queue rail is a linked chain with one i32 link per "
           "request (the segment overlay batches the *writes*; the "
           "links themselves must persist).",
    "tnx": "openwhisk_v2 timer-rail successor rid (same linked-chain "
           "argument as `nxt`, for the per-function re-arm timers).",
    "dnx": "deferred NODE_ARRIVAL rail under net_delay: in-flight "
           "requests ride a time-ordered chain, one i32 link per "
           "request.",
    "land_t": "churn re-route landing time per in-flight rid (f64); "
              "paired with `dnx` when the failure rail is active.",
    "att": "resilience attempt counter per original rid (i32).",
    "rt_t": "resilience retry-eligibility time per rid (f64).",
    "node_of": "exact mode under net_delay records each request's "
               "dispatching node -- an output record, not loop "
               "bookkeeping.",
    "start": "exact-mode per-request dispatch-time record (output).",
    "completion": "exact-mode per-request completion-time record "
                  "(output).",
    "tr_i": "event-trace overlay (trace=True only): one int32 record "
            "per event in an O(SG) segment buffer, flushed to the "
            "host per segment via an ordered io_callback -- never "
            "N-scaling.",
    "tr_f": "event-trace overlay float half (see `tr_i`): per-event "
            "simulation time and execution time, O(SG) carried "
            "state.",
}


def audit_jits():
    """Jitted cluster entry points by name, for `repro.analysis`."""
    return {"simulate_cluster": _simulate_cluster,
            "cluster_metrics": _cluster_metrics}
