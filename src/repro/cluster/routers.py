"""Request routers + the pluggable router registry.

A router decides *which node* of a `repro.cluster.spec.ClusterSpec`
serves each request; the node's own scheduling policy then decides
everything else. Two tiers, mirroring how much state a decision needs:

* `StaticRouter` — the node is a pure function of the trace
  (``assign`` maps the whole arrival stream to node ids in one
  vectorised pre-pass). These run on the **static fast path**
  (`repro.cluster.static`): per-node sub-streams through the
  unmodified single-node engine, streamed metrics merged exactly.
* `DynamicRouter` — the node depends on live cluster state (queue
  depths, warm instances), so ``pick`` is traced into the K-node event
  loop (`repro.cluster.engine`) and runs once per arrival.

`register_router` mirrors `repro.api.register_policy`: external Router
instances join the table under a name and then participate in
`ClusterSpec.router` (and the benchmark CLIs) exactly like the
built-ins.

Randomised routers (``weighted_random`` draws, ``jsq2`` candidate
sampling) use the counter-based `mix32` hash of the request id instead
of a stateful RNG, so a decision depends only on ``(rid, seed)`` — the
JAX engine, the numpy pre-pass and the pure-Python reference simulator
(`repro.cluster.reference`) reproduce each other bit-for-bit.
"""
from __future__ import annotations

import math
from typing import Dict, List

import numpy as np

_M32 = 0xFFFFFFFF
_GOLD = 0x9E3779B9          # seed spreader (golden-ratio constant)
_MIX1, _MIX2 = 0x85EBCA6B, 0xC2B2AE35   # murmur3 fmix32 constants


def mix32_py(x: int, seed: int = 0) -> int:
    """murmur3-style finaliser over ``x ^ spread(seed)`` on Python
    ints — the scalar reference the vectorised variants must match."""
    h = (int(x) ^ ((seed * _GOLD) & _M32)) & _M32
    h ^= h >> 16
    h = (h * _MIX1) & _M32
    h ^= h >> 13
    h = (h * _MIX2) & _M32
    h ^= h >> 16
    return h


def mix32_np(x, seed: int = 0) -> np.ndarray:
    """Vectorised `mix32_py` on a numpy integer array."""
    h = np.asarray(x).astype(np.uint64)
    h = (h ^ ((seed * _GOLD) & _M32)) & _M32
    h ^= h >> np.uint64(16)
    h = (h * _MIX1) & _M32
    h ^= h >> np.uint64(13)
    h = (h * _MIX2) & _M32
    h ^= h >> np.uint64(16)
    return h.astype(np.int64)


def mix32_jax(x, seed: int = 0):
    """Traced `mix32_py` for in-loop routing draws. Stays in uint32
    lanes (x64-independent); callers reduce with ``% K`` and cast."""
    import jax.numpy as jnp
    h = jnp.asarray(x).astype(jnp.uint32)
    h = h ^ jnp.uint32((seed * _GOLD) & _M32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_MIX1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_MIX2)
    h = h ^ (h >> 16)
    return h


class ClusterView:
    """Per-lane snapshot a `DynamicRouter.pick` reads (all arrays are
    one lane's view, node-major): queue depths ``q_len`` (K, F) with
    the carried per-node totals ``q_tot`` (K,) (maintained O(1) per
    event — prefer it to summing ``q_len``), slot rails
    ``slot_fn``/``slot_state`` + ``cap_mask`` (K, C), per-node
    estimator state ``est_sum``/``est_n`` (K, F) with node globals
    ``node_gn``/``node_gsum`` (K,), the function catalogue ``t_cold``
    (F,), the estimator ``prior`` and the static ``n_nodes``/``seed``
    knobs of the ClusterSpec.

    Under churn / time-varying delay the view additionally carries
    ``up`` ((K,) bool — False while a node is down) and ``delay_now``
    ((K,) f64 — the network delay in effect at the decision time).
    Both are ``None`` (python-level, so no-churn jaxprs are unchanged)
    when the spec declares no churn / no delay schedule; routers must
    treat ``None`` as all-up / all-zero. A router may still return a
    down node (e.g. every sampled JSQ candidate is down) — the engine
    and the reference both apply the same correction afterwards,
    re-aiming at the lowest-id up node (all-down arrivals park)."""

    up = None
    delay_now = None
    brk_until = None  # (K,) f64 circuit-breaker open-until times

    def __init__(self, **kw):
        self.__dict__.update(kw)


class Router:
    """Base class: subclass `StaticRouter` or `DynamicRouter`."""

    name = "base"
    dynamic = False


class StaticRouter(Router):
    """Node choice is a pure function of the trace."""

    def assign(self, fn_id: np.ndarray, arrival: np.ndarray,
               spec) -> np.ndarray:
        """(N,) int node ids in [0, spec.n_nodes)."""
        raise NotImplementedError


class DynamicRouter(Router):
    """Node choice reads live cluster state (traced per arrival)."""

    dynamic = True

    def pick(self, g: ClusterView, j, rid, t):
        """Traced node id (i32 scalar) for request ``rid`` of function
        ``j`` arriving at ``t``; ``g`` is this lane's `ClusterView`."""
        raise NotImplementedError


# ------------------------------------------------------- static builtins
class HashRouter(StaticRouter):
    """Function-affinity hashing: every invocation of f_j lands on the
    same node (``mix32(j, seed) % K``), the classic serverless-edge
    sticky routing that maximises warm reuse and accepts imbalance."""

    name = "hash"

    def assign(self, fn_id, arrival, spec):
        return (mix32_np(fn_id, spec.seed)
                % spec.n_nodes).astype(np.int32)


class RoundRobinRouter(StaticRouter):
    """Global round-robin over the arrival sequence — perfect request
    balance, worst-case warm-instance dilution."""

    name = "round_robin"

    def assign(self, fn_id, arrival, spec):
        return (np.arange(len(fn_id), dtype=np.int64)
                % spec.n_nodes).astype(np.int32)


class WeightedRandomRouter(StaticRouter):
    """Seeded weighted-random spread (default uniform): node k drawn
    with probability weight_k / sum(weights) per request id."""

    name = "weighted_random"

    def assign(self, fn_id, arrival, spec):
        w = np.asarray(spec.weights if spec.weights is not None
                       else [1.0] * spec.n_nodes, np.float64)
        cum = np.cumsum(w / w.sum())
        u = (mix32_np(np.arange(len(fn_id)), spec.seed)
             + 0.5) / 2.0 ** 32
        return np.minimum(np.searchsorted(cum, u, side="right"),
                          spec.n_nodes - 1).astype(np.int32)


# ------------------------------------------------------ dynamic builtins
class JSQRouter(DynamicRouter):
    """JSQ(d) / power-of-d-choices: hash-sample ``d`` distinct nodes
    (a partial Fisher-Yates draw over the node ids, one `mix32` swap
    per position, so distinctness holds for every d <= K), send the
    request to the least loaded (load = queued + running; ties keep
    the earliest draw). ``d=2`` is the classic power-of-two-choices
    router."""

    def __init__(self, name: str = "jsq2", d: int = 2):
        self.name = name
        self.d = int(d)

    @staticmethod
    def sample(rid, seed: int, K: int, d: int, mix=mix32_py):
        """Swap positions of the first min(d, K) entries of a partial
        Fisher-Yates shuffle of range(K): position i swaps with
        ``i + mix(rid, seed + i) % (K - i)``. Returns the list of
        (i, j) swap pairs — both the traced and the pure-Python
        routers replay the same pairs, so their candidate sets match
        exactly."""
        return [(i, i + int(mix(rid, seed + i) % (K - i)))
                for i in range(min(d, K))]

    def pick(self, g, j, rid, t):
        import jax.numpy as jnp

        from repro.core.jax_engine import BUSY, I32_MAX
        K = g.n_nodes
        if K == 1:
            return jnp.int32(0)
        load = (g.q_tot
                + ((g.slot_state == BUSY) & g.cap_mask).sum(axis=1))
        if g.up is not None:
            load = jnp.where(g.up, load, I32_MAX)
        nodes = jnp.arange(K, dtype=jnp.int32)
        for i in range(min(self.d, K)):
            jdraw = i + (mix32_jax(rid, g.seed + i)
                         % (K - i)).astype(jnp.int32)
            ni, nj = nodes[i], nodes[jdraw]
            nodes = nodes.at[i].set(nj).at[jdraw].set(ni)
        best = nodes[0]
        for i in range(1, min(self.d, K)):
            cand = nodes[i]
            best = jnp.where(load[cand] < load[best], cand, best)
        return best


class ColdAwareRouter(DynamicRouter):
    """Cold-start-aware routing: score each node by the estimated time
    until this request could start there and take the argmin (ties:
    lowest node id) —

        score_k = [0 if node k has an idle warm instance of f_j,
                   else t_cold(j)]
                + mean_j(k) * queued_j(k)
                + gmean(k) * (queued_total(k) + busy(k))

    where mean_j(k) is node k's running-mean execution estimate of f_j
    (node-global mean, then prior, fallback — the same chain its
    scheduler uses) and gmean(k) the node-global mean. The first term
    is the warm-instance availability; the others weight the backlog
    by the ESFF-style execution estimates."""

    name = "cold_aware"

    def pick(self, g, j, rid, t):
        import jax.numpy as jnp

        from repro.core.jax_engine import BIG
        score = _startability_score(g, j)
        if g.up is not None:
            score = jnp.where(g.up, score, BIG)
        return jnp.argmin(score).astype(jnp.int32)


def _startability_score(g, j):
    """Per-node estimate of the time until a request of fn ``j``
    could start there (traced (K,) f64; shared by `ColdAwareRouter`
    and `SLOAwareRouter` so their backlog term agrees exactly)."""
    import jax.numpy as jnp

    from repro.core.jax_engine import BUSY, IDLE
    jc = jnp.clip(j, 0, g.q_len.shape[1] - 1)
    gn = g.node_gn.astype(jnp.float64)
    gmean = jnp.where(g.node_gn > 0,
                      g.node_gsum / jnp.maximum(gn, 1), g.prior)
    n_j = g.est_n[:, jc]
    mean_j = jnp.where(n_j > 0,
                       g.est_sum[:, jc]
                       / jnp.maximum(n_j.astype(jnp.float64), 1),
                       gmean)
    own = (g.slot_fn == jc) & g.cap_mask
    has_idle = (own & (g.slot_state == IDLE)).any(axis=1)
    busy = ((g.slot_state == BUSY) & g.cap_mask).sum(axis=1)
    qtot = g.q_tot
    return (jnp.where(has_idle, 0.0, g.t_cold[jc])
            + mean_j * g.q_len[:, jc]
            + gmean * (qtot + busy))


class SLOAwareRouter(DynamicRouter):
    """SLO-attainment routing: predicted response on node k is the
    current network delay plus the cold-aware startability estimate

        pred_k = delay_now(k) + score_k(cold_aware)

    and the request goes to the argmin over *up* nodes (ties: lowest
    node id). With no delay schedule and no churn this degrades to
    exactly `cold_aware`; under churn it is the only built-in that
    also discounts nodes whose link is currently slow (the LEO /
    mobile-edge case the churn rail models)."""

    name = "slo_aware"

    def pick(self, g, j, rid, t):
        import jax.numpy as jnp

        from repro.core.jax_engine import BIG
        score = _startability_score(g, j)
        if g.delay_now is not None:
            score = score + g.delay_now
        if g.up is not None:
            score = jnp.where(g.up, score, BIG)
        return jnp.argmin(score).astype(jnp.int32)


class BreakerRouter(DynamicRouter):
    """Circuit-breaker wrapper around another dynamic router.

    Per node, completed attempts are counted in tumbling windows of
    ``volume``; when a full window's failure/timeout count reaches
    ``ceil(threshold * volume)`` the breaker *trips*: the node stops
    receiving routed requests for ``cooldown`` seconds. After the
    cooldown the node is *half-open* — it is routable again, and the
    first attempt that completes on it decides: success closes the
    breaker (counters reset), failure re-trips it for another cooldown.
    If every candidate node is tripped the breaker fails open (routes
    as if no breaker existed) so requests are never lost to the wrapper
    itself. The trip state lives in the cluster engine
    (``brk_until`` — 0 when closed, the reopen time while open) and is
    mirrored exactly by the Python reference cluster.

    Without a failure source (``fail_prob`` / ``timeouts``) the breaker
    never trips and the wrapper degrades to its inner router.
    """

    def __init__(self, inner: "DynamicRouter", name: str = "breaker", *,
                 threshold: float = 0.5, volume: int = 20,
                 cooldown: float = 30.0):
        if not isinstance(inner, DynamicRouter):
            raise TypeError(
                "BreakerRouter wraps a DynamicRouter instance, got "
                f"{type(inner).__name__}")
        if not (0.0 < threshold <= 1.0):
            raise ValueError("BreakerRouter threshold must be in (0, 1]")
        if volume < 1 or cooldown <= 0:
            raise ValueError(
                "BreakerRouter needs volume >= 1 and cooldown > 0")
        self.inner = inner
        self.name = name
        self.threshold = float(threshold)
        self.volume = int(volume)
        self.cooldown = float(cooldown)
        # integer trip point: a full window trips iff fails >= trip_at
        self.trip_at = max(1, int(math.ceil(self.volume * self.threshold)))

    def pick(self, g, j, rid, t):
        import jax.numpy as jnp
        ok = g.brk_until <= t
        base_up = (g.up if g.up is not None
                   else jnp.ones(g.n_nodes, dtype=bool))
        eff = base_up & ok
        eff = jnp.where(eff.any(), eff, base_up)  # fail open
        g2 = ClusterView(**{**g.__dict__, "up": eff})
        return self.inner.pick(g2, j, rid, t)


# --------------------------------------------------------------- registry
ROUTERS: Dict[str, Router] = {
    "hash": HashRouter(),
    "round_robin": RoundRobinRouter(),
    "weighted_random": WeightedRandomRouter(),
    "jsq2": JSQRouter("jsq2", d=2),
    "cold_aware": ColdAwareRouter(),
    "slo_aware": SLOAwareRouter(),
    "breaker": BreakerRouter(JSQRouter("jsq2", d=2)),
}


def available_routers() -> List[str]:
    """Registered router names (built-ins + `register_router` adds)."""
    return sorted(ROUTERS)


def get_router(name: str) -> Router:
    """Router registered under ``name`` (KeyError lists what exists)."""
    try:
        return ROUTERS[name]
    except KeyError:
        raise KeyError(
            f"unknown router {name!r}; registered routers: "
            f"{sorted(ROUTERS)} (add your own with "
            "repro.api.register_router)") from None


def register_router(name: str, router: Router, *,
                    replace: bool = False) -> Router:
    """Register a `Router` instance under ``name`` (mirrors
    `repro.api.register_policy`).

    The instance must be a singleton the caller keeps stable: the
    cluster engine jit-caches per router *identity*. ``replace=True``
    allows overwriting an existing name deliberately. Returns
    ``router`` for one-liner use.
    """
    if not isinstance(router, Router):
        raise TypeError(
            f"register_router({name!r}): expected a Router *instance* "
            f"(got {type(router).__name__}); subclass "
            "repro.cluster.routers.StaticRouter or DynamicRouter and "
            "pass an instance")
    if not name or not isinstance(name, str):
        raise ValueError("register_router: name must be a non-empty "
                         "string")
    if name in ROUTERS and not replace:
        raise ValueError(
            f"register_router: router {name!r} is already registered "
            f"(to {type(ROUTERS[name]).__name__}); pass replace=True "
            "to overwrite deliberately")
    ROUTERS[name] = router
    return router


def unregister_router(name: str) -> None:
    """Remove a registered router (primarily for test cleanup)."""
    if name not in ROUTERS:
        raise KeyError(f"unregister_router: {name!r} is not registered")
    del ROUTERS[name]
