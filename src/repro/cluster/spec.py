"""`ClusterSpec` — one declared multi-node edge cluster topology.

The paper schedules functions on a *single* resource-limited edge
server; real edge deployments (LaSS-style) are K small nodes behind a
request router. A `ClusterSpec` declares that topology — node count,
per-node slot capacities (heterogeneity), the routing policy and its
knobs — as one frozen value that rides the `repro.api.ExperimentSpec`
``cluster`` axis exactly like a policy name rides the policy axis.

Two execution tiers implement a spec (see docs/cluster.md):

* **static routers** (`hash` / `round_robin` / `weighted_random`) fix
  each request's node from the trace alone, so the runner partitions
  the arrival stream into per-node sub-streams as a vectorised
  pre-pass and runs them through the unmodified single-node engine
  (`repro.cluster.static`), merging streamed metrics exactly;
* **dynamic routers** (`jsq2` / `cold_aware`) read cluster state at
  each arrival, so they fold into a generalised K-node event loop
  (`repro.cluster.engine`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union


@dataclass(frozen=True)
class ClusterSpec:
    """K heterogeneous edge nodes behind one request router.

    ``n_nodes``       K — how many nodes the cluster has.
    ``router``        a name registered in `repro.cluster.routers`
                      (built-ins: ``hash``, ``round_robin``,
                      ``weighted_random`` static; ``jsq2``,
                      ``cold_aware`` dynamic).
    ``node_capacity`` per-node slot counts (length K) for heterogeneous
                      nodes / fixed-aggregate scale-out studies. When
                      set it overrides the spec's capacity axis (which
                      must then have exactly one entry, kept as the
                      row label); ``None`` gives every node the
                      capacity-axis value.
    ``net_delay``     per-node network delay (seconds; scalar or
                      length-K tuple) added to each routed request's
                      arrival before it reaches its node. On the
                      dynamic tier the router still decides at the raw
                      arrival; the request then rides the deferred
                      in-flight event rail to its node (see
                      docs/cluster.md).
    ``seed``          the deterministic hash seed of the randomised
                      routers (``weighted_random`` sampling, ``jsq2``
                      candidate draws).
    ``weights``       relative node weights for ``weighted_random``
                      (length K; defaults to uniform).
    """

    n_nodes: int = 2
    router: str = "hash"
    node_capacity: Optional[Tuple[int, ...]] = None
    net_delay: Union[float, Tuple[float, ...]] = 0.0
    seed: int = 0
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        object.__setattr__(self, "n_nodes", int(self.n_nodes))
        if self.node_capacity is not None:
            object.__setattr__(
                self, "node_capacity",
                tuple(int(c) for c in self.node_capacity))
        if not isinstance(self.net_delay, (int, float)):
            object.__setattr__(
                self, "net_delay",
                tuple(float(d) for d in self.net_delay))
        else:
            object.__setattr__(self, "net_delay", float(self.net_delay))
        if self.weights is not None:
            object.__setattr__(
                self, "weights", tuple(float(w) for w in self.weights))

    # ---------------------------------------------------------- helpers
    @property
    def label(self) -> str:
        """Coordinate label on the ResultSet cluster axis, router
        first: ``jsq2:K4``, ``hash:K2x[8,4]``, ``rr:K2+d``."""
        tag = f"{self.router}:K{self.n_nodes}"
        if self.node_capacity is not None:
            caps = set(self.node_capacity)
            tag += (f"x{self.node_capacity[0]}" if len(caps) == 1
                    else "x" + ",".join(map(str, self.node_capacity)))
        if self.delays() and any(self.delays()):
            tag += "+d"
        return tag

    def delays(self) -> Tuple[float, ...]:
        """Per-node network delays, expanded to length K."""
        if isinstance(self.net_delay, tuple):
            return self.net_delay
        return (self.net_delay,) * self.n_nodes

    def node_caps(self, capacity: int) -> Tuple[int, ...]:
        """Per-node slot counts given the capacity-axis value."""
        if self.node_capacity is not None:
            return self.node_capacity
        return (int(capacity),) * self.n_nodes

    def get_router(self):
        from repro.cluster.routers import get_router
        return get_router(self.router)

    def validate(self) -> "ClusterSpec":
        """Raise with a precise message on the first bad field;
        returns self for chaining."""
        if self.n_nodes < 1:
            raise ValueError(
                f"ClusterSpec: n_nodes must be >= 1, got {self.n_nodes}")
        router = self.get_router()      # KeyError lists registered
        if self.node_capacity is not None:
            if len(self.node_capacity) != self.n_nodes:
                raise ValueError(
                    f"ClusterSpec: node_capacity has "
                    f"{len(self.node_capacity)} entries for "
                    f"{self.n_nodes} nodes")
            if any(c < 1 for c in self.node_capacity):
                raise ValueError(
                    f"ClusterSpec: node capacities must be positive, "
                    f"got {self.node_capacity}")
        d = self.delays()
        if len(d) != self.n_nodes:
            raise ValueError(
                f"ClusterSpec: net_delay has {len(d)} entries for "
                f"{self.n_nodes} nodes")
        if any(x < 0 for x in d):
            raise ValueError(
                f"ClusterSpec: net_delay must be >= 0, got {d}")
        if self.weights is not None:
            if len(self.weights) != self.n_nodes:
                raise ValueError(
                    f"ClusterSpec: weights has {len(self.weights)} "
                    f"entries for {self.n_nodes} nodes")
            if any(w <= 0 for w in self.weights):
                raise ValueError(
                    f"ClusterSpec: weights must be positive, got "
                    f"{self.weights}")
        return self
