"""`ClusterSpec` — one declared multi-node edge cluster topology.

The paper schedules functions on a *single* resource-limited edge
server; real edge deployments (LaSS-style) are K small nodes behind a
request router. A `ClusterSpec` declares that topology — node count,
per-node slot capacities (heterogeneity), the routing policy and its
knobs — as one frozen value that rides the `repro.api.ExperimentSpec`
``cluster`` axis exactly like a policy name rides the policy axis.

Two execution tiers implement a spec (see docs/cluster.md):

* **static routers** (`hash` / `round_robin` / `weighted_random`) fix
  each request's node from the trace alone, so the runner partitions
  the arrival stream into per-node sub-streams as a vectorised
  pre-pass and runs them through the unmodified single-node engine
  (`repro.cluster.static`), merging streamed metrics exactly;
* **dynamic routers** (`jsq2` / `cold_aware` / `slo_aware`) read
  cluster state at each arrival, so they fold into a generalised
  K-node event loop (`repro.cluster.engine`).

Robustness axis (PR 7): a spec may also declare per-node *churn*
(availability windows — explicit ``(down_at, up_at)`` lists or a
`PeriodicChurn` generator, the Komet-style LEO case) and a
time-varying per-node network delay (`DelaySchedule`). Both lower
onto the dynamic tier only; the static tier rejects them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple, Union


def _bad(field: str, msg: str):
    raise ValueError(f"ClusterSpec.{field}: {msg}")


@dataclass(frozen=True)
class PeriodicChurn:
    """Periodic availability generator for one node (LEO-satellite
    style): the node repeats a cycle of length ``period`` seconds and
    is **up** for the first ``duty`` fraction of each cycle; the whole
    pattern is shifted by ``phase`` seconds (up intervals are
    ``[phase + n*period, phase + n*period + duty*period)``).
    ``duty=1.0`` means always up (no churn events are generated)."""

    period: float
    duty: float = 0.5
    phase: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "period", float(self.period))
        object.__setattr__(self, "duty", float(self.duty))
        object.__setattr__(self, "phase", float(self.phase))

    def validate(self, field: str = "churn"):
        if not math.isfinite(self.period) or self.period <= 0:
            _bad(field, f"PeriodicChurn.period must be finite and > 0, "
                        f"got {self.period}")
        if math.isnan(self.duty) or not 0.0 < self.duty <= 1.0:
            _bad(field, f"PeriodicChurn.duty must be in (0, 1], got "
                        f"{self.duty}")
        if not math.isfinite(self.phase):
            _bad(field, f"PeriodicChurn.phase must be finite, got "
                        f"{self.phase}")

    def toggles(self, horizon: float) -> Tuple[float, ...]:
        """Alternating (down, up, down, ...) toggle times in
        ``[0, horizon]``; a node that would end the horizon down gets
        its natural next up appended so parked work always recovers."""
        if self.duty >= 1.0:
            return ()
        P, d, ph = self.period, self.duty, self.phase
        # generate (time, is_up) edges from one full cycle before t=0
        n = math.floor((0.0 - ph) / P) - 1
        edges = []
        while True:
            up_at = ph + n * P
            edges.append((up_at, True))
            edges.append((up_at + d * P, False))
            if up_at > horizon:
                break
            n += 1
        # state at t=0: the last edge at time <= 0 decides (the
        # generator always emits one)
        up0 = True
        for t, is_up in edges:
            if t <= 0.0:
                up0 = is_up
        toggles = [] if up0 else [0.0]
        for t, is_up in edges:
            if t <= 0.0 or t > horizon:
                continue
            want_down = len(toggles) % 2 == 0   # next toggle goes down
            if is_up != (not want_down):
                continue                        # duplicate of t=0 state
            toggles.append(t)
        if len(toggles) % 2 == 1:               # ends down: append the
            last = toggles[-1]                  # next up after `last`
            k = math.ceil((last - ph) / P - 1e-12)
            up_next = ph + k * P
            while up_next <= last:
                up_next += P
            toggles.append(up_next)
        return tuple(toggles)


@dataclass(frozen=True)
class DelaySchedule:
    """Piecewise-constant (optionally periodic) per-node network
    delay: ``values[i]`` applies on ``[times[i], times[i+1])``;
    ``times[0]`` must be 0. With ``period > 0`` the schedule wraps
    (lookup at ``t % period``), the LEO orbital-latency case."""

    times: Tuple[float, ...]
    values: Tuple[float, ...]
    period: float = 0.0

    def __post_init__(self):
        object.__setattr__(self, "times",
                           tuple(float(t) for t in self.times))
        object.__setattr__(self, "values",
                           tuple(float(v) for v in self.values))
        object.__setattr__(self, "period", float(self.period))

    def validate(self, field: str = "delay_schedule"):
        if not self.times or len(self.times) != len(self.values):
            _bad(field, f"DelaySchedule needs matching non-empty "
                        f"times/values, got {len(self.times)} times "
                        f"and {len(self.values)} values")
        if self.times[0] != 0.0:
            _bad(field, f"DelaySchedule.times must start at 0, got "
                        f"{self.times[0]}")
        for a, b in zip(self.times, self.times[1:]):
            if not a < b:
                _bad(field, f"DelaySchedule.times must be strictly "
                            f"increasing, got {self.times}")
        if any(not math.isfinite(t) for t in self.times):
            _bad(field, f"DelaySchedule.times must be finite, got "
                        f"{self.times}")
        for v in self.values:
            if math.isnan(v) or v < 0 or math.isinf(v):
                _bad(field, f"DelaySchedule values must be finite and "
                            f">= 0, got {self.values}")
        if math.isnan(self.period) or self.period < 0:
            _bad(field, f"DelaySchedule.period must be >= 0, got "
                        f"{self.period}")
        if self.period > 0 and self.times[-1] >= self.period:
            _bad(field, f"DelaySchedule.times must stay below the "
                        f"period ({self.period}), got {self.times}")

    def at(self, t: float) -> float:
        """Delay in effect at time ``t`` (plain-Python mirror of the
        engine's rail lookup)."""
        tt = t % self.period if self.period > 0 else t
        i = 0
        for j, s in enumerate(self.times):
            if tt >= s:
                i = j
        return self.values[i]


ChurnEntry = Union[None, PeriodicChurn, Tuple[Tuple[float, float], ...]]


@dataclass(frozen=True)
class ClusterSpec:
    """K heterogeneous edge nodes behind one request router.

    ``n_nodes``       K — how many nodes the cluster has.
    ``router``        a name registered in `repro.cluster.routers`
                      (built-ins: ``hash``, ``round_robin``,
                      ``weighted_random`` static; ``jsq2``,
                      ``cold_aware``, ``slo_aware`` dynamic).
    ``node_capacity`` per-node slot counts (length K) for heterogeneous
                      nodes / fixed-aggregate scale-out studies. When
                      set it overrides the spec's capacity axis (which
                      must then have exactly one entry, kept as the
                      row label); ``None`` gives every node the
                      capacity-axis value.
    ``net_delay``     per-node network delay (seconds; scalar or
                      length-K tuple) added to each routed request's
                      arrival before it reaches its node. On the
                      dynamic tier the router still decides at the raw
                      arrival; the request then rides the deferred
                      in-flight event rail to its node (see
                      docs/cluster.md).
    ``delay_schedule``time-varying override of ``net_delay``: a
                      `DelaySchedule` (broadcast to all nodes) or a
                      length-K tuple of ``DelaySchedule | None``
                      (``None`` keeps that node's constant delay).
                      Dynamic tier only.
    ``churn``         per-node availability: ``None`` (always up), a
                      `PeriodicChurn` (broadcast), or a length-K tuple
                      whose entries are ``None``, a `PeriodicChurn`,
                      or an explicit tuple of ``(down_at, up_at)``
                      windows. Dynamic tier only; see docs/cluster.md
                      "Churn, failures & SLOs".
    ``seed``          the deterministic hash seed of the randomised
                      routers (``weighted_random`` sampling, ``jsq2``
                      candidate draws).
    ``weights``       relative node weights for ``weighted_random``
                      (length K; defaults to uniform).
    """

    n_nodes: int = 2
    router: str = "hash"
    node_capacity: Optional[Tuple[int, ...]] = None
    net_delay: Union[float, Tuple[float, ...]] = 0.0
    seed: int = 0
    weights: Optional[Tuple[float, ...]] = None
    churn: Union[None, PeriodicChurn, Tuple[ChurnEntry, ...]] = None
    delay_schedule: Union[None, DelaySchedule,
                          Tuple[Optional[DelaySchedule], ...]] = None

    def __post_init__(self):
        object.__setattr__(self, "n_nodes", int(self.n_nodes))
        if self.node_capacity is not None:
            object.__setattr__(
                self, "node_capacity",
                tuple(int(c) for c in self.node_capacity))
        if not isinstance(self.net_delay, (int, float)):
            object.__setattr__(
                self, "net_delay",
                tuple(float(d) for d in self.net_delay))
        else:
            object.__setattr__(self, "net_delay", float(self.net_delay))
        if self.weights is not None:
            object.__setattr__(
                self, "weights", tuple(float(w) for w in self.weights))
        if self.churn is not None:
            if isinstance(self.churn, PeriodicChurn):
                object.__setattr__(
                    self, "churn", (self.churn,) * self.n_nodes)
            else:
                object.__setattr__(
                    self, "churn",
                    tuple(self._norm_churn_entry(e) for e in self.churn))
        if isinstance(self.delay_schedule, DelaySchedule):
            object.__setattr__(
                self, "delay_schedule",
                (self.delay_schedule,) * self.n_nodes)
        elif self.delay_schedule is not None:
            object.__setattr__(
                self, "delay_schedule", tuple(self.delay_schedule))

    @staticmethod
    def _norm_churn_entry(e) -> ChurnEntry:
        if e is None or isinstance(e, PeriodicChurn):
            return e
        return tuple((float(d), float(u)) for d, u in e)

    # ---------------------------------------------------------- helpers
    @property
    def label(self) -> str:
        """Coordinate label on the ResultSet cluster axis, router
        first: ``jsq2:K4``, ``hash:K2x[8,4]``, ``rr:K2+d``,
        ``slo_aware:K4+churn``."""
        tag = f"{self.router}:K{self.n_nodes}"
        if self.node_capacity is not None:
            caps = set(self.node_capacity)
            tag += (f"x{self.node_capacity[0]}" if len(caps) == 1
                    else "x" + ",".join(map(str, self.node_capacity)))
        if self.delay_ops() is not None:
            tag += "+dvar"
        elif self.delays() and any(self.delays()):
            tag += "+d"
        if self.has_churn():
            tag += "+churn"
        return tag

    def delays(self) -> Tuple[float, ...]:
        """Per-node *constant* network delays, expanded to length K.
        A node whose `DelaySchedule` is effectively constant (a single
        step) folds into this tuple; genuinely time-varying nodes keep
        their base constant here and are overridden by `delay_ops`."""
        if isinstance(self.net_delay, tuple):
            base = list(self.net_delay)
        else:
            base = [self.net_delay] * self.n_nodes
        if self.delay_schedule is not None:
            for k, ds in enumerate(self.delay_schedule):
                if ds is not None and len(ds.values) == 1 \
                        and k < len(base):
                    base[k] = ds.values[0]
        return tuple(base)

    def delay_ops(self):
        """Lower the time-varying delay schedules to padded numpy
        operands ``(dtimes (K,D), dvals (K,D), dper (K,))`` for the
        dynamic engine, or ``None`` when every node is effectively
        constant. Nodes without a (non-trivial) schedule get a
        single-step row holding their constant delay."""
        if self.delay_schedule is None:
            return None
        if not any(ds is not None and len(ds.values) > 1
                   for ds in self.delay_schedule):
            return None
        import numpy as np
        from repro.core.jax_engine import BIG
        consts = self.delays()
        D = max(len(ds.times) if ds is not None else 1
                for ds in self.delay_schedule)
        dtimes = np.full((self.n_nodes, D), BIG, dtype=np.float64)
        dvals = np.zeros((self.n_nodes, D), dtype=np.float64)
        dper = np.zeros((self.n_nodes,), dtype=np.float64)
        for k in range(self.n_nodes):
            ds = self.delay_schedule[k]
            if ds is None or len(ds.values) == 1:
                dtimes[k, 0] = 0.0
                dvals[k, :] = consts[k]
                continue
            n = len(ds.times)
            dtimes[k, :n] = ds.times
            dvals[k, :n] = ds.values
            dvals[k, n:] = ds.values[-1]
            dper[k] = ds.period
        return dtimes, dvals, dper

    def has_churn(self) -> bool:
        """True when any node declares a non-trivial availability
        pattern (a `PeriodicChurn` with ``duty < 1`` or a non-empty
        explicit window list). Horizon-independent; the runner still
        lowers to the plain dynamic loop when the expanded toggle list
        is empty for the actual trace horizon."""
        if self.churn is None:
            return False
        for e in self.churn:
            if e is None:
                continue
            if isinstance(e, PeriodicChurn):
                if e.duty < 1.0:
                    return True
            elif len(e) > 0:
                return True
        return False

    def churn_toggles(self, horizon: float) -> Tuple[Tuple[float, ...],
                                                     ...]:
        """Per-node alternating toggle times (even index: node goes
        DOWN, odd: comes back UP; every node starts up unless its
        first toggle is at 0.0). The one canonical expansion — both
        the JAX engine and the Python reference consume exactly this,
        so churn timestamps agree bitwise across the two."""
        out = []
        for k in range(self.n_nodes):
            e = None if self.churn is None else self.churn[k]
            if e is None:
                out.append(())
            elif isinstance(e, PeriodicChurn):
                out.append(e.toggles(horizon))
            else:
                t = []
                for down, up in e:
                    t.append(down)
                    t.append(up)
                out.append(tuple(t))
        return tuple(out)

    def churn_operand(self, horizon: float):
        """Lower the availability schedule to the dynamic engine's
        (K, E) BIG-padded toggle-time operand (>= 1 all-BIG trailing
        column so the per-node cursor can rest past its last toggle),
        or ``None`` when the schedule is trivial for this horizon —
        the run then takes the plain no-churn loop, bitwise unchanged.

        Lives next to `delay_ops` so every engine-boundary operand the
        spec lowers is built here, explicitly ``float64`` — the dtype
        gate in `repro.analysis` audits these lowerings directly."""
        import numpy as np
        from repro.core.jax_engine import BIG
        toggles = self.churn_toggles(horizon)
        if not any(len(t) for t in toggles):
            return None
        E = max(len(t) for t in toggles) + 1
        churn_t = np.full((self.n_nodes, E), BIG, np.float64)
        for k, tg in enumerate(toggles):
            churn_t[k, : len(tg)] = tg
        return churn_t

    def node_caps(self, capacity: int) -> Tuple[int, ...]:
        """Per-node slot counts given the capacity-axis value."""
        if self.node_capacity is not None:
            return self.node_capacity
        return (int(capacity),) * self.n_nodes

    def get_router(self):
        from repro.cluster.routers import get_router
        return get_router(self.router)

    def validate(self) -> "ClusterSpec":
        """Raise with a precise message on the first bad field;
        returns self for chaining."""
        if self.n_nodes < 1:
            raise ValueError(
                f"ClusterSpec: n_nodes must be >= 1, got {self.n_nodes}")
        router = self.get_router()      # KeyError lists registered
        if self.node_capacity is not None:
            if len(self.node_capacity) != self.n_nodes:
                raise ValueError(
                    f"ClusterSpec: node_capacity has "
                    f"{len(self.node_capacity)} entries for "
                    f"{self.n_nodes} nodes")
            if any(c <= 0 for c in self.node_capacity):
                _bad("node_capacity",
                     f"node capacities must be > 0, got "
                     f"{self.node_capacity}")
        raw = (self.net_delay if isinstance(self.net_delay, tuple)
               else (self.net_delay,) * self.n_nodes)
        if len(raw) != self.n_nodes:
            raise ValueError(
                f"ClusterSpec: net_delay has {len(raw)} entries for "
                f"{self.n_nodes} nodes")
        for k, x in enumerate(raw):
            if math.isnan(x):
                _bad("net_delay", f"entry {k} is NaN")
            if x < 0 or math.isinf(x):
                _bad("net_delay",
                     f"entry {k} must be finite and >= 0, got {x}")
        if self.delay_schedule is not None:
            if len(self.delay_schedule) != self.n_nodes:
                _bad("delay_schedule",
                     f"has {len(self.delay_schedule)} entries for "
                     f"{self.n_nodes} nodes")
            for k, ds in enumerate(self.delay_schedule):
                if ds is None:
                    continue
                if not isinstance(ds, DelaySchedule):
                    raise TypeError(
                        f"ClusterSpec.delay_schedule: entry {k} must "
                        f"be DelaySchedule or None, got "
                        f"{type(ds).__name__}")
                ds.validate(f"delay_schedule[{k}]")
        if self.churn is not None:
            if len(self.churn) != self.n_nodes:
                _bad("churn", f"has {len(self.churn)} entries for "
                              f"{self.n_nodes} nodes")
            for k, e in enumerate(self.churn):
                self._validate_churn_entry(k, e)
        if self.weights is not None:
            if len(self.weights) != self.n_nodes:
                raise ValueError(
                    f"ClusterSpec: weights has {len(self.weights)} "
                    f"entries for {self.n_nodes} nodes")
            if any(w <= 0 for w in self.weights):
                raise ValueError(
                    f"ClusterSpec: weights must be positive, got "
                    f"{self.weights}")
        return self

    @staticmethod
    def _validate_churn_entry(k: int, e: ChurnEntry):
        field = f"churn[{k}]"
        if e is None:
            return
        if isinstance(e, PeriodicChurn):
            e.validate(field)
            return
        prev_up = None
        for i, win in enumerate(e):
            if len(win) != 2:
                _bad(field, f"window {i} must be (down_at, up_at), "
                            f"got {win}")
            down, up = win
            if math.isnan(down) or math.isnan(up):
                _bad(field, f"window {i} contains NaN: {win}")
            if not (0.0 <= down < up) or math.isinf(up):
                _bad(field, f"window {i} needs 0 <= down_at < up_at "
                            f"< inf, got {win}")
            if prev_up is not None and down <= prev_up:
                _bad(field, f"windows must be strictly increasing and "
                            f"non-overlapping; window {i} starts at "
                            f"{down} but the previous window ends at "
                            f"{prev_up}")
            prev_up = up
