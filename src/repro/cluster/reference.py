"""Straightforward Python reference cluster: router + K event engines.

The trustworthy-but-slow baseline the vectorised cluster paths are
parity-tested against (tests/test_cluster.py): K completely ordinary
single-node simulations — each node is its own
`repro.core.server.EdgeServer` + `ExecTimeEstimator` + event-driven
policy instance, untouched — sharing **one** global `EventQueue`, so
simultaneous events interleave across nodes exactly like the paper's
single-server engine orders them (EXEC_DONE < COLD_DONE < TIMER <
NODE_ARRIVAL < REROUTE < CHURN < ARRIVAL, FIFO within a kind). At
each ARRIVAL the router picks the node from live global state using
the *same arithmetic* (same `mix32` draws, same score formula, same
first-argmin tie-break) as the traced routers in
`repro.cluster.routers`, then hands the request to that node's policy.

Churn (docs/cluster.md) is mirrored with two extra event kinds driven
by the spec's canonical `churn_toggles` expansion: a CHURN toggle on
an up node drains it — requests running on it (sorted by request id)
then its queued requests (function-major, FIFO within a function)
re-enter the router as REROUTE events at the failure instant, every
instance dies (cold state lost; the execution-time estimator, which
lives router-side, persists) — while a toggle on a down node re-emits
any parked requests. A request is *parked* whenever it needs a node
and none is up (fresh arrival, re-route, or a deferred delivery
landing on a down node with no alternative); parked requests replay
in FIFO order at the next NODE_UP. Routers see an ``up`` mask and
may still name a down node (e.g. every sampled JSQ candidate is
down); the same lowest-id-up correction as the engine then applies.
Under churn the response convention switches to completion minus the
*raw* arrival (the delivery leg may be paid several times; see
docs/cluster.md), matching the engine's fold-at-EXEC_DONE.

The resilience layer (docs/cluster.md) is mirrored with the shared
pre-planned outcomes of `repro.core.resilience.plan_outcomes`: the
effective execution time (``min(exec, timeout)``) is substituted into
the requests, and at each EXEC_DONE the attempt counter decides
success (``attempt > n_fail``). A failed attempt frees its slot like a
success but erases the completion; if budget remains it re-enters
after ``backoff_py`` through a FIFO retry rail (head-armed RETRY
events, no overtaking — exactly the engine's rid-chain rail; one rail
per node on the static tier, one cluster-global rail on the dynamic
tier). ``queue_cap`` + ``on_overflow`` reproduce the admission-control
modes post-hoc: when an admitted request leaves a per-function queue
longer than the cap, ``shed`` removes the newcomer and ``shed_oldest``
the queue head (terminal, counted ``shed``), while ``error`` keeps the
legacy drop-and-count-overflow behaviour. A `BreakerRouter` keeps
per-node (count, failures, open-until) windows updated at EXEC_DONE
with the engine's exact closed / half-open / open transitions.

Nodes only interact through the router, so any cross-node ordering of
same-time non-arrival events is immaterial — which is what makes this
composition a faithful reference for the JAX loop's node-major
tie-breaking.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Optional, Sequence

import numpy as np

from repro.cluster.routers import (BreakerRouter, DynamicRouter,
                                   JSQRouter, SLOAwareRouter)
from repro.cluster.spec import ClusterSpec
from repro.core.events import EventKind, EventQueue
from repro.core.policy import POLICIES
from repro.core.request import Trace
from repro.core.server import (EdgeServer, ExecTimeEstimator,
                               InstanceState)

_I32_MAX = 2**31 - 1
_BIG = 1e30


def _queues(policy) -> dict:
    """The per-function waiting deques, whatever the policy calls
    them (`queues` for per-function-queue policies, `fifo` for the
    central-queue family)."""
    if hasattr(policy, "queues"):
        return policy.queues
    if hasattr(policy, "fifo"):
        return policy.fifo
    raise TypeError(
        f"policy {policy.name!r} exposes no queue structure the "
        "reference router can read")


def _busy(server: EdgeServer) -> int:
    return sum(1 for i in server.instances.values()
               if i.state == InstanceState.BUSY)


def _pick_dynamic(router: DynamicRouter, servers, policies, ests,
                  functions, rid: int, fn: int, seed: int,
                  prior: float, up=None, delay_now=None) -> int:
    """Python mirror of the traced `DynamicRouter.pick` arithmetic.

    ``up`` (length-K bools) masks down nodes exactly like the traced
    view: JSQ loads become I32_MAX, score routers get BIG — the
    chosen node may still be down (caller applies the lowest-id-up
    correction). ``delay_now`` is the per-node delay in effect at the
    decision instant (the `slo_aware` delay term)."""
    K = len(servers)
    if K == 1:
        return 0
    if isinstance(router, JSQRouter):
        load = [sum(len(q) for q in _queues(p).values()) + _busy(s)
                for p, s in zip(policies, servers)]
        if up is not None:
            load = [ld if u else _I32_MAX for ld, u in zip(load, up)]
        nodes = list(range(K))
        for i, jd in JSQRouter.sample(rid, seed, K, router.d):
            nodes[i], nodes[jd] = nodes[jd], nodes[i]
        best = nodes[0]
        for i in range(1, min(router.d, K)):
            if load[nodes[i]] < load[best]:
                best = nodes[i]
        return best
    # cold_aware / slo_aware: estimated time-to-start per node (plus
    # the current network delay for slo_aware), first argmin
    slo = isinstance(router, SLOAwareRouter)
    best_k, best_score = 0, None
    for k, (srv, pol, est) in enumerate(zip(servers, policies, ests)):
        gmean = est.gsum / max(est.gn, 1) if est.gn > 0 else prior
        n_j = est.n[fn]
        mean_j = est.sum[fn] / max(n_j, 1) if n_j > 0 else gmean
        has_idle = srv.idle_of(fn) is not None
        qtot = sum(len(q) for q in _queues(pol).values())
        score = ((0.0 if has_idle else functions[fn].cold_start)
                 + mean_j * len(_queues(pol)[fn])
                 + gmean * (qtot + _busy(srv)))
        if slo and delay_now is not None:
            score += delay_now[k]
        if up is not None and not up[k]:
            score = _BIG
        if best_score is None or score < best_score:
            best_k, best_score = k, score
    return best_k


def simulate_cluster_reference(trace: Trace, policy_name: str,
                               cspec: ClusterSpec, *,
                               capacity: Optional[int] = None,
                               exec_prior: float = 0.1,
                               max_events: Optional[int] = None,
                               deadlines: Optional[Sequence[float]]
                               = None,
                               horizon: Optional[float] = None,
                               queue_cap: Optional[int] = None,
                               fail_prob=0.0,
                               timeouts=None,
                               retry=None,
                               on_overflow: str = "error",
                               fail_seed: int = 0,
                               event_log: Optional[list] = None
                               ) -> Dict[str, np.ndarray]:
    """Run ``policy_name`` on a K-node cluster over ``trace``.

    ``capacity`` is the per-node slot count when the spec leaves
    ``node_capacity`` unset. Returns per-request ``start`` /
    ``completion`` / ``response`` (original request order), the (N,)
    node ``assign``ment, per-node ``node_done`` / ``node_cold`` counts
    and the cluster totals; with ``deadlines`` ((F,) per-function SLO
    deadlines) also the per-function ``deadline_miss`` counts
    (``response > deadline``, the engine's predicate).

    ``fail_prob`` / ``timeouts`` / ``retry`` (a `RetryPolicy`) /
    ``on_overflow`` + ``queue_cap`` switch on the resilience layer
    (module docstring) with the same trivial-off gate as the engine:
    all-zero ``fail_prob``, no ``timeouts`` and ``on_overflow=
    "error"`` leaves every code path untouched. The extra counters
    (``failed`` / ``timed_out`` / ``retried`` / ``shed`` /
    ``failed_exhausted`` / ``breaker_trips``) are always returned.

    ``event_log``, when a list, receives one ``(kind, rid, fn, node,
    t)`` tuple per processed event in pop order, with
    `repro.telemetry.rail.TraceKind` codes — the ground truth the
    engines' trace rail is parity-tested against. ``node`` is -1
    where no node is defined (a parked request, a rid-less churn
    toggle's request field).
    """
    from repro.core.resilience import (SHED_MODES, RetryPolicy,
                                       backoff_py, plan_outcomes)
    cspec.validate()
    K = cspec.n_nodes
    caps = cspec.node_caps(capacity if capacity is not None else 0)
    if any(c < 1 for c in caps):
        raise ValueError("simulate_cluster_reference: pass capacity= "
                         "or set ClusterSpec.node_capacity")
    router = cspec.get_router()
    delays = cspec.delays()
    if horizon is None:
        # the runner expands toggles against the horizon of the whole
        # stacked trace axis; pass it explicitly when comparing
        # against a multi-trace engine run
        horizon = (max(r.arrival for r in trace.requests)
                   if trace.requests else 0.0)
    toggles = cspec.churn_toggles(horizon)
    has_churn = any(len(t) for t in toggles)
    if has_churn and not router.dynamic:
        raise ValueError(
            "churn requires a dynamic router (static assignment "
            "cannot re-route around a down node); got "
            f"router={cspec.router!r}")
    dscheds = cspec.delay_schedule
    var_delay = dscheds is not None and any(
        ds is not None and len(ds.values) > 1 for ds in dscheds)

    # ---------------------------------------------- resilience layer
    if on_overflow not in SHED_MODES:
        raise ValueError(f"on_overflow must be one of "
                         f"{sorted(SHED_MODES)}, got {on_overflow!r}")
    shed_mode = SHED_MODES[on_overflow]
    fp = np.atleast_1d(np.asarray(fail_prob, np.float64))
    has_resil = (bool(np.any(fp > 0)) or timeouts is not None
                 or on_overflow != "error")
    has_breaker = isinstance(router, BreakerRouter)
    N = len(trace.requests)
    fn_ids = np.array([r.fn_id for r in trace.requests], np.int64)
    orig_exec = np.array([r.exec_time for r in trace.requests])
    if has_resil:
        if retry is None:
            retry = RetryPolicy()
        max_att = int(retry.max_attempts)
        eff_exec, n_fail, is_tmo = plan_outcomes(
            fn_ids, orig_exec, fail_prob=fail_prob, timeouts=timeouts,
            max_attempts=max_att, n_fns=trace.n_functions,
            seed=fail_seed)
        for r, e in zip(trace.requests, eff_exec):
            r.exec_time = float(e)
    att = np.zeros((N,), np.int32)
    counts = dict(failed=0, timed_out=0, retried=0, shed=0,
                  failed_exhausted=0, breaker_trips=0, overflow=0)
    # one retry rail per node on the static tier (independent
    # single-node engines), one cluster-global rail otherwise
    retry_qs = [deque() for _ in range(K if not router.dynamic else 1)]
    brk_n = [0] * K
    brk_f = [0] * K
    brk_until = [0.0] * K

    def delay_at(k: int, t: float) -> float:
        if var_delay:
            ds = dscheds[k]
            if ds is not None and len(ds.values) > 1:
                return ds.at(t)
        return delays[k]

    events = EventQueue()
    servers = [EdgeServer(trace.functions, caps[k], events)
               for k in range(K)]
    ests = [ExecTimeEstimator(trace.n_functions, prior=exec_prior)
            for _ in range(K)]
    policies = []
    for k in range(K):
        pol = POLICIES[policy_name]()
        pol.bind(servers[k], ests[k])
        policies.append(pol)

    assign = np.full((N,), -1, np.int32)
    static_assign = None
    if not router.dynamic:
        a = trace.to_arrays()
        static_assign = np.asarray(
            router.assign(a["fn_id"], a["arrival"], cspec))

    deferred = router.dynamic and (any(delays) or var_delay)
    for r in trace.requests:
        r.start = -1.0
        r.completion = -1.0
        if static_assign is not None:
            # the node is known upfront; the request reaches it after
            # its network delay
            k = int(static_assign[r.req_id])
            events.push(r.arrival + delays[k], EventKind.ARRIVAL, r)
        else:
            events.push(r.arrival, EventKind.ARRIVAL, r)
    # node-major toggle pushes: same-time toggles of different nodes
    # resolve lowest-node-first, the engine's candidate tie-break
    up = [True] * K
    for k in range(K):
        for t in toggles[k]:
            events.push(t, EventKind.CHURN, k)
    parked: list = []   # FIFO of requests waiting for any node

    def owner(inst) -> int:
        for k, srv in enumerate(servers):
            if srv.instances.get(inst.inst_id) is inst:
                return k
        raise RuntimeError(f"instance {inst.inst_id} owned by no node")

    def admit(k: int, req, t: float) -> None:
        # hand the request to the node's policy, then apply the
        # admission-control cap post-hoc: the policy's queues are
        # uncapped, so a push that left the per-function queue longer
        # than ``queue_cap`` is exactly an engine push onto a full
        # queue — ``shed`` removes the newcomer (the tail), ``shed_
        # oldest`` the head, ``error`` drops the newcomer and counts
        # overflow (the legacy invalid-run behaviour)
        policies[k].on_arrival(req, t)
        if not has_resil or queue_cap is None:
            return
        q = _queues(policies[k]).get(req.fn_id)
        if q is None or len(q) <= queue_cap:
            return
        if shed_mode == 2:
            victim = q.popleft()
            counts["shed"] += 1
            victim.completion = -1.0
        elif q[-1] is req:
            q.pop()
            if shed_mode == 1:
                counts["shed"] += 1
            else:
                counts["overflow"] += 1

    def route(req, t: float) -> None:
        dn = [delay_at(i, t) for i in range(K)]
        pick_router = router
        pick_up = up if has_churn else None
        if has_breaker:
            # mask breaker-open nodes for the inner router's pick,
            # failing open when every live node is open — the traced
            # `BreakerRouter.pick` arithmetic
            base_up = pick_up if pick_up is not None else [True] * K
            eff = [u and brk_until[i] <= t
                   for i, u in enumerate(base_up)]
            if not any(eff):
                eff = list(base_up)
            pick_router, pick_up = router.inner, eff
        k = _pick_dynamic(pick_router, servers, policies, ests,
                          trace.functions, req.req_id, req.fn_id,
                          cspec.seed, exec_prior,
                          up=pick_up, delay_now=dn)
        if has_churn and not up[k]:
            k = up.index(True)   # lowest-id up node, engine's argmax
        assign[req.req_id] = k
        if deferred:
            # dynamic routing under net_delay: the decision is made
            # now, the node sees the request delay_k(t) later
            events.push(t + delay_at(k, t), EventKind.NODE_ARRIVAL,
                        req)
        else:
            admit(k, req, t)

    def retry_rail(req) -> deque:
        return retry_qs[int(assign[req.req_id])
                        if not router.dynamic else 0]

    def retry_push(req, elig: float) -> None:
        # FIFO rail, head-armed: only the head has a RETRY event in
        # flight; the successor is armed at pop time with
        # ``max(elig, pop time)`` (no overtaking)
        rail = retry_rail(req)
        if not rail:
            events.push(elig, EventKind.RETRY, req)
        rail.append((req, elig))

    from repro.telemetry.rail import TraceKind

    if event_log is not None:
        def log(kind, req, node, t, fn=None):
            event_log.append((
                int(kind),
                -1 if req is None else int(req.req_id),
                (int(fn) if fn is not None
                 else -1 if req is None else int(req.fn_id)),
                int(node), float(t)))
    else:
        def log(kind, req, node, t, fn=None):
            pass

    node_done = np.zeros((K,), np.int64)
    n_events = 0
    while True:
        ev = events.pop()
        if ev is None:
            break
        n_events += 1
        if max_events is not None and n_events > max_events:
            raise RuntimeError(f"event budget exceeded ({max_events})")
        if ev.kind == EventKind.ARRIVAL:
            req = ev.payload
            if static_assign is not None:
                k = int(static_assign[req.req_id])
                assign[req.req_id] = k
                admit(k, req, ev.time)
                log(TraceKind.ARRIVAL, req, k, ev.time)
            elif has_churn and not any(up):
                parked.append(req)
                log(TraceKind.ARRIVAL, req, -1, ev.time)
            else:
                route(req, ev.time)
                log(TraceKind.ARRIVAL, req, assign[req.req_id],
                    ev.time)
        elif ev.kind == EventKind.NODE_ARRIVAL:
            req = ev.payload
            k = int(assign[req.req_id])
            log(TraceKind.NODE_ARRIVAL, req, k, ev.time)
            if has_churn and not up[k]:
                # landed on a down node: back through the router (or
                # park if there is nowhere to go)
                if any(up):
                    events.push(ev.time, EventKind.REROUTE, req)
                else:
                    parked.append(req)
            else:
                admit(k, req, ev.time)
        elif ev.kind == EventKind.RETRY:
            req = ev.payload
            rail = retry_rail(req)
            assert rail and rail[0][0] is req
            rail.popleft()
            if rail:
                nreq, nelig = rail[0]
                events.push(max(nelig, ev.time), EventKind.RETRY,
                            nreq)
            if static_assign is not None:
                # static tier: the retry re-enters its own node's
                # queue at the fire time (the delivery leg is not
                # re-paid — the request never left the node)
                admit(int(assign[req.req_id]), req, ev.time)
                log(TraceKind.RETRY, req, assign[req.req_id],
                    ev.time)
            elif has_churn and not any(up):
                parked.append(req)
                log(TraceKind.RETRY, req, -1, ev.time)
            else:
                route(req, ev.time)
                log(TraceKind.RETRY, req, assign[req.req_id],
                    ev.time)
        elif ev.kind == EventKind.REROUTE:
            req = ev.payload
            if not any(up):
                parked.append(req)
                log(TraceKind.REROUTE, req, -1, ev.time)
            else:
                route(req, ev.time)
                log(TraceKind.REROUTE, req, assign[req.req_id],
                    ev.time)
        elif ev.kind == EventKind.CHURN:
            k = ev.payload
            log(TraceKind.CHURN, None, k, ev.time)
            if up[k]:
                # NODE_DOWN: drain running requests (by request id)
                # then queued ones (function-major, FIFO within a
                # function); every instance dies, cold state is lost,
                # the estimator persists
                up[k] = False
                srv, pol = servers[k], policies[k]
                running = sorted(
                    (i for i in srv.instances.values()
                     if i.state == InstanceState.BUSY
                     and i.current is not None),
                    key=lambda i: i.current.req_id)
                drained = [i.current for i in running]
                q = _queues(pol)
                for fn in sorted(q):
                    drained.extend(q[fn])
                for inst in srv.instances.values():
                    inst.dead = True   # pending *_DONE events no-op
                srv.instances.clear()
                srv.by_fn = {f.fn_id: set()
                             for f in trace.functions}
                fresh = POLICIES[policy_name]()
                fresh.bind(srv, ests[k])
                policies[k] = fresh
                for req in drained:
                    events.push(ev.time, EventKind.REROUTE, req)
            else:
                # NODE_UP: replay parked requests in arrival order
                up[k] = True
                for req in parked:
                    events.push(ev.time, EventKind.REROUTE, req)
                parked.clear()
        elif ev.kind == EventKind.EXEC_DONE:
            inst = ev.payload
            if getattr(inst, "dead", False):
                continue
            k = owner(inst)
            req = inst.current
            log(TraceKind.EXEC, req, k, ev.time)
            ests[k].observe(req.fn_id, req.exec_time)
            ok = True
            if has_resil:
                # the pre-planned attempt test (core/resilience.py):
                # the engine counts attempts at dispatch, this
                # reference at completion — equal here because a
                # churn-drained attempt reaches neither
                att[req.req_id] += 1
                a = int(att[req.req_id])
                ok = a > int(n_fail[req.req_id])
            if ok:
                node_done[k] += 1
            if has_breaker:
                # engine-exact window transitions: closed counts the
                # attempt and trips on a full window's failures;
                # half-open lets the first completion decide; open
                # completions are pre-trip stragglers, ignored
                u0 = brk_until[k]
                if u0 == 0.0:  # closed
                    brk_n[k] += 1
                    brk_f[k] += 0 if ok else 1
                    if brk_n[k] >= router.volume:
                        if brk_f[k] >= router.trip_at:
                            brk_until[k] = ev.time + router.cooldown
                            counts["breaker_trips"] += 1
                        brk_n[k] = brk_f[k] = 0
                elif u0 <= ev.time:  # half-open: first result decides
                    if ok:
                        brk_until[k] = 0.0
                    else:
                        brk_until[k] = ev.time + router.cooldown
                        counts["breaker_trips"] += 1
                    brk_n[k] = brk_f[k] = 0
            policies[k].on_exec_done(inst, req, ev.time)
            if not ok:
                req.completion = -1.0
                if is_tmo[req.req_id]:
                    counts["timed_out"] += 1
                else:
                    counts["failed"] += 1
                if a >= max_att:
                    counts["failed_exhausted"] += 1
                else:
                    counts["retried"] += 1
                    retry_push(req, ev.time + backoff_py(
                        a, req.req_id, retry.base, retry.cap,
                        retry.jitter, fail_seed))
        elif ev.kind == EventKind.COLD_DONE:
            inst = ev.payload
            if getattr(inst, "dead", False):
                continue
            ko = owner(inst)
            log(TraceKind.COLD, None, ko, ev.time, fn=inst.fn_id)
            policies[ko].on_cold_done(inst, ev.time)
        elif ev.kind == EventKind.TIMER:
            if has_churn or has_resil:
                raise RuntimeError(
                    "timer-armed policies are not supported under "
                    "churn or the resilience layer (matches the "
                    "engine's rejection)")
            # timer payloads are requests; route to the node that owns
            # the request (openwhisk_v2 on the static path)
            req = ev.payload
            k = int(assign[req.req_id])
            log(TraceKind.TIMER, req, k, ev.time)
            if k >= 0:
                policies[k].on_timer(req, ev.time)

    start = np.array([r.start for r in trace.requests])
    completion = np.array([r.completion for r in trace.requests])
    arr = np.array([r.arrival for r in trace.requests])
    if has_resil:
        # restore the pre-substitution execution times so the trace
        # can be replayed (min(exec, timeout) is not idempotent for
        # the timeout classification)
        for r, e in zip(trace.requests, orig_exec):
            r.exec_time = float(e)
    if has_churn or (has_resil and router.dynamic):
        # the delivery leg may be paid several times for a re-routed
        # or retried request, so the response baseline is the raw
        # arrival (the static tier keeps its per-node delayed clock —
        # a retry never leaves its node)
        pass
    elif static_assign is not None:
        # response measured from the node-local (delayed) arrival,
        # the engine's convention (docs/cluster.md)
        arr = arr + np.asarray(delays)[static_assign]
    elif deferred:
        ka = np.clip(assign, 0, K - 1)
        if var_delay:
            arr = arr + np.array([delay_at(int(k), float(a))
                                  for k, a in zip(ka, arr)])
        else:
            arr = arr + np.asarray(delays)[ka]
    response = completion - arr
    if has_resil:
        response = np.where(completion >= 0.0, response, np.nan)
    out = dict(
        start=start, completion=completion, response=response,
        assign=assign, node_done=node_done,
        node_cold=np.array([s.stats.cold_starts for s in servers]),
        cold_starts=int(sum(s.stats.cold_starts for s in servers)),
        evictions=int(sum(s.stats.evictions for s in servers)),
        n_events=n_events, done=int((completion >= 0.0).sum()),
        **counts)
    if deadlines is not None:
        dl = np.asarray(deadlines, np.float64)
        fn = np.array([r.fn_id for r in trace.requests])
        miss = np.zeros((trace.n_functions,), np.int32)
        done = completion >= 0.0
        np.add.at(miss, fn[done & (response > dl[fn])], 1)
        out["deadline_miss"] = miss
    return out
