"""Straightforward Python reference cluster: router + K event engines.

The trustworthy-but-slow baseline the vectorised cluster paths are
parity-tested against (tests/test_cluster.py): K completely ordinary
single-node simulations — each node is its own
`repro.core.server.EdgeServer` + `ExecTimeEstimator` + event-driven
policy instance, untouched — sharing **one** global `EventQueue`, so
simultaneous events interleave across nodes exactly like the paper's
single-server engine orders them (EXEC_DONE < COLD_DONE < TIMER <
ARRIVAL, FIFO within a kind). At each ARRIVAL the router picks the
node from live global state using the *same arithmetic* (same `mix32`
draws, same score formula, same first-argmin tie-break) as the traced
routers in `repro.cluster.routers`, then hands the request to that
node's policy.

Nodes only interact through the router, so any cross-node ordering of
same-time non-arrival events is immaterial — which is what makes this
composition a faithful reference for the JAX loop's node-major
tie-breaking.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.cluster.routers import DynamicRouter, JSQRouter
from repro.cluster.spec import ClusterSpec
from repro.core.events import EventKind, EventQueue
from repro.core.policy import POLICIES
from repro.core.request import Trace
from repro.core.server import (EdgeServer, ExecTimeEstimator,
                               InstanceState)


def _queues(policy) -> dict:
    """The per-function waiting deques, whatever the policy calls
    them (`queues` for per-function-queue policies, `fifo` for the
    central-queue family)."""
    if hasattr(policy, "queues"):
        return policy.queues
    if hasattr(policy, "fifo"):
        return policy.fifo
    raise TypeError(
        f"policy {policy.name!r} exposes no queue structure the "
        "reference router can read")


def _busy(server: EdgeServer) -> int:
    return sum(1 for i in server.instances.values()
               if i.state == InstanceState.BUSY)


def _pick_dynamic(router: DynamicRouter, servers, policies, ests,
                  functions, rid: int, fn: int, seed: int,
                  prior: float) -> int:
    """Python mirror of the traced `DynamicRouter.pick` arithmetic."""
    K = len(servers)
    if K == 1:
        return 0
    if isinstance(router, JSQRouter):
        load = [sum(len(q) for q in _queues(p).values()) + _busy(s)
                for p, s in zip(policies, servers)]
        nodes = list(range(K))
        for i, jd in JSQRouter.sample(rid, seed, K, router.d):
            nodes[i], nodes[jd] = nodes[jd], nodes[i]
        best = nodes[0]
        for i in range(1, min(router.d, K)):
            if load[nodes[i]] < load[best]:
                best = nodes[i]
        return best
    # cold_aware: estimated time-to-start per node, first argmin
    best_k, best_score = 0, None
    for k, (srv, pol, est) in enumerate(zip(servers, policies, ests)):
        gmean = est.gsum / max(est.gn, 1) if est.gn > 0 else prior
        n_j = est.n[fn]
        mean_j = est.sum[fn] / max(n_j, 1) if n_j > 0 else gmean
        has_idle = srv.idle_of(fn) is not None
        qtot = sum(len(q) for q in _queues(pol).values())
        score = ((0.0 if has_idle else functions[fn].cold_start)
                 + mean_j * len(_queues(pol)[fn])
                 + gmean * (qtot + _busy(srv)))
        if best_score is None or score < best_score:
            best_k, best_score = k, score
    return best_k


def simulate_cluster_reference(trace: Trace, policy_name: str,
                               cspec: ClusterSpec, *,
                               capacity: Optional[int] = None,
                               exec_prior: float = 0.1,
                               max_events: Optional[int] = None
                               ) -> Dict[str, np.ndarray]:
    """Run ``policy_name`` on a K-node cluster over ``trace``.

    ``capacity`` is the per-node slot count when the spec leaves
    ``node_capacity`` unset. Returns per-request ``start`` /
    ``completion`` / ``response`` (original request order), the (N,)
    node ``assign``ment, per-node ``node_done`` / ``node_cold`` counts
    and the cluster totals.
    """
    cspec.validate()
    K = cspec.n_nodes
    caps = cspec.node_caps(capacity if capacity is not None else 0)
    if any(c < 1 for c in caps):
        raise ValueError("simulate_cluster_reference: pass capacity= "
                         "or set ClusterSpec.node_capacity")
    router = cspec.get_router()
    delays = cspec.delays()

    events = EventQueue()
    servers = [EdgeServer(trace.functions, caps[k], events)
               for k in range(K)]
    ests = [ExecTimeEstimator(trace.n_functions, prior=exec_prior)
            for _ in range(K)]
    policies = []
    for k in range(K):
        pol = POLICIES[policy_name]()
        pol.bind(servers[k], ests[k])
        policies.append(pol)

    N = len(trace.requests)
    assign = np.full((N,), -1, np.int32)
    static_assign = None
    if not router.dynamic:
        a = trace.to_arrays()
        static_assign = np.asarray(
            router.assign(a["fn_id"], a["arrival"], cspec))

    deferred = router.dynamic and any(delays)
    for r in trace.requests:
        r.start = -1.0
        r.completion = -1.0
        if static_assign is not None:
            # the node is known upfront; the request reaches it after
            # its network delay
            k = int(static_assign[r.req_id])
            events.push(r.arrival + delays[k], EventKind.ARRIVAL, r)
        else:
            events.push(r.arrival, EventKind.ARRIVAL, r)

    def owner(inst) -> int:
        for k, srv in enumerate(servers):
            if srv.instances.get(inst.inst_id) is inst:
                return k
        raise RuntimeError(f"instance {inst.inst_id} owned by no node")

    node_done = np.zeros((K,), np.int64)
    n_events = 0
    while True:
        ev = events.pop()
        if ev is None:
            break
        n_events += 1
        if max_events is not None and n_events > max_events:
            raise RuntimeError(f"event budget exceeded ({max_events})")
        if ev.kind == EventKind.ARRIVAL:
            req = ev.payload
            if static_assign is not None:
                k = int(static_assign[req.req_id])
            else:
                k = _pick_dynamic(router, servers, policies, ests,
                                  trace.functions, req.req_id,
                                  req.fn_id, cspec.seed, exec_prior)
            assign[req.req_id] = k
            if deferred:
                # dynamic routing under net_delay: the decision is
                # made now, the node sees the request delay_k later
                events.push(ev.time + delays[k],
                            EventKind.NODE_ARRIVAL, req)
            else:
                policies[k].on_arrival(req, ev.time)
        elif ev.kind == EventKind.NODE_ARRIVAL:
            req = ev.payload
            policies[int(assign[req.req_id])].on_arrival(req, ev.time)
        elif ev.kind == EventKind.EXEC_DONE:
            inst = ev.payload
            k = owner(inst)
            req = inst.current
            ests[k].observe(req.fn_id, req.exec_time)
            node_done[k] += 1
            policies[k].on_exec_done(inst, req, ev.time)
        elif ev.kind == EventKind.COLD_DONE:
            inst = ev.payload
            policies[owner(inst)].on_cold_done(inst, ev.time)
        elif ev.kind == EventKind.TIMER:
            # timer payloads are requests; route to the node that owns
            # the request (openwhisk_v2 on the static path)
            req = ev.payload
            k = int(assign[req.req_id])
            if k >= 0:
                policies[k].on_timer(req, ev.time)

    start = np.array([r.start for r in trace.requests])
    completion = np.array([r.completion for r in trace.requests])
    # response measured from the node-local (delayed) arrival, the
    # engine's convention (docs/cluster.md)
    arr = np.array([r.arrival for r in trace.requests])
    if static_assign is not None:
        arr = arr + np.asarray(delays)[static_assign]
    elif deferred:
        arr = arr + np.asarray(delays)[np.clip(assign, 0, K - 1)]
    return dict(
        start=start, completion=completion, response=completion - arr,
        assign=assign, node_done=node_done,
        node_cold=np.array([s.stats.cold_starts for s in servers]),
        cold_starts=int(sum(s.stats.cold_starts for s in servers)),
        evictions=int(sum(s.stats.evictions for s in servers)),
        n_events=n_events)
