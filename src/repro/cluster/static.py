"""Static-routing fast path: pre-partition, simulate, merge exactly.

A static router fixes each request's node from the trace alone, so a
K-node cluster is exactly K independent single-node simulations over
the per-node sub-streams of the arrival stream. This module implements
that as a vectorised pre-pass + the *unmodified* single-node engine:

1. ``build_node_streams`` asks the router for the (N,) node assignment
   (checking every request is routed exactly once), splits the
   columnar trace into K arrival-ordered sub-streams, adds each node's
   network delay to its arrivals (a constant shift keeps the
   sub-stream sorted), and right-pads every sub-stream to the common
   length N — the padded rows share one (T·K, N) operand, and the
   engine's ``n_live`` lane cap (PR 5) keeps the padding inert without
   a recompile per sub-stream length.
2. ``run_static_entry`` lowers (policy × trace × capacity × beta ×
   node) onto `jax_engine._sweep_metrics` lanes — node slot counts
   become per-lane capacity masks, so heterogeneous nodes ride the
   same jit specialisation — and merges the per-node streamed metrics
   back into cluster-level cells.

The merge is *exact*: counters and histograms are integer sums, the
response/slowdown/cold-time sums are float sums taken in **canonical
(value-sorted) order** over the node axis, so the merged metrics are
bitwise invariant to node numbering (gated in tests/test_cluster.py),
and means/quantiles are recomputed from the merged sums/histograms the
same way the engine computes them — a K=1 cluster with zero delay is
bitwise identical to the plain single-node run.

Response-time semantics under ``net_delay``: a request routed to node
k *arrives at the node* at ``t + delay_k`` and its response is
measured from that node-local arrival (the engine's definition). The
delay shifts the node's dynamics; it is not added to the reported
latency (docs/cluster.md discusses both conventions).
"""
from __future__ import annotations

import functools
from typing import Dict, List

import numpy as np

from repro.cluster.spec import ClusterSpec

PAD_ARRIVAL = 1e30      # matches jax_engine.BIG: padding never arrives


@functools.lru_cache(maxsize=None)
def _div_by_n_jit():
    import jax

    @functools.partial(jax.jit, static_argnames=("n",))
    def div(x, n):
        return x / n
    return div


def _mean(x: np.ndarray, n: int) -> np.ndarray:
    """``x / n`` through the same jitted constant-denominator division
    `_sweep_metrics` lowers to (XLA folds division by a constant into
    a reciprocal multiply — a plain numpy divide would differ in the
    last ulp and break the K=1 bitwise gate)."""
    import jax.numpy as jnp
    return np.asarray(_div_by_n_jit()(jnp.asarray(x), max(int(n), 1)))


def build_node_streams(arrays: Dict[str, np.ndarray],
                       cspec: ClusterSpec):
    """Partition one columnar trace into per-node padded sub-streams.

    Returns ``(assign, streams, n_live, index)``: the (N,) node
    assignment, a dict of (K, N) padded ``fn_id``/``arrival``/
    ``exec_time`` rows (node k's requests lead row k, arrival order
    preserved, delays applied), the (K,) live lengths and the K
    original-request-id index arrays (for exact-mode reassembly).
    """
    router = cspec.get_router()
    if router.dynamic:
        raise ValueError(
            f"build_node_streams: router {cspec.router!r} is dynamic; "
            "the static path needs a StaticRouter")
    if cspec.has_churn():
        raise ValueError(
            f"cluster router {cspec.router!r} is static: a fixed "
            "assignment cannot re-route around a down node. Churn "
            "needs a dynamic router (jsq2, cold_aware, slo_aware)")
    if cspec.delay_ops() is not None:
        raise ValueError(
            f"cluster router {cspec.router!r} is static: the "
            "pre-partition fast path only supports constant "
            "net_delay (a time-varying DelaySchedule would unsort "
            "the per-node sub-streams); use a dynamic router")
    fn_id = np.asarray(arrays["fn_id"])
    arrival = np.asarray(arrays["arrival"])
    N, K = len(fn_id), cspec.n_nodes
    assign = np.asarray(router.assign(fn_id, arrival, cspec))
    if assign.shape != (N,):
        raise ValueError(
            f"router {cspec.router!r} returned shape {assign.shape} "
            f"for {N} requests — every request must be routed exactly "
            "once")
    if N and (assign.min() < 0 or assign.max() >= K):
        raise ValueError(
            f"router {cspec.router!r} routed outside [0, {K}): "
            f"range [{assign.min()}, {assign.max()}]")
    delays = cspec.delays()
    node_fn = np.zeros((K, N), np.int32)
    node_arr = np.full((K, N), PAD_ARRIVAL, np.float64)
    node_ex = np.zeros((K, N), np.float64)
    n_live = np.zeros((K,), np.int32)
    index: List[np.ndarray] = []
    for k in range(K):
        idx = np.flatnonzero(assign == k)
        n = len(idx)
        node_fn[k, :n] = fn_id[idx]
        node_arr[k, :n] = arrival[idx] + delays[k]
        node_ex[k, :n] = np.asarray(arrays["exec_time"])[idx]
        n_live[k] = n
        index.append(idx)
    streams = dict(fn_id=node_fn, arrival=node_arr, exec_time=node_ex)
    return assign, streams, n_live, index


# ------------------------------------------------------------ exact merge
# float metrics summed over nodes in canonical (value-sorted) order so
# the merged value is bitwise invariant to node numbering; integer
# metrics sum in any order; max is order-free
_SUM_F = ("resp_sum", "slow_sum", "cold_time", "evict_time")
_SUM_I = ("cold_starts", "evictions", "overflow", "stalled", "done",
          "resp_hist", "deadline_miss", "failed", "timed_out",
          "retried", "shed", "failed_exhausted")
_SUM_F_TL = ("tl_resp_sum", "tl_exec_sum")
_SUM_I_TL = ("tl_count",)


def _ordered_sum(a: np.ndarray, axis: int) -> np.ndarray:
    """Sum over ``axis`` with the addends first sorted by value —
    deterministic and permutation-invariant float reduction."""
    return np.sort(a, axis=axis).sum(axis=axis)


def merge_node_metrics(per_node: Dict[str, np.ndarray], node_axis: int,
                       n_total: int, resil: bool = False
                       ) -> Dict[str, np.ndarray]:
    """Fold per-node metric arrays (node axis ``node_axis``) into
    cluster-level metrics over ``n_total`` requests.

    Means and the streamed p99 are recomputed from the merged sums /
    histogram exactly the way `jax_engine._sweep_metrics` computes
    them, so a single-node "cluster" merges to the engine's own
    numbers bit for bit. Under ``resil`` the denominators are the
    merged success counts (``done``) instead of ``n_total`` — an array
    denominator, so plain IEEE division matches the engine's (the
    jitted reciprocal-multiply fold in `_mean` only applies to
    *constant* denominators)."""
    from repro.core.jax_engine import hist_quantile
    out: Dict[str, np.ndarray] = {}
    for m in _SUM_F:
        if m in per_node:
            out[m] = _ordered_sum(per_node[m], node_axis)
    for m in _SUM_I:
        if m in per_node:
            out[m] = per_node[m].sum(axis=node_axis)
    for m in _SUM_F_TL:       # (..., K, bins): sort nodes per bin
        if m in per_node:
            out[m] = _ordered_sum(per_node[m], node_axis - 1
                                  if node_axis < 0 else node_axis)
    for m in _SUM_I_TL:
        if m in per_node:
            out[m] = per_node[m].sum(axis=node_axis - 1
                                     if node_axis < 0 else node_axis)
    out["max_response"] = per_node["max_response"].max(axis=node_axis)
    out["node_done"] = np.moveaxis(per_node["done"], node_axis, -1)
    if resil:
        den = np.maximum(out["done"], 1).astype(np.float64)
        out["mean_response"] = out["resp_sum"] / den
        out["mean_slowdown"] = out["slow_sum"] / den
        out["p99_response"] = np.asarray(hist_quantile(
            out["resp_hist"], 0.99, out["done"][..., None],
            out["max_response"]))
    else:
        out["mean_response"] = _mean(out["resp_sum"], n_total)
        out["mean_slowdown"] = _mean(out["slow_sum"], n_total)
        out["p99_response"] = np.asarray(hist_quantile(
            out["resp_hist"], 0.99, n_total, out["max_response"]))
    return out


def run_static_entry(spec, entry: ClusterSpec,
                     stacked: Dict[str, np.ndarray], F: int, N: int,
                     kernels: dict, beta_cols: Dict[str, np.ndarray],
                     deadlines=None, rs=None,
                     trace_cells=None) -> Dict[str, np.ndarray]:
    """Execute one static `ClusterSpec` over the spec's grid.

    Returns (P, T, KC, B)-shaped metric arrays (plus trailing dims:
    ``node_done`` (.., K), ``resp_hist`` (.., bins), optional
    ``response`` (.., N)) for this cluster entry.

    ``trace_cells`` (a dict, only under ``spec.trace_events``) is
    filled with one merged event stream per (pi, t, kc, b) cell: the
    tier is K independent single-node simulations, so each node's
    stream is collected separately, its node id patched in host-side
    (the single-node rail records node −1), its sub-stream-local
    request ids mapped back to global ids through the partition
    index, and the K streams merged time-ordered
    (`repro.telemetry.rail.merge_events`).
    """
    import jax.numpy as jnp

    from repro.core.jax_engine import _sweep_metrics, resolve_lane_chunk

    T = stacked["fn_id"].shape[0]
    Kn = entry.n_nodes
    KC = len(spec.capacities)
    B = 1 if spec.betas is None else len(spec.betas)
    C = max(max(entry.node_caps(c)) for c in spec.capacities)

    resil = None
    if rs is not None:
        eff, rs_nfail, rs_tmo, _, resil = rs

    # per-trace partition (vectorised pre-pass). Under resilience the
    # timeout-clipped exec times are partitioned instead, and each
    # node's sub-stream carries its requests' pre-planned outcome rows
    # sliced by the same assignment — with the *original* request ids
    # as the jitter keys, so a request's retry backoffs are identical
    # no matter which node (or tier) runs it.
    streams_t: List[Dict[str, np.ndarray]] = []
    n_live_rows = np.zeros((T, Kn), np.int32)
    index: List[List[np.ndarray]] = []
    rs_rows: List[Dict[str, np.ndarray]] = []
    for t in range(T):
        a = {k: stacked[k][t] for k in ("fn_id", "arrival",
                                        "exec_time")}
        if rs is not None:
            a["exec_time"] = eff[t]
        _, streams, n_live, idx = build_node_streams(a, entry)
        streams_t.append(streams)
        n_live_rows[t] = n_live
        index.append(idx)
        if rs is not None:
            nf = np.zeros((Kn, N), np.int32)
            tm = np.zeros((Kn, N), bool)
            ky = np.zeros((Kn, N), np.int32)
            for k in range(Kn):
                i = idx[k]
                nf[k, : len(i)] = rs_nfail[t][i]
                tm[k, : len(i)] = rs_tmo[t][i]
                ky[k, : len(i)] = i
            rs_rows.append(dict(nfail=nf, tmo=tm, key=ky))

    # One engine call per (trace, node) sub-stream row, lanes =
    # capacity x beta. Feeding all T*K rows as one shared (T*K, N)
    # operand batches more lanes per call but falls off XLA:CPU's fast
    # gather path: a multi-row shared operand beyond ~2^16 elements
    # degrades the per-event gathers ~25x (single-row operands of any
    # length stay fast — the N-curve runs 1e6-request rows flat).
    # Per-row calls also collapse every (router, K) topology onto ONE
    # (1, N)-shaped jit specialisation per policy.
    node_masks = {c: np.stack([np.arange(C) < nc
                               for nc in entry.node_caps(c)])
                  for c in spec.capacities}
    L = KC * B
    dl_op = None if deadlines is None else jnp.asarray(deadlines)
    keep_resp = bool(spec.keep_per_request) or not spec.stream
    chunk = resolve_lane_chunk(spec.lane_chunk)
    traced = trace_cells is not None
    if traced:
        from repro.telemetry import rail
    per_policy: Dict[str, Dict[str, np.ndarray]] = {}
    for pi, policy in enumerate(spec.policies):
        outs: Dict[str, list] = {}
        for t in range(T):
            cold = jnp.asarray(stacked["cold_start"][t][None])
            evict = jnp.asarray(stacked["evict"][t][None])
            lane_nodes: Dict[int, list] = {}
            for k in range(Kn):
                shared = tuple(
                    jnp.asarray(streams_t[t][key][k][None])
                    for key in ("fn_id", "arrival", "exec_time")
                ) + (cold, evict)
                masks = np.stack([node_masks[c][k]
                                  for c in spec.capacities
                                  for _ in range(B)])
                beta_l = beta_cols[policy][:L]
                nl = np.full((L,), n_live_rows[t, k], np.int32)
                rs_kw = {}
                if rs is not None:
                    rr = rs_rows[t]
                    rs_kw = dict(
                        rs_nfail=jnp.asarray(rr["nfail"][k][None]),
                        rs_tmo=jnp.asarray(rr["tmo"][k][None]),
                        rs_key=jnp.asarray(rr["key"][k][None]))
                row_outs: Dict[str, list] = {}
                for lo in range(0, L, chunk):
                    hi = min(lo + chunk, L)

                    def call():
                        return _sweep_metrics(
                            *shared, jnp.zeros((hi - lo,), jnp.int32),
                            jnp.asarray(masks[lo:hi]),
                            jnp.asarray(beta_l[lo:hi]),
                            jnp.float64(spec.prior),
                            jnp.float64(spec.threshold),
                            jnp.asarray(nl[lo:hi]), dl_op, **rs_kw,
                            resil=resil,
                            kernel=kernels[policy], n_fns=F,
                            capacity=C, queue_cap=spec.queue_cap,
                            stream=spec.stream, window=spec.window,
                            tl_bins=spec.tl_bins,
                            tl_bucket=spec.tl_bucket,
                            keep_responses=(keep_resp
                                            and not spec.stream),
                            trace=traced)
                    if traced:
                        with rail.collect() as sink:
                            out = {m: np.asarray(v) for m, v
                                   in call().items()}
                        idxk = index[t][k]
                        for j in range(hi - lo):
                            ev = sink.lane_events(j)
                            ev["node"] = np.full_like(ev["node"], k)
                            r = ev["rid"]
                            if len(idxk):
                                gl = idxk[np.clip(r, 0,
                                                  len(idxk) - 1)]
                                ev["rid"] = np.where(
                                    r >= 0, gl, -1).astype(np.int32)
                            lane_nodes.setdefault(lo + j,
                                                  []).append(ev)
                    else:
                        out = call()
                    for m, v in out.items():
                        row_outs.setdefault(m, []).append(
                            np.asarray(v))
                for m, v in row_outs.items():
                    outs.setdefault(m, []).append(np.concatenate(v))
            if traced:
                for lane, evs in lane_nodes.items():
                    kc, b = divmod(lane, B)
                    trace_cells[(pi, t, kc, b)] = rail.merge_events(
                        evs)
        # outs[m]: T*Kn blocks of (KC*B, ...) in (t, node) order
        per_policy[policy] = {
            m: np.stack(v).reshape((T, Kn, KC, B) + v[0].shape[1:])
               .transpose((0, 2, 3, 1)
                          + tuple(range(4, 4 + v[0].ndim - 1)))
            for m, v in outs.items()}

    # ------------------------------------------------- node-axis merge
    data: Dict[str, np.ndarray] = {}
    for pi, policy in enumerate(spec.policies):
        pn = per_policy[policy]
        merged = merge_node_metrics(pn, node_axis=3, n_total=N,
                                    resil=resil is not None)
        if "response" in pn:
            resp = np.zeros((T, KC, B, N), np.float64)
            for t in range(T):
                for k in range(Kn):
                    nk = int(n_live_rows[t, k])
                    resp[t, :, :, index[t][k]] = np.moveaxis(
                        pn["response"][t, :, :, k, :nk], -1, 0)
            if resil is not None:
                # shed / retry-exhausted rids carry NaN responses
                merged["p99_response"] = np.nanpercentile(
                    resp, 99.0, axis=-1)
            else:
                merged["p99_response"] = np.percentile(resp, 99.0,
                                                       axis=-1)
            if spec.keep_per_request:
                merged["response"] = resp
        for m, v in merged.items():
            if m not in data:
                data[m] = np.zeros((len(spec.policies),) + v.shape,
                                   v.dtype)
            data[m][pi] = v
    return data


# ---------------------------------------------------------- audit hooks
def audit_jits():
    """Jitted static-tier helpers by name, for `repro.analysis`'s
    recompilation auditor. The tier's design claim -- every (router,
    K, heterogeneity) topology collapses onto ONE (1, N)-shaped
    `_sweep_metrics` specialisation per policy, because node streams
    are PAD-padded back to full length and masked via ``n_live`` --
    is what the auditor checks by counting engine cache entries after
    a representative grid."""
    return {"div_by_n": _div_by_n_jit()}
