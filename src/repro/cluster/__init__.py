"""Multi-node edge cluster simulation over the vectorised engine.

The paper's scheduler runs on one resource-limited edge server; this
package simulates K heterogeneous edge nodes behind a request router —
the LaSS-style deployment shape — on top of the same policy kernels:

* `ClusterSpec` declares a topology (node count, per-node capacities,
  router, network delays, plus `PeriodicChurn` / explicit-window
  availability schedules and per-node `DelaySchedule`s) and rides
  `repro.api.ExperimentSpec`'s ``cluster`` axis;
* `repro.cluster.routers` holds the router registry (static: ``hash``,
  ``round_robin``, ``weighted_random``; dynamic: ``jsq2``,
  ``cold_aware``, ``slo_aware``) with `register_router` for plug-ins;
* `repro.cluster.static` is the static-routing fast path (sub-stream
  partition → unmodified single-node engine → exact merge);
* `repro.cluster.engine` is the dynamic-routing K-node event loop;
* `repro.cluster.reference` is the straightforward Python cluster
  simulator the JAX paths are parity-tested against.

See docs/cluster.md for the full tour.
"""
from repro.cluster.routers import (ROUTERS, ClusterView, DynamicRouter,
                                   Router, StaticRouter,
                                   available_routers, get_router,
                                   register_router, unregister_router)
from repro.cluster.spec import (ClusterSpec, DelaySchedule,
                                PeriodicChurn)

__all__ = [
    "ClusterSpec", "PeriodicChurn", "DelaySchedule", "Router",
    "StaticRouter", "DynamicRouter", "ClusterView", "ROUTERS",
    "available_routers", "get_router", "register_router",
    "unregister_router",
]
