"""Lower the `ExperimentSpec.cluster` axis onto the two routing tiers.

`run_cluster_experiment` executes one spec whose ``cluster`` field
declares a sequence of topologies and stacks the per-entry
(P, T, K, B) metric grids into a 5-axis `ResultSet` (the new trailing
``cluster`` dim, labeled by `ClusterSpec.label`):

* ``None`` entries run the plain single-node path — literally
  `repro.api.runner.run_experiment` on a cluster-less copy of the
  spec, so those cells are bitwise the non-cluster API's;
* static-router entries run the sub-stream fast path
  (`repro.cluster.static.run_static_entry`);
* dynamic-router entries run the K-node event loop
  (`repro.cluster.engine._cluster_metrics`), lane-batched over
  (trace × capacity × beta) exactly like the single-node sweep.

Every entry contributes the same metric set (plain cells synthesise a
one-node ``node_done``), padded to the axis-wide max node count, so
the stacked arrays stay rectangular.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Dict, List, Optional

import numpy as np

from repro.cluster.spec import ClusterSpec
from repro.cluster.static import run_static_entry


def _churn_operand(entry: ClusterSpec, horizon: float):
    """Back-compat alias: the lowering moved to
    `ClusterSpec.churn_operand` so every engine-boundary operand the
    spec produces is built (and dtype-pinned) in one place."""
    return entry.churn_operand(horizon)


def _run_dynamic_entry(spec, entry: ClusterSpec, stacked, F: int,
                       N: int, kernels, beta_cols, deadlines=None,
                       rs=None,
                       trace_cells=None) -> Dict[str, np.ndarray]:
    """One dynamic-router entry over the spec grid: (P, T, KC, B)
    metric arrays from the K-node loop.

    ``trace_cells`` (a dict, only under ``spec.trace_events``) is
    filled with one event stream per (pi, t, kc, b) cell; chunks run
    serially, each inside its own collect scope, so the ordered
    flushes of one chunk never interleave with another's."""
    import jax.numpy as jnp

    from repro.cluster.engine import _cluster_metrics
    from repro.core.jax_engine import resolve_lane_chunk

    T = stacked["fn_id"].shape[0]
    Kn = entry.n_nodes
    KC = len(spec.capacities)
    B = 1 if spec.betas is None else len(spec.betas)
    C = max(max(entry.node_caps(c)) for c in spec.capacities)
    router = entry.get_router()

    node_masks = {c: np.stack([np.arange(C) < nc
                               for nc in entry.node_caps(c)])
                  for c in spec.capacities}
    tix = np.repeat(np.arange(T, dtype=np.int32), KC * B)
    masks = np.tile(
        np.repeat(np.stack([node_masks[c] for c in spec.capacities]),
                  B, axis=0), (T, 1, 1))
    L = T * KC * B

    resil = None
    rs_kw = {}
    if rs is not None:
        # substitute the timeout-clipped exec operand and ship the
        # pre-planned outcome operands (all (T, N), trace-indexed via
        # tix inside the engine, so they are chunk-invariant)
        eff, rs_nfail, rs_tmo, rs_key, resil = rs
        stacked = dict(stacked, exec_time=eff)
        rs_kw = dict(rs_nfail=jnp.asarray(rs_nfail, jnp.int32),
                     rs_tmo=jnp.asarray(rs_tmo),
                     rs_key=jnp.asarray(rs_key, jnp.int32))
    shared = tuple(jnp.asarray(stacked[k]) for k in
                   ("fn_id", "arrival", "exec_time", "cold_start",
                    "evict"))
    chunk = resolve_lane_chunk(spec.lane_chunk)
    delays = entry.delays()
    dops = entry.delay_ops()
    var_delay = dops is not None
    horizon = float(stacked["arrival"].max()) if N else 0.0
    churn_t = _churn_operand(entry, horizon)
    has_churn = churn_t is not None
    has_delay = any(delays) or var_delay
    delays_op = jnp.asarray(delays, jnp.float64)
    churn_op = None if churn_t is None else jnp.asarray(churn_t)
    dt_op = dv_op = dp_op = None
    if var_delay:
        dt_op, dv_op, dp_op = (jnp.asarray(o) for o in dops)
    if has_churn:
        timered = [p for p in spec.policies
                   if kernels[p].has_timers]
        if timered:
            raise ValueError(
                f"cluster entry {entry.label!r} declares churn, but "
                f"policies {timered} arm per-request timers — a "
                "drained timer would fire against a dead node. Drop "
                "the policy or the churn schedule")
    dl_op = None if deadlines is None else jnp.asarray(deadlines)
    traced = trace_cells is not None
    if traced:
        from repro.telemetry import rail
    per_policy: Dict[str, Dict[str, np.ndarray]] = {}
    for pi, policy in enumerate(spec.policies):
        beta_l = beta_cols[policy]
        outs: Dict[str, list] = {}
        for lo in range(0, L, chunk):
            hi = min(lo + chunk, L)

            def call():
                return _cluster_metrics(
                    *shared, jnp.asarray(tix[lo:hi]),
                    jnp.asarray(masks[lo:hi]),
                    jnp.asarray(beta_l[lo:hi]),
                    jnp.float64(spec.prior),
                    jnp.float64(spec.threshold),
                    delays_op, churn_op, dt_op, dv_op, dp_op, dl_op,
                    **rs_kw,
                    kernel=kernels[policy], router=router, n_nodes=Kn,
                    n_fns=F, capacity=C, queue_cap=spec.queue_cap,
                    seed=entry.seed, stream=spec.stream,
                    tl_bins=spec.tl_bins, tl_bucket=spec.tl_bucket,
                    has_delay=has_delay, has_churn=has_churn,
                    var_delay=var_delay, resil=resil,
                    keep_responses=spec.keep_per_request,
                    trace=traced)
            if traced:
                with rail.collect() as sink:
                    out = {k: np.asarray(v) for k, v
                           in call().items()}
                for j in range(hi - lo):
                    lane = lo + j
                    t_i, rest = divmod(lane, KC * B)
                    kc, b = divmod(rest, B)
                    trace_cells[(pi, t_i, kc, b)] = \
                        sink.lane_events(j)
            else:
                out = call()
            for k, v in out.items():
                outs.setdefault(k, []).append(np.asarray(v))
        per_policy[policy] = {
            k: np.concatenate(v).reshape((T, KC, B) + v[0].shape[1:])
            for k, v in outs.items()}

    data: Dict[str, np.ndarray] = {}
    for pi, policy in enumerate(spec.policies):
        for m, v in per_policy[policy].items():
            if m not in data:
                data[m] = np.zeros((len(spec.policies),) + v.shape,
                                   v.dtype)
            data[m][pi] = v
    return data


def _pad_node_dim(a: np.ndarray, k_max: int) -> np.ndarray:
    """Right-pad the trailing node axis with zeros to ``k_max``."""
    if a.shape[-1] == k_max:
        return a
    pad = [(0, 0)] * (a.ndim - 1) + [(0, k_max - a.shape[-1])]
    return np.pad(a, pad)


def run_cluster_experiment(spec) -> "ResultSet":
    """Execute a cluster-axed `ExperimentSpec`; see the module
    docstring."""
    import jax

    from repro.api.registry import get_kernel
    from repro.api.results import ResultSet
    from repro.api.runner import _lower_grid, _unique_labels
    from repro.api.runner import run_experiment as _run_plain

    spec.validate()
    sources, stacked, F, N = _lower_grid(spec)
    T = len(sources)
    KC = len(spec.capacities)
    B = 1 if spec.betas is None else len(spec.betas)
    P = len(spec.policies)
    kernels = {p: get_kernel(p) for p in spec.policies}

    def beta_col(policy: str) -> np.ndarray:
        bs = np.asarray(
            [kernels[policy].default_beta] if spec.betas is None
            else list(spec.betas), np.float64)
        return np.tile(bs, T * KC)

    beta_cols = {p: beta_col(p) for p in spec.policies}

    entries = list(spec.cluster)
    k_max = max((e.n_nodes if e is not None else 1) for e in entries)
    deadlines = spec.deadline_ops(F)
    rs = spec.resilience_ops(stacked, F)
    entry_data: List[Dict[str, np.ndarray]] = []
    entry_cells: List[Optional[dict]] = []
    for entry in entries:
        cells = {} if spec.trace_events else None
        if entry is None:
            # devices=1 keeps plain cells on the same (default) device
            # the cluster tiers use — spec.validate() already rejects
            # explicit multi-device cluster runs
            plain = _run_plain(replace(spec, cluster=None, devices=1))
            d = dict(plain.data)
            # recomputed below from the stacked counters so every
            # entry's attainment/goodput comes from the one shared
            # helper
            d.pop("slo_attainment", None)
            d.pop("goodput", None)
            d["node_done"] = d["done"][..., None].astype(np.int32)
            if cells is not None:
                cells.update(plain.trace.cells)
        elif entry.get_router().dynamic:
            d = _run_dynamic_entry(spec, entry, stacked, F, N,
                                   kernels, beta_cols, deadlines, rs,
                                   trace_cells=cells)
        else:
            d = run_static_entry(spec, entry, stacked, F, N, kernels,
                                 beta_cols, deadlines, rs,
                                 trace_cells=cells)
        d["node_done"] = _pad_node_dim(d["node_done"], k_max)
        entry_data.append(d)
        entry_cells.append(cells)

    # ``breaker_trips`` only comes out of breaker-routed dynamic
    # entries; other entries contribute an (exact) all-zero column
    if any("breaker_trips" in d for d in entry_data):
        for d in entry_data:
            d.setdefault("breaker_trips",
                         np.zeros_like(d["done"], np.int64))
    keys = set(entry_data[0])
    for d, entry in zip(entry_data[1:], entries[1:]):
        if set(d) != keys:
            raise RuntimeError(
                f"cluster entries disagree on metrics: "
                f"{sorted(keys ^ set(d))}")
    data = {m: np.stack([d[m] for d in entry_data], axis=4)
            for m in keys}
    if deadlines is not None:
        from repro.core.jax_engine import slo_attainment
        data["slo_attainment"] = slo_attainment(
            data["deadline_miss"], data["done"])
    if rs is not None:
        from repro.core.jax_engine import goodput
        data["goodput"] = goodput(data["done"], N)

    labels = _unique_labels([(e.label if e is not None else "none")
                             for e in entries])
    coords = dict(policy=list(spec.policies),
                  trace=_unique_labels([s.label for s in sources]),
                  capacity=list(spec.capacities),
                  beta=(list(spec.betas) if spec.betas is not None
                        else ["default"]),
                  cluster=labels)
    meta = dict(spec.meta,
                n_requests=N, n_functions=F, queue_cap=spec.queue_cap,
                stream=spec.stream, window=spec.window,
                tl_bins=spec.tl_bins, tl_bucket=spec.tl_bucket,
                prior=spec.prior, threshold=spec.threshold,
                backend=jax.default_backend(),
                resilience=spec.resilience_meta(),
                seeds=(list(spec.seeds) if spec.seeds is not None
                       else None),
                deadlines=(None if spec.deadlines is None else
                           (spec.deadlines
                            if isinstance(spec.deadlines, float)
                            else list(spec.deadlines))),
                cluster=[None if e is None else dict(
                    n_nodes=e.n_nodes, router=e.router,
                    node_capacity=(list(e.node_capacity)
                                   if e.node_capacity is not None
                                   else None),
                    net_delay=list(e.delays()), seed=e.seed,
                    has_churn=e.has_churn(),
                    var_delay=e.delay_ops() is not None)
                    for e in entries],
                trace_events=spec.trace_events,
                default_betas={p: kernels[p].default_beta
                               for p in spec.policies})
    trace_run = None
    if spec.trace_events:
        from repro.telemetry.spans import TraceRun
        trace_run = TraceRun(coords)
        for ei, cells in enumerate(entry_cells):
            for key, ev in (cells or {}).items():
                trace_run.add_cell(key + (ei,), ev)
    return ResultSet(data=data, coords=coords, meta=meta,
                     trace=trace_run)


# ---------------------------------------------------------- audit hooks
def jit_cache_sizes() -> Dict[str, int]:
    """Per-entry-point jit cache sizes for the cluster tier (dynamic
    loop + static-tier merge helper), for `repro.analysis`'s
    recompilation auditor."""
    from repro.cluster import engine as _engine
    from repro.cluster import static as _static
    return {name: fn._cache_size()
            for name, fn in {**_engine.audit_jits(),
                             **_static.audit_jits()}.items()}
