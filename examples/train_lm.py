"""Train a language model on the synthetic pipeline with checkpointing.

Default is a quick CPU demo (~10M params, 60 steps). ``--size 100m
--steps 300`` reproduces the deliverable-scale run on real hardware
(the step function is the same jit'd program the dry-run lowers for the
production mesh).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --size 100m --steps 300
"""
import argparse

from repro.launch.train import train
from repro.configs import get_arch

SIZES = {
    # name -> overrides on the qwen3-4b family (GQA + qk-norm trunk)
    "10m": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                head_dim=64, d_ff=1024, vocab_size=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=sorted(SIZES), default="10m")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--out", default="runs/train_lm")
    args = ap.parse_args()

    import repro.launch.train as T
    from repro.models import build_model
    from repro.models.config import ModelConfig

    overrides = SIZES[args.size]
    cfg = get_arch("qwen3-4b").replace(
        param_dtype="float32", compute_dtype="float32",
        attn_chunk=128, **overrides)
    # monkey-free path: temporarily register as a custom config
    orig = T.get_arch
    T.get_arch = lambda name: cfg
    try:
        _, losses = train("custom", smoke=False, steps=args.steps,
                          global_batch=args.batch, seq_len=args.seq_len,
                          ckpt_every=max(args.steps // 3, 1),
                          out=args.out, log_every=10)
    finally:
        T.get_arch = orig
    n_params = sum(p.size for p in __import__("jax").tree.leaves(
        build_model(cfg).init(__import__("jax").random.key(0))[0]))
    print(f"\n{args.size} model ({n_params/1e6:.1f}M params): "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{args.steps} steps")


if __name__ == "__main__":
    main()
