"""Fleet-scale scheduling sweep on the declarative experiment API:
evaluate a policy x capacity grid plus an ESFF hysteresis scan in a
handful of device calls and print the best configuration — the kind of
fleet-sizing study the Python event engine is too slow for (compare
LaSS, arXiv:2104.14087, which sizes capacity per latency target from
exactly this surface).

    PYTHONPATH=src python examples/sweep_policies.py
"""
import numpy as np

from repro.api import ExperimentSpec, SyntheticTrace, run_experiment

POLICIES = ("esff", "esff_h", "sff", "openwhisk", "faascache",
            "openwhisk_v2")
CAPS = (8, 16, 24, 32)


def main():
    src = SyntheticTrace.make(n_functions=60, n_requests=8_000,
                              seed=4, utilization=0.3)

    # policy x capacity plane (per-policy default betas)
    grid = run_experiment(ExperimentSpec(
        traces=[src], policies=POLICIES, capacities=CAPS,
        queue_cap=2048)).check()
    mr = grid["mean_response"][:, 0, :, 0]          # (P, K)
    print(f"{'policy':>13s} " + " ".join(f"C={c:<5d}" for c in CAPS))
    for pi, p in enumerate(POLICIES):
        print(f"{p:>13s} " + " ".join(f"{v:7.3f}" for v in mr[pi]))
    pi, ci = np.unravel_index(mr.argmin(), mr.shape)
    print(f"\nbest policy/capacity: {POLICIES[pi]} @ C={CAPS[ci]} "
          f"(mean response {mr[pi, ci]:.3f}s)")

    # ESFF hysteresis scan on top of the winning capacity axis
    betas = np.linspace(1.0, 3.0, 6)
    hyst = run_experiment(ExperimentSpec(
        traces=[src], policies=("esff",), capacities=CAPS,
        betas=betas, queue_cap=2048)).check()
    hr = hyst["mean_response"][0, 0]                 # (K, B)
    print(f"\nESFF beta scan ({'x'.join(str(c) for c in CAPS)} caps x "
          f"{len(betas)} betas, one batched call):")
    print(f"{'cap':>4s} " + " ".join(f"b={b:.1f}" for b in betas))
    for c, row in zip(CAPS, hr):
        print(f"{c:4d} " + " ".join(f"{v:5.2f}" for v in row))
    ci, bi = np.unravel_index(hr.argmin(), hr.shape)
    n_cfg = mr.size + hr.size
    print(f"\nbest ESFF config: capacity={CAPS[ci]} beta={betas[bi]:.2f} "
          f"mean response {hr[ci, bi]:.3f}s "
          f"({n_cfg} configs swept on device)")


if __name__ == "__main__":
    main()
