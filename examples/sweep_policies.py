"""Fleet-scale policy sweep on the vectorised JAX simulator: evaluate a
(capacity x hysteresis) grid in a few device calls and print the best
configuration — the kind of fleet-sizing study the Python engine is too
slow for.

    PYTHONPATH=src python examples/sweep_policies.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.jax_sim import simulate_esff_jax
from repro.traces import synth_azure_trace


def main():
    jax.config.update("jax_enable_x64", True)
    tr = synth_azure_trace(n_functions=60, n_requests=8_000,
                           utilization=0.3, seed=4)
    a = tr.to_arrays()
    args = (jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
            jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
            jnp.asarray(a["evict"]))
    C = 32
    caps = (8, 16, 24, 32)
    betas = np.linspace(1.0, 3.0, 6)

    def run(mask, beta):
        out = simulate_esff_jax(*args, n_fns=tr.n_functions, capacity=C,
                                queue_cap=2048, beta=beta, cap_mask=mask)
        return (out["completion"] - jnp.asarray(a["arrival"])).mean()

    sweep = jax.jit(jax.vmap(jax.vmap(run, in_axes=(None, 0)),
                             in_axes=(0, None)))
    masks = jnp.stack([jnp.arange(C) < c for c in caps])
    grid = np.asarray(sweep(masks, jnp.asarray(betas)))

    print(f"{'cap':>4s} " + " ".join(f"b={b:.1f}" for b in betas))
    for c, row in zip(caps, grid):
        print(f"{c:4d} " + " ".join(f"{v:5.2f}" for v in row))
    i, j = np.unravel_index(grid.argmin(), grid.shape)
    print(f"\nbest: capacity={caps[i]} beta={betas[j]:.2f} "
          f"mean response {grid[i, j]:.3f}s "
          f"({grid.size} configs swept on device)")


if __name__ == "__main__":
    main()
