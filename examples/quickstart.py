"""Quickstart: the paper in 60 seconds.

Declares an Azure-like trace source, runs ESFF against the paper's
baselines on a 16-slot edge server through the experiment API
(exact per-request mode, so the P99 column is exact), and prints the
comparison table (paper Fig. 5 at the default capacity).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import ExperimentSpec, SyntheticTrace, run_experiment

POLICIES = ("esff", "esff_h", "sff", "openwhisk", "faascache",
            "openwhisk_v2")


def main():
    src = SyntheticTrace.make(n_functions=200, n_requests=20_000,
                              seed=0, utilization=0.2, exec_median=0.1,
                              exec_sigma=1.4, burst_frac=0.3)
    print(f"trace: {src.n_requests} requests, "
          f"{src.n_functions} functions, "
          f"{src.arrays()['arrival'].max():.0f}s span\n")
    spec = ExperimentSpec(traces=[src], policies=POLICIES,
                          capacities=(16,), queue_cap=4096,
                          stream=False)
    rs = run_experiment(spec).check()
    print(f"{'policy':14s} {'mean resp':>10s} {'slowdown':>10s} "
          f"{'P99':>9s} {'cold starts':>12s}")
    for policy in POLICIES:
        cell = rs.sel(policy=policy)
        print(f"{policy:14s} {cell.value('mean_response'):10.3f} "
              f"{cell.value('mean_slowdown'):10.1f} "
              f"{cell.value('p99_response'):9.2f} "
              f"{int(cell.value('cold_starts')):12d}")
    best_base = min(rs.value("mean_response", policy=p)
                    for p in POLICIES if p not in ("esff", "esff_h"))
    gain = 100 * (1 - rs.value("mean_response", policy="esff")
                  / best_base)
    print(f"\nESFF improves mean response by {gain:.1f}% over the best "
          f"baseline (paper reports 18-40% vs SFF).")


if __name__ == "__main__":
    main()
