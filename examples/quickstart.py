"""Quickstart: the paper in 60 seconds.

Generates an Azure-like trace, runs ESFF against the paper's baselines
on a 16-slot edge server, and prints the comparison table (paper Fig. 5
at the default capacity).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import POLICIES, simulate
from repro.traces import synth_azure_trace


def main():
    trace = synth_azure_trace(n_functions=200, n_requests=20_000,
                              utilization=0.2, exec_median=0.1,
                              exec_sigma=1.4, burst_frac=0.3, seed=0)
    print(f"trace: {len(trace)} requests, {trace.n_functions} functions, "
          f"{trace.meta['duration']:.0f}s span\n")
    print(f"{'policy':14s} {'mean resp':>10s} {'slowdown':>10s} "
          f"{'P99':>9s} {'cold starts':>12s}")
    results = {}
    for policy in ("esff", "esff_h", "sff", "openwhisk", "faascache",
                   "openwhisk_v2"):
        r = simulate(trace.head(len(trace)), policy, capacity=16)
        results[policy] = r
        print(f"{policy:14s} {r.mean_response:10.3f} "
              f"{r.mean_slowdown:10.1f} {r.percentile(99):9.2f} "
              f"{r.server.cold_starts:12d}")
    best_base = min(v.mean_response for k, v in results.items()
                    if k not in ("esff", "esff_h"))
    gain = 100 * (1 - results["esff"].mean_response / best_base)
    print(f"\nESFF improves mean response by {gain:.1f}% over the best "
          f"baseline (paper reports 18-40% vs SFF).")


if __name__ == "__main__":
    main()
