"""End-to-end driver: serve a catalogue of small models with batched
requests under ESFF scheduling — cold starts and execution times are
real JAX measurements, not simulation (the paper's scenario with the
"functions" being actual models).

    PYTHONPATH=src python examples/serve_edge.py --requests 40
"""
import argparse

from repro.launch.serve import default_catalogue
from repro.serving import EdgeServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--capacity", type=int, default=2)
    ap.add_argument("--duration", type=float, default=40.0)
    args = ap.parse_args()

    catalogue = default_catalogue()
    print("deployed functions:",
          ", ".join(f.name for f in catalogue))
    results = {}
    for policy in ("esff", "openwhisk"):
        eng = EdgeServingEngine(catalogue, capacity=args.capacity,
                                policy=policy)
        reqs = eng.make_requests(args.requests, args.duration, seed=1)
        results[policy] = eng.run(reqs)
    print(f"\n{'policy':12s} {'mean resp':>10s} {'P95':>8s} "
          f"{'cold starts':>12s}")
    for policy, r in results.items():
        print(f"{policy:12s} {r.mean_response:10.3f} "
              f"{r.percentile(95):8.2f} {r.server.cold_starts:12d}")
    gain = 100 * (1 - results["esff"].mean_response
                  / results["openwhisk"].mean_response)
    print(f"\nESFF vs OpenWhisk on live models: {gain:+.1f}% mean "
          f"response")


if __name__ == "__main__":
    main()
