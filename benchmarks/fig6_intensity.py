"""Paper Fig. 6: metrics vs workload-intensity ratio (0.6..1.4 interval
scaling; >1 = lighter load).

The ratio axis is declared as `TraceSource.scaled` views of one shared
source, so all six policies evaluate the whole axis as a vmapped trace
batch in one `repro.api.ExperimentSpec` run.
"""
from __future__ import annotations

from benchmarks.common import (CAPACITY, POLICIES,
                               default_trace_source, emit,
                               enable_compilation_cache)
from repro.api import ExperimentSpec, run_experiment

RATIOS = (0.6, 0.8, 1.0, 1.2, 1.4)


def run(seed: int = 0):
    base = default_trace_source(seed)
    traces = [base.scaled(r) for r in RATIOS]
    spec = ExperimentSpec(traces=traces, policies=POLICIES,
                          capacities=(CAPACITY,), queue_cap=4096)
    rs = run_experiment(spec).check()
    n = rs.meta["n_requests"]
    rows = []
    for ratio, label in zip(RATIOS, rs.coords["trace"]):
        for policy in POLICIES:
            cell = rs.sel(policy=policy, trace=label)
            rows.append(dict(
                intensity=ratio, policy=policy,
                mean_response=cell.value("mean_response"),
                mean_slowdown=cell.value("mean_slowdown"),
                cold_time_per_request=cell.value("cold_time") / n,
            ))
    return rows


def main():
    enable_compilation_cache()
    rows = run()
    emit(rows, rows[0].keys())
    return rows


if __name__ == "__main__":
    main()
