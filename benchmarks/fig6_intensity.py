"""Paper Fig. 6: metrics vs workload-intensity ratio (0.6..1.4 interval
scaling; >1 = lighter load).

All intensity scalings share one request-array shape, so all six
policies (FaasCache included) evaluate the whole ratio axis as a
vmapped trace batch in one streaming sweep
(`repro.core.jax_engine.sweep`) — no Python-engine fallback.
"""
from __future__ import annotations

from benchmarks.common import (CAPACITY, POLICIES, default_trace,
                               emit, enable_compilation_cache)
from repro.core.jax_engine import sweep

RATIOS = (0.6, 0.8, 1.0, 1.2, 1.4)


def run(seed: int = 0):
    base = default_trace(seed)
    traces = [base.scaled(r) for r in RATIOS]
    n = len(base)
    vec = sweep(traces, policies=POLICIES, capacities=(CAPACITY,),
                queue_cap=4096)
    if int(vec["overflow"].sum()) or int(vec["stalled"].sum()):
        raise RuntimeError("fig6 sweep overflowed/stalled — raise "
                           "queue_cap")
    rows = []
    for ti, ratio in enumerate(RATIOS):
        for pi, policy in enumerate(POLICIES):
            rows.append(dict(
                intensity=ratio, policy=policy,
                mean_response=float(
                    vec["mean_response"][pi, ti, 0, 0]),
                mean_slowdown=float(
                    vec["mean_slowdown"][pi, ti, 0, 0]),
                cold_time_per_request=float(
                    vec["cold_time"][pi, ti, 0, 0]) / n,
            ))
    return rows


def main():
    enable_compilation_cache()
    rows = run()
    emit(rows, rows[0].keys())
    return rows


if __name__ == "__main__":
    main()
