"""Paper Fig. 6: metrics vs workload-intensity ratio (0.6..1.4 interval
scaling; >1 = lighter load)."""
from __future__ import annotations

from benchmarks.common import (CAPACITY, POLICIES, default_trace, emit,
                               run_policy)

RATIOS = (0.6, 0.8, 1.0, 1.2, 1.4)


def run(seed: int = 0):
    rows = []
    base = default_trace(seed)
    for ratio in RATIOS:
        tr = base.scaled(ratio)
        for policy in POLICIES:
            r = run_policy(tr, policy, CAPACITY)
            rows.append(dict(
                intensity=ratio, policy=policy,
                mean_response=r.mean_response,
                mean_slowdown=r.mean_slowdown,
                cold_time_per_request=r.cold_time_per_request,
            ))
    return rows


def main():
    rows = run()
    emit(rows, rows[0].keys())


if __name__ == "__main__":
    main()
