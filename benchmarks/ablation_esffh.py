"""ESFF-H component ablation: which of the three fixes buys what, per
capacity regime (EXPERIMENTS.md §Repro)."""
from __future__ import annotations

from benchmarks.common import default_trace, emit
from repro.core import simulate
from repro.core.esff_h import ESFFH


def variant(beta=2.0, lru=True, coldcount=True):
    class V(ESFFH):
        pass
    V.beta = beta
    V.lru_victim = lru
    if not coldcount:
        V._drain_estimate = lambda self, fn_id, window: \
            super(ESFFH, self)._drain_estimate(fn_id, window)
    return V()


CONFIGS = [
    ("esff (paper)", dict(beta=1.0, lru=False, coldcount=False)),
    ("+hysteresis", dict(beta=2.0, lru=False, coldcount=False)),
    ("+coldcount", dict(beta=2.0, lru=False, coldcount=True)),
    ("+lru (esff_h)", dict(beta=2.0, lru=True, coldcount=True)),
]


def run(seed: int = 0):
    rows = []
    for cap in (8, 16, 32):
        for name, kw in CONFIGS:
            tr = default_trace(seed)
            r = simulate(tr, variant(**kw), cap)
            rows.append(dict(capacity=cap, variant=name,
                             mean_response=r.mean_response,
                             cold_starts=r.server.cold_starts))
    return rows


def main():
    rows = run()
    emit(rows, rows[0].keys())
    return rows


if __name__ == "__main__":
    main()
