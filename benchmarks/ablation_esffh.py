"""ESFF-H component ablation: which of the three fixes buys what, per
capacity regime (EXPERIMENTS.md §Repro).

Runs entirely on the vectorised engine through one
`repro.api.ExperimentSpec`: the four variants are (policy, beta) cells
of a registered-kernel x beta grid — ``esff`` at beta 1/2 isolates the
hysteresis, an ``esff_cc`` kernel (ESFF + cold-aware drain estimates,
registered here via `repro.api.register_policy`) adds the cold-count
fix, and ``esff_h`` completes the trio with the LRU victim rule. Each
kernel is request-for-request equivalent to the Python policy variants
this benchmark used to loop (`repro.core.esff_h`), so the ablation
table is unchanged — it just runs on engine lanes now.
"""
from __future__ import annotations

from benchmarks.common import (default_trace_source, emit,
                               enable_compilation_cache)
from repro.api import (ExperimentSpec, available_policies,
                       register_policy, run_experiment)

CAPACITIES = (8, 16, 32)

# (variant label, policy cell, beta cell) in fix-accumulation order
CONFIGS = (
    ("esff (paper)", "esff", 1.0),
    ("+hysteresis", "esff", 2.0),
    ("+coldcount", "esff_cc", 2.0),
    ("+lru (esff_h)", "esff_h", 2.0),
)


def _ensure_variant_kernels():
    """Register the ablation-only ESFF variant (idempotent; the
    singleton keeps the engine's jit cache warm across runs)."""
    if "esff_cc" not in available_policies():
        from repro.core.jax_policies import ESFFKernel
        register_policy("esff_cc",
                        ESFFKernel("esff_cc", cold_aware=True))


def run(seed: int = 0):
    _ensure_variant_kernels()
    src = default_trace_source(seed)
    # two specs so only the consumed (policy, beta) cells simulate:
    # esff at both betas isolates the hysteresis; the cc/lru variants
    # only matter at beta=2 (one cross-product spec would waste a
    # third of the lanes on cells the table never reads)
    grid = dict(traces=[src], capacities=CAPACITIES, queue_cap=4096)
    by_policy = {
        ("esff",): run_experiment(ExperimentSpec(
            policies=("esff",), betas=(1.0, 2.0), **grid)).check(),
        ("esff_cc", "esff_h"): run_experiment(ExperimentSpec(
            policies=("esff_cc", "esff_h"), betas=(2.0,),
            **grid)).check(),
    }
    rows = []
    for cap in CAPACITIES:
        for name, policy, beta in CONFIGS:
            rs = next(v for k, v in by_policy.items() if policy in k)
            cell = rs.sel(policy=policy, capacity=cap, beta=beta)
            rows.append(dict(
                capacity=cap, variant=name,
                mean_response=cell.value("mean_response"),
                cold_starts=int(cell.value("cold_starts"))))
    return rows


def main():
    enable_compilation_cache()
    rows = run()
    emit(rows, rows[0].keys())
    return rows


if __name__ == "__main__":
    main()
