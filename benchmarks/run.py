"""Benchmark aggregator — one section per paper table/figure plus kernel
and simulator microbenches. Prints ``name,us_per_call,derived`` CSV
blocks; REPRO_BENCH_SCALE scales trace sizes.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,kernels]
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = ("fig5", "fig6", "fig7", "fig8", "ablation", "kernels",
            "simthroughput")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else set(SECTIONS)

    from benchmarks import (ablation_esffh, fig5_capacity, fig6_intensity,
                            fig7_cdf, fig8_timeline, kernels_bench,
                            sim_throughput)
    mods = dict(fig5=fig5_capacity, fig6=fig6_intensity, fig7=fig7_cdf,
                fig8=fig8_timeline, ablation=ablation_esffh,
                kernels=kernels_bench, simthroughput=sim_throughput)
    for name in SECTIONS:
        if name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        mods[name].main()
        print(f"# section {name}: {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == '__main__':
    main()
