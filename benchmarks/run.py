"""Benchmark aggregator — one section per paper table/figure plus kernel
and simulator microbenches. Prints ``name,us_per_call,derived`` CSV
blocks; REPRO_BENCH_SCALE scales trace sizes.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,kernels]
    PYTHONPATH=src python -m benchmarks.run --smoke   # <60s CI gate

``--smoke`` runs every scheduling policy on a tiny trace through both
engines and exits non-zero on any Python/JAX mismatch — cheap enough to
sit next to tier-1 in CI.
"""
from __future__ import annotations

import argparse
import sys
import time

SECTIONS = ("fig5", "fig6", "fig7", "fig8", "ablation", "kernels",
            "simthroughput")


def smoke() -> int:
    import numpy as np

    from benchmarks.common import POLICIES, VEC_POLICIES
    from repro.core import simulate
    from repro.core.jax_engine import simulate_policy_from_trace
    from repro.traces import synth_azure_trace

    tr = synth_azure_trace(n_functions=12, n_requests=400,
                           utilization=0.25, seed=3)
    capacity = 6
    failures = 0
    for policy in POLICIES:
        py = simulate(tr, policy, capacity)
        line = f"{policy:13s} python={py.mean_response:8.4f}s"
        if policy in VEC_POLICIES:
            jx = simulate_policy_from_trace(tr, policy, capacity,
                                            queue_cap=256)
            resp_py = np.array([r.response for r in tr.requests])
            ok = (int(jx["overflow"]) == 0
                  and int(jx["stalled"]) == 0
                  and int(jx["cold_starts"]) == py.server.cold_starts
                  and np.allclose(jx["response"], resp_py, rtol=1e-9,
                                  atol=1e-9))
            failures += 0 if ok else 1
            line += (f"  jax={jx['mean_response']:8.4f}s  "
                     + ("OK" if ok else "MISMATCH"))
        else:
            line += "  (python engine only)"
        print(line)
    print(f"# smoke: {len(POLICIES)} policies, "
          f"{len(VEC_POLICIES)} engine-equivalence checks, "
          f"{failures} failures")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace, all policies, both engines; "
                         "exits non-zero on mismatch (<60s)")
    args = ap.parse_args()
    if args.smoke:
        t0 = time.perf_counter()
        failures = smoke()
        print(f"# smoke total: {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
        sys.exit(1 if failures else 0)
    only = set(args.only.split(",")) if args.only else set(SECTIONS)

    from benchmarks import (ablation_esffh, fig5_capacity, fig6_intensity,
                            fig7_cdf, fig8_timeline, kernels_bench,
                            sim_throughput)
    mods = dict(fig5=fig5_capacity, fig6=fig6_intensity, fig7=fig7_cdf,
                fig8=fig8_timeline, ablation=ablation_esffh,
                kernels=kernels_bench, simthroughput=sim_throughput)
    for name in SECTIONS:
        if name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        mods[name].main()
        print(f"# section {name}: {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == '__main__':
    main()
