"""Benchmark aggregator — one section per paper table/figure plus kernel
and simulator microbenches. Prints ``name,us_per_call,derived`` CSV
blocks; REPRO_BENCH_SCALE scales trace sizes. Every section run also
emits a machine-readable ``BENCH_<stamp>.json`` (per-section wall time
plus each section's rows — req/s per config for the throughput and
engine-scale sections) so the perf trajectory is tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,kernels]
    PYTHONPATH=src python -m benchmarks.run --smoke   # <60s CI gate
    PYTHONPATH=src python -m benchmarks.run --baseline BENCH_x.json

``--smoke`` runs every scheduling policy on a tiny trace through both
engines and exits non-zero on any Python/JAX mismatch — including the
streaming-vs-exact gate (bitwise-equal means, p99 within one histogram
bin), the ``sweep()``-shim bitwise-parity gate against the
`repro.api.ExperimentSpec` path, the resilience gates (trivial fault
knobs lower bitwise onto the unchanged engine; faults + load shedding
conserve every request; the circuit breaker trips and recovers), a
forced 2-device CPU subprocess
(``--xla_force_host_platform_device_count=2``) asserting the sharded
runner is bitwise-identical to single-device, and a static scan that
fails on DeprecationWarning-free use of the old entry points
(``sweep`` imports / ``REPRO_AZURE_NPZ``) creeping back into
benchmarks/examples/src — cheap enough to sit next to tier-1 in CI.

``--baseline`` compares this run's per-row ``req_s`` against a
previous BENCH json and exits non-zero if any matching row dropped
more than 20% (``--regress-tol``) — the perf counterpart of the smoke
gate: run ``--smoke`` for correctness, then
``--only enginescale,simthroughput --baseline <last BENCH json>`` to
catch throughput regressions before merging.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

SECTIONS = ("fig5", "fig6", "fig7", "fig8", "ablation", "cluster",
            "churn", "resilience", "kernels", "simthroughput",
            "enginescale", "telemetry")


def smoke() -> int:
    import warnings

    import numpy as np

    from benchmarks.common import POLICIES
    from repro.api import ExperimentSpec, SyntheticTrace, run_experiment
    from repro.core import simulate
    from repro.core.jax_engine import (hist_edges,
                                       simulate_policy_from_trace,
                                       sweep)

    src = SyntheticTrace.make(n_functions=12, n_requests=400,
                              utilization=0.25, seed=3)
    tr = src.to_trace()
    capacity = 6
    failures = 0
    for policy in POLICIES:
        py = simulate(tr, policy, capacity)
        jx = simulate_policy_from_trace(tr, policy, capacity,
                                        queue_cap=256)
        resp_py = np.array([r.response for r in tr.requests])
        ok = (int(jx["overflow"]) == 0
              and int(jx["stalled"]) == 0
              and int(jx["cold_starts"]) == py.server.cold_starts
              and np.allclose(jx["response"], resp_py, rtol=1e-9,
                              atol=1e-9))
        failures += 0 if ok else 1
        print(f"{policy:13s} python={py.mean_response:8.4f}s  "
              f"jax={jx['mean_response']:8.4f}s  "
              + ("OK" if ok else "MISMATCH"))

    # streaming-vs-exact equivalence gate on the ExperimentSpec grid:
    # identical fold path => means must agree bitwise; histogram p99
    # within one log bin of exact
    bin_ratio = hist_edges()[1] / hist_edges()[0]
    grid = dict(traces=[src], policies=POLICIES,
                capacities=(capacity,), queue_cap=256)
    exact = run_experiment(ExperimentSpec(stream=False, **grid))
    strm = run_experiment(ExperimentSpec(stream=True, **grid))
    ok = (np.array_equal(strm["mean_response"],
                         exact["mean_response"])
          and np.array_equal(strm["mean_slowdown"],
                             exact["mean_slowdown"])
          and bool(np.all(strm["p99_response"]
                          <= exact["p99_response"] * bin_ratio + 1e-12))
          and bool(np.all(strm["p99_response"]
                          >= exact["p99_response"] / bin_ratio - 1e-12)))
    failures += 0 if ok else 1
    print("stream-vs-exact: means "
          + ("bitwise-equal, p99 within one bin  OK" if ok
             else "MISMATCH"))

    # sweep() deprecation shim: must warn, and must be bitwise-equal
    # to the ExperimentSpec path it now wraps
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        legacy = sweep(tr, policies=POLICIES, capacities=(capacity,),
                       queue_cap=256, stream=True)
    warned = any(issubclass(w.category, DeprecationWarning)
                 for w in caught)
    parity = all(np.array_equal(legacy[k], strm[k])
                 for k in strm.data)
    failures += 0 if (warned and parity) else 1
    print("sweep() shim: "
          + ("DeprecationWarning + bitwise parity  OK"
             if warned and parity else
             f"MISMATCH (warned={warned}, parity={parity})"))

    # K=1 cluster gate: a 1-node cluster with zero network delay must
    # be bitwise the single-node engine — through the static
    # sub-stream fast path AND the dynamic routers' K-node event loop,
    # timer-rail policies (openwhisk_v2) included
    from repro.api import ClusterSpec
    cl_policies = ("esff", "sff", "openwhisk_v2")
    cl = run_experiment(ExperimentSpec(
        traces=[src], policies=cl_policies, capacities=(capacity,),
        queue_cap=256,
        cluster=[ClusterSpec(n_nodes=1, router="hash"),
                 ClusterSpec(n_nodes=1, router="jsq2"),
                 ClusterSpec(n_nodes=1, router="cold_aware")]))
    ref = run_experiment(ExperimentSpec(
        traces=[src], policies=cl_policies,
        capacities=(capacity,), queue_cap=256))
    ok = all(
        np.array_equal(ref.data[m], np.take(cl.data[m], u, axis=4))
        for u in range(len(cl.coords["cluster"])) for m in ref.data)
    failures += 0 if ok else 1
    print("cluster K=1 (static + dynamic, incl. timer rail): "
          + ("bitwise-identical to single node  OK" if ok
             else "MISMATCH"))

    # dynamic-tier conservation: openwhisk_v2 over a 3-node jsq2
    # cluster with heterogeneous per-node delays must complete every
    # request exactly once (no overflow, no stalls, node_done sums to
    # done) — the deferred-event rail cannot drop or duplicate work
    cv = run_experiment(ExperimentSpec(
        traces=[src], policies=("openwhisk_v2",),
        capacities=(capacity,), queue_cap=256,
        cluster=[ClusterSpec(n_nodes=3, router="jsq2",
                             net_delay=(0.0, 0.002, 0.005))]))
    done = cv.data["done"]
    ok = (bool(np.all(done == src.n_requests))
          and not np.any(cv.data["overflow"])
          and not np.any(cv.data["stalled"])
          and bool(np.all(cv.data["node_done"].sum(axis=-1) == done)))
    failures += 0 if ok else 1
    print("dynamic openwhisk_v2 + net_delay conservation: "
          + ("every request completes exactly once  OK" if ok
             else "MISMATCH"))

    # churn gates: the fault-injection rail must (a) conserve every
    # request across mid-flight node deaths, (b) lower trivial
    # always-up schedules onto the plain dynamic loop bitwise, and
    # (c) park arrivals while every node is down and drain them all
    # once a node returns
    from repro.api import PeriodicChurn
    arr = src.arrays()["arrival"]
    t30, t60 = (float(np.quantile(arr, q)) for q in (0.30, 0.60))
    ck = dict(traces=[src], policies=("esff",),
              capacities=(capacity,), queue_cap=256)
    churned = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=3, router="jsq2",
                             churn=(None, ((t30, t60),), None))],
        **ck))
    done = churned.data["done"]
    ok = (bool(np.all(done == src.n_requests))
          and not np.any(churned.data["overflow"])
          and not np.any(churned.data["stalled"])
          and bool(np.all(
              churned.data["node_done"].sum(axis=-1) == done)))
    failures += 0 if ok else 1
    print("churn conservation (mid-window node death): "
          + ("every request completes exactly once  OK" if ok
             else "MISMATCH"))

    plain1 = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=1, router="jsq2")], **ck))
    triv = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(
            n_nodes=1, router="jsq2",
            churn=(PeriodicChurn(period=10.0, duty=1.0),))], **ck))
    ok = all(np.array_equal(plain1.data[m], triv.data[m])
             for m in plain1.data)
    failures += 0 if ok else 1
    print("trivial churn lowering (K=1, duty=1.0): "
          + ("bitwise-identical to plain dynamic loop  OK" if ok
             else "MISMATCH"))

    t45 = float(np.quantile(arr, 0.45))
    alldown = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=2, router="jsq2",
                             churn=(((t30, t45),), ((t30, t45),)))],
        keep_per_request=True, stream=False, **ck))
    resp = np.asarray(alldown.data["response"]).reshape(-1)[
        : src.n_requests]
    inside = (arr >= t30) & (arr < t45)
    done = alldown.data["done"]
    ok = (bool(np.all(done == src.n_requests))
          and not np.any(alldown.data["overflow"])
          and bool(np.all(arr[inside] + resp[inside] >= t45)))
    failures += 0 if ok else 1
    print("all-down window parks and resumes: "
          + ("parked arrivals complete after the window  OK" if ok
             else "MISMATCH"))

    # resilience gates: the request-level fault rail must (a) leave
    # trivial-knob specs on the unchanged code path bitwise, (b)
    # conserve every request as exactly one of done/shed/
    # failed-exhausted under faults + load shedding across the
    # dynamic AND static tiers, and (c) trip the circuit breaker
    # under a high failure rate and keep completing work afterwards
    from repro.api import RetryPolicy
    rk = dict(traces=[src], policies=("esff",),
              capacities=(capacity,), queue_cap=256,
              cluster=(None, ClusterSpec(n_nodes=2, router="hash"),
                       ClusterSpec(n_nodes=2, router="jsq2")))
    r0 = run_experiment(ExperimentSpec(**rk))
    r1 = run_experiment(ExperimentSpec(
        **rk, fail_prob=0.0, timeouts=None, on_overflow="error"))
    ok = (set(r0.data) == set(r1.data)
          and all(np.array_equal(r0.data[m], r1.data[m])
                  for m in r0.data)
          and "shed" not in r0.data)
    failures += 0 if ok else 1
    print("trivial fault knobs: "
          + ("lower onto the unchanged engine bitwise  OK" if ok
             else "MISMATCH"))

    faults = dict(fail_prob=0.2, timeouts=8.0, fail_seed=99,
                  retry=RetryPolicy(max_attempts=3, base=0.05,
                                    cap=1.0, jitter=0.3),
                  on_overflow="shed")
    sh = run_experiment(ExperimentSpec(
        traces=[src], policies=("esff",), capacities=(capacity,),
        queue_cap=8, **faults,
        cluster=(None, ClusterSpec(n_nodes=2, router="hash"),
                 ClusterSpec(n_nodes=2, router="jsq2")))).check()
    tot = (sh.data["done"] + sh.data["shed"]
           + sh.data["failed_exhausted"])
    ok = (bool(np.all(tot == src.n_requests))
          and bool(np.all(sh.data["goodput"]
                          == sh.data["done"] / src.n_requests)))
    failures += 0 if ok else 1
    print("shed-mode conservation (dynamic + static tiers): "
          + ("done+shed+failed_exhausted == N  OK" if ok
             else "MISMATCH"))

    br = run_experiment(ExperimentSpec(
        traces=[src], policies=("esff",), capacities=(capacity,),
        queue_cap=256, **dict(faults, fail_prob=0.6),
        cluster=[ClusterSpec(n_nodes=4, router="breaker")])).check()
    trips = int(br.data["breaker_trips"].sum())
    tot = (br.data["done"] + br.data["shed"]
           + br.data["failed_exhausted"])
    ok = (trips > 0 and int(br.data["done"].sum()) > 0
          and bool(np.all(tot == src.n_requests)))
    failures += 0 if ok else 1
    print("breaker trips and recovers: "
          + (f"{trips} trips, work still completes  OK" if ok
             else "MISMATCH"))

    # NpzTrace round-trip: save_npz -> NpzTrace -> run must match the
    # in-memory source bitwise (keeps the real-Azure path covered in
    # containers without the dataset)
    import tempfile

    from repro.api import NpzTrace
    with tempfile.TemporaryDirectory() as td:
        npz_path = os.path.join(td, "smoke_trace.npz")
        tr.save_npz(npz_path)
        kw = dict(policies=("esff",), capacities=(capacity,),
                  queue_cap=256)
        via_npz = run_experiment(ExperimentSpec(
            traces=[NpzTrace(path=npz_path)], **kw))
        direct = run_experiment(ExperimentSpec(traces=[src], **kw))
    ok = all(np.array_equal(via_npz.data[m], direct.data[m])
             for m in direct.data)
    failures += 0 if ok else 1
    print("npz trace round-trip: "
          + ("save_npz -> NpzTrace bitwise  OK" if ok
             else "MISMATCH"))

    # telemetry gates: (a) trace_events=False is the default and
    # trace_events=True must leave every metric bitwise unchanged on
    # every tier (plain + static + dynamic cluster) — the rail only
    # *observes*; (b) the traced event stream must conserve work:
    # one ARRIVAL per request, one EXEC-done per completion, span
    # reassembly agreeing with the done counters; (c) the Perfetto
    # export must validate (the written file is the CI trace artifact)
    from repro.telemetry import save_trace, validate_trace
    tk = dict(traces=[src], policies=("esff",),
              capacities=(capacity,), queue_cap=256,
              cluster=(None, ClusterSpec(n_nodes=2, router="hash"),
                       ClusterSpec(n_nodes=2, router="jsq2")))
    t0r = run_experiment(ExperimentSpec(**tk))
    t1r = run_experiment(ExperimentSpec(**tk, trace_events=True))
    ok = all(np.array_equal(t0r.data[m], t1r.data[m])
             for m in t0r.data)
    failures += 0 if ok else 1
    print("disabled/enabled tracing: "
          + ("metrics bitwise unchanged on all tiers  OK" if ok
             else "MISMATCH"))
    ok = True
    for lab in t1r.coords["cluster"]:
        ev = t1r.trace.events(cluster=lab)
        spans = t1r.trace.spans(cluster=lab)
        dn = int(t0r.value("done", cluster=lab))
        ok = (ok and int((ev["kind"] == 0).sum()) == src.n_requests
              and int((ev["kind"] == 1).sum()) == dn
              and sum(1 for s in spans.values()
                      if s.completion >= 0) == dn)
    try:
        n_ev = validate_trace(save_trace(
            t1r.trace.events(cluster=t1r.coords["cluster"][-1]),
            "trace_sample_perfetto.json", label="smoke"))
    except ValueError:
        ok, n_ev = False, 0
    failures += 0 if ok else 1
    print("traced-run conservation + Perfetto schema: "
          + (f"spans match done counters, {n_ev} trace events  OK"
             if ok else "MISMATCH"))

    failures += _sharded_parity_check()
    failures += deprecation_scan()
    print(f"# smoke: {len(POLICIES)} policies, "
          f"{len(POLICIES)} engine-equivalence checks + streaming, "
          f"shim-parity, cluster-K=1 (incl. timer rail), dynamic "
          f"conservation, churn (conservation, trivial lowering, "
          f"all-down park), resilience (trivial lowering, shed "
          f"conservation, breaker), telemetry (bitwise-off, "
          f"conservation, Perfetto), npz round-trip, 2-device and "
          f"deprecation gates, {failures} failures")
    return failures


_SHARDED_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=2")
import numpy as np
import jax
from repro.api import ExperimentSpec, SyntheticTrace, run_experiment
assert len(jax.local_devices()) >= 2, jax.local_devices()
src = SyntheticTrace.make(n_functions=12, n_requests=400, seed=3,
                          utilization=0.25)
kw = dict(traces=[src], policies=("esff", "sff"), capacities=(4, 6),
          queue_cap=256, lane_chunk=2)
one = run_experiment(ExperimentSpec(devices=1, **kw))
two = run_experiment(ExperimentSpec(devices=2, **kw))
assert two.meta["n_devices"] == 2
for k in one.data:
    assert np.array_equal(one.data[k], two.data[k]), k
print("SHARDED_OK")
"""


def _sharded_parity_check() -> int:
    """Forced 2-CPU-device subprocess: the sharded runner must produce
    bitwise-identical ResultSet metrics to the single-device run."""
    import subprocess

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                       env=env, cwd=root, capture_output=True,
                       text=True, timeout=600)
    ok = r.returncode == 0 and "SHARDED_OK" in r.stdout
    print("2-device sharded parity: " + ("OK" if ok else "MISMATCH"))
    if not ok:
        print(r.stdout[-2000:] + r.stderr[-2000:], file=sys.stderr)
    return 0 if ok else 1


def deprecation_scan() -> int:
    """Fail on use of the old driving surface (importing ``sweep``
    from the engine, the ``REPRO_AZURE_NPZ`` env var, benchmarks
    driving the Python event engine) anywhere in benchmarks/,
    examples/, scripts/ or src/ — tests are exempt (they exercise the
    shim deliberately).

    Since PR 9 this delegates to the AST-level lint in
    `repro.analysis.lint` (same allowlists, same one-line-per-hit
    failure surface): real import statements, attribute calls and
    string constants are matched structurally, so prose can't
    false-positive and a reformatted import can't dodge the gate."""
    from repro.analysis.lint import scan

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    bad = scan(root)
    print("deprecation scan: " + ("OK" if not bad
                                  else f"{bad} hit(s)"))
    return bad


def _provenance() -> dict:
    """Run-provenance metadata folded into every BENCH report (and
    from there into BENCH_history.jsonl): backend/device, jax
    version, x64 flag and the engines' jit cache sizes — enough to
    tell apart rows produced on different machines or lowering
    configurations when reading the perf trajectory."""
    from repro.telemetry import provenance
    return provenance()


def append_history(path: str, report: dict) -> None:
    """Append one compact summary row of ``report`` to the cumulative
    ``BENCH_history.jsonl`` — one json object per line, so the perf
    trajectory across PRs is a single greppable file (CI appends to a
    persisted copy on every run)."""
    row = dict(stamp=report.get("stamp"),
               smoke=bool(report.get("smoke", False)),
               failures=report.get("failures"),
               wall_s=report.get("wall_s"),
               backend=report.get("provenance", {}).get("backend"),
               req_s={f"{sec}/{r['name']}": round(float(r["req_s"]))
                      for sec, sd in report.get("sections", {}).items()
                      for r in sd.get("rows", [])
                      if isinstance(r, dict) and "req_s" in r
                      and r.get("name")})
    with open(path, "a") as f:
        f.write(json.dumps(row, default=str) + "\n")
    print(f"# appended history row to {path}", file=sys.stderr)


def check_regression(baseline_path: str, report: dict,
                     tol: float = 0.20) -> int:
    """Compare ``req_s`` rows against a baseline BENCH json.

    Rows are matched by section + ``name``; a row is a regression when
    its req/s falls below ``(1 - tol)`` of the baseline's. Returns the
    number of regressed rows (and prints each)."""
    with open(baseline_path) as f:
        base = json.load(f)
    regressions = checked = 0
    for sec, sdata in report.get("sections", {}).items():
        brows = {r["name"]: r
                 for r in base.get("sections", {})
                           .get(sec, {}).get("rows", [])
                 if isinstance(r, dict) and "name" in r
                 and "req_s" in r}
        for r in sdata.get("rows", []):
            if not (isinstance(r, dict) and "req_s" in r
                    and r.get("name")):
                continue
            if r["name"] not in brows:
                # new rows (fresh benchmarks, renamed configs) have
                # no baseline yet — warn and skip instead of silently
                # ignoring or failing the gate
                print(f"BASELINE MISSING {sec}/{r['name']}: not in "
                      f"{baseline_path} — skipping (new row?)",
                      file=sys.stderr)
                continue
            checked += 1
            now = float(r["req_s"])
            was = float(brows[r["name"]]["req_s"])
            if now < (1.0 - tol) * was:
                regressions += 1
                print(f"REGRESSION {sec}/{r['name']}: "
                      f"{now:.0f} req/s vs baseline {was:.0f} "
                      f"(-{100 * (1 - now / was):.0f}%)",
                      file=sys.stderr)
    if checked == 0:
        # a gate that compared nothing must not pass silently (row
        # renames / --only selections without req_s rows would turn it
        # vacuous and let real regressions ship)
        print(f"REGRESSION GATE VACUOUS: no req_s rows of this run "
              f"matched {baseline_path} — treating as failure",
              file=sys.stderr)
        return 1
    print(f"# baseline check vs {baseline_path}: {checked} rows, "
          f"{regressions} regression(s) beyond {tol:.0%}",
          file=sys.stderr)
    return regressions


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace, all policies, both engines; "
                         "exits non-zero on mismatch (<60s)")
    ap.add_argument("--json", default="",
                    help="path of the BENCH json report "
                         "(default BENCH_<stamp>.json)")
    ap.add_argument("--baseline", default="",
                    help="previous BENCH json; exit non-zero if any "
                         "section row's req_s drops > --regress-tol")
    ap.add_argument("--regress-tol", type=float, default=0.20,
                    help="allowed fractional req/s drop (default 0.20)")
    ap.add_argument("--history", default="",
                    help="append a one-line summary of this run to a "
                         "cumulative BENCH_history.jsonl")
    args = ap.parse_args()
    from benchmarks.common import enable_compilation_cache
    enable_compilation_cache()
    if args.smoke:
        import contextlib
        import io

        class _Tee(io.TextIOBase):
            def write(self, s):
                sys.__stdout__.write(s)
                buf.write(s)
                return len(s)

            def flush(self):
                sys.__stdout__.flush()

        t0 = time.perf_counter()
        buf = io.StringIO()
        with contextlib.redirect_stdout(_Tee()):
            failures = smoke()
        wall = time.perf_counter() - t0
        print(f"# smoke total: {wall:.1f}s", file=sys.stderr)
        # machine-readable gate report: CI uploads it as an artifact
        # so the smoke trajectory (gates + wall) is tracked per run
        report = dict(stamp=time.strftime("%Y%m%d_%H%M%S"),
                      smoke=True, wall_s=round(wall, 1),
                      failures=failures,
                      provenance=_provenance(),
                      gates=[ln for ln in buf.getvalue().splitlines()
                             if ln and not ln.startswith("#")])
        path = args.json or f"BENCH_smoke_{report['stamp']}.json"
        with open(path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {path}", file=sys.stderr)
        if args.history:
            append_history(args.history, report)
        sys.exit(1 if failures else 0)
    only = set(args.only.split(",")) if args.only else set(SECTIONS)

    from benchmarks import (ablation_esffh, engine_scale, fig5_capacity,
                            fig6_intensity, fig7_cdf, fig8_timeline,
                            fig_churn, fig_cluster, fig_resilience,
                            kernels_bench, sim_throughput,
                            telemetry_bench)
    scale = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
    mods = dict(fig5=fig5_capacity.main, fig6=fig6_intensity.main,
                fig7=fig7_cdf.main, fig8=fig8_timeline.main,
                ablation=ablation_esffh.main,
                cluster=lambda: fig_cluster.main(
                    ["--quick"] if scale < 1.0 else []),
                churn=lambda: fig_churn.main(
                    ["--quick"] if scale < 1.0 else []),
                resilience=lambda: fig_resilience.main(
                    ["--quick"] if scale < 1.0 else []),
                kernels=kernels_bench.main,
                simthroughput=sim_throughput.main,
                # scaled-down aggregate runs skip the 10^6 tier
                enginescale=lambda: engine_scale.main(
                    ["--quick"] if scale < 1.0 else []),
                telemetry=lambda: telemetry_bench.main(
                    ["--n", str(max(int(30_000 * scale), 2_000))]))
    report = dict(stamp=time.strftime("%Y%m%d_%H%M%S"), scale=scale,
                  provenance=_provenance(), sections={})
    for name in SECTIONS:
        if name not in only:
            continue
        print(f"\n===== {name} =====")
        t0 = time.perf_counter()
        rows = mods[name]()
        wall = time.perf_counter() - t0
        print(f"# section {name}: {wall:.1f}s", file=sys.stderr)
        report["sections"][name] = dict(
            wall_s=round(wall, 3),
            rows=rows if isinstance(rows, list) else [])
    path = args.json or f"BENCH_{report['stamp']}.json"
    with open(path, "w") as f:
        json.dump(report, f, indent=1, default=str)
    print(f"# wrote {path}", file=sys.stderr)
    if args.history:
        append_history(args.history, report)
    if args.baseline:
        sys.exit(1 if check_regression(args.baseline, report,
                                       args.regress_tol) else 0)


if __name__ == '__main__':
    main()
