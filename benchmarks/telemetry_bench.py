"""Telemetry overhead and profiling-hook microbench.

Times the same grid with tracing off and on, reports the in-loop
trace rail's overhead (the disabled path is *bitwise free* — gated in
``--smoke`` — so the interesting number is the enabled path's cost:
one record scatter per event plus one ordered host flush per
segment), and exercises the profiling hooks: AOT phase breakdown of
the traced engine call and run provenance for the BENCH report.

    PYTHONPATH=src python -m benchmarks.telemetry_bench [--n N]
"""
from __future__ import annotations

import argparse

from benchmarks.common import (bench_repeats, default_trace_source,
                               emit, enable_compilation_cache, timed)
from repro.api import ExperimentSpec, run_experiment
from repro.telemetry import provenance, save_trace

N_REQUESTS = 30_000
CAPACITY = 16


def run(n: int = N_REQUESTS, trace_json: str = ""):
    src = default_trace_source(seed=0, n_requests=n)
    src.arrays()
    rows = []
    rs_traced = None
    for traced in (False, True):
        spec = ExperimentSpec(traces=[src], policies=("esff",),
                              capacities=(CAPACITY,),
                              queue_cap=1 << 17, stream=True,
                              trace_events=traced)
        run_experiment(spec)                      # warm the jit cache
        rs, dt = timed(run_experiment, spec,
                       repeats=bench_repeats(n))
        rs.check()
        if traced:
            rs_traced = rs
        rows.append(dict(
            name=f"esff_N{n}_{'traced' if traced else 'untraced'}",
            n_requests=n, us_per_call=dt * 1e6, req_s=n / dt,
            events=(rs.trace.n_events if traced else 0),
            derived=f"{n / dt:.0f} req/s "
                    + ("(trace rail on)" if traced else "(baseline)")))
    base, tr = rows[0]["req_s"], rows[1]["req_s"]
    rows.append(dict(name=f"esff_N{n}_overhead", n_requests=n,
                     us_per_call=0.0, req_s=tr, events=rows[1]["events"],
                     derived=f"enabled-tracing overhead "
                             f"{100 * (base / tr - 1):.0f}% "
                             f"({rows[1]['events']} events)"))
    if trace_json and rs_traced is not None:
        ev = rs_traced.trace.events(policy="esff")
        save_trace(ev, trace_json, label=f"esff_N{n}")
    return rows


def main(argv=None):
    enable_compilation_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=N_REQUESTS)
    ap.add_argument("--trace-json", default="",
                    help="also export the traced run as Perfetto "
                         "trace_event JSON")
    args = ap.parse_args(argv)
    rows = run(n=args.n, trace_json=args.trace_json)
    emit(rows, ("name", "n_requests", "us_per_call", "req_s",
                "events", "derived"))
    prov = provenance()
    print(f"# provenance: backend={prov['backend']} "
          f"x64={prov['x64']} jit_caches={prov['jit_cache_sizes']}")
    return rows


if __name__ == "__main__":
    main()
