"""Scheduler-simulation throughput: Python event engine vs the
vectorised JAX engine — single runs, a hysteresis vmap sweep, and the
headline batched policy x capacity grid (one `repro.api.ExperimentSpec`
run, streaming-metrics mode) against looping the Python engine over
the same grid."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, enable_compilation_cache, timed
from repro.api import ExperimentSpec, run_experiment
from repro.core import simulate
from repro.core.jax_sim import simulate_esff_jax
from repro.traces import synth_azure_trace

GRID_POLICIES = ("esff", "sff", "openwhisk")
GRID_CAPS = (8, 12, 16, 24)
GRID_SEEDS = (2, 3, 4, 5)


def run():
    rows = []
    tr = synth_azure_trace(n_functions=50, n_requests=5_000,
                           utilization=0.2, seed=2)
    t0 = time.perf_counter()
    simulate(tr, "esff", capacity=16)
    t_py = time.perf_counter() - t0
    rows.append(dict(name="python_event_engine_5k",
                     us_per_call=t_py * 1e6,
                     req_s=len(tr) / t_py,
                     derived=f"{len(tr) / t_py:.0f} req/s"))

    a = tr.to_arrays()
    args = (jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
            jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
            jnp.asarray(a["evict"]))
    kw = dict(n_fns=tr.n_functions, capacity=16, queue_cap=1024)
    jax.block_until_ready(simulate_esff_jax(*args, **kw)["completion"])
    # best-of-3: at ~16 ms per pass single runs are far too noisy for
    # the regression gate (±30% observed under shared CPUs)
    _, t_jx = timed(
        lambda: jax.block_until_ready(
            simulate_esff_jax(*args, **kw)["completion"]))
    rows.append(dict(name="jax_sim_5k", us_per_call=t_jx * 1e6,
                     req_s=len(tr) / t_jx,
                     derived=f"{len(tr) / t_jx:.0f} req/s"))

    # vmap sweep: 8 hysteresis betas in one device call
    betas = np.linspace(1.0, 3.0, 8)

    def run_beta(beta):
        return simulate_esff_jax(*args, beta=beta, **kw)["completion"]

    sweep_b = jax.jit(jax.vmap(run_beta))
    jax.block_until_ready(sweep_b(jnp.asarray(betas)))
    _, t_sw = timed(                 # best-of, same noise rationale
        lambda: jax.block_until_ready(sweep_b(jnp.asarray(betas))))
    rows.append(dict(
        name="jax_sim_vmap8_sweep", us_per_call=t_sw * 1e6,
        req_s=8 * len(tr) / t_sw,
        derived=f"{8 * len(tr) / t_sw:.0f} req/s aggregate"))

    # batched policy x capacity x seed grid: the fleet-sizing workload.
    # The Python engine loops the grid; the JAX engine packs each
    # policy's capacity x trace plane into engine lanes (streaming
    # metrics — carried state independent of trace length).
    grid_traces = [synth_azure_trace(n_functions=50, n_requests=5_000,
                                     utilization=0.2, seed=s)
                   for s in GRID_SEEDS]
    n_cfg = len(GRID_POLICIES) * len(GRID_CAPS) * len(grid_traces)
    n_req = n_cfg * len(tr)
    t_py_grid = float("inf")
    for _ in range(2):          # best-of: single passes are ±10% noisy
        t0 = time.perf_counter()
        for p in GRID_POLICIES:
            for c in GRID_CAPS:
                for g in grid_traces:
                    simulate(g, p, capacity=c)
        t_py_grid = min(t_py_grid, time.perf_counter() - t0)
    agg_py = n_req / t_py_grid
    rows.append(dict(
        name=f"python_grid_{n_cfg}cfg", us_per_call=t_py_grid * 1e6,
        req_s=agg_py,
        derived=f"{agg_py:.0f} req/s aggregate"))

    grid_spec = ExperimentSpec(traces=grid_traces,
                               policies=GRID_POLICIES,
                               capacities=GRID_CAPS, queue_cap=1024)
    run_experiment(grid_spec)   # warm the compile cache
    t_jx_grid = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        out = run_experiment(grid_spec)
        t_jx_grid = min(t_jx_grid, time.perf_counter() - t0)
    out.check()
    agg_jx = n_req / t_jx_grid
    rows.append(dict(
        name=f"jax_sweep_grid_{n_cfg}cfg", us_per_call=t_jx_grid * 1e6,
        req_s=agg_jx,
        derived=f"{agg_jx:.0f} req/s aggregate"))
    rows.append(dict(
        name="grid_speedup_jax_vs_python", us_per_call=0.0,
        req_s=0.0,
        derived=f"{agg_jx / agg_py:.1f}x aggregate throughput"))
    return rows


def main():
    enable_compilation_cache()
    rows = run()
    emit(rows, ("name", "us_per_call", "req_s", "derived"))
    return rows


if __name__ == "__main__":
    main()
