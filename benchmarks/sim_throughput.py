"""Scheduler-simulation throughput: Python event engine vs the
vectorised JAX simulator (single trace + vmap'd parameter sweep)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import simulate
from repro.core.jax_sim import simulate_esff_jax
from repro.traces import synth_azure_trace


def run():
    jax.config.update("jax_enable_x64", True)
    rows = []
    tr = synth_azure_trace(n_functions=50, n_requests=5_000,
                           utilization=0.2, seed=2)
    t0 = time.perf_counter()
    simulate(tr, "esff", capacity=16)
    t_py = time.perf_counter() - t0
    rows.append(dict(name="python_event_engine_5k",
                     us_per_call=t_py * 1e6,
                     derived=f"{len(tr) / t_py:.0f} req/s"))

    a = tr.to_arrays()
    args = (jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
            jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
            jnp.asarray(a["evict"]))
    kw = dict(n_fns=tr.n_functions, capacity=16, queue_cap=1024)
    jax.block_until_ready(simulate_esff_jax(*args, **kw)["completion"])
    t0 = time.perf_counter()
    jax.block_until_ready(simulate_esff_jax(*args, **kw)["completion"])
    t_jx = time.perf_counter() - t0
    rows.append(dict(name="jax_sim_5k", us_per_call=t_jx * 1e6,
                     derived=f"{len(tr) / t_jx:.0f} req/s"))

    # vmap sweep: 8 hysteresis betas in one device call
    betas = np.linspace(1.0, 3.0, 8)

    def run_beta(beta):
        return simulate_esff_jax(*args, beta=beta, **kw)["completion"]

    sweep = jax.jit(jax.vmap(run_beta))
    jax.block_until_ready(sweep(jnp.asarray(betas)))
    t0 = time.perf_counter()
    jax.block_until_ready(sweep(jnp.asarray(betas)))
    t_sw = time.perf_counter() - t0
    rows.append(dict(
        name="jax_sim_vmap8_sweep", us_per_call=t_sw * 1e6,
        derived=f"{8 * len(tr) / t_sw:.0f} req/s aggregate"))
    return rows


def main():
    rows = run()
    emit(rows, ("name", "us_per_call", "derived"))


if __name__ == "__main__":
    main()
