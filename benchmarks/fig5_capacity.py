"""Paper Fig. 5: mean response / slowdown / cold-start time vs edge
server capacity (8..32) for ESFF and the baselines.

All six policies (FaasCache included, via its GREEDY-DUAL kernel) sweep
every capacity in batched device calls (`repro.core.jax_engine.sweep`,
capacities as vmapped slot masks) in streaming-metrics mode — no
Python-engine fallback. p99 is histogram-derived (exact to one
~1.33x log bin).
"""
from __future__ import annotations

from benchmarks.common import (POLICIES, default_trace, emit,
                               enable_compilation_cache)
from repro.core.jax_engine import sweep

CAPACITIES = (8, 12, 16, 20, 24, 28, 32)


def run(seed: int = 0):
    tr = default_trace(seed)
    n = len(tr)
    vec = sweep(tr, policies=POLICIES, capacities=CAPACITIES,
                queue_cap=4096)
    if int(vec["overflow"].sum()) or int(vec["stalled"].sum()):
        raise RuntimeError("fig5 sweep overflowed/stalled — raise "
                           "queue_cap")
    rows = []
    for ci, cap in enumerate(CAPACITIES):
        for pi, policy in enumerate(POLICIES):
            cell = {k: vec[k][pi, 0, ci, 0]
                    for k in ("mean_response", "mean_slowdown",
                              "cold_time", "cold_starts",
                              "p99_response")}
            rows.append(dict(
                capacity=cap, policy=policy,
                mean_response=float(cell["mean_response"]),
                mean_slowdown=float(cell["mean_slowdown"]),
                cold_time_per_request=float(cell["cold_time"]) / n,
                cold_starts=int(cell["cold_starts"]),
                p99=float(cell["p99_response"]),
            ))
    return rows


def main():
    enable_compilation_cache()
    rows = run()
    emit(rows, rows[0].keys())
    # the paper's headline: ESFF vs the best baseline per capacity
    print()
    for cap in CAPACITIES:
        here = {r["policy"]: r["mean_response"] for r in rows
                if r["capacity"] == cap}
        base = min(v for k, v in here.items()
                   if k not in ("esff", "esff_h"))
        gain = 100 * (1 - here["esff"] / base)
        print(f"# capacity {cap}: ESFF vs best baseline: {gain:+.1f}%")
    return rows


if __name__ == "__main__":
    main()
