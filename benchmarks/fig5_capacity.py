"""Paper Fig. 5: mean response / slowdown / cold-start time vs edge
server capacity (8..32) for ESFF and the baselines.

The five vectorised policies sweep every capacity in one batched device
call each (`repro.core.jax_engine.sweep`, capacities as vmapped slot
masks); FaasCache has no JAX kernel yet and stays on the Python engine.
"""
from __future__ import annotations

from benchmarks.common import (POLICIES, VEC_POLICIES, default_trace,
                               emit, run_policy)
from repro.core.jax_engine import sweep

CAPACITIES = (8, 12, 16, 20, 24, 28, 32)


def run(seed: int = 0):
    tr = default_trace(seed)
    n = len(tr)
    vec = sweep(tr, policies=VEC_POLICIES, capacities=CAPACITIES,
                queue_cap=4096)
    if int(vec["overflow"].sum()) or int(vec["stalled"].sum()):
        raise RuntimeError("fig5 sweep overflowed/stalled — raise "
                           "queue_cap")
    rows = []
    for ci, cap in enumerate(CAPACITIES):
        for policy in POLICIES:
            if policy in VEC_POLICIES:
                pi = VEC_POLICIES.index(policy)
                cell = {k: vec[k][pi, 0, ci, 0]
                        for k in ("mean_response", "mean_slowdown",
                                  "cold_time", "cold_starts",
                                  "p99_response")}
                rows.append(dict(
                    capacity=cap, policy=policy,
                    mean_response=float(cell["mean_response"]),
                    mean_slowdown=float(cell["mean_slowdown"]),
                    cold_time_per_request=float(cell["cold_time"]) / n,
                    cold_starts=int(cell["cold_starts"]),
                    p99=float(cell["p99_response"]),
                ))
            else:
                r = run_policy(tr, policy, cap)
                rows.append(dict(
                    capacity=cap, policy=policy,
                    mean_response=r.mean_response,
                    mean_slowdown=r.mean_slowdown,
                    cold_time_per_request=r.cold_time_per_request,
                    cold_starts=r.server.cold_starts,
                    p99=r.percentile(99),
                ))
    return rows


def main():
    rows = run()
    emit(rows, rows[0].keys())
    # the paper's headline: ESFF vs the best baseline per capacity
    print()
    for cap in CAPACITIES:
        here = {r["policy"]: r["mean_response"] for r in rows
                if r["capacity"] == cap}
        base = min(v for k, v in here.items()
                   if k not in ("esff", "esff_h"))
        gain = 100 * (1 - here["esff"] / base)
        print(f"# capacity {cap}: ESFF vs best baseline: {gain:+.1f}%")


if __name__ == "__main__":
    main()
