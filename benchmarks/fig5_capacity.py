"""Paper Fig. 5: mean response / slowdown / cold-start time vs edge
server capacity (8..32) for ESFF and the baselines.

All six policies (FaasCache included, via its GREEDY-DUAL kernel) sweep
every capacity through one `repro.api.ExperimentSpec` (capacities as
vmapped slot masks, streaming-metrics mode). p99 is histogram-derived
(exact to one ~1.33x log bin).
"""
from __future__ import annotations

from benchmarks.common import (POLICIES, default_trace_source, emit,
                               enable_compilation_cache)
from repro.api import ExperimentSpec, run_experiment

CAPACITIES = (8, 12, 16, 20, 24, 28, 32)


def run(seed: int = 0):
    src = default_trace_source(seed)
    spec = ExperimentSpec(traces=[src], policies=POLICIES,
                          capacities=CAPACITIES, queue_cap=4096)
    rs = run_experiment(spec).check()
    n = rs.meta["n_requests"]
    rows = []
    for cap in CAPACITIES:
        for policy in POLICIES:
            cell = rs.sel(policy=policy, capacity=cap)
            rows.append(dict(
                capacity=cap, policy=policy,
                mean_response=cell.value("mean_response"),
                mean_slowdown=cell.value("mean_slowdown"),
                cold_time_per_request=cell.value("cold_time") / n,
                cold_starts=int(cell.value("cold_starts")),
                p99=cell.value("p99_response"),
            ))
    return rows


def main():
    enable_compilation_cache()
    rows = run()
    emit(rows, rows[0].keys())
    # the paper's headline: ESFF vs the best baseline per capacity
    print()
    for cap in CAPACITIES:
        here = {r["policy"]: r["mean_response"] for r in rows
                if r["capacity"] == cap}
        base = min(v for k, v in here.items()
                   if k not in ("esff", "esff_h"))
        gain = 100 * (1 - here["esff"] / base)
        print(f"# capacity {cap}: ESFF vs best baseline: {gain:+.1f}%")
    return rows


if __name__ == "__main__":
    main()
