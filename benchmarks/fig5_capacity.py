"""Paper Fig. 5: mean response / slowdown / cold-start time vs edge
server capacity (8..32) for ESFF and the baselines."""
from __future__ import annotations

from benchmarks.common import POLICIES, default_trace, emit, run_policy

CAPACITIES = (8, 12, 16, 20, 24, 28, 32)


def run(seed: int = 0):
    rows = []
    for cap in CAPACITIES:
        for policy in POLICIES:
            tr = default_trace(seed)
            r = run_policy(tr, policy, cap)
            rows.append(dict(
                capacity=cap, policy=policy,
                mean_response=r.mean_response,
                mean_slowdown=r.mean_slowdown,
                cold_time_per_request=r.cold_time_per_request,
                cold_starts=r.server.cold_starts,
                p99=r.percentile(99),
            ))
    return rows


def main():
    rows = run()
    emit(rows, rows[0].keys())
    # the paper's headline: ESFF vs the best baseline per capacity
    print()
    for cap in CAPACITIES:
        here = {r["policy"]: r["mean_response"] for r in rows
                if r["capacity"] == cap}
        base = min(v for k, v in here.items()
                   if k not in ("esff", "esff_h"))
        gain = 100 * (1 - here["esff"] / base)
        print(f"# capacity {cap}: ESFF vs best baseline: {gain:+.1f}%")


if __name__ == "__main__":
    main()
