"""Goodput and SLO attainment under request-level failure injection.

The resilience question the churn figure cannot ask: when individual
*requests* fail (crashes, injected faults, timeouts) rather than whole
nodes, how much goodput does a retry policy buy back — and what does
load-shedding admission control cost in offered work? The surface is
fail_prob x retry-policy x K: each (fail_prob, retry) pair is one
`repro.api.ExperimentSpec` (fault knobs are spec-level), whose
``cluster`` axis carries jsq2 topologies at K in {1, 4, 8} with
``node_capacity = AGG // K``, a scalar deadline for the SLO fold, and
``on_overflow="shed"`` so pressure from retries degrades goodput
instead of erroring the run.

Emitted per (fail_prob, retry, K): goodput (done/N), SLO attainment,
mean response, retried/shed/failed_exhausted counts. A second, timed
pass records per-(router, K) ``req_s`` rows (``resil_<router>_K<n>``,
plus a ``resil_breaker_K4`` circuit-breaker row) — the
BENCH_<stamp>.json throughput trajectory of the resilience rail,
gated by ``benchmarks/run.py --baseline``.

    PYTHONPATH=src python -m benchmarks.fig_resilience [--quick]
        [--agg 32] [--deadline 0.35]
"""
from __future__ import annotations

import argparse

from benchmarks.common import (bench_repeats, default_trace_source,
                               emit, enable_compilation_cache, timed)
from repro.api import (ClusterSpec, ExperimentSpec, RetryPolicy,
                       run_experiment)

AGG = 32                      # fixed aggregate slot budget
KS = (1, 4, 8)
ROUTER = "jsq2"
FAIL_PROBS = (0.0, 0.05, 0.15, 0.3)
RETRIES = (
    ("no_retry", RetryPolicy(max_attempts=1)),
    ("retry3", RetryPolicy(max_attempts=3, base=0.05, cap=1.0)),
    ("retry3_jitter", RetryPolicy(max_attempts=3, base=0.05, cap=1.0,
                                  jitter=0.3)),
)
DEADLINE = 0.35
QUEUE_CAP = 1 << 15
FAIL_SEED = 99
# the timed pass pins one mid-curve fault point per (router, K)
BENCH_FAIL_PROB = 0.15
BENCH_RETRY = RETRIES[1][1]


def _entries(router, ks, agg):
    return [ClusterSpec(n_nodes=k, router=router,
                        node_capacity=(agg // k,) * k)
            for k in ks if agg % k == 0]


def run(seed: int = 0, ks=KS, agg=AGG, fail_probs=FAIL_PROBS,
        retries=RETRIES, deadline=DEADLINE, head=None):
    src = default_trace_source(seed)
    if head:
        src = src.head(head)
    entries = _entries(ROUTER, ks, agg)
    rows = []
    for fp in fail_probs:
        for rname, rp in retries:
            rs = run_experiment(ExperimentSpec(
                traces=[src], policies=("esff",), capacities=(agg,),
                queue_cap=QUEUE_CAP, deadlines=deadline,
                cluster=entries, fail_prob=fp, retry=rp,
                on_overflow="shed", fail_seed=FAIL_SEED)).check()
            n = rs.meta["n_requests"]
            for e in entries:
                cell = rs.sel(cluster=e.label)
                rows.append(dict(
                    fail_prob=fp, retry=rname, n_nodes=e.n_nodes,
                    node_capacity=agg // e.n_nodes,
                    goodput=cell.value("goodput"),
                    slo_attainment=cell.value("slo_attainment"),
                    mean_response=cell.value("mean_response"),
                    retried=int(cell.value("retried")),
                    shed=int(cell.value("shed")),
                    failed_exhausted=int(
                        cell.value("failed_exhausted")),
                    n_requests=n,
                ))
    return rows, src, entries


def throughput_rows(src, agg, ks=KS, deadline=DEADLINE,
                    queue_cap=QUEUE_CAP):
    """Timed per-(router, K) re-runs of the resilience rail at the
    pinned mid-curve fault point (jit warm from the figure pass,
    size-scaled best-of-k): the ``req_s`` rows
    `benchmarks/run.py --baseline` regression-gates alongside the
    cluster and churn curves."""
    rows = []
    entries = _entries(ROUTER, ks, agg)
    entries += [ClusterSpec(n_nodes=4, router="breaker",
                            node_capacity=(agg // 4,) * 4)]
    for e in entries:
        spec = ExperimentSpec(
            traces=[src], policies=("esff",), capacities=(agg,),
            queue_cap=queue_cap, deadlines=deadline, cluster=[e],
            fail_prob=BENCH_FAIL_PROB, retry=BENCH_RETRY,
            on_overflow="shed", fail_seed=FAIL_SEED)
        warm = run_experiment(spec)          # warm this topology
        n = warm.meta["n_requests"]
        rs, dt = timed(run_experiment, spec, repeats=bench_repeats(n))
        rows.append(dict(
            name=f"resil_{e.router}_K{e.n_nodes}", router=e.router,
            n_nodes=e.n_nodes, n_requests=n, us_per_call=dt * 1e6,
            req_s=n / dt, derived=f"{n / dt:.0f} req/s"))
    return rows


def main(argv=None):
    enable_compilation_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 fail probs, 2 retries, K in (1, 4), "
                         "4k-request head")
    ap.add_argument("--agg", type=int, default=AGG)
    ap.add_argument("--deadline", type=float, default=DEADLINE)
    args = ap.parse_args(argv)
    fps = (0.0, 0.15) if args.quick else FAIL_PROBS
    retries = RETRIES[:2] if args.quick else RETRIES
    ks = (1, 4) if args.quick else KS
    head = 4000 if args.quick else None

    rows, src, _ = run(ks=ks, agg=args.agg, fail_probs=fps,
                       retries=retries, deadline=args.deadline,
                       head=head)
    emit(rows, rows[0].keys())
    print()
    for rname, _ in retries:
        curve = {x["fail_prob"]: x["goodput"] for x in rows
                 if x["retry"] == rname and x["n_nodes"] == ks[-1]}
        pts = "  ".join(f"p={p}:{g:.3f}"
                        for p, g in sorted(curve.items()))
        print(f"# goodput K={ks[-1]} under {rname}: {pts}")
    tp = throughput_rows(src, args.agg, ks=ks,
                         deadline=args.deadline)
    print()
    emit(tp, tp[0].keys())
    return rows + tp


if __name__ == "__main__":
    main()
