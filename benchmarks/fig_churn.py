"""SLO attainment under node churn: K edge nodes with staggered
availability windows.

The robustness question the steady-state scale-out figure cannot ask:
when nodes blink in and out (maintenance, mobility, failures), how
much SLO attainment does each dynamic router preserve — and what does
the churn-aware event rail cost in raw throughput? One
`repro.api.ExperimentSpec` declares the surface: every (router, K)
topology carries `PeriodicChurn` windows on nodes 1..K-1 (node 0
stays up so requests are always routable), heterogeneous per-node
network delays so ``slo_aware`` has signal, and a scalar deadline so
every cell folds `deadline_miss` / `slo_attainment`.

Emitted per (router, K, policy): SLO attainment, mean response,
deadline-miss count, cold-start fraction. A second, timed pass
records per-(router, K) ``req_s`` rows (``churn_<router>_K<n>``) —
the BENCH_<stamp>.json throughput trajectory of the churn rail,
gated by ``benchmarks/run.py --baseline``.

    PYTHONPATH=src python -m benchmarks.fig_churn [--quick]
        [--agg 32] [--deadline 0.35] [--policies esff,sff]
"""
from __future__ import annotations

import argparse

from benchmarks.common import (bench_repeats, default_trace_source,
                               emit, enable_compilation_cache, timed)
from repro.api import (ClusterSpec, ExperimentSpec, PeriodicChurn,
                       run_experiment)

AGG = 32                      # fixed aggregate slot budget
KS = (2, 4, 8)
ROUTERS = ("jsq2", "cold_aware", "slo_aware")
POLICIES = ("esff", "sff")
DEADLINE = 0.35
QUEUE_CAP = 1 << 15
# one availability cycle per minute, node up 70% of it; phases stagger
# so outages roll around the cluster instead of aligning
CHURN_PERIOD = 60.0
CHURN_DUTY = 0.7


def _entries(routers, ks, agg):
    out = []
    for r in routers:
        for k in ks:
            if agg % k:
                continue
            churn = (None,) + tuple(
                PeriodicChurn(period=CHURN_PERIOD, duty=CHURN_DUTY,
                              phase=i * CHURN_PERIOD / k)
                for i in range(1, k))
            delays = tuple(0.004 * i / max(k - 1, 1) for i in range(k))
            out.append(ClusterSpec(
                n_nodes=k, router=r, node_capacity=(agg // k,) * k,
                net_delay=delays, churn=churn))
    return out


def run(seed: int = 0, routers=ROUTERS, ks=KS, agg=AGG,
        policies=POLICIES, deadline=DEADLINE, head=None):
    src = default_trace_source(seed)
    if head:
        src = src.head(head)
    entries = _entries(routers, ks, agg)
    spec = ExperimentSpec(traces=[src], policies=policies,
                          capacities=(agg,), queue_cap=QUEUE_CAP,
                          deadlines=deadline, cluster=entries)
    rs = run_experiment(spec).check()
    n = rs.meta["n_requests"]
    rows = []
    for e in entries:
        for policy in policies:
            cell = rs.sel(policy=policy, cluster=e.label)
            rows.append(dict(
                router=e.router, n_nodes=e.n_nodes,
                node_capacity=agg // e.n_nodes, policy=policy,
                slo_attainment=cell.value("slo_attainment"),
                mean_response=cell.value("mean_response"),
                deadline_miss=int(cell.value("deadline_miss").sum()),
                cold_frac=cell.value("cold_starts") / n,
            ))
    return rows, src, entries


def throughput_rows(src, entries, agg, deadline=DEADLINE,
                    queue_cap=QUEUE_CAP):
    """Timed per-(router, K) re-runs of the churn rail (jit warm from
    the figure pass, size-scaled best-of-k): the ``req_s`` rows
    `benchmarks/run.py --baseline` regression-gates alongside the
    no-churn cluster curve."""
    rows = []
    for e in entries:
        spec = ExperimentSpec(traces=[src], policies=("esff",),
                              capacities=(agg,), queue_cap=queue_cap,
                              deadlines=deadline, cluster=[e])
        warm = run_experiment(spec)          # warm this topology
        n = warm.meta["n_requests"]
        rs, dt = timed(run_experiment, spec, repeats=bench_repeats(n))
        rows.append(dict(
            name=f"churn_{e.router}_K{e.n_nodes}", router=e.router,
            n_nodes=e.n_nodes, n_requests=n, us_per_call=dt * 1e6,
            req_s=n / dt, derived=f"{n / dt:.0f} req/s"))
    return rows


def main(argv=None):
    enable_compilation_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 routers, K in (2, 4), 4k-request head")
    ap.add_argument("--agg", type=int, default=AGG)
    ap.add_argument("--deadline", type=float, default=DEADLINE)
    ap.add_argument("--policies", default=",".join(POLICIES))
    args = ap.parse_args(argv)
    routers = ("jsq2", "slo_aware") if args.quick else ROUTERS
    ks = (2, 4) if args.quick else KS
    head = 4000 if args.quick else None
    policies = tuple(args.policies.split(","))

    rows, src, entries = run(routers=routers, ks=ks, agg=args.agg,
                             policies=policies,
                             deadline=args.deadline, head=head)
    emit(rows, rows[0].keys())
    print()
    for r in routers:
        curve = {x["n_nodes"]: x["slo_attainment"] for x in rows
                 if x["router"] == r and x["policy"] == policies[0]}
        pts = "  ".join(f"K={k}:{v:.3f}"
                        for k, v in sorted(curve.items()))
        print(f"# {policies[0]} SLO attainment under {r} churn: {pts}")
    tp = throughput_rows(src, entries, args.agg,
                         deadline=args.deadline)
    print()
    emit(tp, tp[0].keys())
    return rows + tp


if __name__ == "__main__":
    main()
