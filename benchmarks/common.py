"""Shared benchmark machinery: the paper's default evaluation setup and
CSV emission."""
from __future__ import annotations

import csv
import os
import sys
import time
from typing import Dict, Iterable, List

from repro.core import simulate
from repro.traces import synth_azure_arrays, synth_azure_trace
# re-exported for benchmark entry points: call it from main(), not at
# import — the persistent cache must stay scoped to engine workloads
# (see repro/utils/jit_cache.py on deserialized donated-buffer steps)
from repro.utils.jit_cache import enable_compilation_cache  # noqa: F401

# Paper §VI-A defaults (scaled for CPU wall-time; full-scale via env)
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
N_REQUESTS = int(30_000 * SCALE)
N_FUNCTIONS = 200
CAPACITY = 16
# every policy has a vectorised kernel (repro.core.jax_policies), so
# figure sweeps run entirely in batched device calls — no Python-engine
# fallback split since the FaasCache GREEDY-DUAL kernel landed
POLICIES = ("esff", "esff_h", "sff", "openwhisk", "faascache",
            "openwhisk_v2")
TRACE_KW = dict(utilization=0.2, exec_median=0.1, exec_sigma=1.4,
                burst_frac=0.3)


def default_trace(seed: int = 0, **kw):
    params = dict(TRACE_KW)
    params.update(kw)
    return synth_azure_trace(n_functions=N_FUNCTIONS,
                             n_requests=N_REQUESTS, seed=seed, **params)


def default_trace_arrays(seed: int = 0, n_requests: int = None, **kw):
    """Columnar default trace (no Request objects) — the fast path for
    large-N engine benchmarks."""
    params = dict(TRACE_KW)
    params.update(kw)
    return synth_azure_arrays(
        n_functions=N_FUNCTIONS,
        n_requests=N_REQUESTS if n_requests is None else n_requests,
        seed=seed, **params)


def run_policy(trace, policy: str, capacity: int = CAPACITY):
    # simulate() resets per-request state, so traces are reusable as-is
    return simulate(trace, policy, capacity)


def emit(rows: List[Dict], header: Iterable[str], out=None) -> None:
    out = out or sys.stdout
    w = csv.DictWriter(out, fieldnames=list(header))
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.6g}" if isinstance(v, float) else v)
                    for k, v in r.items()})


def timed(fn, *args, repeats: int = 3, **kw):
    best = float("inf")
    result = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return result, best
