"""Shared benchmark machinery: the paper's default evaluation setup.

Trace provenance goes through `repro.api.TraceSource` —
`default_trace_source` declares the shared benchmark stream (synthetic
Azure-like by default, a real Azure-2021 npz slice when configured)
and every figure script lowers it through `repro.api.ExperimentSpec`.
The old ``REPRO_AZURE_NPZ`` environment variable still works as a
*deprecated* fallback that constructs an `NpzTrace`; pass
``--azure-npz``/a source explicitly in new code.
"""
from __future__ import annotations

import csv
import os
import sys
import time
import warnings
from typing import Dict, Iterable, List, Optional

from repro.api import NpzTrace, SyntheticTrace, TraceSource
# re-exported for benchmark entry points: call it from main(), not at
# import — the persistent cache must stay scoped to engine workloads
# (see repro/utils/jit_cache.py on deserialized donated-buffer steps)
from repro.utils.jit_cache import enable_compilation_cache  # noqa: F401

# Paper §VI-A defaults (scaled for CPU wall-time; full-scale via env)
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
N_REQUESTS = int(30_000 * SCALE)
N_FUNCTIONS = 200
CAPACITY = 16
# every policy has a vectorised kernel (repro.core.jax_policies), so
# figure sweeps run entirely in batched device calls — no Python-engine
# fallback split since the FaasCache GREEDY-DUAL kernel landed
POLICIES = ("esff", "esff_h", "sff", "openwhisk", "faascache",
            "openwhisk_v2")
TRACE_KW = dict(utilization=0.2, exec_median=0.1, exec_sigma=1.4,
                burst_frac=0.3)

_WARNED_ENV = False


def _deprecated_env_npz() -> Optional[str]:
    """The ``REPRO_AZURE_NPZ`` fallback (deprecated: declare an
    `NpzTrace` instead)."""
    global _WARNED_ENV
    path = os.environ.get("REPRO_AZURE_NPZ", "")
    if path and not _WARNED_ENV:
        _WARNED_ENV = True
        warnings.warn(
            "REPRO_AZURE_NPZ is deprecated; construct "
            "repro.api.NpzTrace(path) (or pass --trace/--azure-npz "
            "where a benchmark offers it) instead",
            DeprecationWarning, stacklevel=3)
    return path or None


def default_trace_source(seed: int = 0, n_requests: Optional[int] = None,
                         **kw) -> TraceSource:
    """The shared benchmark trace, as a declarative `TraceSource`.

    Synthetic Azure-like by default (`SyntheticTrace` over `TRACE_KW`
    with the paper's §VI-A scale). The deprecated ``REPRO_AZURE_NPZ``
    env var substitutes the real Azure-2021 slice when ``n_requests``
    is None (explicit sizes — the engine-scale N-curve tiers — stay
    synthetic); generator knobs are then ignored. Sources cache their
    materialised arrays, so figures sharing one source pay the
    generation/load cost once.
    """
    npz = _deprecated_env_npz()
    if npz and n_requests is None:
        return NpzTrace(path=npz)
    params = dict(TRACE_KW)
    params.update(kw)
    return SyntheticTrace.make(
        n_functions=N_FUNCTIONS,
        n_requests=N_REQUESTS if n_requests is None else n_requests,
        seed=seed, **params)


_TRACE_CACHE: dict = {}


def default_trace(seed: int = 0, **kw):
    """`repro.core.request.Trace` view of `default_trace_source` (the
    Python event engine's representation; cached per source — the
    ablation loops call this repeatedly and rebuilding 10^4+ Request
    objects per call costs seconds)."""
    src = default_trace_source(seed, **kw)
    if src not in _TRACE_CACHE:
        _TRACE_CACHE[src] = src.to_trace()
    return _TRACE_CACHE[src]


def emit(rows: List[Dict], header: Iterable[str], out=None) -> None:
    out = out or sys.stdout
    w = csv.DictWriter(out, fieldnames=list(header))
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.6g}" if isinstance(v, float) else v)
                    for k, v in r.items()})


def timed(fn, *args, repeats: int = 3, **kw):
    best = float("inf")
    result = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return result, best


def bench_repeats(n_requests: int) -> int:
    """Best-of-k repeat count for a timed benchmark row, scaled to the
    row's size: small rows have sub-second walls that flap most under
    shared CPUs, so they get the most repeats. Shared by every
    figure's timed pass so the ``--baseline`` regression gate sees the
    same de-flaking everywhere."""
    return (5 if n_requests <= 30_000
            else 3 if n_requests <= 300_000 else 2)
