"""Shared benchmark machinery: the paper's default evaluation setup and
CSV emission."""
from __future__ import annotations

import csv
import os
import sys
import time
from typing import Dict, Iterable, List

from repro.traces import synth_azure_arrays, synth_azure_trace
# re-exported for benchmark entry points: call it from main(), not at
# import — the persistent cache must stay scoped to engine workloads
# (see repro/utils/jit_cache.py on deserialized donated-buffer steps)
from repro.utils.jit_cache import enable_compilation_cache  # noqa: F401

# Paper §VI-A defaults (scaled for CPU wall-time; full-scale via env)
SCALE = float(os.environ.get("REPRO_BENCH_SCALE", "1.0"))
N_REQUESTS = int(30_000 * SCALE)
N_FUNCTIONS = 200
CAPACITY = 16
# every policy has a vectorised kernel (repro.core.jax_policies), so
# figure sweeps run entirely in batched device calls — no Python-engine
# fallback split since the FaasCache GREEDY-DUAL kernel landed
POLICIES = ("esff", "esff_h", "sff", "openwhisk", "faascache",
            "openwhisk_v2")
TRACE_KW = dict(utilization=0.2, exec_median=0.1, exec_sigma=1.4,
                burst_frac=0.3)


def azure_npz_path():
    """Path of a preprocessed real Azure-2021 npz slice, if configured
    (``REPRO_AZURE_NPZ``; produced by scripts/prepare_azure_trace.py —
    see docs/azure_trace.md)."""
    return os.environ.get("REPRO_AZURE_NPZ", "")


def load_trace_npz_arrays(path):
    """Columnar arrays of a ``Trace.load_npz``-format npz (the engine's
    fast path — no Request objects)."""
    import numpy as np
    with np.load(path) as z:
        return {k: z[k] for k in ("fn_id", "arrival", "exec_time",
                                  "cold_start", "evict")}


_NPZ_TRACE_CACHE: dict = {}


def default_trace(seed: int = 0, **kw):
    """The shared benchmark trace. With ``REPRO_AZURE_NPZ`` set, the
    real Azure 2021 slice is loaded instead (``seed``/generator knobs
    are then ignored; per-figure ``head``/scale knobs still apply).
    The npz Trace is cached per path — figure scripts call this inside
    their sweep loops, and rebuilding 6e5 Request objects per call
    costs seconds each time."""
    npz = azure_npz_path()
    if npz:
        if npz not in _NPZ_TRACE_CACHE:
            from repro.core.request import Trace
            _NPZ_TRACE_CACHE[npz] = Trace.load_npz(npz)
        return _NPZ_TRACE_CACHE[npz]
    params = dict(TRACE_KW)
    params.update(kw)
    return synth_azure_trace(n_functions=N_FUNCTIONS,
                             n_requests=N_REQUESTS, seed=seed, **params)


def default_trace_arrays(seed: int = 0, n_requests: int = None, **kw):
    """Columnar default trace (no Request objects) — the fast path for
    large-N engine benchmarks. ``REPRO_AZURE_NPZ`` substitutes the real
    slice only when ``n_requests`` is None (explicit sizes — the
    engine-scale N-curve tiers — stay synthetic)."""
    npz = azure_npz_path()
    if npz and n_requests is None:
        return load_trace_npz_arrays(npz)
    params = dict(TRACE_KW)
    params.update(kw)
    return synth_azure_arrays(
        n_functions=N_FUNCTIONS,
        n_requests=N_REQUESTS if n_requests is None else n_requests,
        seed=seed, **params)


def emit(rows: List[Dict], header: Iterable[str], out=None) -> None:
    out = out or sys.stdout
    w = csv.DictWriter(out, fieldnames=list(header))
    w.writeheader()
    for r in rows:
        w.writerow({k: (f"{v:.6g}" if isinstance(v, float) else v)
                    for k, v in r.items()})


def timed(fn, *args, repeats: int = 3, **kw):
    best = float("inf")
    result = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return result, best
