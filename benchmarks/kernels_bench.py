"""Kernel microbenchmarks: wall time per call (interpret mode on CPU —
correctness-path timing; compiled-TPU numbers come from the roofline)
plus the XLA-path equivalents for speedup context."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _t(fn, *args, repeats=3, **kw):
    fn(*args, **kw)  # compile/warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def run():
    rows = []

    q = jnp.asarray(RNG.normal(size=(1, 512, 8, 128)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 512, 2, 128)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 512, 2, 128)), jnp.float32)
    rows.append(dict(name="flash_attention_512_pallas_interp",
                     us_per_call=_t(ops.flash_attention, q, k, v,
                                    interpret=True),
                     derived="B1xS512xH8xD128 GQA4"))
    rref = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    rows.append(dict(name="flash_attention_512_xla_ref",
                     us_per_call=_t(rref, q, k, v),
                     derived="same shape, naive softmax"))

    kc = jnp.asarray(RNG.normal(size=(4, 2048, 2, 128)), jnp.float32)
    vc = jnp.asarray(RNG.normal(size=(4, 2048, 2, 128)), jnp.float32)
    qd = jnp.asarray(RNG.normal(size=(4, 1, 8, 128)), jnp.float32)
    rows.append(dict(name="decode_attention_2k_pallas_interp",
                     us_per_call=_t(ops.decode_attention, qd, kc, vc,
                                    jnp.int32(2000), interpret=True),
                     derived="B4xT2048 cache"))

    x = jnp.asarray(RNG.normal(size=(8, 512, 1024)), jnp.float32)
    w = jnp.ones((1024,), jnp.float32)
    rows.append(dict(name="rmsnorm_pallas_interp",
                     us_per_call=_t(ops.rmsnorm, x, w, interpret=True),
                     derived="(8,512,1024)"))
    rows.append(dict(name="rmsnorm_xla_ref",
                     us_per_call=_t(jax.jit(ref.rmsnorm_ref), x, w),
                     derived="same shape"))

    b, nc, c, h, p, n = 1, 8, 64, 4, 64, 128
    xs = jnp.asarray(RNG.normal(size=(b, nc, c, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, nc, c, h)), jnp.float32)
    A = -jnp.ones((h,), jnp.float32)
    cum = jnp.cumsum(dt * A, axis=2)
    B = jnp.asarray(RNG.normal(size=(b, nc, c, h, n)), jnp.float32)
    C = jnp.asarray(RNG.normal(size=(b, nc, c, h, n)), jnp.float32)
    rows.append(dict(name="ssd_chunk_pallas_interp",
                     us_per_call=_t(ops.ssd_chunk, xs, dt, cum, B, C,
                                    interpret=True),
                     derived=f"b{b} nc{nc} c{c} h{h} p{p} n{n}"))

    F = 65536
    te = jnp.asarray(RNG.uniform(0.001, 10, F), jnp.float32)
    tl = jnp.asarray(RNG.uniform(0.5, 1.5, F), jnp.float32)
    tv = jnp.asarray(RNG.uniform(0.5, 1.5, F), jnp.float32)
    nw = jnp.asarray(RNG.integers(0, 4, F), jnp.int32)
    K = jnp.asarray(RNG.integers(0, 3, F), jnp.int32)
    rows.append(dict(name="frp_select_64k_pallas_interp",
                     us_per_call=_t(ops.frp_select, te, tl, tv, nw, K,
                                    1.0, 7, interpret=True),
                     derived="Azure-fleet 64k functions"))
    rows.append(dict(name="frp_select_64k_xla_ref",
                     us_per_call=_t(jax.jit(ref.frp_select_ref), te, tl,
                                    tv, nw, K, 1.0, 7),
                     derived="same"))
    return rows


def main():
    rows = run()
    emit(rows, ("name", "us_per_call", "derived"))
    return rows


if __name__ == "__main__":
    main()
