"""Paper Fig. 7: response-time and slowdown CDFs (+P95/P99 table)."""
from __future__ import annotations

from benchmarks.common import (CAPACITY, POLICIES, default_trace, emit,
                               run_policy)


def run(seed: int = 0, points: int = 20):
    rows, pct = [], []
    for policy in POLICIES:
        tr = default_trace(seed)
        r = run_policy(tr, policy, CAPACITY)
        xs, ys = r.cdf("responses", points)
        for x, y in zip(xs, ys):
            rows.append(dict(policy=policy, response=float(x),
                             cdf=float(y)))
        pct.append(dict(policy=policy,
                        p50=r.percentile(50), p95=r.percentile(95),
                        p99=r.percentile(99),
                        p99_slowdown=r.percentile(99, "slowdowns")))
    return rows, pct


def main():
    rows, pct = run()
    emit(pct, pct[0].keys())
    print()
    emit(rows, rows[0].keys())


if __name__ == "__main__":
    main()
