"""Paper Fig. 7: response-time and slowdown CDFs (+P95/P99 table).

Runs every policy through the engine's *exact* per-request mode via
`ExperimentSpec(stream=False, keep_per_request=True)` — the
distribution tail needs per-request records, which is precisely what
exact mode keeps and the streaming mode folds into its histogram.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import (CAPACITY, POLICIES,
                               default_trace_source, emit,
                               enable_compilation_cache)
from repro.api import ExperimentSpec, run_experiment


def _cdf(values: np.ndarray, points: int):
    xs = np.quantile(values, np.linspace(0, 1, points))
    ys = np.linspace(0, 1, points)
    return xs, ys


def run(seed: int = 0, points: int = 20):
    src = default_trace_source(seed)
    exec_time = src.arrays()["exec_time"]
    spec = ExperimentSpec(traces=[src], policies=POLICIES,
                          capacities=(CAPACITY,), queue_cap=4096,
                          stream=False, keep_per_request=True)
    rs = run_experiment(spec).check()
    rows, pct = [], []
    for policy in POLICIES:
        resp = rs.value("response", policy=policy)
        slow = resp / np.maximum(exec_time, 1e-9)
        xs, ys = _cdf(resp, points)
        for x, y in zip(xs, ys):
            rows.append(dict(policy=policy, response=float(x),
                             cdf=float(y)))
        pct.append(dict(policy=policy,
                        p50=float(np.percentile(resp, 50)),
                        p95=float(np.percentile(resp, 95)),
                        p99=float(np.percentile(resp, 99)),
                        p99_slowdown=float(np.percentile(slow, 99))))
    return rows, pct


def main():
    enable_compilation_cache()
    rows, pct = run()
    emit(pct, pct[0].keys())
    print()
    emit(rows, rows[0].keys())
    return pct


if __name__ == "__main__":
    main()
