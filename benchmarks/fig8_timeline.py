"""Paper Fig. 8: per-minute detail of ESFF over a 20k-request window —
request count, mean exec and mean response per arrival minute."""
from __future__ import annotations

from benchmarks.common import CAPACITY, default_trace, emit, run_policy


def run(seed: int = 0, window: int = 20_000):
    tr = default_trace(seed).head(window)
    r = run_policy(tr, "esff", CAPACITY)
    tl = r.timeline(60.0)
    rows = [dict(minute=int(m), n_requests=int(n),
                 mean_exec=float(e), mean_response=float(mr))
            for m, n, e, mr in zip(tl["minute"], tl["n_requests"],
                                   tl["mean_exec"], tl["mean_response"])
            if n > 0]
    return rows


def main():
    rows = run()
    emit(rows, rows[0].keys())
    # the paper's observation: bursts (count x size) drive response time
    import numpy as np
    n = np.array([r["n_requests"] for r in rows], float)
    resp = np.array([r["mean_response"] for r in rows])
    corr = np.corrcoef(n, resp)[0, 1]
    print(f"# corr(request-count, response) = {corr:.3f}")
    return rows


if __name__ == "__main__":
    main()
