"""Paper Fig. 8: per-minute detail of ESFF over a 20k-request window —
request count, mean exec and mean response per arrival minute.

Declares the window as a `TraceSource.head` view and rides the
engine's streaming minute-binned accumulator
(`ExperimentSpec(tl_bins=...)`: the same per-event fold as the
response histogram, so the carried state stays O(bins)). Bin means
agree with `repro.core.metrics.timeline` to float rounding — the
engine is request-for-request equivalent and both divide per-bin sums
by per-bin counts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import CAPACITY, default_trace_source, emit
from repro.api import ExperimentSpec, run_experiment


def run(seed: int = 0, window: int = 20_000, bucket: float = 60.0):
    src = default_trace_source(seed).head(window)
    n_bins = int(src.arrays()["arrival"].max() // bucket) + 1
    spec = ExperimentSpec(traces=[src], policies=("esff",),
                          capacities=(CAPACITY,), queue_cap=4096,
                          tl_bins=n_bins, tl_bucket=bucket)
    rs = run_experiment(spec).check()
    cnt = np.asarray(rs.value("tl_count", policy="esff"), np.int64)
    rsum = rs.value("tl_resp_sum", policy="esff")
    esum = rs.value("tl_exec_sum", policy="esff")
    nz = cnt > 0
    return [dict(minute=int(m), n_requests=int(n),
                 mean_exec=float(e / n), mean_response=float(r / n))
            for m, n, e, r in zip(np.nonzero(nz)[0], cnt[nz],
                                  esum[nz], rsum[nz])]


def main():
    rows = run()
    emit(rows, rows[0].keys())
    # the paper's observation: bursts (count x size) drive response time
    n = np.array([r["n_requests"] for r in rows], float)
    resp = np.array([r["mean_response"] for r in rows])
    corr = np.corrcoef(n, resp)[0, 1]
    print(f"# corr(request-count, response) = {corr:.3f}")
    return rows


if __name__ == "__main__":
    main()
