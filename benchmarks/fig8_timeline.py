"""Paper Fig. 8: per-minute detail of ESFF over a 20k-request window —
request count, mean exec and mean response per arrival minute.

Runs on the vectorised engine's streaming minute-binned accumulator
(``tl_bins``: the same per-event fold as the response histogram, so the
carried state stays O(bins) and the Python event engine is no longer
needed here). Bin means agree with `repro.core.metrics.timeline` to
float rounding — the engine is request-for-request equivalent and both
divide per-bin sums by per-bin counts.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import CAPACITY, default_trace, emit
from repro.core.jax_engine import sweep


def run(seed: int = 0, window: int = 20_000, bucket: float = 60.0):
    tr = default_trace(seed).head(window)
    a = tr.to_arrays()
    n_bins = int(a["arrival"].max() // bucket) + 1
    out = sweep(tr, policies=("esff",), capacities=(CAPACITY,),
                queue_cap=4096, stream=True, tl_bins=n_bins,
                tl_bucket=bucket)
    if int(out["overflow"].sum()) or int(out["stalled"].sum()):
        raise RuntimeError("fig8 engine run overflowed/stalled")
    cnt = np.asarray(out["tl_count"][0, 0, 0, 0], np.int64)
    rsum = np.asarray(out["tl_resp_sum"][0, 0, 0, 0])
    esum = np.asarray(out["tl_exec_sum"][0, 0, 0, 0])
    nz = cnt > 0
    return [dict(minute=int(m), n_requests=int(n),
                 mean_exec=float(e / n), mean_response=float(r / n))
            for m, n, e, r in zip(np.nonzero(nz)[0], cnt[nz],
                                  esum[nz], rsum[nz])]


def main():
    rows = run()
    emit(rows, rows[0].keys())
    # the paper's observation: bursts (count x size) drive response time
    n = np.array([r["n_requests"] for r in rows], float)
    resp = np.array([r["mean_response"] for r in rows])
    corr = np.corrcoef(n, resp)[0, 1]
    print(f"# corr(request-count, response) = {corr:.3f}")
    return rows


if __name__ == "__main__":
    main()
