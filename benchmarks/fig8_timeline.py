"""Paper Fig. 8: per-minute detail of ESFF over a 20k-request window —
request count, mean exec and mean response per arrival minute, plus
the telemetry-bus panels (queue depth, warm occupancy, utilization).

Declares the window as a `TraceSource.head` view and rides two
independent observability rails at once:

* the engine's streaming minute-binned accumulator
  (``ExperimentSpec(tl_bins=...)``: the same per-event fold as the
  response histogram, so the carried state stays O(bins)) — the
  paper's count/exec/response panels;
* the trace-event metrics bus (``trace_events=True`` +
  `ResultSet.timeline`) — per-bin queue depth, warm-instance
  occupancy and utilization, reconstructed host-side from the
  in-loop event stream.

The two rails are cross-checked per bin: the bus's arrival counts
must equal the engine's ``tl_count`` exactly (both bin by arrival
time), which gates the event stream's completeness on every full
benchmark run.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import CAPACITY, default_trace_source, emit
from repro.api import ExperimentSpec, run_experiment


def run(seed: int = 0, window: int = 20_000, bucket: float = 60.0):
    src = default_trace_source(seed).head(window)
    n_bins = int(src.arrays()["arrival"].max() // bucket) + 1
    spec = ExperimentSpec(traces=[src], policies=("esff",),
                          capacities=(CAPACITY,), queue_cap=4096,
                          tl_bins=n_bins, tl_bucket=bucket,
                          trace_events=True)
    rs = run_experiment(spec).check()
    cnt = np.asarray(rs.value("tl_count", policy="esff"), np.int64)
    rsum = rs.value("tl_resp_sum", policy="esff")
    esum = rs.value("tl_exec_sum", policy="esff")

    # telemetry-bus panels from the in-loop event stream
    tl = rs.timeline(bucket=bucket, policy="esff")
    arr_bus = tl["arrivals"].sum(axis=1).astype(np.int64)
    if not np.array_equal(arr_bus[:n_bins], cnt[: len(arr_bus)]):
        raise RuntimeError(
            "fig8: metrics-bus arrival counts disagree with the "
            "engine's tl_count accumulator")

    nz = cnt > 0
    rows = []
    for m in np.nonzero(nz)[0]:
        n = int(cnt[m])
        rows.append(dict(
            minute=int(m), n_requests=n,
            mean_exec=float(esum[m] / n),
            mean_response=float(rsum[m] / n),
            queue_depth=float(np.nan_to_num(tl["queue_total"][m])),
            warm=float(np.nan_to_num(tl["warm"][m])),
            # already normalised by slot count: ResultSet.timeline
            # feeds the cell's capacity coordinate through
            utilization=float(tl["utilization"][m].sum())))
    return rows


def main():
    rows = run()
    emit(rows, rows[0].keys())
    # the paper's observation: bursts (count x size) drive response time
    n = np.array([r["n_requests"] for r in rows], float)
    resp = np.array([r["mean_response"] for r in rows])
    corr = np.corrcoef(n, resp)[0, 1]
    print(f"# corr(request-count, response) = {corr:.3f}")
    util = np.array([r["utilization"] for r in rows])
    print(f"# telemetry bus: peak queue depth "
          f"{max(r['queue_depth'] for r in rows):.0f}, "
          f"peak utilization {util.max():.2f}")
    return rows


if __name__ == "__main__":
    main()
