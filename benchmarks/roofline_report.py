"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from
results/dryrun.jsonl (so the report regenerates from artifacts)."""
from __future__ import annotations

import json
import sys
from collections import defaultdict
from pathlib import Path


def load(path="results/dryrun.jsonl"):
    rows = []
    seen = {}
    for line in Path(path).read_text().splitlines():
        r = json.loads(line)
        key = (r["arch"], r["shape"], r["mesh"])
        seen[key] = r          # last occurrence wins (reruns)
    return list(seen.values())


def dryrun_table(rows):
    out = ["| arch | shape | mesh | lower+compile s | args GB/dev | "
           "temp GB/dev | collectives (top) |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"],
                                         r["mesh"])):
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"FAIL | - | - | {r['error'][:40]} |")
            continue
        m = r["memory"]
        coll = r["roofline"]["collective_breakdown"]
        top = sorted(coll.items(), key=lambda kv: -kv[1])[:2]
        tops = ", ".join(f"{k} {v:.1f}GB" for k, v in top if v > 0.01) \
            or "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['lower_s']:.0f}+{r['compile_s']:.0f} | "
            f"{m['argument_size_in_bytes'] / 2**30:.1f} | "
            f"{m['temp_size_in_bytes'] / 2**30:.1f} | {tops} |")
    return "\n".join(out)


def roofline_table(rows, mesh="pod16x16"):
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flop | roofline step s | MFU @ roofline |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh or "error" in r:
            continue
        f = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {f['compute_s']:.4g} | "
            f"{f['memory_s']:.4g} | {f['collective_s']:.4g} | "
            f"**{f['dominant']}** | {f['useful_flop_ratio']:.2f} | "
            f"{f['step_time_s']:.4g} | {f['mfu']:.2e} |")
    return "\n".join(out)


def main():
    rows = load(sys.argv[1] if len(sys.argv) > 1 else
                "results/dryrun.jsonl")
    print("## Dry-run matrix\n")
    print(dryrun_table(rows))
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
