"""Engine scaling with trace length: req/s at N in {3e4, 3e5, 1e6}.

The streaming engine carries O(F + C + SEG + HIST_BINS) state per
lane regardless of N (jax_engine perf-contract rule 4), so a
10^6-request synthetic Azure stream — the scale of the paper's §VI
Azure evaluation and beyond — runs through the batched grid on one CPU.
Traces come from the columnar generator (`synth_azure_arrays`); Request
objects are never materialised.

    PYTHONPATH=src python -m benchmarks.engine_scale [--quick]

``--quick`` stops at 3e5 requests (CI-friendly); the default sweeps the
full 10^6. REPRO_SCALE_POLICIES overrides the policy set.
"""
from __future__ import annotations

import argparse
import os
import time

from benchmarks.common import (default_trace_arrays, emit,
                               enable_compilation_cache)
from repro.core.jax_engine import sweep

NS = (30_000, 300_000, 1_000_000)
POLICIES = tuple(os.environ.get(
    "REPRO_SCALE_POLICIES", "esff,sff,openwhisk").split(","))
CAPACITY = 16
# a backlog bound, not storage: positional queues carry O(F) cursors
# whatever the cap, and a 10^6-request bursty trace really does queue
# >4096 requests behind one hot function at times
QUEUE_CAP = 1 << 17


def run(ns=NS, policies=POLICIES):
    rows = []
    for n in ns:
        t0 = time.perf_counter()
        arrs = default_trace_arrays(seed=0, n_requests=n)
        t_gen = time.perf_counter() - t0
        for policy in policies:
            # one warm pass per (policy, N) jit specialisation, then
            # the timed pass
            kw = dict(policies=(policy,), capacities=(CAPACITY,),
                      queue_cap=QUEUE_CAP, stream=True)
            sweep(arrs, **kw)
            t0 = time.perf_counter()
            out = sweep(arrs, **kw)
            dt = time.perf_counter() - t0
            bad = (int(out["overflow"].sum())
                   or int(out["stalled"].sum()))
            if bad:
                raise RuntimeError(
                    f"engine_scale {policy} N={n} overflowed/stalled "
                    "— raise queue_cap")
            rows.append(dict(
                name=f"{policy}_N{n}", n_requests=n, policy=policy,
                us_per_call=dt * 1e6, req_s=n / dt,
                mean_response=float(out["mean_response"][0, 0, 0, 0]),
                p99_response=float(out["p99_response"][0, 0, 0, 0]),
                derived=f"{n / dt:.0f} req/s (gen {t_gen:.1f}s)"))
    return rows


def main(argv=None):
    enable_compilation_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="stop at 3e5 requests")
    args = ap.parse_args(argv)
    ns = tuple(n for n in NS if n <= 300_000) if args.quick else NS
    rows = run(ns=ns)
    emit(rows, ("name", "n_requests", "policy", "us_per_call", "req_s",
                "mean_response", "p99_response", "derived"))
    return rows


if __name__ == "__main__":
    main()
