"""Engine scaling with trace length: the req/s N-curve from 1e4 to 1e6.

The streaming engine carries O(F + C + HIST_BINS) state per
lane regardless of N (jax_engine perf-contract rule 4) and reads the
trace through cache-windowed slabs (rule 6), so a 10^6-request
synthetic Azure stream — the scale of the paper's §VI Azure evaluation
and beyond — runs through the batched grid on one CPU at a roughly
flat per-request cost. Traces are declared as `repro.api` sources
(synthetic generator specs; Request objects are never materialised)
and lowered through `ExperimentSpec`.

    PYTHONPATH=src python -m benchmarks.engine_scale [--quick]
        [--window W] [--trace azure.npz] [--devices D]

``--quick`` stops at 3e5 requests (CI-friendly); the default sweeps the
full 10^6-tier curve. ``--window`` overrides the engine's cache-window
size (results are bitwise window-invariant; only throughput moves).
``--trace`` additionally runs the policies over a preprocessed real
Azure-2021 npz slice (scripts/prepare_azure_trace.py — see
docs/azure_trace.md). ``--devices`` caps the runner's local-device
sharding (default: all). REPRO_SCALE_POLICIES overrides the policy
set.
"""
from __future__ import annotations

import argparse
import os
import time

import numpy as np

from benchmarks.common import (bench_repeats, default_trace_source,
                               emit, enable_compilation_cache, timed)
from repro.api import ExperimentSpec, NpzTrace, run_experiment
from repro.core.jax_engine import DEFAULT_WINDOW, resolve_lane_chunk

NS = (10_000, 30_000, 100_000, 300_000, 1_000_000)
POLICIES = tuple(os.environ.get(
    "REPRO_SCALE_POLICIES", "esff,sff,openwhisk").split(","))
CAPACITY = 16
# a backlog bound, not storage: positional queues carry O(F) cursors
# whatever the cap, and a 10^6-request bursty trace really does queue
# >4096 requests behind one hot function at times
QUEUE_CAP = 1 << 17


def _run_one(src, policy, *, name, window, devices, t_gen=0.0):
    """One warm pass per jit specialisation (timed, so the BENCH row
    records the compile cost separately), then best-of-k timed
    passes — k scales down with N because the small-N rows finish in
    ~50-150 ms, where single-pass timing flaps by 30%+ under shared
    CPUs and trips the 20% regression gate spuriously."""
    spec = ExperimentSpec(traces=[src], policies=(policy,),
                          capacities=(CAPACITY,), queue_cap=QUEUE_CAP,
                          stream=True, window=window, devices=devices)
    t0 = time.perf_counter()
    run_experiment(spec)                  # warm pass: compile + run
    cold = time.perf_counter() - t0
    rs, dt = timed(run_experiment, spec,
                   repeats=bench_repeats(src.n_requests))
    n = rs.meta["n_requests"]
    rs.check()
    return dict(
        name=f"{policy}_{name}", n_requests=n, policy=policy,
        # record the *effective* window so BENCH provenance does not
        # depend on whether the default was spelled out
        window=(window or DEFAULT_WINDOW),
        us_per_call=dt * 1e6, req_s=n / dt,
        # first-call wall minus steady-state wall ≈ trace+lower+compile
        # (0 when the persistent jit cache already held the program)
        compile_s=max(cold - dt, 0.0), run_s=dt,
        mean_response=rs.value("mean_response", policy=policy),
        p99_response=rs.value("p99_response", policy=policy),
        derived=f"{n / dt:.0f} req/s (gen {t_gen:.1f}s)")


MULTI_N = 250_000
MULTI_T = 4


def _run_multi(policy, *, window, devices, n=MULTI_N, t=MULTI_T):
    """T-trace stacked grid at N per row, plus the matching
    single-trace row: the pair regression-gates the multi-row
    shared-operand grouping in `repro.api.run_experiment` (without it
    the stacked (T, N) operand falls off the XLA:CPU batched-gather
    cliff and the T-row grid runs ~an order of magnitude slower than
    T single-row grids)."""
    srcs = [default_trace_source(seed=i, n_requests=n)
            for i in range(t)]
    for s in srcs:
        s.arrays()
    rows = [_run_one(srcs[0], policy, name=f"N{n}", window=window,
                     devices=devices)]
    spec = ExperimentSpec(traces=srcs, policies=(policy,),
                          capacities=(CAPACITY,), queue_cap=QUEUE_CAP,
                          stream=True, window=window, devices=devices)
    t0 = time.perf_counter()
    run_experiment(spec)
    cold = time.perf_counter() - t0
    rs, dt = timed(run_experiment, spec, repeats=3)
    rs.check()
    total = n * t
    rows.append(dict(
        name=f"{policy}_T{t}xN{n}", n_requests=total, policy=policy,
        window=(window or DEFAULT_WINDOW), us_per_call=dt * 1e6,
        req_s=total / dt,
        compile_s=max(cold - dt, 0.0), run_s=dt,
        mean_response=float(np.mean(rs.data["mean_response"])),
        p99_response=float(np.max(rs.data["p99_response"])),
        derived=f"{total / dt:.0f} req/s ({t} traces)"))
    return rows


def run(ns=NS, policies=POLICIES, window=0, trace_npz="",
        devices=None):
    rows = []
    for n in ns:
        src = default_trace_source(seed=0, n_requests=n)
        t0 = time.perf_counter()
        src.arrays()            # materialise outside the timed region
        t_gen = time.perf_counter() - t0
        for policy in policies:
            rows.append(_run_one(src, policy, name=f"N{n}",
                                 window=window, devices=devices,
                                 t_gen=t_gen))
    rows += _run_multi(policies[0], window=window, devices=devices)
    if trace_npz:
        src = NpzTrace(path=trace_npz)
        n = src.n_requests
        for policy in policies:
            rows.append(_run_one(src, policy, name=f"azure{n}",
                                 window=window, devices=devices))
    return rows


def main(argv=None):
    enable_compilation_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="stop at 3e5 requests")
    ap.add_argument("--window", type=int, default=0,
                    help="engine cache-window override (0 = default)")
    ap.add_argument("--trace", default="",
                    help="also run a real Azure-2021 npz slice")
    ap.add_argument("--devices", type=int, default=None,
                    help="cap local-device sharding (default: all)")
    args = ap.parse_args(argv)
    ns = tuple(n for n in NS if n <= 300_000) if args.quick else NS
    print(f"# lane_chunk={resolve_lane_chunk()} "
          f"window={args.window or 'default'}")
    rows = run(ns=ns, window=args.window, trace_npz=args.trace,
               devices=args.devices)
    emit(rows, ("name", "n_requests", "policy", "window", "us_per_call",
                "req_s", "compile_s", "run_s", "mean_response",
                "p99_response", "derived"))
    return rows


if __name__ == "__main__":
    main()
