"""Cluster scale-out: K = 1..64 edge nodes at fixed aggregate capacity.

The LaSS-style question the single-server paper cannot ask: given a
fixed slot budget, is it better served as one big edge server or as K
small nodes behind a router — and how much does the *router* matter
once cold starts dominate? One `repro.api.ExperimentSpec` declares the
whole surface: the ``cluster`` axis carries every (router, K) topology
with ``node_capacity = AGG // K`` per node, policies x routers x K in
a single declarative grid.

Emitted per (router, K, policy): mean/p99 response, cold-start
fraction, and the node-load imbalance (max/mean of per-node completed
requests). A second, timed pass records req/s rows per (router, K) —
the BENCH_<stamp>.json throughput trajectory of the cluster subsystem
(gated by ``benchmarks/run.py --baseline``).

    PYTHONPATH=src python -m benchmarks.fig_cluster [--quick]
        [--agg 32] [--policies esff,sff]
"""
from __future__ import annotations

import argparse

from benchmarks.common import (bench_repeats, default_trace_source,
                               emit, enable_compilation_cache, timed)
from repro.api import ClusterSpec, ExperimentSpec, run_experiment

AGG = 32                      # fixed aggregate slot budget
KS = (1, 2, 4, 8, 16, 32)
# fleet tier: K=64 single-slot nodes needs a 64-slot aggregate so
# node_capacity stays >= 1 — a second spec at its own fixed budget
AGG_FLEET = 64
KS_FLEET = (64,)
ROUTERS = ("hash", "round_robin", "jsq2", "cold_aware")
POLICIES = ("esff", "sff")
QUEUE_CAP = 1 << 15


def _entries(routers, ks, agg):
    return [ClusterSpec(n_nodes=k, router=r,
                        node_capacity=(agg // k,) * k)
            for r in routers for k in ks if agg % k == 0]


def run(seed: int = 0, routers=ROUTERS, ks=KS, agg=AGG,
        policies=POLICIES, head=None):
    src = default_trace_source(seed)
    if head:
        src = src.head(head)
    entries = _entries(routers, ks, agg)
    spec = ExperimentSpec(traces=[src], policies=policies,
                          capacities=(agg,), queue_cap=QUEUE_CAP,
                          cluster=entries)
    rs = run_experiment(spec).check()
    n = rs.meta["n_requests"]
    rows = []
    for e in entries:
        for policy in policies:
            cell = rs.sel(policy=policy, cluster=e.label)
            nd = cell.value("node_done")[: e.n_nodes]
            rows.append(dict(
                router=e.router, n_nodes=e.n_nodes,
                node_capacity=agg // e.n_nodes, policy=policy,
                mean_response=cell.value("mean_response"),
                p99_response=cell.value("p99_response"),
                cold_frac=cell.value("cold_starts") / n,
                imbalance=float(nd.max() / max(nd.mean(), 1e-9)),
            ))
    return rows, src, entries


def throughput_rows(src, entries, agg, queue_cap=QUEUE_CAP):
    """Timed per-(router, K) re-runs (jit warm from the figure pass,
    size-scaled best-of-k — sub-second walls flap under shared CPUs):
    the ``req_s`` rows `benchmarks/run.py --baseline` regression-gates
    alongside the single-node N-curve."""
    rows = []
    for e in entries:
        spec = ExperimentSpec(traces=[src], policies=("esff",),
                              capacities=(agg,), queue_cap=queue_cap,
                              cluster=[e])
        warm = run_experiment(spec)          # warm this topology
        n = warm.meta["n_requests"]
        rs, dt = timed(run_experiment, spec, repeats=bench_repeats(n))
        rows.append(dict(
            name=f"cluster_{e.router}_K{e.n_nodes}", router=e.router,
            n_nodes=e.n_nodes, n_requests=n, us_per_call=dt * 1e6,
            req_s=n / dt, derived=f"{n / dt:.0f} req/s"))
    return rows


def main(argv=None):
    enable_compilation_cache()
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="2 routers, K in (1, 4), 4k-request head")
    ap.add_argument("--agg", type=int, default=AGG)
    ap.add_argument("--policies", default=",".join(POLICIES))
    args = ap.parse_args(argv)
    routers = ("hash", "jsq2") if args.quick else ROUTERS
    ks = (1, 4) if args.quick else KS
    head = 4000 if args.quick else None
    policies = tuple(args.policies.split(","))

    rows, src, entries = run(routers=routers, ks=ks, agg=args.agg,
                             policies=policies, head=head)
    fleet_rows, _, fleet_entries = [], None, []
    if not args.quick:
        fleet_rows, _, fleet_entries = run(
            routers=routers, ks=KS_FLEET, agg=AGG_FLEET,
            policies=policies, head=head)
        rows += fleet_rows
    emit(rows, rows[0].keys())
    print()
    for r in routers:
        curve = {x["n_nodes"]: x["mean_response"] for x in rows
                 if x["router"] == r and x["policy"] == policies[0]}
        pts = "  ".join(f"K={k}:{v:.3f}s"
                        for k, v in sorted(curve.items()))
        print(f"# {policies[0]} scale-out under {r}: {pts}")
    tp = throughput_rows(src, entries, args.agg)
    if fleet_entries:
        tp += throughput_rows(src, fleet_entries, AGG_FLEET)
    print()
    emit(tp, tp[0].keys())
    return rows + tp


if __name__ == "__main__":
    main()
