"""Policy-kernel engine (`repro.core.jax_engine`): per-policy
request-for-request equivalence with the Python event engine, overflow
accounting, and the batched sweep API."""
import numpy as np
import pytest

from repro.core import simulate
from repro.core.jax_engine import (simulate_policy_from_trace,
                                   simulate_policy_jax, sweep)
from repro.traces import synth_azure_trace, trace_from_lists

VEC_POLICIES = ("esff", "esff_h", "sff", "openwhisk", "faascache",
                "openwhisk_v2")


@pytest.mark.parametrize("policy", VEC_POLICIES)
@pytest.mark.parametrize("seed,capacity,n", [(5, 8, 400), (1, 4, 300)])
def test_policy_equivalence_with_python_engine(policy, seed, capacity,
                                               n):
    tr = synth_azure_trace(n_functions=20, n_requests=n,
                           utilization=0.2, seed=seed)
    py = simulate(tr, policy, capacity=capacity)
    jx = simulate_policy_from_trace(tr, policy, capacity)
    assert int(jx["overflow"]) == 0
    assert int(jx["stalled"]) == 0
    assert int(jx["cold_starts"]) == py.server.cold_starts
    resp_py = np.array([r.response for r in tr.requests])
    np.testing.assert_allclose(jx["response"], resp_py, rtol=1e-9,
                               atol=1e-9)


def test_esff_h_default_beta_matches_python_class():
    """The esff_h kernel must carry ESFF-H's hysteresis default."""
    from repro.core.esff_h import ESFFH
    from repro.core.jax_policies import KERNELS
    assert KERNELS["esff_h"].default_beta == ESFFH.beta


def test_queue_overflow_is_reported_not_silent():
    """queue_cap saturation must surface in the overflow counter (and
    the run flagged as stalled, since dropped requests never finish)."""
    n = 12
    tr = trace_from_lists(
        fn_ids=[0] * n,
        arrivals=[0.01 * i for i in range(n)],
        exec_times=[1.0] * n,
        cold=[0.5], evict=[0.2])
    a = tr.to_arrays()
    import jax.numpy as jnp
    out = simulate_policy_jax(
        jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
        jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
        jnp.asarray(a["evict"]), policy="esff", n_fns=1, capacity=1,
        queue_cap=2)
    overflow = int(out["overflow"])
    assert overflow > 0
    assert int(out["stalled"]) == 1
    # exactly the dropped requests never complete
    assert int((np.asarray(out["completion"]) < 0).sum()) == overflow


def test_sweep_grid_matches_single_runs():
    tr1 = synth_azure_trace(n_functions=15, n_requests=250,
                            utilization=0.25, seed=11)
    tr2 = synth_azure_trace(n_functions=15, n_requests=250,
                            utilization=0.25, seed=12)
    caps = (4, 8)
    out = sweep([tr1, tr2], policies=("esff", "openwhisk"),
                capacities=caps, queue_cap=128)
    assert out["mean_response"].shape == (2, 2, 2, 1)
    assert int(out["overflow"].sum()) == 0
    assert int(out["stalled"].sum()) == 0
    for pi, p in enumerate(("esff", "openwhisk")):
        for ti, tr in enumerate((tr1, tr2)):
            for ci, c in enumerate(caps):
                single = simulate_policy_from_trace(tr, p, c,
                                                    queue_cap=128)
                np.testing.assert_allclose(
                    out["mean_response"][pi, ti, ci, 0],
                    single["mean_response"], rtol=1e-9)


def test_sweep_beta_axis():
    tr = synth_azure_trace(n_functions=15, n_requests=250,
                           utilization=0.3, seed=13)
    out = sweep(tr, policies=("esff",), capacities=(4,),
                betas=(1.0, 2.0), queue_cap=128)
    assert out["mean_response"].shape == (1, 1, 1, 2)
    base = simulate_policy_from_trace(tr, "esff", 4, beta=1.0,
                                      queue_cap=128)
    hyst = simulate_policy_from_trace(tr, "esff", 4, beta=2.0,
                                      queue_cap=128)
    np.testing.assert_allclose(out["mean_response"][0, 0, 0, 0],
                               base["mean_response"], rtol=1e-9)
    np.testing.assert_allclose(out["mean_response"][0, 0, 0, 1],
                               hyst["mean_response"], rtol=1e-9)
