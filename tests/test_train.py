"""Training substrate: loss decreases, microbatch equivalence, optimizer
numerics (incl. int8 nu quantisation), gradient compression bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.distributed.compression import psum_int8, quantize_roundtrip
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train import TrainConfig, make_train_step, synthetic_lm_batches
from repro.train.train_step import init_optimizer


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_arch("qwen3-4b").smoke().replace(n_layers=2, d_model=64,
                                               d_ff=128, vocab_size=256)
    model = build_model(cfg)
    return cfg, model


def test_loss_decreases(tiny_setup):
    cfg, model = tiny_setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    step = jax.jit(make_train_step(model, tcfg), donate_argnums=(0, 1))
    params, _ = model.init(jax.random.key(0))
    opt = init_optimizer(tcfg, params)
    losses = []
    for batch in synthetic_lm_batches(cfg, 8, 64, 30, seed=0):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_microbatch_equivalence(tiny_setup):
    """mb=1 and mb=4 must produce (nearly) the same update."""
    cfg, model = tiny_setup
    from repro.train.data import synthetic_lm_batch
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_lm_batch(cfg, 8, 32, 0).items()}
    outs = {}
    for mb in (1, 4):
        tcfg = TrainConfig(microbatches=mb,
                           optimizer=AdamWConfig(lr=1e-3))
        step = jax.jit(make_train_step(model, tcfg))
        params, _ = model.init(jax.random.key(1))
        opt = init_optimizer(tcfg, params)
        p2, _, m = step(params, opt, batch)
        outs[mb] = (p2, float(m["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-5)
    for a, b in zip(jax.tree.leaves(outs[1][0]),
                    jax.tree.leaves(outs[4][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_adamw_matches_reference_update():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, weight_decay=0.0,
                      grad_clip=0.0)
    p = {"w": jnp.ones((4, 4))}
    g = {"w": jnp.full((4, 4), 0.5)}
    st = adamw_init(cfg, p)
    p2, st2, _ = adamw_update(cfg, p, g, st)
    # step 1: mu_hat = g, nu_hat = g^2 -> delta = g/|g| = 1
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               np.ones((4, 4)) - 0.1 * (0.5 / 0.5),
                               rtol=1e-5)


def test_adamw_quantized_nu_close_to_exact():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)}
    exact = AdamWConfig(lr=1e-2, grad_clip=0.0)
    quant = AdamWConfig(lr=1e-2, grad_clip=0.0, quantize_nu=True)
    st_e, st_q = adamw_init(exact, p), adamw_init(quant, p)
    pe, pq = p, p
    for i in range(5):
        g = {"w": jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)}
        pe, st_e, _ = adamw_update(exact, pe, g, st_e)
        pq, st_q, _ = adamw_update(quant, pq, g, st_q)
    err = np.abs(np.asarray(pe["w"]) - np.asarray(pq["w"])).max()
    upd = np.abs(np.asarray(pe["w"]) - np.asarray(p["w"])).max()
    assert err < 0.12 * upd, (err, upd)   # int8 nu: small relative error


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(10_240,)), jnp.float32)
    y = quantize_roundtrip(x, block=256)
    blocks = np.asarray(x).reshape(-1, 256)
    bound = np.abs(blocks).max(1, keepdims=True) / 127.0
    err = np.abs(np.asarray(y).reshape(-1, 256) - blocks)
    assert (err <= bound + 1e-7).all()


def test_train_step_with_compression_still_learns(tiny_setup):
    cfg, model = tiny_setup
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3),
                       compress_grads=True)
    step = jax.jit(make_train_step(model, tcfg))
    params, _ = model.init(jax.random.key(2))
    opt = init_optimizer(tcfg, params)
    losses = []
    for batch in synthetic_lm_batches(cfg, 8, 64, 20, seed=3):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3


def test_psum_int8_single_device():
    # axis of size 1: psum_int8 must be a (quantised) identity
    from jax.sharding import Mesh
    import jax.numpy as jnp
    from repro.utils.compat import shard_map
    mesh = jax.make_mesh((1,), ("d",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1024,)),
                    jnp.float32)
    out = jax.jit(
        shard_map(lambda v: psum_int8(v, "d"), mesh=mesh,
                  in_specs=jax.sharding.PartitionSpec(),
                  out_specs=jax.sharding.PartitionSpec()))(x)
    err = np.abs(np.asarray(out) - np.asarray(x))
    bound = np.abs(np.asarray(x)).reshape(-1, 256).max(1) / 127.0
    assert (err.reshape(-1, 256) <= bound[:, None] + 1e-6).all()
