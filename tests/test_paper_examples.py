"""Faithfulness tests against the paper's own worked examples (Figs. 1, 4)."""
import pytest

from repro.core import simulate
from repro.traces import trace_from_lists


def fig1_trace():
    """Fig. 1: two long requests of f1 arrive before three short of f2."""
    return trace_from_lists(
        fn_ids=[0, 0, 1, 1, 1],
        arrivals=[0.0, 0.1, 1.0, 1.1, 1.2],
        exec_times=[10.0, 10.0, 0.5, 0.5, 0.5],
        cold=[1.0, 1.0], evict=[1.0, 1.0],
    )


class TestFig1:
    def test_openwhisk_blocks_short_requests(self):
        tr = fig1_trace()
        simulate(tr, "openwhisk", capacity=1, oracle_exec=True)
        order = sorted(tr.requests, key=lambda r: r.start)
        # Fig. 1(a): arrival order — r3..r5 blocked behind both long requests
        assert [r.req_id for r in order] == [0, 1, 2, 3, 4]

    def test_openwhisk_v2_still_blocks(self):
        tr = fig1_trace()
        simulate(tr, "openwhisk_v2", capacity=1, oracle_exec=True)
        order = sorted(tr.requests, key=lambda r: r.start)
        # Fig. 1(b): r2 is already waiting when r1 finishes, so f1's
        # instance keeps processing its own queue.
        assert [r.req_id for r in order] == [0, 1, 2, 3, 4]

    def test_esff_reorders_like_fig1c(self):
        tr = fig1_trace()
        simulate(tr, "esff", capacity=1, oracle_exec=True)
        order = sorted(tr.requests, key=lambda r: r.start)
        # Fig. 1(c): after r1, ESFF replaces f1 by f2 (short), then returns.
        assert [r.req_id for r in order] == [0, 2, 3, 4, 1]

    def test_esff_wins_on_mean_response(self):
        results = {}
        for p in ("openwhisk", "openwhisk_v2", "esff"):
            tr = fig1_trace()
            results[p] = simulate(tr, p, capacity=1,
                                  oracle_exec=True).mean_response
        assert results["esff"] < results["openwhisk"]
        assert results["esff"] < results["openwhisk_v2"]


class TestFig4:
    """Fig. 4: C=2; f1: r1,r2,r3; f2: r4,r5. FCP starts a second f1
    instance for r2; r3 queues; at r1's completion FRP replaces f1's
    instance with f2 (w2 < w1)."""

    def make(self):
        # f1 moderately long, f2 short; timings chosen so all Fig. 4
        # decision points occur.
        return trace_from_lists(
            fn_ids=[0, 0, 0, 1, 1],
            arrivals=[0.0, 0.5, 1.0, 1.5, 1.6],
            exec_times=[6.0, 6.0, 6.0, 0.5, 0.5],
            cold=[1.0, 1.0], evict=[0.5, 0.5],
        )

    def test_fcp_creates_second_instance_for_r2(self):
        tr = self.make()
        simulate(tr, "esff", capacity=2, oracle_exec=True)
        r1, r2 = tr.requests[0], tr.requests[1]
        # r2 must not wait for r1's instance (runs on a fresh instance
        # after its own cold start, not after r1 completes at t=7).
        assert r2.start < r1.completion

    def test_frp_replaces_for_f2_at_r1_completion(self):
        tr = self.make()
        simulate(tr, "esff", capacity=2, oracle_exec=True)
        r1 = tr.requests[0]
        r4, r5 = tr.requests[3], tr.requests[4]
        # f2's requests are served right after r1's completion + swap
        # (eviction 0.5 + cold 1.0), NOT after the second f1 instance
        # finishes r2 and r3.
        assert r4.start == pytest.approx(r1.completion + 1.5, abs=1e-6)
        assert r5.start == pytest.approx(r4.completion, abs=1e-6)
        # r3 waits for the other f1 instance (no third slot).
        r2, r3 = tr.requests[1], tr.requests[2]
        assert r3.start == pytest.approx(r2.completion, abs=1e-6)


class TestCostModel:
    def test_replacement_pays_evict_plus_cold(self):
        # Single slot: f0 request, then f1 request -> swap must cost
        # t_v(f0) + t_l(f1).
        tr = trace_from_lists(
            fn_ids=[0, 1], arrivals=[0.0, 0.1], exec_times=[1.0, 1.0],
            cold=[0.7, 1.1], evict=[0.3, 0.9])
        simulate(tr, "esff", capacity=1, oracle_exec=True)
        r0, r1 = tr.requests
        assert r0.start == pytest.approx(0.7)          # own cold start
        # r1: after r0 completes (1.7), evict f0 (0.3) + cold f1 (1.1)
        assert r1.start == pytest.approx(1.7 + 0.3 + 1.1)

    def test_first_cold_start_paid(self):
        tr = trace_from_lists([0], [0.0], [2.0], cold=[1.25], evict=[0.5])
        r = simulate(tr, "esff", capacity=1, oracle_exec=True)
        assert tr.requests[0].completion == pytest.approx(1.25 + 2.0)
        assert r.mean_response == pytest.approx(3.25)
